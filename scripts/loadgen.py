#!/usr/bin/env python3
"""Concurrent-client load generator for `habitat serve`.

Opens N connections, each driving M requests with windowed pipelining
(a mix of predict / rank / stats lines), measures per-request latency,
and prints p50/p90/p99 latency plus aggregate req/s. Results are also
written to a JSON file (default `BENCH_service.json`) so the perf
trajectory has machine-readable data points.

Exit code is non-zero if any response is dropped (a connection closed
with requests outstanding) or any reply is an error other than the
typed `overloaded` backpressure signal — `overloaded` replies are
counted and reported, not treated as failures, because they are the
bounded runtime doing its job.

With `--http` the same request mix is driven as `POST /v2` bodies over
keep-alive HTTP/1.1 connections (one request in flight per connection —
the HTTP front end is measured request/response, not pipelined), against
the server's `--http-port` front end. A `503` carrying the typed
`overloaded` body counts as backpressure, exactly like the TCP mode.

Usage:
  # against an already running server
  python3 scripts/loadgen.py --addr 127.0.0.1:7780

  # boot a private server first (CI mode), quick settings
  python3 scripts/loadgen.py --spawn target/release/habitat --quick

  # the HTTP front end (spawn mode boots the TCP listener on PORT+1)
  python3 scripts/loadgen.py --spawn target/release/habitat --quick --http
"""

import argparse
import json
import socket
import subprocess
import sys
import threading
import time

MODELS = ["mlp", "resnet50", "dcgan"]
BATCHES = [8, 16, 32]
DESTS = ["v100", "p100", "p4000", "t4", "rtx2070", "2080ti"]


def build_requests(conn_id, count):
    """A deterministic mixed workload: mostly predicts (cache-hot after
    the first round), with periodic ranks, multi-trace rank_many sweeps,
    cluster sweeps, and stats probes."""
    lines = []
    for i in range(count):
        if i % 17 == 16:
            lines.append(
                {
                    "v": 2,
                    "op": "rank_many",
                    "items": [
                        {
                            "model": MODELS[(conn_id + i + k) % len(MODELS)],
                            "batch": BATCHES[(conn_id + k) % len(BATCHES)],
                            "origin": "t4",
                        }
                        for k in range(3)
                    ],
                    "dests": DESTS[:4],
                }
            )
        elif i % 13 == 12:
            lines.append({"stats": True})
        elif i % 11 == 10:
            lines.append(
                {
                    "v": 2,
                    "op": "predict_cluster",
                    "model": MODELS[(conn_id + i) % len(MODELS)],
                    "batch": BATCHES[conn_id % len(BATCHES)],
                    "origin": "t4",
                    "dest": DESTS[(conn_id + i) % len(DESTS)],
                    "topologies": ["dgx", "cloud"],
                    "worlds": [1, 2, 4, 8],
                }
            )
        elif i % 7 == 6:
            lines.append(
                {
                    "rank": True,
                    "model": MODELS[(conn_id + i) % len(MODELS)],
                    "batch": BATCHES[conn_id % len(BATCHES)],
                    "origin": "t4",
                }
            )
        else:
            lines.append(
                {
                    "model": MODELS[(conn_id + i) % len(MODELS)],
                    "batch": BATCHES[(conn_id + i) % len(BATCHES)],
                    "origin": "t4",
                    "dest": DESTS[(conn_id + i) % len(DESTS)],
                }
            )
    return [json.dumps(obj) for obj in lines]


class ConnResult:
    def __init__(self):
        self.latencies_ms = []
        self.overloaded = 0
        self.errors = []
        self.dropped = 0


def run_connection(host, port, conn_id, requests, window, timeout, result):
    try:
        sock = socket.create_connection((host, port), timeout=timeout)
    except OSError as e:
        result.errors.append(f"conn {conn_id}: connect failed: {e}")
        result.dropped += len(requests)
        return
    sock.settimeout(timeout)
    rfile = sock.makefile("r", encoding="utf-8")
    sent = 0
    received = 0
    send_times = {}
    try:
        while received < len(requests):
            # Keep up to `window` requests in flight.
            while sent < len(requests) and sent - received < window:
                line = requests[sent]
                send_times[sent] = time.monotonic()
                sock.sendall(line.encode() + b"\n")
                sent += 1
            reply = rfile.readline()
            if not reply:
                result.dropped += sent - received
                result.errors.append(
                    f"conn {conn_id}: connection closed with {sent - received} outstanding"
                )
                return
            now = time.monotonic()
            result.latencies_ms.append((now - send_times.pop(received)) * 1e3)
            try:
                obj = json.loads(reply)
            except json.JSONDecodeError:
                result.errors.append(f"conn {conn_id}: unparseable reply: {reply[:120]!r}")
                obj = {}
            err = obj.get("error")
            if err is not None:
                code = err.get("code") if isinstance(err, dict) else None
                if code == "overloaded":
                    result.overloaded += 1
                else:
                    result.errors.append(f"conn {conn_id}: error reply: {reply.strip()[:200]}")
            received += 1
    except OSError as e:
        result.dropped += sent - received
        result.errors.append(f"conn {conn_id}: socket error: {e}")
    finally:
        try:
            sock.close()
        except OSError:
            pass


def read_http_response(rfile):
    """One HTTP/1.1 response off a buffered reader: (status, body str)."""
    status_line = rfile.readline()
    if not status_line:
        raise OSError("connection closed mid-response")
    parts = status_line.split()
    status = int(parts[1]) if len(parts) >= 2 else 0
    length = 0
    while True:
        header = rfile.readline()
        if not header:
            raise OSError("connection closed mid-headers")
        if header in (b"\r\n", b"\n"):
            break
        key, _, value = header.partition(b":")
        if key.strip().lower() == b"content-length":
            length = int(value.strip())
    body = rfile.read(length) if length else b""
    if length and len(body) < length:
        raise OSError("connection closed mid-body")
    return status, body.decode("utf-8", errors="replace")


def run_http_connection(host, port, conn_id, requests, timeout, result):
    """The HTTP twin of run_connection: same workload, same accounting,
    one keep-alive connection, request/response (no pipelining)."""
    try:
        sock = socket.create_connection((host, port), timeout=timeout)
    except OSError as e:
        result.errors.append(f"conn {conn_id}: connect failed: {e}")
        result.dropped += len(requests)
        return
    sock.settimeout(timeout)
    rfile = sock.makefile("rb")
    answered = 0
    try:
        for line in requests:
            body = line.encode()
            head = (
                f"POST /v2 HTTP/1.1\r\nHost: {host}\r\n"
                f"Content-Type: application/json\r\nContent-Length: {len(body)}\r\n\r\n"
            ).encode()
            t0 = time.monotonic()
            sock.sendall(head + body)
            status, reply = read_http_response(rfile)
            result.latencies_ms.append((time.monotonic() - t0) * 1e3)
            try:
                obj = json.loads(reply)
            except json.JSONDecodeError:
                result.errors.append(f"conn {conn_id}: unparseable reply: {reply[:120]!r}")
                obj = {}
            err = obj.get("error")
            if err is not None:
                code = err.get("code") if isinstance(err, dict) else None
                if code == "overloaded":
                    result.overloaded += 1
                else:
                    result.errors.append(
                        f"conn {conn_id}: error reply (HTTP {status}): {reply.strip()[:200]}"
                    )
            elif status != 200:
                result.errors.append(f"conn {conn_id}: HTTP {status} without an error body")
            answered += 1
    except OSError as e:
        result.dropped += len(requests) - answered
        result.errors.append(f"conn {conn_id}: socket error: {e}")
    finally:
        try:
            sock.close()
        except OSError:
            pass


def percentile(sorted_vals, p):
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(round(p / 100.0 * (len(sorted_vals) - 1))))
    return sorted_vals[idx]


def wait_for_server(host, port, proc=None, attempts=100):
    for _ in range(attempts):
        try:
            probe = socket.create_connection((host, port), timeout=1)
            probe.close()
            return True
        except OSError:
            if proc is not None and proc.poll() is not None:
                out = proc.stdout.read().decode() if proc.stdout else ""
                print(f"server exited early:\n{out}")
                return False
            time.sleep(0.1)
    return False


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--addr", default="127.0.0.1:7791", help="host:port of the server")
    ap.add_argument("--conns", type=int, default=16, help="concurrent connections")
    ap.add_argument("--requests", type=int, default=200, help="requests per connection")
    ap.add_argument("--window", type=int, default=8, help="pipelined requests in flight per connection")
    ap.add_argument("--timeout", type=float, default=120.0, help="per-socket timeout, seconds")
    ap.add_argument("--out", default="BENCH_service.json", help="JSON results path")
    ap.add_argument("--quick", action="store_true", help="small CI-sized run (8 conns x 50 reqs)")
    ap.add_argument(
        "--http",
        action="store_true",
        help="drive POST /v2 on the HTTP front end at ADDR instead of the TCP line protocol",
    )
    ap.add_argument(
        "--spawn",
        metavar="HABITAT_BIN",
        default=None,
        help="boot `HABITAT_BIN serve --addr ADDR` first and tear it down after "
        "(with --http, ADDR is the HTTP port and the TCP listener takes PORT+1)",
    )
    args = ap.parse_args()
    if args.quick:
        args.conns = min(args.conns, 8)
        args.requests = min(args.requests, 50)

    host, port = args.addr.rsplit(":", 1)
    port = int(port)

    server = None
    if args.spawn:
        cmd = [args.spawn, "serve"]
        if args.http:
            # ADDR names the HTTP front end under test; the (always-on)
            # TCP listener parks one port up.
            cmd += ["--addr", f"{host}:{port + 1}", "--http-port", str(port)]
        else:
            cmd += ["--addr", args.addr]
        server = subprocess.Popen(
            cmd,
            stdout=subprocess.PIPE,
            stderr=subprocess.STDOUT,
        )
    try:
        if not wait_for_server(host, port, server):
            print(f"loadgen: no server at {args.addr}")
            sys.exit(1)

        # Warm the trace cache so the measured run reflects steady-state
        # service latency, not first-touch tracking passes.
        warm = ConnResult()
        if args.http:
            run_http_connection(host, port, 0, build_requests(0, 8), args.timeout, warm)
        else:
            run_connection(host, port, 0, build_requests(0, 8), 1, args.timeout, warm)
        if warm.errors:
            print("loadgen: warmup failed:")
            for e in warm.errors:
                print(f"  {e}")
            sys.exit(1)

        results = [ConnResult() for _ in range(args.conns)]
        threads = []
        t0 = time.monotonic()
        for c in range(args.conns):
            if args.http:
                target, targs = run_http_connection, (
                    host, port, c, build_requests(c, args.requests), args.timeout, results[c],
                )
            else:
                target, targs = run_connection, (
                    host, port, c, build_requests(c, args.requests), args.window, args.timeout, results[c],
                )
            t = threading.Thread(target=target, args=targs)
            t.start()
            threads.append(t)
        for t in threads:
            t.join()
        elapsed = time.monotonic() - t0
    finally:
        if server is not None:
            server.terminate()
            try:
                server.wait(timeout=10)
            except subprocess.TimeoutExpired:
                server.kill()
                server.wait(timeout=10)

    latencies = sorted(x for r in results for x in r.latencies_ms)
    total = args.conns * args.requests
    answered = len(latencies)
    overloaded = sum(r.overloaded for r in results)
    dropped = sum(r.dropped for r in results)
    errors = [e for r in results for e in r.errors]

    summary = {
        "schema": "habitat-loadgen-v1",
        "config": {
            "addr": args.addr,
            "conns": args.conns,
            "requests_per_conn": args.requests,
            "pipeline_window": 1 if args.http else args.window,
            "transport": "http" if args.http else "tcp",
        },
        "totals": {
            "requests": total,
            "answered": answered,
            "overloaded": overloaded,
            "dropped": dropped,
            "errors": len(errors),
        },
        "elapsed_s": round(elapsed, 4),
        "req_per_s": round(answered / elapsed, 2) if elapsed > 0 else 0.0,
        "latency_ms": {
            "p50": round(percentile(latencies, 50), 4),
            "p90": round(percentile(latencies, 90), 4),
            "p99": round(percentile(latencies, 99), 4),
            "max": round(latencies[-1], 4) if latencies else 0.0,
        },
    }
    with open(args.out, "w", encoding="utf-8") as fh:
        json.dump(summary, fh, indent=2, sort_keys=True)
        fh.write("\n")

    lat = summary["latency_ms"]
    print(
        f"loadgen: {answered}/{total} answered in {elapsed:.2f}s "
        f"({summary['req_per_s']} req/s), latency p50 {lat['p50']:.2f} ms, "
        f"p90 {lat['p90']:.2f} ms, p99 {lat['p99']:.2f} ms; "
        f"{overloaded} overloaded, {dropped} dropped -> {args.out}"
    )
    if errors:
        print(f"loadgen FAILED: {len(errors)} non-overloaded error(s):")
        for e in errors[:20]:
            print(f"  {e}")
        sys.exit(1)
    if dropped:
        print(f"loadgen FAILED: {dropped} dropped response(s)")
        sys.exit(1)
    print("loadgen OK")


if __name__ == "__main__":
    main()
