#!/usr/bin/env python3
"""Parse `cargo bench` output from the hand-rolled harness into JSON.

The harness in `rust/src/util/bench.rs` prints one line per benchmark:

    plan/evaluate_batch_60_dests/resnet50             12.3 µs/iter (p50      11.9, p95      14.0, n=200)

This script collects those lines (from a file or stdin), writes them to
a JSON baseline (default `BENCH_predictor.json`) so the perf trajectory
has machine-readable data points PR over PR, and computes the headline
speedups the perf work is accountable for, e.g.:

    scalar_vs_batched_60_dests = plan/evaluate_60_dests / plan/evaluate_batch_60_dests
    plan_build_serial_vs_parallel = plan/build_serial / plan/build_parallel
    recompile_vs_warm_restore_zoo = engine/recompile_zoo / engine/warm_restore_zoo

Two gating knobs turn ratios into CI gates (exit non-zero on a miss):

  --min-speedup 2.0          the historical batched-evaluator gate
                             (scalar_vs_batched_60_dests >= 2.0)
  --gate LABEL:MIN           repeatable; gate any speedup label, e.g.
                             --gate plan_build_serial_vs_parallel:2.0
                             --gate recompile_vs_warm_restore_zoo:10.0

The output is stable (benches sorted by name, keys sorted) so a run's
JSON is committable as a baseline and diffs PR over PR are meaningful.

Usage:
  cargo bench --bench predictor | tee bench.txt
  python3 scripts/bench_to_json.py bench.txt --out BENCH_predictor.json \
      --min-speedup 2.0 --gate plan_build_serial_vs_parallel:2.0
"""

import argparse
import json
import re
import sys

LINE_RE = re.compile(
    r"^(?P<name>\S+)\s+(?P<mean>[\d.]+) µs/iter "
    r"\(p50\s+(?P<p50>[\d.]+), p95\s+(?P<p95>[\d.]+), n=(?P<n>\d+)\)\s*$"
)

# (label, numerator bench, denominator bench): ratio > 1 means the
# denominator (the new path) is faster.
SPEEDUPS = [
    (
        "scalar_vs_batched_60_dests",
        "plan/evaluate_60_dests/resnet50",
        "plan/evaluate_batch_60_dests/resnet50",
    ),
    (
        "legacy_walk_vs_batched_60_dests",
        "legacy/trace_walk_60_dests/resnet50",
        "plan/evaluate_batch_60_dests/resnet50",
    ),
    (
        "materialized_vs_sweep_60_dests",
        "plan/evaluate_batch_60_dests/resnet50",
        "plan/evaluate_batch_sweep_60_dests/resnet50",
    ),
    # The SIMD-lane gate: the per-destination scalar path against the
    # lane-vectorized warm-scratch sweep over the same 60 destinations
    # (CI gates this at >= 1.5x). Note this compares code paths, not
    # backends — SIMD-on vs HABITAT_SIMD=off on the same path is
    # powf-dominated and intentionally not gated.
    (
        "scalar_vs_simd_sweep",
        "plan/evaluate_60_dests/resnet50",
        "plan/evaluate_batch_simd_vs_scalar",
    ),
    (
        "plan_build_serial_vs_parallel",
        "plan/build_serial/resnet50",
        "plan/build_parallel/resnet50",
    ),
    (
        "recompile_vs_warm_restore_zoo",
        "engine/recompile_zoo",
        "engine/warm_restore_zoo",
    ),
    # Informational: the 2-topology × 9-world cluster sweep against one
    # scalar evaluate — how cheap the collective-model epilogue is on
    # top of the shared compute prediction.
    (
        "cluster_sweep_256_vs_single_dest",
        "cluster/sweep_256_ranks",
        "engine/single_dest/resnet50",
    ),
    # Informational: the HTTP dispatch entry point against the TCP line
    # entry point for the same warm predict request. Both route through
    # the one shared Dispatcher, so this should sit near 1.0 — a drift
    # would mean a transport grew its own request-handling logic.
    (
        "http_vs_tcp_dispatch",
        "service/dispatch_http_request/predict",
        "service/dispatch_tcp_line/predict",
    ),
]

# The ratio --min-speedup gates on (kept for CI-invocation stability).
GATED_SPEEDUP = "scalar_vs_batched_60_dests"


def parse(lines):
    benches = []
    for line in lines:
        m = LINE_RE.match(line.rstrip("\n"))
        if m:
            benches.append(
                {
                    "name": m.group("name"),
                    "mean_us": float(m.group("mean")),
                    "p50_us": float(m.group("p50")),
                    "p95_us": float(m.group("p95")),
                    "iters": int(m.group("n")),
                }
            )
    # Stable order regardless of harness print order, so baselines diff
    # cleanly PR over PR.
    benches.sort(key=lambda b: b["name"])
    return benches


def speedups(benches):
    by_name = {b["name"]: b for b in benches}
    out = {}
    for label, slow, fast in SPEEDUPS:
        if slow in by_name and fast in by_name and by_name[fast]["mean_us"] > 0:
            out[label] = round(by_name[slow]["mean_us"] / by_name[fast]["mean_us"], 3)
    return out


def parse_gate(spec):
    label, sep, floor = spec.rpartition(":")
    if not sep or not label:
        raise argparse.ArgumentTypeError(f"--gate wants LABEL:MIN, got {spec!r}")
    try:
        return label, float(floor)
    except ValueError as e:
        raise argparse.ArgumentTypeError(f"--gate {spec!r}: {e}") from e


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("input", nargs="?", help="bench output file (default: stdin)")
    ap.add_argument("--out", default="BENCH_predictor.json", help="JSON output path")
    ap.add_argument(
        "--min-speedup",
        type=float,
        default=None,
        help=f"fail unless {GATED_SPEEDUP} is at least this ratio",
    )
    ap.add_argument(
        "--gate",
        type=parse_gate,
        action="append",
        default=[],
        metavar="LABEL:MIN",
        help="fail unless speedup LABEL is at least MIN (repeatable)",
    )
    args = ap.parse_args()

    if args.input:
        with open(args.input, encoding="utf-8") as f:
            lines = f.readlines()
    else:
        lines = sys.stdin.readlines()

    benches = parse(lines)
    if not benches:
        print("bench_to_json: no bench lines recognized in input", file=sys.stderr)
        return 1

    doc = {
        "schema": "habitat-bench-v1",
        "source": "cargo bench --bench predictor | scripts/bench_to_json.py",
        "benches": benches,
        "speedups": speedups(benches),
    }
    with open(args.out, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"bench_to_json: wrote {len(benches)} benches to {args.out}")
    for label, ratio in sorted(doc["speedups"].items()):
        print(f"  {label}: {ratio}x")

    gates = list(args.gate)
    if args.min_speedup is not None:
        gates.append((GATED_SPEEDUP, args.min_speedup))
    failed = False
    for label, floor in gates:
        got = doc["speedups"].get(label)
        if got is None:
            print(
                f"bench_to_json: {label} not computable "
                "(missing bench lines) — failing the gate",
                file=sys.stderr,
            )
            failed = True
        elif got < floor:
            print(
                f"bench_to_json: {label} = {got}x is below the {floor}x floor",
                file=sys.stderr,
            )
            failed = True
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
