#!/usr/bin/env python3
"""Parse `cargo bench` output from the hand-rolled harness into JSON.

The harness in `rust/src/util/bench.rs` prints one line per benchmark:

    plan/evaluate_batch_60_dests/resnet50             12.3 µs/iter (p50      11.9, p95      14.0, n=200)

This script collects those lines (from a file or stdin), writes them to
a JSON baseline (default `BENCH_predictor.json`) so the perf trajectory
has machine-readable data points PR over PR, and computes the headline
speedups the batched evaluator is accountable for:

    scalar_vs_batched_60_dests = plan/evaluate_60_dests / plan/evaluate_batch_60_dests

Pass `--min-speedup 2.0` to turn that ratio into a CI gate: exit
non-zero when the batched sweep is less than 2x faster than 60 scalar
`evaluate` calls (the acceptance floor for the kernel-major refactor).

Usage:
  cargo bench --bench predictor | tee bench.txt
  python3 scripts/bench_to_json.py bench.txt --out BENCH_predictor.json --min-speedup 2.0
"""

import argparse
import json
import re
import sys

LINE_RE = re.compile(
    r"^(?P<name>\S+)\s+(?P<mean>[\d.]+) µs/iter "
    r"\(p50\s+(?P<p50>[\d.]+), p95\s+(?P<p95>[\d.]+), n=(?P<n>\d+)\)\s*$"
)

# (label, numerator bench, denominator bench): ratio > 1 means the
# denominator (the new path) is faster.
SPEEDUPS = [
    (
        "scalar_vs_batched_60_dests",
        "plan/evaluate_60_dests/resnet50",
        "plan/evaluate_batch_60_dests/resnet50",
    ),
    (
        "legacy_walk_vs_batched_60_dests",
        "legacy/trace_walk_60_dests/resnet50",
        "plan/evaluate_batch_60_dests/resnet50",
    ),
    (
        "materialized_vs_sweep_60_dests",
        "plan/evaluate_batch_60_dests/resnet50",
        "plan/evaluate_batch_sweep_60_dests/resnet50",
    ),
]

# The ratio --min-speedup gates on.
GATED_SPEEDUP = "scalar_vs_batched_60_dests"


def parse(lines):
    benches = []
    for line in lines:
        m = LINE_RE.match(line.rstrip("\n"))
        if m:
            benches.append(
                {
                    "name": m.group("name"),
                    "mean_us": float(m.group("mean")),
                    "p50_us": float(m.group("p50")),
                    "p95_us": float(m.group("p95")),
                    "iters": int(m.group("n")),
                }
            )
    return benches


def speedups(benches):
    by_name = {b["name"]: b for b in benches}
    out = {}
    for label, slow, fast in SPEEDUPS:
        if slow in by_name and fast in by_name and by_name[fast]["mean_us"] > 0:
            out[label] = round(by_name[slow]["mean_us"] / by_name[fast]["mean_us"], 3)
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("input", nargs="?", help="bench output file (default: stdin)")
    ap.add_argument("--out", default="BENCH_predictor.json", help="JSON output path")
    ap.add_argument(
        "--min-speedup",
        type=float,
        default=None,
        help=f"fail unless {GATED_SPEEDUP} is at least this ratio",
    )
    args = ap.parse_args()

    if args.input:
        with open(args.input, encoding="utf-8") as f:
            lines = f.readlines()
    else:
        lines = sys.stdin.readlines()

    benches = parse(lines)
    if not benches:
        print("bench_to_json: no bench lines recognized in input", file=sys.stderr)
        return 1

    doc = {
        "schema": "habitat-bench-v1",
        "source": "cargo bench --bench predictor | scripts/bench_to_json.py",
        "benches": benches,
        "speedups": speedups(benches),
    }
    with open(args.out, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=2, sort_keys=False)
        f.write("\n")
    print(f"bench_to_json: wrote {len(benches)} benches to {args.out}")
    for label, ratio in doc["speedups"].items():
        print(f"  {label}: {ratio}x")

    if args.min_speedup is not None:
        got = doc["speedups"].get(GATED_SPEEDUP)
        if got is None:
            print(
                f"bench_to_json: {GATED_SPEEDUP} not computable "
                "(missing bench lines) — failing the gate",
                file=sys.stderr,
            )
            return 1
        if got < args.min_speedup:
            print(
                f"bench_to_json: {GATED_SPEEDUP} = {got}x is below the "
                f"--min-speedup {args.min_speedup}x floor",
                file=sys.stderr,
            )
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
