#!/usr/bin/env python3
"""End-to-end smoke for `habitat serve`: boots the server, pipes a
scripted v1+v2 session through one TCP connection, and diffs the
responses against expectations.

Checks, in order:
  1. v1 predict and rank still answer (wave-only engine), and the v2
     envelope's payload for the same request is field-for-field
     identical to the v1 reply (the compat contract);
  2. register_device makes a new GPU immediately rankable, with the
     correct cost-normalized position and value;
  3. submit_trace -> predict-by-trace_id returns the same iter_ms as a
     v1 predict of the same (model, batch, origin, dest) — i.e. the
     uploaded-trace path is numerically identical to the in-process
     path;
  4. predict_cluster/rank_cluster/export_workload answer over the same
     connection: the sweep covers the full topology × world grid,
     world=1 equals the single-GPU predict exactly, scaling efficiency
     stays in (0, 1], the ranking is sorted, and the exported workload
     is a well-formed COMM_OPS-style schedule;
  5. rank_many answers both cached items in one multi-trace sweep, and
     each item's ranking is identical to the equivalent standalone rank;
  6. stats reflects the session's activity;
  7. malformed lines (including unknown topologies/links) produce the
     exact expected error shapes and do not kill the connection;
  8. the HTTP front end (`--http-port`) answers the same dispatcher:
     `GET /healthz`, `POST /v2` (a v1-shaped body replies field-for-field
     identically to the TCP session's v1 predict), malformed bodies get
     a structured 400, and `GET /metrics` exposes per-op request
     counters and latency histogram buckets that increase across the
     scripted HTTP session.

With `--store DIR` the server runs against the persistent plan store,
and the script boots it TWICE: the first boot runs the full session
(and, when the store directory started empty, asserts the cold-path
counters), then — after the write-behind persistence has landed — a
second boot must take the warm-restore path: the v2 stats op reports
warm_restores >= 1, the restored plan answers the same prediction
bit-for-bit, and no retracking happens. When DIR already holds records
(e.g. restored from a CI cache of a previous workflow run), even the
first boot warm-restores and the cold-only assertions are skipped.

Exit code 0 = all green. Any mismatch prints a diff-style report and
exits 1.
"""

import argparse
import glob
import http.client
import json
import os
import socket
import subprocess
import sys
import time

HOST, PORT = "127.0.0.1", 7797
HTTP_PORT = PORT + 2  # PORT + 1 is the warm-restore second boot
FAILURES = []
BUILTINS = ["P4000", "P100", "V100", "RTX2070", "RTX2080Ti", "T4"]


def check(name, cond, detail=""):
    tag = "ok" if cond else "FAIL"
    print(f"[{tag}] {name}" + (f" — {detail}" if detail and not cond else ""))
    if not cond:
        FAILURES.append(name)


def expect_eq(name, got, want):
    check(name, got == want, f"got {got!r}, want {want!r}")


def boot_server(port, store, http_port=None):
    argv = ["target/release/habitat", "serve", "--addr", f"{HOST}:{port}"]
    if store:
        argv += ["--store", store]
    if http_port:
        argv += ["--http-port", str(http_port)]
    server = subprocess.Popen(argv, stdout=subprocess.PIPE, stderr=subprocess.STDOUT)
    for _ in range(100):
        try:
            probe = socket.create_connection((HOST, port), timeout=1)
            probe.close()
            return server
        except OSError:
            if server.poll() is not None:
                out = server.stdout.read().decode()
                print(f"server exited early:\n{out}")
                sys.exit(1)
            time.sleep(0.1)
    print("server never came up")
    server.kill()
    sys.exit(1)


def stop_server(server):
    server.terminate()
    try:
        server.wait(timeout=10)
    except subprocess.TimeoutExpired:
        server.kill()
        server.wait(timeout=10)


def connect(port):
    sock = socket.create_connection((HOST, port), timeout=120)
    rfile = sock.makefile("r", encoding="utf-8")

    def rpc(obj_or_line):
        line = obj_or_line if isinstance(obj_or_line, str) else json.dumps(obj_or_line)
        sock.sendall(line.encode() + b"\n")
        reply = rfile.readline()
        assert reply, f"connection closed after: {line[:120]}"
        return json.loads(reply)

    return sock, rpc


def plan_count(store):
    return len(glob.glob(os.path.join(store, "*.plan")))


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--store", default=None, help="plan-store dir: enables the two-boot warm-restore checks")
    args = ap.parse_args()

    # Cold means the first boot cannot warm-restore anything: either no
    # store at all, or a store directory with no persisted plans yet.
    cold = args.store is None or plan_count(args.store) == 0

    server = boot_server(PORT, args.store, http_port=HTTP_PORT)
    try:
        v1_predict = run_session(PORT, cold=cold, store=args.store is not None)
        run_http_session(HTTP_PORT, v1_predict)
    finally:
        if args.store:
            # The engine persists write-behind on its worker pool; give
            # the three records (two zoo plans + one upload) time to
            # land before we pull the plug (SIGTERM skips the drain).
            deadline = time.time() + 30
            while plan_count(args.store) < 3 and time.time() < deadline:
                time.sleep(0.2)
        stop_server(server)

    if args.store:
        check("first boot persisted plan records", plan_count(args.store) >= 3, f"{plan_count(args.store)} *.plan files in {args.store}")
        run_warm_boot_checks(PORT + 1, args.store, v1_predict)

    if FAILURES:
        print(f"\nsmoke FAILED: {len(FAILURES)} check(s): {FAILURES}")
        sys.exit(1)
    print("\nsmoke OK")


def run_warm_boot_checks(port, store, v1_predict_ref):
    print(f"\n-- second boot against {store} (warm-restore path) --")
    server = boot_server(port, store)
    try:
        sock, rpc = connect(port)
        boot_stats = rpc({"v": 2, "op": "stats"})
        check(
            "second boot warm-restored persisted plans",
            boot_stats.get("warm_restores", 0) >= 3,
            str(boot_stats),
        )
        expect_eq("warm boot did no retracking at restore", boot_stats.get("trace_misses"), 0)
        pred = rpc({"model": "resnet50", "batch": 32, "origin": "rtx2070", "dest": "v100"})
        expect_eq("restored plan answers bit-identically across boots", pred, v1_predict_ref)
        after = rpc({"v": 2, "op": "stats"})
        expect_eq("restored prediction skipped the tracking pipeline", after.get("trace_misses"), 0)
        expect_eq("restored prediction compiled no plan", after.get("plan_builds"), 0)
        sock.close()
    finally:
        stop_server(server)


def metric_value(text, name, labels):
    """Value of one Prometheus sample line, e.g.
    metric_value(text, "habitat_requests_total", '{op="predict"}')."""
    prefix = f"{name}{labels} "
    for line in text.splitlines():
        if line.startswith(prefix):
            return float(line[len(prefix):])
    return None


def run_http_session(port, v1_predict_ref):
    print(f"\n-- HTTP front end on :{port} (same dispatcher, second transport) --")
    conn = http.client.HTTPConnection(HOST, port, timeout=120)

    def http_rpc(method, path, body=None):
        payload = None if body is None else (
            body if isinstance(body, str) else json.dumps(body)
        )
        conn.request(method, path, body=payload)
        resp = conn.getresponse()
        return resp.status, resp.read().decode("utf-8")

    status, body = http_rpc("GET", "/healthz")
    expect_eq("healthz status", status, 200)
    expect_eq("healthz body", body, "ok\n")

    # Baseline scrape, then a scripted session, then a second scrape:
    # the per-op counters and histogram buckets must count every request.
    status, before = http_rpc("GET", "/metrics")
    expect_eq("metrics scrape status", status, 200)
    check("metrics exposes the request counter family", "# TYPE habitat_requests_total counter" in before, before[:200])
    check("metrics exposes latency histograms", "# TYPE habitat_request_latency_ms histogram" in before, before[:200])
    p_before = metric_value(before, "habitat_requests_total", '{op="predict"}') or 0
    h_before = metric_value(before, "habitat_request_latency_ms_count", '{op="predict"}') or 0
    e_before = metric_value(before, "habitat_request_errors_total", '{op="predict"}') or 0

    # A v1-shaped body over HTTP answers field-for-field like the TCP
    # session's v1 predict (one dispatcher behind both transports).
    status, body = http_rpc(
        "POST", "/v2", {"model": "resnet50", "batch": 32, "origin": "rtx2070", "dest": "v100"}
    )
    expect_eq("HTTP v1-shaped predict status", status, 200)
    expect_eq("HTTP v1-shaped predict == TCP v1 predict", json.loads(body), v1_predict_ref)

    status, body = http_rpc(
        "POST", "/v2",
        {"v": 2, "op": "predict", "model": "resnet50", "batch": 32, "origin": "rtx2070", "dest": "v100"},
    )
    expect_eq("HTTP v2 predict status", status, 200)
    expect_eq("HTTP v2 envelope op echo", json.loads(body).get("op"), "predict")

    status, body = http_rpc("POST", "/v2", {"v": 2, "op": "stats"})
    expect_eq("HTTP v2 stats status", status, 200)
    v2_stats = json.loads(body)
    for field in ("requests", "request_errors"):
        check(f"HTTP v2 stats carries {field}", field in v2_stats, str(v2_stats)[:200])

    # Error mapping: dispatcher codes become statuses, bodies stay
    # structured.
    status, body = http_rpc("POST", "/v2", "this is not json")
    expect_eq("malformed body status", status, 400)
    expect_eq("malformed body error code", json.loads(body).get("error", {}).get("code"), "bad_request")
    status, body = http_rpc(
        "POST", "/v2", {"model": "resnet50", "batch": 8, "origin": "a100", "dest": "v100"}
    )
    expect_eq("unknown device over HTTP status", status, 400)
    expect_eq("unknown device over HTTP keeps the v1 body", json.loads(body), {"error": 'unknown origin device "a100"'})
    status, body = http_rpc("GET", "/nope")
    expect_eq("unknown endpoint status", status, 404)
    expect_eq("unknown endpoint error code", json.loads(body).get("error", {}).get("code"), "bad_request")
    status, _ = http_rpc("PUT", "/v2")
    expect_eq("wrong method status", status, 405)

    status, after = http_rpc("GET", "/metrics")
    expect_eq("second metrics scrape status", status, 200)
    p_after = metric_value(after, "habitat_requests_total", '{op="predict"}')
    h_after = metric_value(after, "habitat_request_latency_ms_count", '{op="predict"}')
    e_after = metric_value(after, "habitat_request_errors_total", '{op="predict"}')
    inf_after = metric_value(after, "habitat_request_latency_ms_bucket", '{op="predict",le="+Inf"}')
    # 3 predict requests this session (v1-shaped, v2, unknown-device),
    # one of them an error.
    expect_eq("predict counter counted the HTTP session", p_after, p_before + 3)
    expect_eq("predict histogram counted the HTTP session", h_after, h_before + 3)
    expect_eq("predict error counter counted the bad device", e_after, e_before + 1)
    expect_eq("+Inf bucket is cumulative over all requests", inf_after, h_after)
    s_after = metric_value(after, "habitat_requests_total", '{op="stats"}')
    check("stats op counted", (s_after or 0) >= 1, after[:400])

    conn.close()


def run_session(port, cold=True, store=False):
    sock, rpc = connect(port)

    # --- 1. v1 baseline + v2 payload parity ----------------------------
    v1_predict = rpc({"model": "resnet50", "batch": 32, "origin": "rtx2070", "dest": "v100"})
    check("v1 predict answers", "iter_ms" in v1_predict, str(v1_predict)[:200])
    v2_predict = rpc(
        {"v": 2, "op": "predict", "model": "resnet50", "batch": 32, "origin": "rtx2070", "dest": "v100"}
    )
    expect_eq("v2 envelope op echo", v2_predict.get("op"), "predict")
    for key, val in v1_predict.items():
        expect_eq(f"v2 predict field {key} == v1", v2_predict.get(key), val)

    v1_rank = rpc({"rank": True, "model": "resnet50", "batch": 32, "origin": "rtx2070"})
    base_names = [r["dest"] for r in v1_rank.get("ranking", [])]
    # On a warm boot the store's device log has already replayed
    # smoke-gpu into the registry, so the default rank includes it.
    want_base = BUILTINS if cold else BUILTINS + ["smoke-gpu"]
    expect_eq("v1 default rank covers the expected registry", sorted(base_names), sorted(want_base))
    v2_rank = rpc({"v": 2, "op": "rank", "model": "resnet50", "batch": 32, "origin": "rtx2070"})
    expect_eq("v2 rank payload == v1 rank", v2_rank.get("ranking"), v1_rank.get("ranking"))

    # --- 2. register_device → rankable with correct ordering -----------
    reg = rpc(
        {
            "v": 2,
            "op": "register_device",
            "name": "smoke-gpu",
            "sms": 80,
            "clock_mhz": 1530,
            "mem_bw_gbps": 900,
            "fp32_tflops": 15.7,
            "tensor_cores": True,
            "usd_per_hr": 0.05,
        }
    )
    expect_eq("register_device acks the name", reg.get("device"), "smoke-gpu")
    check("register_device assigns a fresh id", reg.get("id", -1) >= 6, str(reg))
    rank2 = rpc({"rank": True, "model": "resnet50", "batch": 32, "origin": "rtx2070"})
    names2 = [r["dest"] for r in rank2["ranking"]]
    check("registered device appears in the next rank", "smoke-gpu" in names2, str(names2))
    expect_eq(
        "other devices unchanged",
        sorted(n for n in names2 if n != "smoke-gpu"),
        sorted(n for n in base_names if n != "smoke-gpu"),
    )
    entry = next(r for r in rank2["ranking"] if r["dest"] == "smoke-gpu")
    want_cnt = entry["throughput"] / 0.05
    check(
        "cost-normalized throughput uses the registered price",
        abs(entry["cost_normalized_throughput"] - want_cnt) < 1e-6 * max(1.0, want_cnt),
        f'{entry["cost_normalized_throughput"]} vs {want_cnt}',
    )
    # V100-class silicon at $0.05/hr must out-rank every built-in on
    # samples/s/$ — registration changed the *decision*, not just the list.
    expect_eq("cost-normalized ordering puts it first", names2[0], "smoke-gpu")
    priced = [r["cost_normalized_throughput"] for r in rank2["ranking"] if r["cost_normalized_throughput"]]
    check("priced entries sorted descending", priced == sorted(priced, reverse=True), str(priced))

    replay = rpc(
        {
            "v": 2,
            "op": "register_device",
            "name": "smoke-gpu",
            "sms": 80,
            "clock_mhz": 1530,
            "mem_bw_gbps": 900,
            "fp32_tflops": 15.7,
            "tensor_cores": True,
            "usd_per_hr": 0.05,
        }
    )
    expect_eq("identical re-registration is idempotent", replay.get("id"), reg.get("id"))
    clash = rpc(
        {
            "v": 2,
            "op": "register_device",
            "name": "smoke-gpu",
            "sms": 81,  # differs from the registered spec
            "clock_mhz": 1530,
            "mem_bw_gbps": 900,
            "fp32_tflops": 15.7,
            "tensor_cores": True,
            "usd_per_hr": 0.05,
        }
    )
    expect_eq("conflicting re-registration errors", clash.get("error", {}).get("code"), "conflict")

    # --- 3. submit_trace → trace_id predictions ≡ model predictions ----
    # Track dcgan@16 on the server's own CLI to produce a trace file,
    # then upload it: the id-based prediction must equal the v1
    # model-based prediction bit-for-bit (same trace content, same
    # engine, same plan arithmetic).
    subprocess.run(
        [
            "target/release/habitat", "track", "--model", "dcgan", "--batch", "16",
            "--origin", "t4", "--out", "/tmp/smoke_trace.json",
        ],
        check=True,
        stdout=subprocess.DEVNULL,
    )
    with open("/tmp/smoke_trace.json", encoding="utf-8") as fh:
        trace = json.load(fh)
    sub = rpc({"v": 2, "op": "submit_trace", "trace": trace})
    check("submit_trace returns a content id", str(sub.get("trace_id", "")).startswith("tr-"), str(sub))
    expect_eq("submit_trace echoes the model", sub.get("model"), "dcgan")
    sub2 = rpc({"v": 2, "op": "submit_trace", "trace": trace})
    expect_eq("re-submission maps to the same id", sub2.get("trace_id"), sub.get("trace_id"))

    by_id = rpc({"v": 2, "op": "predict", "trace_id": sub["trace_id"], "dest": "v100"})
    check("trace_id predict answers", "iter_ms" in by_id, str(by_id)[:200])
    # Note: the uploaded trace was measured by a separate CLI process
    # with the same deterministic simulator, so the numbers must agree
    # with a fresh server-side track of the same (model, batch, origin).
    by_model = rpc({"model": "dcgan", "batch": 16, "origin": "t4", "dest": "v100"})
    expect_eq("trace_id iter_ms == model-path iter_ms", by_id.get("iter_ms"), by_model.get("iter_ms"))
    rank_by_id = rpc({"v": 2, "op": "rank", "trace_id": sub["trace_id"]})
    check(
        "trace_id rank includes the registered device",
        "smoke-gpu" in [r["dest"] for r in rank_by_id.get("ranking", [])],
        str(rank_by_id)[:200],
    )

    # --- 4. cluster prediction ops -------------------------------------
    # Same (model, batch, origin) as section 1, so the sweep reuses the
    # cached trace and the world=1 cell must equal the v1 predict.
    topologies = ["dgx", "cloud"]
    worlds = [1, 2, 4, 8]
    clu = rpc(
        {
            "v": 2, "op": "predict_cluster", "model": "resnet50", "batch": 32,
            "origin": "rtx2070", "dest": "v100",
            "topologies": topologies, "worlds": worlds,
        }
    )
    expect_eq("predict_cluster envelope op echo", clu.get("op"), "predict_cluster")
    configs = clu.get("configs", [])
    expect_eq("predict_cluster covers the full grid", len(configs), len(topologies) * len(worlds))
    grid = {(c["topology"], c["world"]) for c in configs}
    expect_eq(
        "every (topology, world) cell present",
        grid,
        {(t, w) for t in topologies for w in worlds},
    )
    check(
        "scaling efficiency in (0, 1]",
        all(0.0 < c["efficiency"] <= 1.0 + 1e-9 for c in configs),
        str([c["efficiency"] for c in configs]),
    )
    for c in configs:
        if c["world"] == 1:
            expect_eq(
                f'world=1 on {c["topology"]} == single-GPU predict',
                c["iter_ms"],
                v1_predict["iter_ms"],
            )
            expect_eq(f'world=1 on {c["topology"]} moves no bytes', c["comm_ms"], 0.0)

    rclu = rpc(
        {
            "v": 2, "op": "rank_cluster", "model": "resnet50", "batch": 32,
            "origin": "rtx2070", "dests": ["v100", "t4"],
            "topologies": ["dgx"], "worlds": [1, 4],
        }
    )
    entries = rclu.get("ranking", [])
    expect_eq("rank_cluster covers dests × topologies × worlds", len(entries), 4)
    rpriced = [e["cost_normalized_throughput"] for e in entries]
    check("rank_cluster entries all priced", all(v is not None for v in rpriced), str(rpriced))
    check(
        "rank_cluster sorted by cost-normalized throughput",
        rpriced == sorted(rpriced, reverse=True),
        str(rpriced),
    )

    wl = rpc(
        {
            "v": 2, "op": "export_workload", "model": "resnet50", "batch": 32,
            "origin": "rtx2070", "dest": "v100", "topology": "dgx", "world": 8,
        }
    )
    expect_eq("export_workload echoes the topology", wl.get("topology"), "dgx")
    ops = wl.get("comm_ops", [])
    check("export_workload emits a schedule", len(ops) > 0, str(wl)[:200])
    check(
        "comm ops are known collectives",
        all(o["op"] in ("ALLREDUCE", "ALLGATHER", "REDUCESCATTER", "ALLTOALL") for o in ops),
        str([o["op"] for o in ops]),
    )
    check(
        "comm ops carry positive payloads and in-range ranks",
        all(o["bytes"] > 0 and all(0 <= r < 8 for r in o["participants"]) for o in ops),
        str(ops)[:200],
    )

    # --- 5. rank_many: one multi-trace sweep ---------------------------
    # Both items are (model, batch, origin) combos the session already
    # cached, so this adds no tracking work — and each item's ranking
    # must be identical to the standalone rank of the same trace.
    many = rpc(
        {
            "v": 2, "op": "rank_many",
            "items": [
                {"model": "resnet50", "batch": 32, "origin": "rtx2070"},
                {"model": "dcgan", "batch": 16, "origin": "t4"},
            ],
        }
    )
    expect_eq("rank_many envelope op echo", many.get("op"), "rank_many")
    expect_eq("rank_many answers every item", many.get("count"), 2)
    results = many.get("results", [])
    expect_eq("rank_many result count matches items", len(results), 2)
    if len(results) == 2:
        expect_eq("rank_many echoes item models", [r.get("model") for r in results], ["resnet50", "dcgan"])
        expect_eq("rank_many[resnet50] == standalone rank", results[0].get("ranking"), rank2["ranking"])
        expect_eq("rank_many[dcgan] == standalone trace rank", results[1].get("ranking"), rank_by_id.get("ranking"))

    # --- 6. stats ------------------------------------------------------
    v1_stats = rpc({"stats": True})
    expect_eq(
        "v1 stats keeps its original seven fields",
        sorted(v1_stats.keys()),
        sorted(["trace_hits", "trace_misses", "trace_entries", "plan_builds", "wave_hits", "wave_misses", "workers"]),
    )
    v2_stats = rpc({"v": 2, "op": "stats"})
    expect_eq("stats sees the registered device", v2_stats.get("devices"), 7)
    for field in ("store_hits", "store_misses", "warm_restores", "parallel_build_chunks"):
        check(f"v2 stats carries {field}", field in v2_stats, str(v2_stats))
    if cold:
        # A warm boot restores the upload from the store (no insert) and
        # serves the session from restored plans (no tracking misses),
        # so these counters only have fixed values on a cold boot.
        expect_eq("stats counts the upload", v2_stats.get("trace_uploads"), 1)
        check("stats counted tracking work", v2_stats.get("trace_misses", 0) >= 2, str(v2_stats))
        if store:
            expect_eq("cold boot had nothing to warm-restore", v2_stats.get("warm_restores"), 0)
            check("cold boot recorded store misses", v2_stats.get("store_misses", 0) >= 2, str(v2_stats))
    else:
        check("warm boot restored persisted plans", v2_stats.get("warm_restores", 0) >= 3, str(v2_stats))
        # The upload is usually deduped against the restored record; a
        # store cached from an older commit may hold a trace the current
        # simulator no longer produces, in which case it re-uploads once.
        check("warm boot upload count sane", v2_stats.get("trace_uploads", 2) <= 1, str(v2_stats))

    # --- 7. malformed input, exact expected error shapes ---------------
    bad = rpc("this is not json")
    check("v1 parse error shape", str(bad.get("error", "")).startswith("bad request:"), str(bad))
    expect_eq(
        "unknown v1 device error",
        rpc({"model": "resnet50", "batch": 8, "origin": "a100", "dest": "v100"}),
        {"error": 'unknown origin device "a100"'},
    )
    expect_eq(
        "unsupported version error",
        rpc({"v": 7, "op": "predict"}),
        {"v": 2, "error": {"code": "unsupported_version", "message": "unsupported protocol version 7"}},
    )
    expect_eq(
        "unsupported op error",
        rpc({"v": 2, "op": "teleport"})["error"]["code"],
        "unsupported_op",
    )
    expect_eq(
        "unknown trace error",
        rpc({"v": 2, "op": "predict", "trace_id": "tr-0000000000000000", "dest": "v100"})["error"]["code"],
        "unknown_trace",
    )
    expect_eq(
        "bad embedded trace error",
        rpc({"v": 2, "op": "submit_trace", "trace": {"format": "nope"}})["error"]["code"],
        "invalid_argument",
    )
    expect_eq(
        "unknown topology error",
        rpc(
            {
                "v": 2, "op": "predict_cluster", "model": "resnet50", "batch": 32,
                "origin": "rtx2070", "dest": "v100", "topologies": ["atlantis"],
            }
        )["error"]["code"],
        "unknown_topology",
    )
    expect_eq(
        "unknown link error",
        rpc(
            {
                "v": 2, "op": "predict_cluster", "model": "resnet50", "batch": 32,
                "origin": "rtx2070", "dest": "v100",
                "topologies": [
                    {"name": "smoke-badlink", "gpus_per_node": 4, "intra": "no-such-link", "inter": "ib-hdr"}
                ],
            }
        )["error"]["code"],
        "unknown_link",
    )
    expect_eq(
        "empty rank_many items error",
        rpc({"v": 2, "op": "rank_many", "items": []})["error"]["code"],
        "invalid_argument",
    )
    expect_eq(
        "rank_many without items error",
        rpc({"v": 2, "op": "rank_many"})["error"]["code"],
        "bad_request",
    )
    expect_eq(
        "zero world size error",
        rpc(
            {
                "v": 2, "op": "rank_cluster", "model": "resnet50", "batch": 32,
                "origin": "rtx2070", "worlds": [0],
            }
        )["error"]["code"],
        "invalid_argument",
    )
    # The connection survived all of the above.
    final = rpc({"model": "resnet50", "batch": 32, "origin": "rtx2070", "dest": "v100"})
    expect_eq("connection survives; replies still deterministic", final, v1_predict)

    sock.close()
    return v1_predict


if __name__ == "__main__":
    main()
