//! Wire-protocol integration tests for the coordinator service: JSON
//! round-trips, malformed-line rejection, and the `rank` request — all
//! exercised over a real TCP connection against the wave-only engine
//! (no MLP artifacts required).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use habitat::coordinator::{
    service, Client, PredictionRequest, PredictionResponse, PredictionService, RankRequest,
    RankResponse, Request, StatsResponse,
};
use habitat::device::ALL_DEVICES;
use habitat::predict::HybridPredictor;

/// Spawn a wave-only service accepting any number of connections;
/// returns its address and a handle to the shared service.
fn spawn_server() -> (String, Arc<PredictionService>) {
    let svc = Arc::new(PredictionService::with_predictor(HybridPredictor::wave_only()));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let shared = svc.clone();
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            let svc = shared.clone();
            std::thread::spawn(move || {
                let _ = service::handle_connection(stream.unwrap(), &svc);
            });
        }
    });
    (addr, svc)
}

fn send_lines(addr: &str, lines: &[String]) -> Vec<String> {
    let stream = TcpStream::connect(addr).unwrap();
    let mut write = stream.try_clone().unwrap();
    for line in lines {
        write.write_all(line.as_bytes()).unwrap();
        write.write_all(b"\n").unwrap();
    }
    drop(write);
    BufReader::new(stream)
        .lines()
        .map(|l| l.unwrap())
        .collect()
}

#[test]
fn prediction_request_json_roundtrip() {
    let req = PredictionRequest {
        model: "gnmt".into(),
        batch: 64,
        origin: "p4000".into(),
        dest: "t4".into(),
        precision: Some("amp".into()),
    };
    let parsed = PredictionRequest::from_json(&req.to_json()).unwrap();
    assert_eq!(parsed.model, "gnmt");
    assert_eq!(parsed.batch, 64);
    assert_eq!(parsed.origin, "p4000");
    assert_eq!(parsed.dest, "t4");
    assert_eq!(parsed.precision.as_deref(), Some("amp"));
}

#[test]
fn rank_request_json_roundtrip_and_dispatch() {
    let req = RankRequest {
        model: "mlp".into(),
        batch: 8,
        origin: "t4".into(),
        precision: None,
        dests: Some(vec!["v100".into(), "p100".into()]),
    };
    match Request::from_json(&req.to_json()).unwrap() {
        Request::Rank(r) => {
            assert_eq!(r.model, "mlp");
            assert_eq!(r.dests.as_deref().unwrap().len(), 2);
        }
        other => panic!("expected rank dispatch, got {other:?}"),
    }
}

#[test]
fn malformed_lines_are_rejected_not_fatal() {
    let (addr, _svc) = spawn_server();
    let replies = send_lines(
        &addr,
        &[
            "not json at all".to_string(),
            "{\"model\":\"mlp\"}".to_string(), // missing fields
            "{\"model\":\"mlp\",\"batch\":-3,\"origin\":\"t4\",\"dest\":\"v100\"}".to_string(),
            // The connection must survive all of the above:
            "{\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\"}".to_string(),
        ],
    );
    assert_eq!(replies.len(), 4);
    assert!(replies[0].contains("bad request"));
    assert!(replies[1].contains("bad request"));
    assert!(replies[2].contains("bad request") || replies[2].contains("error"));
    let ok = PredictionResponse::from_json(&replies[3]).unwrap();
    assert!(ok.iter_ms > 0.0);
}

#[test]
fn rank_over_tcp_has_expected_shape() {
    let (addr, _svc) = spawn_server();
    let replies = send_lines(
        &addr,
        &["{\"rank\":true,\"model\":\"mlp\",\"batch\":16,\"origin\":\"t4\"}".to_string()],
    );
    let resp = RankResponse::from_json(&replies[0]).unwrap();
    assert_eq!(resp.model, "mlp");
    assert_eq!(resp.origin, "T4");
    assert!(resp.origin_iter_ms > 0.0);
    assert_eq!(resp.ranking.len(), ALL_DEVICES.len());
    let mut seen: Vec<&str> = resp.ranking.iter().map(|r| r.dest.as_str()).collect();
    seen.sort_unstable();
    let mut want: Vec<&str> = ALL_DEVICES.iter().map(|d| d.id()).collect();
    want.sort_unstable();
    assert_eq!(seen, want, "every built-in device must appear exactly once");
}

#[test]
fn rank_equals_individual_predictions_over_the_wire() {
    let (addr, svc) = spawn_server();
    let rank_line = "{\"rank\":true,\"model\":\"mlp\",\"batch\":32,\"origin\":\"p4000\"}".to_string();
    let rank = RankResponse::from_json(&send_lines(&addr, &[rank_line])[0]).unwrap();
    assert_eq!(svc.engine().stats().trace_misses, 1);

    let lines: Vec<String> = rank
        .ranking
        .iter()
        .map(|r| {
            PredictionRequest {
                model: "mlp".into(),
                batch: 32,
                origin: "p4000".into(),
                dest: r.dest.clone(),
                precision: None,
            }
            .to_json()
        })
        .collect();
    let replies = send_lines(&addr, &lines);
    for (entry, reply) in rank.ranking.iter().zip(&replies) {
        let resp = PredictionResponse::from_json(reply).unwrap();
        assert!(
            (resp.iter_ms - entry.iter_ms).abs() < 1e-9,
            "{}: rank {} vs individual {}",
            entry.dest,
            entry.iter_ms,
            resp.iter_ms
        );
    }
    // All individual requests were served from the cached trace.
    let stats = svc.engine().stats();
    assert_eq!(stats.trace_misses, 1);
    assert_eq!(stats.trace_hits as usize, rank.ranking.len());
}

#[test]
fn stats_over_the_wire_counts_cache_activity() {
    let (addr, svc) = spawn_server();
    let replies = send_lines(
        &addr,
        &[
            "{\"stats\":true}".to_string(),
            "{\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\"}".to_string(),
            "{\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"p100\"}".to_string(),
            "{\"stats\":true}".to_string(),
        ],
    );
    assert_eq!(replies.len(), 4);
    let cold = StatsResponse::from_json(&replies[0]).unwrap();
    assert_eq!((cold.trace_hits, cold.trace_misses), (0, 0));
    assert_eq!(cold.trace_entries, 0);
    let warm = StatsResponse::from_json(&replies[3]).unwrap();
    assert_eq!(warm.trace_misses, 1, "one tracking pass for both predicts");
    assert_eq!(warm.trace_hits, 1);
    assert_eq!(warm.trace_entries, 1);
    assert_eq!(warm.plan_builds, 1, "the plan is compiled once, next to the trace");
    assert_eq!(warm.workers, svc.engine().workers());
    assert!(warm.workers >= 1);
}

#[test]
fn client_stats_helper_roundtrips() {
    let (addr, _svc) = spawn_server();
    let mut client = Client::connect(&addr).unwrap();
    let cold = client.stats().unwrap();
    assert_eq!(cold.trace_misses, 0);
    client
        .predict(&PredictionRequest {
            model: "mlp".into(),
            batch: 16,
            origin: "t4".into(),
            dest: "v100".into(),
            precision: None,
        })
        .unwrap();
    let warm = client.stats().unwrap();
    assert_eq!(warm.trace_misses, 1);
    assert_eq!(warm.plan_builds, 1);
}

#[test]
fn pipelined_mixed_requests_come_back_in_order() {
    let (addr, _svc) = spawn_server();
    let replies = send_lines(
        &addr,
        &[
            "{\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\"}".to_string(),
            "{\"rank\":true,\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\"}".to_string(),
            "{\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"p100\"}".to_string(),
        ],
    );
    assert_eq!(replies.len(), 3);
    assert_eq!(PredictionResponse::from_json(&replies[0]).unwrap().dest, "V100");
    assert_eq!(
        RankResponse::from_json(&replies[1]).unwrap().ranking.len(),
        ALL_DEVICES.len()
    );
    assert_eq!(PredictionResponse::from_json(&replies[2]).unwrap().dest, "P100");
}
