//! Wire-protocol integration tests for the coordinator service: JSON
//! round-trips, malformed-line rejection, and the `rank` request — all
//! exercised over a real TCP connection against the wave-only engine
//! (no MLP artifacts required).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use habitat::coordinator::{
    service, v2_check_error, v2_export_workload_request, v2_predict_cluster_request,
    v2_predict_model_request, v2_predict_trace_request, v2_rank_cluster_request,
    v2_rank_trace_request, v2_stats_request, v2_submit_trace_request, Client, ClusterRankResponse,
    ClusterResponse, PredictionRequest, PredictionResponse, PredictionService, RankRequest,
    RankResponse, RegisteredDevice, Request, StatsResponse,
};
use habitat::device::{Device, ALL_DEVICES};
use habitat::predict::HybridPredictor;
use habitat::util::json::{self, Json};

/// Spawn a wave-only service accepting any number of connections;
/// returns its address and a handle to the shared service.
fn spawn_server() -> (String, Arc<PredictionService>) {
    let svc = Arc::new(PredictionService::with_predictor(HybridPredictor::wave_only()));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let shared = svc.clone();
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            let svc = shared.clone();
            std::thread::spawn(move || {
                let _ = service::handle_connection(stream.unwrap(), &svc);
            });
        }
    });
    (addr, svc)
}

fn send_lines(addr: &str, lines: &[String]) -> Vec<String> {
    let stream = TcpStream::connect(addr).unwrap();
    let mut write = stream.try_clone().unwrap();
    for line in lines {
        write.write_all(line.as_bytes()).unwrap();
        write.write_all(b"\n").unwrap();
    }
    // Half-close the write side so the server sees EOF and closes after
    // the final reply (dropping the clone alone leaves the socket open
    // through the read half, and this collect would never terminate).
    stream.shutdown(std::net::Shutdown::Write).unwrap();
    BufReader::new(stream)
        .lines()
        .map(|l| l.unwrap())
        .collect()
}

#[test]
fn prediction_request_json_roundtrip() {
    let req = PredictionRequest {
        model: "gnmt".into(),
        batch: 64,
        origin: "p4000".into(),
        dest: "t4".into(),
        precision: Some("amp".into()),
    };
    let parsed = PredictionRequest::from_json(&req.to_json()).unwrap();
    assert_eq!(parsed.model, "gnmt");
    assert_eq!(parsed.batch, 64);
    assert_eq!(parsed.origin, "p4000");
    assert_eq!(parsed.dest, "t4");
    assert_eq!(parsed.precision.as_deref(), Some("amp"));
}

#[test]
fn rank_request_json_roundtrip_and_dispatch() {
    let req = RankRequest {
        model: "mlp".into(),
        batch: 8,
        origin: "t4".into(),
        precision: None,
        dests: Some(vec!["v100".into(), "p100".into()]),
    };
    match Request::from_json(&req.to_json()).unwrap() {
        Request::Rank(r) => {
            assert_eq!(r.model, "mlp");
            assert_eq!(r.dests.as_deref().unwrap().len(), 2);
        }
        other => panic!("expected rank dispatch, got {other:?}"),
    }
}

#[test]
fn malformed_lines_are_rejected_not_fatal() {
    let (addr, _svc) = spawn_server();
    let replies = send_lines(
        &addr,
        &[
            "not json at all".to_string(),
            "{\"model\":\"mlp\"}".to_string(), // missing fields
            "{\"model\":\"mlp\",\"batch\":-3,\"origin\":\"t4\",\"dest\":\"v100\"}".to_string(),
            // The connection must survive all of the above:
            "{\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\"}".to_string(),
        ],
    );
    assert_eq!(replies.len(), 4);
    assert!(replies[0].contains("bad request"));
    assert!(replies[1].contains("bad request"));
    assert!(replies[2].contains("bad request") || replies[2].contains("error"));
    let ok = PredictionResponse::from_json(&replies[3]).unwrap();
    assert!(ok.iter_ms > 0.0);
}

#[test]
fn rank_over_tcp_has_expected_shape() {
    let (addr, _svc) = spawn_server();
    let replies = send_lines(
        &addr,
        &["{\"rank\":true,\"model\":\"mlp\",\"batch\":16,\"origin\":\"t4\"}".to_string()],
    );
    let resp = RankResponse::from_json(&replies[0]).unwrap();
    assert_eq!(resp.model, "mlp");
    assert_eq!(resp.origin, "T4");
    assert!(resp.origin_iter_ms > 0.0);
    // Default dests = the whole registry: at least the six built-ins,
    // each exactly once (tests in this binary may register more).
    assert!(resp.ranking.len() >= ALL_DEVICES.len());
    for d in ALL_DEVICES {
        assert_eq!(
            resp.ranking.iter().filter(|r| r.dest == d.id()).count(),
            1,
            "built-in {d} must appear exactly once"
        );
    }
}

#[test]
fn rank_equals_individual_predictions_over_the_wire() {
    let (addr, svc) = spawn_server();
    let rank_line = "{\"rank\":true,\"model\":\"mlp\",\"batch\":32,\"origin\":\"p4000\"}".to_string();
    let rank = RankResponse::from_json(&send_lines(&addr, &[rank_line])[0]).unwrap();
    assert_eq!(svc.engine().stats().trace_misses, 1);

    let lines: Vec<String> = rank
        .ranking
        .iter()
        .map(|r| {
            PredictionRequest {
                model: "mlp".into(),
                batch: 32,
                origin: "p4000".into(),
                dest: r.dest.clone(),
                precision: None,
            }
            .to_json()
        })
        .collect();
    let replies = send_lines(&addr, &lines);
    for (entry, reply) in rank.ranking.iter().zip(&replies) {
        let resp = PredictionResponse::from_json(reply).unwrap();
        assert!(
            (resp.iter_ms - entry.iter_ms).abs() < 1e-9,
            "{}: rank {} vs individual {}",
            entry.dest,
            entry.iter_ms,
            resp.iter_ms
        );
    }
    // All individual requests were served from the cached trace.
    let stats = svc.engine().stats();
    assert_eq!(stats.trace_misses, 1);
    assert_eq!(stats.trace_hits as usize, rank.ranking.len());
}

#[test]
fn stats_over_the_wire_counts_cache_activity() {
    let (addr, svc) = spawn_server();
    let replies = send_lines(
        &addr,
        &[
            "{\"stats\":true}".to_string(),
            "{\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\"}".to_string(),
            "{\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"p100\"}".to_string(),
            "{\"stats\":true}".to_string(),
        ],
    );
    assert_eq!(replies.len(), 4);
    let cold = StatsResponse::from_json(&replies[0]).unwrap();
    assert_eq!((cold.trace_hits, cold.trace_misses), (0, 0));
    assert_eq!(cold.trace_entries, 0);
    let warm = StatsResponse::from_json(&replies[3]).unwrap();
    assert_eq!(warm.trace_misses, 1, "one tracking pass for both predicts");
    assert_eq!(warm.trace_hits, 1);
    assert_eq!(warm.trace_entries, 1);
    assert_eq!(warm.plan_builds, 1, "the plan is compiled once, next to the trace");
    assert_eq!(warm.workers, svc.engine().workers());
    assert!(warm.workers >= 1);
}

#[test]
fn client_stats_helper_roundtrips() {
    let (addr, _svc) = spawn_server();
    let mut client = Client::connect(&addr).unwrap();
    let cold = client.stats().unwrap();
    assert_eq!(cold.trace_misses, 0);
    client
        .predict(&PredictionRequest {
            model: "mlp".into(),
            batch: 16,
            origin: "t4".into(),
            dest: "v100".into(),
            precision: None,
        })
        .unwrap();
    let warm = client.stats().unwrap();
    assert_eq!(warm.trace_misses, 1);
    assert_eq!(warm.plan_builds, 1);
}

#[test]
fn v2_session_over_tcp_register_submit_predict_rank_stats() {
    let (addr, svc) = spawn_server();
    let graph = habitat::models::by_name("mlp", 20).unwrap();
    let trace = habitat::tracker::OperationTracker::new(Device::Rtx2070).track(&graph);

    let replies = send_lines(
        &addr,
        &[
            // 1. register a budget GPU
            "{\"v\":2,\"op\":\"register_device\",\"name\":\"sim-proto4\",\"sms\":72,\"clock_mhz\":1455,\"mem_bw_gbps\":768,\"fp32_tflops\":19.5,\"tensor_cores\":true,\"usd_per_hr\":0.8,\"mem_gib\":24}".to_string(),
            // 2. upload a trace
            v2_submit_trace_request(&trace),
            // 3. v2 predict by model
            v2_predict_model_request("mlp", 20, "rtx2070", "v100", None),
            // 4. v2 stats
            v2_stats_request(),
        ],
    );
    assert_eq!(replies.len(), 4);

    let ack = RegisteredDevice::from_json(&replies[0]).unwrap();
    assert_eq!(ack.device, "sim-proto4");

    let submitted = json::parse(&replies[1]).unwrap();
    v2_check_error(&submitted).unwrap();
    let trace_id = submitted.req_str("trace_id").unwrap().to_string();

    let predicted = json::parse(&replies[2]).unwrap();
    v2_check_error(&predicted).unwrap();
    assert_eq!(predicted.req_str("op").unwrap(), "predict");

    let stats = json::parse(&replies[3]).unwrap();
    assert_eq!(stats.req_usize("trace_uploads").unwrap(), 1);
    assert!(stats.req_usize("devices").unwrap() > ALL_DEVICES.len());

    // Second connection: the registered device and uploaded trace are
    // server state, not connection state.
    let replies = send_lines(
        &addr,
        &[
            v2_predict_trace_request(&trace_id, "sim-proto4", None),
            v2_rank_trace_request(&trace_id, None, None),
        ],
    );
    let pred = json::parse(&replies[0]).unwrap();
    v2_check_error(&pred).unwrap();
    let wire_ms = pred.get("iter_ms").and_then(Json::as_f64).unwrap();
    // The acceptance bar: a submit_trace'd workload must produce the
    // same iter_ms as the equivalent in-process library call.
    let dest = Device::parse("sim-proto4").expect("registered on the shared in-process registry");
    let plan = svc.engine().analyze(&trace);
    let direct = svc.engine().evaluate(&plan, dest, habitat::Precision::Fp32);
    assert_eq!(
        wire_ms.to_bits(),
        direct.run_time_ms().to_bits(),
        "wire {wire_ms} vs library {}",
        direct.run_time_ms()
    );

    let ranked = json::parse(&replies[1]).unwrap();
    v2_check_error(&ranked).unwrap();
    let ranking = ranked.get("ranking").and_then(Json::as_arr).unwrap();
    assert!(
        ranking
            .iter()
            .any(|r| r.get("dest").and_then(Json::as_str) == Some("sim-proto4")),
        "registered device must appear in the default rank"
    );
    // Priced entries are in descending cost-normalized order wherever
    // the new device landed.
    let priced: Vec<f64> = ranking
        .iter()
        .filter_map(|r| r.get("cost_normalized_throughput").and_then(Json::as_f64))
        .collect();
    for w in priced.windows(2) {
        assert!(w[0] >= w[1], "cost-normalized ordering violated: {priced:?}");
    }
}

#[test]
fn v2_malformed_lines_get_structured_errors_and_v1_shape_is_unchanged() {
    let (addr, _svc) = spawn_server();
    let replies = send_lines(
        &addr,
        &[
            "{\"v\":2,\"op\":\"teleport\"}".to_string(),
            "{\"v\":2,\"op\":\"predict\",\"trace_id\":\"tr-0000000000000000\",\"dest\":\"v100\"}".to_string(),
            "{\"v\":1,\"op\":\"predict\"}".to_string(),
            // v1 lines after v2 errors still work, with the v1 shapes.
            "garbage".to_string(),
            "{\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\"}".to_string(),
        ],
    );
    assert_eq!(replies.len(), 5);
    let code_of = |line: &str| {
        json::parse(line)
            .unwrap()
            .get("error")
            .and_then(|e| e.get("code"))
            .and_then(Json::as_str)
            .map(str::to_string)
    };
    assert_eq!(code_of(&replies[0]).as_deref(), Some("unsupported_op"));
    assert_eq!(code_of(&replies[1]).as_deref(), Some("unknown_trace"));
    assert_eq!(code_of(&replies[2]).as_deref(), Some("unsupported_version"));
    assert!(replies[3].contains("bad request"), "v1 error shape: {}", replies[3]);
    assert_eq!(code_of(&replies[3]), None, "v1 errors stay plain strings");
    let ok = PredictionResponse::from_json(&replies[4]).unwrap();
    assert!(ok.iter_ms > 0.0);
}

#[test]
fn v2_predict_payload_equals_v1_response_over_tcp() {
    let (addr, _svc) = spawn_server();
    let v1_line = "{\"model\":\"gnmt\",\"batch\":16,\"origin\":\"p4000\",\"dest\":\"t4\",\"precision\":\"amp\"}";
    let replies = send_lines(
        &addr,
        &[
            v1_line.to_string(),
            v2_predict_model_request("gnmt", 16, "p4000", "t4", Some("amp")),
        ],
    );
    let v1 = json::parse(&replies[0]).unwrap();
    let v2 = json::parse(&replies[1]).unwrap();
    match &v1 {
        Json::Obj(m) => {
            for (k, val) in m {
                assert_eq!(v2.get(k), Some(val), "v2 must carry v1 field {k} bit-identically");
            }
        }
        other => panic!("v1 reply not an object: {other:?}"),
    }
}

#[test]
fn v2_predict_cluster_over_tcp_world_one_matches_predict() {
    let (addr, _svc) = spawn_server();
    let topologies = ["dgx".to_string()];
    let worlds = [1usize, 8];
    let replies = send_lines(
        &addr,
        &[
            v2_predict_model_request("mlp", 16, "t4", "v100", None),
            v2_predict_cluster_request("mlp", 16, "t4", "v100", Some(&topologies), Some(&worlds), None),
        ],
    );
    let single = json::parse(&replies[0]).unwrap();
    v2_check_error(&single).unwrap();
    let single_ms = single.get("iter_ms").and_then(Json::as_f64).unwrap();

    let cluster = ClusterResponse::from_json(&replies[1]).unwrap();
    assert_eq!(cluster.model, "mlp");
    assert_eq!(cluster.dest, "V100");
    assert_eq!(cluster.configs.len(), 2);
    let w1 = cluster.configs.iter().find(|c| c.world == 1).unwrap();
    assert_eq!(
        w1.iter_ms.to_bits(),
        single_ms.to_bits(),
        "world=1 over the wire must equal single-GPU predict: {} vs {single_ms}",
        w1.iter_ms
    );
    assert_eq!(w1.comm_ms, 0.0);
    for c in &cluster.configs {
        assert!(c.efficiency > 0.0 && c.efficiency <= 1.0 + 1e-9);
        assert!(c.iter_ms >= cluster.compute_ms - 1e-12);
    }
}

#[test]
fn v2_rank_cluster_over_tcp_is_sorted_and_complete() {
    let (addr, _svc) = spawn_server();
    let dests = ["v100".to_string(), "t4".to_string()];
    let topologies = ["dgx".to_string(), "cloud".to_string()];
    let worlds = [1usize, 4];
    let replies = send_lines(
        &addr,
        &[v2_rank_cluster_request("mlp", 16, "t4", Some(&dests), Some(&topologies), Some(&worlds), None)],
    );
    let resp = ClusterRankResponse::from_json(&replies[0]).unwrap();
    assert_eq!(resp.ranking.len(), dests.len() * topologies.len() * worlds.len());
    // Both seed dests are rentable, so every entry is priced and the
    // ranking is descending cost-normalized throughput.
    let priced: Vec<f64> = resp
        .ranking
        .iter()
        .map(|e| e.cost_normalized_throughput.expect("seed devices are priced"))
        .collect();
    for w in priced.windows(2) {
        assert!(w[0] >= w[1], "cluster ranking out of order: {priced:?}");
    }
    for (dest, topology, world) in dests.iter().flat_map(|d| {
        topologies
            .iter()
            .flat_map(move |t| worlds.iter().map(move |w| (d.clone(), t.clone(), *w)))
    }) {
        assert!(
            resp.ranking.iter().any(|e| e.dest.eq_ignore_ascii_case(&dest)
                && e.topology == topology
                && e.world == world),
            "missing cell {dest}/{topology}/{world}"
        );
    }
}

#[test]
fn v2_cluster_errors_are_structured_over_tcp() {
    let (addr, _svc) = spawn_server();
    let bad_topo = ["atlantis".to_string()];
    let replies = send_lines(
        &addr,
        &[
            v2_predict_cluster_request("mlp", 8, "t4", "v100", Some(&bad_topo), None, None),
            // Inline topology referencing a link that was never registered.
            "{\"v\":2,\"op\":\"predict_cluster\",\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\",\"topologies\":[{\"name\":\"sim-proto-badlink\",\"gpus_per_node\":4,\"intra\":\"no-such-link\",\"inter\":\"ib-hdr\"}]}".to_string(),
            v2_export_workload_request("mlp", 8, "t4", "v100", "atlantis", 4, None),
            // The connection survives all of the above.
            v2_predict_cluster_request("mlp", 8, "t4", "v100", None, Some(&[2]), None),
        ],
    );
    assert_eq!(replies.len(), 4);
    let code_of = |line: &str| {
        json::parse(line)
            .unwrap()
            .get("error")
            .and_then(|e| e.get("code"))
            .and_then(Json::as_str)
            .map(str::to_string)
    };
    assert_eq!(code_of(&replies[0]).as_deref(), Some("unknown_topology"));
    assert_eq!(code_of(&replies[1]).as_deref(), Some("unknown_link"));
    assert_eq!(code_of(&replies[2]).as_deref(), Some("unknown_topology"));
    let ok = ClusterResponse::from_json(&replies[3]).unwrap();
    assert!(!ok.configs.is_empty());
}

#[test]
fn v2_export_workload_over_tcp_round_trips() {
    let (addr, _svc) = spawn_server();
    let world = 16usize;
    let replies = send_lines(
        &addr,
        &[v2_export_workload_request("resnet50", 32, "rtx2070", "v100", "dgx", world, None)],
    );
    let reply = json::parse(&replies[0]).unwrap();
    v2_check_error(&reply).unwrap();
    assert_eq!(reply.req_str("op").unwrap(), "export_workload");
    // The envelope carries the COMM_OPS-style workload fields directly.
    let workload = habitat::comm::Workload::from_value(&reply).unwrap();
    assert_eq!(workload.model, "resnet50");
    assert_eq!(workload.world, world);
    assert!(!workload.comm_ops.is_empty());
    for op in &workload.comm_ops {
        assert!(op.bytes > 0.0);
        assert!(op.participants.iter().all(|&r| r < world));
    }
    // Lossless: dump → parse → rebuild is identical.
    let json_text = workload.to_value().dump();
    let back = habitat::comm::Workload::from_value(&json::parse(&json_text).unwrap()).unwrap();
    assert_eq!(back, workload);
}

#[test]
fn pipelined_mixed_requests_come_back_in_order() {
    let (addr, _svc) = spawn_server();
    let replies = send_lines(
        &addr,
        &[
            "{\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\"}".to_string(),
            "{\"rank\":true,\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\"}".to_string(),
            "{\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"p100\"}".to_string(),
        ],
    );
    assert_eq!(replies.len(), 3);
    assert_eq!(PredictionResponse::from_json(&replies[0]).unwrap().dest, "V100");
    assert!(RankResponse::from_json(&replies[1]).unwrap().ranking.len() >= ALL_DEVICES.len());
    assert_eq!(PredictionResponse::from_json(&replies[2]).unwrap().dest, "P100");
}
