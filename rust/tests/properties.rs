//! Property-based tests over randomized inputs.
//!
//! The image has no proptest crate, so properties are checked by
//! deterministic fuzzing: a SplitMix64 stream generates hundreds of
//! random cases per property, and failures print the offending seed.

use habitat::device::{blocks_per_sm, occupancy_fraction, wave_size, Device, LaunchConfig, ALL_DEVICES};
use habitat::lowering::{lower, Pass, Precision};
use habitat::predict::{roofline, wave};
use habitat::sim::Simulator;
use habitat::util::Rng;

fn random_launch(rng: &mut Rng) -> LaunchConfig {
    LaunchConfig::new(
        rng.int_range(1, 1 << 20),
        *rng.choose(&[32u32, 64, 128, 256, 512, 1024]),
        rng.int_range(16, 255) as u32,
        *rng.choose(&[0u32, 1024, 8 * 1024, 16 * 1024, 32 * 1024, 48 * 1024]),
    )
}

/// Occupancy: 1 ≤ blocks/SM ≤ hardware limit; wave = blocks/SM × SMs;
/// occupancy fraction ∈ (0, 1].
#[test]
fn prop_occupancy_invariants() {
    let mut rng = Rng::new(0xA11CE);
    for case in 0..2000 {
        let cfg = random_launch(&mut rng);
        for device in ALL_DEVICES {
            let spec = device.spec();
            let b = blocks_per_sm(spec, &cfg);
            assert!(b >= 1, "case {case}: zero blocks");
            assert!(b <= spec.max_blocks_per_sm, "case {case}: over block limit");
            assert!(
                b * cfg.threads_per_block <= spec.max_threads_per_sm.max(cfg.threads_per_block),
                "case {case} on {device}: thread oversubscription"
            );
            assert_eq!(wave_size(spec, &cfg), b as u64 * spec.sms as u64);
            let occ = occupancy_fraction(spec, &cfg);
            assert!(occ > 0.0 && occ <= 1.0, "case {case}: occ {occ}");
        }
    }
}

/// Wave scaling: identity on the same device; multiplicative inverse on
/// the way back (Eq. 2 is a pure ratio product); monotone in T_o.
#[test]
fn prop_wave_scaling_algebra() {
    let mut rng = Rng::new(0xBEEF);
    for case in 0..2000 {
        let cfg = random_launch(&mut rng);
        let o = *rng.choose(&ALL_DEVICES);
        let d = *rng.choose(&ALL_DEVICES);
        let gamma = rng.next_f64();
        let t = rng.next_f64() * 100.0 + 1e-3;

        let there = wave::scale_eq2(t, &wave::ratios(&cfg.clone(), o.spec(), d.spec()), gamma);
        assert!(there > 0.0 && there.is_finite(), "case {case}");
        let back = wave::scale_eq2(there, &wave::ratios(&cfg, d.spec(), o.spec()), gamma);
        assert!(
            (back / t - 1.0).abs() < 1e-9,
            "case {case}: {o}→{d}→{o} not inverse ({t} → {back})"
        );
        // Identity.
        let same = wave::scale_eq2(t, &wave::ratios(&cfg, o.spec(), o.spec()), gamma);
        assert!((same / t - 1.0).abs() < 1e-12, "case {case}");
        // Linearity in T_o.
        let double = wave::scale_eq2(2.0 * t, &wave::ratios(&cfg, o.spec(), d.spec()), gamma);
        assert!((double / there - 2.0).abs() < 1e-9, "case {case}");
    }
}

/// Eq. 1 equals Eq. 2 modulo the wave-quantization factor, and both stay
/// positive/finite.
#[test]
fn prop_eq1_eq2_within_quantization() {
    let mut rng = Rng::new(0x1234);
    for _ in 0..2000 {
        let cfg = random_launch(&mut rng);
        let o = rng.choose(&ALL_DEVICES).spec();
        let d = rng.choose(&ALL_DEVICES).spec();
        let gamma = rng.next_f64();
        let r = wave::ratios(&cfg, o, d);
        let e1 = wave::scale_eq1(1.0, &r, gamma);
        let e2 = wave::scale_eq2(1.0, &r, gamma);
        assert!(e1 > 0.0 && e2 > 0.0);
        // ⌈B/W⌉/(B/W) ∈ [1, 2] per side ⇒ ratio within [1/4, 4] always.
        assert!(e1 / e2 < 4.0 && e2 / e1 < 4.0, "e1={e1} e2={e2}");
    }
}

/// γ ∈ [0, 1] and non-increasing in arithmetic intensity on every device.
#[test]
fn prop_gamma_bounds_all_devices() {
    let mut rng = Rng::new(0x9e37);
    for _ in 0..200 {
        let device = *rng.choose(&ALL_DEVICES);
        let mut prev = f64::INFINITY;
        for i in 0..300 {
            let x = i as f64 * rng.next_f64().max(0.01);
            let g = roofline::gamma(x, device.spec());
            assert!((0.0..=1.0).contains(&g));
            if x > 0.0 {
                let _ = prev;
            }
            prev = g;
        }
    }
}

/// Simulator sanity over random sampled kernel-varying ops: positive,
/// finite, deterministic, and monotone under 2× batch where applicable.
#[test]
fn prop_simulator_on_random_ops() {
    let mut rng = Rng::new(0xF00D);
    let sim = Simulator::noiseless();
    for case in 0..400 {
        let op_kind = *rng.choose(&habitat::opgraph::MlpOp::ALL);
        let op = habitat::dataset::sample(op_kind, &mut rng);
        for device in [Device::P4000, Device::V100, Device::T4] {
            let t = habitat::dataset::measure(&op, device, &sim);
            assert!(t > 0.0 && t.is_finite(), "case {case} on {device}: {t}");
            let t2 = habitat::dataset::measure(&op, device, &sim);
            assert_eq!(t, t2, "case {case}: nondeterministic");
        }
    }
}

/// Lowering invariants across random ops, archs, passes: every kernel has
/// positive grid/flops/bytes and a finite intensity; backward exists for
/// trainable ops.
#[test]
fn prop_lowering_invariants() {
    let mut rng = Rng::new(0xCAFE);
    for case in 0..600 {
        let op_kind = *rng.choose(&habitat::opgraph::MlpOp::ALL);
        let op = habitat::dataset::sample(op_kind, &mut rng);
        for device in ALL_DEVICES {
            for pass in [Pass::Forward, Pass::Backward] {
                let kernels = lower(&op, device.spec().arch, Precision::Fp32, pass);
                assert!(!kernels.is_empty(), "case {case}: empty lowering");
                for k in &kernels {
                    assert!(k.launch.grid_blocks >= 1, "case {case}");
                    assert!(k.flops >= 0.0 && k.flops.is_finite());
                    assert!(k.dram_bytes > 0.0 && k.dram_bytes.is_finite());
                    assert!(k.arith_intensity() >= 0.0);
                }
            }
        }
    }
}

/// The metrics-policy percentile machinery never panics and always
/// returns a subset of the trace's kernels, for random percentiles.
#[test]
fn prop_metrics_policy_subset() {
    let mut rng = Rng::new(0xDEAD);
    let graph = habitat::models::mlp_benchmark_net(16);
    let trace = habitat::OperationTracker::new(Device::T4).track(&graph);
    let all_keys: std::collections::HashSet<u64> = trace
        .ops
        .iter()
        .flat_map(|o| o.fwd.iter().chain(&o.bwd))
        .map(|m| roofline::cache_key(&m.kernel))
        .collect();
    for _ in 0..200 {
        let p = rng.next_f64() * 100.0;
        let keys = habitat::predict::MetricsPolicy::Percentile(p)
            .profiled_kernels(&trace)
            .unwrap();
        assert!(keys.is_subset(&all_keys), "p={p}");
        assert!(!keys.is_empty(), "the top op is always profiled (p={p})");
    }
}

/// Dataset CSV schema: header length matches rows for every op family.
#[test]
fn prop_dataset_feature_vectors_match_headers() {
    let mut rng = Rng::new(0x5EED);
    for op in habitat::opgraph::MlpOp::ALL {
        let header = habitat::dataset::header(op);
        for _ in 0..200 {
            let sample_op = habitat::dataset::sample(op, &mut rng);
            let (fam, features) = sample_op.mlp_features().unwrap();
            assert_eq!(fam, op);
            // features + 4 gpu features + time = header len
            assert_eq!(features.len() + 5, header.len());
            for v in &features {
                assert!(v.is_finite() && *v >= 0.0);
            }
        }
    }
}
