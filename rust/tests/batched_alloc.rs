//! Zero-allocation pin for the steady-state batched evaluator.
//!
//! `HybridPredictor::evaluate_batch_times` promises that once its
//! scratch arena has been sized by a first sweep, repeat sweeps over
//! the same `(plan, destination-set)` shape perform **no heap
//! allocation** — the property that makes high-rate fan-out serving
//! cheap. `PredictionEngine::evaluate_many_times` extends the promise
//! to one-call multi-trace sweeps through a warm `SweepTimes` arena on
//! a serial engine. This binary pins both with a counting
//! `#[global_allocator]`.
//!
//! It lives in its own test binary (see the `[[test]]` entry in
//! `Cargo.toml`) with exactly one `#[test]`: the allocator counts every
//! allocation in the process, so a concurrently running test — or a
//! second test's harness bookkeeping — would contaminate the measured
//! window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

use habitat::device::{Device, ALL_DEVICES};
use habitat::engine::{PredictionEngine, SweepJob, SweepTimes};
use habitat::plan::{AnalyzedPlan, EvalScratch};
use habitat::predict::HybridPredictor;
use habitat::tracker::OperationTracker;
use habitat::Precision;

#[test]
fn steady_state_batched_sweep_allocates_nothing() {
    let graph = habitat::models::by_name("resnet50", 16).unwrap();
    let trace = OperationTracker::new(Device::Rtx2070).track(&graph);
    let p = HybridPredictor::wave_only();
    let plan = AnalyzedPlan::build(&trace, &p.metrics_policy);
    // A rank-sized fan-out of snapshot devices (post-snapshot devices
    // are the documented exception: their computed lanes consult the
    // shared wave table).
    let dests: Vec<Device> = ALL_DEVICES.iter().copied().cycle().take(60).collect();

    // Warm-up sweeps size every buffer (both precisions, so the AMP
    // phase is warm too).
    let mut scratch = EvalScratch::new();
    for precision in [Precision::Fp32, Precision::Amp] {
        p.evaluate_batch_times(&plan, &dests, precision, &mut scratch);
    }

    // Measured window: steady-state sweeps plus aggregate reads.
    let before = ALLOCS.load(Relaxed);
    let mut checksum = 0.0_f64;
    for _ in 0..16 {
        p.evaluate_batch_times(&plan, &dests, Precision::Fp32, &mut scratch);
        checksum += scratch.run_time_ms(0) + scratch.throughput(dests.len() - 1, 16);
        p.evaluate_batch_times(&plan, &dests, Precision::Amp, &mut scratch);
        checksum += scratch.run_time_ms(dests.len() - 1);
    }
    let after = ALLOCS.load(Relaxed);

    assert!(checksum.is_finite() && checksum > 0.0);
    assert!(!scratch.grew(), "warm sweeps must reuse buffer capacity");
    assert_eq!(
        after - before,
        0,
        "steady-state batched evaluation must not touch the heap"
    );

    // The one-call multi-trace sweep keeps the same promise: on a
    // serial engine (one claimer — the parallel path's channel is the
    // documented allocating exception) with a warm `SweepTimes` arena,
    // repeat `evaluate_many_times` calls over the same job shapes stay
    // off the heap. The job list is built outside the measured window;
    // each job carries only an `Arc` bump and a borrowed destination
    // slice.
    let engine = PredictionEngine::wave_only().with_workers(1);
    let mlp_graph = habitat::models::by_name("mlp", 16).unwrap();
    let mlp_trace = OperationTracker::new(Device::Rtx2070).track(&mlp_graph);
    let plans = [engine.analyze(&trace), engine.analyze(&mlp_trace)];
    let jobs: Vec<SweepJob<'_>> = plans
        .iter()
        .zip([Precision::Fp32, Precision::Amp])
        .map(|(plan, precision)| SweepJob {
            plan: std::sync::Arc::clone(plan),
            dests: &dests,
            precision,
        })
        .collect();
    let mut times = SweepTimes::new();
    engine.evaluate_many_times(&jobs, &mut times); // sizes the arena

    let before = ALLOCS.load(Relaxed);
    let mut many_checksum = 0.0_f64;
    for _ in 0..16 {
        engine.evaluate_many_times(&jobs, &mut times);
        many_checksum += times.job(0)[0] + times.job(1)[dests.len() - 1];
    }
    let after = ALLOCS.load(Relaxed);

    assert!(many_checksum.is_finite() && many_checksum > 0.0);
    assert_eq!(
        after - before,
        0,
        "steady-state multi-trace sweeps must not touch the heap"
    );
}
