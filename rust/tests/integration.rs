//! Cross-module integration tests: models → tracker → predictor →
//! ground truth, exercised the way the experiment harness composes them.

use habitat::device::{Device, ALL_DEVICES};
use habitat::predict::{HybridPredictor, MetricsPolicy};
use habitat::sim::{Precision, Simulator};
use habitat::tracker::OperationTracker;
use habitat::util::stats;
use habitat::{experiments, models};

/// Wave scaling from any origin must land within a sane band of the
/// simulator ground truth for every model (the hybrid predictor only
/// tightens this further).
#[test]
fn wave_only_prediction_error_bounded() {
    let predictor = HybridPredictor::wave_only();
    let mut errs = Vec::new();
    for model in models::MODEL_NAMES {
        let graph = models::by_name(model, 16).unwrap();
        let trace = OperationTracker::new(Device::Rtx2070).track(&graph);
        for dest in ALL_DEVICES {
            if dest == Device::Rtx2070 {
                continue;
            }
            let pred = predictor.predict(&trace, dest).run_time_ms();
            let truth = experiments::ground_truth_ms(model, 16, dest);
            errs.push(stats::ape(pred, truth));
        }
    }
    let avg = stats::mean(&errs);
    assert!(avg < 0.40, "avg wave-only error {:.1}% too high", avg * 100.0);
    assert!(stats::max(&errs) < 1.5, "max error {:.1}%", stats::max(&errs) * 100.0);
}

/// Same-device prediction must be (near-)exact: all scaling ratios are 1.
#[test]
fn same_device_prediction_is_identity() {
    for model in models::MODEL_NAMES {
        let graph = models::by_name(model, 16).unwrap();
        for origin in [Device::P4000, Device::V100, Device::T4] {
            let trace = OperationTracker::new(origin).track(&graph);
            let pred = HybridPredictor::wave_only()
                .with_metrics_policy(MetricsPolicy::All)
                .predict(&trace, origin);
            let rel = (pred.run_time_ms() / trace.run_time_ms() - 1.0).abs();
            assert!(rel < 1e-9, "{model} on {origin}: rel {rel}");
        }
    }
}

/// Bigger batches must take longer on every model and device.
#[test]
fn iteration_time_monotone_in_batch_size() {
    let sim = Simulator::noiseless();
    for model in models::MODEL_NAMES {
        for device in [Device::P4000, Device::V100] {
            let t16 = sim.graph_time_ms(
                device.spec(),
                &models::by_name(model, 16).unwrap(),
                Precision::Fp32,
            );
            let t64 = sim.graph_time_ms(
                device.spec(),
                &models::by_name(model, 64).unwrap(),
                Precision::Fp32,
            );
            assert!(t64 > t16, "{model} on {device}: {t16} vs {t64}");
        }
    }
}

/// The V100 (biggest chip, most bandwidth) must beat the P4000 (smallest)
/// on every heavy model.
#[test]
fn v100_faster_than_p4000_everywhere() {
    let sim = Simulator::noiseless();
    for model in models::MODEL_NAMES {
        let graph = models::by_name(model, 32).unwrap();
        let p4000 = sim.graph_time_ms(Device::P4000.spec(), &graph, Precision::Fp32);
        let v100 = sim.graph_time_ms(Device::V100.spec(), &graph, Precision::Fp32);
        assert!(v100 < p4000, "{model}: v100 {v100} !< p4000 {p4000}");
    }
}

/// AMP must speed up the tensor-core GPUs and leave the P4000 roughly
/// unchanged-to-modestly-better (traffic halves, no fast fp16 math).
#[test]
fn amp_speedups_follow_hardware() {
    let sim = Simulator::noiseless();
    let graph = models::resnet50(32);
    for (device, min_speedup) in [(Device::V100, 1.8), (Device::Rtx2080Ti, 1.8), (Device::P4000, 1.0)] {
        let fp32 = sim.graph_time_ms(device.spec(), &graph, Precision::Fp32);
        let amp = sim.graph_time_ms(device.spec(), &graph, Precision::Amp);
        let speedup = fp32 / amp;
        assert!(
            speedup >= min_speedup && speedup < 8.0,
            "{device}: amp speedup {speedup:.2}"
        );
    }
}

/// Habitat's decisions (paper §5.3) must hold against ground truth:
/// T4 wins cost-normalized throughput for GNMT; V100 is not significantly
/// better than the 2080Ti for DCGAN.
#[test]
fn paper_case_study_decisions_hold_in_ground_truth() {
    // Case study 1.
    for batch in [16usize, 32, 64] {
        let mut best: Option<(Device, f64)> = None;
        for dest in [Device::P100, Device::T4, Device::V100] {
            let truth = experiments::ground_truth_ms("gnmt", batch, dest);
            let cnt = habitat::cost::cost_normalized_throughput(
                dest,
                habitat::cost::throughput(batch, truth),
            )
            .unwrap();
            if best.map_or(true, |(_, b)| cnt > b) {
                best = Some((dest, cnt));
            }
        }
        assert_eq!(best.unwrap().0, Device::T4, "batch {batch}");
    }
    // Case study 2.
    for batch in [64usize, 128] {
        let ti = experiments::ground_truth_ms("dcgan", batch, Device::Rtx2080Ti);
        let v100 = experiments::ground_truth_ms("dcgan", batch, Device::V100);
        let speedup = ti / v100;
        assert!(speedup < 1.35, "batch {batch}: V100 speedup {speedup:.2}");
    }
}

/// Wave-only predictions must also predict the *decisions* correctly
/// (the paper's point: ordering matters more than absolute error).
#[test]
fn predictions_rank_cloud_gpus_correctly_for_gnmt() {
    let predictor = HybridPredictor::wave_only();
    let trace = OperationTracker::new(Device::P4000).track(&models::gnmt(32));
    let mut pred_rank: Vec<(Device, f64)> = [Device::P100, Device::T4, Device::V100]
        .into_iter()
        .map(|d| {
            let tput = predictor.predict(&trace, d).throughput();
            (d, habitat::cost::cost_normalized_throughput(d, tput).unwrap())
        })
        .collect();
    pred_rank.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    assert_eq!(pred_rank[0].0, Device::T4, "T4 must win cost-normalized");
}

/// The heuristic baseline must be substantially worse than Habitat on the
/// paper's Fig. 1 workload.
#[test]
fn heuristic_worse_than_wave_scaling_on_fig1() {
    let trace = OperationTracker::new(Device::T4).track(&models::dcgan(128));
    let predictor = HybridPredictor::wave_only();
    let (mut heur_errs, mut wave_errs) = (Vec::new(), Vec::new());
    for dest in ALL_DEVICES {
        if dest == Device::T4 {
            continue;
        }
        let truth = experiments::ground_truth_ms("dcgan", 128, dest);
        heur_errs.push(stats::ape(
            habitat::predict::heuristic::flops_ratio_prediction(&trace, dest),
            truth,
        ));
        wave_errs.push(stats::ape(predictor.predict(&trace, dest).run_time_ms(), truth));
    }
    // Wave scaling alone already beats the heuristic on DCGAN; the hybrid
    // predictor widens the gap to ~3× (see `habitat experiment fig1`, and
    // `runtime_integration.rs` for the artifact-backed check).
    assert!(
        stats::mean(&heur_errs) > 1.1 * stats::mean(&wave_errs),
        "heuristic {:.1}% vs wave {:.1}%",
        stats::mean(&heur_errs) * 100.0,
        stats::mean(&wave_errs) * 100.0
    );
}

/// Batch extrapolation composes with prediction (the §6.1.3 pipeline).
#[test]
fn extrapolation_pipeline_reasonable() {
    let predictor = HybridPredictor::wave_only();
    let points: Vec<(usize, f64)> = [8usize, 16, 24]
        .into_iter()
        .map(|b| {
            let trace = OperationTracker::new(Device::Rtx2070).track(&models::resnet50(b));
            (b, predictor.predict(&trace, Device::V100).run_time_ms())
        })
        .collect();
    let model = habitat::predict::extrapolate::BatchExtrapolator::fit(&points);
    let pred64 = model.predict(64);
    let truth64 = experiments::ground_truth_ms("resnet50", 64, Device::V100);
    assert!(stats::ape(pred64, truth64) < 0.5, "{pred64} vs {truth64}");
    assert!(model.b > 0.0, "time must grow with batch size");
}

/// Tracking the same graph with different measurement salts gives close
/// but not identical times (simulated measurement noise), and predictions
/// stay stable.
#[test]
fn measurement_noise_is_small_and_predictions_stable() {
    let graph = models::dcgan(64);
    let a = OperationTracker::new(Device::T4)
        .with_simulator(Simulator::new(habitat::sim::SimConfig { salt: 1, ..Default::default() }))
        .track(&graph);
    let b = OperationTracker::new(Device::T4)
        .with_simulator(Simulator::new(habitat::sim::SimConfig { salt: 2, ..Default::default() }))
        .track(&graph);
    let drift = (a.run_time_ms() / b.run_time_ms() - 1.0).abs();
    assert!(drift > 0.0, "salts must change measurements");
    assert!(drift < 0.05, "noise too large: {drift}");
    let predictor = HybridPredictor::wave_only();
    let pa = predictor.predict(&a, Device::V100).run_time_ms();
    let pb = predictor.predict(&b, Device::V100).run_time_ms();
    assert!((pa / pb - 1.0).abs() < 0.05);
}
