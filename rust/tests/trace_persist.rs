//! Trace JSON persistence guarantees.
//!
//! The open-world API leans on trace serialization twice: the CLI's
//! `track --out` / `predict --trace` file workflow, and the service's
//! `submit_trace` request (which content-hashes the canonical JSON to
//! mint `trace_id`s). Both need (a) a byte-stable round trip —
//! save → load → save must reproduce the exact same document, or
//! content-hash ids would drift — and (b) firm rejection of malformed
//! input, since `submit_trace` feeds this parser with arbitrary client
//! bytes.

use habitat::device::Device;
use habitat::tracker::{OperationTracker, Trace};
use habitat::{models, Precision};

fn tracked(model: &str, batch: usize, origin: Device) -> Trace {
    let graph = models::by_name(model, batch).expect("known model");
    OperationTracker::new(origin).track(&graph)
}

#[test]
fn save_load_save_is_byte_stable() {
    for (model, batch, origin) in [
        ("resnet50", 16, Device::Rtx2070),
        ("gnmt", 16, Device::P4000),
        ("transformer", 8, Device::V100),
        ("dcgan", 32, Device::T4),
    ] {
        let trace = tracked(model, batch, origin);
        let first = trace.to_json();
        let reloaded = Trace::from_json(&first).unwrap();
        let second = reloaded.to_json();
        assert_eq!(
            first, second,
            "{model}: save→load→save must reproduce the document byte-for-byte"
        );
        // And one more lap for good measure (fixed point, not a cycle).
        assert_eq!(Trace::from_json(&second).unwrap().to_json(), second);
    }
}

#[test]
fn roundtrip_preserves_semantics_not_just_bytes() {
    let trace = tracked("resnet50", 16, Device::Rtx2070);
    let back = Trace::from_json(&trace.to_json()).unwrap();
    assert_eq!(back.model, trace.model);
    assert_eq!(back.batch_size, trace.batch_size);
    assert_eq!(back.origin, trace.origin);
    assert_eq!(back.precision, trace.precision);
    assert_eq!(back.ops.len(), trace.ops.len());
    for (a, b) in trace.ops.iter().zip(&back.ops) {
        assert_eq!(a.index, b.index);
        assert_eq!(a.op.name, b.op.name);
        assert_eq!(a.fwd.len(), b.fwd.len());
        assert_eq!(a.bwd.len(), b.bwd.len());
        for (ka, kb) in a.fwd.iter().chain(&a.bwd).zip(b.fwd.iter().chain(&b.bwd)) {
            assert_eq!(ka.time_ms.to_bits(), kb.time_ms.to_bits());
            assert_eq!(ka.kernel.launch, kb.kernel.launch);
            assert_eq!(ka.kernel.name, kb.kernel.name);
        }
    }
}

#[test]
fn file_roundtrip_is_byte_stable() {
    let trace = tracked("dcgan", 8, Device::P100);
    let path = std::env::temp_dir().join("habitat_trace_persist_test.json");
    trace.save(&path).unwrap();
    let reloaded = Trace::load(&path).unwrap();
    assert_eq!(std::fs::read_to_string(&path).unwrap(), reloaded.to_json());
    std::fs::remove_file(&path).ok();
}

#[test]
fn malformed_input_is_rejected() {
    // Not JSON at all.
    assert!(Trace::from_json("").is_err());
    assert!(Trace::from_json("not json").is_err());
    assert!(Trace::from_json("[1,2,3]").is_err());
    // JSON, wrong shape.
    assert!(Trace::from_json("{}").is_err());
    assert!(Trace::from_json("{\"format\":\"habitat-trace-v2\"}").is_err(), "unknown format tag");
    assert!(
        Trace::from_json(
            "{\"format\":\"habitat-trace-v1\",\"model\":\"m\",\"batch_size\":4,\"origin\":\"warp9\",\"precision\":\"fp32\",\"ops\":[]}"
        )
        .is_err(),
        "unregistered origin device"
    );
    assert!(
        Trace::from_json(
            "{\"format\":\"habitat-trace-v1\",\"model\":\"m\",\"batch_size\":4,\"origin\":\"t4\",\"precision\":\"fp8\",\"ops\":[]}"
        )
        .is_err(),
        "unknown precision"
    );
    assert!(
        Trace::from_json(
            "{\"format\":\"habitat-trace-v1\",\"model\":\"m\",\"batch_size\":4,\"origin\":\"t4\",\"precision\":\"fp32\"}"
        )
        .is_err(),
        "missing ops array"
    );
    // Valid envelope, corrupt op entries.
    let with_ops = |ops: &str| {
        format!(
            "{{\"format\":\"habitat-trace-v1\",\"model\":\"m\",\"batch_size\":4,\"origin\":\"t4\",\"precision\":\"fp32\",\"ops\":[{ops}]}}"
        )
    };
    assert!(Trace::from_json(&with_ops("{}")).is_err(), "op missing every field");
    assert!(
        Trace::from_json(&with_ops(
            "{\"index\":0,\"name\":\"x\",\"kind\":\"frobnicate(1)\",\"input\":[4],\"fwd\":[],\"bwd\":[]}"
        ))
        .is_err(),
        "unknown op kind"
    );
    assert!(
        Trace::from_json(&with_ops(
            "{\"index\":0,\"name\":\"x\",\"kind\":\"ln(8)\",\"input\":[4],\"fwd\":[{\"name\":\"k\"}],\"bwd\":[]}"
        ))
        .is_err(),
        "kernel missing launch/time fields"
    );
}

#[test]
fn amp_and_fp32_precisions_roundtrip() {
    for precision in [Precision::Fp32, Precision::Amp] {
        let graph = models::by_name("dcgan", 8).unwrap();
        let trace = OperationTracker::new(Device::V100)
            .with_precision(precision)
            .track(&graph);
        let back = Trace::from_json(&trace.to_json()).unwrap();
        assert_eq!(back.precision, precision);
        assert_eq!(back.to_json(), trace.to_json());
    }
}

#[test]
fn roundtripped_trace_predicts_identically() {
    // The property submit_trace depends on: a deserialized trace drives
    // the predictor to the exact same numbers as the original.
    let trace = tracked("gnmt", 16, Device::P4000);
    let back = Trace::from_json(&trace.to_json()).unwrap();
    let p = habitat::predict::HybridPredictor::wave_only();
    for dest in habitat::device::ALL_DEVICES {
        let a = p.predict(&trace, dest);
        let b = p.predict(&back, dest);
        assert_eq!(
            a.run_time_ms().to_bits(),
            b.run_time_ms().to_bits(),
            "{dest}"
        );
    }
}
