//! Durability suite for the persistent plan store (`engine::store`).
//!
//! Every test here attacks the on-disk format the way a real deployment
//! would: truncation, bit flips, a version bump, and a crash mid-write.
//! The store's contract is *reject-and-rebuild*, never serve-corrupt:
//! any damaged record must load as `None`, the engine must fall back to
//! a fresh compile transparently, and the rebuilt record must land back
//! on disk (write-behind, drained when the engine drops).

use std::fs;
use std::path::{Path, PathBuf};

use habitat::device::Device;
use habitat::engine::store::{PlanStore, StoredKind, STORE_FORMAT_VERSION};
use habitat::engine::{PredictionEngine, TraceKey};
use habitat::predict::MetricsPolicy;
use habitat::Precision;

/// Per-test scratch directory, unique across concurrently running test
/// binaries and pre-cleaned so a crashed previous run can't leak state.
fn store_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("habitat-storetest-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    dir
}

/// Compile + persist one zoo entry, drain the write-behind queue (by
/// dropping the engine), and return the record's id and file path.
fn seed_record(dir: &Path) -> (String, PathBuf) {
    {
        let engine = PredictionEngine::wave_only().with_store(dir).expect("store opens");
        engine.analyzed("mlp", 16, Device::T4).expect("mlp tracks");
    }
    let store = PlanStore::open(dir, &MetricsPolicy::default()).expect("store reopens");
    let ids = store.ids();
    assert_eq!(ids.len(), 1, "exactly one record persisted after drop-drain");
    let id = ids[0].clone();
    let path = dir.join(format!("{id}.plan"));
    assert!(path.exists());
    (id, path)
}

fn key() -> TraceKey {
    ("mlp".to_string(), 16, Device::T4, Precision::Fp32)
}

#[test]
fn truncated_record_is_rejected_and_rebuilt() {
    let dir = store_dir("truncate");
    let (id, path) = seed_record(&dir);
    let bytes = fs::read(&path).unwrap();
    fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();

    // Direct load refuses the damaged record.
    let store = PlanStore::open(&dir, &MetricsPolicy::default()).unwrap();
    assert!(store.load(&id).is_none(), "truncated record must not load");
    assert!(store.lookup(&key()).is_none(), "rejected record must not be indexed");

    // The engine restores nothing, rebuilds transparently, and the
    // rebuilt plan (same trace content → same id) overwrites the
    // damaged file on the write-behind path.
    let reference = {
        let engine = PredictionEngine::wave_only().with_store(&dir).unwrap();
        assert_eq!(engine.stats().warm_restores, 0);
        let entry = engine.analyzed("mlp", 16, Device::T4).unwrap();
        let stats = engine.stats();
        assert_eq!(stats.trace_misses, 1, "rebuild pays one tracking pass");
        assert_eq!(stats.store_misses, 1);
        assert_eq!(stats.plan_builds, 1);
        engine.evaluate(&entry.plan, Device::V100, Precision::Fp32).run_time_ms()
    };

    let healed = PlanStore::open(&dir, &MetricsPolicy::default()).unwrap();
    let (kind, entry) = healed.load(&id).expect("rebuilt record readable again");
    assert_eq!(kind, StoredKind::Zoo);
    let wave = habitat::HybridPredictor::wave_only();
    assert_eq!(
        wave.evaluate(&entry.plan, Device::V100).run_time_ms().to_bits(),
        reference.to_bits(),
        "healed record evaluates bit-identically"
    );
    fs::remove_dir_all(&dir).ok();
}

#[test]
fn bit_flipped_payload_is_rejected() {
    let dir = store_dir("bitflip");
    let (id, path) = seed_record(&dir);
    let mut bytes = fs::read(&path).unwrap();
    let last = bytes.len() - 1;
    bytes[last] ^= 0x01; // deep in the lane tables, past the header
    fs::write(&path, &bytes).unwrap();

    let store = PlanStore::open(&dir, &MetricsPolicy::default()).unwrap();
    assert!(store.load(&id).is_none(), "checksum must catch a single flipped bit");

    let engine = PredictionEngine::wave_only().with_store(&dir).unwrap();
    assert_eq!(engine.stats().warm_restores, 0);
    engine.analyzed("mlp", 16, Device::T4).expect("rebuild succeeds");
    fs::remove_dir_all(&dir).ok();
}

#[test]
fn version_mismatch_is_rejected() {
    let dir = store_dir("version");
    let (id, path) = seed_record(&dir);
    let mut bytes = fs::read(&path).unwrap();
    // Record layout: 8-byte magic, then the little-endian u32 format
    // version. A future format must never parse as the current one.
    bytes[8..12].copy_from_slice(&(STORE_FORMAT_VERSION + 1).to_le_bytes());
    fs::write(&path, &bytes).unwrap();

    let store = PlanStore::open(&dir, &MetricsPolicy::default()).unwrap();
    assert!(store.load(&id).is_none(), "future-version record must not load");

    let engine = PredictionEngine::wave_only().with_store(&dir).unwrap();
    assert_eq!(engine.stats().warm_restores, 0, "version mismatch is a clean miss");
    engine.analyzed("mlp", 16, Device::T4).expect("rebuild succeeds");
    fs::remove_dir_all(&dir).ok();
}

#[test]
fn renamed_record_fails_the_id_check() {
    // A record copied under the wrong id (or an id collision attempt)
    // is internally consistent — magic, version, checksum all pass —
    // but its content hash disagrees with its filename.
    let dir = store_dir("rename");
    let (_, path) = seed_record(&dir);
    let forged = dir.join("tr-00000000deadbeef.plan");
    fs::copy(&path, &forged).unwrap();

    let store = PlanStore::open(&dir, &MetricsPolicy::default()).unwrap();
    assert!(store.load("tr-00000000deadbeef").is_none(), "forged id must not load");
    fs::remove_dir_all(&dir).ok();
}

#[test]
fn kill_mid_write_restart_recovers() {
    let dir = store_dir("killmid");
    let (id, path) = seed_record(&dir);

    // Simulate a crash mid-write: a half-written temp file next to the
    // good record (saves go to `<id>.plan.tmp-<pid>-<seq>` and rename
    // into place, so a kill can only ever strand the temp).
    let bytes = fs::read(&path).unwrap();
    let debris = dir.join(format!("{id}.plan.tmp-999-7"));
    fs::write(&debris, &bytes[..bytes.len() / 3]).unwrap();
    let unrelated = dir.join("tr-1111111111111111.plan.tmp-999-8");
    fs::write(&unrelated, b"\x00\x01garbage").unwrap();

    // Restart: open() sweeps the debris, and the intact record still
    // warm-restores.
    let engine = PredictionEngine::wave_only().with_store(&dir).unwrap();
    assert!(!debris.exists(), "stranded temp file swept on open");
    assert!(!unrelated.exists(), "all temp debris swept on open");
    assert_eq!(engine.stats().warm_restores, 1);
    let entry = engine.analyzed("mlp", 16, Device::T4).unwrap();
    let stats = engine.stats();
    assert_eq!(stats.trace_misses, 0, "restored entry serves without retracking");
    assert_eq!(stats.trace_hits, 1);
    engine.evaluate(&entry.plan, Device::V100, Precision::Fp32);
    fs::remove_dir_all(&dir).ok();
}

#[test]
fn reopen_indexes_zoo_records_for_claim_bypass() {
    // `lookup` is how the engine's build path skips recompilation after
    // an LRU eviction: the index must survive a reopen (rebuilt lazily
    // from disk on load).
    let dir = store_dir("reindex");
    let (id, _) = seed_record(&dir);
    let store = PlanStore::open(&dir, &MetricsPolicy::default()).unwrap();
    let (kind, _) = store.load(&id).expect("intact record loads");
    assert_eq!(kind, StoredKind::Zoo);
    assert_eq!(store.lookup(&key()).as_deref(), Some(id.as_str()));
    assert!(
        store.lookup(&("mlp".to_string(), 99, Device::T4, Precision::Fp32)).is_none(),
        "different batch size is a different key"
    );
    fs::remove_dir_all(&dir).ok();
}
