//! Integration tests over the PJRT runtime + batching service + hybrid
//! predictor. These require `make artifacts`; when the artifacts are
//! missing each test prints a note and passes vacuously (CI without the
//! build path still runs the rest of the suite).

use habitat::device::Device;
use habitat::opgraph::MlpOp;
use habitat::predict::MlpBackend;
use habitat::runtime::{MlpService, MlpServiceHandle};
use habitat::tracker::OperationTracker;
use habitat::util::stats;

fn service() -> Option<MlpServiceHandle> {
    match MlpService::spawn("artifacts".into()) {
        Ok(h) => Some(h),
        Err(e) => {
            eprintln!("skipping (run `make artifacts`): {e}");
            None
        }
    }
}

fn conv_row() -> Vec<f64> {
    // batch, in_ch, out_ch, kernel, stride, padding, image
    vec![32.0, 256.0, 256.0, 3.0, 1.0, 1.0, 28.0]
}

#[test]
fn mlp_outputs_positive_and_finite() {
    let Some(h) = service() else { return };
    for op in MlpOp::ALL {
        let row = match op {
            MlpOp::Conv2d => conv_row(),
            MlpOp::Lstm => vec![32.0, 1024.0, 1024.0, 50.0, 1.0, 0.0, 1.0],
            MlpOp::Bmm => vec![64.0, 50.0, 64.0, 50.0],
            MlpOp::Linear => vec![512.0, 1024.0, 1024.0, 1.0],
        };
        let out = h.predict_batch(op, &[row], Device::V100).unwrap();
        assert_eq!(out.len(), 1);
        assert!(out[0] > 0.0 && out[0].is_finite(), "{op}: {}", out[0]);
        assert!(out[0] < 1e5, "{op}: absurd time {}", out[0]);
    }
}

#[test]
fn batched_equals_individual() {
    let Some(h) = service() else { return };
    let rows: Vec<Vec<f64>> = (0..20)
        .map(|i| {
            let mut r = conv_row();
            r[0] = 1.0 + i as f64; // vary batch
            r
        })
        .collect();
    let batched = h.predict_batch(MlpOp::Conv2d, &rows, Device::T4).unwrap();
    for (i, row) in rows.iter().enumerate() {
        let single = h.predict_batch(MlpOp::Conv2d, &[row.clone()], Device::T4).unwrap();
        let rel = (batched[i] / single[0] - 1.0).abs();
        assert!(rel < 1e-4, "row {i}: batched {} vs single {}", batched[i], single[0]);
    }
}

#[test]
fn bucket_boundaries_consistent() {
    // Crossing a bucket boundary (8 → 9 rows pads to bucket 32) must not
    // change per-row results.
    let Some(h) = service() else { return };
    let row = conv_row();
    let eight = h.predict_batch(MlpOp::Conv2d, &vec![row.clone(); 8], Device::P100).unwrap();
    let nine = h.predict_batch(MlpOp::Conv2d, &vec![row.clone(); 9], Device::P100).unwrap();
    assert!((eight[0] / nine[0] - 1.0).abs() < 1e-4);
    // Beyond the largest bucket (512): chunking still returns all rows.
    let many = h.predict_batch(MlpOp::Conv2d, &vec![row; 700], Device::P100).unwrap();
    assert_eq!(many.len(), 700);
    assert!((many[0] / many[699] - 1.0).abs() < 1e-4);
}

#[test]
fn gpu_features_change_prediction() {
    let Some(h) = service() else { return };
    let row = conv_row();
    let v100 = h.predict_batch(MlpOp::Conv2d, &[row.clone()], Device::V100).unwrap()[0];
    let p4000 = h.predict_batch(MlpOp::Conv2d, &[row], Device::P4000).unwrap()[0];
    assert!(p4000 > v100, "P4000 must be predicted slower: {p4000} vs {v100}");
}

#[test]
fn concurrent_requests_batch_and_agree() {
    let Some(h) = service() else { return };
    let row = conv_row();
    let expected = h.predict_batch(MlpOp::Conv2d, &[row.clone()], Device::T4).unwrap()[0];
    let results: Vec<f64> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..16)
            .map(|_| {
                let h = h.clone();
                let row = row.clone();
                s.spawn(move || h.predict_batch(MlpOp::Conv2d, &[row], Device::T4).unwrap()[0])
            })
            .collect();
        handles.into_iter().map(|j| j.join().unwrap()).collect()
    });
    for r in results {
        assert!((r / expected - 1.0).abs() < 1e-6);
    }
}

#[test]
fn mlp_accuracy_against_simulator_in_distribution() {
    // The MLPs were trained on simulator measurements; on freshly sampled
    // configs (same distribution, unseen samples) they must hit a MAPE
    // comparable to the recorded test error.
    let Some(h) = service() else { return };
    let mut rng = habitat::util::Rng::new(0x7E57);
    let sim = habitat::sim::Simulator::default();
    for op in MlpOp::ALL {
        let mut rows = Vec::new();
        let mut truth = Vec::new();
        for _ in 0..100 {
            let sample = habitat::dataset::sample(op, &mut rng);
            let (_, features) = sample.mlp_features().unwrap();
            rows.push(features);
            truth.push(habitat::dataset::measure(&sample, Device::Rtx2080Ti, &sim));
        }
        let pred = h.predict_batch(op, &rows, Device::Rtx2080Ti).unwrap();
        let mape = stats::mape(&pred, &truth);
        assert!(mape < 0.40, "{op}: MAPE {:.1}%", mape * 100.0);
    }
}

#[test]
fn hybrid_beats_or_matches_wave_only_end_to_end() {
    let Some(_h) = service() else { return };
    let hybrid = habitat::runtime::predictor_from_artifacts("artifacts").unwrap();
    let wave = habitat::predict::HybridPredictor::wave_only();
    let mut hybrid_errs = Vec::new();
    let mut wave_errs = Vec::new();
    for model in habitat::models::MODEL_NAMES {
        let graph = habitat::models::by_name(model, 32).unwrap();
        let trace = OperationTracker::new(Device::P4000).track(&graph);
        for dest in [Device::V100, Device::T4, Device::Rtx2080Ti] {
            let truth = habitat::experiments::ground_truth_ms(model, 32, dest);
            hybrid_errs.push(stats::ape(hybrid.predict(&trace, dest).run_time_ms(), truth));
            wave_errs.push(stats::ape(wave.predict(&trace, dest).run_time_ms(), truth));
        }
    }
    let (h_avg, w_avg) = (stats::mean(&hybrid_errs), stats::mean(&wave_errs));
    eprintln!("hybrid {:.1}% vs wave-only {:.1}%", h_avg * 100.0, w_avg * 100.0);
    assert!(h_avg < 0.25, "hybrid avg error too high: {:.1}%", h_avg * 100.0);
    assert!(h_avg <= w_avg * 1.1, "hybrid should not be worse than wave-only");
}

#[test]
fn prediction_service_end_to_end_with_artifacts() {
    let Some(_h) = service() else { return };
    let svc = habitat::coordinator::PredictionService::new("artifacts").unwrap();
    let resp = svc
        .handle(&habitat::coordinator::PredictionRequest {
            model: "gnmt".into(),
            batch: 32,
            origin: "p4000".into(),
            dest: "v100".into(),
            precision: None,
        })
        .unwrap();
    assert!(resp.iter_ms > 0.0);
    assert_eq!(resp.mlp_fallbacks, 0, "all kernel-varying ops must hit MLPs");
    assert!(resp.mlp_time_fraction > 0.1, "LSTM time should flow through MLPs");
}
