//! Golden-value regression tests for the plan-based prediction path.
//!
//! The plan refactor's contract: compiling a trace into an
//! `AnalyzedPlan` and evaluating it per destination must be
//! **bit-identical** to the legacy trace-walking path
//! (`HybridPredictor::predict` + `amp_transform`), which is kept in-tree
//! as the reference implementation. These tests pin the current
//! `predict`/`rank` outputs for all five paper models across two
//! origin→destination pairs and both precisions:
//!
//! 1. every engine (plan-path) prediction is compared bit-for-bit
//!    against the independently computed reference path — this runs
//!    unconditionally, everywhere, and is the primary regression guard;
//! 2. the bit patterns are additionally pinned in
//!    `tests/golden/wave_only.txt`. A missing file is blessed (written)
//!    on first run and the comparison starts pinning from the next run
//!    onward — commit the blessed file to make the pin durable across
//!    fresh checkouts. Set `GOLDEN_REQUIRE=1` to make a missing file an
//!    error instead (for environments that expect a committed pin), or
//!    `GOLDEN_BLESS=1` to re-bless after an intentional numeric change.

use std::fmt::Write as _;
use std::sync::Arc;

use habitat::device::{Device, ALL_DEVICES};
use habitat::engine::PredictionEngine;
use habitat::predict::{amp, HybridPredictor};
use habitat::tracker::Trace;
use habitat::{models, Precision};

/// The two origin→destination pairs the golden set covers: a
/// Turing→Volta upgrade and a Pascal→Turing cloud move.
const PAIRS: [(Device, Device); 2] = [
    (Device::Rtx2070, Device::V100),
    (Device::P4000, Device::T4),
];

const PRECISIONS: [(Precision, &str); 2] = [(Precision::Fp32, "fp32"), (Precision::Amp, "amp")];

/// The smallest paper-evaluated batch size per model keeps the golden
/// sweep fast while exercising every lowering family.
fn golden_batch(model: &str) -> usize {
    models::eval_batch_sizes(model)[0]
}

/// The legacy reference path, composed exactly as the pre-plan engine
/// did: trace-walking wave scaling, then the Daydream AMP transform.
fn reference_ms(predictor: &HybridPredictor, trace: &Trace, dest: Device, precision: Precision) -> f64 {
    let fp32 = predictor.predict(trace, dest);
    match precision {
        Precision::Fp32 => fp32.run_time_ms(),
        Precision::Amp => amp::amp_transform(&fp32, trace).run_time_ms(),
    }
}

#[test]
fn plan_path_reproduces_reference_path_bit_for_bit() {
    let engine = PredictionEngine::wave_only();
    let reference = HybridPredictor::wave_only();
    for model in models::MODEL_NAMES {
        let batch = golden_batch(model);
        for (origin, dest) in PAIRS {
            let trace: Arc<Trace> = engine.trace(model, batch, origin).unwrap();
            for (precision, label) in PRECISIONS {
                let plan_ms = engine
                    .predict(model, batch, origin, dest, precision)
                    .unwrap()
                    .pred
                    .run_time_ms();
                let legacy_ms = reference_ms(&reference, &trace, dest, precision);
                assert_eq!(
                    plan_ms.to_bits(),
                    legacy_ms.to_bits(),
                    "{model} bs={batch} {origin}→{dest} {label}: plan {plan_ms} vs legacy {legacy_ms}"
                );
            }
        }
    }
}

#[test]
fn plan_path_matches_reference_per_op() {
    // Per-op granularity on one model per lowering family keeps the
    // failure message actionable when a single op family drifts.
    let engine = PredictionEngine::wave_only();
    let reference = HybridPredictor::wave_only();
    for (model, origin, dest) in [
        ("resnet50", Device::Rtx2070, Device::V100),
        ("gnmt", Device::P4000, Device::T4),
    ] {
        let batch = golden_batch(model);
        let analyzed = engine.analyzed(model, batch, origin).unwrap();
        let fast = engine.evaluate(&analyzed.plan, dest, Precision::Fp32);
        let legacy = reference.predict(&analyzed.trace, dest);
        assert_eq!(fast.ops.len(), legacy.ops.len());
        for (a, b) in legacy.ops.iter().zip(&fast.ops) {
            assert_eq!(a.index, b.index);
            assert_eq!(a.name, b.name);
            assert_eq!(a.method, b.method);
            assert_eq!(
                a.time_ms.to_bits(),
                b.time_ms.to_bits(),
                "{model} {origin}→{dest} op {}: legacy {} vs plan {}",
                a.name,
                a.time_ms,
                b.time_ms
            );
        }
    }
}

#[test]
fn rank_reproduces_individual_reference_predictions() {
    let engine = PredictionEngine::wave_only();
    let reference = HybridPredictor::wave_only();
    for (model, origin) in [("resnet50", Device::Rtx2070), ("dcgan", Device::P4000)] {
        let batch = golden_batch(model);
        for (precision, label) in PRECISIONS {
            let ranking = engine
                .rank(model, batch, origin, &ALL_DEVICES, precision)
                .unwrap();
            assert_eq!(ranking.entries.len(), ALL_DEVICES.len());
            for entry in &ranking.entries {
                let legacy_ms = reference_ms(&reference, &ranking.trace, entry.dest, precision);
                assert_eq!(
                    entry.pred.run_time_ms().to_bits(),
                    legacy_ms.to_bits(),
                    "{model} rank {label} → {}: ranked {} vs legacy {}",
                    entry.dest,
                    entry.pred.run_time_ms(),
                    legacy_ms
                );
            }
        }
    }
}

#[test]
fn batched_evaluation_is_bit_identical_to_scalar_across_the_zoo() {
    // The kernel-major batched sweep must reproduce N scalar `evaluate`
    // calls bit-for-bit: every paper model × every registry device ×
    // both precisions, per op.
    let engine = PredictionEngine::wave_only();
    let devices = habitat::device::registry::all_devices();
    for model in models::MODEL_NAMES {
        let batch = golden_batch(model);
        let analyzed = engine.analyzed(model, batch, Device::Rtx2070).unwrap();
        for (precision, label) in PRECISIONS {
            let batched = engine.evaluate_batch(&analyzed.plan, &devices, precision);
            assert_eq!(batched.len(), devices.len());
            for (pred, &dest) in batched.iter().zip(&devices) {
                let scalar = engine.evaluate(&analyzed.plan, dest, precision);
                assert_eq!(pred.dest, dest);
                assert_eq!(pred.ops.len(), scalar.ops.len());
                assert_eq!(pred.mlp_fallbacks, scalar.mlp_fallbacks);
                for (a, b) in scalar.ops.iter().zip(&pred.ops) {
                    assert_eq!(
                        a.time_ms.to_bits(),
                        b.time_ms.to_bits(),
                        "{model} bs={batch} {label} {dest} op {}: scalar {} vs batched {}",
                        a.name,
                        a.time_ms,
                        b.time_ms
                    );
                    assert_eq!(a.method, b.method);
                }
            }
        }
    }
}

#[test]
fn batched_evaluation_covers_post_snapshot_registered_devices() {
    use habitat::device::registry::{self, NewDevice};

    // Compile the plan *before* registering, so the new device sits
    // outside the plan's dense tables and the batched sweep must route
    // it through the computed-lane path — mixed into the same sweep as
    // snapshot devices.
    let engine = PredictionEngine::wave_only();
    let analyzed = engine
        .analyzed("resnet50", golden_batch("resnet50"), Device::Rtx2070)
        .unwrap();
    let d = registry::register(&NewDevice {
        usd_per_hr: Some(1.1),
        ..NewDevice::new("sim-golden-batch", 56, 1600.0, 700.0, 16.0, true)
    })
    .unwrap();
    assert!(
        d.index() >= analyzed.plan.n_devices(),
        "the device must be outside the plan's registry snapshot"
    );
    let mut dests: Vec<Device> = ALL_DEVICES.to_vec();
    dests.push(d);
    dests.push(Device::V100); // duplicate, after the computed-lane dest
    for (precision, label) in PRECISIONS {
        let batched = engine.evaluate_batch(&analyzed.plan, &dests, precision);
        assert_eq!(batched.len(), dests.len());
        for (pred, &dest) in batched.iter().zip(&dests) {
            let scalar = engine.evaluate(&analyzed.plan, dest, precision);
            assert_eq!(pred.dest, dest);
            assert_eq!(
                pred.run_time_ms().to_bits(),
                scalar.run_time_ms().to_bits(),
                "{label} {dest}: batched {} vs scalar {}",
                pred.run_time_ms(),
                scalar.run_time_ms()
            );
        }
    }
}

#[test]
fn simd_lanes_match_the_scalar_fallback_bit_for_bit() {
    use habitat::device::registry::{self, NewDevice};
    use habitat::util::simdf64;

    // The SIMD referee: the vector backend and the portable
    // scalar-chunk fallback must produce identical bits across the
    // whole golden grid — every zoo model × every registry device
    // (plus one registered after the plans compiled, to cover the
    // padded computed-lane path) × both precisions, per op. CI also
    // runs the entire suite twice (default and `HABITAT_SIMD=off`);
    // this test pins the same equivalence in-process so a divergence
    // names the exact op. On machines without AVX2 both sweeps select
    // the scalar backend and the comparison is trivially tight.
    let engine = PredictionEngine::wave_only();
    let plans: Vec<_> = models::MODEL_NAMES
        .iter()
        .map(|m| engine.analyzed(m, golden_batch(m), Device::Rtx2070).unwrap())
        .collect();
    registry::register(&NewDevice::new("sim-golden-simd", 48, 1400.0, 550.0, 12.0, true))
        .unwrap();
    let dests = registry::all_devices();

    simdf64::set_enabled(false);
    assert_eq!(simdf64::backend(), "scalar");
    let mut scalar = Vec::new();
    for a in &plans {
        for (precision, _) in PRECISIONS {
            scalar.push(engine.evaluate_batch(&a.plan, &dests, precision));
        }
    }
    // Re-detect: AVX2 where the CPU has it (still scalar under
    // `HABITAT_SIMD=off`) — either way the bits below must agree.
    simdf64::set_enabled(true);

    let mut idx = 0;
    for (model, a) in models::MODEL_NAMES.iter().zip(&plans) {
        for (precision, label) in PRECISIONS {
            let vector = engine.evaluate_batch(&a.plan, &dests, precision);
            let base = &scalar[idx];
            idx += 1;
            assert_eq!(vector.len(), base.len());
            for ((v, s), &dest) in vector.iter().zip(base).zip(&dests) {
                assert_eq!(v.ops.len(), s.ops.len());
                assert_eq!(v.mlp_fallbacks, s.mlp_fallbacks);
                for (s_op, v_op) in s.ops.iter().zip(&v.ops) {
                    assert_eq!(
                        s_op.time_ms.to_bits(),
                        v_op.time_ms.to_bits(),
                        "{model} {label} {dest} op {}: scalar {} vs simd {}",
                        s_op.name,
                        s_op.time_ms,
                        v_op.time_ms
                    );
                    assert_eq!(s_op.method, v_op.method);
                }
                assert_eq!(
                    v.run_time_ms().to_bits(),
                    s.run_time_ms().to_bits(),
                    "{model} {label} {dest}: scalar {} vs simd {}",
                    s.run_time_ms(),
                    v.run_time_ms()
                );
            }
        }
    }
}

#[test]
fn restored_plans_are_bit_identical_across_the_zoo() {
    // The persistent plan store's referee: compile + persist the whole
    // five-model zoo, reboot a fresh engine from disk, and compare the
    // restored plans' predictions bit-for-bit against the live compile
    // on every golden pair and both precisions. A restore that reruns
    // any arithmetic differently — lane decode, γ resolution, AMP
    // factors — fails here before it can drift a served prediction.
    let dir = std::env::temp_dir().join(format!("habitat-golden-store-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let compiled = PredictionEngine::wave_only();
    {
        let seeded = PredictionEngine::wave_only().with_store(&dir).unwrap();
        for model in models::MODEL_NAMES {
            let batch = golden_batch(model);
            for (origin, _) in PAIRS {
                seeded.analyzed(model, batch, origin).unwrap();
                compiled.analyzed(model, batch, origin).unwrap();
            }
        }
        // Drop drains the write-behind queue: every plan is on disk.
    }

    let restored = PredictionEngine::wave_only().with_store(&dir).unwrap();
    let stats = restored.stats();
    assert_eq!(
        stats.warm_restores,
        (models::MODEL_NAMES.len() * PAIRS.len()) as u64,
        "every persisted zoo plan must warm-restore"
    );
    assert_eq!(stats.plan_builds, 0, "restore must not recompile");

    for model in models::MODEL_NAMES {
        let batch = golden_batch(model);
        for (origin, dest) in PAIRS {
            let live = compiled.analyzed(model, batch, origin).unwrap();
            let warm = restored.analyzed(model, batch, origin).unwrap();
            for (precision, label) in PRECISIONS {
                let live_pred = compiled.evaluate(&live.plan, dest, precision);
                let warm_pred = restored.evaluate(&warm.plan, dest, precision);
                assert_eq!(live_pred.ops.len(), warm_pred.ops.len());
                for (a, b) in live_pred.ops.iter().zip(&warm_pred.ops) {
                    assert_eq!(
                        a.time_ms.to_bits(),
                        b.time_ms.to_bits(),
                        "{model} bs={batch} {origin}→{dest} {label} op {}: live {} vs restored {}",
                        a.name,
                        a.time_ms,
                        b.time_ms
                    );
                }
                assert_eq!(
                    live_pred.run_time_ms().to_bits(),
                    warm_pred.run_time_ms().to_bits(),
                    "{model} bs={batch} {origin}→{dest} {label}: live {} vs restored {}",
                    live_pred.run_time_ms(),
                    warm_pred.run_time_ms()
                );
            }
        }
    }
    assert_eq!(restored.stats().trace_misses, 0, "restored zoo served without retracking");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn golden_bit_patterns_are_pinned() {
    let engine = PredictionEngine::wave_only();
    let mut lines = Vec::new();
    for model in models::MODEL_NAMES {
        let batch = golden_batch(model);
        for (origin, dest) in PAIRS {
            for (precision, label) in PRECISIONS {
                let ms = engine
                    .predict(model, batch, origin, dest, precision)
                    .unwrap()
                    .pred
                    .run_time_ms();
                let mut line = String::new();
                write!(
                    line,
                    "{model},{batch},{},{},{label},{:016x}",
                    origin.id(),
                    dest.id(),
                    ms.to_bits()
                )
                .unwrap();
                lines.push(line);
            }
        }
    }
    let current = lines.join("\n") + "\n";

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden");
    let path = dir.join("wave_only.txt");
    if !path.exists() && std::env::var_os("GOLDEN_REQUIRE").is_some() {
        panic!(
            "GOLDEN_REQUIRE is set but {} is missing — run the suite once without \
             GOLDEN_REQUIRE and commit the blessed file",
            path.display()
        );
    }
    let bless = std::env::var_os("GOLDEN_BLESS").is_some() || !path.exists();
    if bless {
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(&path, &current).unwrap();
        eprintln!(
            "golden: blessed {} ({} entries) — commit this file to pin the values",
            path.display(),
            lines.len()
        );
        return;
    }
    let recorded = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        recorded, current,
        "golden predictions drifted from {} — if the change is intentional, \
         delete the file or re-run with GOLDEN_BLESS=1 to re-bless",
        path.display()
    );
}
