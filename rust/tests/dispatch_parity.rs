//! Table-driven v1/v2 parity for the layered request core.
//!
//! The service monolith was split into protocol (codec) → dispatch →
//! transports (tcp/http). These tests pin the invariant the split must
//! preserve: every entry point into the [`Dispatcher`] produces the
//! same bytes for the same request. Concretely:
//!
//! * a scripted session of every op replayed through `handle_line`
//!   (the TCP path) and through `dispatch_http` (the HTTP path) on
//!   fresh engines answers reply-for-reply byte-identically;
//! * v2 envelopes through `handle_v2` (the embedding API) match
//!   `handle_line`;
//! * the v1 typed codec path — decode with [`Request`], dispatch with
//!   `handle`/`handle_rank`/`handle_stats`, encode with `to_json` —
//!   reproduces `handle_line` exactly (this *is* the pre-split
//!   `handle_line` semantics, spelled out);
//! * error replies are the same strings the public
//!   [`v2_error_json`] helper builds, and `dispatch_http` labels each
//!   outcome with the matching error code.
//!
//! Expected strings are always *computed* through the same codec
//! helpers (`util::json` sorts object keys on dump), never hardcoded.

use habitat::coordinator::{
    service, v2_check_error, v2_error_json, v2_export_workload_request,
    v2_predict_cluster_request, v2_predict_model_request, v2_predict_trace_request,
    v2_rank_cluster_request, v2_rank_trace_request, v2_register_device_request, v2_stats_request,
    v2_submit_trace_request, PredictionService, RegisteredDevice, Request,
};
use habitat::device::{registry::NewDevice, Device};
use habitat::predict::HybridPredictor;
use habitat::util::json::{self, Json};
use habitat::{models, OperationTracker};

fn fresh() -> PredictionService {
    PredictionService::with_predictor(HybridPredictor::wave_only())
}

fn t4() -> Device {
    Device::parse("t4").unwrap()
}

/// A small real trace plus its content-hashed id (deterministic, so
/// every fresh engine in a case agrees on it).
fn mlp_trace_line_and_id() -> (String, String) {
    let graph = models::by_name("mlp", 8).unwrap();
    let trace = OperationTracker::new(t4()).track(&graph);
    let line = v2_submit_trace_request(&trace);
    let reply = fresh().handle_line(&line);
    let v = json::parse(&reply).unwrap();
    v2_check_error(&v).unwrap();
    let id = v.get("trace_id").and_then(Json::as_str).unwrap().to_string();
    (line, id)
}

/// One scripted session covering every op (and every error family),
/// with the error code `dispatch_http` must attach to each reply.
fn script() -> Vec<(String, Option<&'static str>)> {
    let (submit_line, trace_id) = mlp_trace_line_and_id();
    let dests: Vec<String> = vec!["v100".into(), "p4000".into()];
    let dgx: Vec<String> = vec!["dgx".into()];
    let v1_rank = r#"{"rank":true,"model":"mlp","batch":8,"origin":"t4","dests":["v100","p4000"]}"#;
    let v2_rank =
        r#"{"v":2,"op":"rank","model":"mlp","batch":8,"origin":"t4","dests":["v100","p4000"]}"#;
    let cluster =
        v2_predict_cluster_request("mlp", 8, "t4", "v100", Some(&dgx), Some(&[1, 2, 4]), None);
    let rank_cluster =
        v2_rank_cluster_request("mlp", 8, "t4", Some(&dests), Some(&dgx), Some(&[1, 2]), None);
    vec![
        // Happy paths, v1 then v2, across every op family.
        (r#"{"model":"mlp","batch":8,"origin":"t4","dest":"v100"}"#.into(), None),
        (v2_predict_model_request("mlp", 8, "t4", "p4000", None), None),
        (v1_rank.into(), None),
        (v2_rank.into(), None),
        (submit_line, None),
        (v2_predict_trace_request(&trace_id, "v100", None), None),
        (v2_rank_trace_request(&trace_id, Some(&dests), None), None),
        (cluster, None),
        (rank_cluster, None),
        (v2_export_workload_request("mlp", 8, "t4", "v100", "dgx", 8, None), None),
        // Every error family.
        (r#"{"model":"mlp","batch":8,"origin":"t4","dest":"a100"}"#.into(), Some("unknown_device")),
        (v2_predict_model_request("mlp", 8, "t4", "a100", None), Some("unknown_device")),
        (r#"{"model":"nope","batch":8,"origin":"t4","dest":"v100"}"#.into(), Some("unknown_model")),
        (v2_predict_trace_request("deadbeef", "v100", None), Some("unknown_trace")),
        (r#"{"v":7}"#.into(), Some("unsupported_version")),
        (r#"{"v":2,"op":"noop"}"#.into(), Some("unsupported_op")),
        ("this is not json".into(), Some("bad_request")),
        // Stats last: the v2 reply carries the per-op request counters,
        // so it only matches across entry points that record metrics
        // identically for every prior line (both of these do).
        (service::stats_request_json(), None),
        (v2_stats_request(), None),
    ]
}

#[test]
fn scripted_session_matches_byte_for_byte_across_tcp_and_http_entry_points() {
    let cases = script();
    let via_tcp = fresh();
    let via_http = fresh();
    for (i, (line, code)) in cases.iter().enumerate() {
        let tcp_reply = via_tcp.handle_line(line);
        let outcome = via_http.dispatch_http(line);
        if *code == Some("bad_request") && json::parse(line).is_err() {
            // The one deliberate divergence: a line that is not JSON at
            // all answers in the transport's native error shape — the
            // flat v1 `{"error": "bad request: ..."}` object on the line
            // protocol, the structured v2 object over HTTP (its
            // transport needs a code to map to a status). Codes and the
            // embedded parse message still agree.
            let v1 = json::parse(&tcp_reply).unwrap();
            let msg = v1.get("error").and_then(Json::as_str).unwrap();
            assert!(msg.starts_with("bad request: "), "case {i}: {tcp_reply}");
            let v = json::parse(&outcome.reply).unwrap();
            let err = v2_check_error(&v).unwrap_err().to_string();
            assert!(err.contains("bad_request"), "case {i}: {err}");
            assert!(err.contains(msg), "case {i}: {err} vs {msg}");
        } else {
            assert_eq!(tcp_reply, outcome.reply, "case {i} ({line}) diverged across transports");
        }
        assert_eq!(outcome.error, *code, "case {i} ({line}) mislabeled its outcome");
    }
}

#[test]
fn v2_envelopes_match_between_handle_line_and_handle_v2() {
    // v2-only (handle_v2 is the post-version-sniff entry) and
    // stats-free (handle_v2 deliberately records no metrics, so the
    // counter-bearing stats reply is the one op that may differ).
    let v2_lines: Vec<String> = script()
        .into_iter()
        .map(|(line, _)| line)
        .filter(|l| {
            json::parse(l).is_ok_and(|v| {
                v.get("v").and_then(Json::as_f64) == Some(2.0)
                    && v.get("op").and_then(Json::as_str) != Some("stats")
            })
        })
        .collect();
    assert!(v2_lines.len() >= 8, "script lost its v2 coverage");
    let via_line = fresh();
    let via_value = fresh();
    for line in &v2_lines {
        let parsed = json::parse(line).unwrap();
        assert_eq!(
            via_line.handle_line(line),
            via_value.handle_v2(&parsed),
            "{line} diverged between handle_line and handle_v2"
        );
    }
}

#[test]
fn v1_typed_codec_path_reproduces_handle_line() {
    // decode → dispatch → encode, spelled out with the protocol types,
    // equals the dispatcher's own routing for each v1 op.
    let via_typed = fresh();
    let via_line = fresh();
    let lines = [
        r#"{"model":"mlp","batch":8,"origin":"t4","dest":"v100"}"#,
        r#"{"rank":true,"model":"mlp","batch":8,"origin":"t4","dests":["v100","p4000"]}"#,
        r#"{"stats":true}"#,
    ];
    for line in lines {
        let expected = match Request::from_json(line).unwrap() {
            Request::Predict(req) => via_typed.handle(&req).unwrap().to_json(),
            Request::Rank(req) => via_typed.handle_rank(&req).unwrap().to_json(),
            Request::Stats => via_typed.handle_stats().to_json(),
        };
        assert_eq!(via_line.handle_line(line), expected, "{line}");
    }
}

#[test]
fn v1_error_strings_survive_the_split() {
    // The v1 error contract is frozen: a bare {"error": "..."} object,
    // parse failures prefixed `bad request: `. Computed via the codec,
    // compared byte-for-byte.
    let svc = fresh();
    let reply = svc.handle_line(r#"{"model":"mlp","batch":8,"origin":"t4","dest":"a100"}"#);
    let expected = Json::obj(vec![(
        "error",
        Json::Str("unknown destination device \"a100\"".into()),
    )])
    .dump();
    assert_eq!(reply, expected);
    let reply = svc.handle_line(r#"{"model":"mlp","batch":"eight"}"#);
    let v = json::parse(&reply).unwrap();
    let msg = v.get("error").and_then(Json::as_str).unwrap();
    assert!(msg.starts_with("bad request: "), "{reply}");
}

#[test]
fn v2_error_replies_are_the_public_helper_strings() {
    let svc = fresh();
    assert_eq!(
        svc.handle_line(r#"{"v":7}"#),
        v2_error_json("unsupported_version", "unsupported protocol version 7"),
    );
    let reply = svc.handle_line(r#"{"v":2}"#);
    assert_eq!(reply, v2_error_json("bad_request", "missing string field \"op\""));
    // dispatch_http wraps even non-JSON input in the same structured
    // shape (its transport has a body to put it in).
    let out = svc.dispatch_http("{{{");
    let v = json::parse(&out.reply).unwrap();
    let code = v.get("error").and_then(|e| e.get("code")).and_then(Json::as_str);
    assert_eq!(code, Some("bad_request"));
    assert_eq!(out.error, Some("bad_request"));
}

#[test]
fn register_device_conflicts_identically_across_transports() {
    // register_device mutates the process-global registry, so the
    // byte-parity claim is made on the *conflict* replies (idempotently
    // reproducible), while first registration is checked structurally.
    let line = v2_register_device_request(&NewDevice {
        usd_per_hr: Some(0.40),
        ..NewDevice::new("sim-parity9", 40, 1500.0, 320.0, 8.1, true)
    });
    let svc = fresh();
    let first = svc.handle_line(&line);
    let ack = RegisteredDevice::from_json(&first).unwrap();
    assert_eq!(ack.device, "sim-parity9");
    // Same descriptor again: idempotent success must also agree.
    assert_eq!(svc.handle_line(&line), svc.dispatch_http(&line).reply);
    // A conflicting descriptor (different SM count) errors with the
    // same bytes and a labeled code on the HTTP side.
    let clash =
        v2_register_device_request(&NewDevice::new("sim-parity9", 41, 1500.0, 320.0, 8.1, true));
    let via_line = svc.handle_line(&clash);
    let via_http = svc.dispatch_http(&clash);
    assert_eq!(via_line, via_http.reply);
    assert_eq!(via_http.error, Some("conflict"));
    let v = json::parse(&via_line).unwrap();
    assert!(v2_check_error(&v).unwrap_err().to_string().contains("conflict"), "{via_line}");
}
