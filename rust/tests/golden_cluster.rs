//! Golden-value regression tests for cluster-scale prediction.
//!
//! The cluster subsystem's contract, pinned three ways:
//!
//! 1. `predict_cluster` at `world = 1` must be **bit-identical** to the
//!    single-GPU `predict` path — the collective model composes on top
//!    of the plan evaluation without perturbing it;
//! 2. every cell of a `predict_cluster` sweep must be bit-identical to
//!    an independent manual composition
//!    (`evaluate` + `trace_comm` + `comm::cluster::compose`);
//! 3. the bit patterns of the full 5-model × 2-topology × 9-world grid
//!    are pinned in `tests/golden/cluster.txt` with the same
//!    bless-on-first-run protocol as `golden_predictions`
//!    (`GOLDEN_BLESS=1` re-blesses, `GOLDEN_REQUIRE=1` makes a missing
//!    file an error).

use std::fmt::Write as _;

use habitat::comm::{self, ClusterParams, Topology};
use habitat::device::Device;
use habitat::engine::PredictionEngine;
use habitat::{models, Precision};

const TOPOLOGIES: [Topology; 2] = [Topology::DGX, Topology::CLOUD];
const WORLDS: [usize; 9] = [1, 2, 4, 8, 16, 32, 64, 128, 256];

fn golden_batch(model: &str) -> usize {
    models::eval_batch_sizes(model)[0]
}

#[test]
fn world_one_is_bit_identical_to_single_gpu_predict() {
    let engine = PredictionEngine::wave_only();
    let params = ClusterParams::default();
    for model in models::MODEL_NAMES {
        let batch = golden_batch(model);
        let single = engine
            .predict(model, batch, Device::Rtx2070, Device::V100, Precision::Fp32)
            .unwrap()
            .pred
            .run_time_ms();
        for topology in TOPOLOGIES {
            let report = engine
                .predict_cluster(
                    model,
                    batch,
                    Device::Rtx2070,
                    Device::V100,
                    Precision::Fp32,
                    &[topology],
                    &[1],
                    &params,
                )
                .unwrap();
            assert_eq!(report.configs.len(), 1);
            let cell = &report.configs[0];
            assert_eq!(cell.pred.comm_ms, 0.0, "{model}: world=1 must move no bytes");
            assert_eq!(cell.pred.exposed_ms, 0.0);
            assert_eq!(
                cell.pred.iter_ms.to_bits(),
                single.to_bits(),
                "{model} on {}: cluster world=1 {} vs single-GPU {}",
                topology.name(),
                cell.pred.iter_ms,
                single
            );
        }
    }
}

#[test]
fn sweep_cells_match_manual_composition_bit_for_bit() {
    let engine = PredictionEngine::wave_only();
    let params = ClusterParams::default();
    for (model, origin, dest) in [
        ("resnet50", Device::Rtx2070, Device::V100),
        ("gnmt", Device::P4000, Device::T4),
    ] {
        let batch = golden_batch(model);
        let report = engine
            .predict_cluster(model, batch, origin, dest, Precision::Fp32, &TOPOLOGIES, &WORLDS, &params)
            .unwrap();
        assert_eq!(report.configs.len(), TOPOLOGIES.len() * WORLDS.len());

        // The independent path: scalar evaluate + per-cell composition.
        let analyzed = engine.analyzed(model, batch, origin).unwrap();
        let compute_ms = engine.evaluate(&analyzed.plan, dest, Precision::Fp32).run_time_ms();
        let tc = comm::trace_comm(&analyzed.trace);
        assert_eq!(report.compute_ms.to_bits(), compute_ms.to_bits());
        for cell in &report.configs {
            let manual = comm::cluster::compose(compute_ms, batch, &tc, cell.topology, cell.world, &params);
            assert_eq!(
                cell.pred.iter_ms.to_bits(),
                manual.iter_ms.to_bits(),
                "{model} {}×{}: sweep {} vs manual {}",
                cell.topology.name(),
                cell.world,
                cell.pred.iter_ms,
                manual.iter_ms
            );
            assert_eq!(cell.pred.comm_ms.to_bits(), manual.comm_ms.to_bits());
            assert_eq!(cell.pred.throughput.to_bits(), manual.throughput.to_bits());
            assert_eq!(cell.pred.efficiency.to_bits(), manual.efficiency.to_bits());
        }
    }
}

#[test]
fn efficiency_is_monotone_nonincreasing_in_world_size() {
    let engine = PredictionEngine::wave_only();
    let params = ClusterParams::default();
    for model in models::MODEL_NAMES {
        let batch = golden_batch(model);
        let report = engine
            .predict_cluster(
                model,
                batch,
                Device::Rtx2070,
                Device::V100,
                Precision::Fp32,
                &TOPOLOGIES,
                &WORLDS,
                &params,
            )
            .unwrap();
        for topology in TOPOLOGIES {
            let effs: Vec<f64> = report
                .configs
                .iter()
                .filter(|c| c.topology == topology)
                .map(|c| c.pred.efficiency)
                .collect();
            assert_eq!(effs.len(), WORLDS.len());
            assert!((effs[0] - 1.0).abs() < 1e-12, "{model}: world=1 efficiency must be 1");
            for w in effs.windows(2) {
                assert!(
                    w[1] <= w[0] + 1e-12,
                    "{model} on {}: efficiency rose with world size ({} → {})",
                    topology.name(),
                    w[0],
                    w[1]
                );
            }
        }
    }
}

#[test]
fn exported_workload_round_trips_and_matches_the_sweep() {
    let engine = PredictionEngine::wave_only();
    let params = ClusterParams::default();
    let world = 16usize;
    let workload = engine
        .export_workload(
            "resnet50",
            golden_batch("resnet50"),
            Device::Rtx2070,
            Device::V100,
            Precision::Fp32,
            Topology::DGX,
            world,
            &params,
        )
        .unwrap();
    assert!(!workload.comm_ops.is_empty());
    for op in &workload.comm_ops {
        assert!(op.bytes > 0.0);
        assert!(!op.participants.is_empty());
        assert!(op.participants.iter().all(|&r| r < world));
    }
    // COMM_OPS-style JSON: dump → parse → rebuild must be lossless.
    let json = workload.to_value().dump();
    let parsed = habitat::util::json::parse(&json).unwrap();
    let back = comm::Workload::from_value(&parsed).unwrap();
    assert_eq!(back, workload);
    assert_eq!(back.to_value().dump(), json);
}

#[test]
fn golden_cluster_bit_patterns_are_pinned() {
    let engine = PredictionEngine::wave_only();
    let params = ClusterParams::default();
    let mut lines = Vec::new();
    for model in models::MODEL_NAMES {
        let batch = golden_batch(model);
        let report = engine
            .predict_cluster(
                model,
                batch,
                Device::Rtx2070,
                Device::V100,
                Precision::Fp32,
                &TOPOLOGIES,
                &WORLDS,
                &params,
            )
            .unwrap();
        for cell in &report.configs {
            let mut line = String::new();
            write!(
                line,
                "{model},{batch},{},{},{:016x},{:016x}",
                cell.topology.name(),
                cell.world,
                cell.pred.iter_ms.to_bits(),
                cell.pred.efficiency.to_bits()
            )
            .unwrap();
            lines.push(line);
        }
    }
    let current = lines.join("\n") + "\n";

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden");
    let path = dir.join("cluster.txt");
    if !path.exists() && std::env::var_os("GOLDEN_REQUIRE").is_some() {
        panic!(
            "GOLDEN_REQUIRE is set but {} is missing — run the suite once without \
             GOLDEN_REQUIRE and commit the blessed file",
            path.display()
        );
    }
    let bless = std::env::var_os("GOLDEN_BLESS").is_some() || !path.exists();
    if bless {
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(&path, &current).unwrap();
        eprintln!(
            "golden: blessed {} ({} entries) — commit this file to pin the values",
            path.display(),
            lines.len()
        );
        return;
    }
    let recorded = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        recorded, current,
        "golden cluster predictions drifted from {} — if the change is intentional, \
         delete the file or re-run with GOLDEN_BLESS=1 to re-bless",
        path.display()
    );
}
