//! Concurrency suite for the sharded engine and the bounded serving
//! runtime: hammer one engine from 16 threads (singleflight, cross-key
//! independence, atomic counters), then drive the TCP server with
//! 8 simultaneous pipelined clients.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use habitat::coordinator::{
    service, PredictionResponse, PredictionService, RankResponse, ServeOptions, StatsResponse,
};
use habitat::device::Device;
use habitat::engine::PredictionEngine;
use habitat::predict::HybridPredictor;
use habitat::Precision;

fn engine() -> PredictionEngine {
    PredictionEngine::wave_only()
}

// ------------------------------------------------------ engine layer --

#[test]
fn sixteen_threads_same_key_build_exactly_once() {
    let e = engine();
    std::thread::scope(|s| {
        for _ in 0..16 {
            s.spawn(|| {
                let analyzed = e.analyzed("mlp", 16, Device::T4).unwrap();
                assert!(!analyzed.trace.ops.is_empty());
            });
        }
    });
    let st = e.stats();
    assert_eq!(st.trace_misses, 1, "singleflight must track exactly once");
    assert_eq!(st.trace_hits, 15);
    assert_eq!(st.plan_builds, 1, "…and analyze exactly once");
    assert_eq!(st.trace_entries, 1);
}

#[test]
fn sixteen_threads_distinct_keys_all_build_independently() {
    // Generous capacity so per-shard bounds cannot evict however the 16
    // keys stripe.
    let e = PredictionEngine::with_capacity(HybridPredictor::wave_only(), 1024);
    std::thread::scope(|s| {
        for t in 0..16usize {
            let e = &e;
            s.spawn(move || {
                // Distinct batch per thread → 16 distinct keys, all
                // tracked in parallel with no cross-key gating.
                e.analyzed("mlp", t + 1, Device::T4).unwrap();
            });
        }
    });
    let st = e.stats();
    assert_eq!(st.trace_misses, 16, "every distinct key tracks once");
    assert_eq!(st.trace_hits, 0);
    assert_eq!(st.trace_entries, 16);
}

#[test]
fn atomic_stats_add_up_under_mixed_load() {
    // 16 threads × 50 requests over 4 keys: whatever the interleaving,
    // hits + misses == total requests, each key built exactly once, and
    // the entry count matches the key count — the counters are atomics,
    // not lossy approximations.
    let e = engine();
    let batches = [8usize, 16, 24, 32];
    std::thread::scope(|s| {
        for t in 0..16usize {
            let e = &e;
            let batches = &batches;
            s.spawn(move || {
                for i in 0..50usize {
                    let batch = batches[(t + i) % batches.len()];
                    e.analyzed("mlp", batch, Device::T4).unwrap();
                }
            });
        }
    });
    let st = e.stats();
    assert_eq!(st.trace_hits + st.trace_misses, 16 * 50);
    assert_eq!(st.trace_misses, 4, "4 keys → 4 tracking passes, never more");
    assert_eq!(st.plan_builds, 4);
    assert_eq!(st.trace_entries, 4);
}

#[test]
fn concurrent_identical_uploads_count_once() {
    let e = engine();
    let graph = habitat::models::by_name("mlp", 24).unwrap();
    let trace = habitat::OperationTracker::new(Device::T4).track(&graph);
    let ids: Vec<String> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let e = &e;
                let trace = trace.clone();
                s.spawn(move || e.submit_trace(trace).unwrap().0)
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert!(ids.windows(2).all(|w| w[0] == w[1]), "one content, one id");
    let st = e.stats();
    assert_eq!(st.trace_uploads, 1, "identical concurrent uploads count once");
    assert_eq!(st.uploaded_entries, 1);
}

#[test]
fn concurrent_rank_and_predict_agree_with_sequential() {
    // Fan-outs racing with individual predicts must produce the same
    // bits as a quiet engine.
    let e = engine();
    let expected = {
        let quiet = engine();
        let analyzed = quiet.analyzed("mlp", 32, Device::T4).unwrap();
        quiet
            .evaluate(&analyzed.plan, Device::V100, Precision::Fp32)
            .run_time_ms()
    };
    std::thread::scope(|s| {
        for _ in 0..8 {
            let e = &e;
            s.spawn(move || {
                let dests = habitat::device::registry::all_devices();
                let ranking = e
                    .rank("mlp", 32, Device::T4, &dests, Precision::Fp32)
                    .unwrap();
                assert_eq!(ranking.entries.len(), dests.len());
            });
        }
        for _ in 0..8 {
            let e = &e;
            s.spawn(move || {
                let out = e
                    .predict("mlp", 32, Device::T4, Device::V100, Precision::Fp32)
                    .unwrap();
                assert_eq!(
                    out.pred.run_time_ms().to_bits(),
                    expected.to_bits(),
                    "concurrency must not change prediction bits"
                );
            });
        }
    });
    assert_eq!(e.stats().trace_misses, 1, "all 16 callers shared one tracking pass");
}

// ----------------------------------------------------- serving layer --

fn start_server() -> service::ServerHandle {
    service::start(
        "127.0.0.1:0",
        Arc::new(PredictionService::with_predictor(HybridPredictor::wave_only())),
        ServeOptions::default(),
    )
    .unwrap()
}

#[test]
fn eight_simultaneous_clients_pipelined_lines_all_answered_in_order() {
    let handle = start_server();
    let addr = handle.local_addr();
    let dests = ["v100", "p100", "p4000", "t4", "rtx2070", "2080ti"];

    std::thread::scope(|s| {
        for c in 0..8usize {
            s.spawn(move || {
                let stream = TcpStream::connect(addr).unwrap();
                let mut write = stream.try_clone().unwrap();
                // Pipeline a mixed burst: predicts with a known reply
                // order, one rank, one stats.
                let mut lines = Vec::new();
                for i in 0..10usize {
                    let dest = dests[(c + i) % dests.len()];
                    lines.push(format!(
                        "{{\"model\":\"mlp\",\"batch\":{},\"origin\":\"t4\",\"dest\":\"{dest}\"}}",
                        8 + (c % 3) * 8
                    ));
                }
                lines.push("{\"rank\":true,\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\"}".into());
                lines.push("{\"stats\":true}".into());
                for line in &lines {
                    write.write_all(line.as_bytes()).unwrap();
                    write.write_all(b"\n").unwrap();
                }
                stream.shutdown(std::net::Shutdown::Write).unwrap();

                let replies: Vec<String> =
                    BufReader::new(stream).lines().map(|l| l.unwrap()).collect();
                assert_eq!(replies.len(), lines.len(), "no reply may be dropped");
                for (i, reply) in replies[..10].iter().enumerate() {
                    let resp = PredictionResponse::from_json(reply)
                        .unwrap_or_else(|e| panic!("client {c} line {i}: {e}: {reply}"));
                    let want = Device::parse(dests[(c + i) % dests.len()]).unwrap();
                    assert_eq!(resp.dest, want.id(), "replies must keep request order");
                }
                assert!(!RankResponse::from_json(&replies[10]).unwrap().ranking.is_empty());
                StatsResponse::from_json(&replies[11]).unwrap();
            });
        }
    });

    // Every connection wound down; the slots drained.
    for _ in 0..100 {
        if handle.active_connections() == 0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert_eq!(handle.active_connections(), 0);
    handle.shutdown();
}

#[test]
fn shutdown_joins_the_runtime_and_frees_the_port() {
    let handle = start_server();
    let addr = handle.local_addr();

    // A connection with an in-flight request at shutdown time still gets
    // its reply (drain, not abort).
    let stream = TcpStream::connect(addr).unwrap();
    let mut write = stream.try_clone().unwrap();
    write
        .write_all(b"{\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\"}\n")
        .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(PredictionResponse::from_json(line.trim()).is_ok());

    handle.shutdown();
    // The listener is closed and the reader was unblocked: the next read
    // on the old connection sees EOF rather than hanging forever.
    let mut line = String::new();
    assert_eq!(reader.read_line(&mut line).unwrap(), 0, "reader must see EOF");
    assert!(TcpStream::connect(addr).is_err(), "port must be released");
}

#[test]
fn counters_are_coherent_after_a_concurrent_session() {
    let handle = start_server();
    let addr = handle.local_addr();
    let per_client = 20usize;
    std::thread::scope(|s| {
        for _ in 0..8 {
            s.spawn(move || {
                let stream = TcpStream::connect(addr).unwrap();
                let mut write = stream.try_clone().unwrap();
                for _ in 0..per_client {
                    write
                        .write_all(
                            b"{\"model\":\"mlp\",\"batch\":16,\"origin\":\"t4\",\"dest\":\"v100\"}\n",
                        )
                        .unwrap();
                }
                stream.shutdown(std::net::Shutdown::Write).unwrap();
                let n = BufReader::new(stream).lines().filter(|l| l.is_ok()).count();
                assert_eq!(n, per_client);
            });
        }
    });
    let st = handle.service().engine().stats();
    assert_eq!(
        st.trace_hits + st.trace_misses,
        8 * per_client as u64,
        "atomic counters must account for every request"
    );
    assert_eq!(st.trace_misses, 1, "one tracking pass across all clients");
    handle.shutdown();
}

#[test]
fn pool_counter_sharing_rank_draws_from_the_service_budget() {
    // The engine pool and the serving workers are the same pool: the
    // worker count the stats report is the bound that both the fan-out
    // helpers and the request handlers live under.
    let engine = PredictionEngine::wave_only().with_workers(3).with_queue_depth(64);
    let service = Arc::new(PredictionService::with_engine(engine));
    let handle = service::start("127.0.0.1:0", service, ServeOptions::default()).unwrap();
    let addr = handle.local_addr();

    let stream = TcpStream::connect(addr).unwrap();
    let mut write = stream.try_clone().unwrap();
    write
        .write_all(b"{\"rank\":true,\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\"}\n{\"stats\":true}\n")
        .unwrap();
    stream.shutdown(std::net::Shutdown::Write).unwrap();
    let replies: Vec<String> = BufReader::new(stream).lines().map(|l| l.unwrap()).collect();
    assert_eq!(replies.len(), 2);
    assert!(!RankResponse::from_json(&replies[0]).unwrap().ranking.is_empty());
    let stats = StatsResponse::from_json(&replies[1]).unwrap();
    assert_eq!(stats.workers, 3, "one shared pool, one worker bound");
    assert_eq!(handle.service().engine().queue_depth(), 64);
    handle.shutdown();
}

#[test]
fn engine_queue_depth_is_configurable_and_clamped() {
    let e = PredictionEngine::wave_only().with_queue_depth(0);
    assert_eq!(e.queue_depth(), 1, "zero clamps to one");
    let e = PredictionEngine::wave_only().with_queue_depth(7);
    assert_eq!(e.queue_depth(), 7);
    // Forcing the pool into existence keeps the same depth.
    let _ = e.pool();
    assert_eq!(e.queue_depth(), 7);
}
