//! Fixed-width SIMD chunk ops over `f64` slices — the vector lanes
//! under the batched evaluation sweep
//! ([`crate::predict::HybridPredictor::evaluate_batch_times`]).
//!
//! Everything here is **bit-identical** to the equivalent scalar loop:
//! only IEEE-754-exact element-wise operations (multiply, divide, add)
//! are vectorized, each lane computes exactly the expression the scalar
//! path computes in exactly the same association order, and no FMA
//! contraction is ever used (a fused multiply-add rounds once where
//! `mul` + `add` round twice, which would change bits). Transcendental
//! factors (`powf`) are *not* vectorized — the evaluator computes them
//! with scalar per-lane libm calls and hands the results in as plain
//! slices — so switching the backend can never change a prediction.
//!
//! Backend selection happens once, at first use:
//!
//! * on `x86_64` with AVX2 available at runtime
//!   (`is_x86_feature_detected!`), the 4-lane `std::arch` path;
//! * otherwise a portable scalar-chunk fallback (the same loop shape,
//!   plain Rust — the optimizer is free to auto-vectorize it).
//!
//! Kill-switch: set `HABITAT_SIMD=off` (or `0`/`false`) to force the
//! scalar path — CI runs the whole test suite under both settings, and
//! the golden suite pins the two paths bit-identical. Tests can also
//! flip the backend in-process with [`set_enabled`]; because the paths
//! are bit-identical this is safe even while other threads evaluate.

use std::sync::atomic::{AtomicU8, Ordering::Relaxed};

/// Lane width the evaluator pads its destination arrays to. The AVX2
/// path consumes exactly this many `f64`s per vector op; the portable
/// fallback uses the same chunking so both paths touch memory alike.
pub const LANES: usize = 4;

/// Environment variable disabling the vector path (`off`, `0`, `false`).
pub const SIMD_ENV: &str = "HABITAT_SIMD";

const UNINIT: u8 = 0;
const SCALAR: u8 = 1;
const AVX2: u8 = 2;

static STATE: AtomicU8 = AtomicU8::new(UNINIT);

fn detect() -> u8 {
    if let Ok(v) = std::env::var(SIMD_ENV) {
        let v = v.to_ascii_lowercase();
        if v == "off" || v == "0" || v == "false" {
            return SCALAR;
        }
    }
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return AVX2;
        }
    }
    SCALAR
}

fn state() -> u8 {
    match STATE.load(Relaxed) {
        UNINIT => {
            let s = detect();
            // A concurrent first use races benignly: both sides compute
            // the same value from the same environment.
            STATE.store(s, Relaxed);
            s
        }
        s => s,
    }
}

/// Is the vector backend selected? (`false`: scalar-chunk fallback —
/// killed by `HABITAT_SIMD=off`, or no AVX2 on this machine.)
pub fn active() -> bool {
    state() == AVX2
}

/// The selected backend, for the engine's `simd` stat: `"avx2"` or
/// `"scalar"`.
pub fn backend() -> &'static str {
    if active() {
        "avx2"
    } else {
        "scalar"
    }
}

/// Force the backend in-process: `set_enabled(false)` selects the
/// scalar path, `set_enabled(true)` re-detects (which still honours
/// `HABITAT_SIMD=off`). For tests that pin SIMD-on/SIMD-off
/// bit-identity without respawning the process.
pub fn set_enabled(on: bool) {
    STATE.store(if on { detect() } else { SCALAR }, Relaxed);
}

/// `dst[i] = a[i] * b[i]` — one exact IEEE multiply per lane.
pub fn mul_into(dst: &mut [f64], a: &[f64], b: &[f64]) {
    debug_assert!(dst.len() == a.len() && dst.len() == b.len());
    #[cfg(target_arch = "x86_64")]
    if active() {
        // SAFETY: AVX2 availability was runtime-checked by `active`.
        unsafe { avx2::mul_into(dst, a, b) };
        return;
    }
    for i in 0..dst.len() {
        dst[i] = a[i] * b[i];
    }
}

/// `dst[i] = a[i] / b[i]` — one exact IEEE divide per lane.
pub fn div_into(dst: &mut [f64], a: &[f64], b: &[f64]) {
    debug_assert!(dst.len() == a.len() && dst.len() == b.len());
    #[cfg(target_arch = "x86_64")]
    if active() {
        // SAFETY: AVX2 availability was runtime-checked by `active`.
        unsafe { avx2::div_into(dst, a, b) };
        return;
    }
    for i in 0..dst.len() {
        dst[i] = a[i] / b[i];
    }
}

/// `dst[i] *= a[i]` — the AMP factor application.
pub fn mul_assign(dst: &mut [f64], a: &[f64]) {
    debug_assert!(dst.len() == a.len());
    #[cfg(target_arch = "x86_64")]
    if active() {
        // SAFETY: AVX2 availability was runtime-checked by `active`.
        unsafe { avx2::mul_assign(dst, a) };
        return;
    }
    for i in 0..dst.len() {
        dst[i] *= a[i];
    }
}

/// `dst[i] += (t * p1[i]) * p2[i]` — the Eq. 2 accumulation step
/// ([`crate::predict::wave::scale_eq2_parts`] with its two `powf`
/// factors precomputed into `p1`/`p2`). Association order matches the
/// scalar expression exactly; no FMA.
pub fn eq2_add(dst: &mut [f64], t: f64, p1: &[f64], p2: &[f64]) {
    debug_assert!(dst.len() == p1.len() && dst.len() == p2.len());
    #[cfg(target_arch = "x86_64")]
    if active() {
        // SAFETY: AVX2 availability was runtime-checked by `active`.
        unsafe { avx2::eq2_add(dst, t, p1, p2) };
        return;
    }
    for i in 0..dst.len() {
        dst[i] += (t * p1[i]) * p2[i];
    }
}

/// `dst[i] += (((t * wd[i]) * p1[i]) * p2[i]) / wo` — the Eq. 1
/// accumulation step ([`crate::predict::wave::scale_eq1_parts`] with
/// its two `powf` factors precomputed). Same association order as the
/// scalar expression; no FMA.
pub fn eq1_add(dst: &mut [f64], t: f64, wd: &[f64], p1: &[f64], p2: &[f64], wo: f64) {
    debug_assert!(dst.len() == wd.len() && dst.len() == p1.len() && dst.len() == p2.len());
    #[cfg(target_arch = "x86_64")]
    if active() {
        // SAFETY: AVX2 availability was runtime-checked by `active`.
        unsafe { avx2::eq1_add(dst, t, wd, p1, p2, wo) };
        return;
    }
    for i in 0..dst.len() {
        dst[i] += (((t * wd[i]) * p1[i]) * p2[i]) / wo;
    }
}

/// The AVX2 lanes. Every function is `unsafe` (callers must have
/// runtime-verified AVX2) and uses only `_mm256_{mul,div,add}_pd` —
/// exact IEEE-754 operations, never FMA — so each lane is bit-identical
/// to the scalar fallback. Trailing elements past the last full
/// 4-lane chunk run the identical scalar expressions.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::{
        __m256d, _mm256_add_pd, _mm256_div_pd, _mm256_loadu_pd, _mm256_mul_pd, _mm256_set1_pd,
        _mm256_storeu_pd,
    };

    use super::LANES;

    #[inline]
    unsafe fn load(s: &[f64], i: usize) -> __m256d {
        _mm256_loadu_pd(s.as_ptr().add(i))
    }

    #[inline]
    unsafe fn store(s: &mut [f64], i: usize, v: __m256d) {
        _mm256_storeu_pd(s.as_mut_ptr().add(i), v)
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn mul_into(dst: &mut [f64], a: &[f64], b: &[f64]) {
        let n = dst.len() / LANES * LANES;
        for i in (0..n).step_by(LANES) {
            store(dst, i, _mm256_mul_pd(load(a, i), load(b, i)));
        }
        for i in n..dst.len() {
            dst[i] = a[i] * b[i];
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn div_into(dst: &mut [f64], a: &[f64], b: &[f64]) {
        let n = dst.len() / LANES * LANES;
        for i in (0..n).step_by(LANES) {
            store(dst, i, _mm256_div_pd(load(a, i), load(b, i)));
        }
        for i in n..dst.len() {
            dst[i] = a[i] / b[i];
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn mul_assign(dst: &mut [f64], a: &[f64]) {
        let n = dst.len() / LANES * LANES;
        for i in (0..n).step_by(LANES) {
            store(dst, i, _mm256_mul_pd(load(dst, i), load(a, i)));
        }
        for i in n..dst.len() {
            dst[i] *= a[i];
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn eq2_add(dst: &mut [f64], t: f64, p1: &[f64], p2: &[f64]) {
        let tv = _mm256_set1_pd(t);
        let n = dst.len() / LANES * LANES;
        for i in (0..n).step_by(LANES) {
            let term = _mm256_mul_pd(_mm256_mul_pd(tv, load(p1, i)), load(p2, i));
            store(dst, i, _mm256_add_pd(load(dst, i), term));
        }
        for i in n..dst.len() {
            dst[i] += (t * p1[i]) * p2[i];
        }
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn eq1_add(
        dst: &mut [f64],
        t: f64,
        wd: &[f64],
        p1: &[f64],
        p2: &[f64],
        wo: f64,
    ) {
        let tv = _mm256_set1_pd(t);
        let wov = _mm256_set1_pd(wo);
        let n = dst.len() / LANES * LANES;
        for i in (0..n).step_by(LANES) {
            let term = _mm256_mul_pd(
                _mm256_mul_pd(_mm256_mul_pd(tv, load(wd, i)), load(p1, i)),
                load(p2, i),
            );
            store(dst, i, _mm256_add_pd(load(dst, i), _mm256_div_pd(term, wov)));
        }
        for i in n..dst.len() {
            dst[i] += (((t * wd[i]) * p1[i]) * p2[i]) / wo;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp(n: usize, seed: f64) -> Vec<f64> {
        (0..n).map(|i| seed + i as f64 * 0.37).collect()
    }

    /// Run `f` under both backends and assert the outputs match
    /// bit-for-bit (on machines without AVX2 both runs take the scalar
    /// path and the comparison is trivially true).
    fn both_backends(f: impl Fn() -> Vec<f64>) {
        set_enabled(true);
        let vector = f();
        set_enabled(false);
        let scalar = f();
        set_enabled(true);
        for (a, b) in vector.iter().zip(&scalar) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn backend_reports_a_known_name() {
        assert!(matches!(backend(), "avx2" | "scalar"));
        set_enabled(false);
        assert_eq!(backend(), "scalar");
        assert!(!active());
        set_enabled(true);
    }

    #[test]
    fn mul_div_assign_match_scalar_bitwise() {
        // Lengths straddling the 4-lane chunk boundary exercise both
        // the vector body and the scalar tail.
        for n in [1usize, 3, 4, 7, 8, 17] {
            let a = ramp(n, 1.25);
            let b = ramp(n, 0.5);
            both_backends(|| {
                let mut dst = vec![0.0; n];
                mul_into(&mut dst, &a, &b);
                dst
            });
            both_backends(|| {
                let mut dst = vec![0.0; n];
                div_into(&mut dst, &a, &b);
                dst
            });
            both_backends(|| {
                let mut dst = a.clone();
                mul_assign(&mut dst, &b);
                dst
            });
        }
    }

    #[test]
    fn accumulation_steps_match_scalar_bitwise() {
        for n in [1usize, 4, 6, 12, 31] {
            let p1 = ramp(n, 0.9);
            let p2 = ramp(n, 1.1);
            let wd = ramp(n, 2.0);
            both_backends(|| {
                let mut dst = ramp(n, 0.01);
                eq2_add(&mut dst, 3.5, &p1, &p2);
                dst
            });
            both_backends(|| {
                let mut dst = ramp(n, 0.02);
                eq1_add(&mut dst, 3.5, &wd, &p1, &p2, 7.0);
                dst
            });
        }
    }

    #[test]
    fn eq2_add_matches_the_wave_expression() {
        // The lane step must reproduce scale_eq2_parts exactly when
        // handed its powf factors.
        let (t, bw, wave, clock, g) = (1.75, 0.8, 1.3, 0.95, 0.4);
        let p1 = [f64::powf(bw, g)];
        let p2 = [f64::powf(wave * clock, 1.0 - g)];
        let mut dst = [0.0];
        eq2_add(&mut dst, t, &p1, &p2);
        let scalar = crate::predict::wave::scale_eq2_parts(t, bw, wave, clock, g);
        assert_eq!(dst[0].to_bits(), scalar.to_bits());
    }

    #[test]
    fn eq1_add_matches_the_wave_expression() {
        let (t, wo, wd, bw, wave, clock, g) = (1.75, 3.0, 5.0, 0.8, 1.3, 0.95, 0.4);
        let p1 = [f64::powf(bw / wave, g)];
        let p2 = [f64::powf(clock, 1.0 - g)];
        let mut dst = [0.0];
        eq1_add(&mut dst, t, &[wd], &p1, &p2, wo);
        let scalar = crate::predict::wave::scale_eq1_parts(t, wo, wd, bw, wave, clock, g);
        assert_eq!(dst[0].to_bits(), scalar.to_bits());
    }
}
