//! Deterministic pseudo-random number generation.
//!
//! The dataset sampler (§4.3.1 of the paper) and the simulator's
//! measurement jitter both need *reproducible* randomness: the paper uses a
//! fixed seed so the same input configurations are sampled on every GPU.
//! We use SplitMix64 — tiny, fast, and statistically solid for this use.

/// SplitMix64 PRNG (Steele, Lea & Flood 2014). Deterministic for a seed.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. The same seed always yields the same
    /// stream, on every platform.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    pub fn int_range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        let span = hi - lo + 1;
        lo + self.next_u64() % span
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn usize_range(&mut self, lo: usize, hi: usize) -> usize {
        self.int_range(lo as u64, hi as u64) as usize
    }

    /// Log-uniform integer in `[lo, hi]` (inclusive). Layer-dimension
    /// parameters (channels, features) are sampled log-uniformly so small
    /// and large configurations are both well represented — matching how
    /// real DNN layer sizes are distributed across torchvision models.
    pub fn log_int_range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo >= 1 && lo <= hi);
        let (llo, lhi) = ((lo as f64).ln(), (hi as f64).ln());
        let v = (llo + self.next_f64() * (lhi - llo)).exp().round() as u64;
        v.clamp(lo, hi)
    }

    /// Bernoulli draw.
    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_range(0, xs.len() - 1)]
    }
}

/// Stateless deterministic hash → `[0, 1)` float. Used for the simulator's
/// per-kernel measurement jitter so that "measurements" are noisy but
/// perfectly reproducible (same kernel + device + salt ⇒ same jitter).
pub fn hash01(parts: &[u64]) -> f64 {
    let mut h: u64 = 0xcbf29ce484222325; // FNV offset basis
    for &p in parts {
        for b in p.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3); // FNV prime
        }
    }
    // Finalize through one SplitMix64 round for avalanche.
    Rng::new(h).next_f64()
}

/// Hash a string into a u64 (FNV-1a), for use with [`hash01`].
pub fn hash_str(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn int_range_inclusive_bounds() {
        let mut r = Rng::new(3);
        let (mut saw_lo, mut saw_hi) = (false, false);
        for _ in 0..10_000 {
            let v = r.int_range(5, 8);
            assert!((5..=8).contains(&v));
            saw_lo |= v == 5;
            saw_hi |= v == 8;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn log_range_clamps_and_covers() {
        let mut r = Rng::new(11);
        for _ in 0..10_000 {
            let v = r.log_int_range(3, 2048);
            assert!((3..=2048).contains(&v));
        }
    }

    #[test]
    fn log_range_biases_small() {
        // Log-uniform over [1, 1024]: ~half the mass below 32.
        let mut r = Rng::new(13);
        let below = (0..10_000)
            .filter(|_| r.log_int_range(1, 1024) <= 32)
            .count();
        assert!(below > 4_000, "below={below}");
    }

    #[test]
    fn hash01_deterministic_and_unit() {
        let a = hash01(&[1, 2, 3]);
        let b = hash01(&[1, 2, 3]);
        let c = hash01(&[1, 2, 4]);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!((0.0..1.0).contains(&a));
    }

    #[test]
    fn hash_str_stable() {
        assert_eq!(hash_str("gemm"), hash_str("gemm"));
        assert_ne!(hash_str("gemm"), hash_str("conv"));
    }
}
