//! Minimal CSV writing/reading for the MLP dataset pipeline and experiment
//! results. Values are plain (no quoting needed): numbers and identifiers.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use crate::Result;

/// Buffered CSV writer with a fixed header.
pub struct CsvWriter {
    out: BufWriter<File>,
    columns: usize,
}

impl CsvWriter {
    /// Create the file (truncating) and write the header row.
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter {
            out,
            columns: header.len(),
        })
    }

    /// Write one row of string fields.
    pub fn row(&mut self, fields: &[String]) -> Result<()> {
        debug_assert_eq!(fields.len(), self.columns, "column count mismatch");
        writeln!(self.out, "{}", fields.join(","))?;
        Ok(())
    }

    /// Write one row of f64 fields with compact formatting.
    pub fn row_f64(&mut self, fields: &[f64]) -> Result<()> {
        let s: Vec<String> = fields.iter().map(|v| format_num(*v)).collect();
        self.row(&s)
    }

    /// Flush buffered rows to disk.
    pub fn finish(mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

/// Format a float compactly: integers without a trailing `.0`, otherwise
/// up to 6 significant decimals.
pub fn format_num(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:.6}")
    }
}

/// Read a CSV file into (header, rows-of-f64). Non-numeric fields error.
pub fn read_numeric<P: AsRef<Path>>(path: P) -> Result<(Vec<String>, Vec<Vec<f64>>)> {
    let f = BufReader::new(File::open(path)?);
    let mut lines = f.lines();
    let header = lines
        .next()
        .ok_or_else(|| anyhow::anyhow!("empty csv"))??
        .split(',')
        .map(str::to_string)
        .collect();
    let mut rows = Vec::new();
    for line in lines {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        rows.push(
            line.split(',')
                .map(|s| s.trim().parse::<f64>().map_err(|e| anyhow::anyhow!("{e}: {s:?}")))
                .collect::<Result<Vec<f64>>>()?,
        );
    }
    Ok((header, rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("habitat_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        w.row_f64(&[1.0, 2.5]).unwrap();
        w.row_f64(&[3.0, 4.0]).unwrap();
        w.finish().unwrap();
        let (header, rows) = read_numeric(&path).unwrap();
        assert_eq!(header, vec!["a", "b"]);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0][0], 1.0);
        assert!((rows[0][1] - 2.5).abs() < 1e-9);
    }

    #[test]
    fn format_compact() {
        assert_eq!(format_num(3.0), "3");
        assert_eq!(format_num(3.25), "3.250000");
    }
}
