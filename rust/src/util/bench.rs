//! Tiny benchmark harness (the image has no criterion).
//!
//! `cargo bench` runs each `[[bench]]` target's `main()`; this module
//! provides the timing loop: warmup, then timed iterations, reporting
//! mean / p50 / p95 and throughput. Deterministic workloads + wall-clock
//! medians make results stable enough for the §Perf iteration log.

use std::time::Instant;

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<44} {:>10.1} µs/iter (p50 {:>9.1}, p95 {:>9.1}, n={})",
            self.name, self.mean_us, self.p50_us, self.p95_us, self.iters
        );
    }
}

/// Run `f` with warmup then timed iterations; prints and returns stats.
/// The closure's return value is black-boxed to keep the optimizer honest.
pub fn bench<T, F: FnMut() -> T>(name: &str, mut f: F) -> BenchResult {
    // Calibrate: aim for ~0.6 s of timed work, 3..=200 iterations.
    let t0 = Instant::now();
    std::hint::black_box(f());
    let once = t0.elapsed().as_secs_f64().max(1e-7);
    let iters = ((0.6 / once) as usize).clamp(3, 200);

    // Warmup.
    for _ in 0..(iters / 5).max(1) {
        std::hint::black_box(f());
    }

    let mut samples_us = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        std::hint::black_box(f());
        samples_us.push(t.elapsed().as_secs_f64() * 1e6);
    }
    let mean = crate::util::stats::mean(&samples_us);
    let p50 = crate::util::stats::percentile(&samples_us, 50.0);
    let p95 = crate::util::stats::percentile(&samples_us, 95.0);
    let result = BenchResult {
        name: name.to_string(),
        iters,
        mean_us: mean,
        p50_us: p50,
        p95_us: p95,
    };
    result.print();
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("noop-ish", || {
            let mut s = 0u64;
            for i in 0..1000u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.mean_us >= 0.0);
        assert!(r.p50_us <= r.p95_us + 1e-9);
        assert!(r.iters >= 3);
    }
}
