//! Minimal little-endian binary reader/writer for the persistent plan
//! store (`engine::store`).
//!
//! The store's promise is *bit*-preservation: an `f64` lane value must
//! round-trip to the identical bit pattern, which JSON cannot guarantee
//! (and parses far too slowly for the warm-restore budget). This module
//! writes raw LE bytes with length-prefixed strings and slices, and
//! reads them back with hard bounds checks — a truncated or corrupt
//! buffer yields an `Err`, never a panic or an unbounded allocation.

use anyhow::{bail, ensure, Result};

/// Append-only little-endian byte writer.
#[derive(Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Raw byte append (no length prefix) — for fixed-size framing
    /// like the store's record magic.
    pub fn raw(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    pub fn bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// `f64` as its raw bit pattern (exact round-trip, NaN included).
    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    /// UTF-8 string, `u32` byte-length prefixed.
    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// `u64` slice, `u32` count prefixed, raw LE elements.
    pub fn u64_slice(&mut self, xs: &[u64]) {
        self.u32(xs.len() as u32);
        for &x in xs {
            self.u64(x);
        }
    }

    /// `f64` slice, `u32` count prefixed, raw bit-pattern elements.
    pub fn f64_slice(&mut self, xs: &[f64]) {
        self.u32(xs.len() as u32);
        for &x in xs {
            self.f64(x);
        }
    }
}

/// Bounds-checked little-endian reader over a byte slice.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.remaining() >= n, "truncated: need {n} bytes, have {}", self.remaining());
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => bail!("invalid bool byte {b}"),
        }
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// A length-prefixed count of `elem_size`-byte elements, validated
    /// against the bytes actually remaining — a bit-flipped length
    /// cannot trigger a huge allocation.
    fn count(&mut self, elem_size: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        ensure!(
            n.saturating_mul(elem_size) <= self.remaining(),
            "corrupt length {n} exceeds remaining {} bytes",
            self.remaining()
        );
        Ok(n)
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.count(1)?;
        Ok(std::str::from_utf8(self.take(n)?)?.to_string())
    }

    pub fn u64_vec(&mut self) -> Result<Vec<u64>> {
        let n = self.count(8)?;
        (0..n).map(|_| self.u64()).collect()
    }

    pub fn f64_vec(&mut self) -> Result<Vec<f64>> {
        let n = self.count(8)?;
        (0..n).map(|_| self.f64()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_every_type() {
        let mut w = Writer::new();
        w.u8(7);
        w.bool(true);
        w.bool(false);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX - 3);
        w.f64(-0.0);
        w.f64(f64::NAN);
        w.str("habitat");
        w.str("");
        w.u64_slice(&[1, 2, 3]);
        w.f64_slice(&[1.5, -2.25]);
        let bytes = w.into_bytes();

        let mut r = Reader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 7);
        assert!(r.bool().unwrap());
        assert!(!r.bool().unwrap());
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.f64().unwrap().to_bits(), (-0.0f64).to_bits());
        assert!(r.f64().unwrap().is_nan());
        assert_eq!(r.str().unwrap(), "habitat");
        assert_eq!(r.str().unwrap(), "");
        assert_eq!(r.u64_vec().unwrap(), vec![1, 2, 3]);
        assert_eq!(r.f64_vec().unwrap(), vec![1.5, -2.25]);
        assert!(r.is_empty());
    }

    #[test]
    fn truncation_and_corruption_error_out() {
        let mut w = Writer::new();
        w.u64_slice(&[1, 2, 3, 4]);
        let bytes = w.into_bytes();
        // Truncated mid-slice.
        assert!(Reader::new(&bytes[..bytes.len() - 1]).u64_vec().is_err());
        // A length field claiming far more elements than bytes remain
        // must be rejected before allocating.
        let mut huge = bytes.clone();
        huge[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(Reader::new(&huge).u64_vec().is_err());
        // Bad bool byte.
        assert!(Reader::new(&[9]).bool().is_err());
        // Invalid UTF-8 in a string.
        let mut sw = Writer::new();
        sw.u32(2);
        sw.u8(0xFF);
        sw.u8(0xFE);
        assert!(Reader::new(&sw.into_bytes()).str().is_err());
    }
}
