//! Minimal JSON parser/serializer (the image has no serde_json).
//!
//! Supports the full JSON grammar minus exotic number forms; good enough
//! for the artifact sidecar files (`*.meta.json`) and the TCP prediction
//! protocol. Numbers are f64; object keys keep insertion order.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::Result;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // --- accessors ------------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|v| *v >= 0.0 && v.fract() == 0.0).map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Required typed field helpers (error messages name the field).
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field {key:?}"))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid integer field {key:?}"))
    }

    pub fn req_f64_array(&self, key: &str) -> Result<Vec<f64>> {
        let arr = self
            .get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid array field {key:?}"))?;
        arr.iter()
            .map(|v| v.as_f64().ok_or_else(|| anyhow::anyhow!("non-number in {key:?}")))
            .collect()
    }

    // --- construction helpers --------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num_arr(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
    }

    // --- serialization ----------------------------------------------------
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Json> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    anyhow::ensure!(p.pos == p.bytes.len(), "trailing garbage at byte {}", p.pos);
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        anyhow::ensure!(
            self.peek() == Some(b),
            "expected {:?} at byte {}",
            b as char,
            self.pos
        );
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        anyhow::ensure!(
            self.bytes[self.pos..].starts_with(lit.as_bytes()),
            "bad literal at byte {}",
            self.pos
        );
        self.pos += lit.len();
        Ok(v)
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            anyhow::ensure!(self.pos + 4 < self.bytes.len(), "bad \\u escape");
                            let hex = std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => anyhow::bail!("bad escape {other:?}"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (we sliced on byte positions,
                    // so walk to the next char boundary).
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => anyhow::bail!("expected , or ] but got {other:?} at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let value = self.value()?;
            map.insert(key, value);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => anyhow::bail!("expected , or }} but got {other:?} at byte {}", self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].req_str("b").unwrap(), "x");
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let v = Json::obj(vec![
            ("op", Json::Str("conv2d".into())),
            ("mean", Json::num_arr(&[1.0, 2.5, -3.0])),
            ("features", Json::Num(11.0)),
            ("flag", Json::Bool(false)),
        ]);
        let s = v.dump();
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = parse(r#""Aß→""#).unwrap();
        assert_eq!(v, Json::Str("Aß→".into()));
        let s = Json::Str("a\"b\\c\u{1}".into()).dump();
        assert_eq!(parse(&s).unwrap(), Json::Str("a\"b\\c\u{1}".into()));
    }

    #[test]
    fn typed_field_helpers() {
        let v = parse(r#"{"n": 5, "s": "x", "a": [1.5, 2]}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 5);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert_eq!(v.req_f64_array("a").unwrap(), vec![1.5, 2.0]);
        assert!(v.req_usize("s").is_err());
        assert!(v.req_str("missing").is_err());
    }
}
