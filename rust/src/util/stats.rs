//! Error metrics and summary statistics used throughout the evaluation.

/// Mean of a slice. Returns 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation. Returns 0.0 for fewer than two samples.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Absolute percentage error `|pred - meas| / meas`, as a fraction.
/// This is the paper's headline error metric (§5.2).
pub fn ape(predicted: f64, measured: f64) -> f64 {
    debug_assert!(measured > 0.0, "measured time must be positive");
    (predicted - measured).abs() / measured
}

/// Mean absolute percentage error over paired slices, as a fraction.
pub fn mape(predicted: &[f64], measured: &[f64]) -> f64 {
    assert_eq!(predicted.len(), measured.len());
    mean(
        &predicted
            .iter()
            .zip(measured)
            .map(|(p, m)| ape(*p, *m))
            .collect::<Vec<_>>(),
    )
}

/// Percentile via linear interpolation (`p` in `[0, 100]`).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (s.len() - 1) as f64;
    let (lo, hi) = (rank.floor() as usize, rank.ceil() as usize);
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
    }
}

/// Maximum of a slice (0.0 if empty).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(0.0, f64::max)
}

/// Ordinary least-squares fit `y = a + b·x`; returns `(a, b)`.
/// Used by the batch-size extrapolator (§6.1.3), which builds a linear
/// model of iteration time vs. batch size.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "need at least two points to fit a line");
    let (mx, my) = (mean(xs), mean(ys));
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    let b = if den == 0.0 { 0.0 } else { num / den };
    (my - b * mx, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
        assert!((std_dev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn ape_symmetric_in_magnitude() {
        assert!((ape(110.0, 100.0) - 0.1).abs() < 1e-12);
        assert!((ape(90.0, 100.0) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn mape_pairs() {
        let p = [110.0, 90.0];
        let m = [100.0, 100.0];
        assert!((mape(&p, &m) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn linear_fit_exact_line() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [3.0, 5.0, 7.0, 9.0]; // y = 1 + 2x
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
    }

    #[test]
    fn max_of_slice() {
        assert_eq!(max(&[1.0, 5.0, 3.0]), 5.0);
        assert_eq!(max(&[]), 0.0);
    }
}
