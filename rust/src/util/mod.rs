//! Small shared utilities: deterministic RNG, statistics, CSV and
//! binary I/O.

pub mod bench;
pub mod binio;
pub mod csv;
pub mod json;
pub mod rng;
pub mod simdf64;
pub mod stats;

pub use rng::Rng;
