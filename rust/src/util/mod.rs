//! Small shared utilities: deterministic RNG, statistics, CSV I/O.

pub mod bench;
pub mod csv;
pub mod json;
pub mod rng;
pub mod stats;

pub use rng::Rng;
