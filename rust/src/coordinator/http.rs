//! The HTTP transport: a dependency-free HTTP/1.1 front end over the
//! shared [`Dispatcher`](super::Dispatcher).
//!
//! Serves three endpoints (see `docs/SERVICE.md` for the full
//! reference):
//!
//! * `POST /v2` — one request per body, same envelope the TCP transport
//!   speaks (v1 bare objects are accepted too and answer in the v1
//!   shape). The dispatcher's error code maps to the status: success →
//!   200, `internal` → 500, `overloaded` → 503, everything else → 400.
//! * `GET /healthz` — liveness: `200 ok`.
//! * `GET /metrics` — Prometheus text exposition of the per-op request
//!   counters, latency histograms, and engine gauges
//!   ([`ServiceMetrics::render_prometheus`](crate::engine::metrics::ServiceMetrics::render_prometheus)).
//!
//! The runtime mirrors the TCP transport's bounds
//! ([`ServeOptions`]): connection slots (a connect past
//! `max_conns` gets one `503` and a close), per-request jobs on the
//! engine's shared compute pool (full queue → `503 overloaded` for that
//! request), and graceful drain on shutdown. Like every transport, this
//! module never parses envelopes — bodies go to
//! [`Dispatcher::dispatch_http`](super::Dispatcher::dispatch_http)
//! opaque, and only the returned error code is inspected for status
//! mapping.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use crate::Result;

use super::dispatch::PredictionService;
use super::protocol::v2_error_json;
use super::tcp::{internal_error_json, overloaded_json, ServeOptions, CONN_WRITE_TIMEOUT};

/// Largest accepted request body. Even the biggest `submit_trace`
/// payloads are a few MiB of JSON; anything larger is a mistake or
/// abuse and gets `413` before the server buffers it.
pub const MAX_BODY_BYTES: usize = 16 * 1024 * 1024;

const CONTENT_TYPE_JSON: &str = "application/json";
const CONTENT_TYPE_TEXT: &str = "text/plain; version=0.0.4";

/// One parsed request head plus its (bounded) body.
struct HttpRequest {
    method: String,
    path: String,
    body: String,
    /// Client asked to close (or speaks HTTP/1.0 without keep-alive).
    close: bool,
}

/// What reading one request off the socket produced.
enum ReadOutcome {
    /// Clean end of the connection.
    Eof,
    Request(HttpRequest),
    /// Protocol-level reject: answer with this status and close.
    Reject { status: u16, message: String },
}

/// State shared by the acceptor, the connection threads, and the
/// [`HttpServerHandle`] — the same slot/drain scaffolding as the TCP
/// runtime.
struct HttpShared {
    service: Arc<PredictionService>,
    opts: ServeOptions,
    shutdown: AtomicBool,
    active: AtomicUsize,
    streams: Mutex<HashMap<u64, TcpStream>>,
    threads: Mutex<Vec<JoinHandle<()>>>,
    next_conn: AtomicU64,
}

impl HttpShared {
    fn spawn_connection(self: &Arc<Self>, stream: TcpStream) {
        if self.active.fetch_add(1, Ordering::SeqCst) >= self.opts.max_conns {
            self.active.fetch_sub(1, Ordering::SeqCst);
            let mut stream = stream;
            let body = body_line(overloaded_json());
            let _ = write_response(&mut stream, 503, CONTENT_TYPE_JSON, &body, true);
            return; // drop closes the socket
        }
        let _ = stream.set_write_timeout(Some(CONN_WRITE_TIMEOUT));
        let id = self.next_conn.fetch_add(1, Ordering::Relaxed);
        if let Ok(clone) = stream.try_clone() {
            self.streams.lock().unwrap().insert(id, clone);
        }
        self.threads.lock().unwrap().retain(|h| !h.is_finished());
        let shared = Arc::clone(self);
        let spawned = std::thread::Builder::new()
            .name(format!("habitat-http-{id}"))
            .spawn(move || {
                let peer = stream.peer_addr().map(|p| p.to_string()).unwrap_or_default();
                if let Err(e) = run_connection(stream, &shared) {
                    if !shared.shutdown.load(Ordering::SeqCst) {
                        eprintln!("habitat: http connection {peer}: {e}");
                    }
                }
                shared.streams.lock().unwrap().remove(&id);
                shared.active.fetch_sub(1, Ordering::SeqCst);
            });
        match spawned {
            Ok(handle) => self.threads.lock().unwrap().push(handle),
            Err(_) => {
                self.streams.lock().unwrap().remove(&id);
                self.active.fetch_sub(1, Ordering::SeqCst);
            }
        }
    }
}

/// A running HTTP front end. Dropping the handle drains and stops it
/// (same contract as the TCP [`ServerHandle`](super::ServerHandle)).
pub struct HttpServerHandle {
    addr: SocketAddr,
    shared: Arc<HttpShared>,
    acceptor: Option<JoinHandle<()>>,
}

impl HttpServerHandle {
    /// The bound address (with the OS-assigned port when `:0` was
    /// requested).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn service(&self) -> &Arc<PredictionService> {
        &self.shared.service
    }

    /// Occupied connection slots right now.
    pub fn active_connections(&self) -> usize {
        self.shared.active.load(Ordering::SeqCst)
    }

    /// Stop accepting, drain in-flight responses, and join all runtime
    /// threads. Idempotent; also invoked by `Drop`.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        if self.shared.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        let mut wake = self.addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST));
        }
        let _ = TcpStream::connect_timeout(&wake, std::time::Duration::from_millis(250));
        if let Some(acceptor) = self.acceptor.take() {
            let _ = acceptor.join();
        }
        // Half-close read sides: keep-alive connections parked in
        // `read_line` see EOF and wind down after flushing their
        // in-flight response.
        for stream in self.shared.streams.lock().unwrap().values() {
            let _ = stream.shutdown(std::net::Shutdown::Read);
        }
        let threads: Vec<JoinHandle<()>> = self.shared.threads.lock().unwrap().drain(..).collect();
        for handle in threads {
            let _ = handle.join();
        }
    }
}

impl Drop for HttpServerHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Start the HTTP front end on `addr` around an existing (shared)
/// service. Returns once the listener is bound; accepting and all
/// request handling run on background threads owned by the returned
/// handle. `opts.max_conns` bounds concurrent connections exactly like
/// the TCP runtime ([`opts.http_port`](ServeOptions::http_port) is not
/// consulted here — the caller already chose this address).
pub fn start(
    addr: &str,
    service: Arc<PredictionService>,
    opts: ServeOptions,
) -> Result<HttpServerHandle> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let shared = Arc::new(HttpShared {
        service,
        opts,
        shutdown: AtomicBool::new(false),
        active: AtomicUsize::new(0),
        streams: Mutex::new(HashMap::new()),
        threads: Mutex::new(Vec::new()),
        next_conn: AtomicU64::new(0),
    });
    let for_acceptor = Arc::clone(&shared);
    let acceptor = std::thread::Builder::new()
        .name("habitat-http-accept".to_string())
        .spawn(move || {
            for stream in listener.incoming() {
                if for_acceptor.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                let stream = match stream {
                    Ok(s) => s,
                    Err(e) => {
                        eprintln!("habitat: http accept error: {e}");
                        std::thread::sleep(std::time::Duration::from_millis(100));
                        continue;
                    }
                };
                for_acceptor.spawn_connection(stream);
            }
        })?;
    Ok(HttpServerHandle {
        addr: local,
        shared,
        acceptor: Some(acceptor),
    })
}

/// One keep-alive connection: read a request, answer it, repeat until
/// the client closes (or asks to via `Connection: close`).
fn run_connection(stream: TcpStream, shared: &Arc<HttpShared>) -> Result<()> {
    let mut write = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    loop {
        let req = match read_request(&mut reader, &mut write)? {
            ReadOutcome::Eof => break,
            ReadOutcome::Reject { status, message } => {
                write_response(
                    &mut write,
                    status,
                    CONTENT_TYPE_JSON,
                    &body_line(v2_error_json("bad_request", &message)),
                    true,
                )?;
                break;
            }
            ReadOutcome::Request(req) => req,
        };
        let (status, content_type, body) = respond(&req, shared);
        let close = req.close || shared.shutdown.load(Ordering::SeqCst);
        write_response(&mut write, status, content_type, &body, close)?;
        if close {
            break;
        }
    }
    Ok(())
}

/// Parse one request off the wire: request line, headers (only
/// `Content-Length`, `Connection`, `Expect`, and `Transfer-Encoding`
/// matter to us), then exactly `Content-Length` body bytes.
fn read_request(
    reader: &mut BufReader<TcpStream>,
    write: &mut TcpStream,
) -> Result<ReadOutcome> {
    // Request line (tolerate stray blank lines between requests).
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(ReadOutcome::Eof);
        }
        if !line.trim_end().is_empty() {
            break;
        }
    }
    let mut parts = line.split_whitespace();
    let (method, path, version) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v)) if v.starts_with("HTTP/") => (m, p, v),
        _ => {
            return Ok(ReadOutcome::Reject {
                status: 400,
                message: format!("malformed request line {:?}", line.trim_end()),
            })
        }
    };
    let method = method.to_string();
    let path = path.to_string();
    // HTTP/1.1 defaults to keep-alive; anything else to close.
    let mut close = version != "HTTP/1.1";
    let mut content_length = 0usize;
    let mut expect_continue = false;
    loop {
        let mut header = String::new();
        if reader.read_line(&mut header)? == 0 {
            return Ok(ReadOutcome::Eof); // truncated mid-headers
        }
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        let Some((key, value)) = header.split_once(':') else {
            continue; // tolerate junk header lines
        };
        let value = value.trim();
        match key.trim().to_ascii_lowercase().as_str() {
            "content-length" => match value.parse::<usize>() {
                Ok(n) => content_length = n,
                Err(_) => {
                    return Ok(ReadOutcome::Reject {
                        status: 400,
                        message: format!("invalid Content-Length {value:?}"),
                    })
                }
            },
            "connection" => {
                let value = value.to_ascii_lowercase();
                if value.split(',').any(|t| t.trim() == "close") {
                    close = true;
                } else if value.split(',').any(|t| t.trim() == "keep-alive") {
                    close = false;
                }
            }
            "expect" => {
                if value.eq_ignore_ascii_case("100-continue") {
                    expect_continue = true;
                }
            }
            "transfer-encoding" => {
                return Ok(ReadOutcome::Reject {
                    status: 400,
                    message: "chunked transfer encoding is not supported; send Content-Length"
                        .to_string(),
                })
            }
            _ => {}
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Ok(ReadOutcome::Reject {
            status: 413,
            message: format!(
                "request body of {content_length} bytes exceeds the {MAX_BODY_BYTES} limit"
            ),
        });
    }
    if expect_continue && content_length > 0 {
        write.write_all(b"HTTP/1.1 100 Continue\r\n\r\n")?;
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    let body = String::from_utf8_lossy(&body).into_owned();
    Ok(ReadOutcome::Request(HttpRequest { method, path, body, close }))
}

/// Route one request: the observability endpoints answer inline (they
/// only read counters); `POST /v2` rides the compute pool exactly like
/// a TCP request line.
fn respond(req: &HttpRequest, shared: &Arc<HttpShared>) -> (u16, &'static str, String) {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => (200, CONTENT_TYPE_TEXT, "ok\n".to_string()),
        ("GET", "/metrics") => {
            let engine = shared.service.engine();
            let text = engine.metrics().render_prometheus(&engine.stats());
            (200, CONTENT_TYPE_TEXT, text)
        }
        ("POST", "/v2") => dispatch_pooled(&req.body, shared),
        (_, "/v2") => (
            405,
            CONTENT_TYPE_JSON,
            body_line(v2_error_json(
                "bad_request",
                &format!("method {} not allowed on /v2 (want POST)", req.method),
            )),
        ),
        (_, "/healthz") | (_, "/metrics") => (
            405,
            CONTENT_TYPE_JSON,
            body_line(v2_error_json(
                "bad_request",
                &format!("method {} not allowed on {} (want GET)", req.method, req.path),
            )),
        ),
        _ => (
            404,
            CONTENT_TYPE_JSON,
            body_line(v2_error_json(
                "bad_request",
                &format!(
                    "no such endpoint {:?} (want POST /v2, GET /healthz, GET /metrics)",
                    req.path
                ),
            )),
        ),
    }
}

/// Run one body through the dispatcher on the engine's compute pool:
/// the same bounded-concurrency path TCP lines take, including typed
/// backpressure when the queue is full and panic containment.
fn dispatch_pooled(body: &str, shared: &Arc<HttpShared>) -> (u16, &'static str, String) {
    let service = Arc::clone(&shared.service);
    let body = body.to_string();
    let (tx, rx) = mpsc::channel::<(Option<&'static str>, String)>();
    let submitted = shared.service.engine().pool().try_execute(move || {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            service.dispatch_http(&body)
        }));
        let _ = tx.send(match result {
            Ok(out) => (out.error, out.reply),
            Err(_) => (Some("internal"), internal_error_json()),
        });
    });
    if submitted.is_err() {
        return (503, CONTENT_TYPE_JSON, body_line(overloaded_json()));
    }
    match rx.recv() {
        Ok((error, reply)) => (status_for(error), CONTENT_TYPE_JSON, body_line(reply)),
        // Pool torn down mid-request: the job (and its sender) was lost.
        Err(_) => (500, CONTENT_TYPE_JSON, body_line(internal_error_json())),
    }
}

/// Dispatcher error code → HTTP status. Transports never look inside
/// the reply; this code is the whole contract.
fn status_for(error: Option<&'static str>) -> u16 {
    match error {
        None => 200,
        Some("internal") => 500,
        Some("overloaded") => 503,
        Some(_) => 400,
    }
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Error",
    }
}

/// JSON reply lines get a trailing newline, mirroring the TCP wire
/// (and keeping `curl` output tidy).
fn body_line(mut reply: String) -> String {
    reply.push('\n');
    reply
}

fn write_response(
    write: &mut TcpStream,
    status: u16,
    content_type: &str,
    body: &str,
    close: bool,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        reason(status),
        body.len(),
        if close { "close" } else { "keep-alive" },
    );
    write.write_all(head.as_bytes())?;
    write.write_all(body.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::{
        v2_predict_model_request, v2_stats_request, PredictionResponse,
    };
    use crate::engine::metrics::OpKind;
    use crate::predict::HybridPredictor;
    use crate::util::json::{self, Json};

    fn wave_service() -> Arc<PredictionService> {
        Arc::new(PredictionService::with_predictor(HybridPredictor::wave_only()))
    }

    /// A minimal keep-alive HTTP client over one socket.
    struct TestClient {
        write: TcpStream,
        reader: BufReader<TcpStream>,
    }

    impl TestClient {
        fn connect(addr: SocketAddr) -> Self {
            let stream = TcpStream::connect(addr).unwrap();
            TestClient {
                write: stream.try_clone().unwrap(),
                reader: BufReader::new(stream),
            }
        }

        fn request(&mut self, method: &str, path: &str, body: Option<&str>) -> (u16, String) {
            let mut req = format!("{method} {path} HTTP/1.1\r\nHost: test\r\n");
            if let Some(b) = body {
                req.push_str(&format!("Content-Length: {}\r\n", b.len()));
            }
            req.push_str("\r\n");
            if let Some(b) = body {
                req.push_str(b);
            }
            self.write.write_all(req.as_bytes()).unwrap();
            self.read_response()
        }

        fn read_response(&mut self) -> (u16, String) {
            let mut status_line = String::new();
            self.reader.read_line(&mut status_line).unwrap();
            let status: u16 = status_line
                .split_whitespace()
                .nth(1)
                .unwrap_or_else(|| panic!("bad status line {status_line:?}"))
                .parse()
                .unwrap();
            let mut len = 0usize;
            loop {
                let mut header = String::new();
                self.reader.read_line(&mut header).unwrap();
                if header.trim_end().is_empty() {
                    break;
                }
                let lower = header.to_ascii_lowercase();
                if let Some(v) = lower.strip_prefix("content-length:") {
                    len = v.trim().parse().unwrap();
                }
            }
            let mut body = vec![0u8; len];
            self.reader.read_exact(&mut body).unwrap();
            (status, String::from_utf8(body).unwrap())
        }
    }

    #[test]
    fn healthz_and_dispatch_over_one_keepalive_connection() {
        let handle = start("127.0.0.1:0", wave_service(), ServeOptions::default()).unwrap();
        let mut client = TestClient::connect(handle.local_addr());

        let (status, body) = client.request("GET", "/healthz", None);
        assert_eq!(status, 200);
        assert_eq!(body, "ok\n");

        // v1 body → 200 with the v1 reply shape, on the same socket.
        let (status, body) = client.request(
            "POST",
            "/v2",
            Some("{\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\"}"),
        );
        assert_eq!(status, 200);
        let resp = PredictionResponse::from_json(body.trim()).unwrap();
        assert_eq!(resp.dest, "V100");

        // v2 body → 200 with the envelope, byte-equal to the TCP reply.
        let line = v2_predict_model_request("mlp", 8, "t4", "v100", None);
        let (status, body) = client.request("POST", "/v2", Some(&line));
        assert_eq!(status, 200);
        assert_eq!(body.trim_end(), handle.service().handle_line(&line));
        handle.shutdown();
    }

    #[test]
    fn error_bodies_carry_matching_statuses() {
        let handle = start("127.0.0.1:0", wave_service(), ServeOptions::default()).unwrap();
        let addr = handle.local_addr();
        let check_code = |body: &str, code: &str| {
            let v = json::parse(body.trim()).unwrap();
            assert_eq!(
                v.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
                Some(code),
                "{body}"
            );
        };

        // Malformed JSON → 400 in the structured v2 shape.
        let (status, body) = TestClient::connect(addr).request("POST", "/v2", Some("not json"));
        assert_eq!(status, 400);
        check_code(&body, "bad_request");

        // Unknown device through a valid envelope → 400 with its code.
        let (status, body) = TestClient::connect(addr).request(
            "POST",
            "/v2",
            Some("{\"v\":2,\"op\":\"predict\",\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"a100\"}"),
        );
        assert_eq!(status, 400);
        check_code(&body, "unknown_device");

        // Routing errors.
        let (status, body) = TestClient::connect(addr).request("GET", "/nope", None);
        assert_eq!(status, 404);
        check_code(&body, "bad_request");
        let (status, _) = TestClient::connect(addr).request("GET", "/v2", None);
        assert_eq!(status, 405);
        let (status, _) = TestClient::connect(addr).request("POST", "/metrics", None);
        assert_eq!(status, 405);
        handle.shutdown();
    }

    #[test]
    fn metrics_expose_and_count_http_requests() {
        let handle = start("127.0.0.1:0", wave_service(), ServeOptions::default()).unwrap();
        let addr = handle.local_addr();
        let mut client = TestClient::connect(addr);

        let (status, before) = client.request("GET", "/metrics", None);
        assert_eq!(status, 200);
        assert!(before.contains("# TYPE habitat_requests_total counter"));
        assert!(before.contains("habitat_request_latency_ms_bucket"));

        client.request(
            "POST",
            "/v2",
            Some("{\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\"}"),
        );
        client.request("POST", "/v2", Some(&v2_stats_request()));

        let (_, after) = client.request("GET", "/metrics", None);
        assert!(after.contains("habitat_requests_total{op=\"predict\"} 1"));
        assert!(after.contains("habitat_requests_total{op=\"stats\"} 1"));
        let m = handle.service().engine().metrics();
        assert_eq!(m.snapshot(OpKind::Predict).requests, 1);
        assert_eq!(m.snapshot(OpKind::Stats).requests, 1);
        handle.shutdown();
    }

    #[test]
    fn connection_slots_reject_with_503() {
        let handle = start(
            "127.0.0.1:0",
            wave_service(),
            ServeOptions {
                max_conns: 1,
                ..ServeOptions::default()
            },
        )
        .unwrap();
        let addr = handle.local_addr();

        // Fill the slot and prove it live with a roundtrip.
        let mut first = TestClient::connect(addr);
        let (status, _) = first.request("GET", "/healthz", None);
        assert_eq!(status, 200);

        // The next connection gets a typed 503 and a close.
        let (status, body) = TestClient::connect(addr).read_response();
        assert_eq!(status, 503);
        let v = json::parse(body.trim()).unwrap();
        assert_eq!(
            v.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
            Some("overloaded")
        );
        handle.shutdown();
    }

    #[test]
    fn oversized_and_malformed_requests_are_rejected() {
        let handle = start("127.0.0.1:0", wave_service(), ServeOptions::default()).unwrap();
        let addr = handle.local_addr();

        // A Content-Length past the cap is refused before buffering.
        let mut client = TestClient::connect(addr);
        client
            .write
            .write_all(
                format!(
                    "POST /v2 HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n",
                    MAX_BODY_BYTES + 1
                )
                .as_bytes(),
            )
            .unwrap();
        let (status, _) = client.read_response();
        assert_eq!(status, 413);

        // Garbage instead of a request line → 400.
        let mut client = TestClient::connect(addr);
        client.write.write_all(b"how are you\r\n\r\n").unwrap();
        let (status, _) = client.read_response();
        assert_eq!(status, 400);
        handle.shutdown();
    }
}
