//! Wire protocol: typed request/response codecs for every op, v1 and
//! v2, with structured errors. Pure data — this module never touches a
//! socket and holds no engine state; the transport-agnostic
//! [`Dispatcher`](super::Dispatcher) consumes these types and the
//! transports ([`super::tcp`], [`super::http`]) move the resulting
//! bytes.
//!
//! Two protocol generations share the wire (see `docs/SERVICE.md`):
//!
//! **v1** (bare objects, no `"v"` field — kept bit-identical):
//!
//! * **predict** — `{"model", "batch", "origin", "dest", "precision"?}`
//!   → one destination's decision metrics;
//! * **rank** — `{"rank": true, ...}` → destination GPUs ordered by
//!   cost-normalized throughput;
//! * **stats** — `{"stats": true}` → the engine's counter snapshot.
//!
//! **v2** (the open-world envelope `{"v":2,"op":...}`): everything v1
//! does, plus `submit_trace`, `register_device`, `rank_many` (one call,
//! many traces — served by a single multi-trace sweep), the cluster
//! suite (`predict_cluster`, `rank_cluster`, `export_workload`), and
//! structured `{"v":2,"error":{"code","message"}}` errors.

use crate::device::{Device, NewDevice};
use crate::lowering::Precision;
use crate::tracker::Trace;
use crate::util::json::{self, Json};
use crate::Result;

/// One prediction request (wire format and internal API).
#[derive(Debug, Clone)]
pub struct PredictionRequest {
    /// Model name (see [`crate::models::MODEL_NAMES`]).
    pub model: String,
    pub batch: usize,
    /// Origin GPU short name (e.g. `"t4"`).
    pub origin: String,
    /// Destination GPU short name.
    pub dest: String,
    /// `"fp32"` (default) or `"amp"` — AMP composes Habitat with the
    /// Daydream transformation (§6.1.2).
    pub precision: Option<String>,
}

impl PredictionRequest {
    /// Parse from a JSON object line.
    pub fn from_json(line: &str) -> Result<Self> {
        Self::from_value(&json::parse(line)?)
    }

    pub(crate) fn from_value(v: &Json) -> Result<Self> {
        Ok(PredictionRequest {
            model: v.req_str("model")?.to_string(),
            batch: v.req_usize("batch")?,
            origin: v.req_str("origin")?.to_string(),
            dest: v.req_str("dest")?.to_string(),
            precision: v.get("precision").and_then(Json::as_str).map(str::to_string),
        })
    }

    pub fn to_json(&self) -> String {
        let mut pairs = vec![
            ("model", Json::Str(self.model.clone())),
            ("batch", Json::Num(self.batch as f64)),
            ("origin", Json::Str(self.origin.clone())),
            ("dest", Json::Str(self.dest.clone())),
        ];
        if let Some(p) = &self.precision {
            pairs.push(("precision", Json::Str(p.clone())));
        }
        Json::obj(pairs).dump()
    }
}

/// A rank request: predict one origin trace onto many destinations and
/// order them by cost-normalized throughput.
#[derive(Debug, Clone)]
pub struct RankRequest {
    pub model: String,
    pub batch: usize,
    pub origin: String,
    /// `"fp32"` (default) or `"amp"`.
    pub precision: Option<String>,
    /// Candidate destinations; `None` means every device in the
    /// registry — built-ins plus runtime registrations.
    pub dests: Option<Vec<String>>,
}

impl RankRequest {
    pub fn from_json(line: &str) -> Result<Self> {
        Self::from_value(&json::parse(line)?)
    }

    pub(crate) fn from_value(v: &Json) -> Result<Self> {
        let dests = match v.get("dests") {
            None | Some(Json::Null) => None,
            Some(arr) => {
                let items = arr
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("dests must be an array of device names"))?;
                let mut names = Vec::with_capacity(items.len());
                for it in items {
                    names.push(
                        it.as_str()
                            .ok_or_else(|| anyhow::anyhow!("dests entries must be strings"))?
                            .to_string(),
                    );
                }
                Some(names)
            }
        };
        Ok(RankRequest {
            model: v.req_str("model")?.to_string(),
            batch: v.req_usize("batch")?,
            origin: v.req_str("origin")?.to_string(),
            precision: v.get("precision").and_then(Json::as_str).map(str::to_string),
            dests,
        })
    }

    pub fn to_json(&self) -> String {
        let mut pairs = vec![
            ("rank", Json::Bool(true)),
            ("model", Json::Str(self.model.clone())),
            ("batch", Json::Num(self.batch as f64)),
            ("origin", Json::Str(self.origin.clone())),
        ];
        if let Some(p) = &self.precision {
            pairs.push(("precision", Json::Str(p.clone())));
        }
        if let Some(d) = &self.dests {
            pairs.push((
                "dests",
                Json::Arr(d.iter().map(|s| Json::Str(s.clone())).collect()),
            ));
        }
        Json::obj(pairs).dump()
    }
}

/// Any request shape, as dispatched off the wire: a line with
/// `"rank": true` is a [`RankRequest`], a line with `"stats": true` a
/// stats request, anything else a [`PredictionRequest`].
#[derive(Debug, Clone)]
pub enum Request {
    Predict(PredictionRequest),
    Rank(RankRequest),
    Stats,
}

impl Request {
    pub fn from_json(line: &str) -> Result<Request> {
        Self::from_value(&json::parse(line)?)
    }

    /// Dispatch an already-parsed v1 request value (the dispatcher
    /// parses each line once, for the version sniff, and reuses the
    /// value here).
    pub fn from_value(v: &Json) -> Result<Request> {
        if matches!(v.get("rank"), Some(Json::Bool(true))) {
            Ok(Request::Rank(RankRequest::from_value(v)?))
        } else if matches!(v.get("stats"), Some(Json::Bool(true))) {
            Ok(Request::Stats)
        } else {
            Ok(Request::Predict(PredictionRequest::from_value(v)?))
        }
    }
}

/// The wire form of a stats request.
pub fn stats_request_json() -> String {
    Json::obj(vec![("stats", Json::Bool(true))]).dump()
}

/// The answer to a stats request: the engine's counter snapshot
/// ([`crate::engine::EngineStats`]) in wire form.
#[derive(Debug, Clone, Copy)]
pub struct StatsResponse {
    /// Cache hits (requests that skipped the tracking pipeline).
    pub trace_hits: u64,
    /// Cache misses (tracking-pipeline executions).
    pub trace_misses: u64,
    /// Trace+plan entries currently resident.
    pub trace_entries: usize,
    /// Compiled-plan builds (cache misses + one-off analyses); the
    /// plan rides the same cache entry as its trace, so cached-plan
    /// reuses equal `trace_hits`.
    pub plan_builds: u64,
    /// Process-wide wave-table counters.
    pub wave_hits: u64,
    pub wave_misses: u64,
    /// Persistent fan-out worker-pool width.
    pub workers: usize,
}

impl From<crate::engine::EngineStats> for StatsResponse {
    fn from(s: crate::engine::EngineStats) -> Self {
        StatsResponse {
            trace_hits: s.trace_hits,
            trace_misses: s.trace_misses,
            trace_entries: s.trace_entries,
            plan_builds: s.plan_builds,
            wave_hits: s.wave_hits,
            wave_misses: s.wave_misses,
            workers: s.workers,
        }
    }
}

impl StatsResponse {
    pub fn to_json(&self) -> String {
        self.to_value().dump()
    }

    /// The v1 stats payload. (The v2 `stats` op extends this with the
    /// open-world counters — `trace_uploads`, `uploaded_entries`,
    /// `devices` — the store/compile counters — `store_hits`,
    /// `store_misses`, `warm_restores`, `parallel_build_chunks` — and
    /// the dispatcher's wire counters — `requests`, `request_errors`;
    /// v1 keeps its original seven fields bit-for-bit.)
    pub fn to_value(&self) -> Json {
        Json::obj(vec![
            ("trace_hits", Json::Num(self.trace_hits as f64)),
            ("trace_misses", Json::Num(self.trace_misses as f64)),
            ("trace_entries", Json::Num(self.trace_entries as f64)),
            ("plan_builds", Json::Num(self.plan_builds as f64)),
            ("wave_hits", Json::Num(self.wave_hits as f64)),
            ("wave_misses", Json::Num(self.wave_misses as f64)),
            ("workers", Json::Num(self.workers as f64)),
        ])
    }

    pub fn from_json(line: &str) -> Result<Self> {
        let v = json::parse(line)?;
        if let Some(err) = v.get("error").and_then(Json::as_str) {
            anyhow::bail!("server error: {err}");
        }
        let req_u64 = |key: &str| -> Result<u64> {
            Ok(v.req_usize(key)? as u64)
        };
        Ok(StatsResponse {
            trace_hits: req_u64("trace_hits")?,
            trace_misses: req_u64("trace_misses")?,
            trace_entries: v.req_usize("trace_entries")?,
            plan_builds: req_u64("plan_builds")?,
            wave_hits: req_u64("wave_hits")?,
            wave_misses: req_u64("wave_misses")?,
            workers: v.req_usize("workers")?,
        })
    }
}

/// The service's answer: decision-ready metrics.
#[derive(Debug, Clone)]
pub struct PredictionResponse {
    pub model: String,
    pub batch: usize,
    pub origin: String,
    pub dest: String,
    /// Measured iteration time on the origin, ms.
    pub origin_iter_ms: f64,
    /// Predicted iteration time on the destination, ms.
    pub iter_ms: f64,
    /// Predicted training throughput, samples/s.
    pub throughput: f64,
    /// Throughput per rental dollar, if the destination is rentable.
    pub cost_normalized_throughput: Option<f64>,
    /// Fraction of predicted time that came from the MLP predictors.
    pub mlp_time_fraction: f64,
    /// Kernel-varying ops that fell back to wave scaling.
    pub mlp_fallbacks: usize,
}

impl PredictionResponse {
    pub fn to_json(&self) -> String {
        self.to_value().dump()
    }

    pub fn to_value(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("batch", Json::Num(self.batch as f64)),
            ("origin", Json::Str(self.origin.clone())),
            ("dest", Json::Str(self.dest.clone())),
            ("origin_iter_ms", Json::Num(self.origin_iter_ms)),
            ("iter_ms", Json::Num(self.iter_ms)),
            ("throughput", Json::Num(self.throughput)),
            (
                "cost_normalized_throughput",
                self.cost_normalized_throughput.map_or(Json::Null, Json::Num),
            ),
            ("mlp_time_fraction", Json::Num(self.mlp_time_fraction)),
            ("mlp_fallbacks", Json::Num(self.mlp_fallbacks as f64)),
        ])
    }

    /// Parse a response line (used by clients/examples/tests).
    pub fn from_json(line: &str) -> Result<Self> {
        let v = json::parse(line)?;
        if let Some(err) = v.get("error").and_then(Json::as_str) {
            anyhow::bail!("server error: {err}");
        }
        Ok(PredictionResponse {
            model: v.req_str("model")?.to_string(),
            batch: v.req_usize("batch")?,
            origin: v.req_str("origin")?.to_string(),
            dest: v.req_str("dest")?.to_string(),
            origin_iter_ms: v
                .get("origin_iter_ms")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("missing origin_iter_ms"))?,
            iter_ms: v
                .get("iter_ms")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("missing iter_ms"))?,
            throughput: v
                .get("throughput")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("missing throughput"))?,
            cost_normalized_throughput: v.get("cost_normalized_throughput").and_then(Json::as_f64),
            mlp_time_fraction: v.get("mlp_time_fraction").and_then(Json::as_f64).unwrap_or(0.0),
            mlp_fallbacks: v.get("mlp_fallbacks").and_then(Json::as_usize).unwrap_or(0),
        })
    }
}

/// One destination's row in a [`RankResponse`], best decision first.
#[derive(Debug, Clone)]
pub struct RankedDest {
    pub dest: String,
    pub iter_ms: f64,
    pub throughput: f64,
    pub cost_normalized_throughput: Option<f64>,
    pub mlp_time_fraction: f64,
    pub mlp_fallbacks: usize,
}

impl RankedDest {
    fn to_value(&self) -> Json {
        Json::obj(vec![
            ("dest", Json::Str(self.dest.clone())),
            ("iter_ms", Json::Num(self.iter_ms)),
            ("throughput", Json::Num(self.throughput)),
            (
                "cost_normalized_throughput",
                self.cost_normalized_throughput.map_or(Json::Null, Json::Num),
            ),
            ("mlp_time_fraction", Json::Num(self.mlp_time_fraction)),
            ("mlp_fallbacks", Json::Num(self.mlp_fallbacks as f64)),
        ])
    }

    fn from_value(v: &Json) -> Result<Self> {
        Ok(RankedDest {
            dest: v.req_str("dest")?.to_string(),
            iter_ms: v
                .get("iter_ms")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("missing iter_ms"))?,
            throughput: v
                .get("throughput")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("missing throughput"))?,
            cost_normalized_throughput: v.get("cost_normalized_throughput").and_then(Json::as_f64),
            mlp_time_fraction: v.get("mlp_time_fraction").and_then(Json::as_f64).unwrap_or(0.0),
            mlp_fallbacks: v.get("mlp_fallbacks").and_then(Json::as_usize).unwrap_or(0),
        })
    }
}

/// The answer to a [`RankRequest`].
#[derive(Debug, Clone)]
pub struct RankResponse {
    pub model: String,
    pub batch: usize,
    pub origin: String,
    /// Measured iteration time on the origin, ms.
    pub origin_iter_ms: f64,
    /// Every requested destination, sorted: rentable devices by
    /// descending cost-normalized throughput, then unpriced devices by
    /// descending raw throughput.
    pub ranking: Vec<RankedDest>,
}

impl RankResponse {
    pub fn to_json(&self) -> String {
        self.to_value().dump()
    }

    pub fn to_value(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("batch", Json::Num(self.batch as f64)),
            ("origin", Json::Str(self.origin.clone())),
            ("origin_iter_ms", Json::Num(self.origin_iter_ms)),
            (
                "ranking",
                Json::Arr(self.ranking.iter().map(RankedDest::to_value).collect()),
            ),
        ])
    }

    pub fn from_json(line: &str) -> Result<Self> {
        let v = json::parse(line)?;
        if let Some(err) = v.get("error").and_then(Json::as_str) {
            anyhow::bail!("server error: {err}");
        }
        Self::from_value(&v)
    }

    /// Parse one ranking object — a whole v1/v2 `rank` response line, or
    /// one entry of a v2 `rank_many` `results` array.
    pub fn from_value(v: &Json) -> Result<Self> {
        let ranking = v
            .get("ranking")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing ranking array"))?
            .iter()
            .map(RankedDest::from_value)
            .collect::<Result<Vec<_>>>()?;
        Ok(RankResponse {
            model: v.req_str("model")?.to_string(),
            batch: v.req_usize("batch")?,
            origin: v.req_str("origin")?.to_string(),
            origin_iter_ms: v
                .get("origin_iter_ms")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("missing origin_iter_ms"))?,
            ranking,
        })
    }
}

/// The answer to a v2 `rank_many` request: one [`RankResponse`]-shaped
/// object per requested `(model, batch, origin)` item, in request
/// order. Every item's sweep ran as one work-claimed job set on the
/// server ([`crate::engine::PredictionEngine::rank_many`]).
#[derive(Debug, Clone)]
pub struct RankManyResponse {
    pub results: Vec<RankResponse>,
}

impl RankManyResponse {
    pub fn to_value(&self) -> Json {
        Json::obj(vec![(
            "results",
            Json::Arr(self.results.iter().map(RankResponse::to_value).collect()),
        )])
    }

    pub fn from_json(line: &str) -> Result<Self> {
        let v = json::parse(line)?;
        v2_check_error(&v)?;
        Ok(RankManyResponse {
            results: v
                .get("results")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("missing results array"))?
                .iter()
                .map(RankResponse::from_value)
                .collect::<Result<Vec<_>>>()?,
        })
    }
}

/// Serialize a v1 error line: `{"error": "<message>"}`.
pub(crate) fn error_json(msg: &str) -> String {
    Json::obj(vec![("error", Json::Str(msg.to_string()))]).dump()
}

pub(crate) fn parse_device(name: &str, role: &str) -> Result<Device> {
    Device::parse(name).ok_or_else(|| anyhow::anyhow!("unknown {role} device {name:?}"))
}

pub(crate) fn parse_precision(p: Option<&str>) -> Result<Precision> {
    match p {
        None | Some("fp32") => Ok(Precision::Fp32),
        Some("amp") => Ok(Precision::Amp),
        Some(other) => anyhow::bail!("unknown precision {other:?} (want fp32|amp)"),
    }
}

// ------------------------------------------------------------------ v2 --
//
// The versioned envelope: `{"v":2,"op":"<op>",...}` requests, answered
// with `{"v":2,"op":"<op>",...payload}` on success and
// `{"v":2,"error":{"code","message"}}` on failure. v1 bare-object lines
// (no "v" field) keep flowing through the original code path
// bit-identically. See docs/SERVICE.md for the full schema.

/// Envelope protocol version served by
/// [`Dispatcher::handle_v2`](super::Dispatcher::handle_v2).
pub const PROTOCOL_V2: f64 = 2.0;

/// A structured v2 error: a stable machine-readable `code` plus a human
/// message. Codes: `bad_request`, `unsupported_version`,
/// `unsupported_op`, `unknown_device`, `unknown_model`, `unknown_trace`,
/// `invalid_argument`, `conflict`.
pub(crate) struct V2Error {
    pub(crate) code: &'static str,
    pub(crate) message: String,
}

impl V2Error {
    pub(crate) fn new(code: &'static str, message: impl Into<String>) -> V2Error {
        V2Error { code, message: message.into() }
    }
}

pub(crate) type V2Result = std::result::Result<Json, V2Error>;

/// Serialize a v2 error line.
pub fn v2_error_json(code: &str, message: &str) -> String {
    Json::obj(vec![
        ("v", Json::Num(PROTOCOL_V2)),
        (
            "error",
            Json::obj(vec![
                ("code", Json::Str(code.to_string())),
                ("message", Json::Str(message.to_string())),
            ]),
        ),
    ])
    .dump()
}

/// Wrap a payload object in the v2 success envelope.
pub(crate) fn v2_envelope(op: &str, payload: Json, extra: Vec<(&str, Json)>) -> Json {
    let mut m = match payload {
        Json::Obj(m) => m,
        _ => Default::default(),
    };
    m.insert("v".to_string(), Json::Num(PROTOCOL_V2));
    m.insert("op".to_string(), Json::Str(op.to_string()));
    for (k, v) in extra {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

/// Fail on a v2 (or v1) error line; `Ok(())` on a success payload.
/// Client-side counterpart of [`v2_error_json`].
pub fn v2_check_error(v: &Json) -> Result<()> {
    match v.get("error") {
        None => Ok(()),
        Some(Json::Str(msg)) => anyhow::bail!("server error: {msg}"),
        Some(err) => {
            let code = err.get("code").and_then(Json::as_str).unwrap_or("unknown");
            let msg = err.get("message").and_then(Json::as_str).unwrap_or("");
            anyhow::bail!("server error [{code}]: {msg}")
        }
    }
}

pub(crate) fn classify_engine_error(e: &anyhow::Error) -> &'static str {
    let msg = e.to_string();
    if msg.contains("unknown model") {
        "unknown_model"
    } else if msg.contains("unknown trace") {
        "unknown_trace"
    } else {
        "invalid_argument"
    }
}

// --- v2 request builders (used by the Client and the tests) -----------

fn precision_pair(precision: Option<&str>) -> Vec<(&'static str, Json)> {
    match precision {
        Some(p) => vec![("precision", Json::Str(p.to_string()))],
        None => Vec::new(),
    }
}

/// `{"v":2,"op":"predict"}` over a zoo model.
pub fn v2_predict_model_request(
    model: &str,
    batch: usize,
    origin: &str,
    dest: &str,
    precision: Option<&str>,
) -> String {
    let mut pairs = vec![
        ("v", Json::Num(PROTOCOL_V2)),
        ("op", Json::Str("predict".into())),
        ("model", Json::Str(model.to_string())),
        ("batch", Json::Num(batch as f64)),
        ("origin", Json::Str(origin.to_string())),
        ("dest", Json::Str(dest.to_string())),
    ];
    pairs.extend(precision_pair(precision));
    Json::obj(pairs).dump()
}

/// `{"v":2,"op":"predict"}` over a previously submitted trace.
pub fn v2_predict_trace_request(trace_id: &str, dest: &str, precision: Option<&str>) -> String {
    let mut pairs = vec![
        ("v", Json::Num(PROTOCOL_V2)),
        ("op", Json::Str("predict".into())),
        ("trace_id", Json::Str(trace_id.to_string())),
        ("dest", Json::Str(dest.to_string())),
    ];
    pairs.extend(precision_pair(precision));
    Json::obj(pairs).dump()
}

/// `{"v":2,"op":"rank"}` over a previously submitted trace.
pub fn v2_rank_trace_request(
    trace_id: &str,
    dests: Option<&[String]>,
    precision: Option<&str>,
) -> String {
    let mut pairs = vec![
        ("v", Json::Num(PROTOCOL_V2)),
        ("op", Json::Str("rank".into())),
        ("trace_id", Json::Str(trace_id.to_string())),
    ];
    if let Some(d) = dests {
        pairs.push(("dests", Json::Arr(d.iter().map(|s| Json::Str(s.clone())).collect())));
    }
    pairs.extend(precision_pair(precision));
    Json::obj(pairs).dump()
}

/// `{"v":2,"op":"submit_trace"}` with the trace embedded.
pub fn v2_submit_trace_request(trace: &Trace) -> String {
    Json::obj(vec![
        ("v", Json::Num(PROTOCOL_V2)),
        ("op", Json::Str("submit_trace".into())),
        ("trace", trace.to_value()),
    ])
    .dump()
}

/// `{"v":2,"op":"register_device"}` from a device description.
pub fn v2_register_device_request(d: &NewDevice) -> String {
    let mut pairs = vec![
        ("v", Json::Num(PROTOCOL_V2)),
        ("op", Json::Str("register_device".into())),
        ("name", Json::Str(d.name.clone())),
        ("sms", Json::Num(d.sms as f64)),
        ("clock_mhz", Json::Num(d.clock_mhz)),
        ("mem_bw_gbps", Json::Num(d.mem_bw_gbps)),
        ("fp32_tflops", Json::Num(d.fp32_tflops)),
        ("tensor_cores", Json::Bool(d.tensor_cores)),
    ];
    if let Some(p) = d.usd_per_hr {
        pairs.push(("usd_per_hr", Json::Num(p)));
    }
    if let Some(a) = d.arch {
        pairs.push(("arch", Json::Str(a.to_string().to_ascii_lowercase())));
    }
    if let Some(x) = d.achieved_bw_gbps {
        pairs.push(("achieved_bw_gbps", Json::Num(x)));
    }
    if let Some(x) = d.mem_gib {
        pairs.push(("mem_gib", Json::Num(x)));
    }
    if let Some(x) = d.fp16_tflops {
        pairs.push(("fp16_tflops", Json::Num(x)));
    }
    if let Some(x) = d.cuda_cores {
        pairs.push(("cuda_cores", Json::Num(x as f64)));
    }
    if let Some(x) = d.l2_kib {
        pairs.push(("l2_kib", Json::Num(x as f64)));
    }
    Json::obj(pairs).dump()
}

/// `{"v":2,"op":"rank_many"}`: rank several `(model, batch, origin)`
/// traces over one shared destination set in a single call. `None`
/// dests mean every registered device.
pub fn v2_rank_many_request(
    items: &[(&str, usize, &str)],
    dests: Option<&[String]>,
    precision: Option<&str>,
) -> String {
    let mut pairs = vec![
        ("v", Json::Num(PROTOCOL_V2)),
        ("op", Json::Str("rank_many".into())),
        (
            "items",
            Json::Arr(
                items
                    .iter()
                    .map(|(model, batch, origin)| {
                        Json::obj(vec![
                            ("model", Json::Str(model.to_string())),
                            ("batch", Json::Num(*batch as f64)),
                            ("origin", Json::Str(origin.to_string())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ];
    if let Some(d) = dests {
        pairs.push(("dests", Json::Arr(d.iter().map(|s| Json::Str(s.clone())).collect())));
    }
    pairs.extend(precision_pair(precision));
    Json::obj(pairs).dump()
}

/// `{"v":2,"op":"stats"}`.
pub fn v2_stats_request() -> String {
    Json::obj(vec![("v", Json::Num(PROTOCOL_V2)), ("op", Json::Str("stats".into()))]).dump()
}

// --- cluster ops (v2 only) --------------------------------------------

/// Default world-size sweep for the cluster ops when the request omits
/// `worlds`: powers of two through 256 ranks.
pub const DEFAULT_CLUSTER_WORLDS: [usize; 9] = [1, 2, 4, 8, 16, 32, 64, 128, 256];

/// Largest accepted world size in a cluster sweep.
pub(crate) const MAX_CLUSTER_WORLD: usize = 65_536;

/// Cap on `dests × topologies × worlds` cells in one cluster request.
pub(crate) const MAX_CLUSTER_SWEEP: usize = 16_384;

/// One (topology, world) cell of a [`ClusterResponse`].
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub topology: String,
    pub world: usize,
    /// Predicted per-iteration wall time, ms (compute + exposed comm).
    pub iter_ms: f64,
    /// Raw bucketed-allreduce time before overlap, ms.
    pub comm_ms: f64,
    /// Communication left exposed after overlap with backward, ms.
    pub exposed_ms: f64,
    /// Global throughput, samples/s across all ranks.
    pub throughput: f64,
    /// Scaling efficiency vs perfect linear scaling, in (0, 1].
    pub efficiency: f64,
    /// Global samples/s per total fleet $/hr; `None` when unpriced.
    pub cost_normalized_throughput: Option<f64>,
}

impl ClusterConfig {
    fn to_value(&self) -> Json {
        Json::obj(vec![
            ("topology", Json::Str(self.topology.clone())),
            ("world", Json::Num(self.world as f64)),
            ("iter_ms", Json::Num(self.iter_ms)),
            ("comm_ms", Json::Num(self.comm_ms)),
            ("exposed_ms", Json::Num(self.exposed_ms)),
            ("throughput", Json::Num(self.throughput)),
            ("efficiency", Json::Num(self.efficiency)),
            (
                "cost_normalized_throughput",
                self.cost_normalized_throughput.map_or(Json::Null, Json::Num),
            ),
        ])
    }

    fn from_value(v: &Json) -> Result<Self> {
        let num = |k: &str| -> Result<f64> {
            v.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("missing/invalid number field {k:?}"))
        };
        Ok(ClusterConfig {
            topology: v.req_str("topology")?.to_string(),
            world: v.req_usize("world")?,
            iter_ms: num("iter_ms")?,
            comm_ms: num("comm_ms")?,
            exposed_ms: num("exposed_ms")?,
            throughput: num("throughput")?,
            efficiency: num("efficiency")?,
            cost_normalized_throughput: v.get("cost_normalized_throughput").and_then(Json::as_f64),
        })
    }
}

/// The answer to a `predict_cluster` request: one destination swept
/// across a topology × world grid (topology-major, request order).
#[derive(Debug, Clone)]
pub struct ClusterResponse {
    pub model: String,
    pub batch: usize,
    pub origin: String,
    pub dest: String,
    /// Per-replica single-GPU compute time shared by every cell, ms.
    pub compute_ms: f64,
    pub configs: Vec<ClusterConfig>,
}

impl ClusterResponse {
    pub fn to_value(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("batch", Json::Num(self.batch as f64)),
            ("origin", Json::Str(self.origin.clone())),
            ("dest", Json::Str(self.dest.clone())),
            ("compute_ms", Json::Num(self.compute_ms)),
            (
                "configs",
                Json::Arr(self.configs.iter().map(ClusterConfig::to_value).collect()),
            ),
        ])
    }

    pub fn from_json(line: &str) -> Result<Self> {
        let v = json::parse(line)?;
        v2_check_error(&v)?;
        Ok(ClusterResponse {
            model: v.req_str("model")?.to_string(),
            batch: v.req_usize("batch")?,
            origin: v.req_str("origin")?.to_string(),
            dest: v.req_str("dest")?.to_string(),
            compute_ms: v
                .get("compute_ms")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("missing compute_ms"))?,
            configs: v
                .get("configs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("missing configs array"))?
                .iter()
                .map(ClusterConfig::from_value)
                .collect::<Result<Vec<_>>>()?,
        })
    }
}

/// One entry of a [`ClusterRankResponse`], best decision first.
#[derive(Debug, Clone)]
pub struct ClusterRankedConfig {
    pub dest: String,
    pub topology: String,
    pub world: usize,
    pub iter_ms: f64,
    pub throughput: f64,
    pub efficiency: f64,
    pub cost_normalized_throughput: Option<f64>,
}

impl ClusterRankedConfig {
    fn to_value(&self) -> Json {
        Json::obj(vec![
            ("dest", Json::Str(self.dest.clone())),
            ("topology", Json::Str(self.topology.clone())),
            ("world", Json::Num(self.world as f64)),
            ("iter_ms", Json::Num(self.iter_ms)),
            ("throughput", Json::Num(self.throughput)),
            ("efficiency", Json::Num(self.efficiency)),
            (
                "cost_normalized_throughput",
                self.cost_normalized_throughput.map_or(Json::Null, Json::Num),
            ),
        ])
    }

    fn from_value(v: &Json) -> Result<Self> {
        let num = |k: &str| -> Result<f64> {
            v.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("missing/invalid number field {k:?}"))
        };
        Ok(ClusterRankedConfig {
            dest: v.req_str("dest")?.to_string(),
            topology: v.req_str("topology")?.to_string(),
            world: v.req_usize("world")?,
            iter_ms: num("iter_ms")?,
            throughput: num("throughput")?,
            efficiency: num("efficiency")?,
            cost_normalized_throughput: v.get("cost_normalized_throughput").and_then(Json::as_f64),
        })
    }
}

/// The answer to a `rank_cluster` request: every (destination, topology,
/// world) configuration, ordered like `rank` — priced fleets by
/// descending cost-normalized throughput, then unpriced by raw global
/// throughput.
#[derive(Debug, Clone)]
pub struct ClusterRankResponse {
    pub model: String,
    pub batch: usize,
    pub origin: String,
    pub ranking: Vec<ClusterRankedConfig>,
}

impl ClusterRankResponse {
    pub fn to_value(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("batch", Json::Num(self.batch as f64)),
            ("origin", Json::Str(self.origin.clone())),
            (
                "ranking",
                Json::Arr(self.ranking.iter().map(ClusterRankedConfig::to_value).collect()),
            ),
        ])
    }

    pub fn from_json(line: &str) -> Result<Self> {
        let v = json::parse(line)?;
        v2_check_error(&v)?;
        Ok(ClusterRankResponse {
            model: v.req_str("model")?.to_string(),
            batch: v.req_usize("batch")?,
            origin: v.req_str("origin")?.to_string(),
            ranking: v
                .get("ranking")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("missing ranking array"))?
                .iter()
                .map(ClusterRankedConfig::from_value)
                .collect::<Result<Vec<_>>>()?,
        })
    }
}

fn cluster_grid_pairs(
    topologies: Option<&[String]>,
    worlds: Option<&[usize]>,
) -> Vec<(&'static str, Json)> {
    let mut pairs = Vec::new();
    if let Some(t) = topologies {
        pairs.push((
            "topologies",
            Json::Arr(t.iter().map(|s| Json::Str(s.clone())).collect()),
        ));
    }
    if let Some(w) = worlds {
        pairs.push((
            "worlds",
            Json::Arr(w.iter().map(|&x| Json::Num(x as f64)).collect()),
        ));
    }
    pairs
}

/// `{"v":2,"op":"predict_cluster"}` over a zoo model. `None` topologies
/// and worlds mean the server defaults (every registered topology,
/// [`DEFAULT_CLUSTER_WORLDS`]).
pub fn v2_predict_cluster_request(
    model: &str,
    batch: usize,
    origin: &str,
    dest: &str,
    topologies: Option<&[String]>,
    worlds: Option<&[usize]>,
    precision: Option<&str>,
) -> String {
    let mut pairs = vec![
        ("v", Json::Num(PROTOCOL_V2)),
        ("op", Json::Str("predict_cluster".into())),
        ("model", Json::Str(model.to_string())),
        ("batch", Json::Num(batch as f64)),
        ("origin", Json::Str(origin.to_string())),
        ("dest", Json::Str(dest.to_string())),
    ];
    pairs.extend(cluster_grid_pairs(topologies, worlds));
    pairs.extend(precision_pair(precision));
    Json::obj(pairs).dump()
}

/// `{"v":2,"op":"rank_cluster"}` over a zoo model. `None` dests mean
/// every registered device.
#[allow(clippy::too_many_arguments)]
pub fn v2_rank_cluster_request(
    model: &str,
    batch: usize,
    origin: &str,
    dests: Option<&[String]>,
    topologies: Option<&[String]>,
    worlds: Option<&[usize]>,
    precision: Option<&str>,
) -> String {
    let mut pairs = vec![
        ("v", Json::Num(PROTOCOL_V2)),
        ("op", Json::Str("rank_cluster".into())),
        ("model", Json::Str(model.to_string())),
        ("batch", Json::Num(batch as f64)),
        ("origin", Json::Str(origin.to_string())),
    ];
    if let Some(d) = dests {
        pairs.push(("dests", Json::Arr(d.iter().map(|s| Json::Str(s.clone())).collect())));
    }
    pairs.extend(cluster_grid_pairs(topologies, worlds));
    pairs.extend(precision_pair(precision));
    Json::obj(pairs).dump()
}

/// `{"v":2,"op":"export_workload"}`: one (dest, topology, world)
/// configuration's predicted compute + collective schedule.
pub fn v2_export_workload_request(
    model: &str,
    batch: usize,
    origin: &str,
    dest: &str,
    topology: &str,
    world: usize,
    precision: Option<&str>,
) -> String {
    let mut pairs = vec![
        ("v", Json::Num(PROTOCOL_V2)),
        ("op", Json::Str("export_workload".into())),
        ("model", Json::Str(model.to_string())),
        ("batch", Json::Num(batch as f64)),
        ("origin", Json::Str(origin.to_string())),
        ("dest", Json::Str(dest.to_string())),
        ("topology", Json::Str(topology.to_string())),
        ("world", Json::Num(world as f64)),
    ];
    pairs.extend(precision_pair(precision));
    Json::obj(pairs).dump()
}

/// The `register_device` acknowledgement (client-side view).
#[derive(Debug, Clone)]
pub struct RegisteredDevice {
    /// Canonical device name (as stored in the registry).
    pub device: String,
    /// Interned registry index on the server.
    pub id: usize,
    /// Registry size after the registration.
    pub devices: usize,
}

impl RegisteredDevice {
    pub fn from_json(line: &str) -> Result<RegisteredDevice> {
        let v = json::parse(line)?;
        v2_check_error(&v)?;
        Ok(RegisteredDevice {
            device: v.req_str("device")?.to_string(),
            id: v.req_usize("id")?,
            devices: v.req_usize("devices")?,
        })
    }
}

pub(crate) fn new_device_from_value(v: &Json) -> std::result::Result<NewDevice, V2Error> {
    let req_num = |k: &str| -> std::result::Result<f64, V2Error> {
        v.get(k)
            .and_then(Json::as_f64)
            .ok_or_else(|| V2Error::new("bad_request", format!("missing/invalid number field {k:?}")))
    };
    let opt_num = |k: &str| v.get(k).and_then(Json::as_f64);
    let opt_u32 = |k: &str| v.get(k).and_then(Json::as_usize).map(|x| x as u32);
    let arch = match v.get("arch").and_then(Json::as_str) {
        None => None,
        Some(s) => Some(crate::device::Arch::parse(s).ok_or_else(|| {
            V2Error::new("invalid_argument", format!("unknown arch {s:?} (want pascal|volta|turing)"))
        })?),
    };
    Ok(NewDevice {
        name: v
            .req_str("name")
            .map_err(|e| V2Error::new("bad_request", e.to_string()))?
            .to_string(),
        sms: v
            .req_usize("sms")
            .map_err(|e| V2Error::new("bad_request", e.to_string()))? as u32,
        clock_mhz: req_num("clock_mhz")?,
        mem_bw_gbps: req_num("mem_bw_gbps")?,
        fp32_tflops: req_num("fp32_tflops")?,
        // Absent `tensor_cores` defaults from an explicit arch (so
        // `"arch":"turing"` alone is valid); bare requests default false.
        tensor_cores: match v.get("tensor_cores") {
            Some(Json::Bool(b)) => *b,
            _ => arch.map_or(false, |a| a.has_tensor_cores()),
        },
        usd_per_hr: opt_num("usd_per_hr"),
        arch,
        achieved_bw_gbps: opt_num("achieved_bw_gbps"),
        mem_gib: opt_num("mem_gib"),
        fp16_tflops: opt_num("fp16_tflops"),
        cuda_cores: opt_u32("cuda_cores"),
        l2_kib: opt_u32("l2_kib"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_request_json_roundtrip() {
        let r = RankRequest {
            model: "mlp".into(),
            batch: 16,
            origin: "t4".into(),
            precision: Some("amp".into()),
            dests: Some(vec!["v100".into(), "p100".into()]),
        };
        let line = r.to_json();
        let parsed = match Request::from_json(&line).unwrap() {
            Request::Rank(rr) => rr,
            other => panic!("expected rank request, got {other:?}"),
        };
        assert_eq!(parsed.model, "mlp");
        assert_eq!(parsed.batch, 16);
        assert_eq!(parsed.precision.as_deref(), Some("amp"));
        assert_eq!(parsed.dests.as_deref().unwrap().len(), 2);
    }

    #[test]
    fn predict_line_still_dispatches_as_predict() {
        let line = PredictionRequest {
            model: "mlp".into(),
            batch: 8,
            origin: "t4".into(),
            dest: "v100".into(),
            precision: None,
        }
        .to_json();
        assert!(matches!(Request::from_json(&line).unwrap(), Request::Predict(_)));
    }

    #[test]
    fn stats_line_dispatches_as_stats() {
        let line = stats_request_json();
        assert!(matches!(Request::from_json(&line).unwrap(), Request::Stats));
    }

    #[test]
    fn rank_many_request_and_response_roundtrip() {
        let line = v2_rank_many_request(
            &[("mlp", 16, "t4"), ("dcgan", 32, "p4000")],
            Some(&["v100".to_string()]),
            Some("amp"),
        );
        let v = json::parse(&line).unwrap();
        assert_eq!(v.req_str("op").unwrap(), "rank_many");
        let items = v.get("items").and_then(Json::as_arr).unwrap();
        assert_eq!(items.len(), 2);
        assert_eq!(items[1].req_str("model").unwrap(), "dcgan");
        assert_eq!(items[1].req_usize("batch").unwrap(), 32);
        assert_eq!(v.req_str("precision").unwrap(), "amp");

        let resp = RankManyResponse {
            results: vec![RankResponse {
                model: "mlp".into(),
                batch: 16,
                origin: "t4".into(),
                origin_iter_ms: 2.0,
                ranking: vec![RankedDest {
                    dest: "v100".into(),
                    iter_ms: 1.0,
                    throughput: 16_000.0,
                    cost_normalized_throughput: None,
                    mlp_time_fraction: 0.0,
                    mlp_fallbacks: 0,
                }],
            }],
        };
        let env = v2_envelope("rank_many", resp.to_value(), vec![("count", Json::Num(1.0))]);
        let parsed = RankManyResponse::from_json(&env.dump()).unwrap();
        assert_eq!(parsed.results.len(), 1);
        assert_eq!(parsed.results[0].model, "mlp");
        assert_eq!(parsed.results[0].ranking[0].dest, "v100");
    }

    #[test]
    fn v2_error_shape_is_structured() {
        let line = v2_error_json("bad_request", "nope");
        let v = json::parse(&line).unwrap();
        assert_eq!(v.get("v"), Some(&Json::Num(PROTOCOL_V2)));
        assert_eq!(
            v.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
            Some("bad_request")
        );
        assert!(v2_check_error(&v).is_err());
    }

    #[test]
    fn v2_envelope_inserts_version_op_and_extras() {
        let env = v2_envelope(
            "predict",
            Json::obj(vec![("iter_ms", Json::Num(1.5))]),
            vec![("trace_id", Json::Str("tr-1".into()))],
        );
        assert_eq!(env.get("v"), Some(&Json::Num(PROTOCOL_V2)));
        assert_eq!(env.req_str("op").unwrap(), "predict");
        assert_eq!(env.req_str("trace_id").unwrap(), "tr-1");
        assert_eq!(env.get("iter_ms"), Some(&Json::Num(1.5)));
    }
}
