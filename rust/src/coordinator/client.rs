//! Blocking TCP client for the prediction service.
//!
//! Speaks the newline-delimited JSON protocol of [`super::service`] —
//! both the v1 bare-object requests and the v2 envelope ops
//! (`register_device`, `submit_trace`, trace-id predictions): requests
//! may be pipelined; responses return in order. Used by the service
//! integration tests and available to downstream tools (e.g. a cluster
//! scheduler running on a different host than the predictor).
//!
//! Every stream carries **read and write timeouts**
//! ([`Client::DEFAULT_TIMEOUT`] unless overridden via
//! [`Client::connect_with_timeout`]), so a hung or wedged server
//! surfaces as an error instead of blocking the caller forever.
//!
//! **Disconnect handling**: a server that closes (or resets) the
//! connection mid-session surfaces as the typed
//! [`ClientError::Disconnected`] — downcastable from the returned
//! `anyhow::Error` — never as a bare broken-pipe `io::Error`. For
//! *idempotent* operations (`predict`, `rank`, `rank_many`, `stats`,
//! `predict_trace`, `rank_trace`, `predict_cluster`, `rank_cluster`,
//! `export_workload`) the client additionally performs
//! **one** automatic reconnect-and-retry; state-changing operations
//! (`submit_trace`, `register_device`) are never retried — the caller
//! decides whether replaying a write is safe.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use crate::comm::Workload;
use crate::coordinator::{
    service, ClusterRankResponse, ClusterResponse, PredictionRequest, PredictionResponse,
    RankManyResponse, RankRequest, RankResponse, RegisteredDevice, StatsResponse,
};
use crate::device::NewDevice;
use crate::tracker::Trace;
use crate::util::json;
use crate::Result;

/// Typed client-side failures, downcastable from the `anyhow::Error`s
/// this module returns (`err.downcast_ref::<ClientError>()`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClientError {
    /// The server closed or reset the connection mid-session. Idempotent
    /// operations retry once over a fresh connection before surfacing
    /// this; state-changing operations surface it immediately.
    Disconnected,
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Disconnected => f.write_str("server disconnected mid-session"),
        }
    }
}

impl std::error::Error for ClientError {}

/// An I/O failure that means "the peer is gone" rather than "the
/// operation timed out" (timeouts must *not* trigger a retry: the
/// server may still be processing the original request).
fn is_disconnect(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::BrokenPipe
            | std::io::ErrorKind::ConnectionReset
            | std::io::ErrorKind::ConnectionAborted
            | std::io::ErrorKind::NotConnected
            | std::io::ErrorKind::UnexpectedEof
    )
}

/// A connected prediction-service client.
pub struct Client {
    addr: String,
    timeout: Option<Duration>,
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    /// Default per-operation socket timeout: generous enough for a cold
    /// tracking pass on a loaded server, small enough that a wedged
    /// server cannot hold a caller hostage.
    pub const DEFAULT_TIMEOUT: Duration = Duration::from_secs(30);

    /// Connect to a running `habitat serve` instance with
    /// [`Client::DEFAULT_TIMEOUT`] read/write timeouts.
    pub fn connect(addr: &str) -> Result<Self> {
        Self::connect_with_timeout(addr, Some(Self::DEFAULT_TIMEOUT))
    }

    /// Connect with explicit read/write timeouts (`None` = block
    /// forever, the pre-timeout behavior).
    pub fn connect_with_timeout(addr: &str, timeout: Option<Duration>) -> Result<Self> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| anyhow::anyhow!("connecting to {addr}: {e}"))?;
        stream.set_nodelay(true).ok();
        if let Some(t) = timeout {
            anyhow::ensure!(!t.is_zero(), "timeout must be nonzero (use None to block forever)");
            stream.set_read_timeout(Some(t))?;
            stream.set_write_timeout(Some(t))?;
        }
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client {
            addr: addr.to_string(),
            timeout,
            writer: stream,
            reader,
        })
    }

    /// Tear down the dead stream and dial the original address again
    /// with the original timeout settings.
    fn reconnect(&mut self) -> Result<()> {
        *self = Self::connect_with_timeout(&self.addr, self.timeout)?;
        Ok(())
    }

    /// Send one request and wait for its response (idempotent: one
    /// automatic reconnect-and-retry on a mid-session disconnect).
    ///
    /// Like [`Client::rank`], this must not be called while pipelined
    /// [`Client::send`] requests still have unread responses — drain
    /// them with [`Client::recv`] first. A retry replays only *this*
    /// request over a fresh connection, which would silently lose any
    /// outstanding pipelined replies.
    pub fn predict(&mut self, request: &PredictionRequest) -> Result<PredictionResponse> {
        PredictionResponse::from_json(&self.request_idempotent(&request.to_json())?)
    }

    /// Pipeline: send without waiting. Raw sends are never auto-retried
    /// (the client cannot know how many pipelined responses were lost),
    /// but a dead peer still surfaces as the typed
    /// [`ClientError::Disconnected`].
    pub fn send(&mut self, request: &PredictionRequest) -> Result<()> {
        self.send_line(&request.to_json())
    }

    /// Receive the next in-order response.
    pub fn recv(&mut self) -> Result<PredictionResponse> {
        PredictionResponse::from_json(&self.recv_line()?)
    }

    /// Send one rank request and wait for the ranked response
    /// (idempotent: one automatic reconnect-and-retry on disconnect).
    ///
    /// Responses come back strictly in request order, so this must not
    /// be called while pipelined [`Client::send`] requests still have
    /// unread responses — drain them with [`Client::recv`] first, or
    /// the streams desynchronize.
    pub fn rank(&mut self, request: &RankRequest) -> Result<RankResponse> {
        RankResponse::from_json(&self.request_idempotent(&request.to_json())?)
    }

    /// Fetch the server engine's counter snapshot (trace/plan cache
    /// hits & misses, wave-table counters, pool size). Idempotent (one
    /// automatic reconnect-and-retry on disconnect), with the same
    /// in-order caveat as [`Client::rank`]: drain any pipelined
    /// responses first.
    pub fn stats(&mut self) -> Result<StatsResponse> {
        StatsResponse::from_json(&self.request_idempotent(&service::stats_request_json())?)
    }

    // --- v2 envelope operations ----------------------------------------
    //
    // All of these share the in-order caveat of [`Client::rank`]: drain
    // pipelined predict responses before calling them.

    /// Register a new GPU on the server (`{"v":2,"op":"register_device"}`).
    /// Idempotent *server-side* for identical descriptions (a name
    /// collision with a different spec is a `conflict` error), but as a
    /// state-changing operation it is **never** auto-retried: a
    /// disconnect surfaces as [`ClientError::Disconnected`].
    pub fn register_device(&mut self, device: &NewDevice) -> Result<RegisteredDevice> {
        let line = self.request_once(&service::v2_register_device_request(device))?;
        RegisteredDevice::from_json(&line)
    }

    /// Upload a locally profiled trace (`{"v":2,"op":"submit_trace"}`)
    /// and return its content-hashed `trace_id`, which
    /// [`Client::predict_trace`] / [`Client::rank_trace`] accept in
    /// place of `model` + `batch` + `origin`. State-changing: a
    /// disconnect is **never** auto-retried and surfaces as
    /// [`ClientError::Disconnected`].
    pub fn submit_trace(&mut self, trace: &Trace) -> Result<String> {
        let v = json::parse(&self.request_once(&service::v2_submit_trace_request(trace))?)?;
        service::v2_check_error(&v)?;
        Ok(v.req_str("trace_id")?.to_string())
    }

    /// Predict a previously submitted trace onto one destination
    /// (idempotent: one automatic reconnect-and-retry on disconnect).
    pub fn predict_trace(
        &mut self,
        trace_id: &str,
        dest: &str,
        precision: Option<&str>,
    ) -> Result<PredictionResponse> {
        let line =
            self.request_idempotent(&service::v2_predict_trace_request(trace_id, dest, precision))?;
        service::v2_check_error(&json::parse(&line)?)?;
        PredictionResponse::from_json(&line)
    }

    /// Rank destinations for a previously submitted trace (`None` dests
    /// = every device in the server's registry). Idempotent: one
    /// automatic reconnect-and-retry on disconnect.
    pub fn rank_trace(
        &mut self,
        trace_id: &str,
        dests: Option<&[String]>,
        precision: Option<&str>,
    ) -> Result<RankResponse> {
        let line =
            self.request_idempotent(&service::v2_rank_trace_request(trace_id, dests, precision))?;
        service::v2_check_error(&json::parse(&line)?)?;
        RankResponse::from_json(&line)
    }

    /// Rank several `(model, batch, origin)` traces over one shared
    /// destination set in a single roundtrip
    /// (`{"v":2,"op":"rank_many"}`) — the server runs all of them as one
    /// work-claimed multi-trace sweep. `None` dests mean every device in
    /// the server's registry. Idempotent: one automatic
    /// reconnect-and-retry on disconnect.
    pub fn rank_many(
        &mut self,
        items: &[(&str, usize, &str)],
        dests: Option<&[String]>,
        precision: Option<&str>,
    ) -> Result<RankManyResponse> {
        let line =
            self.request_idempotent(&service::v2_rank_many_request(items, dests, precision))?;
        RankManyResponse::from_json(&line)
    }

    /// Sweep one destination across a topology × world grid
    /// (`{"v":2,"op":"predict_cluster"}`). `None` topologies/worlds
    /// mean the server defaults (every registered topology,
    /// [`service::DEFAULT_CLUSTER_WORLDS`]). Idempotent: one automatic
    /// reconnect-and-retry on disconnect.
    #[allow(clippy::too_many_arguments)]
    pub fn predict_cluster(
        &mut self,
        model: &str,
        batch: usize,
        origin: &str,
        dest: &str,
        topologies: Option<&[String]>,
        worlds: Option<&[usize]>,
        precision: Option<&str>,
    ) -> Result<ClusterResponse> {
        let line = self.request_idempotent(&service::v2_predict_cluster_request(
            model, batch, origin, dest, topologies, worlds, precision,
        ))?;
        ClusterResponse::from_json(&line)
    }

    /// Rank every (destination, topology, world) configuration
    /// (`{"v":2,"op":"rank_cluster"}`), best decision first. `None`
    /// dests mean every device in the server's registry. Idempotent:
    /// one automatic reconnect-and-retry on disconnect.
    #[allow(clippy::too_many_arguments)]
    pub fn rank_cluster(
        &mut self,
        model: &str,
        batch: usize,
        origin: &str,
        dests: Option<&[String]>,
        topologies: Option<&[String]>,
        worlds: Option<&[usize]>,
        precision: Option<&str>,
    ) -> Result<ClusterRankResponse> {
        let line = self.request_idempotent(&service::v2_rank_cluster_request(
            model, batch, origin, dests, topologies, worlds, precision,
        ))?;
        ClusterRankResponse::from_json(&line)
    }

    /// Export one configuration's predicted compute + collective
    /// schedule (`{"v":2,"op":"export_workload"}`) as a
    /// [`Workload`]. Idempotent: one automatic reconnect-and-retry on
    /// disconnect.
    #[allow(clippy::too_many_arguments)]
    pub fn export_workload(
        &mut self,
        model: &str,
        batch: usize,
        origin: &str,
        dest: &str,
        topology: &str,
        world: usize,
        precision: Option<&str>,
    ) -> Result<Workload> {
        let line = self.request_idempotent(&service::v2_export_workload_request(
            model, batch, origin, dest, topology, world, precision,
        ))?;
        let v = json::parse(&line)?;
        service::v2_check_error(&v)?;
        Workload::from_value(&v)
    }

    /// One request/response roundtrip, retried exactly once over a
    /// fresh connection if the server disconnected mid-session. Only
    /// for idempotent operations; must not be used while pipelined
    /// responses are outstanding (a retry would replay into a
    /// desynchronized stream).
    fn request_idempotent(&mut self, line: &str) -> Result<String> {
        match self.request_once(line) {
            Err(e) if e.downcast_ref::<ClientError>() == Some(&ClientError::Disconnected) => {
                self.reconnect()?;
                self.request_once(line)
            }
            other => other,
        }
    }

    /// One request/response roundtrip, no retry.
    fn request_once(&mut self, line: &str) -> Result<String> {
        self.send_line(line)?;
        self.recv_line()
    }

    fn send_line(&mut self, line: &str) -> Result<()> {
        let io = self
            .writer
            .write_all(line.as_bytes())
            .and_then(|()| self.writer.write_all(b"\n"));
        io.map_err(|e| {
            if is_disconnect(&e) {
                anyhow::Error::new(e).context(ClientError::Disconnected)
            } else {
                anyhow::Error::new(e)
            }
        })
    }

    fn recv_line(&mut self) -> Result<String> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).map_err(|e| {
            if is_disconnect(&e) {
                anyhow::Error::new(e).context(ClientError::Disconnected)
            } else {
                anyhow::Error::new(e)
            }
        })?;
        if n == 0 {
            // A clean EOF mid-session is the typed disconnect, too.
            return Err(anyhow::Error::new(ClientError::Disconnected));
        }
        Ok(line.trim().to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::PredictionService;
    use crate::predict::HybridPredictor;
    use std::sync::Arc;

    fn spawn_server() -> String {
        let service = Arc::new(PredictionService::with_predictor(HybridPredictor::wave_only()));
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                let service = service.clone();
                std::thread::spawn(move || {
                    let _ = crate::coordinator::service::handle_connection(stream.unwrap(), &service);
                });
            }
        });
        addr
    }

    fn req(model: &str, dest: &str) -> PredictionRequest {
        PredictionRequest {
            model: model.into(),
            batch: 16,
            origin: "t4".into(),
            dest: dest.into(),
            precision: None,
        }
    }

    #[test]
    fn simple_roundtrip() {
        let addr = spawn_server();
        let mut client = Client::connect(&addr).unwrap();
        let resp = client.predict(&req("mlp", "v100")).unwrap();
        assert_eq!(resp.model, "mlp");
        assert!(resp.iter_ms > 0.0);
    }

    #[test]
    fn pipelined_requests_come_back_in_order() {
        let addr = spawn_server();
        let mut client = Client::connect(&addr).unwrap();
        for dest in ["v100", "p100", "p4000"] {
            client.send(&req("mlp", dest)).unwrap();
        }
        assert_eq!(client.recv().unwrap().dest, "V100");
        assert_eq!(client.recv().unwrap().dest, "P100");
        assert_eq!(client.recv().unwrap().dest, "P4000");
    }

    #[test]
    fn rank_roundtrip_over_tcp() {
        let addr = spawn_server();
        let mut client = Client::connect(&addr).unwrap();
        let resp = client
            .rank(&crate::coordinator::RankRequest {
                model: "mlp".into(),
                batch: 16,
                origin: "t4".into(),
                precision: None,
                dests: None,
            })
            .unwrap();
        // Default dests = the whole registry: at least the built-ins
        // (other tests may have registered more devices concurrently).
        assert!(resp.ranking.len() >= crate::device::ALL_DEVICES.len());
        for d in crate::device::ALL_DEVICES {
            assert!(resp.ranking.iter().any(|r| r.dest == d.id()), "{d} missing");
        }
        assert!(resp.ranking.iter().all(|r| r.iter_ms > 0.0));
        // A predict request on the same connection still works afterwards.
        let single = client.predict(&req("mlp", "v100")).unwrap();
        assert!(single.iter_ms > 0.0);
    }

    #[test]
    fn stats_over_tcp() {
        let addr = spawn_server();
        let mut client = Client::connect(&addr).unwrap();
        let cold = client.stats().unwrap();
        assert_eq!(cold.trace_misses, 0);
        client.predict(&req("mlp", "v100")).unwrap();
        let warm = client.stats().unwrap();
        assert_eq!(warm.trace_misses, 1);
        assert_eq!(warm.plan_builds, 1);
        assert!(warm.workers >= 1);
    }

    #[test]
    fn server_errors_surface_as_client_errors() {
        let addr = spawn_server();
        let mut client = Client::connect(&addr).unwrap();
        let err = client.predict(&req("not_a_model", "v100")).unwrap_err();
        assert!(err.to_string().contains("unknown model"), "{err}");
    }

    #[test]
    fn connect_applies_socket_timeouts() {
        let addr = spawn_server();
        let client = Client::connect(&addr).unwrap();
        assert_eq!(
            client.writer.read_timeout().unwrap(),
            Some(Client::DEFAULT_TIMEOUT)
        );
        assert_eq!(
            client.writer.write_timeout().unwrap(),
            Some(Client::DEFAULT_TIMEOUT)
        );
        let untimed = Client::connect_with_timeout(&addr, None).unwrap();
        assert_eq!(untimed.writer.read_timeout().unwrap(), None);
        assert!(Client::connect_with_timeout(&addr, Some(std::time::Duration::ZERO)).is_err());
    }

    #[test]
    fn hung_server_times_out_instead_of_wedging() {
        // A listener that accepts and then never replies.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let _hold = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            std::thread::sleep(std::time::Duration::from_secs(5));
            drop(stream);
        });
        let mut client =
            Client::connect_with_timeout(&addr, Some(std::time::Duration::from_millis(100)))
                .unwrap();
        let t0 = std::time::Instant::now();
        let err = client.predict(&req("mlp", "v100")).unwrap_err();
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(3),
            "read must time out promptly, got {err}"
        );
    }

    /// A server that answers exactly `answers` requests per connection,
    /// then closes it — the disconnect/retry workhorse. Returns the
    /// address and a counter of accepted connections.
    fn flaky_server(answers: usize) -> (String, Arc<std::sync::atomic::AtomicUsize>) {
        let service = Arc::new(PredictionService::with_predictor(HybridPredictor::wave_only()));
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let accepted = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let counter = Arc::clone(&accepted);
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                let Ok(stream) = stream else { break };
                counter.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                let mut write = stream.try_clone().unwrap();
                let mut lines = BufReader::new(stream).lines();
                for _ in 0..answers {
                    let Some(Ok(line)) = lines.next() else { break };
                    let reply = service.handle_line(&line);
                    if write.write_all(reply.as_bytes()).is_err()
                        || write.write_all(b"\n").is_err()
                    {
                        break;
                    }
                }
                // Dropping both halves closes the connection mid-session.
            }
        });
        (addr, accepted)
    }

    #[test]
    fn disconnect_is_a_typed_error_and_idempotent_ops_retry_once() {
        use std::sync::atomic::Ordering;
        let (addr, accepted) = flaky_server(1);
        let mut client = Client::connect(&addr).unwrap();
        // Connection 1 has one answer in it.
        assert_eq!(client.predict(&req("mlp", "v100")).unwrap().dest, "V100");
        assert_eq!(accepted.load(Ordering::SeqCst), 1);
        // The server hung up after that answer; the next predict hits the
        // dead stream, reconnects transparently, and succeeds.
        assert_eq!(client.predict(&req("mlp", "p100")).unwrap().dest, "P100");
        assert_eq!(accepted.load(Ordering::SeqCst), 2, "exactly one reconnect");
        // rank and stats retry the same way.
        let ranking = client
            .rank(&crate::coordinator::RankRequest {
                model: "mlp".into(),
                batch: 16,
                origin: "t4".into(),
                precision: None,
                dests: None,
            })
            .unwrap();
        assert!(!ranking.ranking.is_empty());
        assert_eq!(accepted.load(Ordering::SeqCst), 3);
        assert!(client.stats().unwrap().trace_misses >= 1);
        assert_eq!(accepted.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn state_changing_ops_never_retry_and_surface_the_typed_error() {
        use std::sync::atomic::Ordering;
        // Answers zero requests: every operation meets a disconnect.
        let (addr, accepted) = flaky_server(0);
        let mut client = Client::connect(&addr).unwrap();
        // Let the server-side close land before we write.
        std::thread::sleep(std::time::Duration::from_millis(50));
        let before = accepted.load(Ordering::SeqCst);

        let mut g = crate::Graph::new("retry-probe", 2);
        g.push(crate::Op::new(
            "fc",
            crate::OpKind::Linear { in_features: 8, out_features: 4, bias: true },
            vec![2, 8],
        ));
        let trace = crate::tracker::OperationTracker::new(crate::device::Device::T4).track(&g);
        let err = client.submit_trace(&trace).unwrap_err();
        assert_eq!(
            err.downcast_ref::<ClientError>(),
            Some(&ClientError::Disconnected),
            "submit_trace must surface the typed disconnect, got: {err:#}"
        );
        assert_eq!(
            accepted.load(Ordering::SeqCst),
            before,
            "submit_trace must not reconnect"
        );

        let err = client
            .register_device(&NewDevice::new("sim-noretry", 10, 1000.0, 100.0, 5.0, false))
            .unwrap_err();
        assert_eq!(err.downcast_ref::<ClientError>(), Some(&ClientError::Disconnected));
        assert_eq!(
            accepted.load(Ordering::SeqCst),
            before,
            "register_device must not reconnect"
        );
    }

    #[test]
    fn idempotent_retry_gives_up_after_one_reconnect() {
        // Answers zero requests: the retry's fresh connection dies too,
        // so the typed error must come back instead of an infinite loop.
        let (addr, accepted) = flaky_server(0);
        let mut client = Client::connect(&addr).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(50));
        let before = accepted.load(std::sync::atomic::Ordering::SeqCst);
        let err = client.predict(&req("mlp", "v100")).unwrap_err();
        assert_eq!(err.downcast_ref::<ClientError>(), Some(&ClientError::Disconnected));
        assert_eq!(
            accepted.load(std::sync::atomic::Ordering::SeqCst),
            before + 1,
            "exactly one reconnect attempt"
        );
    }

    #[test]
    fn v2_register_submit_and_trace_predictions_over_tcp() {
        let addr = spawn_server();
        let mut client = Client::connect(&addr).unwrap();

        // Register a new GPU and see it in a default rank.
        let ack = client
            .register_device(&NewDevice {
                usd_per_hr: Some(0.55),
                ..NewDevice::new("sim-cli7", 60, 1600.0, 500.0, 14.0, true)
            })
            .unwrap();
        assert_eq!(ack.device, "sim-cli7");
        let resp = client
            .rank(&crate::coordinator::RankRequest {
                model: "mlp".into(),
                batch: 16,
                origin: "t4".into(),
                precision: None,
                dests: None,
            })
            .unwrap();
        assert!(resp.ranking.iter().any(|r| r.dest == "sim-cli7"));

        // Conflicting re-registration is a structured error.
        let err = client
            .register_device(&NewDevice::new("sim-cli7", 61, 1600.0, 500.0, 14.0, true))
            .unwrap_err();
        assert!(err.to_string().contains("conflict"), "{err}");

        // Upload a locally profiled (non-zoo) trace and predict it.
        let mut g = crate::Graph::new("homegrown", 4);
        g.push(crate::Op::new(
            "fc",
            crate::OpKind::Linear { in_features: 96, out_features: 48, bias: true },
            vec![4, 96],
        ));
        let trace = crate::tracker::OperationTracker::new(crate::device::Device::T4).track(&g);
        let id = client.submit_trace(&trace).unwrap();
        assert!(id.starts_with("tr-"));
        let pred = client.predict_trace(&id, "v100", None).unwrap();
        assert_eq!(pred.model, "homegrown");
        assert!(pred.iter_ms > 0.0);
        let ranked = client.rank_trace(&id, None, Some("amp")).unwrap();
        assert!(ranked.ranking.len() >= crate::device::ALL_DEVICES.len());
        let unknown = client.predict_trace("tr-ffffffffffffffff", "v100", None).unwrap_err();
        assert!(unknown.to_string().contains("unknown_trace"), "{unknown}");
    }

    #[test]
    fn cluster_ops_over_tcp() {
        let addr = spawn_server();
        let mut client = Client::connect(&addr).unwrap();
        let topologies = vec!["dgx".to_string(), "cloud".to_string()];

        let resp = client
            .predict_cluster("mlp", 16, "t4", "v100", Some(&topologies), Some(&[1, 2, 8]), None)
            .unwrap();
        assert_eq!(resp.dest, "V100");
        assert_eq!(resp.configs.len(), 6);
        assert!(resp.configs.iter().all(|c| c.efficiency > 0.0 && c.efficiency <= 1.0 + 1e-9));

        let dests = vec!["v100".to_string(), "t4".to_string()];
        let ranked = client
            .rank_cluster("mlp", 16, "t4", Some(&dests), Some(&topologies), Some(&[1, 8]), None)
            .unwrap();
        assert_eq!(ranked.ranking.len(), 2 * 2 * 2);
        let scores: Vec<f64> = ranked
            .ranking
            .iter()
            .map(|e| e.cost_normalized_throughput.unwrap())
            .collect();
        assert!(scores.windows(2).all(|w| w[0] >= w[1]));

        let workload = client.export_workload("mlp", 16, "t4", "v100", "dgx", 16, None).unwrap();
        assert_eq!(workload.world, 16);
        assert!(!workload.comm_ops.is_empty());

        let err = client
            .predict_cluster("mlp", 16, "t4", "v100", Some(&["nope".to_string()]), None, None)
            .unwrap_err();
        assert!(err.to_string().contains("unknown_topology"), "{err}");
    }

    #[test]
    fn rank_many_over_tcp() {
        let addr = spawn_server();
        let mut client = Client::connect(&addr).unwrap();
        let dests = vec!["v100".to_string(), "t4".to_string()];
        let items = [("mlp", 8usize, "t4"), ("dcgan", 16, "p4000")];

        let many = client.rank_many(&items, Some(&dests), None).unwrap();
        assert_eq!(many.results.len(), items.len());

        // Each result is bitwise the same ranking a per-model `rank`
        // with the same destination set would produce.
        for ((model, batch, origin), got) in items.iter().zip(&many.results) {
            let solo = client
                .rank(&crate::coordinator::RankRequest {
                    model: model.to_string(),
                    batch: *batch,
                    origin: origin.to_string(),
                    precision: None,
                    dests: Some(dests.clone()),
                })
                .unwrap();
            assert_eq!(got.model, solo.model);
            assert_eq!(got.ranking.len(), solo.ranking.len());
            for (a, b) in got.ranking.iter().zip(&solo.ranking) {
                assert_eq!(a.dest, b.dest);
                assert_eq!(a.iter_ms.to_bits(), b.iter_ms.to_bits());
            }
        }

        let err = client.rank_many(&[("nope", 8, "t4")], Some(&dests), None).unwrap_err();
        assert!(err.to_string().contains("unknown_model"), "{err}");
    }
}
