//! Blocking TCP client for the prediction service.
//!
//! Speaks the newline-delimited JSON protocol of [`super::service`]:
//! requests may be pipelined; responses return in order. Used by the
//! service integration tests and available to downstream tools (e.g. a
//! cluster scheduler running on a different host than the predictor).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use crate::coordinator::{
    PredictionRequest, PredictionResponse, RankRequest, RankResponse, StatsResponse,
};
use crate::Result;

/// A connected prediction-service client.
pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    /// Connect to a running `habitat serve` instance.
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| anyhow::anyhow!("connecting to {addr}: {e}"))?;
        stream.set_nodelay(true).ok();
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { writer: stream, reader })
    }

    /// Send one request and wait for its response.
    pub fn predict(&mut self, request: &PredictionRequest) -> Result<PredictionResponse> {
        self.send(request)?;
        self.recv()
    }

    /// Pipeline: send without waiting.
    pub fn send(&mut self, request: &PredictionRequest) -> Result<()> {
        self.writer.write_all(request.to_json().as_bytes())?;
        self.writer.write_all(b"\n")?;
        Ok(())
    }

    /// Receive the next in-order response.
    pub fn recv(&mut self) -> Result<PredictionResponse> {
        PredictionResponse::from_json(&self.recv_line()?)
    }

    /// Send one rank request and wait for the ranked response.
    ///
    /// Responses come back strictly in request order, so this must not
    /// be called while pipelined [`Client::send`] requests still have
    /// unread responses — drain them with [`Client::recv`] first, or
    /// the streams desynchronize.
    pub fn rank(&mut self, request: &RankRequest) -> Result<RankResponse> {
        self.writer.write_all(request.to_json().as_bytes())?;
        self.writer.write_all(b"\n")?;
        RankResponse::from_json(&self.recv_line()?)
    }

    /// Fetch the server engine's counter snapshot (trace/plan cache
    /// hits & misses, wave-table counters, fan-out pool size). Same
    /// in-order caveat as [`Client::rank`]: drain any pipelined
    /// responses first.
    pub fn stats(&mut self) -> Result<StatsResponse> {
        self.writer
            .write_all(crate::coordinator::service::stats_request_json().as_bytes())?;
        self.writer.write_all(b"\n")?;
        StatsResponse::from_json(&self.recv_line()?)
    }

    fn recv_line(&mut self) -> Result<String> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        anyhow::ensure!(n > 0, "server closed the connection");
        Ok(line.trim().to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::PredictionService;
    use crate::predict::HybridPredictor;
    use std::sync::Arc;

    fn spawn_server() -> String {
        let service = Arc::new(PredictionService::with_predictor(HybridPredictor::wave_only()));
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                let service = service.clone();
                std::thread::spawn(move || {
                    let _ = crate::coordinator::service::handle_connection(stream.unwrap(), &service);
                });
            }
        });
        addr
    }

    fn req(model: &str, dest: &str) -> PredictionRequest {
        PredictionRequest {
            model: model.into(),
            batch: 16,
            origin: "t4".into(),
            dest: dest.into(),
            precision: None,
        }
    }

    #[test]
    fn simple_roundtrip() {
        let addr = spawn_server();
        let mut client = Client::connect(&addr).unwrap();
        let resp = client.predict(&req("mlp", "v100")).unwrap();
        assert_eq!(resp.model, "mlp");
        assert!(resp.iter_ms > 0.0);
    }

    #[test]
    fn pipelined_requests_come_back_in_order() {
        let addr = spawn_server();
        let mut client = Client::connect(&addr).unwrap();
        for dest in ["v100", "p100", "p4000"] {
            client.send(&req("mlp", dest)).unwrap();
        }
        assert_eq!(client.recv().unwrap().dest, "V100");
        assert_eq!(client.recv().unwrap().dest, "P100");
        assert_eq!(client.recv().unwrap().dest, "P4000");
    }

    #[test]
    fn rank_roundtrip_over_tcp() {
        let addr = spawn_server();
        let mut client = Client::connect(&addr).unwrap();
        let resp = client
            .rank(&crate::coordinator::RankRequest {
                model: "mlp".into(),
                batch: 16,
                origin: "t4".into(),
                precision: None,
                dests: None,
            })
            .unwrap();
        assert_eq!(resp.ranking.len(), crate::device::ALL_DEVICES.len());
        assert!(resp.ranking.iter().all(|r| r.iter_ms > 0.0));
        // A predict request on the same connection still works afterwards.
        let single = client.predict(&req("mlp", "v100")).unwrap();
        assert!(single.iter_ms > 0.0);
    }

    #[test]
    fn stats_over_tcp() {
        let addr = spawn_server();
        let mut client = Client::connect(&addr).unwrap();
        let cold = client.stats().unwrap();
        assert_eq!(cold.trace_misses, 0);
        client.predict(&req("mlp", "v100")).unwrap();
        let warm = client.stats().unwrap();
        assert_eq!(warm.trace_misses, 1);
        assert_eq!(warm.plan_builds, 1);
        assert!(warm.workers >= 1);
    }

    #[test]
    fn server_errors_surface_as_client_errors() {
        let addr = spawn_server();
        let mut client = Client::connect(&addr).unwrap();
        let err = client.predict(&req("not_a_model", "v100")).unwrap_err();
        assert!(err.to_string().contains("unknown model"), "{err}");
    }
}
