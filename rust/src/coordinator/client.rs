//! Blocking TCP client for the prediction service.
//!
//! Speaks the newline-delimited JSON protocol of [`super::service`] —
//! both the v1 bare-object requests and the v2 envelope ops
//! (`register_device`, `submit_trace`, trace-id predictions): requests
//! may be pipelined; responses return in order. Used by the service
//! integration tests and available to downstream tools (e.g. a cluster
//! scheduler running on a different host than the predictor).
//!
//! Every stream carries **read and write timeouts**
//! ([`Client::DEFAULT_TIMEOUT`] unless overridden via
//! [`Client::connect_with_timeout`]), so a hung or wedged server
//! surfaces as an error instead of blocking the caller forever.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use crate::coordinator::{
    service, PredictionRequest, PredictionResponse, RankRequest, RankResponse, RegisteredDevice,
    StatsResponse,
};
use crate::device::NewDevice;
use crate::tracker::Trace;
use crate::util::json;
use crate::Result;

/// A connected prediction-service client.
pub struct Client {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    /// Default per-operation socket timeout: generous enough for a cold
    /// tracking pass on a loaded server, small enough that a wedged
    /// server cannot hold a caller hostage.
    pub const DEFAULT_TIMEOUT: Duration = Duration::from_secs(30);

    /// Connect to a running `habitat serve` instance with
    /// [`Client::DEFAULT_TIMEOUT`] read/write timeouts.
    pub fn connect(addr: &str) -> Result<Self> {
        Self::connect_with_timeout(addr, Some(Self::DEFAULT_TIMEOUT))
    }

    /// Connect with explicit read/write timeouts (`None` = block
    /// forever, the pre-timeout behavior).
    pub fn connect_with_timeout(addr: &str, timeout: Option<Duration>) -> Result<Self> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| anyhow::anyhow!("connecting to {addr}: {e}"))?;
        stream.set_nodelay(true).ok();
        if let Some(t) = timeout {
            anyhow::ensure!(!t.is_zero(), "timeout must be nonzero (use None to block forever)");
            stream.set_read_timeout(Some(t))?;
            stream.set_write_timeout(Some(t))?;
        }
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { writer: stream, reader })
    }

    /// Send one request and wait for its response.
    pub fn predict(&mut self, request: &PredictionRequest) -> Result<PredictionResponse> {
        self.send(request)?;
        self.recv()
    }

    /// Pipeline: send without waiting.
    pub fn send(&mut self, request: &PredictionRequest) -> Result<()> {
        self.writer.write_all(request.to_json().as_bytes())?;
        self.writer.write_all(b"\n")?;
        Ok(())
    }

    /// Receive the next in-order response.
    pub fn recv(&mut self) -> Result<PredictionResponse> {
        PredictionResponse::from_json(&self.recv_line()?)
    }

    /// Send one rank request and wait for the ranked response.
    ///
    /// Responses come back strictly in request order, so this must not
    /// be called while pipelined [`Client::send`] requests still have
    /// unread responses — drain them with [`Client::recv`] first, or
    /// the streams desynchronize.
    pub fn rank(&mut self, request: &RankRequest) -> Result<RankResponse> {
        self.writer.write_all(request.to_json().as_bytes())?;
        self.writer.write_all(b"\n")?;
        RankResponse::from_json(&self.recv_line()?)
    }

    /// Fetch the server engine's counter snapshot (trace/plan cache
    /// hits & misses, wave-table counters, fan-out pool size). Same
    /// in-order caveat as [`Client::rank`]: drain any pipelined
    /// responses first.
    pub fn stats(&mut self) -> Result<StatsResponse> {
        self.send_line(&service::stats_request_json())?;
        StatsResponse::from_json(&self.recv_line()?)
    }

    // --- v2 envelope operations ----------------------------------------
    //
    // All of these share the in-order caveat of [`Client::rank`]: drain
    // pipelined predict responses before calling them.

    /// Register a new GPU on the server (`{"v":2,"op":"register_device"}`).
    /// Idempotent for identical descriptions; a name collision with a
    /// different spec is a server-side `conflict` error.
    pub fn register_device(&mut self, device: &NewDevice) -> Result<RegisteredDevice> {
        self.send_line(&service::v2_register_device_request(device))?;
        RegisteredDevice::from_json(&self.recv_line()?)
    }

    /// Upload a locally profiled trace (`{"v":2,"op":"submit_trace"}`)
    /// and return its content-hashed `trace_id`, which
    /// [`Client::predict_trace`] / [`Client::rank_trace`] accept in
    /// place of `model` + `batch` + `origin`.
    pub fn submit_trace(&mut self, trace: &Trace) -> Result<String> {
        self.send_line(&service::v2_submit_trace_request(trace))?;
        let v = json::parse(&self.recv_line()?)?;
        service::v2_check_error(&v)?;
        Ok(v.req_str("trace_id")?.to_string())
    }

    /// Predict a previously submitted trace onto one destination.
    pub fn predict_trace(
        &mut self,
        trace_id: &str,
        dest: &str,
        precision: Option<&str>,
    ) -> Result<PredictionResponse> {
        self.send_line(&service::v2_predict_trace_request(trace_id, dest, precision))?;
        let line = self.recv_line()?;
        service::v2_check_error(&json::parse(&line)?)?;
        PredictionResponse::from_json(&line)
    }

    /// Rank destinations for a previously submitted trace (`None` dests
    /// = every device in the server's registry).
    pub fn rank_trace(
        &mut self,
        trace_id: &str,
        dests: Option<&[String]>,
        precision: Option<&str>,
    ) -> Result<RankResponse> {
        self.send_line(&service::v2_rank_trace_request(trace_id, dests, precision))?;
        let line = self.recv_line()?;
        service::v2_check_error(&json::parse(&line)?)?;
        RankResponse::from_json(&line)
    }

    fn send_line(&mut self, line: &str) -> Result<()> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        Ok(())
    }

    fn recv_line(&mut self) -> Result<String> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        anyhow::ensure!(n > 0, "server closed the connection");
        Ok(line.trim().to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::PredictionService;
    use crate::predict::HybridPredictor;
    use std::sync::Arc;

    fn spawn_server() -> String {
        let service = Arc::new(PredictionService::with_predictor(HybridPredictor::wave_only()));
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                let service = service.clone();
                std::thread::spawn(move || {
                    let _ = crate::coordinator::service::handle_connection(stream.unwrap(), &service);
                });
            }
        });
        addr
    }

    fn req(model: &str, dest: &str) -> PredictionRequest {
        PredictionRequest {
            model: model.into(),
            batch: 16,
            origin: "t4".into(),
            dest: dest.into(),
            precision: None,
        }
    }

    #[test]
    fn simple_roundtrip() {
        let addr = spawn_server();
        let mut client = Client::connect(&addr).unwrap();
        let resp = client.predict(&req("mlp", "v100")).unwrap();
        assert_eq!(resp.model, "mlp");
        assert!(resp.iter_ms > 0.0);
    }

    #[test]
    fn pipelined_requests_come_back_in_order() {
        let addr = spawn_server();
        let mut client = Client::connect(&addr).unwrap();
        for dest in ["v100", "p100", "p4000"] {
            client.send(&req("mlp", dest)).unwrap();
        }
        assert_eq!(client.recv().unwrap().dest, "V100");
        assert_eq!(client.recv().unwrap().dest, "P100");
        assert_eq!(client.recv().unwrap().dest, "P4000");
    }

    #[test]
    fn rank_roundtrip_over_tcp() {
        let addr = spawn_server();
        let mut client = Client::connect(&addr).unwrap();
        let resp = client
            .rank(&crate::coordinator::RankRequest {
                model: "mlp".into(),
                batch: 16,
                origin: "t4".into(),
                precision: None,
                dests: None,
            })
            .unwrap();
        // Default dests = the whole registry: at least the built-ins
        // (other tests may have registered more devices concurrently).
        assert!(resp.ranking.len() >= crate::device::ALL_DEVICES.len());
        for d in crate::device::ALL_DEVICES {
            assert!(resp.ranking.iter().any(|r| r.dest == d.id()), "{d} missing");
        }
        assert!(resp.ranking.iter().all(|r| r.iter_ms > 0.0));
        // A predict request on the same connection still works afterwards.
        let single = client.predict(&req("mlp", "v100")).unwrap();
        assert!(single.iter_ms > 0.0);
    }

    #[test]
    fn stats_over_tcp() {
        let addr = spawn_server();
        let mut client = Client::connect(&addr).unwrap();
        let cold = client.stats().unwrap();
        assert_eq!(cold.trace_misses, 0);
        client.predict(&req("mlp", "v100")).unwrap();
        let warm = client.stats().unwrap();
        assert_eq!(warm.trace_misses, 1);
        assert_eq!(warm.plan_builds, 1);
        assert!(warm.workers >= 1);
    }

    #[test]
    fn server_errors_surface_as_client_errors() {
        let addr = spawn_server();
        let mut client = Client::connect(&addr).unwrap();
        let err = client.predict(&req("not_a_model", "v100")).unwrap_err();
        assert!(err.to_string().contains("unknown model"), "{err}");
    }

    #[test]
    fn connect_applies_socket_timeouts() {
        let addr = spawn_server();
        let client = Client::connect(&addr).unwrap();
        assert_eq!(
            client.writer.read_timeout().unwrap(),
            Some(Client::DEFAULT_TIMEOUT)
        );
        assert_eq!(
            client.writer.write_timeout().unwrap(),
            Some(Client::DEFAULT_TIMEOUT)
        );
        let untimed = Client::connect_with_timeout(&addr, None).unwrap();
        assert_eq!(untimed.writer.read_timeout().unwrap(), None);
        assert!(Client::connect_with_timeout(&addr, Some(std::time::Duration::ZERO)).is_err());
    }

    #[test]
    fn hung_server_times_out_instead_of_wedging() {
        // A listener that accepts and then never replies.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let _hold = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            std::thread::sleep(std::time::Duration::from_secs(5));
            drop(stream);
        });
        let mut client =
            Client::connect_with_timeout(&addr, Some(std::time::Duration::from_millis(100)))
                .unwrap();
        let t0 = std::time::Instant::now();
        let err = client.predict(&req("mlp", "v100")).unwrap_err();
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(3),
            "read must time out promptly, got {err}"
        );
    }

    #[test]
    fn v2_register_submit_and_trace_predictions_over_tcp() {
        let addr = spawn_server();
        let mut client = Client::connect(&addr).unwrap();

        // Register a new GPU and see it in a default rank.
        let ack = client
            .register_device(&NewDevice {
                usd_per_hr: Some(0.55),
                ..NewDevice::new("sim-cli7", 60, 1600.0, 500.0, 14.0, true)
            })
            .unwrap();
        assert_eq!(ack.device, "sim-cli7");
        let resp = client
            .rank(&crate::coordinator::RankRequest {
                model: "mlp".into(),
                batch: 16,
                origin: "t4".into(),
                precision: None,
                dests: None,
            })
            .unwrap();
        assert!(resp.ranking.iter().any(|r| r.dest == "sim-cli7"));

        // Conflicting re-registration is a structured error.
        let err = client
            .register_device(&NewDevice::new("sim-cli7", 61, 1600.0, 500.0, 14.0, true))
            .unwrap_err();
        assert!(err.to_string().contains("conflict"), "{err}");

        // Upload a locally profiled (non-zoo) trace and predict it.
        let mut g = crate::Graph::new("homegrown", 4);
        g.push(crate::Op::new(
            "fc",
            crate::OpKind::Linear { in_features: 96, out_features: 48, bias: true },
            vec![4, 96],
        ));
        let trace = crate::tracker::OperationTracker::new(crate::device::Device::T4).track(&g);
        let id = client.submit_trace(&trace).unwrap();
        assert!(id.starts_with("tr-"));
        let pred = client.predict_trace(&id, "v100", None).unwrap();
        assert_eq!(pred.model, "homegrown");
        assert!(pred.iter_ms > 0.0);
        let ranked = client.rank_trace(&id, None, Some("amp")).unwrap();
        assert!(ranked.ranking.len() >= crate::device::ALL_DEVICES.len());
        let unknown = client.predict_trace("tr-ffffffffffffffff", "v100", None).unwrap_err();
        assert!(unknown.to_string().contains("unknown_trace"), "{unknown}");
    }
}
