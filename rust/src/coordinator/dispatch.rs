//! The transport-agnostic dispatcher: one request core shared by every
//! front end.
//!
//! [`Dispatcher`] owns the [`PredictionEngine`] and implements every op
//! of the wire protocol ([`super::protocol`]) — decode, execute,
//! encode — without ever touching a socket. The TCP runtime
//! ([`super::tcp`]) and the HTTP front end ([`super::http`]) both hand
//! raw request text to this layer and write back whatever bytes it
//! returns, so v1/v2 semantics are defined exactly once.
//!
//! Every routed request is timed and recorded into the engine's
//! [`ServiceMetrics`](crate::engine::metrics::ServiceMetrics): per-op
//! request/error counters plus a fixed-bucket latency histogram,
//! surfaced through the v2 `stats` op and the HTTP `GET /metrics`
//! Prometheus endpoint.

use std::sync::Arc;
use std::time::Instant;

use crate::comm::{self, ClusterParams, Topology};
use crate::device::{registry, Device, RegisterError};
use crate::engine::metrics::OpKind;
use crate::engine::PredictionEngine;
use crate::lowering::Precision;
use crate::predict::HybridPredictor;
use crate::tracker::Trace;
use crate::util::json::{self, Json};
use crate::Result;

use super::protocol::{
    classify_engine_error, error_json, new_device_from_value, parse_device, parse_precision,
    v2_envelope, v2_error_json, ClusterConfig, ClusterRankResponse, ClusterRankedConfig,
    ClusterResponse, PredictionRequest, PredictionResponse, RankRequest, RankResponse, RankedDest,
    Request, StatsResponse, V2Error, V2Result, DEFAULT_CLUSTER_WORLDS, MAX_CLUSTER_SWEEP,
    MAX_CLUSTER_WORLD, PROTOCOL_V2,
};

/// One dispatched request's routed result: the serialized reply line,
/// which op it was (for metrics), and the error code when it failed —
/// `None` on success. Transports map the code to their own signalling
/// (the HTTP front end turns it into a status; TCP sends the reply
/// as-is, where the shape already carries the error).
pub struct DispatchOutcome {
    /// The reply, serialized in the shape the request's protocol
    /// version dictates (no trailing newline).
    pub reply: String,
    /// The op this request routed to ([`OpKind::Other`] for lines that
    /// never reached a handler).
    pub op: OpKind,
    /// Stable error code (`"bad_request"`, `"unknown_device"`, …);
    /// `None` on success.
    pub error: Option<&'static str>,
}

impl DispatchOutcome {
    fn ok(reply: String, op: OpKind) -> Self {
        DispatchOutcome { reply, op, error: None }
    }

    fn err(reply: String, op: OpKind, code: &'static str) -> Self {
        DispatchOutcome { reply, op, error: Some(code) }
    }
}

/// The historical name of the dispatcher, kept so every existing
/// `PredictionService` call site (library users, tests, examples)
/// compiles unchanged.
pub type PredictionService = Dispatcher;

/// The transport-agnostic prediction core: protocol decode → engine →
/// protocol encode, with per-op metrics. See the module docs.
pub struct Dispatcher {
    engine: PredictionEngine,
}

impl Dispatcher {
    /// Build with the paper's full hybrid predictor (requires artifacts).
    pub fn new(artifacts: &str) -> Result<Self> {
        Ok(Self::with_engine(PredictionEngine::from_artifacts(artifacts)?))
    }

    /// Build around any predictor (wave-only for tests / no artifacts).
    pub fn with_predictor(predictor: HybridPredictor) -> Self {
        Self::with_engine(PredictionEngine::new(predictor))
    }

    /// Build around an existing engine (shared caches, custom capacity).
    pub fn with_engine(engine: PredictionEngine) -> Self {
        Dispatcher { engine }
    }

    /// Attach (and warm-restore) a persistent plan store — see
    /// [`PredictionEngine::attach_store`].
    pub fn attach_store<P: AsRef<std::path::Path>>(&mut self, dir: P) -> Result<()> {
        self.engine.attach_store(dir)
    }

    pub fn engine(&self) -> &PredictionEngine {
        &self.engine
    }

    pub fn predictor(&self) -> &HybridPredictor {
        self.engine.predictor()
    }

    /// Get or build the origin trace for a request (memoized in the
    /// engine). The tracker always measures FP32 — the paper profiles
    /// FP32 and *predicts* AMP.
    pub fn trace_for(&self, model: &str, batch: usize, origin: Device) -> Result<Arc<Trace>> {
        self.engine.trace(model, batch, origin)
    }

    /// Handle one prediction request synchronously.
    pub fn handle(&self, req: &PredictionRequest) -> Result<PredictionResponse> {
        let origin = parse_device(&req.origin, "origin")?;
        let dest = parse_device(&req.dest, "destination")?;
        let precision = parse_precision(req.precision.as_deref())?;
        anyhow::ensure!(req.batch > 0, "batch must be positive");

        let out = self.engine.predict(&req.model, req.batch, origin, dest, precision)?;
        let tput = out.pred.throughput();
        Ok(PredictionResponse {
            model: req.model.clone(),
            batch: req.batch,
            origin: origin.id().to_string(),
            dest: dest.id().to_string(),
            origin_iter_ms: out.trace.run_time_ms(),
            iter_ms: out.pred.run_time_ms(),
            throughput: tput,
            cost_normalized_throughput: crate::cost::cost_normalized_throughput(dest, tput),
            mlp_time_fraction: out.pred.mlp_time_fraction(),
            mlp_fallbacks: out.pred.mlp_fallbacks,
        })
    }

    /// Handle one rank request: a single tracking pass, fanned out to
    /// every destination on the engine's worker pool.
    pub fn handle_rank(&self, req: &RankRequest) -> Result<RankResponse> {
        let origin = parse_device(&req.origin, "origin")?;
        let precision = parse_precision(req.precision.as_deref())?;
        anyhow::ensure!(req.batch > 0, "batch must be positive");
        // Default destination set: every device in the registry —
        // including GPUs registered at runtime via `register_device`.
        let dests: Vec<Device> = match &req.dests {
            None => registry::all_devices(),
            Some(names) => names
                .iter()
                .map(|n| parse_device(n, "destination"))
                .collect::<Result<Vec<_>>>()?,
        };

        let ranking = self.engine.rank(&req.model, req.batch, origin, &dests, precision)?;
        Ok(RankResponse {
            model: req.model.clone(),
            batch: req.batch,
            origin: origin.id().to_string(),
            origin_iter_ms: ranking.trace.run_time_ms(),
            ranking: ranking
                .entries
                .iter()
                .map(|e| RankedDest {
                    dest: e.dest.id().to_string(),
                    iter_ms: e.pred.run_time_ms(),
                    throughput: e.pred.throughput(),
                    cost_normalized_throughput: e.cost_normalized_throughput,
                    mlp_time_fraction: e.pred.mlp_time_fraction(),
                    mlp_fallbacks: e.pred.mlp_fallbacks,
                })
                .collect(),
        })
    }

    /// Handle a stats request: the engine's counter snapshot.
    pub fn handle_stats(&self) -> StatsResponse {
        self.engine.stats().into()
    }

    /// Parse one wire line, dispatch it, serialize the reply, and
    /// record the request into the per-op metrics.
    ///
    /// Version routing: a line with `"v":2` takes the v2 envelope path;
    /// any other `"v"` value gets a structured `unsupported_version`
    /// error; a line with no `"v"` field is a v1 request and flows
    /// through the original code path **bit-identically** (pinned by the
    /// golden suite and the CI service smoke).
    pub fn handle_line(&self, line: &str) -> String {
        let start = Instant::now();
        let out = self.route_line(line);
        self.engine.metrics().record(out.op, out.error.is_none(), start.elapsed());
        out.reply
    }

    /// Route an HTTP request body: the same version routing as
    /// [`Self::handle_line`] — a v1 body still gets the v1 reply shape —
    /// except that unparseable bodies answer in the structured v2 error
    /// shape (over HTTP there is no bit-identical v1 contract to
    /// preserve for garbage, and the transport needs a code to map to a
    /// status). Records metrics; the returned outcome carries the error
    /// code for status mapping.
    pub fn dispatch_http(&self, body: &str) -> DispatchOutcome {
        let start = Instant::now();
        let out = match json::parse(body) {
            Ok(v) => self.route_value(&v),
            Err(e) => DispatchOutcome::err(
                v2_error_json("bad_request", &format!("bad request: {e}")),
                OpKind::Other,
                "bad_request",
            ),
        };
        self.engine.metrics().record(out.op, out.error.is_none(), start.elapsed());
        out
    }

    /// Dispatch one parsed v2 envelope and serialize the reply.
    /// (Metrics are recorded by the line/body entry points, not here.)
    pub fn handle_v2(&self, v: &Json) -> String {
        self.route_v2(v).reply
    }

    /// One parse per line: the version sniff and the v1 dispatch share
    /// the same value.
    fn route_line(&self, line: &str) -> DispatchOutcome {
        match json::parse(line) {
            Ok(v) => self.route_value(&v),
            // v1 contract: malformed lines answer in the v1 error shape.
            Err(e) => DispatchOutcome::err(
                error_json(&format!("bad request: {e}")),
                OpKind::Other,
                "bad_request",
            ),
        }
    }

    fn route_value(&self, v: &Json) -> DispatchOutcome {
        match v.get("v") {
            Some(Json::Num(n)) if *n == PROTOCOL_V2 => self.route_v2(v),
            Some(other) => DispatchOutcome::err(
                v2_error_json(
                    "unsupported_version",
                    &format!("unsupported protocol version {}", other.dump()),
                ),
                OpKind::Other,
                "unsupported_version",
            ),
            None => self.route_v1(v),
        }
    }

    fn route_v1(&self, v: &Json) -> DispatchOutcome {
        match Request::from_value(v) {
            Ok(Request::Predict(req)) => match self.handle(&req) {
                Ok(resp) => DispatchOutcome::ok(resp.to_json(), OpKind::Predict),
                Err(e) => DispatchOutcome::err(
                    error_json(&e.to_string()),
                    OpKind::Predict,
                    Self::classify_v1(&e),
                ),
            },
            Ok(Request::Rank(req)) => match self.handle_rank(&req) {
                Ok(resp) => DispatchOutcome::ok(resp.to_json(), OpKind::Rank),
                Err(e) => DispatchOutcome::err(
                    error_json(&e.to_string()),
                    OpKind::Rank,
                    Self::classify_v1(&e),
                ),
            },
            Ok(Request::Stats) => DispatchOutcome::ok(self.handle_stats().to_json(), OpKind::Stats),
            Err(e) => DispatchOutcome::err(
                error_json(&format!("bad request: {e}")),
                OpKind::Other,
                "bad_request",
            ),
        }
    }

    fn route_v2(&self, v: &Json) -> DispatchOutcome {
        let (op, result) = self.dispatch_v2(v);
        match result {
            Ok(reply) => DispatchOutcome::ok(reply.dump(), op),
            Err(e) => DispatchOutcome::err(v2_error_json(e.code, &e.message), op, e.code),
        }
    }

    fn dispatch_v2(&self, v: &Json) -> (OpKind, V2Result) {
        let op = match v.req_str("op") {
            Ok(op) => op,
            Err(_) => {
                return (
                    OpKind::Other,
                    Err(V2Error::new("bad_request", "missing string field \"op\"")),
                )
            }
        };
        match op {
            "predict" => (OpKind::Predict, self.v2_predict(v)),
            "rank" => (OpKind::Rank, self.v2_rank(v)),
            "rank_many" => (OpKind::RankMany, self.v2_rank_many(v)),
            "stats" => (OpKind::Stats, Ok(self.v2_stats())),
            "submit_trace" => (OpKind::SubmitTrace, self.v2_submit_trace(v)),
            "register_device" => (OpKind::RegisterDevice, self.v2_register_device(v)),
            "predict_cluster" => (OpKind::PredictCluster, self.v2_predict_cluster(v)),
            "rank_cluster" => (OpKind::RankCluster, self.v2_rank_cluster(v)),
            "export_workload" => (OpKind::ExportWorkload, self.v2_export_workload(v)),
            other => (
                OpKind::Other,
                Err(V2Error::new(
                    "unsupported_op",
                    format!("unsupported op {other:?} (want predict|rank|rank_many|stats|submit_trace|register_device|predict_cluster|rank_cluster|export_workload)"),
                )),
            ),
        }
    }

    fn v2_precision(v: &Json) -> std::result::Result<Precision, V2Error> {
        parse_precision(v.get("precision").and_then(Json::as_str))
            .map_err(|e| V2Error::new("invalid_argument", e.to_string()))
    }

    fn v2_dest(v: &Json) -> std::result::Result<Device, V2Error> {
        let name = v
            .req_str("dest")
            .map_err(|_| V2Error::new("bad_request", "missing string field \"dest\""))?;
        parse_device(name, "destination").map_err(|e| V2Error::new("unknown_device", e.to_string()))
    }

    fn v2_predict(&self, v: &Json) -> V2Result {
        let precision = Self::v2_precision(v)?;
        let dest = Self::v2_dest(v)?;
        if let Some(trace_id) = v.get("trace_id").and_then(Json::as_str) {
            let out = self
                .engine
                .predict_uploaded(trace_id, dest, precision)
                .map_err(|e| V2Error::new(classify_engine_error(&e), e.to_string()))?;
            let resp = Self::prediction_response(&out);
            Ok(v2_envelope(
                "predict",
                resp.to_value(),
                vec![("trace_id", Json::Str(trace_id.to_string()))],
            ))
        } else {
            let req = PredictionRequest::from_value(v)
                .map_err(|e| V2Error::new("bad_request", e.to_string()))?;
            let resp = self
                .handle(&req)
                .map_err(|e| V2Error::new(Self::classify_v1(&e), e.to_string()))?;
            Ok(v2_envelope("predict", resp.to_value(), Vec::new()))
        }
    }

    fn v2_rank(&self, v: &Json) -> V2Result {
        if let Some(trace_id) = v.get("trace_id").and_then(Json::as_str) {
            let precision = Self::v2_precision(v)?;
            let dests = Self::v2_dests(v)?;
            let ranking = self
                .engine
                .rank_uploaded(trace_id, &dests, precision)
                .map_err(|e| V2Error::new(classify_engine_error(&e), e.to_string()))?;
            let resp = Self::rank_response(&ranking);
            Ok(v2_envelope(
                "rank",
                resp.to_value(),
                vec![("trace_id", Json::Str(trace_id.to_string()))],
            ))
        } else {
            let req = RankRequest::from_value(v)
                .map_err(|e| V2Error::new("bad_request", e.to_string()))?;
            let resp = self
                .handle_rank(&req)
                .map_err(|e| V2Error::new(Self::classify_v1(&e), e.to_string()))?;
            Ok(v2_envelope("rank", resp.to_value(), Vec::new()))
        }
    }

    /// `rank_many`: several `(model, batch, origin)` items ranked over
    /// one shared destination set, served by a single work-claimed
    /// multi-trace sweep ([`PredictionEngine::rank_many`]). The
    /// `items × dests` product is capped like the cluster sweeps.
    fn v2_rank_many(&self, v: &Json) -> V2Result {
        let precision = Self::v2_precision(v)?;
        let dests = Self::v2_dests(v)?;
        let items_v = v
            .get("items")
            .and_then(Json::as_arr)
            .ok_or_else(|| V2Error::new("bad_request", "missing array field \"items\""))?;
        if items_v.is_empty() {
            return Err(V2Error::new("invalid_argument", "items must be non-empty"));
        }
        Self::check_sweep(items_v.len().saturating_mul(dests.len()))?;
        let mut items = Vec::with_capacity(items_v.len());
        for it in items_v {
            let (model, batch, origin) = Self::v2_model_origin(it)?;
            items.push(crate::engine::RankManyItem { model, batch, origin });
        }
        let rankings = self
            .engine
            .rank_many(&items, &dests, precision)
            .map_err(|e| V2Error::new(classify_engine_error(&e), e.to_string()))?;
        let results: Vec<Json> =
            rankings.iter().map(|r| Self::rank_response(r).to_value()).collect();
        Ok(v2_envelope(
            "rank_many",
            Json::obj(vec![
                ("count", Json::Num(results.len() as f64)),
                ("results", Json::Arr(results)),
            ]),
            Vec::new(),
        ))
    }

    fn v2_stats(&self) -> Json {
        let s = self.engine.stats();
        v2_envelope(
            "stats",
            StatsResponse::from(s).to_value(),
            vec![
                ("trace_uploads", Json::Num(s.trace_uploads as f64)),
                ("uploaded_entries", Json::Num(s.uploaded_entries as f64)),
                ("devices", Json::Num(s.devices as f64)),
                ("store_hits", Json::Num(s.store_hits as f64)),
                ("store_misses", Json::Num(s.store_misses as f64)),
                ("warm_restores", Json::Num(s.warm_restores as f64)),
                (
                    "parallel_build_chunks",
                    Json::Num(s.parallel_build_chunks as f64),
                ),
                // Which evaluation backend the sweeps run on ("avx2" or
                // "scalar") — bit-identical either way.
                ("simd", Json::Str(s.simd.to_string())),
                // Dispatcher-level wire counters (0 until a transport
                // routes through this dispatcher). A stats reply counts
                // itself only after it is serialized, so these reflect
                // the totals *before* the request carrying them.
                ("requests", Json::Num(s.requests as f64)),
                ("request_errors", Json::Num(s.request_errors as f64)),
            ],
        )
    }

    fn v2_submit_trace(&self, v: &Json) -> V2Result {
        let tv = v
            .get("trace")
            .ok_or_else(|| V2Error::new("bad_request", "missing object field \"trace\""))?;
        let trace = Trace::from_value(tv)
            .map_err(|e| V2Error::new("invalid_argument", format!("bad trace: {e}")))?;
        let (trace_id, analyzed) = self
            .engine
            .submit_trace(trace)
            .map_err(|e| V2Error::new("invalid_argument", e.to_string()))?;
        Ok(v2_envelope(
            "submit_trace",
            Json::obj(vec![
                ("trace_id", Json::Str(trace_id)),
                ("model", Json::Str(analyzed.trace.model.clone())),
                ("batch", Json::Num(analyzed.trace.batch_size as f64)),
                ("origin", Json::Str(analyzed.trace.origin.id().to_string())),
                ("ops", Json::Num(analyzed.trace.ops.len() as f64)),
                ("origin_iter_ms", Json::Num(analyzed.trace.run_time_ms())),
            ]),
            Vec::new(),
        ))
    }

    fn v2_register_device(&self, v: &Json) -> V2Result {
        let desc = new_device_from_value(v)?;
        // Through the engine, not the bare registry: a genuinely new
        // device gets its lane appended to every cached plan once and
        // is logged to the persistent store's device log.
        let d = self.engine.register_device(&desc).map_err(|e| match e {
            RegisterError::Conflict(m) => V2Error::new("conflict", m),
            RegisterError::Invalid(m) => V2Error::new("invalid_argument", m),
        })?;
        let s = d.spec();
        Ok(v2_envelope(
            "register_device",
            Json::obj(vec![
                ("device", Json::Str(s.name.to_string())),
                ("id", Json::Num(d.index() as f64)),
                ("arch", Json::Str(s.arch.to_string())),
                ("sms", Json::Num(s.sms as f64)),
                ("mem_gib", Json::Num(s.mem_gib)),
                ("peak_mem_bw_gbps", Json::Num(s.peak_mem_bw_gbps)),
                ("achieved_mem_bw_gbps", Json::Num(s.achieved_mem_bw_gbps)),
                ("clock_mhz", Json::Num(s.boost_clock_mhz)),
                ("fp32_tflops", Json::Num(s.peak_fp32_tflops)),
                ("fp16_tflops", Json::Num(s.peak_fp16_tflops)),
                ("usd_per_hr", s.rental_usd_per_hr.map_or(Json::Null, Json::Num)),
                ("devices", Json::Num(registry::device_count() as f64)),
            ]),
            Vec::new(),
        ))
    }

    // --- cluster ops --------------------------------------------------

    fn v2_predict_cluster(&self, v: &Json) -> V2Result {
        let precision = Self::v2_precision(v)?;
        let dest = Self::v2_dest(v)?;
        let topologies = Self::v2_topologies(v)?;
        let worlds = Self::v2_worlds(v)?;
        let params = Self::v2_cluster_params(v)?;
        Self::check_sweep(topologies.len().saturating_mul(worlds.len()))?;
        if let Some(trace_id) = v.get("trace_id").and_then(Json::as_str) {
            let report = self
                .engine
                .predict_cluster_uploaded(trace_id, dest, precision, &topologies, &worlds, &params)
                .map_err(|e| V2Error::new(classify_engine_error(&e), e.to_string()))?;
            Ok(v2_envelope(
                "predict_cluster",
                Self::cluster_response(&report).to_value(),
                vec![("trace_id", Json::Str(trace_id.to_string()))],
            ))
        } else {
            let (model, batch, origin) = Self::v2_model_origin(v)?;
            let report = self
                .engine
                .predict_cluster(&model, batch, origin, dest, precision, &topologies, &worlds, &params)
                .map_err(|e| V2Error::new(classify_engine_error(&e), e.to_string()))?;
            Ok(v2_envelope("predict_cluster", Self::cluster_response(&report).to_value(), Vec::new()))
        }
    }

    fn v2_rank_cluster(&self, v: &Json) -> V2Result {
        let precision = Self::v2_precision(v)?;
        let dests = Self::v2_dests(v)?;
        let topologies = Self::v2_topologies(v)?;
        let worlds = Self::v2_worlds(v)?;
        let params = Self::v2_cluster_params(v)?;
        Self::check_sweep(
            dests
                .len()
                .saturating_mul(topologies.len())
                .saturating_mul(worlds.len()),
        )?;
        if let Some(trace_id) = v.get("trace_id").and_then(Json::as_str) {
            let ranking = self
                .engine
                .rank_cluster_uploaded(trace_id, &dests, precision, &topologies, &worlds, &params)
                .map_err(|e| V2Error::new(classify_engine_error(&e), e.to_string()))?;
            Ok(v2_envelope(
                "rank_cluster",
                Self::cluster_rank_response(&ranking).to_value(),
                vec![("trace_id", Json::Str(trace_id.to_string()))],
            ))
        } else {
            let (model, batch, origin) = Self::v2_model_origin(v)?;
            let ranking = self
                .engine
                .rank_cluster(&model, batch, origin, &dests, precision, &topologies, &worlds, &params)
                .map_err(|e| V2Error::new(classify_engine_error(&e), e.to_string()))?;
            Ok(v2_envelope("rank_cluster", Self::cluster_rank_response(&ranking).to_value(), Vec::new()))
        }
    }

    fn v2_export_workload(&self, v: &Json) -> V2Result {
        let precision = Self::v2_precision(v)?;
        let dest = Self::v2_dest(v)?;
        let topology = match v.get("topology") {
            None | Some(Json::Null) => {
                return Err(V2Error::new("bad_request", "missing field \"topology\""))
            }
            Some(it) => Self::v2_topology_entry(it)?,
        };
        let world = v
            .req_usize("world")
            .map_err(|e| V2Error::new("bad_request", e.to_string()))?;
        if !(1..=MAX_CLUSTER_WORLD).contains(&world) {
            return Err(V2Error::new(
                "invalid_argument",
                format!("world size {world} out of range 1..={MAX_CLUSTER_WORLD}"),
            ));
        }
        let params = Self::v2_cluster_params(v)?;
        let (model, batch, origin) = Self::v2_model_origin(v)?;
        let workload = self
            .engine
            .export_workload(&model, batch, origin, dest, precision, topology, world, &params)
            .map_err(|e| V2Error::new(classify_engine_error(&e), e.to_string()))?;
        Ok(v2_envelope("export_workload", workload.to_value(), Vec::new()))
    }

    /// Common `model`/`batch`/`origin` triple of the zoo-model paths.
    fn v2_model_origin(v: &Json) -> std::result::Result<(String, usize, Device), V2Error> {
        let model = v
            .req_str("model")
            .map_err(|e| V2Error::new("bad_request", e.to_string()))?
            .to_string();
        let batch = v
            .req_usize("batch")
            .map_err(|e| V2Error::new("bad_request", e.to_string()))?;
        let origin_name = v
            .req_str("origin")
            .map_err(|e| V2Error::new("bad_request", e.to_string()))?;
        let origin = parse_device(origin_name, "origin")
            .map_err(|e| V2Error::new("unknown_device", e.to_string()))?;
        Ok((model, batch, origin))
    }

    /// Resolve a v2 `topologies` field: names and/or inline topology
    /// objects, or every registered topology when absent.
    fn v2_topologies(v: &Json) -> std::result::Result<Vec<Topology>, V2Error> {
        match v.get("topologies") {
            None | Some(Json::Null) => Ok(comm::topology::all_topologies()),
            Some(arr) => {
                let items = arr.as_arr().ok_or_else(|| {
                    V2Error::new("bad_request", "topologies must be an array of names or objects")
                })?;
                if items.is_empty() {
                    return Err(V2Error::new("invalid_argument", "topologies must be non-empty"));
                }
                items.iter().map(Self::v2_topology_entry).collect()
            }
        }
    }

    /// One topology entry: a registered name, or an inline
    /// `{"name","gpus_per_node","intra","inter"}` object (registered
    /// through the interning registry, idempotently).
    fn v2_topology_entry(it: &Json) -> std::result::Result<Topology, V2Error> {
        match it {
            Json::Str(name) => comm::topology::find_topology(name).ok_or_else(|| {
                V2Error::new(
                    "unknown_topology",
                    format!(
                        "unknown topology {name:?} (known: {})",
                        comm::topology::topology_names().join("|")
                    ),
                )
            }),
            Json::Obj(_) => {
                let name = it
                    .req_str("name")
                    .map_err(|_| V2Error::new("bad_request", "inline topology needs string field \"name\""))?;
                let gpus_per_node = it.req_usize("gpus_per_node").map_err(|_| {
                    V2Error::new("bad_request", "inline topology needs integer field \"gpus_per_node\"")
                })?;
                let intra = Self::v2_link(it.get("intra"), "intra")?;
                let inter = Self::v2_link(it.get("inter"), "inter")?;
                comm::topology::register_topology(&comm::NewTopology {
                    name: name.to_string(),
                    gpus_per_node: gpus_per_node as u32,
                    intra,
                    inter,
                })
                .map_err(Self::register_error)
            }
            _ => Err(V2Error::new(
                "bad_request",
                "topologies entries must be topology names or inline objects",
            )),
        }
    }

    /// One link field of an inline topology: a registered name, or an
    /// inline `{"name","bandwidth_gbps","step_latency_ms"?}` object.
    fn v2_link(it: Option<&Json>, role: &str) -> std::result::Result<comm::Link, V2Error> {
        let it = it.ok_or_else(|| {
            V2Error::new("bad_request", format!("inline topology needs field {role:?}"))
        })?;
        match it {
            Json::Str(name) => comm::find_link(name).ok_or_else(|| {
                V2Error::new(
                    "unknown_link",
                    format!(
                        "unknown {role} link {name:?} (known: {})",
                        comm::link_names().join("|")
                    ),
                )
            }),
            Json::Obj(_) => {
                let name = it.req_str("name").map_err(|_| {
                    V2Error::new("bad_request", format!("inline {role} link needs string field \"name\""))
                })?;
                let bandwidth_gbps = it.get("bandwidth_gbps").and_then(Json::as_f64).ok_or_else(|| {
                    V2Error::new(
                        "bad_request",
                        format!("inline {role} link needs number field \"bandwidth_gbps\""),
                    )
                })?;
                let step_latency_ms =
                    it.get("step_latency_ms").and_then(Json::as_f64).unwrap_or(0.01);
                comm::register_link(&comm::NewLink {
                    name: name.to_string(),
                    bandwidth_gbps,
                    step_latency_ms,
                })
                .map_err(Self::register_error)
            }
            _ => Err(V2Error::new(
                "bad_request",
                format!("{role} link must be a link name or an inline object"),
            )),
        }
    }

    /// Resolve a v2 `worlds` field ([`DEFAULT_CLUSTER_WORLDS`] when
    /// absent).
    fn v2_worlds(v: &Json) -> std::result::Result<Vec<usize>, V2Error> {
        match v.get("worlds") {
            None | Some(Json::Null) => Ok(DEFAULT_CLUSTER_WORLDS.to_vec()),
            Some(arr) => {
                let items = arr.as_arr().ok_or_else(|| {
                    V2Error::new("bad_request", "worlds must be an array of rank counts")
                })?;
                if items.is_empty() {
                    return Err(V2Error::new("invalid_argument", "worlds must be non-empty"));
                }
                items
                    .iter()
                    .map(|it| {
                        let w = it.as_usize().ok_or_else(|| {
                            V2Error::new("bad_request", "worlds entries must be non-negative integers")
                        })?;
                        if !(1..=MAX_CLUSTER_WORLD).contains(&w) {
                            return Err(V2Error::new(
                                "invalid_argument",
                                format!("world size {w} out of range 1..={MAX_CLUSTER_WORLD}"),
                            ));
                        }
                        Ok(w)
                    })
                    .collect()
            }
        }
    }

    /// Optional overlap/bucket knobs → [`ClusterParams`].
    fn v2_cluster_params(v: &Json) -> std::result::Result<ClusterParams, V2Error> {
        let mut params = ClusterParams::default();
        if let Some(x) = v.get("overlap") {
            params.overlap = x
                .as_f64()
                .filter(|o| (0.0..=1.0).contains(o))
                .ok_or_else(|| V2Error::new("invalid_argument", "overlap must be a number in 0..=1"))?;
        }
        if let Some(x) = v.get("bucket_mib") {
            let mib = x
                .as_f64()
                .filter(|b| b.is_finite() && *b >= 0.0)
                .ok_or_else(|| {
                    V2Error::new("invalid_argument", "bucket_mib must be a non-negative number")
                })?;
            params.bucket_bytes = mib * 1024.0 * 1024.0;
        }
        Ok(params)
    }

    fn check_sweep(cells: usize) -> std::result::Result<(), V2Error> {
        if cells > MAX_CLUSTER_SWEEP {
            return Err(V2Error::new(
                "invalid_argument",
                format!("cluster sweep of {cells} configurations exceeds the {MAX_CLUSTER_SWEEP} limit"),
            ));
        }
        Ok(())
    }

    fn register_error(e: RegisterError) -> V2Error {
        match e {
            RegisterError::Conflict(m) => V2Error::new("conflict", m),
            RegisterError::Invalid(m) => V2Error::new("invalid_argument", m),
        }
    }

    fn cluster_response(report: &crate::engine::ClusterReport) -> ClusterResponse {
        ClusterResponse {
            model: report.trace.model.clone(),
            batch: report.trace.batch_size,
            origin: report.trace.origin.id().to_string(),
            dest: report.dest.id().to_string(),
            compute_ms: report.compute_ms,
            configs: report
                .configs
                .iter()
                .map(|c| ClusterConfig {
                    topology: c.topology.name().to_string(),
                    world: c.world,
                    iter_ms: c.pred.iter_ms,
                    comm_ms: c.pred.comm_ms,
                    exposed_ms: c.pred.exposed_ms,
                    throughput: c.pred.throughput,
                    efficiency: c.pred.efficiency,
                    cost_normalized_throughput: c.cost_normalized_throughput,
                })
                .collect(),
        }
    }

    fn cluster_rank_response(ranking: &crate::engine::ClusterRanking) -> ClusterRankResponse {
        ClusterRankResponse {
            model: ranking.trace.model.clone(),
            batch: ranking.trace.batch_size,
            origin: ranking.trace.origin.id().to_string(),
            ranking: ranking
                .entries
                .iter()
                .map(|e| ClusterRankedConfig {
                    dest: e.dest.id().to_string(),
                    topology: e.topology.name().to_string(),
                    world: e.world,
                    iter_ms: e.pred.iter_ms,
                    throughput: e.pred.throughput,
                    efficiency: e.pred.efficiency,
                    cost_normalized_throughput: e.cost_normalized_throughput,
                })
                .collect(),
        }
    }

    /// Resolve a v2 `dests` field: explicit names, or the full registry.
    fn v2_dests(v: &Json) -> std::result::Result<Vec<Device>, V2Error> {
        match v.get("dests") {
            None | Some(Json::Null) => Ok(registry::all_devices()),
            Some(arr) => {
                let items = arr
                    .as_arr()
                    .ok_or_else(|| V2Error::new("bad_request", "dests must be an array of device names"))?;
                items
                    .iter()
                    .map(|it| {
                        let name = it
                            .as_str()
                            .ok_or_else(|| V2Error::new("bad_request", "dests entries must be strings"))?;
                        parse_device(name, "destination")
                            .map_err(|e| V2Error::new("unknown_device", e.to_string()))
                    })
                    .collect()
            }
        }
    }

    /// v1 handler errors carry no code; classify from the message.
    fn classify_v1(e: &anyhow::Error) -> &'static str {
        let msg = e.to_string();
        if msg.contains("unknown model") {
            "unknown_model"
        } else if msg.contains("unknown origin device") || msg.contains("unknown destination device") {
            "unknown_device"
        } else {
            "invalid_argument"
        }
    }

    /// Decision-ready response fields from an engine prediction (the
    /// uploaded-trace path, where there is no request echo to copy).
    fn prediction_response(out: &crate::engine::EnginePrediction) -> PredictionResponse {
        let pred = &out.pred;
        let tput = pred.throughput();
        PredictionResponse {
            model: pred.model.clone(),
            batch: pred.batch_size,
            origin: pred.origin.id().to_string(),
            dest: pred.dest.id().to_string(),
            origin_iter_ms: out.trace.run_time_ms(),
            iter_ms: pred.run_time_ms(),
            throughput: tput,
            cost_normalized_throughput: crate::cost::cost_normalized_throughput(pred.dest, tput),
            mlp_time_fraction: pred.mlp_time_fraction(),
            mlp_fallbacks: pred.mlp_fallbacks,
        }
    }

    fn rank_response(ranking: &crate::engine::Ranking) -> RankResponse {
        RankResponse {
            model: ranking.trace.model.clone(),
            batch: ranking.trace.batch_size,
            origin: ranking.trace.origin.id().to_string(),
            origin_iter_ms: ranking.trace.run_time_ms(),
            ranking: ranking
                .entries
                .iter()
                .map(|e| RankedDest {
                    dest: e.dest.id().to_string(),
                    iter_ms: e.pred.run_time_ms(),
                    throughput: e.pred.throughput(),
                    cost_normalized_throughput: e.cost_normalized_throughput,
                    mlp_time_fraction: e.pred.mlp_time_fraction(),
                    mlp_fallbacks: e.pred.mlp_fallbacks,
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::{
        stats_request_json, v2_check_error, v2_export_workload_request, v2_predict_cluster_request,
        v2_predict_model_request, v2_predict_trace_request, v2_rank_cluster_request,
        v2_rank_many_request, v2_rank_trace_request, v2_stats_request, v2_submit_trace_request,
        RankManyResponse, RegisteredDevice,
    };
    use crate::device::ALL_DEVICES;

    fn wave_service() -> PredictionService {
        PredictionService::with_predictor(HybridPredictor::wave_only())
    }

    fn req(model: &str, batch: usize, origin: &str, dest: &str) -> PredictionRequest {
        PredictionRequest {
            model: model.into(),
            batch,
            origin: origin.into(),
            dest: dest.into(),
            precision: None,
        }
    }

    fn rank_req(model: &str, batch: usize, origin: &str) -> RankRequest {
        RankRequest {
            model: model.into(),
            batch,
            origin: origin.into(),
            precision: None,
            dests: None,
        }
    }

    #[test]
    fn handles_basic_request() {
        let s = wave_service();
        let r = s.handle(&req("mlp", 32, "t4", "v100")).unwrap();
        assert!(r.iter_ms > 0.0);
        assert!(r.throughput > 0.0);
        assert!(r.cost_normalized_throughput.is_some());
        assert_eq!(r.dest, "V100");
    }

    #[test]
    fn rejects_unknown_inputs() {
        let s = wave_service();
        assert!(s.handle(&req("nope", 32, "t4", "v100")).is_err());
        assert!(s.handle(&req("mlp", 32, "a100", "v100")).is_err());
        assert!(s.handle(&req("mlp", 0, "t4", "v100")).is_err());
        let mut r = req("mlp", 8, "t4", "v100");
        r.precision = Some("fp64".into());
        assert!(s.handle(&r).is_err());
    }

    #[test]
    fn request_response_json_roundtrip() {
        let r = req("gnmt", 64, "p4000", "t4");
        let parsed = PredictionRequest::from_json(&r.to_json()).unwrap();
        assert_eq!(parsed.model, "gnmt");
        assert_eq!(parsed.batch, 64);

        let resp = wave_service().handle(&r).unwrap();
        let parsed = PredictionResponse::from_json(&resp.to_json()).unwrap();
        assert!((parsed.iter_ms - resp.iter_ms).abs() < 1e-9);
        assert_eq!(
            parsed.cost_normalized_throughput.is_some(),
            resp.cost_normalized_throughput.is_some()
        );
    }

    #[test]
    fn rank_response_json_roundtrip() {
        let s = wave_service();
        let resp = s.handle_rank(&rank_req("mlp", 32, "t4")).unwrap();
        let parsed = RankResponse::from_json(&resp.to_json()).unwrap();
        assert_eq!(parsed.ranking.len(), resp.ranking.len());
        for (a, b) in parsed.ranking.iter().zip(&resp.ranking) {
            assert_eq!(a.dest, b.dest);
            assert!((a.iter_ms - b.iter_ms).abs() < 1e-9);
            assert_eq!(
                a.cost_normalized_throughput.is_some(),
                b.cost_normalized_throughput.is_some()
            );
        }
    }

    #[test]
    fn rank_matches_individual_requests_with_one_tracking_pass() {
        // A default rank equals N individual requests, with exactly one
        // run of the tracking pipeline. (The default destination set is
        // the whole registry — at least the six built-ins, plus any
        // devices other concurrently running tests have registered.)
        let s = wave_service();
        let ranking = s.handle_rank(&rank_req("mlp", 16, "t4")).unwrap();
        assert!(ranking.ranking.len() >= ALL_DEVICES.len());
        for d in ALL_DEVICES {
            assert!(
                ranking.ranking.iter().any(|r| r.dest == d.id()),
                "built-in {d} missing from the default rank"
            );
        }
        let stats = s.engine().stats();
        assert_eq!(stats.trace_misses, 1, "rank must track exactly once");
        assert_eq!(stats.trace_hits, 0);

        for entry in &ranking.ranking {
            let resp = s.handle(&req("mlp", 16, "t4", &entry.dest)).unwrap();
            assert!(
                (resp.iter_ms - entry.iter_ms).abs() < 1e-9,
                "{}: rank {} vs individual {}",
                entry.dest,
                entry.iter_ms,
                resp.iter_ms
            );
        }
        let stats = s.engine().stats();
        assert_eq!(stats.trace_misses, 1, "individual requests must reuse the trace");
        assert_eq!(stats.trace_hits as usize, ranking.ranking.len());
    }

    #[test]
    fn rank_is_sorted_by_cost_normalized_throughput() {
        let s = wave_service();
        let resp = s.handle_rank(&rank_req("mlp", 32, "p4000")).unwrap();
        let priced: Vec<f64> = resp
            .ranking
            .iter()
            .filter_map(|r| r.cost_normalized_throughput)
            .collect();
        assert!(!priced.is_empty());
        for w in priced.windows(2) {
            assert!(w[0] >= w[1], "priced devices must be in descending order");
        }
        // Priced devices all come before unpriced ones.
        let first_unpriced = resp
            .ranking
            .iter()
            .position(|r| r.cost_normalized_throughput.is_none())
            .unwrap_or(resp.ranking.len());
        assert!(resp.ranking[first_unpriced..]
            .iter()
            .all(|r| r.cost_normalized_throughput.is_none()));
    }

    #[test]
    fn rank_with_explicit_dests_and_errors() {
        let s = wave_service();
        let mut r = rank_req("mlp", 16, "t4");
        r.dests = Some(vec!["v100".into(), "p100".into()]);
        let resp = s.handle_rank(&r).unwrap();
        assert_eq!(resp.ranking.len(), 2);

        let mut bad = rank_req("mlp", 16, "t4");
        bad.dests = Some(vec!["a100".into()]);
        assert!(s.handle_rank(&bad).is_err());
        assert!(s.handle_rank(&rank_req("nope", 16, "t4")).is_err());
        assert!(s.handle_rank(&rank_req("mlp", 0, "t4")).is_err());
    }

    #[test]
    fn handle_line_dispatches_and_reports_errors() {
        let s = wave_service();
        let ok = s.handle_line("{\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\"}");
        assert!(PredictionResponse::from_json(&ok).is_ok());
        let rank = s.handle_line("{\"rank\":true,\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\"}");
        assert!(RankResponse::from_json(&rank).is_ok());
        let bad = s.handle_line("not json");
        assert!(bad.contains("bad request"));
        let unknown = s.handle_line("{\"model\":\"mlp\",\"batch\":8,\"origin\":\"a100\",\"dest\":\"v100\"}");
        assert!(unknown.contains("error"));
    }

    #[test]
    fn stats_request_reflects_engine_counters() {
        let s = wave_service();
        let cold = s.handle_stats();
        assert_eq!(cold.trace_hits, 0);
        assert_eq!(cold.trace_misses, 0);
        assert!(cold.workers >= 1);

        s.handle(&req("mlp", 8, "t4", "v100")).unwrap();
        s.handle(&req("mlp", 8, "t4", "p100")).unwrap();
        let warm = s.handle_stats();
        assert_eq!(warm.trace_misses, 1);
        assert_eq!(warm.trace_hits, 1);
        assert_eq!(warm.trace_entries, 1);
        assert_eq!(warm.plan_builds, 1);
    }

    #[test]
    fn stats_line_dispatches_and_roundtrips() {
        let s = wave_service();
        s.handle(&req("mlp", 8, "t4", "v100")).unwrap();
        let line = stats_request_json();
        assert!(matches!(Request::from_json(&line).unwrap(), Request::Stats));
        let reply = s.handle_line(&line);
        let parsed = StatsResponse::from_json(&reply).unwrap();
        assert_eq!(parsed.trace_misses, 1);
        assert_eq!(parsed.workers, s.engine().workers());
    }

    #[test]
    fn trace_cache_hits() {
        let s = wave_service();
        let a = s.trace_for("mlp", 16, Device::T4).unwrap();
        let b = s.trace_for("mlp", 16, Device::T4).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second lookup must hit the cache");
    }

    #[test]
    fn amp_prediction_not_slower_than_fp32() {
        let s = wave_service();
        let fp32 = s.handle(&req("mlp", 32, "p4000", "2080ti")).unwrap();
        let mut amp_req = req("mlp", 32, "p4000", "2080ti");
        amp_req.precision = Some("amp".into());
        let amp = s.handle(&amp_req).unwrap();
        assert!(amp.iter_ms <= fp32.iter_ms);
    }

    #[test]
    fn handle_line_records_per_op_metrics() {
        let s = wave_service();
        s.handle_line("{\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\"}");
        s.handle_line("{\"stats\":true}");
        s.handle_line("not json");
        s.handle_line("{\"v\":2,\"op\":\"predict\",\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"a100\"}");

        let m = s.engine().metrics();
        let predict = m.snapshot(OpKind::Predict);
        // One v1 success, one v2 unknown-device failure.
        assert_eq!(predict.requests, 2);
        assert_eq!(predict.errors, 1);
        assert_eq!(predict.buckets.iter().sum::<u64>(), 2);
        assert!(predict.latency_ms_sum > 0.0);
        assert_eq!(m.snapshot(OpKind::Stats).requests, 1);
        let other = m.snapshot(OpKind::Other);
        assert_eq!(other.requests, 1);
        assert_eq!(other.errors, 1);

        // The totals surface through EngineStats (and so through the
        // v2 stats op).
        let es = s.engine().stats();
        assert_eq!(es.requests, 4);
        assert_eq!(es.request_errors, 2);
        let reply = s.handle_line(&v2_stats_request());
        let v = json::parse(&reply).unwrap();
        assert_eq!(v.req_usize("requests").unwrap(), 4);
        assert_eq!(v.req_usize("request_errors").unwrap(), 2);
    }

    #[test]
    fn dispatch_http_shapes_parse_errors_structurally() {
        let s = wave_service();
        // Garbage answers in the structured v2 shape (the transport
        // needs a code), unlike the TCP line path's v1 contract.
        let out = s.dispatch_http("not json");
        assert_eq!(out.error, Some("bad_request"));
        let v = json::parse(&out.reply).unwrap();
        assert_eq!(v.get("v"), Some(&Json::Num(PROTOCOL_V2)));
        assert_eq!(
            v.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
            Some("bad_request")
        );

        // Well-formed v1 bodies keep their v1 reply shape…
        let out = s.dispatch_http("{\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\"}");
        assert!(out.error.is_none());
        assert_eq!(out.op, OpKind::Predict);
        assert!(PredictionResponse::from_json(&out.reply).is_ok());

        // …including v1-shaped errors, classified for status mapping.
        let out = s.dispatch_http("{\"model\":\"mlp\",\"batch\":8,\"origin\":\"a100\",\"dest\":\"v100\"}");
        assert_eq!(out.error, Some("unknown_device"));
        assert!(out.reply.contains("unknown origin device"));

        // v2 bodies flow the envelope path, same as TCP.
        let out = s.dispatch_http(&v2_predict_model_request("mlp", 8, "t4", "v100", None));
        assert!(out.error.is_none());
        let tcp = s.handle_line(&v2_predict_model_request("mlp", 8, "t4", "v100", None));
        assert_eq!(out.reply, tcp);
    }

    #[test]
    fn v2_predict_payload_matches_v1_bit_for_bit() {
        let s = wave_service();
        let v1_line = "{\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\"}";
        let v1 = s.handle_line(v1_line);
        let v2 = s.handle_line(&v2_predict_model_request("mlp", 8, "t4", "v100", None));
        let v1_parsed = json::parse(&v1).unwrap();
        let v2_parsed = json::parse(&v2).unwrap();
        assert_eq!(v2_parsed.get("v"), Some(&Json::Num(2.0)));
        assert_eq!(v2_parsed.req_str("op").unwrap(), "predict");
        // Every v1 field appears identically in the v2 payload.
        if let Json::Obj(m) = &v1_parsed {
            for (k, val) in m {
                assert_eq!(v2_parsed.get(k), Some(val), "field {k}");
            }
        } else {
            panic!("v1 reply is not an object");
        }
    }

    #[test]
    fn v2_envelope_dispatches_rank_and_stats() {
        let s = wave_service();
        let rank = s.handle_line(
            "{\"v\":2,\"op\":\"rank\",\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dests\":[\"v100\",\"t4\"]}",
        );
        let parsed = json::parse(&rank).unwrap();
        assert_eq!(parsed.req_str("op").unwrap(), "rank");
        assert_eq!(parsed.get("ranking").and_then(Json::as_arr).unwrap().len(), 2);

        let stats = s.handle_line(&v2_stats_request());
        let parsed = json::parse(&stats).unwrap();
        assert_eq!(parsed.req_str("op").unwrap(), "stats");
        assert_eq!(parsed.req_usize("trace_misses").unwrap(), 1);
        assert_eq!(parsed.req_usize("trace_uploads").unwrap(), 0);
        assert!(parsed.req_usize("devices").unwrap() >= ALL_DEVICES.len());
    }

    #[test]
    fn v2_rank_many_matches_individual_ranks() {
        let s = wave_service();
        let dests = vec!["v100".to_string(), "t4".to_string()];
        let items = [("mlp", 8usize, "t4"), ("dcgan", 16, "p4000")];
        let reply = s.handle_line(&v2_rank_many_request(&items, Some(&dests), None));
        let v = json::parse(&reply).unwrap();
        assert_eq!(v.req_str("op").unwrap(), "rank_many");
        assert_eq!(v.req_usize("count").unwrap(), items.len());
        let many = RankManyResponse::from_json(&reply).unwrap();
        assert_eq!(many.results.len(), items.len());
        for ((model, batch, origin), result) in items.iter().zip(&many.results) {
            let mut solo_req = rank_req(model, *batch, origin);
            solo_req.dests = Some(dests.clone());
            let solo = s.handle_rank(&solo_req).unwrap();
            assert_eq!(result.model, solo.model);
            assert_eq!(result.origin_iter_ms.to_bits(), solo.origin_iter_ms.to_bits());
            assert_eq!(result.ranking.len(), solo.ranking.len());
            for (a, b) in result.ranking.iter().zip(&solo.ranking) {
                assert_eq!(a.dest, b.dest);
                assert_eq!(a.iter_ms.to_bits(), b.iter_ms.to_bits());
                assert_eq!(a.throughput.to_bits(), b.throughput.to_bits());
            }
        }
        // One sweep's metrics line: a single rank_many request recorded.
        assert_eq!(s.engine().metrics().snapshot(OpKind::RankMany).requests, 1);
    }

    #[test]
    fn v2_rank_many_errors_are_structured() {
        let s = wave_service();
        let check = |line: &str, code: &str| {
            let reply = s.handle_line(line);
            let v = json::parse(&reply).unwrap();
            assert_eq!(
                v.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
                Some(code),
                "line {line} → {reply}"
            );
        };
        check("{\"v\":2,\"op\":\"rank_many\"}", "bad_request");
        check("{\"v\":2,\"op\":\"rank_many\",\"items\":[]}", "invalid_argument");
        check(
            "{\"v\":2,\"op\":\"rank_many\",\"items\":[{\"model\":\"nope\",\"batch\":8,\"origin\":\"t4\"}],\"dests\":[\"v100\"]}",
            "unknown_model",
        );
        check(
            "{\"v\":2,\"op\":\"rank_many\",\"items\":[{\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\"}],\"dests\":[\"a100\"]}",
            "unknown_device",
        );
        // An oversized items × dests sweep is refused before any compute.
        let dests = vec!["v100".to_string(), "t4".to_string()];
        let items: Vec<(&str, usize, &str)> =
            (0..MAX_CLUSTER_SWEEP / 2 + 1).map(|_| ("mlp", 8usize, "t4")).collect();
        let line = v2_rank_many_request(&items, Some(&dests), None);
        check(&line, "invalid_argument");
    }

    #[test]
    fn v2_stats_report_the_simd_backend() {
        let s = wave_service();
        let reply = s.handle_line(&v2_stats_request());
        let v = json::parse(&reply).unwrap();
        assert_eq!(
            v.req_str("simd").unwrap(),
            crate::util::simdf64::backend(),
            "v2 stats must report the active evaluation backend"
        );
    }

    #[test]
    fn v2_errors_are_structured() {
        let s = wave_service();
        let check = |line: &str, code: &str| {
            let reply = s.handle_line(line);
            let v = json::parse(&reply).unwrap();
            assert_eq!(
                v.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
                Some(code),
                "line {line} → {reply}"
            );
            assert!(v.get("error").and_then(|e| e.get("message")).is_some());
        };
        check("{\"v\":2}", "bad_request");
        check("{\"v\":2,\"op\":\"frobnicate\"}", "unsupported_op");
        check(
            "{\"v\":2,\"op\":\"predict\",\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"a100\"}",
            "unknown_device",
        );
        check(
            "{\"v\":2,\"op\":\"predict\",\"model\":\"nope\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\"}",
            "unknown_model",
        );
        check(
            "{\"v\":2,\"op\":\"predict\",\"trace_id\":\"tr-0000000000000000\",\"dest\":\"v100\"}",
            "unknown_trace",
        );
        check(
            "{\"v\":2,\"op\":\"predict\",\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\",\"precision\":\"fp64\"}",
            "invalid_argument",
        );
        check("{\"v\":3,\"op\":\"predict\"}", "unsupported_version");
        // v1 malformed lines keep the v1 error shape.
        assert!(s.handle_line("not json").contains("bad request"));
    }

    #[test]
    fn v2_register_device_becomes_rankable_with_correct_ordering() {
        let s = wave_service();
        // Absurdly cost-efficient so its rank position is deterministic:
        // V100-class hardware at a tenth of the T4's price.
        let line = s.handle_line(
            "{\"v\":2,\"op\":\"register_device\",\"name\":\"sim-wire9\",\"sms\":80,\"clock_mhz\":1530,\"mem_bw_gbps\":900,\"fp32_tflops\":15.7,\"tensor_cores\":true,\"usd_per_hr\":0.03}",
        );
        let ack = RegisteredDevice::from_json(&line).unwrap();
        assert_eq!(ack.device, "sim-wire9");
        assert!(ack.id >= ALL_DEVICES.len());
        assert!(ack.devices > ALL_DEVICES.len());

        // Idempotent replay: same spec, same id, no conflict.
        let replay = RegisteredDevice::from_json(&s.handle_line(
            "{\"v\":2,\"op\":\"register_device\",\"name\":\"sim-wire9\",\"sms\":80,\"clock_mhz\":1530,\"mem_bw_gbps\":900,\"fp32_tflops\":15.7,\"tensor_cores\":true,\"usd_per_hr\":0.03}",
        ))
        .unwrap();
        assert_eq!(replay.id, ack.id);

        // Different spec under the same name → conflict.
        let clash = s.handle_line(
            "{\"v\":2,\"op\":\"register_device\",\"name\":\"sim-wire9\",\"sms\":81,\"clock_mhz\":1530,\"mem_bw_gbps\":900,\"fp32_tflops\":15.7,\"tensor_cores\":true}",
        );
        let v = json::parse(&clash).unwrap();
        assert_eq!(
            v.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
            Some("conflict")
        );

        // The new device appears in a default (v1!) rank, and — being a
        // V100 at 1/12 the T4's price — tops the cost-normalized order.
        let ranking = s.handle_rank(&rank_req("mlp", 16, "t4")).unwrap();
        let pos = ranking.ranking.iter().position(|r| r.dest == "sim-wire9");
        assert_eq!(pos, Some(0), "cheapest-per-throughput device must rank first");
        let entry = &ranking.ranking[pos.unwrap()];
        let expected_cnt = entry.throughput / 0.03;
        assert!(
            (entry.cost_normalized_throughput.unwrap() - expected_cnt).abs() < 1e-6,
            "cost normalization must use the registered price"
        );

        // …and works as an explicit v1 predict destination.
        let resp = s.handle(&req("mlp", 16, "t4", "sim-wire9")).unwrap();
        assert!(resp.iter_ms > 0.0);
        assert_eq!(resp.dest, "sim-wire9");
    }

    #[test]
    fn v2_submit_trace_then_predict_matches_in_process_evaluation() {
        let s = wave_service();
        let graph = crate::models::by_name("mlp", 12).unwrap();
        let trace = crate::tracker::OperationTracker::new(Device::P4000).track(&graph);

        let reply = s.handle_line(&v2_submit_trace_request(&trace));
        let v = json::parse(&reply).unwrap();
        v2_check_error(&v).unwrap();
        let trace_id = v.req_str("trace_id").unwrap().to_string();
        assert!(trace_id.starts_with("tr-"));
        assert_eq!(v.req_usize("ops").unwrap(), trace.ops.len());
        assert_eq!(v.req_str("origin").unwrap(), "P4000");

        // Predict by id over the wire ≡ analyze+evaluate in-process.
        let reply = s.handle_line(&v2_predict_trace_request(&trace_id, "v100", None));
        let v = json::parse(&reply).unwrap();
        v2_check_error(&v).unwrap();
        let wire_ms = v.get("iter_ms").and_then(Json::as_f64).unwrap();
        let plan = s.engine().analyze(&trace);
        let direct = s.engine().evaluate(&plan, Device::V100, Precision::Fp32);
        assert_eq!(
            wire_ms.to_bits(),
            direct.run_time_ms().to_bits(),
            "wire {wire_ms} vs in-process {}",
            direct.run_time_ms()
        );

        // Rank by id: default dests cover at least the built-ins.
        let reply = s.handle_line(&v2_rank_trace_request(&trace_id, None, Some("amp")));
        let v = json::parse(&reply).unwrap();
        v2_check_error(&v).unwrap();
        let ranking = v.get("ranking").and_then(Json::as_arr).unwrap();
        assert!(ranking.len() >= ALL_DEVICES.len());
        assert_eq!(v.req_str("model").unwrap(), "mlp");

        // Submitting garbage is a structured error.
        let bad = s.handle_line("{\"v\":2,\"op\":\"submit_trace\",\"trace\":{\"format\":\"nope\"}}");
        let v = json::parse(&bad).unwrap();
        assert_eq!(
            v.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
            Some("invalid_argument")
        );
    }

    #[test]
    fn v2_predict_cluster_world_one_matches_v2_predict() {
        let s = wave_service();
        let topologies = vec!["dgx".to_string()];
        let reply = s.handle_line(&v2_predict_cluster_request(
            "mlp",
            8,
            "t4",
            "v100",
            Some(&topologies),
            Some(&[1, 4]),
            None,
        ));
        let resp = ClusterResponse::from_json(&reply).unwrap();
        assert_eq!(resp.model, "mlp");
        assert_eq!(resp.dest, "V100");
        assert_eq!(resp.configs.len(), 2);
        for c in &resp.configs {
            assert_eq!(c.topology, "dgx");
            assert!(c.efficiency > 0.0 && c.efficiency <= 1.0 + 1e-9);
            assert!(c.exposed_ms >= 0.0);
        }
        // The world=1 cell is the single-GPU prediction, bit-identical.
        let single = s.handle_line(&v2_predict_model_request("mlp", 8, "t4", "v100", None));
        let single_ms = json::parse(&single).unwrap().get("iter_ms").and_then(Json::as_f64).unwrap();
        let w1 = resp.configs.iter().find(|c| c.world == 1).unwrap();
        assert_eq!(w1.iter_ms.to_bits(), single_ms.to_bits());
        assert_eq!(w1.comm_ms, 0.0);
    }

    #[test]
    fn v2_predict_cluster_defaults_cover_every_topology_and_world() {
        let s = wave_service();
        let reply = s.handle_line(&v2_predict_cluster_request("mlp", 8, "t4", "v100", None, None, None));
        let resp = ClusterResponse::from_json(&reply).unwrap();
        // At least the dgx/cloud seeds × the default world sweep (other
        // concurrently running tests may have registered more
        // topologies).
        assert!(resp.configs.len() >= 2 * DEFAULT_CLUSTER_WORLDS.len());
        for t in ["dgx", "cloud"] {
            for &w in &DEFAULT_CLUSTER_WORLDS {
                assert!(
                    resp.configs.iter().any(|c| c.topology == t && c.world == w),
                    "missing cell ({t}, {w})"
                );
            }
        }
    }

    #[test]
    fn v2_rank_cluster_is_sorted_and_complete() {
        let s = wave_service();
        let dests = vec!["v100".to_string(), "t4".to_string()];
        let topologies = vec!["dgx".to_string(), "cloud".to_string()];
        let reply = s.handle_line(&v2_rank_cluster_request(
            "mlp",
            8,
            "t4",
            Some(&dests),
            Some(&topologies),
            Some(&[1, 4]),
            None,
        ));
        let resp = ClusterRankResponse::from_json(&reply).unwrap();
        assert_eq!(resp.ranking.len(), 2 * 2 * 2);
        // Both dests are rentable, so the whole ranking is priced and
        // descending in cost-normalized throughput.
        let priced: Vec<f64> = resp
            .ranking
            .iter()
            .map(|e| e.cost_normalized_throughput.unwrap())
            .collect();
        for w in priced.windows(2) {
            assert!(w[0] >= w[1], "ranking must be descending: {priced:?}");
        }
    }

    #[test]
    fn v2_cluster_errors_are_structured() {
        let s = wave_service();
        let check = |line: &str, code: &str| {
            let reply = s.handle_line(line);
            let v = json::parse(&reply).unwrap();
            assert_eq!(
                v.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
                Some(code),
                "line {line} → {reply}"
            );
        };
        check(
            "{\"v\":2,\"op\":\"predict_cluster\",\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\",\"topologies\":[\"no-such-topology\"]}",
            "unknown_topology",
        );
        check(
            "{\"v\":2,\"op\":\"predict_cluster\",\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\",\"topologies\":[{\"name\":\"sim-svc-badlink\",\"gpus_per_node\":4,\"intra\":\"no-such-link\",\"inter\":\"eth25g\"}]}",
            "unknown_link",
        );
        check(
            "{\"v\":2,\"op\":\"predict_cluster\",\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\",\"worlds\":[0]}",
            "invalid_argument",
        );
        check(
            "{\"v\":2,\"op\":\"predict_cluster\",\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\",\"topologies\":[]}",
            "invalid_argument",
        );
        check(
            "{\"v\":2,\"op\":\"predict_cluster\",\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\",\"overlap\":1.5}",
            "invalid_argument",
        );
        check(
            "{\"v\":2,\"op\":\"rank_cluster\",\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dests\":[\"a100\"]}",
            "unknown_device",
        );
        check(
            "{\"v\":2,\"op\":\"export_workload\",\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\",\"world\":8}",
            "bad_request",
        );
        // An oversized sweep is refused before any compute.
        let worlds: Vec<usize> = (1..=MAX_CLUSTER_SWEEP + 1).collect();
        let line = v2_predict_cluster_request("mlp", 8, "t4", "v100", None, Some(&worlds), None);
        check(&line, "invalid_argument");
    }

    #[test]
    fn v2_inline_topologies_register_links_idempotently() {
        let s = wave_service();
        let line = "{\"v\":2,\"op\":\"predict_cluster\",\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\",\"worlds\":[2],\"topologies\":[{\"name\":\"sim-svc-pod\",\"gpus_per_node\":2,\"intra\":\"nvlink\",\"inter\":{\"name\":\"sim-svc-wan\",\"bandwidth_gbps\":10.0,\"step_latency_ms\":0.02}}]}";
        let resp = ClusterResponse::from_json(&s.handle_line(line)).unwrap();
        assert_eq!(resp.configs.len(), 1);
        assert_eq!(resp.configs[0].topology, "sim-svc-pod");
        // Replay is idempotent (same inline specs re-intern silently)…
        let replay = ClusterResponse::from_json(&s.handle_line(line)).unwrap();
        assert_eq!(replay.configs[0].iter_ms.to_bits(), resp.configs[0].iter_ms.to_bits());
        // …while the same name with a different shape is a conflict.
        let clash = s.handle_line(
            "{\"v\":2,\"op\":\"predict_cluster\",\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\",\"worlds\":[2],\"topologies\":[{\"name\":\"sim-svc-pod\",\"gpus_per_node\":4,\"intra\":\"nvlink\",\"inter\":\"eth25g\"}]}",
        );
        let v = json::parse(&clash).unwrap();
        assert_eq!(
            v.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
            Some("conflict")
        );
    }

    #[test]
    fn v2_export_workload_round_trips() {
        let s = wave_service();
        let reply = s.handle_line(&v2_export_workload_request("mlp", 8, "t4", "v100", "dgx", 16, None));
        let v = json::parse(&reply).unwrap();
        v2_check_error(&v).unwrap();
        assert_eq!(v.req_str("op").unwrap(), "export_workload");
        let w = crate::comm::Workload::from_value(&v).unwrap();
        assert_eq!(w.topology, "dgx");
        assert_eq!(w.world, 16);
        assert!(w.compute_ms > 0.0);
        assert!(!w.comm_ops.is_empty());
        assert!(w.comm_ops.iter().all(|op| op.participants.iter().all(|&r| r < 16)));
        // A re-serialized workload parses back to the same value.
        let again = crate::comm::Workload::from_value(&json::parse(&w.to_value().dump()).unwrap()).unwrap();
        assert_eq!(again, w);
    }
}
