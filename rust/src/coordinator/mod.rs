//! Layer-3 coordination: the prediction service, split into explicit
//! layers.
//!
//! Habitat is a library in the paper; in this reproduction it is also a
//! deployable *service*. The request path is layered so every transport
//! shares one brain:
//!
//! ```text
//! TCP lines ──┐                                  ┌─ engine caches
//! HTTP bodies ┴→ protocol (codec) → dispatch ────┤  fan-out pool
//!                                   │            └─ hybrid predictor
//!                                   └→ per-op metrics (/metrics, stats)
//! ```
//!
//! * [`protocol`] — typed request/response structs for every op and the
//!   v1/v2 JSON codec, including structured errors. Pure data: this
//!   layer never touches a socket.
//! * [`dispatch`] — [`Dispatcher`] (aliased [`PredictionService`]), the
//!   transport-agnostic core that routes decoded requests into the
//!   shared [`crate::engine::PredictionEngine`] and records per-op
//!   counters and latency histograms
//!   ([`crate::engine::metrics::ServiceMetrics`]).
//! * [`tcp`] — the newline-delimited JSON transport on the bounded
//!   runtime (capped connection slots, a shared bounded compute pool,
//!   typed `overloaded` backpressure, in-order pipelining).
//! * [`http`] — the dependency-free HTTP/1.1 transport on the same
//!   bounds: `POST /v2` (same envelope), `GET /healthz`, and
//!   `GET /metrics` (Prometheus text).
//!
//! Transports move bytes and map dispatch outcomes onto their wire;
//! they never parse envelopes. The engine behind the dispatcher
//! supplies:
//!
//! * the **trace/plan cache** — tracking a model on the simulator is
//!   the expensive, reusable step, so traces are memoized per
//!   (model, batch, origin, precision) in a content-keyed LRU, each
//!   next to its compiled [`crate::plan::AnalyzedPlan`];
//! * the **multi-destination fan-out** behind the `rank` request — one
//!   cached plan evaluated onto every destination GPU on a persistent
//!   worker pool, returned sorted by cost-normalized throughput (the
//!   paper's Fig. 1 decision as a single RPC);
//! * the **hybrid predictor**, whose kernel-varying ops funnel into the
//!   MLP service thread ([`crate::runtime::MlpService`]), where requests
//!   from all concurrent connections are **dynamically batched** into a
//!   few large PJRT executions;
//! * the **cost model**, so responses carry decision-ready metrics
//!   (throughput, cost-normalized throughput), not just milliseconds.
//!
//! The wire protocol is documented in `docs/SERVICE.md`.
//! [`service`] remains as a re-export shim for pre-split paths.

pub mod client;
pub mod dispatch;
pub mod http;
pub mod protocol;
pub mod service;
pub mod tcp;

pub use client::{Client, ClientError};
pub use dispatch::{DispatchOutcome, Dispatcher};
pub use service::{
    overloaded_json, v2_check_error, v2_error_json, v2_export_workload_request,
    v2_predict_cluster_request, v2_predict_model_request, v2_predict_trace_request,
    v2_rank_cluster_request, v2_rank_many_request, v2_rank_trace_request,
    v2_register_device_request, v2_stats_request, v2_submit_trace_request, ClusterConfig,
    ClusterRankResponse, ClusterRankedConfig, ClusterResponse, PredictionRequest,
    PredictionResponse, PredictionService, RankManyResponse, RankRequest, RankResponse,
    RankedDest, RegisteredDevice, Request, ServeOptions, ServerHandle, StatsResponse,
    DEFAULT_CLUSTER_WORLDS, DEFAULT_MAX_CONNS, MAX_CONNS_ENV, PROTOCOL_V2, STORE_ENV,
};

use crate::Result;

/// Run the TCP prediction server (the `habitat serve` subcommand) on
/// the bounded runtime; with [`ServeOptions::http_port`] set, the HTTP
/// front end runs alongside it. Blocks forever.
pub fn serve(addr: &str, artifacts: &str) -> Result<()> {
    tcp::serve(addr, artifacts)
}

/// [`serve`] with explicit runtime bounds (`--max-conns`,
/// `--http-port`, etc.).
pub fn serve_with(addr: &str, artifacts: &str, opts: ServeOptions) -> Result<()> {
    tcp::serve_with(addr, artifacts, opts)
}

/// Start the TCP server on background threads and return its
/// [`ServerHandle`] (tests and embedding applications).
pub fn start(
    addr: &str,
    service: std::sync::Arc<PredictionService>,
    opts: ServeOptions,
) -> Result<ServerHandle> {
    tcp::start(addr, service, opts)
}
