//! Layer-3 coordination: the prediction service.
//!
//! Habitat is a library in the paper; in this reproduction it is also a
//! deployable *service*: a TCP front end (newline-delimited JSON on a
//! bounded runtime — capped connection slots, a shared bounded compute
//! pool, typed `overloaded` backpressure, in-order pipelining) that
//! routes every request through the shared
//! [`crate::engine::PredictionEngine`]. The engine supplies:
//!
//! * the **trace/plan cache** — tracking a model on the simulator is
//!   the expensive, reusable step, so traces are memoized per
//!   (model, batch, origin, precision) in a content-keyed LRU, each
//!   next to its compiled [`crate::plan::AnalyzedPlan`];
//! * the **multi-destination fan-out** behind the `rank` request — one
//!   cached plan evaluated onto every destination GPU on a persistent
//!   worker pool, returned sorted by cost-normalized throughput (the
//!   paper's Fig. 1 decision as a single RPC);
//! * the **hybrid predictor**, whose kernel-varying ops funnel into the
//!   MLP service thread ([`crate::runtime::MlpService`]), where requests
//!   from all concurrent connections are **dynamically batched** into a
//!   few large PJRT executions;
//! * the **cost model**, so responses carry decision-ready metrics
//!   (throughput, cost-normalized throughput), not just milliseconds.
//!
//! The wire protocol is documented in `docs/SERVICE.md`.

pub mod client;
pub mod service;

pub use client::{Client, ClientError};
pub use service::{
    overloaded_json, v2_check_error, v2_error_json, v2_export_workload_request,
    v2_predict_cluster_request, v2_predict_model_request, v2_predict_trace_request,
    v2_rank_cluster_request, v2_rank_trace_request, v2_register_device_request,
    v2_stats_request, v2_submit_trace_request, ClusterConfig, ClusterRankResponse,
    ClusterRankedConfig, ClusterResponse, PredictionRequest, PredictionResponse,
    PredictionService, RankRequest, RankResponse, RankedDest, RegisteredDevice, Request,
    ServeOptions, ServerHandle, StatsResponse, DEFAULT_CLUSTER_WORLDS, DEFAULT_MAX_CONNS,
    MAX_CONNS_ENV, PROTOCOL_V2, STORE_ENV,
};

use crate::Result;

/// Run the TCP prediction server (the `habitat serve` subcommand) on
/// the bounded runtime. Blocks forever.
pub fn serve(addr: &str, artifacts: &str) -> Result<()> {
    service::serve(addr, artifacts)
}

/// [`serve`] with explicit runtime bounds (`--max-conns` etc.).
pub fn serve_with(addr: &str, artifacts: &str, opts: service::ServeOptions) -> Result<()> {
    service::serve_with(addr, artifacts, opts)
}

/// Start the server on background threads and return its
/// [`service::ServerHandle`] (tests and embedding applications).
pub fn start(
    addr: &str,
    service: std::sync::Arc<PredictionService>,
    opts: service::ServeOptions,
) -> Result<service::ServerHandle> {
    service::start(addr, service, opts)
}
