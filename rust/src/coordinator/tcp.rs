//! The TCP transport: newline-delimited JSON over a bounded serving
//! runtime.
//!
//! This module moves bytes and threads only — every request line goes
//! straight to [`Dispatcher::handle_line`](super::Dispatcher::handle_line)
//! and the reply is written back verbatim, so the wire protocol
//! (including v1 bit-compatibility) is owned entirely by
//! [`super::protocol`] / [`super::dispatch`]. What lives here:
//!
//! * **Connection slots** ([`ServeOptions::max_conns`]): a connect past
//!   the bound gets one typed `overloaded` line and a close.
//! * **Pipelined connections**: each line becomes a job on the engine's
//!   shared compute pool; a writer thread emits replies strictly in
//!   request order. A full compute queue answers `overloaded` per
//!   request; a full pipeline window stops reading the socket (TCP
//!   backpressure).
//! * **Graceful drain**: shutdown half-closes the read side of every
//!   live connection so in-flight replies still flush.
//!
//! [`serve_with`] also boots the HTTP front end ([`super::http`]) next
//! to the TCP listener when [`ServeOptions::http_port`] is set — both
//! transports share one dispatcher, one engine, and one metrics
//! surface.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use crate::predict::HybridPredictor;
use crate::Result;

use super::dispatch::PredictionService;
use super::protocol::v2_error_json;

/// Environment variable bounding concurrent connections
/// ([`DEFAULT_MAX_CONNS`] when unset).
pub const MAX_CONNS_ENV: &str = "HABITAT_MAX_CONNS";

/// Default concurrent-connection bound.
pub const DEFAULT_MAX_CONNS: usize = 256;

/// Default per-connection pipelining bound: how many request lines may
/// be in flight (submitted but unanswered) on one connection before the
/// reader stops pulling bytes off the socket — backpressure lands on
/// that connection's TCP window, not on server memory.
pub const DEFAULT_PIPELINE_DEPTH: usize = 64;

/// Server-side write timeout per connection. A client that stops
/// reading its replies (zero TCP window) errors that connection's
/// writer out instead of pinning a runtime thread forever — without
/// this, `ServerHandle::shutdown` could block joining a writer stuck
/// in `write_all`.
pub const CONN_WRITE_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(30);

/// The wire form of the typed backpressure reply: sent per request when
/// the compute queue is full, and once (followed by a close) to a
/// connection that arrives while every connection slot is taken. Always
/// the structured v2 error shape, whatever protocol generation the
/// client speaks — `overloaded` is a server condition, not a request
/// parse result.
pub fn overloaded_json() -> String {
    v2_error_json("overloaded", "server at capacity; retry later")
}

pub(crate) fn internal_error_json() -> String {
    v2_error_json("internal", "request handler failed")
}

/// Serving-runtime knobs (see `docs/SERVICE.md`).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Connection slots; further connects get an `overloaded` line and
    /// a close. `Default` reads [`MAX_CONNS_ENV`].
    pub max_conns: usize,
    /// In-flight request lines per connection.
    pub pipeline_depth: usize,
    /// When set, [`serve_with`] also boots the HTTP front end
    /// ([`super::http`]) on this port (same host as the TCP address),
    /// sharing the dispatcher. `None` (the default) serves TCP only.
    pub http_port: Option<u16>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            max_conns: std::env::var(MAX_CONNS_ENV)
                .ok()
                .and_then(|v| v.parse::<usize>().ok())
                .filter(|&n| n > 0)
                .unwrap_or(DEFAULT_MAX_CONNS),
            pipeline_depth: DEFAULT_PIPELINE_DEPTH,
            http_port: None,
        }
    }
}

/// State shared by the acceptor, the connection threads, and the
/// [`ServerHandle`].
struct ServerShared {
    service: Arc<PredictionService>,
    opts: ServeOptions,
    shutdown: AtomicBool,
    /// Occupied connection slots.
    active: AtomicUsize,
    /// Socket clones of live connections, for shutdown wake-up.
    streams: Mutex<HashMap<u64, TcpStream>>,
    /// Connection reader threads, joined on shutdown.
    threads: Mutex<Vec<JoinHandle<()>>>,
    next_conn: AtomicU64,
}

impl ServerShared {
    fn spawn_connection(self: &Arc<Self>, stream: TcpStream) {
        // Claim a slot optimistically; over the bound, tell the client
        // why and close instead of letting connects pile up at the OS.
        if self.active.fetch_add(1, Ordering::SeqCst) >= self.opts.max_conns {
            self.active.fetch_sub(1, Ordering::SeqCst);
            let mut stream = stream;
            let _ = stream.write_all(overloaded_json().as_bytes());
            let _ = stream.write_all(b"\n");
            return; // drop closes the socket
        }
        // A stalled client must not pin a writer thread forever (see
        // CONN_WRITE_TIMEOUT); reads stay unbounded — idle connections
        // are legitimate.
        let _ = stream.set_write_timeout(Some(CONN_WRITE_TIMEOUT));
        let id = self.next_conn.fetch_add(1, Ordering::Relaxed);
        if let Ok(clone) = stream.try_clone() {
            self.streams.lock().unwrap().insert(id, clone);
        }
        // Reap finished connection threads so a long-running server's
        // handle list stays proportional to *live* connections, not to
        // every connection ever accepted.
        self.threads.lock().unwrap().retain(|h| !h.is_finished());
        let shared = Arc::clone(self);
        let spawned = std::thread::Builder::new()
            .name(format!("habitat-conn-{id}"))
            .spawn(move || {
                let peer = stream.peer_addr().map(|p| p.to_string()).unwrap_or_default();
                if let Err(e) = run_connection(stream, &shared) {
                    if !shared.shutdown.load(Ordering::SeqCst) {
                        eprintln!("habitat: connection {peer}: {e}");
                    }
                }
                shared.streams.lock().unwrap().remove(&id);
                shared.active.fetch_sub(1, Ordering::SeqCst);
            });
        match spawned {
            Ok(handle) => self.threads.lock().unwrap().push(handle),
            Err(_) => {
                self.streams.lock().unwrap().remove(&id);
                self.active.fetch_sub(1, Ordering::SeqCst);
            }
        }
    }
}

/// A running prediction server. Dropping the handle shuts the runtime
/// down; [`ServerHandle::join`] blocks on the acceptor instead (the
/// `habitat serve` foreground mode).
pub struct ServerHandle {
    addr: SocketAddr,
    shared: Arc<ServerShared>,
    acceptor: Option<JoinHandle<()>>,
}

impl ServerHandle {
    /// The bound address (with the OS-assigned port when `:0` was
    /// requested).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn service(&self) -> &Arc<PredictionService> {
        &self.shared.service
    }

    /// Occupied connection slots right now.
    pub fn active_connections(&self) -> usize {
        self.shared.active.load(Ordering::SeqCst)
    }

    /// Stop accepting, unblock every connection reader, drain in-flight
    /// replies, and join all runtime threads. Idempotent; also invoked
    /// by `Drop`, so tests can simply let the handle fall out of scope.
    pub fn shutdown(mut self) {
        self.stop();
    }

    /// Block on the acceptor thread (runs until the process exits or
    /// another owner flips the shutdown flag).
    pub fn join(mut self) -> Result<()> {
        if let Some(acceptor) = self.acceptor.take() {
            acceptor
                .join()
                .map_err(|_| anyhow::anyhow!("acceptor thread panicked"))?;
        }
        Ok(())
    }

    fn stop(&mut self) {
        if self.shared.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        // Wake the acceptor out of `accept` with one throwaway connect.
        let mut wake = self.addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST));
        }
        let _ = TcpStream::connect_timeout(&wake, std::time::Duration::from_millis(250));
        if let Some(acceptor) = self.acceptor.take() {
            let _ = acceptor.join();
        }
        // Half-close every live connection's read side: readers see EOF
        // and wind down, while writers still flush in-flight replies —
        // a drain, not an abort.
        for stream in self.shared.streams.lock().unwrap().values() {
            let _ = stream.shutdown(std::net::Shutdown::Read);
        }
        let threads: Vec<JoinHandle<()>> = self.shared.threads.lock().unwrap().drain(..).collect();
        for handle in threads {
            let _ = handle.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Start the bounded serving runtime on `addr` around an existing
/// (shared) service. Returns once the listener is bound; the acceptor
/// and all connection handling run on background threads owned by the
/// returned [`ServerHandle`].
pub fn start(
    addr: &str,
    service: Arc<PredictionService>,
    opts: ServeOptions,
) -> Result<ServerHandle> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let shared = Arc::new(ServerShared {
        service,
        opts,
        shutdown: AtomicBool::new(false),
        active: AtomicUsize::new(0),
        streams: Mutex::new(HashMap::new()),
        threads: Mutex::new(Vec::new()),
        next_conn: AtomicU64::new(0),
    });
    let for_acceptor = Arc::clone(&shared);
    let acceptor = std::thread::Builder::new()
        .name("habitat-accept".to_string())
        .spawn(move || {
            for stream in listener.incoming() {
                if for_acceptor.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                let stream = match stream {
                    Ok(s) => s,
                    Err(e) => {
                        // A persistent accept failure (e.g. fd
                        // exhaustion) must not become a silent
                        // busy-loop: say so and back off.
                        eprintln!("habitat: accept error: {e}");
                        std::thread::sleep(std::time::Duration::from_millis(100));
                        continue;
                    }
                };
                for_acceptor.spawn_connection(stream);
            }
        })?;
    Ok(ServerHandle {
        addr: local,
        shared,
        acceptor: Some(acceptor),
    })
}

/// One pipelined connection: the reader submits each line as a job on
/// the engine's shared compute pool and a writer thread emits replies
/// strictly in request order. A full compute queue becomes a typed
/// `overloaded` reply for that line (the stream stays in sync); a full
/// pipeline window stops reading the socket (TCP backpressure).
fn run_connection(stream: TcpStream, shared: &Arc<ServerShared>) -> Result<()> {
    let mut write = stream.try_clone()?;
    // The in-order reply rail: the reader enqueues one slot (a oneshot
    // receiver) per request; the writer drains slots in order, waiting
    // on each request's reply before touching the next.
    let (slot_tx, slot_rx) =
        mpsc::sync_channel::<mpsc::Receiver<String>>(shared.opts.pipeline_depth.max(1));
    let writer = std::thread::Builder::new()
        .name("habitat-conn-writer".to_string())
        .spawn(move || {
            while let Ok(slot) = slot_rx.recv() {
                // A dropped slot without a reply means the handler was
                // lost (e.g. pool teardown mid-request): answer with a
                // typed internal error so the stream never desyncs.
                let reply = slot.recv().unwrap_or_else(|_| internal_error_json());
                if write.write_all(reply.as_bytes()).is_err() || write.write_all(b"\n").is_err() {
                    break;
                }
            }
        })?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let (reply_tx, reply_rx) = mpsc::channel::<String>();
        if slot_tx.send(reply_rx).is_err() {
            break; // writer gone: the socket is dead
        }
        let service = Arc::clone(&shared.service);
        let tx = reply_tx.clone();
        let submitted = shared.service.engine().pool().try_execute(move || {
            let reply =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    service.handle_line(&line)
                }))
                .unwrap_or_else(|_| internal_error_json());
            let _ = tx.send(reply);
        });
        if submitted.is_err() {
            // Compute queue full: typed per-request backpressure through
            // the same reply slot, preserving response order.
            let _ = reply_tx.send(overloaded_json());
        }
    }
    drop(slot_tx);
    let _ = writer.join();
    Ok(())
}

/// Build the service for `serve`/`start`: the paper's full hybrid
/// predictor, degrading to wave-scaling-only predictions when MLP
/// artifacts are missing (like `habitat compare`) rather than refusing
/// to start.
pub fn service_from_artifacts(artifacts: &str) -> PredictionService {
    match PredictionService::new(artifacts) {
        Ok(s) => s,
        Err(e) => {
            eprintln!(
                "habitat: MLP artifacts unavailable ({e}); serving wave-scaling-only predictions"
            );
            PredictionService::with_predictor(HybridPredictor::wave_only())
        }
    }
}

/// Serve newline-delimited JSON requests over TCP on the bounded
/// runtime (the `habitat serve` subcommand). Blocks forever.
pub fn serve(addr: &str, artifacts: &str) -> Result<()> {
    serve_with(addr, artifacts, ServeOptions::default())
}

/// Environment variable naming the persistent plan-store directory for
/// `habitat serve` (also settable via the CLI's `--store` flag). Only
/// the serving entry point reads it — library engines never attach a
/// store implicitly.
pub const STORE_ENV: &str = "HABITAT_STORE";

/// [`serve`] with explicit runtime bounds. When
/// [`ServeOptions::http_port`] is set, the HTTP front end boots next to
/// the TCP listener on the same host, sharing the dispatcher.
pub fn serve_with(addr: &str, artifacts: &str, opts: ServeOptions) -> Result<()> {
    let mut service = service_from_artifacts(artifacts);
    if let Ok(dir) = std::env::var(STORE_ENV) {
        if !dir.is_empty() {
            // Persistence is an optimization: a store that cannot be
            // opened degrades to a cold boot, never a refused one.
            match service.attach_store(&dir) {
                Ok(()) => println!(
                    "habitat: plan store at {dir} ({} plans warm-restored)",
                    service.engine().stats().warm_restores
                ),
                Err(e) => eprintln!("habitat: plan store at {dir} unavailable ({e}); serving without persistence"),
            }
        }
    }
    let service = Arc::new(service);
    let max_conns = opts.max_conns;
    // The HTTP handle must outlive `handle.join()` below: dropping it
    // would drain the HTTP runtime while TCP keeps serving.
    let _http = match opts.http_port {
        None => None,
        Some(port) => {
            let host = addr.rsplit_once(':').map_or(addr, |(h, _)| h);
            let http_addr = format!("{host}:{port}");
            let handle = super::http::start(&http_addr, Arc::clone(&service), opts.clone())?;
            println!(
                "habitat: http front end on {} (POST /v2, GET /healthz, GET /metrics)",
                handle.local_addr()
            );
            Some(handle)
        }
    };
    let handle = start(addr, service, opts)?;
    {
        let engine = handle.service().engine();
        println!(
            "habitat: serving predictions on {addr} ({} workers, queue depth {}, max {} connections)",
            engine.workers(),
            engine.queue_depth(),
            max_conns
        );
    }
    handle.join()
}

/// Handle one connection until EOF.
pub fn handle_connection(stream: TcpStream, service: &PredictionService) -> Result<()> {
    let mut write = stream.try_clone()?;
    let read = BufReader::new(stream);
    for line in read.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = service.handle_line(&line);
        write.write_all(reply.as_bytes())?;
        write.write_all(b"\n")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::{PredictionResponse, RankResponse, StatsResponse};
    use crate::device::ALL_DEVICES;
    use crate::engine::PredictionEngine;
    use crate::util::json::{self, Json};

    fn wave_service() -> PredictionService {
        PredictionService::with_predictor(HybridPredictor::wave_only())
    }

    #[test]
    fn serve_options_defaults_are_bounded() {
        let opts = ServeOptions::default();
        assert!(opts.max_conns >= 1);
        assert!(opts.pipeline_depth >= 1);
        assert!(opts.http_port.is_none(), "HTTP must be opt-in");
        let line = overloaded_json();
        let v = json::parse(&line).unwrap();
        assert_eq!(
            v.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
            Some("overloaded")
        );
        assert_eq!(v.get("v"), Some(&Json::Num(2.0)));
    }

    #[test]
    fn bounded_runtime_serves_pipelined_lines_in_order() {
        let handle = start(
            "127.0.0.1:0",
            Arc::new(wave_service()),
            ServeOptions::default(),
        )
        .unwrap();
        let addr = handle.local_addr();
        let stream = TcpStream::connect(addr).unwrap();
        let mut write = stream.try_clone().unwrap();
        write
            .write_all(
                b"{\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\"}\n\
                  {\"rank\":true,\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\"}\n\
                  {\"stats\":true}\n",
            )
            .unwrap();
        // Half-close the write side so the server sees EOF after the
        // pipelined burst (dropping a clone alone does not, because the
        // read half still holds the socket open).
        stream.shutdown(std::net::Shutdown::Write).unwrap();
        let replies: Vec<String> = BufReader::new(stream).lines().map(|l| l.unwrap()).collect();
        assert_eq!(replies.len(), 3);
        assert_eq!(PredictionResponse::from_json(&replies[0]).unwrap().dest, "V100");
        assert!(RankResponse::from_json(&replies[1]).unwrap().ranking.len() >= ALL_DEVICES.len());
        assert!(StatsResponse::from_json(&replies[2]).is_ok());
        handle.shutdown();
        // The listener is gone after shutdown — nothing leaked.
        assert!(TcpStream::connect(addr).is_err(), "listener must be closed");
    }

    #[test]
    fn connection_slots_are_enforced_with_a_typed_reply() {
        let handle = start(
            "127.0.0.1:0",
            Arc::new(wave_service()),
            ServeOptions {
                max_conns: 1,
                ..ServeOptions::default()
            },
        )
        .unwrap();
        let addr = handle.local_addr();

        // Fill the single slot and prove it is live with a roundtrip
        // (which also guarantees the acceptor registered it).
        let first = TcpStream::connect(addr).unwrap();
        let mut w1 = first.try_clone().unwrap();
        w1.write_all(b"{\"stats\":true}\n").unwrap();
        let mut r1 = BufReader::new(first.try_clone().unwrap());
        let mut line = String::new();
        r1.read_line(&mut line).unwrap();
        assert!(StatsResponse::from_json(line.trim()).is_ok());

        // The second connection gets one typed overloaded line, then EOF.
        let second = TcpStream::connect(addr).unwrap();
        let mut lines = BufReader::new(second).lines();
        let reply = lines.next().unwrap().unwrap();
        let v = json::parse(&reply).unwrap();
        assert_eq!(
            v.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
            Some("overloaded"),
            "{reply}"
        );
        assert!(lines.next().is_none(), "rejected connection must be closed");

        // Freeing the slot readmits clients (every clone of the first
        // connection must drop for the server to see EOF).
        drop(w1);
        drop(r1);
        drop(first);
        for _ in 0..100 {
            let probe = TcpStream::connect(addr).unwrap();
            let mut w = probe.try_clone().unwrap();
            w.write_all(b"{\"stats\":true}\n").unwrap();
            let mut line = String::new();
            BufReader::new(probe).read_line(&mut line).unwrap();
            if StatsResponse::from_json(line.trim()).is_ok() {
                return; // slot reclaimed
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        panic!("slot was never reclaimed after the first client left");
    }

    #[test]
    fn full_compute_queue_answers_overloaded_per_request() {
        let engine = PredictionEngine::wave_only()
            .with_workers(1)
            .with_queue_depth(1);
        let handle = start(
            "127.0.0.1:0",
            Arc::new(PredictionService::with_engine(engine)),
            ServeOptions::default(),
        )
        .unwrap();
        let addr = handle.local_addr();
        let pool_gate = {
            // Wedge the single worker and fill the single queue slot so
            // the next request job cannot be accepted. Wait for the
            // wedge job to *start* before filling: otherwise the fillers
            // could land while the wedge is still queued, and the queue
            // would drain again as the worker picks it up.
            let engine = handle.service().engine();
            let (gate_tx, gate_rx) = std::sync::mpsc::channel::<()>();
            let (started_tx, started_rx) = std::sync::mpsc::channel::<()>();
            engine.pool().execute(move || {
                started_tx.send(()).unwrap();
                gate_rx.recv().unwrap();
            });
            started_rx.recv().unwrap();
            while engine.pool().try_execute(|| {}).is_ok() {}
            gate_tx
        };

        let stream = TcpStream::connect(addr).unwrap();
        let mut write = stream.try_clone().unwrap();
        write
            .write_all(b"{\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\"}\n")
            .unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = json::parse(line.trim()).unwrap();
        assert_eq!(
            v.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
            Some("overloaded"),
            "wedged pool must answer with typed backpressure: {line}"
        );

        // Release the pool; the connection is still in sync and serves.
        drop(pool_gate);
        for _ in 0..100 {
            write
                .write_all(b"{\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\"}\n")
                .unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            if PredictionResponse::from_json(line.trim()).is_ok() {
                return;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        panic!("service never recovered after the queue drained");
    }

    #[test]
    fn tcp_roundtrip() {
        let service = Arc::new(wave_service());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let srv = service.clone();
        std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            handle_connection(stream, &srv).unwrap();
        });

        let stream = TcpStream::connect(addr).unwrap();
        let mut write = stream.try_clone().unwrap();
        write
            .write_all(b"{\"model\":\"mlp\",\"batch\":16,\"origin\":\"t4\",\"dest\":\"p100\"}\nnot json\n")
            .unwrap();
        drop(write);
        let mut lines = BufReader::new(stream).lines();
        let ok = PredictionResponse::from_json(&lines.next().unwrap().unwrap()).unwrap();
        assert!(ok.iter_ms > 0.0);
        let err_line = lines.next().unwrap().unwrap();
        assert!(err_line.contains("bad request"));
    }
}
