//! Compatibility shim for the old service monolith.
//!
//! The coordinator used to live in this one module; it is now split
//! into explicit layers (see `docs/ARCHITECTURE.md`, "Request path"):
//!
//! * [`protocol`](super::protocol) — typed requests/responses and the
//!   v1/v2 wire codec. Pure data; no sockets.
//! * [`dispatch`](super::dispatch) — the transport-agnostic
//!   [`Dispatcher`](super::dispatch::Dispatcher) that routes decoded
//!   requests into the engine and records per-op metrics.
//! * [`tcp`](super::tcp) / [`http`](super::http) — the transports.
//!   They move bytes and map outcomes to their wire; they never parse
//!   envelopes.
//!
//! Everything that was public here is re-exported below, so
//! `coordinator::service::*` paths keep compiling unchanged. New code
//! should import from the layer modules (or from
//! [`coordinator`](crate::coordinator) directly) instead.

pub use super::dispatch::{DispatchOutcome, Dispatcher, PredictionService};
pub use super::protocol::{
    stats_request_json, v2_check_error, v2_error_json, v2_export_workload_request,
    v2_predict_cluster_request, v2_predict_model_request, v2_predict_trace_request,
    v2_rank_cluster_request, v2_rank_many_request, v2_rank_trace_request,
    v2_register_device_request, v2_stats_request, v2_submit_trace_request, ClusterConfig,
    ClusterRankResponse, ClusterRankedConfig, ClusterResponse, PredictionRequest,
    PredictionResponse, RankManyResponse, RankRequest, RankResponse, RankedDest, RegisteredDevice,
    Request, StatsResponse, DEFAULT_CLUSTER_WORLDS, PROTOCOL_V2,
};
pub use super::tcp::{
    handle_connection, overloaded_json, serve, serve_with, service_from_artifacts, start,
    ServeOptions, ServerHandle, CONN_WRITE_TIMEOUT, DEFAULT_MAX_CONNS, DEFAULT_PIPELINE_DEPTH,
    MAX_CONNS_ENV, STORE_ENV,
};
