//! The prediction service and its TCP front end.
//!
//! Wire protocol: newline-delimited JSON, one request per line, one
//! response per line, pipelining allowed (see `docs/SERVICE.md` for the
//! full schema and worked `nc` examples). Two request shapes share the
//! stream:
//!
//! * **predict** — `{"model", "batch", "origin", "dest", "precision"?}`
//!   → one destination's decision metrics;
//! * **rank** — `{"rank": true, "model", "batch", "origin",
//!   "precision"?, "dests"?}` → *every* destination GPU, ordered by
//!   cost-normalized throughput, from a single pass over one cached
//!   trace (the paper's Fig. 1 decision as one RPC);
//! * **stats** — `{"stats": true}` → the engine's trace/plan cache
//!   hit & miss counters, wave-table counters, and fan-out pool size.
//!
//! The server is thread-per-connection over `std::net` (the image has no
//! async runtime); all prediction work funnels into the shared
//! [`crate::engine::PredictionEngine`], so concurrent connections reuse
//! each other's traces, and PJRT MLP execution stays centralized on the
//! batching service thread regardless of how many connections are open.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use crate::device::{Device, ALL_DEVICES};
use crate::engine::PredictionEngine;
use crate::lowering::Precision;
use crate::predict::HybridPredictor;
use crate::tracker::Trace;
use crate::util::json::{self, Json};
use crate::Result;

/// One prediction request (wire format and internal API).
#[derive(Debug, Clone)]
pub struct PredictionRequest {
    /// Model name (see [`crate::models::MODEL_NAMES`]).
    pub model: String,
    pub batch: usize,
    /// Origin GPU short name (e.g. `"t4"`).
    pub origin: String,
    /// Destination GPU short name.
    pub dest: String,
    /// `"fp32"` (default) or `"amp"` — AMP composes Habitat with the
    /// Daydream transformation (§6.1.2).
    pub precision: Option<String>,
}

impl PredictionRequest {
    /// Parse from a JSON object line.
    pub fn from_json(line: &str) -> Result<Self> {
        Self::from_value(&json::parse(line)?)
    }

    fn from_value(v: &Json) -> Result<Self> {
        Ok(PredictionRequest {
            model: v.req_str("model")?.to_string(),
            batch: v.req_usize("batch")?,
            origin: v.req_str("origin")?.to_string(),
            dest: v.req_str("dest")?.to_string(),
            precision: v.get("precision").and_then(Json::as_str).map(str::to_string),
        })
    }

    pub fn to_json(&self) -> String {
        let mut pairs = vec![
            ("model", Json::Str(self.model.clone())),
            ("batch", Json::Num(self.batch as f64)),
            ("origin", Json::Str(self.origin.clone())),
            ("dest", Json::Str(self.dest.clone())),
        ];
        if let Some(p) = &self.precision {
            pairs.push(("precision", Json::Str(p.clone())));
        }
        Json::obj(pairs).dump()
    }
}

/// A rank request: predict one origin trace onto many destinations and
/// order them by cost-normalized throughput.
#[derive(Debug, Clone)]
pub struct RankRequest {
    pub model: String,
    pub batch: usize,
    pub origin: String,
    /// `"fp32"` (default) or `"amp"`.
    pub precision: Option<String>,
    /// Candidate destinations; `None` means every built-in device.
    pub dests: Option<Vec<String>>,
}

impl RankRequest {
    pub fn from_json(line: &str) -> Result<Self> {
        Self::from_value(&json::parse(line)?)
    }

    fn from_value(v: &Json) -> Result<Self> {
        let dests = match v.get("dests") {
            None | Some(Json::Null) => None,
            Some(arr) => {
                let items = arr
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("dests must be an array of device names"))?;
                let mut names = Vec::with_capacity(items.len());
                for it in items {
                    names.push(
                        it.as_str()
                            .ok_or_else(|| anyhow::anyhow!("dests entries must be strings"))?
                            .to_string(),
                    );
                }
                Some(names)
            }
        };
        Ok(RankRequest {
            model: v.req_str("model")?.to_string(),
            batch: v.req_usize("batch")?,
            origin: v.req_str("origin")?.to_string(),
            precision: v.get("precision").and_then(Json::as_str).map(str::to_string),
            dests,
        })
    }

    pub fn to_json(&self) -> String {
        let mut pairs = vec![
            ("rank", Json::Bool(true)),
            ("model", Json::Str(self.model.clone())),
            ("batch", Json::Num(self.batch as f64)),
            ("origin", Json::Str(self.origin.clone())),
        ];
        if let Some(p) = &self.precision {
            pairs.push(("precision", Json::Str(p.clone())));
        }
        if let Some(d) = &self.dests {
            pairs.push((
                "dests",
                Json::Arr(d.iter().map(|s| Json::Str(s.clone())).collect()),
            ));
        }
        Json::obj(pairs).dump()
    }
}

/// Any request shape, as dispatched off the wire: a line with
/// `"rank": true` is a [`RankRequest`], a line with `"stats": true` a
/// stats request, anything else a [`PredictionRequest`].
#[derive(Debug, Clone)]
pub enum Request {
    Predict(PredictionRequest),
    Rank(RankRequest),
    Stats,
}

impl Request {
    pub fn from_json(line: &str) -> Result<Request> {
        let v = json::parse(line)?;
        if matches!(v.get("rank"), Some(Json::Bool(true))) {
            Ok(Request::Rank(RankRequest::from_value(&v)?))
        } else if matches!(v.get("stats"), Some(Json::Bool(true))) {
            Ok(Request::Stats)
        } else {
            Ok(Request::Predict(PredictionRequest::from_value(&v)?))
        }
    }
}

/// The wire form of a stats request.
pub fn stats_request_json() -> String {
    Json::obj(vec![("stats", Json::Bool(true))]).dump()
}

/// The answer to a stats request: the engine's counter snapshot
/// ([`crate::engine::EngineStats`]) in wire form.
#[derive(Debug, Clone, Copy)]
pub struct StatsResponse {
    /// Cache hits (requests that skipped the tracking pipeline).
    pub trace_hits: u64,
    /// Cache misses (tracking-pipeline executions).
    pub trace_misses: u64,
    /// Trace+plan entries currently resident.
    pub trace_entries: usize,
    /// Compiled-plan builds (cache misses + one-off analyses); the
    /// plan rides the same cache entry as its trace, so cached-plan
    /// reuses equal `trace_hits`.
    pub plan_builds: u64,
    /// Process-wide wave-table counters.
    pub wave_hits: u64,
    pub wave_misses: u64,
    /// Persistent fan-out worker-pool width.
    pub workers: usize,
}

impl From<crate::engine::EngineStats> for StatsResponse {
    fn from(s: crate::engine::EngineStats) -> Self {
        StatsResponse {
            trace_hits: s.trace_hits,
            trace_misses: s.trace_misses,
            trace_entries: s.trace_entries,
            plan_builds: s.plan_builds,
            wave_hits: s.wave_hits,
            wave_misses: s.wave_misses,
            workers: s.workers,
        }
    }
}

impl StatsResponse {
    pub fn to_json(&self) -> String {
        Json::obj(vec![
            ("trace_hits", Json::Num(self.trace_hits as f64)),
            ("trace_misses", Json::Num(self.trace_misses as f64)),
            ("trace_entries", Json::Num(self.trace_entries as f64)),
            ("plan_builds", Json::Num(self.plan_builds as f64)),
            ("wave_hits", Json::Num(self.wave_hits as f64)),
            ("wave_misses", Json::Num(self.wave_misses as f64)),
            ("workers", Json::Num(self.workers as f64)),
        ])
        .dump()
    }

    pub fn from_json(line: &str) -> Result<Self> {
        let v = json::parse(line)?;
        if let Some(err) = v.get("error").and_then(Json::as_str) {
            anyhow::bail!("server error: {err}");
        }
        let req_u64 = |key: &str| -> Result<u64> {
            Ok(v.req_usize(key)? as u64)
        };
        Ok(StatsResponse {
            trace_hits: req_u64("trace_hits")?,
            trace_misses: req_u64("trace_misses")?,
            trace_entries: v.req_usize("trace_entries")?,
            plan_builds: req_u64("plan_builds")?,
            wave_hits: req_u64("wave_hits")?,
            wave_misses: req_u64("wave_misses")?,
            workers: v.req_usize("workers")?,
        })
    }
}

/// The service's answer: decision-ready metrics.
#[derive(Debug, Clone)]
pub struct PredictionResponse {
    pub model: String,
    pub batch: usize,
    pub origin: String,
    pub dest: String,
    /// Measured iteration time on the origin, ms.
    pub origin_iter_ms: f64,
    /// Predicted iteration time on the destination, ms.
    pub iter_ms: f64,
    /// Predicted training throughput, samples/s.
    pub throughput: f64,
    /// Throughput per rental dollar, if the destination is rentable.
    pub cost_normalized_throughput: Option<f64>,
    /// Fraction of predicted time that came from the MLP predictors.
    pub mlp_time_fraction: f64,
    /// Kernel-varying ops that fell back to wave scaling.
    pub mlp_fallbacks: usize,
}

impl PredictionResponse {
    pub fn to_json(&self) -> String {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("batch", Json::Num(self.batch as f64)),
            ("origin", Json::Str(self.origin.clone())),
            ("dest", Json::Str(self.dest.clone())),
            ("origin_iter_ms", Json::Num(self.origin_iter_ms)),
            ("iter_ms", Json::Num(self.iter_ms)),
            ("throughput", Json::Num(self.throughput)),
            (
                "cost_normalized_throughput",
                self.cost_normalized_throughput.map_or(Json::Null, Json::Num),
            ),
            ("mlp_time_fraction", Json::Num(self.mlp_time_fraction)),
            ("mlp_fallbacks", Json::Num(self.mlp_fallbacks as f64)),
        ])
        .dump()
    }

    /// Parse a response line (used by clients/examples/tests).
    pub fn from_json(line: &str) -> Result<Self> {
        let v = json::parse(line)?;
        if let Some(err) = v.get("error").and_then(Json::as_str) {
            anyhow::bail!("server error: {err}");
        }
        Ok(PredictionResponse {
            model: v.req_str("model")?.to_string(),
            batch: v.req_usize("batch")?,
            origin: v.req_str("origin")?.to_string(),
            dest: v.req_str("dest")?.to_string(),
            origin_iter_ms: v
                .get("origin_iter_ms")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("missing origin_iter_ms"))?,
            iter_ms: v
                .get("iter_ms")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("missing iter_ms"))?,
            throughput: v
                .get("throughput")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("missing throughput"))?,
            cost_normalized_throughput: v.get("cost_normalized_throughput").and_then(Json::as_f64),
            mlp_time_fraction: v.get("mlp_time_fraction").and_then(Json::as_f64).unwrap_or(0.0),
            mlp_fallbacks: v.get("mlp_fallbacks").and_then(Json::as_usize).unwrap_or(0),
        })
    }
}

/// One destination's row in a [`RankResponse`], best decision first.
#[derive(Debug, Clone)]
pub struct RankedDest {
    pub dest: String,
    pub iter_ms: f64,
    pub throughput: f64,
    pub cost_normalized_throughput: Option<f64>,
    pub mlp_time_fraction: f64,
    pub mlp_fallbacks: usize,
}

impl RankedDest {
    fn to_value(&self) -> Json {
        Json::obj(vec![
            ("dest", Json::Str(self.dest.clone())),
            ("iter_ms", Json::Num(self.iter_ms)),
            ("throughput", Json::Num(self.throughput)),
            (
                "cost_normalized_throughput",
                self.cost_normalized_throughput.map_or(Json::Null, Json::Num),
            ),
            ("mlp_time_fraction", Json::Num(self.mlp_time_fraction)),
            ("mlp_fallbacks", Json::Num(self.mlp_fallbacks as f64)),
        ])
    }

    fn from_value(v: &Json) -> Result<Self> {
        Ok(RankedDest {
            dest: v.req_str("dest")?.to_string(),
            iter_ms: v
                .get("iter_ms")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("missing iter_ms"))?,
            throughput: v
                .get("throughput")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("missing throughput"))?,
            cost_normalized_throughput: v.get("cost_normalized_throughput").and_then(Json::as_f64),
            mlp_time_fraction: v.get("mlp_time_fraction").and_then(Json::as_f64).unwrap_or(0.0),
            mlp_fallbacks: v.get("mlp_fallbacks").and_then(Json::as_usize).unwrap_or(0),
        })
    }
}

/// The answer to a [`RankRequest`].
#[derive(Debug, Clone)]
pub struct RankResponse {
    pub model: String,
    pub batch: usize,
    pub origin: String,
    /// Measured iteration time on the origin, ms.
    pub origin_iter_ms: f64,
    /// Every requested destination, sorted: rentable devices by
    /// descending cost-normalized throughput, then unpriced devices by
    /// descending raw throughput.
    pub ranking: Vec<RankedDest>,
}

impl RankResponse {
    pub fn to_json(&self) -> String {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("batch", Json::Num(self.batch as f64)),
            ("origin", Json::Str(self.origin.clone())),
            ("origin_iter_ms", Json::Num(self.origin_iter_ms)),
            (
                "ranking",
                Json::Arr(self.ranking.iter().map(RankedDest::to_value).collect()),
            ),
        ])
        .dump()
    }

    pub fn from_json(line: &str) -> Result<Self> {
        let v = json::parse(line)?;
        if let Some(err) = v.get("error").and_then(Json::as_str) {
            anyhow::bail!("server error: {err}");
        }
        let ranking = v
            .get("ranking")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing ranking array"))?
            .iter()
            .map(RankedDest::from_value)
            .collect::<Result<Vec<_>>>()?;
        Ok(RankResponse {
            model: v.req_str("model")?.to_string(),
            batch: v.req_usize("batch")?,
            origin: v.req_str("origin")?.to_string(),
            origin_iter_ms: v
                .get("origin_iter_ms")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("missing origin_iter_ms"))?,
            ranking,
        })
    }
}

fn error_json(msg: &str) -> String {
    Json::obj(vec![("error", Json::Str(msg.to_string()))]).dump()
}

fn parse_device(name: &str, role: &str) -> Result<Device> {
    Device::parse(name).ok_or_else(|| anyhow::anyhow!("unknown {role} device {name:?}"))
}

fn parse_precision(p: Option<&str>) -> Result<Precision> {
    match p {
        None | Some("fp32") => Ok(Precision::Fp32),
        Some("amp") => Ok(Precision::Amp),
        Some(other) => anyhow::bail!("unknown precision {other:?} (want fp32|amp)"),
    }
}

/// The TCP-facing prediction service: a thin protocol layer over the
/// shared [`PredictionEngine`].
pub struct PredictionService {
    engine: PredictionEngine,
}

impl PredictionService {
    /// Build with the paper's full hybrid predictor (requires artifacts).
    pub fn new(artifacts: &str) -> Result<Self> {
        Ok(Self::with_engine(PredictionEngine::from_artifacts(artifacts)?))
    }

    /// Build around any predictor (wave-only for tests / no artifacts).
    pub fn with_predictor(predictor: HybridPredictor) -> Self {
        Self::with_engine(PredictionEngine::new(predictor))
    }

    /// Build around an existing engine (shared caches, custom capacity).
    pub fn with_engine(engine: PredictionEngine) -> Self {
        PredictionService { engine }
    }

    pub fn engine(&self) -> &PredictionEngine {
        &self.engine
    }

    pub fn predictor(&self) -> &HybridPredictor {
        self.engine.predictor()
    }

    /// Get or build the origin trace for a request (memoized in the
    /// engine). The tracker always measures FP32 — the paper profiles
    /// FP32 and *predicts* AMP.
    pub fn trace_for(&self, model: &str, batch: usize, origin: Device) -> Result<Arc<Trace>> {
        self.engine.trace(model, batch, origin)
    }

    /// Handle one prediction request synchronously.
    pub fn handle(&self, req: &PredictionRequest) -> Result<PredictionResponse> {
        let origin = parse_device(&req.origin, "origin")?;
        let dest = parse_device(&req.dest, "destination")?;
        let precision = parse_precision(req.precision.as_deref())?;
        anyhow::ensure!(req.batch > 0, "batch must be positive");

        let out = self.engine.predict(&req.model, req.batch, origin, dest, precision)?;
        let tput = out.pred.throughput();
        Ok(PredictionResponse {
            model: req.model.clone(),
            batch: req.batch,
            origin: origin.id().to_string(),
            dest: dest.id().to_string(),
            origin_iter_ms: out.trace.run_time_ms(),
            iter_ms: out.pred.run_time_ms(),
            throughput: tput,
            cost_normalized_throughput: crate::cost::cost_normalized_throughput(dest, tput),
            mlp_time_fraction: out.pred.mlp_time_fraction(),
            mlp_fallbacks: out.pred.mlp_fallbacks,
        })
    }

    /// Handle one rank request: a single tracking pass, fanned out to
    /// every destination on the engine's worker pool.
    pub fn handle_rank(&self, req: &RankRequest) -> Result<RankResponse> {
        let origin = parse_device(&req.origin, "origin")?;
        let precision = parse_precision(req.precision.as_deref())?;
        anyhow::ensure!(req.batch > 0, "batch must be positive");
        let dests: Vec<Device> = match &req.dests {
            None => ALL_DEVICES.to_vec(),
            Some(names) => names
                .iter()
                .map(|n| parse_device(n, "destination"))
                .collect::<Result<Vec<_>>>()?,
        };

        let ranking = self.engine.rank(&req.model, req.batch, origin, &dests, precision)?;
        Ok(RankResponse {
            model: req.model.clone(),
            batch: req.batch,
            origin: origin.id().to_string(),
            origin_iter_ms: ranking.trace.run_time_ms(),
            ranking: ranking
                .entries
                .iter()
                .map(|e| RankedDest {
                    dest: e.dest.id().to_string(),
                    iter_ms: e.pred.run_time_ms(),
                    throughput: e.pred.throughput(),
                    cost_normalized_throughput: e.cost_normalized_throughput,
                    mlp_time_fraction: e.pred.mlp_time_fraction(),
                    mlp_fallbacks: e.pred.mlp_fallbacks,
                })
                .collect(),
        })
    }

    /// Handle a stats request: the engine's counter snapshot.
    pub fn handle_stats(&self) -> StatsResponse {
        self.engine.stats().into()
    }

    /// Parse one wire line, dispatch it, and serialize the reply.
    pub fn handle_line(&self, line: &str) -> String {
        match Request::from_json(line) {
            Ok(Request::Predict(req)) => match self.handle(&req) {
                Ok(resp) => resp.to_json(),
                Err(e) => error_json(&e.to_string()),
            },
            Ok(Request::Rank(req)) => match self.handle_rank(&req) {
                Ok(resp) => resp.to_json(),
                Err(e) => error_json(&e.to_string()),
            },
            Ok(Request::Stats) => self.handle_stats().to_json(),
            Err(e) => error_json(&format!("bad request: {e}")),
        }
    }
}

/// Serve newline-delimited JSON requests over TCP, one thread per
/// connection (the `habitat serve` subcommand). Blocks forever.
pub fn serve(addr: &str, artifacts: &str) -> Result<()> {
    let service = Arc::new(PredictionService::new(artifacts)?);
    let listener = TcpListener::bind(addr)?;
    println!("habitat: serving predictions on {addr}");
    for stream in listener.incoming() {
        let stream = stream?;
        let service = service.clone();
        std::thread::spawn(move || {
            let peer = stream.peer_addr().map(|p| p.to_string()).unwrap_or_default();
            if let Err(e) = handle_connection(stream, &service) {
                eprintln!("habitat: connection {peer}: {e}");
            }
        });
    }
    Ok(())
}

/// Handle one connection until EOF.
pub fn handle_connection(stream: TcpStream, service: &PredictionService) -> Result<()> {
    let mut write = stream.try_clone()?;
    let read = BufReader::new(stream);
    for line in read.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = service.handle_line(&line);
        write.write_all(reply.as_bytes())?;
        write.write_all(b"\n")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wave_service() -> PredictionService {
        PredictionService::with_predictor(HybridPredictor::wave_only())
    }

    fn req(model: &str, batch: usize, origin: &str, dest: &str) -> PredictionRequest {
        PredictionRequest {
            model: model.into(),
            batch,
            origin: origin.into(),
            dest: dest.into(),
            precision: None,
        }
    }

    fn rank_req(model: &str, batch: usize, origin: &str) -> RankRequest {
        RankRequest {
            model: model.into(),
            batch,
            origin: origin.into(),
            precision: None,
            dests: None,
        }
    }

    #[test]
    fn handles_basic_request() {
        let s = wave_service();
        let r = s.handle(&req("mlp", 32, "t4", "v100")).unwrap();
        assert!(r.iter_ms > 0.0);
        assert!(r.throughput > 0.0);
        assert!(r.cost_normalized_throughput.is_some());
        assert_eq!(r.dest, "V100");
    }

    #[test]
    fn rejects_unknown_inputs() {
        let s = wave_service();
        assert!(s.handle(&req("nope", 32, "t4", "v100")).is_err());
        assert!(s.handle(&req("mlp", 32, "a100", "v100")).is_err());
        assert!(s.handle(&req("mlp", 0, "t4", "v100")).is_err());
        let mut r = req("mlp", 8, "t4", "v100");
        r.precision = Some("fp64".into());
        assert!(s.handle(&r).is_err());
    }

    #[test]
    fn request_response_json_roundtrip() {
        let r = req("gnmt", 64, "p4000", "t4");
        let parsed = PredictionRequest::from_json(&r.to_json()).unwrap();
        assert_eq!(parsed.model, "gnmt");
        assert_eq!(parsed.batch, 64);

        let resp = wave_service().handle(&r).unwrap();
        let parsed = PredictionResponse::from_json(&resp.to_json()).unwrap();
        assert!((parsed.iter_ms - resp.iter_ms).abs() < 1e-9);
        assert_eq!(
            parsed.cost_normalized_throughput.is_some(),
            resp.cost_normalized_throughput.is_some()
        );
    }

    #[test]
    fn rank_request_json_roundtrip() {
        let mut r = rank_req("mlp", 16, "t4");
        r.dests = Some(vec!["v100".into(), "p100".into()]);
        r.precision = Some("amp".into());
        let line = r.to_json();
        let parsed = match Request::from_json(&line).unwrap() {
            Request::Rank(rr) => rr,
            other => panic!("expected rank request, got {other:?}"),
        };
        assert_eq!(parsed.model, "mlp");
        assert_eq!(parsed.batch, 16);
        assert_eq!(parsed.precision.as_deref(), Some("amp"));
        assert_eq!(parsed.dests.as_deref().unwrap().len(), 2);
    }

    #[test]
    fn predict_line_still_dispatches_as_predict() {
        let line = req("mlp", 8, "t4", "v100").to_json();
        assert!(matches!(Request::from_json(&line).unwrap(), Request::Predict(_)));
    }

    #[test]
    fn rank_response_json_roundtrip() {
        let s = wave_service();
        let resp = s.handle_rank(&rank_req("mlp", 32, "t4")).unwrap();
        let parsed = RankResponse::from_json(&resp.to_json()).unwrap();
        assert_eq!(parsed.ranking.len(), resp.ranking.len());
        for (a, b) in parsed.ranking.iter().zip(&resp.ranking) {
            assert_eq!(a.dest, b.dest);
            assert!((a.iter_ms - b.iter_ms).abs() < 1e-9);
            assert_eq!(
                a.cost_normalized_throughput.is_some(),
                b.cost_normalized_throughput.is_some()
            );
        }
    }

    #[test]
    fn rank_matches_individual_requests_with_one_tracking_pass() {
        // The ISSUE's acceptance criterion: a rank over all built-in
        // devices equals N individual requests, with exactly one run of
        // the tracking pipeline.
        let s = wave_service();
        let ranking = s.handle_rank(&rank_req("mlp", 16, "t4")).unwrap();
        assert_eq!(ranking.ranking.len(), ALL_DEVICES.len());
        let stats = s.engine().stats();
        assert_eq!(stats.trace_misses, 1, "rank must track exactly once");
        assert_eq!(stats.trace_hits, 0);

        for entry in &ranking.ranking {
            let resp = s.handle(&req("mlp", 16, "t4", &entry.dest)).unwrap();
            assert!(
                (resp.iter_ms - entry.iter_ms).abs() < 1e-9,
                "{}: rank {} vs individual {}",
                entry.dest,
                entry.iter_ms,
                resp.iter_ms
            );
        }
        let stats = s.engine().stats();
        assert_eq!(stats.trace_misses, 1, "individual requests must reuse the trace");
        assert_eq!(stats.trace_hits as usize, ALL_DEVICES.len());
    }

    #[test]
    fn rank_is_sorted_by_cost_normalized_throughput() {
        let s = wave_service();
        let resp = s.handle_rank(&rank_req("mlp", 32, "p4000")).unwrap();
        let priced: Vec<f64> = resp
            .ranking
            .iter()
            .filter_map(|r| r.cost_normalized_throughput)
            .collect();
        assert!(!priced.is_empty());
        for w in priced.windows(2) {
            assert!(w[0] >= w[1], "priced devices must be in descending order");
        }
        // Priced devices all come before unpriced ones.
        let first_unpriced = resp
            .ranking
            .iter()
            .position(|r| r.cost_normalized_throughput.is_none())
            .unwrap_or(resp.ranking.len());
        assert!(resp.ranking[first_unpriced..]
            .iter()
            .all(|r| r.cost_normalized_throughput.is_none()));
    }

    #[test]
    fn rank_with_explicit_dests_and_errors() {
        let s = wave_service();
        let mut r = rank_req("mlp", 16, "t4");
        r.dests = Some(vec!["v100".into(), "p100".into()]);
        let resp = s.handle_rank(&r).unwrap();
        assert_eq!(resp.ranking.len(), 2);

        let mut bad = rank_req("mlp", 16, "t4");
        bad.dests = Some(vec!["a100".into()]);
        assert!(s.handle_rank(&bad).is_err());
        assert!(s.handle_rank(&rank_req("nope", 16, "t4")).is_err());
        assert!(s.handle_rank(&rank_req("mlp", 0, "t4")).is_err());
    }

    #[test]
    fn handle_line_dispatches_and_reports_errors() {
        let s = wave_service();
        let ok = s.handle_line("{\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\"}");
        assert!(PredictionResponse::from_json(&ok).is_ok());
        let rank = s.handle_line("{\"rank\":true,\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\"}");
        assert!(RankResponse::from_json(&rank).is_ok());
        let bad = s.handle_line("not json");
        assert!(bad.contains("bad request"));
        let unknown = s.handle_line("{\"model\":\"mlp\",\"batch\":8,\"origin\":\"a100\",\"dest\":\"v100\"}");
        assert!(unknown.contains("error"));
    }

    #[test]
    fn stats_request_reflects_engine_counters() {
        let s = wave_service();
        let cold = s.handle_stats();
        assert_eq!(cold.trace_hits, 0);
        assert_eq!(cold.trace_misses, 0);
        assert!(cold.workers >= 1);

        s.handle(&req("mlp", 8, "t4", "v100")).unwrap();
        s.handle(&req("mlp", 8, "t4", "p100")).unwrap();
        let warm = s.handle_stats();
        assert_eq!(warm.trace_misses, 1);
        assert_eq!(warm.trace_hits, 1);
        assert_eq!(warm.trace_entries, 1);
        assert_eq!(warm.plan_builds, 1);
    }

    #[test]
    fn stats_line_dispatches_and_roundtrips() {
        let s = wave_service();
        s.handle(&req("mlp", 8, "t4", "v100")).unwrap();
        let line = stats_request_json();
        assert!(matches!(Request::from_json(&line).unwrap(), Request::Stats));
        let reply = s.handle_line(&line);
        let parsed = StatsResponse::from_json(&reply).unwrap();
        assert_eq!(parsed.trace_misses, 1);
        assert_eq!(parsed.workers, s.engine().workers());
    }

    #[test]
    fn trace_cache_hits() {
        let s = wave_service();
        let a = s.trace_for("mlp", 16, Device::T4).unwrap();
        let b = s.trace_for("mlp", 16, Device::T4).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second lookup must hit the cache");
    }

    #[test]
    fn amp_prediction_not_slower_than_fp32() {
        let s = wave_service();
        let fp32 = s.handle(&req("mlp", 32, "p4000", "2080ti")).unwrap();
        let mut amp_req = req("mlp", 32, "p4000", "2080ti");
        amp_req.precision = Some("amp".into());
        let amp = s.handle(&amp_req).unwrap();
        assert!(amp.iter_ms <= fp32.iter_ms);
    }

    #[test]
    fn tcp_roundtrip() {
        let service = Arc::new(wave_service());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let srv = service.clone();
        std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            handle_connection(stream, &srv).unwrap();
        });

        let stream = TcpStream::connect(addr).unwrap();
        let mut write = stream.try_clone().unwrap();
        write
            .write_all(b"{\"model\":\"mlp\",\"batch\":16,\"origin\":\"t4\",\"dest\":\"p100\"}\nnot json\n")
            .unwrap();
        drop(write);
        let mut lines = BufReader::new(stream).lines();
        let ok = PredictionResponse::from_json(&lines.next().unwrap().unwrap()).unwrap();
        assert!(ok.iter_ms > 0.0);
        let err_line = lines.next().unwrap().unwrap();
        assert!(err_line.contains("bad request"));
    }
}
