//! The prediction service and its TCP front end.
//!
//! Wire protocol: newline-delimited JSON, one request per line, one
//! response per line, pipelining allowed. The server is thread-per-
//! connection over `std::net` (the image has no async runtime); the
//! heavy lifting — PJRT MLP execution — is centralized on the batching
//! service thread regardless of how many connections are open, so
//! concurrency still coalesces into few large executions.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex};

use crate::device::Device;
use crate::lowering::Precision;
use crate::predict::{amp, HybridPredictor};
use crate::tracker::{OperationTracker, Trace};
use crate::util::json::{self, Json};
use crate::{cost, models, Result};

/// One prediction request (wire format and internal API).
#[derive(Debug, Clone)]
pub struct PredictionRequest {
    /// Model name (see [`crate::models::MODEL_NAMES`]).
    pub model: String,
    pub batch: usize,
    /// Origin GPU short name (e.g. `"t4"`).
    pub origin: String,
    /// Destination GPU short name.
    pub dest: String,
    /// `"fp32"` (default) or `"amp"` — AMP composes Habitat with the
    /// Daydream transformation (§6.1.2).
    pub precision: Option<String>,
}

impl PredictionRequest {
    /// Parse from a JSON object line.
    pub fn from_json(line: &str) -> Result<Self> {
        let v = json::parse(line)?;
        Ok(PredictionRequest {
            model: v.req_str("model")?.to_string(),
            batch: v.req_usize("batch")?,
            origin: v.req_str("origin")?.to_string(),
            dest: v.req_str("dest")?.to_string(),
            precision: v.get("precision").and_then(Json::as_str).map(str::to_string),
        })
    }

    pub fn to_json(&self) -> String {
        let mut pairs = vec![
            ("model", Json::Str(self.model.clone())),
            ("batch", Json::Num(self.batch as f64)),
            ("origin", Json::Str(self.origin.clone())),
            ("dest", Json::Str(self.dest.clone())),
        ];
        if let Some(p) = &self.precision {
            pairs.push(("precision", Json::Str(p.clone())));
        }
        Json::obj(pairs).dump()
    }
}

/// The service's answer: decision-ready metrics.
#[derive(Debug, Clone)]
pub struct PredictionResponse {
    pub model: String,
    pub batch: usize,
    pub origin: String,
    pub dest: String,
    /// Measured iteration time on the origin, ms.
    pub origin_iter_ms: f64,
    /// Predicted iteration time on the destination, ms.
    pub iter_ms: f64,
    /// Predicted training throughput, samples/s.
    pub throughput: f64,
    /// Throughput per rental dollar, if the destination is rentable.
    pub cost_normalized_throughput: Option<f64>,
    /// Fraction of predicted time that came from the MLP predictors.
    pub mlp_time_fraction: f64,
    /// Kernel-varying ops that fell back to wave scaling.
    pub mlp_fallbacks: usize,
}

impl PredictionResponse {
    pub fn to_json(&self) -> String {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("batch", Json::Num(self.batch as f64)),
            ("origin", Json::Str(self.origin.clone())),
            ("dest", Json::Str(self.dest.clone())),
            ("origin_iter_ms", Json::Num(self.origin_iter_ms)),
            ("iter_ms", Json::Num(self.iter_ms)),
            ("throughput", Json::Num(self.throughput)),
            (
                "cost_normalized_throughput",
                self.cost_normalized_throughput.map_or(Json::Null, Json::Num),
            ),
            ("mlp_time_fraction", Json::Num(self.mlp_time_fraction)),
            ("mlp_fallbacks", Json::Num(self.mlp_fallbacks as f64)),
        ])
        .dump()
    }

    /// Parse a response line (used by clients/examples/tests).
    pub fn from_json(line: &str) -> Result<Self> {
        let v = json::parse(line)?;
        if let Some(err) = v.get("error").and_then(Json::as_str) {
            anyhow::bail!("server error: {err}");
        }
        Ok(PredictionResponse {
            model: v.req_str("model")?.to_string(),
            batch: v.req_usize("batch")?,
            origin: v.req_str("origin")?.to_string(),
            dest: v.req_str("dest")?.to_string(),
            origin_iter_ms: v
                .get("origin_iter_ms")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("missing origin_iter_ms"))?,
            iter_ms: v
                .get("iter_ms")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("missing iter_ms"))?,
            throughput: v
                .get("throughput")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("missing throughput"))?,
            cost_normalized_throughput: v.get("cost_normalized_throughput").and_then(Json::as_f64),
            mlp_time_fraction: v.get("mlp_time_fraction").and_then(Json::as_f64).unwrap_or(0.0),
            mlp_fallbacks: v.get("mlp_fallbacks").and_then(Json::as_usize).unwrap_or(0),
        })
    }
}

fn error_json(msg: &str) -> String {
    Json::obj(vec![("error", Json::Str(msg.to_string()))]).dump()
}

type TraceKey = (String, usize, Device);

/// Shared prediction engine: predictor + trace cache.
pub struct PredictionService {
    predictor: HybridPredictor,
    traces: Mutex<HashMap<TraceKey, Arc<Trace>>>,
}

impl PredictionService {
    /// Build with the paper's full hybrid predictor (requires artifacts).
    pub fn new(artifacts: &str) -> Result<Self> {
        Ok(Self::with_predictor(crate::runtime::predictor_from_artifacts(artifacts)?))
    }

    /// Build around any predictor (wave-only for tests / no artifacts).
    pub fn with_predictor(predictor: HybridPredictor) -> Self {
        PredictionService {
            predictor,
            traces: Mutex::new(HashMap::new()),
        }
    }

    pub fn predictor(&self) -> &HybridPredictor {
        &self.predictor
    }

    /// Get or build the origin trace for a request (memoized). The tracker
    /// always measures FP32 — the paper profiles FP32 and *predicts* AMP.
    pub fn trace_for(&self, model: &str, batch: usize, origin: Device) -> Result<Arc<Trace>> {
        let key = (model.to_string(), batch, origin);
        if let Some(t) = self.traces.lock().unwrap().get(&key) {
            return Ok(t.clone());
        }
        let graph = models::by_name(model, batch)
            .ok_or_else(|| anyhow::anyhow!("unknown model {model:?}"))?;
        let trace = Arc::new(OperationTracker::new(origin).track(&graph));
        self.traces.lock().unwrap().insert(key, trace.clone());
        Ok(trace)
    }

    /// Handle one request synchronously.
    pub fn handle(&self, req: &PredictionRequest) -> Result<PredictionResponse> {
        let origin = Device::parse(&req.origin)
            .ok_or_else(|| anyhow::anyhow!("unknown origin device {:?}", req.origin))?;
        let dest = Device::parse(&req.dest)
            .ok_or_else(|| anyhow::anyhow!("unknown destination device {:?}", req.dest))?;
        let precision = match req.precision.as_deref() {
            None | Some("fp32") => Precision::Fp32,
            Some("amp") => Precision::Amp,
            Some(p) => anyhow::bail!("unknown precision {p:?} (want fp32|amp)"),
        };
        anyhow::ensure!(req.batch > 0, "batch must be positive");

        let trace = self.trace_for(&req.model, req.batch, origin)?;
        let pred = match precision {
            Precision::Fp32 => self.predictor.predict(&trace, dest),
            Precision::Amp => amp::predict_amp(&self.predictor, &trace, dest),
        };
        let tput = pred.throughput();
        Ok(PredictionResponse {
            model: req.model.clone(),
            batch: req.batch,
            origin: origin.id().to_string(),
            dest: dest.id().to_string(),
            origin_iter_ms: trace.run_time_ms(),
            iter_ms: pred.run_time_ms(),
            throughput: tput,
            cost_normalized_throughput: cost::cost_normalized_throughput(dest, tput),
            mlp_time_fraction: pred.mlp_time_fraction(),
            mlp_fallbacks: pred.mlp_fallbacks,
        })
    }
}

/// Serve newline-delimited JSON requests over TCP, one thread per
/// connection (the `habitat serve` subcommand). Blocks forever.
pub fn serve(addr: &str, artifacts: &str) -> Result<()> {
    let service = Arc::new(PredictionService::new(artifacts)?);
    let listener = TcpListener::bind(addr)?;
    println!("habitat: serving predictions on {addr}");
    for stream in listener.incoming() {
        let stream = stream?;
        let service = service.clone();
        std::thread::spawn(move || {
            let peer = stream.peer_addr().map(|p| p.to_string()).unwrap_or_default();
            if let Err(e) = handle_connection(stream, &service) {
                eprintln!("habitat: connection {peer}: {e}");
            }
        });
    }
    Ok(())
}

/// Handle one connection until EOF.
pub fn handle_connection(stream: TcpStream, service: &PredictionService) -> Result<()> {
    let mut write = stream.try_clone()?;
    let read = BufReader::new(stream);
    for line in read.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match PredictionRequest::from_json(&line) {
            Ok(req) => match service.handle(&req) {
                Ok(resp) => resp.to_json(),
                Err(e) => error_json(&e.to_string()),
            },
            Err(e) => error_json(&format!("bad request: {e}")),
        };
        write.write_all(reply.as_bytes())?;
        write.write_all(b"\n")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wave_service() -> PredictionService {
        PredictionService::with_predictor(HybridPredictor::wave_only())
    }

    fn req(model: &str, batch: usize, origin: &str, dest: &str) -> PredictionRequest {
        PredictionRequest {
            model: model.into(),
            batch,
            origin: origin.into(),
            dest: dest.into(),
            precision: None,
        }
    }

    #[test]
    fn handles_basic_request() {
        let s = wave_service();
        let r = s.handle(&req("mlp", 32, "t4", "v100")).unwrap();
        assert!(r.iter_ms > 0.0);
        assert!(r.throughput > 0.0);
        assert!(r.cost_normalized_throughput.is_some());
        assert_eq!(r.dest, "V100");
    }

    #[test]
    fn rejects_unknown_inputs() {
        let s = wave_service();
        assert!(s.handle(&req("nope", 32, "t4", "v100")).is_err());
        assert!(s.handle(&req("mlp", 32, "a100", "v100")).is_err());
        assert!(s.handle(&req("mlp", 0, "t4", "v100")).is_err());
        let mut r = req("mlp", 8, "t4", "v100");
        r.precision = Some("fp64".into());
        assert!(s.handle(&r).is_err());
    }

    #[test]
    fn request_response_json_roundtrip() {
        let r = req("gnmt", 64, "p4000", "t4");
        let parsed = PredictionRequest::from_json(&r.to_json()).unwrap();
        assert_eq!(parsed.model, "gnmt");
        assert_eq!(parsed.batch, 64);

        let resp = wave_service().handle(&r).unwrap();
        let parsed = PredictionResponse::from_json(&resp.to_json()).unwrap();
        assert!((parsed.iter_ms - resp.iter_ms).abs() < 1e-9);
        assert_eq!(
            parsed.cost_normalized_throughput.is_some(),
            resp.cost_normalized_throughput.is_some()
        );
    }

    #[test]
    fn trace_cache_hits() {
        let s = wave_service();
        let a = s.trace_for("mlp", 16, Device::T4).unwrap();
        let b = s.trace_for("mlp", 16, Device::T4).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second lookup must hit the cache");
    }

    #[test]
    fn amp_prediction_not_slower_than_fp32() {
        let s = wave_service();
        let fp32 = s.handle(&req("mlp", 32, "p4000", "2080ti")).unwrap();
        let mut amp_req = req("mlp", 32, "p4000", "2080ti");
        amp_req.precision = Some("amp".into());
        let amp = s.handle(&amp_req).unwrap();
        assert!(amp.iter_ms <= fp32.iter_ms);
    }

    #[test]
    fn tcp_roundtrip() {
        let service = Arc::new(wave_service());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let srv = service.clone();
        std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            handle_connection(stream, &srv).unwrap();
        });

        let stream = TcpStream::connect(addr).unwrap();
        let mut write = stream.try_clone().unwrap();
        write
            .write_all(b"{\"model\":\"mlp\",\"batch\":16,\"origin\":\"t4\",\"dest\":\"p100\"}\nnot json\n")
            .unwrap();
        drop(write);
        let mut lines = BufReader::new(stream).lines();
        let ok = PredictionResponse::from_json(&lines.next().unwrap().unwrap()).unwrap();
        assert!(ok.iter_ms > 0.0);
        let err_line = lines.next().unwrap().unwrap();
        assert!(err_line.contains("bad request"));
    }
}
