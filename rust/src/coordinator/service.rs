//! The prediction service and its TCP front end.
//!
//! Wire protocol: newline-delimited JSON, one request per line, one
//! response per line, pipelining allowed (see `docs/SERVICE.md` for the
//! full schema and worked `nc` examples). Two protocol generations
//! share the stream:
//!
//! **v1** (bare objects, no `"v"` field — kept bit-identical):
//!
//! * **predict** — `{"model", "batch", "origin", "dest", "precision"?}`
//!   → one destination's decision metrics;
//! * **rank** — `{"rank": true, "model", "batch", "origin",
//!   "precision"?, "dests"?}` → destination GPUs ordered by
//!   cost-normalized throughput, from a single pass over one cached
//!   trace (the paper's Fig. 1 decision as one RPC);
//! * **stats** — `{"stats": true}` → the engine's trace/plan cache
//!   hit & miss counters, wave-table counters, and fan-out pool size.
//!
//! **v2** (the open-world envelope, `{"v":2,"op":...}`): everything v1
//! does, plus **register_device** (make a new GPU rankable at runtime),
//! **submit_trace** (predict arbitrary client-profiled workloads by
//! content-hashed `trace_id`), and the cluster suite —
//! **predict_cluster** / **rank_cluster** (topology × world-size sweeps
//! of the data-parallel step-time model, with scaling efficiency and
//! fleet-cost-normalized ranking) and **export_workload** (the
//! predicted compute + collective schedule as COMM_OPS-style JSON) —
//! with structured `{"error":{"code","message"}}` errors. See
//! [`PredictionService::handle_v2`].
//!
//! The server is a **bounded runtime** over `std::net` (the image has
//! no async runtime): a fixed acceptor, at most `HABITAT_MAX_CONNS`
//! concurrent connections (excess connects receive a typed
//! `overloaded` error and are closed), and per-request compute jobs
//! submitted to the engine's shared bounded worker pool — the same
//! pool that runs `rank` fan-out helpers, so 60 destinations and 60
//! concurrent clients draw from one compute budget. A full queue is
//! answered per request with `{"v":2,"error":{"code":"overloaded"}}`
//! instead of piling work (or connections) up at the OS. Connections
//! are pipelined: any number of in-flight lines, answered strictly in
//! order. [`start`] returns a [`ServerHandle`] whose `shutdown` drains
//! in-flight work and joins every runtime thread (tests use it instead
//! of leaking listener threads); [`serve`] wraps it for the CLI.
//!
//! All prediction work funnels into the shared
//! [`crate::engine::PredictionEngine`], so concurrent connections reuse
//! each other's traces, and PJRT MLP execution stays centralized on the
//! batching service thread regardless of how many connections are open.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use crate::comm::{self, ClusterParams, Topology};
use crate::device::{registry, Device, NewDevice, RegisterError};
use crate::engine::PredictionEngine;
use crate::lowering::Precision;
use crate::predict::HybridPredictor;
use crate::tracker::Trace;
use crate::util::json::{self, Json};
use crate::Result;

/// One prediction request (wire format and internal API).
#[derive(Debug, Clone)]
pub struct PredictionRequest {
    /// Model name (see [`crate::models::MODEL_NAMES`]).
    pub model: String,
    pub batch: usize,
    /// Origin GPU short name (e.g. `"t4"`).
    pub origin: String,
    /// Destination GPU short name.
    pub dest: String,
    /// `"fp32"` (default) or `"amp"` — AMP composes Habitat with the
    /// Daydream transformation (§6.1.2).
    pub precision: Option<String>,
}

impl PredictionRequest {
    /// Parse from a JSON object line.
    pub fn from_json(line: &str) -> Result<Self> {
        Self::from_value(&json::parse(line)?)
    }

    fn from_value(v: &Json) -> Result<Self> {
        Ok(PredictionRequest {
            model: v.req_str("model")?.to_string(),
            batch: v.req_usize("batch")?,
            origin: v.req_str("origin")?.to_string(),
            dest: v.req_str("dest")?.to_string(),
            precision: v.get("precision").and_then(Json::as_str).map(str::to_string),
        })
    }

    pub fn to_json(&self) -> String {
        let mut pairs = vec![
            ("model", Json::Str(self.model.clone())),
            ("batch", Json::Num(self.batch as f64)),
            ("origin", Json::Str(self.origin.clone())),
            ("dest", Json::Str(self.dest.clone())),
        ];
        if let Some(p) = &self.precision {
            pairs.push(("precision", Json::Str(p.clone())));
        }
        Json::obj(pairs).dump()
    }
}

/// A rank request: predict one origin trace onto many destinations and
/// order them by cost-normalized throughput.
#[derive(Debug, Clone)]
pub struct RankRequest {
    pub model: String,
    pub batch: usize,
    pub origin: String,
    /// `"fp32"` (default) or `"amp"`.
    pub precision: Option<String>,
    /// Candidate destinations; `None` means every device in the
    /// registry — built-ins plus runtime registrations.
    pub dests: Option<Vec<String>>,
}

impl RankRequest {
    pub fn from_json(line: &str) -> Result<Self> {
        Self::from_value(&json::parse(line)?)
    }

    fn from_value(v: &Json) -> Result<Self> {
        let dests = match v.get("dests") {
            None | Some(Json::Null) => None,
            Some(arr) => {
                let items = arr
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("dests must be an array of device names"))?;
                let mut names = Vec::with_capacity(items.len());
                for it in items {
                    names.push(
                        it.as_str()
                            .ok_or_else(|| anyhow::anyhow!("dests entries must be strings"))?
                            .to_string(),
                    );
                }
                Some(names)
            }
        };
        Ok(RankRequest {
            model: v.req_str("model")?.to_string(),
            batch: v.req_usize("batch")?,
            origin: v.req_str("origin")?.to_string(),
            precision: v.get("precision").and_then(Json::as_str).map(str::to_string),
            dests,
        })
    }

    pub fn to_json(&self) -> String {
        let mut pairs = vec![
            ("rank", Json::Bool(true)),
            ("model", Json::Str(self.model.clone())),
            ("batch", Json::Num(self.batch as f64)),
            ("origin", Json::Str(self.origin.clone())),
        ];
        if let Some(p) = &self.precision {
            pairs.push(("precision", Json::Str(p.clone())));
        }
        if let Some(d) = &self.dests {
            pairs.push((
                "dests",
                Json::Arr(d.iter().map(|s| Json::Str(s.clone())).collect()),
            ));
        }
        Json::obj(pairs).dump()
    }
}

/// Any request shape, as dispatched off the wire: a line with
/// `"rank": true` is a [`RankRequest`], a line with `"stats": true` a
/// stats request, anything else a [`PredictionRequest`].
#[derive(Debug, Clone)]
pub enum Request {
    Predict(PredictionRequest),
    Rank(RankRequest),
    Stats,
}

impl Request {
    pub fn from_json(line: &str) -> Result<Request> {
        Self::from_value(&json::parse(line)?)
    }

    /// Dispatch an already-parsed v1 request value (the service parses
    /// each line once, for the version sniff, and reuses the value here).
    pub fn from_value(v: &Json) -> Result<Request> {
        if matches!(v.get("rank"), Some(Json::Bool(true))) {
            Ok(Request::Rank(RankRequest::from_value(v)?))
        } else if matches!(v.get("stats"), Some(Json::Bool(true))) {
            Ok(Request::Stats)
        } else {
            Ok(Request::Predict(PredictionRequest::from_value(v)?))
        }
    }
}

/// The wire form of a stats request.
pub fn stats_request_json() -> String {
    Json::obj(vec![("stats", Json::Bool(true))]).dump()
}

/// The answer to a stats request: the engine's counter snapshot
/// ([`crate::engine::EngineStats`]) in wire form.
#[derive(Debug, Clone, Copy)]
pub struct StatsResponse {
    /// Cache hits (requests that skipped the tracking pipeline).
    pub trace_hits: u64,
    /// Cache misses (tracking-pipeline executions).
    pub trace_misses: u64,
    /// Trace+plan entries currently resident.
    pub trace_entries: usize,
    /// Compiled-plan builds (cache misses + one-off analyses); the
    /// plan rides the same cache entry as its trace, so cached-plan
    /// reuses equal `trace_hits`.
    pub plan_builds: u64,
    /// Process-wide wave-table counters.
    pub wave_hits: u64,
    pub wave_misses: u64,
    /// Persistent fan-out worker-pool width.
    pub workers: usize,
}

impl From<crate::engine::EngineStats> for StatsResponse {
    fn from(s: crate::engine::EngineStats) -> Self {
        StatsResponse {
            trace_hits: s.trace_hits,
            trace_misses: s.trace_misses,
            trace_entries: s.trace_entries,
            plan_builds: s.plan_builds,
            wave_hits: s.wave_hits,
            wave_misses: s.wave_misses,
            workers: s.workers,
        }
    }
}

impl StatsResponse {
    pub fn to_json(&self) -> String {
        self.to_value().dump()
    }

    /// The v1 stats payload. (The v2 `stats` op extends this with the
    /// open-world counters — `trace_uploads`, `uploaded_entries`,
    /// `devices` — and the store/compile counters — `store_hits`,
    /// `store_misses`, `warm_restores`, `parallel_build_chunks`; v1
    /// keeps its original seven fields bit-for-bit.)
    pub fn to_value(&self) -> Json {
        Json::obj(vec![
            ("trace_hits", Json::Num(self.trace_hits as f64)),
            ("trace_misses", Json::Num(self.trace_misses as f64)),
            ("trace_entries", Json::Num(self.trace_entries as f64)),
            ("plan_builds", Json::Num(self.plan_builds as f64)),
            ("wave_hits", Json::Num(self.wave_hits as f64)),
            ("wave_misses", Json::Num(self.wave_misses as f64)),
            ("workers", Json::Num(self.workers as f64)),
        ])
    }

    pub fn from_json(line: &str) -> Result<Self> {
        let v = json::parse(line)?;
        if let Some(err) = v.get("error").and_then(Json::as_str) {
            anyhow::bail!("server error: {err}");
        }
        let req_u64 = |key: &str| -> Result<u64> {
            Ok(v.req_usize(key)? as u64)
        };
        Ok(StatsResponse {
            trace_hits: req_u64("trace_hits")?,
            trace_misses: req_u64("trace_misses")?,
            trace_entries: v.req_usize("trace_entries")?,
            plan_builds: req_u64("plan_builds")?,
            wave_hits: req_u64("wave_hits")?,
            wave_misses: req_u64("wave_misses")?,
            workers: v.req_usize("workers")?,
        })
    }
}

/// The service's answer: decision-ready metrics.
#[derive(Debug, Clone)]
pub struct PredictionResponse {
    pub model: String,
    pub batch: usize,
    pub origin: String,
    pub dest: String,
    /// Measured iteration time on the origin, ms.
    pub origin_iter_ms: f64,
    /// Predicted iteration time on the destination, ms.
    pub iter_ms: f64,
    /// Predicted training throughput, samples/s.
    pub throughput: f64,
    /// Throughput per rental dollar, if the destination is rentable.
    pub cost_normalized_throughput: Option<f64>,
    /// Fraction of predicted time that came from the MLP predictors.
    pub mlp_time_fraction: f64,
    /// Kernel-varying ops that fell back to wave scaling.
    pub mlp_fallbacks: usize,
}

impl PredictionResponse {
    pub fn to_json(&self) -> String {
        self.to_value().dump()
    }

    pub fn to_value(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("batch", Json::Num(self.batch as f64)),
            ("origin", Json::Str(self.origin.clone())),
            ("dest", Json::Str(self.dest.clone())),
            ("origin_iter_ms", Json::Num(self.origin_iter_ms)),
            ("iter_ms", Json::Num(self.iter_ms)),
            ("throughput", Json::Num(self.throughput)),
            (
                "cost_normalized_throughput",
                self.cost_normalized_throughput.map_or(Json::Null, Json::Num),
            ),
            ("mlp_time_fraction", Json::Num(self.mlp_time_fraction)),
            ("mlp_fallbacks", Json::Num(self.mlp_fallbacks as f64)),
        ])
    }

    /// Parse a response line (used by clients/examples/tests).
    pub fn from_json(line: &str) -> Result<Self> {
        let v = json::parse(line)?;
        if let Some(err) = v.get("error").and_then(Json::as_str) {
            anyhow::bail!("server error: {err}");
        }
        Ok(PredictionResponse {
            model: v.req_str("model")?.to_string(),
            batch: v.req_usize("batch")?,
            origin: v.req_str("origin")?.to_string(),
            dest: v.req_str("dest")?.to_string(),
            origin_iter_ms: v
                .get("origin_iter_ms")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("missing origin_iter_ms"))?,
            iter_ms: v
                .get("iter_ms")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("missing iter_ms"))?,
            throughput: v
                .get("throughput")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("missing throughput"))?,
            cost_normalized_throughput: v.get("cost_normalized_throughput").and_then(Json::as_f64),
            mlp_time_fraction: v.get("mlp_time_fraction").and_then(Json::as_f64).unwrap_or(0.0),
            mlp_fallbacks: v.get("mlp_fallbacks").and_then(Json::as_usize).unwrap_or(0),
        })
    }
}

/// One destination's row in a [`RankResponse`], best decision first.
#[derive(Debug, Clone)]
pub struct RankedDest {
    pub dest: String,
    pub iter_ms: f64,
    pub throughput: f64,
    pub cost_normalized_throughput: Option<f64>,
    pub mlp_time_fraction: f64,
    pub mlp_fallbacks: usize,
}

impl RankedDest {
    fn to_value(&self) -> Json {
        Json::obj(vec![
            ("dest", Json::Str(self.dest.clone())),
            ("iter_ms", Json::Num(self.iter_ms)),
            ("throughput", Json::Num(self.throughput)),
            (
                "cost_normalized_throughput",
                self.cost_normalized_throughput.map_or(Json::Null, Json::Num),
            ),
            ("mlp_time_fraction", Json::Num(self.mlp_time_fraction)),
            ("mlp_fallbacks", Json::Num(self.mlp_fallbacks as f64)),
        ])
    }

    fn from_value(v: &Json) -> Result<Self> {
        Ok(RankedDest {
            dest: v.req_str("dest")?.to_string(),
            iter_ms: v
                .get("iter_ms")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("missing iter_ms"))?,
            throughput: v
                .get("throughput")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("missing throughput"))?,
            cost_normalized_throughput: v.get("cost_normalized_throughput").and_then(Json::as_f64),
            mlp_time_fraction: v.get("mlp_time_fraction").and_then(Json::as_f64).unwrap_or(0.0),
            mlp_fallbacks: v.get("mlp_fallbacks").and_then(Json::as_usize).unwrap_or(0),
        })
    }
}

/// The answer to a [`RankRequest`].
#[derive(Debug, Clone)]
pub struct RankResponse {
    pub model: String,
    pub batch: usize,
    pub origin: String,
    /// Measured iteration time on the origin, ms.
    pub origin_iter_ms: f64,
    /// Every requested destination, sorted: rentable devices by
    /// descending cost-normalized throughput, then unpriced devices by
    /// descending raw throughput.
    pub ranking: Vec<RankedDest>,
}

impl RankResponse {
    pub fn to_json(&self) -> String {
        self.to_value().dump()
    }

    pub fn to_value(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("batch", Json::Num(self.batch as f64)),
            ("origin", Json::Str(self.origin.clone())),
            ("origin_iter_ms", Json::Num(self.origin_iter_ms)),
            (
                "ranking",
                Json::Arr(self.ranking.iter().map(RankedDest::to_value).collect()),
            ),
        ])
    }

    pub fn from_json(line: &str) -> Result<Self> {
        let v = json::parse(line)?;
        if let Some(err) = v.get("error").and_then(Json::as_str) {
            anyhow::bail!("server error: {err}");
        }
        let ranking = v
            .get("ranking")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing ranking array"))?
            .iter()
            .map(RankedDest::from_value)
            .collect::<Result<Vec<_>>>()?;
        Ok(RankResponse {
            model: v.req_str("model")?.to_string(),
            batch: v.req_usize("batch")?,
            origin: v.req_str("origin")?.to_string(),
            origin_iter_ms: v
                .get("origin_iter_ms")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("missing origin_iter_ms"))?,
            ranking,
        })
    }
}

fn error_json(msg: &str) -> String {
    Json::obj(vec![("error", Json::Str(msg.to_string()))]).dump()
}

fn parse_device(name: &str, role: &str) -> Result<Device> {
    Device::parse(name).ok_or_else(|| anyhow::anyhow!("unknown {role} device {name:?}"))
}

fn parse_precision(p: Option<&str>) -> Result<Precision> {
    match p {
        None | Some("fp32") => Ok(Precision::Fp32),
        Some("amp") => Ok(Precision::Amp),
        Some(other) => anyhow::bail!("unknown precision {other:?} (want fp32|amp)"),
    }
}

// ------------------------------------------------------------------ v2 --
//
// The versioned envelope: `{"v":2,"op":"<op>",...}` requests, answered
// with `{"v":2,"op":"<op>",...payload}` on success and
// `{"v":2,"error":{"code","message"}}` on failure. v1 bare-object lines
// (no "v" field) keep flowing through the original code path
// bit-identically. See docs/SERVICE.md for the full schema.

/// Envelope protocol version served by [`PredictionService::handle_v2`].
pub const PROTOCOL_V2: f64 = 2.0;

/// A structured v2 error: a stable machine-readable `code` plus a human
/// message. Codes: `bad_request`, `unsupported_version`,
/// `unsupported_op`, `unknown_device`, `unknown_model`, `unknown_trace`,
/// `invalid_argument`, `conflict`.
struct V2Error {
    code: &'static str,
    message: String,
}

impl V2Error {
    fn new(code: &'static str, message: impl Into<String>) -> V2Error {
        V2Error { code, message: message.into() }
    }
}

type V2Result = std::result::Result<Json, V2Error>;

/// Serialize a v2 error line.
pub fn v2_error_json(code: &str, message: &str) -> String {
    Json::obj(vec![
        ("v", Json::Num(PROTOCOL_V2)),
        (
            "error",
            Json::obj(vec![
                ("code", Json::Str(code.to_string())),
                ("message", Json::Str(message.to_string())),
            ]),
        ),
    ])
    .dump()
}

/// Wrap a payload object in the v2 success envelope.
fn v2_envelope(op: &str, payload: Json, extra: Vec<(&str, Json)>) -> Json {
    let mut m = match payload {
        Json::Obj(m) => m,
        _ => Default::default(),
    };
    m.insert("v".to_string(), Json::Num(PROTOCOL_V2));
    m.insert("op".to_string(), Json::Str(op.to_string()));
    for (k, v) in extra {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

/// Fail on a v2 (or v1) error line; `Ok(())` on a success payload.
/// Client-side counterpart of [`v2_error_json`].
pub fn v2_check_error(v: &Json) -> Result<()> {
    match v.get("error") {
        None => Ok(()),
        Some(Json::Str(msg)) => anyhow::bail!("server error: {msg}"),
        Some(err) => {
            let code = err.get("code").and_then(Json::as_str).unwrap_or("unknown");
            let msg = err.get("message").and_then(Json::as_str).unwrap_or("");
            anyhow::bail!("server error [{code}]: {msg}")
        }
    }
}

fn classify_engine_error(e: &anyhow::Error) -> &'static str {
    let msg = e.to_string();
    if msg.contains("unknown model") {
        "unknown_model"
    } else if msg.contains("unknown trace") {
        "unknown_trace"
    } else {
        "invalid_argument"
    }
}

// --- v2 request builders (used by the Client and the tests) -----------

fn precision_pair(precision: Option<&str>) -> Vec<(&'static str, Json)> {
    match precision {
        Some(p) => vec![("precision", Json::Str(p.to_string()))],
        None => Vec::new(),
    }
}

/// `{"v":2,"op":"predict"}` over a zoo model.
pub fn v2_predict_model_request(
    model: &str,
    batch: usize,
    origin: &str,
    dest: &str,
    precision: Option<&str>,
) -> String {
    let mut pairs = vec![
        ("v", Json::Num(PROTOCOL_V2)),
        ("op", Json::Str("predict".into())),
        ("model", Json::Str(model.to_string())),
        ("batch", Json::Num(batch as f64)),
        ("origin", Json::Str(origin.to_string())),
        ("dest", Json::Str(dest.to_string())),
    ];
    pairs.extend(precision_pair(precision));
    Json::obj(pairs).dump()
}

/// `{"v":2,"op":"predict"}` over a previously submitted trace.
pub fn v2_predict_trace_request(trace_id: &str, dest: &str, precision: Option<&str>) -> String {
    let mut pairs = vec![
        ("v", Json::Num(PROTOCOL_V2)),
        ("op", Json::Str("predict".into())),
        ("trace_id", Json::Str(trace_id.to_string())),
        ("dest", Json::Str(dest.to_string())),
    ];
    pairs.extend(precision_pair(precision));
    Json::obj(pairs).dump()
}

/// `{"v":2,"op":"rank"}` over a previously submitted trace.
pub fn v2_rank_trace_request(
    trace_id: &str,
    dests: Option<&[String]>,
    precision: Option<&str>,
) -> String {
    let mut pairs = vec![
        ("v", Json::Num(PROTOCOL_V2)),
        ("op", Json::Str("rank".into())),
        ("trace_id", Json::Str(trace_id.to_string())),
    ];
    if let Some(d) = dests {
        pairs.push(("dests", Json::Arr(d.iter().map(|s| Json::Str(s.clone())).collect())));
    }
    pairs.extend(precision_pair(precision));
    Json::obj(pairs).dump()
}

/// `{"v":2,"op":"submit_trace"}` with the trace embedded.
pub fn v2_submit_trace_request(trace: &Trace) -> String {
    Json::obj(vec![
        ("v", Json::Num(PROTOCOL_V2)),
        ("op", Json::Str("submit_trace".into())),
        ("trace", trace.to_value()),
    ])
    .dump()
}

/// `{"v":2,"op":"register_device"}` from a device description.
pub fn v2_register_device_request(d: &NewDevice) -> String {
    let mut pairs = vec![
        ("v", Json::Num(PROTOCOL_V2)),
        ("op", Json::Str("register_device".into())),
        ("name", Json::Str(d.name.clone())),
        ("sms", Json::Num(d.sms as f64)),
        ("clock_mhz", Json::Num(d.clock_mhz)),
        ("mem_bw_gbps", Json::Num(d.mem_bw_gbps)),
        ("fp32_tflops", Json::Num(d.fp32_tflops)),
        ("tensor_cores", Json::Bool(d.tensor_cores)),
    ];
    if let Some(p) = d.usd_per_hr {
        pairs.push(("usd_per_hr", Json::Num(p)));
    }
    if let Some(a) = d.arch {
        pairs.push(("arch", Json::Str(a.to_string().to_ascii_lowercase())));
    }
    if let Some(x) = d.achieved_bw_gbps {
        pairs.push(("achieved_bw_gbps", Json::Num(x)));
    }
    if let Some(x) = d.mem_gib {
        pairs.push(("mem_gib", Json::Num(x)));
    }
    if let Some(x) = d.fp16_tflops {
        pairs.push(("fp16_tflops", Json::Num(x)));
    }
    if let Some(x) = d.cuda_cores {
        pairs.push(("cuda_cores", Json::Num(x as f64)));
    }
    if let Some(x) = d.l2_kib {
        pairs.push(("l2_kib", Json::Num(x as f64)));
    }
    Json::obj(pairs).dump()
}

/// `{"v":2,"op":"stats"}`.
pub fn v2_stats_request() -> String {
    Json::obj(vec![("v", Json::Num(PROTOCOL_V2)), ("op", Json::Str("stats".into()))]).dump()
}

// --- cluster ops (v2 only) --------------------------------------------

/// Default world-size sweep for the cluster ops when the request omits
/// `worlds`: powers of two through 256 ranks.
pub const DEFAULT_CLUSTER_WORLDS: [usize; 9] = [1, 2, 4, 8, 16, 32, 64, 128, 256];

/// Largest accepted world size in a cluster sweep.
const MAX_CLUSTER_WORLD: usize = 65_536;

/// Cap on `dests × topologies × worlds` cells in one cluster request.
const MAX_CLUSTER_SWEEP: usize = 16_384;

/// One (topology, world) cell of a [`ClusterResponse`].
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub topology: String,
    pub world: usize,
    /// Predicted per-iteration wall time, ms (compute + exposed comm).
    pub iter_ms: f64,
    /// Raw bucketed-allreduce time before overlap, ms.
    pub comm_ms: f64,
    /// Communication left exposed after overlap with backward, ms.
    pub exposed_ms: f64,
    /// Global throughput, samples/s across all ranks.
    pub throughput: f64,
    /// Scaling efficiency vs perfect linear scaling, in (0, 1].
    pub efficiency: f64,
    /// Global samples/s per total fleet $/hr; `None` when unpriced.
    pub cost_normalized_throughput: Option<f64>,
}

impl ClusterConfig {
    fn to_value(&self) -> Json {
        Json::obj(vec![
            ("topology", Json::Str(self.topology.clone())),
            ("world", Json::Num(self.world as f64)),
            ("iter_ms", Json::Num(self.iter_ms)),
            ("comm_ms", Json::Num(self.comm_ms)),
            ("exposed_ms", Json::Num(self.exposed_ms)),
            ("throughput", Json::Num(self.throughput)),
            ("efficiency", Json::Num(self.efficiency)),
            (
                "cost_normalized_throughput",
                self.cost_normalized_throughput.map_or(Json::Null, Json::Num),
            ),
        ])
    }

    fn from_value(v: &Json) -> Result<Self> {
        let num = |k: &str| -> Result<f64> {
            v.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("missing/invalid number field {k:?}"))
        };
        Ok(ClusterConfig {
            topology: v.req_str("topology")?.to_string(),
            world: v.req_usize("world")?,
            iter_ms: num("iter_ms")?,
            comm_ms: num("comm_ms")?,
            exposed_ms: num("exposed_ms")?,
            throughput: num("throughput")?,
            efficiency: num("efficiency")?,
            cost_normalized_throughput: v.get("cost_normalized_throughput").and_then(Json::as_f64),
        })
    }
}

/// The answer to a `predict_cluster` request: one destination swept
/// across a topology × world grid (topology-major, request order).
#[derive(Debug, Clone)]
pub struct ClusterResponse {
    pub model: String,
    pub batch: usize,
    pub origin: String,
    pub dest: String,
    /// Per-replica single-GPU compute time shared by every cell, ms.
    pub compute_ms: f64,
    pub configs: Vec<ClusterConfig>,
}

impl ClusterResponse {
    pub fn to_value(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("batch", Json::Num(self.batch as f64)),
            ("origin", Json::Str(self.origin.clone())),
            ("dest", Json::Str(self.dest.clone())),
            ("compute_ms", Json::Num(self.compute_ms)),
            (
                "configs",
                Json::Arr(self.configs.iter().map(ClusterConfig::to_value).collect()),
            ),
        ])
    }

    pub fn from_json(line: &str) -> Result<Self> {
        let v = json::parse(line)?;
        v2_check_error(&v)?;
        Ok(ClusterResponse {
            model: v.req_str("model")?.to_string(),
            batch: v.req_usize("batch")?,
            origin: v.req_str("origin")?.to_string(),
            dest: v.req_str("dest")?.to_string(),
            compute_ms: v
                .get("compute_ms")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("missing compute_ms"))?,
            configs: v
                .get("configs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("missing configs array"))?
                .iter()
                .map(ClusterConfig::from_value)
                .collect::<Result<Vec<_>>>()?,
        })
    }
}

/// One entry of a [`ClusterRankResponse`], best decision first.
#[derive(Debug, Clone)]
pub struct ClusterRankedConfig {
    pub dest: String,
    pub topology: String,
    pub world: usize,
    pub iter_ms: f64,
    pub throughput: f64,
    pub efficiency: f64,
    pub cost_normalized_throughput: Option<f64>,
}

impl ClusterRankedConfig {
    fn to_value(&self) -> Json {
        Json::obj(vec![
            ("dest", Json::Str(self.dest.clone())),
            ("topology", Json::Str(self.topology.clone())),
            ("world", Json::Num(self.world as f64)),
            ("iter_ms", Json::Num(self.iter_ms)),
            ("throughput", Json::Num(self.throughput)),
            ("efficiency", Json::Num(self.efficiency)),
            (
                "cost_normalized_throughput",
                self.cost_normalized_throughput.map_or(Json::Null, Json::Num),
            ),
        ])
    }

    fn from_value(v: &Json) -> Result<Self> {
        let num = |k: &str| -> Result<f64> {
            v.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("missing/invalid number field {k:?}"))
        };
        Ok(ClusterRankedConfig {
            dest: v.req_str("dest")?.to_string(),
            topology: v.req_str("topology")?.to_string(),
            world: v.req_usize("world")?,
            iter_ms: num("iter_ms")?,
            throughput: num("throughput")?,
            efficiency: num("efficiency")?,
            cost_normalized_throughput: v.get("cost_normalized_throughput").and_then(Json::as_f64),
        })
    }
}

/// The answer to a `rank_cluster` request: every (destination, topology,
/// world) configuration, ordered like `rank` — priced fleets by
/// descending cost-normalized throughput, then unpriced by raw global
/// throughput.
#[derive(Debug, Clone)]
pub struct ClusterRankResponse {
    pub model: String,
    pub batch: usize,
    pub origin: String,
    pub ranking: Vec<ClusterRankedConfig>,
}

impl ClusterRankResponse {
    pub fn to_value(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("batch", Json::Num(self.batch as f64)),
            ("origin", Json::Str(self.origin.clone())),
            (
                "ranking",
                Json::Arr(self.ranking.iter().map(ClusterRankedConfig::to_value).collect()),
            ),
        ])
    }

    pub fn from_json(line: &str) -> Result<Self> {
        let v = json::parse(line)?;
        v2_check_error(&v)?;
        Ok(ClusterRankResponse {
            model: v.req_str("model")?.to_string(),
            batch: v.req_usize("batch")?,
            origin: v.req_str("origin")?.to_string(),
            ranking: v
                .get("ranking")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("missing ranking array"))?
                .iter()
                .map(ClusterRankedConfig::from_value)
                .collect::<Result<Vec<_>>>()?,
        })
    }
}

fn cluster_grid_pairs(
    topologies: Option<&[String]>,
    worlds: Option<&[usize]>,
) -> Vec<(&'static str, Json)> {
    let mut pairs = Vec::new();
    if let Some(t) = topologies {
        pairs.push((
            "topologies",
            Json::Arr(t.iter().map(|s| Json::Str(s.clone())).collect()),
        ));
    }
    if let Some(w) = worlds {
        pairs.push((
            "worlds",
            Json::Arr(w.iter().map(|&x| Json::Num(x as f64)).collect()),
        ));
    }
    pairs
}

/// `{"v":2,"op":"predict_cluster"}` over a zoo model. `None` topologies
/// and worlds mean the server defaults (every registered topology,
/// [`DEFAULT_CLUSTER_WORLDS`]).
pub fn v2_predict_cluster_request(
    model: &str,
    batch: usize,
    origin: &str,
    dest: &str,
    topologies: Option<&[String]>,
    worlds: Option<&[usize]>,
    precision: Option<&str>,
) -> String {
    let mut pairs = vec![
        ("v", Json::Num(PROTOCOL_V2)),
        ("op", Json::Str("predict_cluster".into())),
        ("model", Json::Str(model.to_string())),
        ("batch", Json::Num(batch as f64)),
        ("origin", Json::Str(origin.to_string())),
        ("dest", Json::Str(dest.to_string())),
    ];
    pairs.extend(cluster_grid_pairs(topologies, worlds));
    pairs.extend(precision_pair(precision));
    Json::obj(pairs).dump()
}

/// `{"v":2,"op":"rank_cluster"}` over a zoo model. `None` dests mean
/// every registered device.
#[allow(clippy::too_many_arguments)]
pub fn v2_rank_cluster_request(
    model: &str,
    batch: usize,
    origin: &str,
    dests: Option<&[String]>,
    topologies: Option<&[String]>,
    worlds: Option<&[usize]>,
    precision: Option<&str>,
) -> String {
    let mut pairs = vec![
        ("v", Json::Num(PROTOCOL_V2)),
        ("op", Json::Str("rank_cluster".into())),
        ("model", Json::Str(model.to_string())),
        ("batch", Json::Num(batch as f64)),
        ("origin", Json::Str(origin.to_string())),
    ];
    if let Some(d) = dests {
        pairs.push(("dests", Json::Arr(d.iter().map(|s| Json::Str(s.clone())).collect())));
    }
    pairs.extend(cluster_grid_pairs(topologies, worlds));
    pairs.extend(precision_pair(precision));
    Json::obj(pairs).dump()
}

/// `{"v":2,"op":"export_workload"}`: one (dest, topology, world)
/// configuration's predicted compute + collective schedule.
pub fn v2_export_workload_request(
    model: &str,
    batch: usize,
    origin: &str,
    dest: &str,
    topology: &str,
    world: usize,
    precision: Option<&str>,
) -> String {
    let mut pairs = vec![
        ("v", Json::Num(PROTOCOL_V2)),
        ("op", Json::Str("export_workload".into())),
        ("model", Json::Str(model.to_string())),
        ("batch", Json::Num(batch as f64)),
        ("origin", Json::Str(origin.to_string())),
        ("dest", Json::Str(dest.to_string())),
        ("topology", Json::Str(topology.to_string())),
        ("world", Json::Num(world as f64)),
    ];
    pairs.extend(precision_pair(precision));
    Json::obj(pairs).dump()
}

/// The `register_device` acknowledgement (client-side view).
#[derive(Debug, Clone)]
pub struct RegisteredDevice {
    /// Canonical device name (as stored in the registry).
    pub device: String,
    /// Interned registry index on the server.
    pub id: usize,
    /// Registry size after the registration.
    pub devices: usize,
}

impl RegisteredDevice {
    pub fn from_json(line: &str) -> Result<RegisteredDevice> {
        let v = json::parse(line)?;
        v2_check_error(&v)?;
        Ok(RegisteredDevice {
            device: v.req_str("device")?.to_string(),
            id: v.req_usize("id")?,
            devices: v.req_usize("devices")?,
        })
    }
}

fn new_device_from_value(v: &Json) -> std::result::Result<NewDevice, V2Error> {
    let req_num = |k: &str| -> std::result::Result<f64, V2Error> {
        v.get(k)
            .and_then(Json::as_f64)
            .ok_or_else(|| V2Error::new("bad_request", format!("missing/invalid number field {k:?}")))
    };
    let opt_num = |k: &str| v.get(k).and_then(Json::as_f64);
    let opt_u32 = |k: &str| v.get(k).and_then(Json::as_usize).map(|x| x as u32);
    let arch = match v.get("arch").and_then(Json::as_str) {
        None => None,
        Some(s) => Some(crate::device::Arch::parse(s).ok_or_else(|| {
            V2Error::new("invalid_argument", format!("unknown arch {s:?} (want pascal|volta|turing)"))
        })?),
    };
    Ok(NewDevice {
        name: v
            .req_str("name")
            .map_err(|e| V2Error::new("bad_request", e.to_string()))?
            .to_string(),
        sms: v
            .req_usize("sms")
            .map_err(|e| V2Error::new("bad_request", e.to_string()))? as u32,
        clock_mhz: req_num("clock_mhz")?,
        mem_bw_gbps: req_num("mem_bw_gbps")?,
        fp32_tflops: req_num("fp32_tflops")?,
        // Absent `tensor_cores` defaults from an explicit arch (so
        // `"arch":"turing"` alone is valid); bare requests default false.
        tensor_cores: match v.get("tensor_cores") {
            Some(Json::Bool(b)) => *b,
            _ => arch.map_or(false, |a| a.has_tensor_cores()),
        },
        usd_per_hr: opt_num("usd_per_hr"),
        arch,
        achieved_bw_gbps: opt_num("achieved_bw_gbps"),
        mem_gib: opt_num("mem_gib"),
        fp16_tflops: opt_num("fp16_tflops"),
        cuda_cores: opt_u32("cuda_cores"),
        l2_kib: opt_u32("l2_kib"),
    })
}

/// The TCP-facing prediction service: a thin protocol layer over the
/// shared [`PredictionEngine`].
pub struct PredictionService {
    engine: PredictionEngine,
}

impl PredictionService {
    /// Build with the paper's full hybrid predictor (requires artifacts).
    pub fn new(artifacts: &str) -> Result<Self> {
        Ok(Self::with_engine(PredictionEngine::from_artifacts(artifacts)?))
    }

    /// Build around any predictor (wave-only for tests / no artifacts).
    pub fn with_predictor(predictor: HybridPredictor) -> Self {
        Self::with_engine(PredictionEngine::new(predictor))
    }

    /// Build around an existing engine (shared caches, custom capacity).
    pub fn with_engine(engine: PredictionEngine) -> Self {
        PredictionService { engine }
    }

    /// Attach (and warm-restore) a persistent plan store — see
    /// [`PredictionEngine::attach_store`].
    pub fn attach_store<P: AsRef<std::path::Path>>(&mut self, dir: P) -> Result<()> {
        self.engine.attach_store(dir)
    }

    pub fn engine(&self) -> &PredictionEngine {
        &self.engine
    }

    pub fn predictor(&self) -> &HybridPredictor {
        self.engine.predictor()
    }

    /// Get or build the origin trace for a request (memoized in the
    /// engine). The tracker always measures FP32 — the paper profiles
    /// FP32 and *predicts* AMP.
    pub fn trace_for(&self, model: &str, batch: usize, origin: Device) -> Result<Arc<Trace>> {
        self.engine.trace(model, batch, origin)
    }

    /// Handle one prediction request synchronously.
    pub fn handle(&self, req: &PredictionRequest) -> Result<PredictionResponse> {
        let origin = parse_device(&req.origin, "origin")?;
        let dest = parse_device(&req.dest, "destination")?;
        let precision = parse_precision(req.precision.as_deref())?;
        anyhow::ensure!(req.batch > 0, "batch must be positive");

        let out = self.engine.predict(&req.model, req.batch, origin, dest, precision)?;
        let tput = out.pred.throughput();
        Ok(PredictionResponse {
            model: req.model.clone(),
            batch: req.batch,
            origin: origin.id().to_string(),
            dest: dest.id().to_string(),
            origin_iter_ms: out.trace.run_time_ms(),
            iter_ms: out.pred.run_time_ms(),
            throughput: tput,
            cost_normalized_throughput: crate::cost::cost_normalized_throughput(dest, tput),
            mlp_time_fraction: out.pred.mlp_time_fraction(),
            mlp_fallbacks: out.pred.mlp_fallbacks,
        })
    }

    /// Handle one rank request: a single tracking pass, fanned out to
    /// every destination on the engine's worker pool.
    pub fn handle_rank(&self, req: &RankRequest) -> Result<RankResponse> {
        let origin = parse_device(&req.origin, "origin")?;
        let precision = parse_precision(req.precision.as_deref())?;
        anyhow::ensure!(req.batch > 0, "batch must be positive");
        // Default destination set: every device in the registry —
        // including GPUs registered at runtime via `register_device`.
        let dests: Vec<Device> = match &req.dests {
            None => registry::all_devices(),
            Some(names) => names
                .iter()
                .map(|n| parse_device(n, "destination"))
                .collect::<Result<Vec<_>>>()?,
        };

        let ranking = self.engine.rank(&req.model, req.batch, origin, &dests, precision)?;
        Ok(RankResponse {
            model: req.model.clone(),
            batch: req.batch,
            origin: origin.id().to_string(),
            origin_iter_ms: ranking.trace.run_time_ms(),
            ranking: ranking
                .entries
                .iter()
                .map(|e| RankedDest {
                    dest: e.dest.id().to_string(),
                    iter_ms: e.pred.run_time_ms(),
                    throughput: e.pred.throughput(),
                    cost_normalized_throughput: e.cost_normalized_throughput,
                    mlp_time_fraction: e.pred.mlp_time_fraction(),
                    mlp_fallbacks: e.pred.mlp_fallbacks,
                })
                .collect(),
        })
    }

    /// Handle a stats request: the engine's counter snapshot.
    pub fn handle_stats(&self) -> StatsResponse {
        self.engine.stats().into()
    }

    /// Parse one wire line, dispatch it, and serialize the reply.
    ///
    /// Version routing: a line with `"v":2` takes the v2 envelope path;
    /// any other `"v"` value gets a structured `unsupported_version`
    /// error; a line with no `"v"` field is a v1 request and flows
    /// through the original code path **bit-identically** (pinned by the
    /// golden suite and the CI service smoke).
    pub fn handle_line(&self, line: &str) -> String {
        // One parse per line: the version sniff and the v1 dispatch
        // share the same value.
        let request = match json::parse(line) {
            Ok(v) => {
                match v.get("v") {
                    Some(Json::Num(n)) if *n == PROTOCOL_V2 => return self.handle_v2(&v),
                    Some(other) => {
                        return v2_error_json(
                            "unsupported_version",
                            &format!("unsupported protocol version {}", other.dump()),
                        )
                    }
                    None => {}
                }
                Request::from_value(&v)
            }
            Err(e) => Err(e),
        };
        match request {
            Ok(Request::Predict(req)) => match self.handle(&req) {
                Ok(resp) => resp.to_json(),
                Err(e) => error_json(&e.to_string()),
            },
            Ok(Request::Rank(req)) => match self.handle_rank(&req) {
                Ok(resp) => resp.to_json(),
                Err(e) => error_json(&e.to_string()),
            },
            Ok(Request::Stats) => self.handle_stats().to_json(),
            Err(e) => error_json(&format!("bad request: {e}")),
        }
    }

    /// Dispatch one parsed v2 envelope and serialize the reply.
    pub fn handle_v2(&self, v: &Json) -> String {
        match self.dispatch_v2(v) {
            Ok(reply) => reply.dump(),
            Err(e) => v2_error_json(e.code, &e.message),
        }
    }

    fn dispatch_v2(&self, v: &Json) -> V2Result {
        let op = v
            .req_str("op")
            .map_err(|_| V2Error::new("bad_request", "missing string field \"op\""))?;
        match op {
            "predict" => self.v2_predict(v),
            "rank" => self.v2_rank(v),
            "stats" => Ok(self.v2_stats()),
            "submit_trace" => self.v2_submit_trace(v),
            "register_device" => self.v2_register_device(v),
            "predict_cluster" => self.v2_predict_cluster(v),
            "rank_cluster" => self.v2_rank_cluster(v),
            "export_workload" => self.v2_export_workload(v),
            other => Err(V2Error::new(
                "unsupported_op",
                format!("unsupported op {other:?} (want predict|rank|stats|submit_trace|register_device|predict_cluster|rank_cluster|export_workload)"),
            )),
        }
    }

    fn v2_precision(v: &Json) -> std::result::Result<Precision, V2Error> {
        parse_precision(v.get("precision").and_then(Json::as_str))
            .map_err(|e| V2Error::new("invalid_argument", e.to_string()))
    }

    fn v2_dest(v: &Json) -> std::result::Result<Device, V2Error> {
        let name = v
            .req_str("dest")
            .map_err(|_| V2Error::new("bad_request", "missing string field \"dest\""))?;
        parse_device(name, "destination").map_err(|e| V2Error::new("unknown_device", e.to_string()))
    }

    fn v2_predict(&self, v: &Json) -> V2Result {
        let precision = Self::v2_precision(v)?;
        let dest = Self::v2_dest(v)?;
        if let Some(trace_id) = v.get("trace_id").and_then(Json::as_str) {
            let out = self
                .engine
                .predict_uploaded(trace_id, dest, precision)
                .map_err(|e| V2Error::new(classify_engine_error(&e), e.to_string()))?;
            let resp = Self::prediction_response(&out);
            Ok(v2_envelope(
                "predict",
                resp.to_value(),
                vec![("trace_id", Json::Str(trace_id.to_string()))],
            ))
        } else {
            let req = PredictionRequest::from_value(v)
                .map_err(|e| V2Error::new("bad_request", e.to_string()))?;
            let resp = self
                .handle(&req)
                .map_err(|e| V2Error::new(Self::classify_v1(&e), e.to_string()))?;
            Ok(v2_envelope("predict", resp.to_value(), Vec::new()))
        }
    }

    fn v2_rank(&self, v: &Json) -> V2Result {
        if let Some(trace_id) = v.get("trace_id").and_then(Json::as_str) {
            let precision = Self::v2_precision(v)?;
            let dests = Self::v2_dests(v)?;
            let ranking = self
                .engine
                .rank_uploaded(trace_id, &dests, precision)
                .map_err(|e| V2Error::new(classify_engine_error(&e), e.to_string()))?;
            let resp = Self::rank_response(&ranking);
            Ok(v2_envelope(
                "rank",
                resp.to_value(),
                vec![("trace_id", Json::Str(trace_id.to_string()))],
            ))
        } else {
            let req = RankRequest::from_value(v)
                .map_err(|e| V2Error::new("bad_request", e.to_string()))?;
            let resp = self
                .handle_rank(&req)
                .map_err(|e| V2Error::new(Self::classify_v1(&e), e.to_string()))?;
            Ok(v2_envelope("rank", resp.to_value(), Vec::new()))
        }
    }

    fn v2_stats(&self) -> Json {
        let s = self.engine.stats();
        v2_envelope(
            "stats",
            StatsResponse::from(s).to_value(),
            vec![
                ("trace_uploads", Json::Num(s.trace_uploads as f64)),
                ("uploaded_entries", Json::Num(s.uploaded_entries as f64)),
                ("devices", Json::Num(s.devices as f64)),
                ("store_hits", Json::Num(s.store_hits as f64)),
                ("store_misses", Json::Num(s.store_misses as f64)),
                ("warm_restores", Json::Num(s.warm_restores as f64)),
                (
                    "parallel_build_chunks",
                    Json::Num(s.parallel_build_chunks as f64),
                ),
            ],
        )
    }

    fn v2_submit_trace(&self, v: &Json) -> V2Result {
        let tv = v
            .get("trace")
            .ok_or_else(|| V2Error::new("bad_request", "missing object field \"trace\""))?;
        let trace = Trace::from_value(tv)
            .map_err(|e| V2Error::new("invalid_argument", format!("bad trace: {e}")))?;
        let (trace_id, analyzed) = self
            .engine
            .submit_trace(trace)
            .map_err(|e| V2Error::new("invalid_argument", e.to_string()))?;
        Ok(v2_envelope(
            "submit_trace",
            Json::obj(vec![
                ("trace_id", Json::Str(trace_id)),
                ("model", Json::Str(analyzed.trace.model.clone())),
                ("batch", Json::Num(analyzed.trace.batch_size as f64)),
                ("origin", Json::Str(analyzed.trace.origin.id().to_string())),
                ("ops", Json::Num(analyzed.trace.ops.len() as f64)),
                ("origin_iter_ms", Json::Num(analyzed.trace.run_time_ms())),
            ]),
            Vec::new(),
        ))
    }

    fn v2_register_device(&self, v: &Json) -> V2Result {
        let desc = new_device_from_value(v)?;
        // Through the engine, not the bare registry: a genuinely new
        // device gets its lane appended to every cached plan once and
        // is logged to the persistent store's device log.
        let d = self.engine.register_device(&desc).map_err(|e| match e {
            RegisterError::Conflict(m) => V2Error::new("conflict", m),
            RegisterError::Invalid(m) => V2Error::new("invalid_argument", m),
        })?;
        let s = d.spec();
        Ok(v2_envelope(
            "register_device",
            Json::obj(vec![
                ("device", Json::Str(s.name.to_string())),
                ("id", Json::Num(d.index() as f64)),
                ("arch", Json::Str(s.arch.to_string())),
                ("sms", Json::Num(s.sms as f64)),
                ("mem_gib", Json::Num(s.mem_gib)),
                ("peak_mem_bw_gbps", Json::Num(s.peak_mem_bw_gbps)),
                ("achieved_mem_bw_gbps", Json::Num(s.achieved_mem_bw_gbps)),
                ("clock_mhz", Json::Num(s.boost_clock_mhz)),
                ("fp32_tflops", Json::Num(s.peak_fp32_tflops)),
                ("fp16_tflops", Json::Num(s.peak_fp16_tflops)),
                ("usd_per_hr", s.rental_usd_per_hr.map_or(Json::Null, Json::Num)),
                ("devices", Json::Num(registry::device_count() as f64)),
            ]),
            Vec::new(),
        ))
    }

    // --- cluster ops --------------------------------------------------

    fn v2_predict_cluster(&self, v: &Json) -> V2Result {
        let precision = Self::v2_precision(v)?;
        let dest = Self::v2_dest(v)?;
        let topologies = Self::v2_topologies(v)?;
        let worlds = Self::v2_worlds(v)?;
        let params = Self::v2_cluster_params(v)?;
        Self::check_sweep(topologies.len().saturating_mul(worlds.len()))?;
        if let Some(trace_id) = v.get("trace_id").and_then(Json::as_str) {
            let report = self
                .engine
                .predict_cluster_uploaded(trace_id, dest, precision, &topologies, &worlds, &params)
                .map_err(|e| V2Error::new(classify_engine_error(&e), e.to_string()))?;
            Ok(v2_envelope(
                "predict_cluster",
                Self::cluster_response(&report).to_value(),
                vec![("trace_id", Json::Str(trace_id.to_string()))],
            ))
        } else {
            let (model, batch, origin) = Self::v2_model_origin(v)?;
            let report = self
                .engine
                .predict_cluster(&model, batch, origin, dest, precision, &topologies, &worlds, &params)
                .map_err(|e| V2Error::new(classify_engine_error(&e), e.to_string()))?;
            Ok(v2_envelope("predict_cluster", Self::cluster_response(&report).to_value(), Vec::new()))
        }
    }

    fn v2_rank_cluster(&self, v: &Json) -> V2Result {
        let precision = Self::v2_precision(v)?;
        let dests = Self::v2_dests(v)?;
        let topologies = Self::v2_topologies(v)?;
        let worlds = Self::v2_worlds(v)?;
        let params = Self::v2_cluster_params(v)?;
        Self::check_sweep(
            dests
                .len()
                .saturating_mul(topologies.len())
                .saturating_mul(worlds.len()),
        )?;
        if let Some(trace_id) = v.get("trace_id").and_then(Json::as_str) {
            let ranking = self
                .engine
                .rank_cluster_uploaded(trace_id, &dests, precision, &topologies, &worlds, &params)
                .map_err(|e| V2Error::new(classify_engine_error(&e), e.to_string()))?;
            Ok(v2_envelope(
                "rank_cluster",
                Self::cluster_rank_response(&ranking).to_value(),
                vec![("trace_id", Json::Str(trace_id.to_string()))],
            ))
        } else {
            let (model, batch, origin) = Self::v2_model_origin(v)?;
            let ranking = self
                .engine
                .rank_cluster(&model, batch, origin, &dests, precision, &topologies, &worlds, &params)
                .map_err(|e| V2Error::new(classify_engine_error(&e), e.to_string()))?;
            Ok(v2_envelope("rank_cluster", Self::cluster_rank_response(&ranking).to_value(), Vec::new()))
        }
    }

    fn v2_export_workload(&self, v: &Json) -> V2Result {
        let precision = Self::v2_precision(v)?;
        let dest = Self::v2_dest(v)?;
        let topology = match v.get("topology") {
            None | Some(Json::Null) => {
                return Err(V2Error::new("bad_request", "missing field \"topology\""))
            }
            Some(it) => Self::v2_topology_entry(it)?,
        };
        let world = v
            .req_usize("world")
            .map_err(|e| V2Error::new("bad_request", e.to_string()))?;
        if !(1..=MAX_CLUSTER_WORLD).contains(&world) {
            return Err(V2Error::new(
                "invalid_argument",
                format!("world size {world} out of range 1..={MAX_CLUSTER_WORLD}"),
            ));
        }
        let params = Self::v2_cluster_params(v)?;
        let (model, batch, origin) = Self::v2_model_origin(v)?;
        let workload = self
            .engine
            .export_workload(&model, batch, origin, dest, precision, topology, world, &params)
            .map_err(|e| V2Error::new(classify_engine_error(&e), e.to_string()))?;
        Ok(v2_envelope("export_workload", workload.to_value(), Vec::new()))
    }

    /// Common `model`/`batch`/`origin` triple of the zoo-model paths.
    fn v2_model_origin(v: &Json) -> std::result::Result<(String, usize, Device), V2Error> {
        let model = v
            .req_str("model")
            .map_err(|e| V2Error::new("bad_request", e.to_string()))?
            .to_string();
        let batch = v
            .req_usize("batch")
            .map_err(|e| V2Error::new("bad_request", e.to_string()))?;
        let origin_name = v
            .req_str("origin")
            .map_err(|e| V2Error::new("bad_request", e.to_string()))?;
        let origin = parse_device(origin_name, "origin")
            .map_err(|e| V2Error::new("unknown_device", e.to_string()))?;
        Ok((model, batch, origin))
    }

    /// Resolve a v2 `topologies` field: names and/or inline topology
    /// objects, or every registered topology when absent.
    fn v2_topologies(v: &Json) -> std::result::Result<Vec<Topology>, V2Error> {
        match v.get("topologies") {
            None | Some(Json::Null) => Ok(comm::topology::all_topologies()),
            Some(arr) => {
                let items = arr.as_arr().ok_or_else(|| {
                    V2Error::new("bad_request", "topologies must be an array of names or objects")
                })?;
                if items.is_empty() {
                    return Err(V2Error::new("invalid_argument", "topologies must be non-empty"));
                }
                items.iter().map(Self::v2_topology_entry).collect()
            }
        }
    }

    /// One topology entry: a registered name, or an inline
    /// `{"name","gpus_per_node","intra","inter"}` object (registered
    /// through the interning registry, idempotently).
    fn v2_topology_entry(it: &Json) -> std::result::Result<Topology, V2Error> {
        match it {
            Json::Str(name) => comm::topology::find_topology(name).ok_or_else(|| {
                V2Error::new(
                    "unknown_topology",
                    format!(
                        "unknown topology {name:?} (known: {})",
                        comm::topology::topology_names().join("|")
                    ),
                )
            }),
            Json::Obj(_) => {
                let name = it
                    .req_str("name")
                    .map_err(|_| V2Error::new("bad_request", "inline topology needs string field \"name\""))?;
                let gpus_per_node = it.req_usize("gpus_per_node").map_err(|_| {
                    V2Error::new("bad_request", "inline topology needs integer field \"gpus_per_node\"")
                })?;
                let intra = Self::v2_link(it.get("intra"), "intra")?;
                let inter = Self::v2_link(it.get("inter"), "inter")?;
                comm::topology::register_topology(&comm::NewTopology {
                    name: name.to_string(),
                    gpus_per_node: gpus_per_node as u32,
                    intra,
                    inter,
                })
                .map_err(Self::register_error)
            }
            _ => Err(V2Error::new(
                "bad_request",
                "topologies entries must be topology names or inline objects",
            )),
        }
    }

    /// One link field of an inline topology: a registered name, or an
    /// inline `{"name","bandwidth_gbps","step_latency_ms"?}` object.
    fn v2_link(it: Option<&Json>, role: &str) -> std::result::Result<comm::Link, V2Error> {
        let it = it.ok_or_else(|| {
            V2Error::new("bad_request", format!("inline topology needs field {role:?}"))
        })?;
        match it {
            Json::Str(name) => comm::find_link(name).ok_or_else(|| {
                V2Error::new(
                    "unknown_link",
                    format!(
                        "unknown {role} link {name:?} (known: {})",
                        comm::link_names().join("|")
                    ),
                )
            }),
            Json::Obj(_) => {
                let name = it.req_str("name").map_err(|_| {
                    V2Error::new("bad_request", format!("inline {role} link needs string field \"name\""))
                })?;
                let bandwidth_gbps = it.get("bandwidth_gbps").and_then(Json::as_f64).ok_or_else(|| {
                    V2Error::new(
                        "bad_request",
                        format!("inline {role} link needs number field \"bandwidth_gbps\""),
                    )
                })?;
                let step_latency_ms =
                    it.get("step_latency_ms").and_then(Json::as_f64).unwrap_or(0.01);
                comm::register_link(&comm::NewLink {
                    name: name.to_string(),
                    bandwidth_gbps,
                    step_latency_ms,
                })
                .map_err(Self::register_error)
            }
            _ => Err(V2Error::new(
                "bad_request",
                format!("{role} link must be a link name or an inline object"),
            )),
        }
    }

    /// Resolve a v2 `worlds` field ([`DEFAULT_CLUSTER_WORLDS`] when
    /// absent).
    fn v2_worlds(v: &Json) -> std::result::Result<Vec<usize>, V2Error> {
        match v.get("worlds") {
            None | Some(Json::Null) => Ok(DEFAULT_CLUSTER_WORLDS.to_vec()),
            Some(arr) => {
                let items = arr.as_arr().ok_or_else(|| {
                    V2Error::new("bad_request", "worlds must be an array of rank counts")
                })?;
                if items.is_empty() {
                    return Err(V2Error::new("invalid_argument", "worlds must be non-empty"));
                }
                items
                    .iter()
                    .map(|it| {
                        let w = it.as_usize().ok_or_else(|| {
                            V2Error::new("bad_request", "worlds entries must be non-negative integers")
                        })?;
                        if !(1..=MAX_CLUSTER_WORLD).contains(&w) {
                            return Err(V2Error::new(
                                "invalid_argument",
                                format!("world size {w} out of range 1..={MAX_CLUSTER_WORLD}"),
                            ));
                        }
                        Ok(w)
                    })
                    .collect()
            }
        }
    }

    /// Optional overlap/bucket knobs → [`ClusterParams`].
    fn v2_cluster_params(v: &Json) -> std::result::Result<ClusterParams, V2Error> {
        let mut params = ClusterParams::default();
        if let Some(x) = v.get("overlap") {
            params.overlap = x
                .as_f64()
                .filter(|o| (0.0..=1.0).contains(o))
                .ok_or_else(|| V2Error::new("invalid_argument", "overlap must be a number in 0..=1"))?;
        }
        if let Some(x) = v.get("bucket_mib") {
            let mib = x
                .as_f64()
                .filter(|b| b.is_finite() && *b >= 0.0)
                .ok_or_else(|| {
                    V2Error::new("invalid_argument", "bucket_mib must be a non-negative number")
                })?;
            params.bucket_bytes = mib * 1024.0 * 1024.0;
        }
        Ok(params)
    }

    fn check_sweep(cells: usize) -> std::result::Result<(), V2Error> {
        if cells > MAX_CLUSTER_SWEEP {
            return Err(V2Error::new(
                "invalid_argument",
                format!("cluster sweep of {cells} configurations exceeds the {MAX_CLUSTER_SWEEP} limit"),
            ));
        }
        Ok(())
    }

    fn register_error(e: RegisterError) -> V2Error {
        match e {
            RegisterError::Conflict(m) => V2Error::new("conflict", m),
            RegisterError::Invalid(m) => V2Error::new("invalid_argument", m),
        }
    }

    fn cluster_response(report: &crate::engine::ClusterReport) -> ClusterResponse {
        ClusterResponse {
            model: report.trace.model.clone(),
            batch: report.trace.batch_size,
            origin: report.trace.origin.id().to_string(),
            dest: report.dest.id().to_string(),
            compute_ms: report.compute_ms,
            configs: report
                .configs
                .iter()
                .map(|c| ClusterConfig {
                    topology: c.topology.name().to_string(),
                    world: c.world,
                    iter_ms: c.pred.iter_ms,
                    comm_ms: c.pred.comm_ms,
                    exposed_ms: c.pred.exposed_ms,
                    throughput: c.pred.throughput,
                    efficiency: c.pred.efficiency,
                    cost_normalized_throughput: c.cost_normalized_throughput,
                })
                .collect(),
        }
    }

    fn cluster_rank_response(ranking: &crate::engine::ClusterRanking) -> ClusterRankResponse {
        ClusterRankResponse {
            model: ranking.trace.model.clone(),
            batch: ranking.trace.batch_size,
            origin: ranking.trace.origin.id().to_string(),
            ranking: ranking
                .entries
                .iter()
                .map(|e| ClusterRankedConfig {
                    dest: e.dest.id().to_string(),
                    topology: e.topology.name().to_string(),
                    world: e.world,
                    iter_ms: e.pred.iter_ms,
                    throughput: e.pred.throughput,
                    efficiency: e.pred.efficiency,
                    cost_normalized_throughput: e.cost_normalized_throughput,
                })
                .collect(),
        }
    }

    /// Resolve a v2 `dests` field: explicit names, or the full registry.
    fn v2_dests(v: &Json) -> std::result::Result<Vec<Device>, V2Error> {
        match v.get("dests") {
            None | Some(Json::Null) => Ok(registry::all_devices()),
            Some(arr) => {
                let items = arr
                    .as_arr()
                    .ok_or_else(|| V2Error::new("bad_request", "dests must be an array of device names"))?;
                items
                    .iter()
                    .map(|it| {
                        let name = it
                            .as_str()
                            .ok_or_else(|| V2Error::new("bad_request", "dests entries must be strings"))?;
                        parse_device(name, "destination")
                            .map_err(|e| V2Error::new("unknown_device", e.to_string()))
                    })
                    .collect()
            }
        }
    }

    /// v1 handler errors carry no code; classify from the message.
    fn classify_v1(e: &anyhow::Error) -> &'static str {
        let msg = e.to_string();
        if msg.contains("unknown model") {
            "unknown_model"
        } else if msg.contains("unknown origin device") || msg.contains("unknown destination device") {
            "unknown_device"
        } else {
            "invalid_argument"
        }
    }

    /// Decision-ready response fields from an engine prediction (the
    /// uploaded-trace path, where there is no request echo to copy).
    fn prediction_response(out: &crate::engine::EnginePrediction) -> PredictionResponse {
        let pred = &out.pred;
        let tput = pred.throughput();
        PredictionResponse {
            model: pred.model.clone(),
            batch: pred.batch_size,
            origin: pred.origin.id().to_string(),
            dest: pred.dest.id().to_string(),
            origin_iter_ms: out.trace.run_time_ms(),
            iter_ms: pred.run_time_ms(),
            throughput: tput,
            cost_normalized_throughput: crate::cost::cost_normalized_throughput(pred.dest, tput),
            mlp_time_fraction: pred.mlp_time_fraction(),
            mlp_fallbacks: pred.mlp_fallbacks,
        }
    }

    fn rank_response(ranking: &crate::engine::Ranking) -> RankResponse {
        RankResponse {
            model: ranking.trace.model.clone(),
            batch: ranking.trace.batch_size,
            origin: ranking.trace.origin.id().to_string(),
            origin_iter_ms: ranking.trace.run_time_ms(),
            ranking: ranking
                .entries
                .iter()
                .map(|e| RankedDest {
                    dest: e.dest.id().to_string(),
                    iter_ms: e.pred.run_time_ms(),
                    throughput: e.pred.throughput(),
                    cost_normalized_throughput: e.cost_normalized_throughput,
                    mlp_time_fraction: e.pred.mlp_time_fraction(),
                    mlp_fallbacks: e.pred.mlp_fallbacks,
                })
                .collect(),
        }
    }
}

// ------------------------------------------------- bounded runtime --

/// Environment variable bounding concurrent connections
/// ([`DEFAULT_MAX_CONNS`] when unset).
pub const MAX_CONNS_ENV: &str = "HABITAT_MAX_CONNS";

/// Default concurrent-connection bound.
pub const DEFAULT_MAX_CONNS: usize = 256;

/// Default per-connection pipelining bound: how many request lines may
/// be in flight (submitted but unanswered) on one connection before the
/// reader stops pulling bytes off the socket — backpressure lands on
/// that connection's TCP window, not on server memory.
pub const DEFAULT_PIPELINE_DEPTH: usize = 64;

/// Server-side write timeout per connection. A client that stops
/// reading its replies (zero TCP window) errors that connection's
/// writer out instead of pinning a runtime thread forever — without
/// this, `ServerHandle::shutdown` could block joining a writer stuck
/// in `write_all`.
pub const CONN_WRITE_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(30);

/// The wire form of the typed backpressure reply: sent per request when
/// the compute queue is full, and once (followed by a close) to a
/// connection that arrives while every connection slot is taken. Always
/// the structured v2 error shape, whatever protocol generation the
/// client speaks — `overloaded` is a server condition, not a request
/// parse result.
pub fn overloaded_json() -> String {
    v2_error_json("overloaded", "server at capacity; retry later")
}

fn internal_error_json() -> String {
    v2_error_json("internal", "request handler failed")
}

/// Serving-runtime knobs (see `docs/SERVICE.md`).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Connection slots; further connects get an `overloaded` line and
    /// a close. `Default` reads [`MAX_CONNS_ENV`].
    pub max_conns: usize,
    /// In-flight request lines per connection.
    pub pipeline_depth: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            max_conns: std::env::var(MAX_CONNS_ENV)
                .ok()
                .and_then(|v| v.parse::<usize>().ok())
                .filter(|&n| n > 0)
                .unwrap_or(DEFAULT_MAX_CONNS),
            pipeline_depth: DEFAULT_PIPELINE_DEPTH,
        }
    }
}

/// State shared by the acceptor, the connection threads, and the
/// [`ServerHandle`].
struct ServerShared {
    service: Arc<PredictionService>,
    opts: ServeOptions,
    shutdown: AtomicBool,
    /// Occupied connection slots.
    active: AtomicUsize,
    /// Socket clones of live connections, for shutdown wake-up.
    streams: Mutex<HashMap<u64, TcpStream>>,
    /// Connection reader threads, joined on shutdown.
    threads: Mutex<Vec<JoinHandle<()>>>,
    next_conn: AtomicU64,
}

impl ServerShared {
    fn spawn_connection(self: &Arc<Self>, stream: TcpStream) {
        // Claim a slot optimistically; over the bound, tell the client
        // why and close instead of letting connects pile up at the OS.
        if self.active.fetch_add(1, Ordering::SeqCst) >= self.opts.max_conns {
            self.active.fetch_sub(1, Ordering::SeqCst);
            let mut stream = stream;
            let _ = stream.write_all(overloaded_json().as_bytes());
            let _ = stream.write_all(b"\n");
            return; // drop closes the socket
        }
        // A stalled client must not pin a writer thread forever (see
        // CONN_WRITE_TIMEOUT); reads stay unbounded — idle connections
        // are legitimate.
        let _ = stream.set_write_timeout(Some(CONN_WRITE_TIMEOUT));
        let id = self.next_conn.fetch_add(1, Ordering::Relaxed);
        if let Ok(clone) = stream.try_clone() {
            self.streams.lock().unwrap().insert(id, clone);
        }
        // Reap finished connection threads so a long-running server's
        // handle list stays proportional to *live* connections, not to
        // every connection ever accepted.
        self.threads.lock().unwrap().retain(|h| !h.is_finished());
        let shared = Arc::clone(self);
        let spawned = std::thread::Builder::new()
            .name(format!("habitat-conn-{id}"))
            .spawn(move || {
                let peer = stream.peer_addr().map(|p| p.to_string()).unwrap_or_default();
                if let Err(e) = run_connection(stream, &shared) {
                    if !shared.shutdown.load(Ordering::SeqCst) {
                        eprintln!("habitat: connection {peer}: {e}");
                    }
                }
                shared.streams.lock().unwrap().remove(&id);
                shared.active.fetch_sub(1, Ordering::SeqCst);
            });
        match spawned {
            Ok(handle) => self.threads.lock().unwrap().push(handle),
            Err(_) => {
                self.streams.lock().unwrap().remove(&id);
                self.active.fetch_sub(1, Ordering::SeqCst);
            }
        }
    }
}

/// A running prediction server. Dropping the handle shuts the runtime
/// down; [`ServerHandle::join`] blocks on the acceptor instead (the
/// `habitat serve` foreground mode).
pub struct ServerHandle {
    addr: SocketAddr,
    shared: Arc<ServerShared>,
    acceptor: Option<JoinHandle<()>>,
}

impl ServerHandle {
    /// The bound address (with the OS-assigned port when `:0` was
    /// requested).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn service(&self) -> &Arc<PredictionService> {
        &self.shared.service
    }

    /// Occupied connection slots right now.
    pub fn active_connections(&self) -> usize {
        self.shared.active.load(Ordering::SeqCst)
    }

    /// Stop accepting, unblock every connection reader, drain in-flight
    /// replies, and join all runtime threads. Idempotent; also invoked
    /// by `Drop`, so tests can simply let the handle fall out of scope.
    pub fn shutdown(mut self) {
        self.stop();
    }

    /// Block on the acceptor thread (runs until the process exits or
    /// another owner flips the shutdown flag).
    pub fn join(mut self) -> Result<()> {
        if let Some(acceptor) = self.acceptor.take() {
            acceptor
                .join()
                .map_err(|_| anyhow::anyhow!("acceptor thread panicked"))?;
        }
        Ok(())
    }

    fn stop(&mut self) {
        if self.shared.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        // Wake the acceptor out of `accept` with one throwaway connect.
        let mut wake = self.addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST));
        }
        let _ = TcpStream::connect_timeout(&wake, std::time::Duration::from_millis(250));
        if let Some(acceptor) = self.acceptor.take() {
            let _ = acceptor.join();
        }
        // Half-close every live connection's read side: readers see EOF
        // and wind down, while writers still flush in-flight replies —
        // a drain, not an abort.
        for stream in self.shared.streams.lock().unwrap().values() {
            let _ = stream.shutdown(std::net::Shutdown::Read);
        }
        let threads: Vec<JoinHandle<()>> = self.shared.threads.lock().unwrap().drain(..).collect();
        for handle in threads {
            let _ = handle.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Start the bounded serving runtime on `addr` around an existing
/// (shared) service. Returns once the listener is bound; the acceptor
/// and all connection handling run on background threads owned by the
/// returned [`ServerHandle`].
pub fn start(
    addr: &str,
    service: Arc<PredictionService>,
    opts: ServeOptions,
) -> Result<ServerHandle> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    let shared = Arc::new(ServerShared {
        service,
        opts,
        shutdown: AtomicBool::new(false),
        active: AtomicUsize::new(0),
        streams: Mutex::new(HashMap::new()),
        threads: Mutex::new(Vec::new()),
        next_conn: AtomicU64::new(0),
    });
    let for_acceptor = Arc::clone(&shared);
    let acceptor = std::thread::Builder::new()
        .name("habitat-accept".to_string())
        .spawn(move || {
            for stream in listener.incoming() {
                if for_acceptor.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                let stream = match stream {
                    Ok(s) => s,
                    Err(e) => {
                        // A persistent accept failure (e.g. fd
                        // exhaustion) must not become a silent
                        // busy-loop: say so and back off.
                        eprintln!("habitat: accept error: {e}");
                        std::thread::sleep(std::time::Duration::from_millis(100));
                        continue;
                    }
                };
                for_acceptor.spawn_connection(stream);
            }
        })?;
    Ok(ServerHandle {
        addr: local,
        shared,
        acceptor: Some(acceptor),
    })
}

/// One pipelined connection: the reader submits each line as a job on
/// the engine's shared compute pool and a writer thread emits replies
/// strictly in request order. A full compute queue becomes a typed
/// `overloaded` reply for that line (the stream stays in sync); a full
/// pipeline window stops reading the socket (TCP backpressure).
fn run_connection(stream: TcpStream, shared: &Arc<ServerShared>) -> Result<()> {
    let mut write = stream.try_clone()?;
    // The in-order reply rail: the reader enqueues one slot (a oneshot
    // receiver) per request; the writer drains slots in order, waiting
    // on each request's reply before touching the next.
    let (slot_tx, slot_rx) =
        mpsc::sync_channel::<mpsc::Receiver<String>>(shared.opts.pipeline_depth.max(1));
    let writer = std::thread::Builder::new()
        .name("habitat-conn-writer".to_string())
        .spawn(move || {
            while let Ok(slot) = slot_rx.recv() {
                // A dropped slot without a reply means the handler was
                // lost (e.g. pool teardown mid-request): answer with a
                // typed internal error so the stream never desyncs.
                let reply = slot.recv().unwrap_or_else(|_| internal_error_json());
                if write.write_all(reply.as_bytes()).is_err() || write.write_all(b"\n").is_err() {
                    break;
                }
            }
        })?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let (reply_tx, reply_rx) = mpsc::channel::<String>();
        if slot_tx.send(reply_rx).is_err() {
            break; // writer gone: the socket is dead
        }
        let service = Arc::clone(&shared.service);
        let tx = reply_tx.clone();
        let submitted = shared.service.engine().pool().try_execute(move || {
            let reply =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    service.handle_line(&line)
                }))
                .unwrap_or_else(|_| internal_error_json());
            let _ = tx.send(reply);
        });
        if submitted.is_err() {
            // Compute queue full: typed per-request backpressure through
            // the same reply slot, preserving response order.
            let _ = reply_tx.send(overloaded_json());
        }
    }
    drop(slot_tx);
    let _ = writer.join();
    Ok(())
}

/// Build the service for `serve`/`start`: the paper's full hybrid
/// predictor, degrading to wave-scaling-only predictions when MLP
/// artifacts are missing (like `habitat compare`) rather than refusing
/// to start.
pub fn service_from_artifacts(artifacts: &str) -> PredictionService {
    match PredictionService::new(artifacts) {
        Ok(s) => s,
        Err(e) => {
            eprintln!(
                "habitat: MLP artifacts unavailable ({e}); serving wave-scaling-only predictions"
            );
            PredictionService::with_predictor(HybridPredictor::wave_only())
        }
    }
}

/// Serve newline-delimited JSON requests over TCP on the bounded
/// runtime (the `habitat serve` subcommand). Blocks forever.
pub fn serve(addr: &str, artifacts: &str) -> Result<()> {
    serve_with(addr, artifacts, ServeOptions::default())
}

/// Environment variable naming the persistent plan-store directory for
/// `habitat serve` (also settable via the CLI's `--store` flag). Only
/// the serving entry point reads it — library engines never attach a
/// store implicitly.
pub const STORE_ENV: &str = "HABITAT_STORE";

/// [`serve`] with explicit runtime bounds.
pub fn serve_with(addr: &str, artifacts: &str, opts: ServeOptions) -> Result<()> {
    let mut service = service_from_artifacts(artifacts);
    if let Ok(dir) = std::env::var(STORE_ENV) {
        if !dir.is_empty() {
            // Persistence is an optimization: a store that cannot be
            // opened degrades to a cold boot, never a refused one.
            match service.attach_store(&dir) {
                Ok(()) => println!(
                    "habitat: plan store at {dir} ({} plans warm-restored)",
                    service.engine().stats().warm_restores
                ),
                Err(e) => eprintln!("habitat: plan store at {dir} unavailable ({e}); serving without persistence"),
            }
        }
    }
    let service = Arc::new(service);
    let max_conns = opts.max_conns;
    let handle = start(addr, service, opts)?;
    {
        let engine = handle.service().engine();
        println!(
            "habitat: serving predictions on {addr} ({} workers, queue depth {}, max {} connections)",
            engine.workers(),
            engine.queue_depth(),
            max_conns
        );
    }
    handle.join()
}

/// Handle one connection until EOF.
pub fn handle_connection(stream: TcpStream, service: &PredictionService) -> Result<()> {
    let mut write = stream.try_clone()?;
    let read = BufReader::new(stream);
    for line in read.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = service.handle_line(&line);
        write.write_all(reply.as_bytes())?;
        write.write_all(b"\n")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::ALL_DEVICES;

    fn wave_service() -> PredictionService {
        PredictionService::with_predictor(HybridPredictor::wave_only())
    }

    fn req(model: &str, batch: usize, origin: &str, dest: &str) -> PredictionRequest {
        PredictionRequest {
            model: model.into(),
            batch,
            origin: origin.into(),
            dest: dest.into(),
            precision: None,
        }
    }

    fn rank_req(model: &str, batch: usize, origin: &str) -> RankRequest {
        RankRequest {
            model: model.into(),
            batch,
            origin: origin.into(),
            precision: None,
            dests: None,
        }
    }

    #[test]
    fn handles_basic_request() {
        let s = wave_service();
        let r = s.handle(&req("mlp", 32, "t4", "v100")).unwrap();
        assert!(r.iter_ms > 0.0);
        assert!(r.throughput > 0.0);
        assert!(r.cost_normalized_throughput.is_some());
        assert_eq!(r.dest, "V100");
    }

    #[test]
    fn rejects_unknown_inputs() {
        let s = wave_service();
        assert!(s.handle(&req("nope", 32, "t4", "v100")).is_err());
        assert!(s.handle(&req("mlp", 32, "a100", "v100")).is_err());
        assert!(s.handle(&req("mlp", 0, "t4", "v100")).is_err());
        let mut r = req("mlp", 8, "t4", "v100");
        r.precision = Some("fp64".into());
        assert!(s.handle(&r).is_err());
    }

    #[test]
    fn request_response_json_roundtrip() {
        let r = req("gnmt", 64, "p4000", "t4");
        let parsed = PredictionRequest::from_json(&r.to_json()).unwrap();
        assert_eq!(parsed.model, "gnmt");
        assert_eq!(parsed.batch, 64);

        let resp = wave_service().handle(&r).unwrap();
        let parsed = PredictionResponse::from_json(&resp.to_json()).unwrap();
        assert!((parsed.iter_ms - resp.iter_ms).abs() < 1e-9);
        assert_eq!(
            parsed.cost_normalized_throughput.is_some(),
            resp.cost_normalized_throughput.is_some()
        );
    }

    #[test]
    fn rank_request_json_roundtrip() {
        let mut r = rank_req("mlp", 16, "t4");
        r.dests = Some(vec!["v100".into(), "p100".into()]);
        r.precision = Some("amp".into());
        let line = r.to_json();
        let parsed = match Request::from_json(&line).unwrap() {
            Request::Rank(rr) => rr,
            other => panic!("expected rank request, got {other:?}"),
        };
        assert_eq!(parsed.model, "mlp");
        assert_eq!(parsed.batch, 16);
        assert_eq!(parsed.precision.as_deref(), Some("amp"));
        assert_eq!(parsed.dests.as_deref().unwrap().len(), 2);
    }

    #[test]
    fn predict_line_still_dispatches_as_predict() {
        let line = req("mlp", 8, "t4", "v100").to_json();
        assert!(matches!(Request::from_json(&line).unwrap(), Request::Predict(_)));
    }

    #[test]
    fn rank_response_json_roundtrip() {
        let s = wave_service();
        let resp = s.handle_rank(&rank_req("mlp", 32, "t4")).unwrap();
        let parsed = RankResponse::from_json(&resp.to_json()).unwrap();
        assert_eq!(parsed.ranking.len(), resp.ranking.len());
        for (a, b) in parsed.ranking.iter().zip(&resp.ranking) {
            assert_eq!(a.dest, b.dest);
            assert!((a.iter_ms - b.iter_ms).abs() < 1e-9);
            assert_eq!(
                a.cost_normalized_throughput.is_some(),
                b.cost_normalized_throughput.is_some()
            );
        }
    }

    #[test]
    fn rank_matches_individual_requests_with_one_tracking_pass() {
        // A default rank equals N individual requests, with exactly one
        // run of the tracking pipeline. (The default destination set is
        // the whole registry — at least the six built-ins, plus any
        // devices other concurrently running tests have registered.)
        let s = wave_service();
        let ranking = s.handle_rank(&rank_req("mlp", 16, "t4")).unwrap();
        assert!(ranking.ranking.len() >= ALL_DEVICES.len());
        for d in ALL_DEVICES {
            assert!(
                ranking.ranking.iter().any(|r| r.dest == d.id()),
                "built-in {d} missing from the default rank"
            );
        }
        let stats = s.engine().stats();
        assert_eq!(stats.trace_misses, 1, "rank must track exactly once");
        assert_eq!(stats.trace_hits, 0);

        for entry in &ranking.ranking {
            let resp = s.handle(&req("mlp", 16, "t4", &entry.dest)).unwrap();
            assert!(
                (resp.iter_ms - entry.iter_ms).abs() < 1e-9,
                "{}: rank {} vs individual {}",
                entry.dest,
                entry.iter_ms,
                resp.iter_ms
            );
        }
        let stats = s.engine().stats();
        assert_eq!(stats.trace_misses, 1, "individual requests must reuse the trace");
        assert_eq!(stats.trace_hits as usize, ranking.ranking.len());
    }

    #[test]
    fn rank_is_sorted_by_cost_normalized_throughput() {
        let s = wave_service();
        let resp = s.handle_rank(&rank_req("mlp", 32, "p4000")).unwrap();
        let priced: Vec<f64> = resp
            .ranking
            .iter()
            .filter_map(|r| r.cost_normalized_throughput)
            .collect();
        assert!(!priced.is_empty());
        for w in priced.windows(2) {
            assert!(w[0] >= w[1], "priced devices must be in descending order");
        }
        // Priced devices all come before unpriced ones.
        let first_unpriced = resp
            .ranking
            .iter()
            .position(|r| r.cost_normalized_throughput.is_none())
            .unwrap_or(resp.ranking.len());
        assert!(resp.ranking[first_unpriced..]
            .iter()
            .all(|r| r.cost_normalized_throughput.is_none()));
    }

    #[test]
    fn rank_with_explicit_dests_and_errors() {
        let s = wave_service();
        let mut r = rank_req("mlp", 16, "t4");
        r.dests = Some(vec!["v100".into(), "p100".into()]);
        let resp = s.handle_rank(&r).unwrap();
        assert_eq!(resp.ranking.len(), 2);

        let mut bad = rank_req("mlp", 16, "t4");
        bad.dests = Some(vec!["a100".into()]);
        assert!(s.handle_rank(&bad).is_err());
        assert!(s.handle_rank(&rank_req("nope", 16, "t4")).is_err());
        assert!(s.handle_rank(&rank_req("mlp", 0, "t4")).is_err());
    }

    #[test]
    fn handle_line_dispatches_and_reports_errors() {
        let s = wave_service();
        let ok = s.handle_line("{\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\"}");
        assert!(PredictionResponse::from_json(&ok).is_ok());
        let rank = s.handle_line("{\"rank\":true,\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\"}");
        assert!(RankResponse::from_json(&rank).is_ok());
        let bad = s.handle_line("not json");
        assert!(bad.contains("bad request"));
        let unknown = s.handle_line("{\"model\":\"mlp\",\"batch\":8,\"origin\":\"a100\",\"dest\":\"v100\"}");
        assert!(unknown.contains("error"));
    }

    #[test]
    fn stats_request_reflects_engine_counters() {
        let s = wave_service();
        let cold = s.handle_stats();
        assert_eq!(cold.trace_hits, 0);
        assert_eq!(cold.trace_misses, 0);
        assert!(cold.workers >= 1);

        s.handle(&req("mlp", 8, "t4", "v100")).unwrap();
        s.handle(&req("mlp", 8, "t4", "p100")).unwrap();
        let warm = s.handle_stats();
        assert_eq!(warm.trace_misses, 1);
        assert_eq!(warm.trace_hits, 1);
        assert_eq!(warm.trace_entries, 1);
        assert_eq!(warm.plan_builds, 1);
    }

    #[test]
    fn stats_line_dispatches_and_roundtrips() {
        let s = wave_service();
        s.handle(&req("mlp", 8, "t4", "v100")).unwrap();
        let line = stats_request_json();
        assert!(matches!(Request::from_json(&line).unwrap(), Request::Stats));
        let reply = s.handle_line(&line);
        let parsed = StatsResponse::from_json(&reply).unwrap();
        assert_eq!(parsed.trace_misses, 1);
        assert_eq!(parsed.workers, s.engine().workers());
    }

    #[test]
    fn trace_cache_hits() {
        let s = wave_service();
        let a = s.trace_for("mlp", 16, Device::T4).unwrap();
        let b = s.trace_for("mlp", 16, Device::T4).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second lookup must hit the cache");
    }

    #[test]
    fn amp_prediction_not_slower_than_fp32() {
        let s = wave_service();
        let fp32 = s.handle(&req("mlp", 32, "p4000", "2080ti")).unwrap();
        let mut amp_req = req("mlp", 32, "p4000", "2080ti");
        amp_req.precision = Some("amp".into());
        let amp = s.handle(&amp_req).unwrap();
        assert!(amp.iter_ms <= fp32.iter_ms);
    }

    #[test]
    fn v2_predict_payload_matches_v1_bit_for_bit() {
        let s = wave_service();
        let v1_line = "{\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\"}";
        let v1 = s.handle_line(v1_line);
        let v2 = s.handle_line(&v2_predict_model_request("mlp", 8, "t4", "v100", None));
        let v1_parsed = json::parse(&v1).unwrap();
        let v2_parsed = json::parse(&v2).unwrap();
        assert_eq!(v2_parsed.get("v"), Some(&Json::Num(2.0)));
        assert_eq!(v2_parsed.req_str("op").unwrap(), "predict");
        // Every v1 field appears identically in the v2 payload.
        if let Json::Obj(m) = &v1_parsed {
            for (k, val) in m {
                assert_eq!(v2_parsed.get(k), Some(val), "field {k}");
            }
        } else {
            panic!("v1 reply is not an object");
        }
    }

    #[test]
    fn v2_envelope_dispatches_rank_and_stats() {
        let s = wave_service();
        let rank = s.handle_line(
            "{\"v\":2,\"op\":\"rank\",\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dests\":[\"v100\",\"t4\"]}",
        );
        let parsed = json::parse(&rank).unwrap();
        assert_eq!(parsed.req_str("op").unwrap(), "rank");
        assert_eq!(parsed.get("ranking").and_then(Json::as_arr).unwrap().len(), 2);

        let stats = s.handle_line(&v2_stats_request());
        let parsed = json::parse(&stats).unwrap();
        assert_eq!(parsed.req_str("op").unwrap(), "stats");
        assert_eq!(parsed.req_usize("trace_misses").unwrap(), 1);
        assert_eq!(parsed.req_usize("trace_uploads").unwrap(), 0);
        assert!(parsed.req_usize("devices").unwrap() >= ALL_DEVICES.len());
    }

    #[test]
    fn v2_errors_are_structured() {
        let s = wave_service();
        let check = |line: &str, code: &str| {
            let reply = s.handle_line(line);
            let v = json::parse(&reply).unwrap();
            assert_eq!(
                v.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
                Some(code),
                "line {line} → {reply}"
            );
            assert!(v.get("error").and_then(|e| e.get("message")).is_some());
        };
        check("{\"v\":2}", "bad_request");
        check("{\"v\":2,\"op\":\"frobnicate\"}", "unsupported_op");
        check(
            "{\"v\":2,\"op\":\"predict\",\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"a100\"}",
            "unknown_device",
        );
        check(
            "{\"v\":2,\"op\":\"predict\",\"model\":\"nope\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\"}",
            "unknown_model",
        );
        check(
            "{\"v\":2,\"op\":\"predict\",\"trace_id\":\"tr-0000000000000000\",\"dest\":\"v100\"}",
            "unknown_trace",
        );
        check(
            "{\"v\":2,\"op\":\"predict\",\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\",\"precision\":\"fp64\"}",
            "invalid_argument",
        );
        check("{\"v\":3,\"op\":\"predict\"}", "unsupported_version");
        // v1 malformed lines keep the v1 error shape.
        assert!(s.handle_line("not json").contains("bad request"));
    }

    #[test]
    fn v2_register_device_becomes_rankable_with_correct_ordering() {
        let s = wave_service();
        // Absurdly cost-efficient so its rank position is deterministic:
        // V100-class hardware at a tenth of the T4's price.
        let line = s.handle_line(
            "{\"v\":2,\"op\":\"register_device\",\"name\":\"sim-wire9\",\"sms\":80,\"clock_mhz\":1530,\"mem_bw_gbps\":900,\"fp32_tflops\":15.7,\"tensor_cores\":true,\"usd_per_hr\":0.03}",
        );
        let ack = RegisteredDevice::from_json(&line).unwrap();
        assert_eq!(ack.device, "sim-wire9");
        assert!(ack.id >= ALL_DEVICES.len());
        assert!(ack.devices > ALL_DEVICES.len());

        // Idempotent replay: same spec, same id, no conflict.
        let replay = RegisteredDevice::from_json(&s.handle_line(
            "{\"v\":2,\"op\":\"register_device\",\"name\":\"sim-wire9\",\"sms\":80,\"clock_mhz\":1530,\"mem_bw_gbps\":900,\"fp32_tflops\":15.7,\"tensor_cores\":true,\"usd_per_hr\":0.03}",
        ))
        .unwrap();
        assert_eq!(replay.id, ack.id);

        // Different spec under the same name → conflict.
        let clash = s.handle_line(
            "{\"v\":2,\"op\":\"register_device\",\"name\":\"sim-wire9\",\"sms\":81,\"clock_mhz\":1530,\"mem_bw_gbps\":900,\"fp32_tflops\":15.7,\"tensor_cores\":true}",
        );
        let v = json::parse(&clash).unwrap();
        assert_eq!(
            v.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
            Some("conflict")
        );

        // The new device appears in a default (v1!) rank, and — being a
        // V100 at 1/12 the T4's price — tops the cost-normalized order.
        let ranking = s.handle_rank(&rank_req("mlp", 16, "t4")).unwrap();
        let pos = ranking.ranking.iter().position(|r| r.dest == "sim-wire9");
        assert_eq!(pos, Some(0), "cheapest-per-throughput device must rank first");
        let entry = &ranking.ranking[pos.unwrap()];
        let expected_cnt = entry.throughput / 0.03;
        assert!(
            (entry.cost_normalized_throughput.unwrap() - expected_cnt).abs() < 1e-6,
            "cost normalization must use the registered price"
        );

        // …and works as an explicit v1 predict destination.
        let resp = s.handle(&req("mlp", 16, "t4", "sim-wire9")).unwrap();
        assert!(resp.iter_ms > 0.0);
        assert_eq!(resp.dest, "sim-wire9");
    }

    #[test]
    fn v2_submit_trace_then_predict_matches_in_process_evaluation() {
        let s = wave_service();
        let graph = crate::models::by_name("mlp", 12).unwrap();
        let trace = crate::tracker::OperationTracker::new(Device::P4000).track(&graph);

        let reply = s.handle_line(&v2_submit_trace_request(&trace));
        let v = json::parse(&reply).unwrap();
        v2_check_error(&v).unwrap();
        let trace_id = v.req_str("trace_id").unwrap().to_string();
        assert!(trace_id.starts_with("tr-"));
        assert_eq!(v.req_usize("ops").unwrap(), trace.ops.len());
        assert_eq!(v.req_str("origin").unwrap(), "P4000");

        // Predict by id over the wire ≡ analyze+evaluate in-process.
        let reply = s.handle_line(&v2_predict_trace_request(&trace_id, "v100", None));
        let v = json::parse(&reply).unwrap();
        v2_check_error(&v).unwrap();
        let wire_ms = v.get("iter_ms").and_then(Json::as_f64).unwrap();
        let plan = s.engine().analyze(&trace);
        let direct = s.engine().evaluate(&plan, Device::V100, Precision::Fp32);
        assert_eq!(
            wire_ms.to_bits(),
            direct.run_time_ms().to_bits(),
            "wire {wire_ms} vs in-process {}",
            direct.run_time_ms()
        );

        // Rank by id: default dests cover at least the built-ins.
        let reply = s.handle_line(&v2_rank_trace_request(&trace_id, None, Some("amp")));
        let v = json::parse(&reply).unwrap();
        v2_check_error(&v).unwrap();
        let ranking = v.get("ranking").and_then(Json::as_arr).unwrap();
        assert!(ranking.len() >= ALL_DEVICES.len());
        assert_eq!(v.req_str("model").unwrap(), "mlp");

        // Submitting garbage is a structured error.
        let bad = s.handle_line("{\"v\":2,\"op\":\"submit_trace\",\"trace\":{\"format\":\"nope\"}}");
        let v = json::parse(&bad).unwrap();
        assert_eq!(
            v.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
            Some("invalid_argument")
        );
    }

    #[test]
    fn serve_options_defaults_are_bounded() {
        let opts = ServeOptions::default();
        assert!(opts.max_conns >= 1);
        assert!(opts.pipeline_depth >= 1);
        let line = overloaded_json();
        let v = json::parse(&line).unwrap();
        assert_eq!(
            v.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
            Some("overloaded")
        );
        assert_eq!(v.get("v"), Some(&Json::Num(2.0)));
    }

    #[test]
    fn bounded_runtime_serves_pipelined_lines_in_order() {
        let handle = start(
            "127.0.0.1:0",
            Arc::new(wave_service()),
            ServeOptions::default(),
        )
        .unwrap();
        let addr = handle.local_addr();
        let stream = TcpStream::connect(addr).unwrap();
        let mut write = stream.try_clone().unwrap();
        write
            .write_all(
                b"{\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\"}\n\
                  {\"rank\":true,\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\"}\n\
                  {\"stats\":true}\n",
            )
            .unwrap();
        // Half-close the write side so the server sees EOF after the
        // pipelined burst (dropping a clone alone does not, because the
        // read half still holds the socket open).
        stream.shutdown(std::net::Shutdown::Write).unwrap();
        let replies: Vec<String> = BufReader::new(stream).lines().map(|l| l.unwrap()).collect();
        assert_eq!(replies.len(), 3);
        assert_eq!(PredictionResponse::from_json(&replies[0]).unwrap().dest, "V100");
        assert!(RankResponse::from_json(&replies[1]).unwrap().ranking.len() >= ALL_DEVICES.len());
        assert!(StatsResponse::from_json(&replies[2]).is_ok());
        handle.shutdown();
        // The listener is gone after shutdown — nothing leaked.
        assert!(TcpStream::connect(addr).is_err(), "listener must be closed");
    }

    #[test]
    fn connection_slots_are_enforced_with_a_typed_reply() {
        let handle = start(
            "127.0.0.1:0",
            Arc::new(wave_service()),
            ServeOptions {
                max_conns: 1,
                ..ServeOptions::default()
            },
        )
        .unwrap();
        let addr = handle.local_addr();

        // Fill the single slot and prove it is live with a roundtrip
        // (which also guarantees the acceptor registered it).
        let first = TcpStream::connect(addr).unwrap();
        let mut w1 = first.try_clone().unwrap();
        w1.write_all(b"{\"stats\":true}\n").unwrap();
        let mut r1 = BufReader::new(first.try_clone().unwrap());
        let mut line = String::new();
        r1.read_line(&mut line).unwrap();
        assert!(StatsResponse::from_json(line.trim()).is_ok());

        // The second connection gets one typed overloaded line, then EOF.
        let second = TcpStream::connect(addr).unwrap();
        let mut lines = BufReader::new(second).lines();
        let reply = lines.next().unwrap().unwrap();
        let v = json::parse(&reply).unwrap();
        assert_eq!(
            v.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
            Some("overloaded"),
            "{reply}"
        );
        assert!(lines.next().is_none(), "rejected connection must be closed");

        // Freeing the slot readmits clients (every clone of the first
        // connection must drop for the server to see EOF).
        drop(w1);
        drop(r1);
        drop(first);
        for _ in 0..100 {
            let probe = TcpStream::connect(addr).unwrap();
            let mut w = probe.try_clone().unwrap();
            w.write_all(b"{\"stats\":true}\n").unwrap();
            let mut line = String::new();
            BufReader::new(probe).read_line(&mut line).unwrap();
            if StatsResponse::from_json(line.trim()).is_ok() {
                return; // slot reclaimed
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        panic!("slot was never reclaimed after the first client left");
    }

    #[test]
    fn full_compute_queue_answers_overloaded_per_request() {
        let engine = PredictionEngine::wave_only()
            .with_workers(1)
            .with_queue_depth(1);
        let handle = start(
            "127.0.0.1:0",
            Arc::new(PredictionService::with_engine(engine)),
            ServeOptions::default(),
        )
        .unwrap();
        let addr = handle.local_addr();
        let pool_gate = {
            // Wedge the single worker and fill the single queue slot so
            // the next request job cannot be accepted. Wait for the
            // wedge job to *start* before filling: otherwise the fillers
            // could land while the wedge is still queued, and the queue
            // would drain again as the worker picks it up.
            let engine = handle.service().engine();
            let (gate_tx, gate_rx) = std::sync::mpsc::channel::<()>();
            let (started_tx, started_rx) = std::sync::mpsc::channel::<()>();
            engine.pool().execute(move || {
                started_tx.send(()).unwrap();
                gate_rx.recv().unwrap();
            });
            started_rx.recv().unwrap();
            while engine.pool().try_execute(|| {}).is_ok() {}
            gate_tx
        };

        let stream = TcpStream::connect(addr).unwrap();
        let mut write = stream.try_clone().unwrap();
        write
            .write_all(b"{\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\"}\n")
            .unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = json::parse(line.trim()).unwrap();
        assert_eq!(
            v.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
            Some("overloaded"),
            "wedged pool must answer with typed backpressure: {line}"
        );

        // Release the pool; the connection is still in sync and serves.
        drop(pool_gate);
        for _ in 0..100 {
            write
                .write_all(b"{\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\"}\n")
                .unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            if PredictionResponse::from_json(line.trim()).is_ok() {
                return;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        panic!("service never recovered after the queue drained");
    }

    #[test]
    fn tcp_roundtrip() {
        let service = Arc::new(wave_service());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let srv = service.clone();
        std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            handle_connection(stream, &srv).unwrap();
        });

        let stream = TcpStream::connect(addr).unwrap();
        let mut write = stream.try_clone().unwrap();
        write
            .write_all(b"{\"model\":\"mlp\",\"batch\":16,\"origin\":\"t4\",\"dest\":\"p100\"}\nnot json\n")
            .unwrap();
        drop(write);
        let mut lines = BufReader::new(stream).lines();
        let ok = PredictionResponse::from_json(&lines.next().unwrap().unwrap()).unwrap();
        assert!(ok.iter_ms > 0.0);
        let err_line = lines.next().unwrap().unwrap();
        assert!(err_line.contains("bad request"));
    }

    #[test]
    fn v2_predict_cluster_world_one_matches_v2_predict() {
        let s = wave_service();
        let topologies = vec!["dgx".to_string()];
        let reply = s.handle_line(&v2_predict_cluster_request(
            "mlp",
            8,
            "t4",
            "v100",
            Some(&topologies),
            Some(&[1, 4]),
            None,
        ));
        let resp = ClusterResponse::from_json(&reply).unwrap();
        assert_eq!(resp.model, "mlp");
        assert_eq!(resp.dest, "V100");
        assert_eq!(resp.configs.len(), 2);
        for c in &resp.configs {
            assert_eq!(c.topology, "dgx");
            assert!(c.efficiency > 0.0 && c.efficiency <= 1.0 + 1e-9);
            assert!(c.exposed_ms >= 0.0);
        }
        // The world=1 cell is the single-GPU prediction, bit-identical.
        let single = s.handle_line(&v2_predict_model_request("mlp", 8, "t4", "v100", None));
        let single_ms = json::parse(&single).unwrap().get("iter_ms").and_then(Json::as_f64).unwrap();
        let w1 = resp.configs.iter().find(|c| c.world == 1).unwrap();
        assert_eq!(w1.iter_ms.to_bits(), single_ms.to_bits());
        assert_eq!(w1.comm_ms, 0.0);
    }

    #[test]
    fn v2_predict_cluster_defaults_cover_every_topology_and_world() {
        let s = wave_service();
        let reply = s.handle_line(&v2_predict_cluster_request("mlp", 8, "t4", "v100", None, None, None));
        let resp = ClusterResponse::from_json(&reply).unwrap();
        // At least the dgx/cloud seeds × the default world sweep (other
        // concurrently running tests may have registered more
        // topologies).
        assert!(resp.configs.len() >= 2 * DEFAULT_CLUSTER_WORLDS.len());
        for t in ["dgx", "cloud"] {
            for &w in &DEFAULT_CLUSTER_WORLDS {
                assert!(
                    resp.configs.iter().any(|c| c.topology == t && c.world == w),
                    "missing cell ({t}, {w})"
                );
            }
        }
    }

    #[test]
    fn v2_rank_cluster_is_sorted_and_complete() {
        let s = wave_service();
        let dests = vec!["v100".to_string(), "t4".to_string()];
        let topologies = vec!["dgx".to_string(), "cloud".to_string()];
        let reply = s.handle_line(&v2_rank_cluster_request(
            "mlp",
            8,
            "t4",
            Some(&dests),
            Some(&topologies),
            Some(&[1, 4]),
            None,
        ));
        let resp = ClusterRankResponse::from_json(&reply).unwrap();
        assert_eq!(resp.ranking.len(), 2 * 2 * 2);
        // Both dests are rentable, so the whole ranking is priced and
        // descending in cost-normalized throughput.
        let priced: Vec<f64> = resp
            .ranking
            .iter()
            .map(|e| e.cost_normalized_throughput.unwrap())
            .collect();
        for w in priced.windows(2) {
            assert!(w[0] >= w[1], "ranking must be descending: {priced:?}");
        }
    }

    #[test]
    fn v2_cluster_errors_are_structured() {
        let s = wave_service();
        let check = |line: &str, code: &str| {
            let reply = s.handle_line(line);
            let v = json::parse(&reply).unwrap();
            assert_eq!(
                v.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
                Some(code),
                "line {line} → {reply}"
            );
        };
        check(
            "{\"v\":2,\"op\":\"predict_cluster\",\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\",\"topologies\":[\"no-such-topology\"]}",
            "unknown_topology",
        );
        check(
            "{\"v\":2,\"op\":\"predict_cluster\",\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\",\"topologies\":[{\"name\":\"sim-svc-badlink\",\"gpus_per_node\":4,\"intra\":\"no-such-link\",\"inter\":\"eth25g\"}]}",
            "unknown_link",
        );
        check(
            "{\"v\":2,\"op\":\"predict_cluster\",\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\",\"worlds\":[0]}",
            "invalid_argument",
        );
        check(
            "{\"v\":2,\"op\":\"predict_cluster\",\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\",\"topologies\":[]}",
            "invalid_argument",
        );
        check(
            "{\"v\":2,\"op\":\"predict_cluster\",\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\",\"overlap\":1.5}",
            "invalid_argument",
        );
        check(
            "{\"v\":2,\"op\":\"rank_cluster\",\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dests\":[\"a100\"]}",
            "unknown_device",
        );
        check(
            "{\"v\":2,\"op\":\"export_workload\",\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\",\"world\":8}",
            "bad_request",
        );
        // An oversized sweep is refused before any compute.
        let worlds: Vec<usize> = (1..=MAX_CLUSTER_SWEEP + 1).collect();
        let line = v2_predict_cluster_request("mlp", 8, "t4", "v100", None, Some(&worlds), None);
        check(&line, "invalid_argument");
    }

    #[test]
    fn v2_inline_topologies_register_links_idempotently() {
        let s = wave_service();
        let line = "{\"v\":2,\"op\":\"predict_cluster\",\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\",\"worlds\":[2],\"topologies\":[{\"name\":\"sim-svc-pod\",\"gpus_per_node\":2,\"intra\":\"nvlink\",\"inter\":{\"name\":\"sim-svc-wan\",\"bandwidth_gbps\":10.0,\"step_latency_ms\":0.02}}]}";
        let resp = ClusterResponse::from_json(&s.handle_line(line)).unwrap();
        assert_eq!(resp.configs.len(), 1);
        assert_eq!(resp.configs[0].topology, "sim-svc-pod");
        // Replay is idempotent (same inline specs re-intern silently)…
        let replay = ClusterResponse::from_json(&s.handle_line(line)).unwrap();
        assert_eq!(replay.configs[0].iter_ms.to_bits(), resp.configs[0].iter_ms.to_bits());
        // …while the same name with a different shape is a conflict.
        let clash = s.handle_line(
            "{\"v\":2,\"op\":\"predict_cluster\",\"model\":\"mlp\",\"batch\":8,\"origin\":\"t4\",\"dest\":\"v100\",\"worlds\":[2],\"topologies\":[{\"name\":\"sim-svc-pod\",\"gpus_per_node\":4,\"intra\":\"nvlink\",\"inter\":\"eth25g\"}]}",
        );
        let v = json::parse(&clash).unwrap();
        assert_eq!(
            v.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
            Some("conflict")
        );
    }

    #[test]
    fn v2_export_workload_round_trips() {
        let s = wave_service();
        let reply = s.handle_line(&v2_export_workload_request("mlp", 8, "t4", "v100", "dgx", 16, None));
        let v = json::parse(&reply).unwrap();
        v2_check_error(&v).unwrap();
        assert_eq!(v.req_str("op").unwrap(), "export_workload");
        let w = crate::comm::Workload::from_value(&v).unwrap();
        assert_eq!(w.topology, "dgx");
        assert_eq!(w.world, 16);
        assert!(w.compute_ms > 0.0);
        assert!(!w.comm_ops.is_empty());
        assert!(w.comm_ops.iter().all(|op| op.participants.iter().all(|&r| r < 16)));
        // A re-serialized workload parses back to the same value.
        let again = crate::comm::Workload::from_value(&json::parse(&w.to_value().dump()).unwrap()).unwrap();
        assert_eq!(again, w);
    }
}
