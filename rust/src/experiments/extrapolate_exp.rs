//! §6.1.3 — batch-size extrapolation.
//!
//! Predict ResNet-50 on a V100 at batch sizes that "don't fit" on the
//! 2070 origin by fitting a linear model over predictions at three small
//! batch sizes, then extrapolating — and compare against ground truth.

use crate::device::Device;
use crate::experiments::{ground_truth_ms, Ctx};
use crate::predict::extrapolate::BatchExtrapolator;
use crate::util::csv::CsvWriter;
use crate::util::stats;
use crate::{Precision, Result};

pub fn run(ctx: &Ctx) -> Result<()> {
    println!("\n=== §6.1.3: batch-size extrapolation (ResNet-50, 2070 → V100) ===");
    let origin = Device::Rtx2070;
    let dest = Device::V100;
    let fit_batches = [8usize, 16, 24];
    let targets = [32usize, 48, 64, 96];

    // Predict the fit points through the engine.
    let mut points = Vec::new();
    for &b in &fit_batches {
        let analyzed = ctx.engine().analyzed("resnet50", b, origin)?;
        let pred = ctx.engine().evaluate(&analyzed.plan, dest, Precision::Fp32).run_time_ms();
        points.push((b, pred));
    }
    let model = BatchExtrapolator::fit(&points);
    println!(
        "fitted from predictions at batches {fit_batches:?}: time ≈ {:.2} + {:.3}·batch ms",
        model.a, model.b
    );

    let mut w = CsvWriter::create(
        ctx.csv_path("extrapolate"),
        &["batch", "extrapolated_ms", "measured_ms", "err_pct"],
    )?;
    println!("{:<8} {:>14} {:>12} {:>6}", "batch", "extrapolated", "measured", "err%");
    let mut errs = Vec::new();
    for &b in &targets {
        let pred = model.predict(b);
        let measured = ground_truth_ms("resnet50", b, dest);
        let err = stats::ape(pred, measured);
        errs.push(err);
        println!("{b:<8} {:>12.1}ms {:>10.1}ms {:>5.1}%", pred, measured, err * 100.0);
        w.row(&[
            b.to_string(),
            format!("{pred:.4}"),
            format!("{measured:.4}"),
            format!("{:.2}", err * 100.0),
        ])?;
    }
    w.finish()?;
    println!("avg extrapolation error {:.1}%", stats::mean(&errs) * 100.0);
    Ok(())
}
