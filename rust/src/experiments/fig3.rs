//! Fig. 3 — end-to-end iteration-time predictions (paper §5.2.1).
//!
//! All five models × three batch sizes × all 30 (origin, destination)
//! pairs. For each (model, batch, destination) the paper plots the
//! measured time and the prediction averaged over the five origins, with
//! the average error on top. Paper headline: 11.8% average error overall;
//! per-model 13.4% / 9.5% / 12.6% / 11.2% / 12.3%.

use std::collections::BTreeMap;

use crate::device::ALL_DEVICES;
use crate::experiments::{ground_truth_ms, Ctx};
use crate::util::csv::CsvWriter;
use crate::util::stats;
use crate::{Precision, Result};

pub fn run(ctx: &Ctx) -> Result<()> {
    println!("\n=== Fig. 3: end-to-end predictions (5 models × 3 batch sizes × 30 GPU pairs) ===");
    let mut w = CsvWriter::create(
        ctx.csv_path("fig3"),
        &["model", "batch", "origin", "dest", "measured_ms", "predicted_ms", "err_pct"],
    )?;

    let mut per_model: BTreeMap<&str, Vec<f64>> = Default::default();
    let mut all_errs = Vec::new();

    for model in crate::models::MODEL_NAMES {
        for &batch in crate::models::eval_batch_sizes(model) {
            // Track + analyze once per origin through the engine's
            // cache; every destination below is a thin evaluation over
            // the compiled plan (reused by any later experiment too).
            let mut analyzed = Vec::new();
            for o in ALL_DEVICES {
                analyzed.push((o, ctx.engine().analyzed(model, batch, o)?));
            }
            for dest in ALL_DEVICES {
                let measured = ground_truth_ms(model, batch, dest);
                let mut dest_preds = Vec::new();
                for (origin, at) in &analyzed {
                    if *origin == dest {
                        continue;
                    }
                    let pred = ctx.engine().evaluate(&at.plan, dest, Precision::Fp32).run_time_ms();
                    let err = stats::ape(pred, measured);
                    dest_preds.push(pred);
                    all_errs.push(err);
                    per_model.entry(model).or_default().push(err);
                    w.row(&[
                        model.to_string(),
                        batch.to_string(),
                        origin.id().to_string(),
                        dest.id().to_string(),
                        format!("{measured:.4}"),
                        format!("{pred:.4}"),
                        format!("{:.2}", err * 100.0),
                    ])?;
                }
                let avg_pred = stats::mean(&dest_preds);
                println!(
                    "{model:<12} bs={batch:<3} → {:<10} measured {:>9.1} ms | avg-pred {:>9.1} ms | err {:>5.1}%",
                    dest.id(),
                    measured,
                    avg_pred,
                    stats::ape(avg_pred, measured) * 100.0
                );
            }
        }
    }
    w.finish()?;

    println!("\nper-model average error (paper: resnet 13.4%, inception 9.5%, transformer 12.6%, gnmt 11.2%, dcgan 12.3%):");
    for (model, errs) in &per_model {
        println!("  {model:<12} {:>5.1}%  (n={})", stats::mean(errs) * 100.0, errs.len());
    }
    println!(
        "OVERALL average error: {:.1}%  (paper: 11.8%)  [{} predictions, {}]",
        stats::mean(&all_errs) * 100.0,
        all_errs.len(),
        if ctx.hybrid { "hybrid" } else { "wave-only" }
    );
    Ok(())
}
