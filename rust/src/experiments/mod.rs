//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (§2.3, §5, §6.1) against the simulator ground truth.
//!
//! | id             | Paper artifact                                      |
//! |----------------|-----------------------------------------------------|
//! | `fig1`         | Fig. 1 — peak-FLOPS heuristic vs Habitat, DCGAN/T4  |
//! | `fig3`         | Fig. 3 — end-to-end predictions, 30 GPU pairs       |
//! | `fig4`         | Fig. 4 — per-op error breakdown with importance     |
//! | `table1`       | Table 1 — MLP dataset summary                       |
//! | `contribution` | §5.2.3 — wave-scaling vs MLP contribution           |
//! | `fig6`         | Fig. 6 — case study 1 (GNMT, rent a cloud GPU?)     |
//! | `fig7`         | Fig. 7 — case study 2 (DCGAN, is the V100 better?)  |
//! | `amp`          | §6.1.2 — mixed-precision composition with Daydream  |
//! | `extrapolate`  | §6.1.3 — batch-size extrapolation                   |
//! | `ablation`     | (extra) Eq. 1 vs Eq. 2, metrics-policy sensitivity  |
//! | `dp`           | §6.1.1 — data-parallel scaling composition          |
//! | `scheduler`    | (extra) value of predictions to a Gavel scheduler   |
//! | `all`          | everything above                                    |
//!
//! Each experiment prints a paper-style table to stdout and writes a CSV
//! under the output directory; EXPERIMENTS.md records paper-vs-measured.

mod ablation;
mod amp_exp;
mod contribution;
mod dp;
mod extrapolate_exp;
mod fig1;
mod fig3;
mod fig4;
mod fig6;
mod fig7;
mod scheduler;
mod table1;

use crate::engine::PredictionEngine;
use crate::predict::HybridPredictor;
use crate::Result;

/// Shared context passed to every experiment. All predictions flow
/// through one [`PredictionEngine`], so traces tracked (and plans
/// compiled) by one experiment are reused by the next (`experiment all`
/// tracks and analyzes each (model, batch, origin) exactly once; every
/// per-destination prediction is a thin plan evaluation).
pub struct Ctx {
    engine: PredictionEngine,
    pub out_dir: String,
    /// Whether the MLP artifacts were available (experiments note this).
    pub hybrid: bool,
}

impl Ctx {
    fn new(out_dir: &str, artifacts: &str) -> Self {
        let (engine, hybrid) = match PredictionEngine::from_artifacts(artifacts) {
            Ok(e) => (e, true),
            Err(e) => {
                eprintln!(
                    "note: MLP artifacts unavailable ({e}); running with wave scaling only.\n\
                     Run `make artifacts` for the paper's full hybrid predictor."
                );
                (PredictionEngine::wave_only(), false)
            }
        };
        std::fs::create_dir_all(out_dir).ok();
        Ctx {
            engine,
            out_dir: out_dir.to_string(),
            hybrid,
        }
    }

    pub fn engine(&self) -> &PredictionEngine {
        &self.engine
    }

    pub fn predictor(&self) -> &HybridPredictor {
        self.engine.predictor()
    }

    pub fn csv_path(&self, name: &str) -> String {
        format!("{}/{name}.csv", self.out_dir)
    }
}

/// Ground truth: simulate the model directly on the destination GPU —
/// the stand-in for the paper's "measured" bars.
pub fn ground_truth_ms(model: &str, batch: usize, dest: crate::Device) -> f64 {
    let graph = crate::models::by_name(model, batch).expect("known model");
    crate::sim::Simulator::default().graph_time_ms(dest.spec(), &graph, crate::Precision::Fp32)
}

/// Run one experiment (or `all`).
pub fn run(id: &str, out_dir: &str, artifacts: &str) -> Result<()> {
    let ctx = Ctx::new(out_dir, artifacts);
    match id {
        "fig1" => fig1::run(&ctx)?,
        "fig3" => fig3::run(&ctx)?,
        "fig4" => fig4::run(&ctx)?,
        "table1" => table1::run(&ctx)?,
        "contribution" => contribution::run(&ctx)?,
        "fig6" => fig6::run(&ctx)?,
        "fig7" => fig7::run(&ctx)?,
        "amp" => amp_exp::run(&ctx)?,
        "extrapolate" => extrapolate_exp::run(&ctx)?,
        "ablation" => ablation::run(&ctx)?,
        "dp" => dp::run(&ctx)?,
        "scheduler" => scheduler::run(&ctx)?,
        "all" => {
            fig1::run(&ctx)?;
            fig3::run(&ctx)?;
            fig4::run(&ctx)?;
            table1::run(&ctx)?;
            contribution::run(&ctx)?;
            fig6::run(&ctx)?;
            fig7::run(&ctx)?;
            amp_exp::run(&ctx)?;
            extrapolate_exp::run(&ctx)?;
            ablation::run(&ctx)?;
            dp::run(&ctx)?;
            scheduler::run(&ctx)?;
        }
        other => anyhow::bail!(
            "unknown experiment {other:?}; want fig1|fig3|fig4|table1|contribution|fig6|fig7|amp|extrapolate|ablation|dp|scheduler|all"
        ),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn ground_truth_positive_for_all_models() {
        for model in crate::models::MODEL_NAMES {
            let ms = super::ground_truth_ms(model, 16, crate::Device::V100);
            assert!(ms > 0.0, "{model}");
        }
    }

    #[test]
    fn unknown_experiment_errors() {
        let dir = std::env::temp_dir().join("habitat_exp_test");
        let r = super::run("fig99", dir.to_str().unwrap(), "/nonexistent");
        assert!(r.is_err());
    }
}
