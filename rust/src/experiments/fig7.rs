//! Fig. 7 — case study 2: is the V100 always better? (paper §5.3.2)
//!
//! A user with a 2080Ti considers other GPUs for DCGAN (batch 64 and
//! 128). The paper's finding: the V100 offers only ~1.1× over the 2080Ti
//! and nothing else helps at all — DCGAN is too computationally light to
//! exploit a bigger GPU. Habitat predicts this correctly (avg error 7.7%).

use crate::device::{Device, ALL_DEVICES};
use crate::experiments::{ground_truth_ms, Ctx};
use crate::util::csv::CsvWriter;
use crate::util::stats;
use crate::{Precision, Result};

pub fn run(ctx: &Ctx) -> Result<()> {
    println!("\n=== Fig. 7: case study 2 — DCGAN from a 2080Ti: is the V100 worth it? ===");
    let origin = Device::Rtx2080Ti;
    let mut w = CsvWriter::create(
        ctx.csv_path("fig7"),
        &["batch", "dest", "pred_tput_norm", "measured_tput_norm", "err_pct"],
    )?;

    let dests: Vec<Device> = ALL_DEVICES.into_iter().filter(|d| *d != origin).collect();
    let mut errs = Vec::new();
    for batch in [64usize, 128] {
        let analyzed = ctx.engine().analyzed("dcgan", batch, origin)?;
        let preds = ctx.engine().fan_out(&analyzed.plan, &dests, Precision::Fp32);
        let base = ground_truth_ms("dcgan", batch, origin);
        println!("\nbatch {batch}:  (2080Ti measured {base:.1} ms)");
        println!("{:<10} {:>16} {:>16} {:>6}", "dest", "pred tput (norm)", "meas tput (norm)", "err%");
        for (&dest, pred) in dests.iter().zip(&preds) {
            let measured = ground_truth_ms("dcgan", batch, dest);
            // Throughput normalized to the 2080Ti's measured throughput:
            // ratios of iteration times (same batch size).
            let pred_norm = base / pred.run_time_ms();
            let meas_norm = base / measured;
            let err = stats::ape(pred.run_time_ms(), measured);
            errs.push(err);
            println!(
                "{:<10} {:>15.2}× {:>15.2}× {:>5.1}%",
                dest.id(), pred_norm, meas_norm, err * 100.0
            );
            w.row(&[
                batch.to_string(),
                dest.id().to_string(),
                format!("{pred_norm:.4}"),
                format!("{meas_norm:.4}"),
                format!("{:.2}", err * 100.0),
            ])?;
        }
        let v100_meas = base / ground_truth_ms("dcgan", batch, Device::V100);
        println!(
            "  V100 measured speedup {v100_meas:.2}× — {}",
            if v100_meas < 1.35 {
                "paper's finding holds: not significantly better than the 2080Ti"
            } else {
                "NOTE: differs from the paper's finding"
            }
        );
    }
    w.finish()?;
    println!("\navg prediction error {:.1}% (paper: 7.7%)", stats::mean(&errs) * 100.0);
    Ok(())
}
