//! §6.1.2 — mixed-precision prediction (Habitat ∘ Daydream).
//!
//! From a P4000 FP32 trace, predict the **AMP** iteration time of
//! ResNet-50 on the 2070 and 2080Ti; also between the 2070 and 2080Ti.
//! Paper: the combined approach averages 16.1% error; Daydream alone
//! (from ground-truth FP32 on the destination) averages 10.7%.

use crate::device::Device;
use crate::experiments::Ctx;
use crate::predict::amp;
use crate::sim::{Precision, Simulator};
use crate::util::csv::CsvWriter;
use crate::util::stats;
use crate::Result;

pub fn run(ctx: &Ctx) -> Result<()> {
    println!("\n=== §6.1.2: mixed-precision prediction (Habitat + Daydream) ===");
    let pairs = [
        (Device::P4000, Device::Rtx2070),
        (Device::P4000, Device::Rtx2080Ti),
        (Device::Rtx2070, Device::Rtx2080Ti),
        (Device::Rtx2080Ti, Device::Rtx2070),
    ];
    let batch = 32;
    let graph = crate::models::resnet50(batch);
    let sim = Simulator::default();

    let mut w = CsvWriter::create(
        ctx.csv_path("amp"),
        &["origin", "dest", "measured_amp_ms", "habitat_daydream_ms", "err_pct", "daydream_only_ms", "daydream_err_pct"],
    )?;
    println!(
        "{:<9} {:<9} {:>10} {:>13} {:>6} {:>13} {:>6}",
        "origin", "dest", "meas(amp)", "hab+daydream", "err%", "daydream-only", "err%"
    );
    let (mut combined, mut alone) = (Vec::new(), Vec::new());
    for (origin, dest) in pairs {
        // Ground truth: the simulator running the AMP iteration on dest.
        let measured = sim.graph_time_ms(dest.spec(), &graph, Precision::Amp);
        // Habitat + Daydream from the origin's FP32 trace, through the
        // engine's AMP prediction path (precomputed AMP factors in the
        // compiled plan).
        let analyzed = ctx.engine().analyzed("resnet50", batch, origin)?;
        let predicted = ctx.engine().evaluate(&analyzed.plan, dest, Precision::Amp).run_time_ms();
        // Daydream alone, from the destination's own FP32 trace.
        let dest_trace = ctx.engine().trace("resnet50", batch, dest)?;
        let daydream = amp::amp_time_same_device(&dest_trace);
        let e1 = stats::ape(predicted, measured);
        let e2 = stats::ape(daydream, measured);
        combined.push(e1);
        alone.push(e2);
        println!(
            "{:<9} {:<9} {:>8.1}ms {:>11.1}ms {:>5.1}% {:>11.1}ms {:>5.1}%",
            origin.id(), dest.id(), measured, predicted, e1 * 100.0, daydream, e2 * 100.0
        );
        w.row(&[
            origin.id().to_string(),
            dest.id().to_string(),
            format!("{measured:.4}"),
            format!("{predicted:.4}"),
            format!("{:.2}", e1 * 100.0),
            format!("{daydream:.4}"),
            format!("{:.2}", e2 * 100.0),
        ])?;
    }
    w.finish()?;
    println!(
        "\ncombined avg {:.1}% (paper 16.1%) | daydream-alone avg {:.1}% (paper 10.7%)",
        stats::mean(&combined) * 100.0,
        stats::mean(&alone) * 100.0
    );
    Ok(())
}
