//! Fig. 1 — the peak-FLOPS heuristic goes wrong (paper §2.3).
//!
//! Measure DCGAN (batch 128) on the T4, predict every other GPU with the
//! FLOPS-ratio heuristic, and compare against ground truth — then show
//! Habitat's error on the same predictions. Paper: heuristic errors
//! 42.5–64.9%; Habitat 10.2% average (max 21.8%).

use crate::device::{Device, ALL_DEVICES};
use crate::experiments::{ground_truth_ms, Ctx};
use crate::predict::heuristic;
use crate::util::csv::CsvWriter;
use crate::util::stats;
use crate::{Precision, Result};

pub fn run(ctx: &Ctx) -> Result<()> {
    println!("\n=== Fig. 1: peak-FLOPS heuristic vs Habitat (DCGAN bs=128 from T4) ===");
    let origin = Device::T4;
    let analyzed = ctx.engine().analyzed("dcgan", 128, origin)?;
    let trace = &analyzed.trace;
    let dests: Vec<Device> = ALL_DEVICES.into_iter().filter(|d| *d != origin).collect();
    // One fan-out pass over the compiled plan for all five destinations.
    let preds = ctx.engine().fan_out(&analyzed.plan, &dests, Precision::Fp32);

    let mut w = CsvWriter::create(
        ctx.csv_path("fig1"),
        &["dest", "measured_ms", "heuristic_ms", "heuristic_err_pct", "habitat_ms", "habitat_err_pct"],
    )?;
    println!(
        "{:<10} {:>11} {:>12} {:>9} {:>11} {:>9}",
        "dest", "measured", "heuristic", "err%", "habitat", "err%"
    );
    let mut heur_errs = Vec::new();
    let mut hab_errs = Vec::new();
    for (&dest, pred) in dests.iter().zip(&preds) {
        let measured = ground_truth_ms("dcgan", 128, dest);
        let heur = heuristic::flops_ratio_prediction(trace, dest);
        let hab = pred.run_time_ms();
        let he = stats::ape(heur, measured);
        let ha = stats::ape(hab, measured);
        heur_errs.push(he);
        hab_errs.push(ha);
        println!(
            "{:<10} {:>9.1}ms {:>10.1}ms {:>8.1}% {:>9.1}ms {:>8.1}%",
            dest.id(),
            measured,
            heur,
            he * 100.0,
            hab,
            ha * 100.0
        );
        w.row(&[
            dest.id().to_string(),
            format!("{measured:.4}"),
            format!("{heur:.4}"),
            format!("{:.2}", he * 100.0),
            format!("{hab:.4}"),
            format!("{:.2}", ha * 100.0),
        ])?;
    }
    w.finish()?;
    println!(
        "heuristic: avg {:.1}% / max {:.1}%   habitat: avg {:.1}% / max {:.1}%   (paper: ≥42.5%/64.9% vs 10.2%/21.8%)",
        stats::mean(&heur_errs) * 100.0,
        stats::max(&heur_errs) * 100.0,
        stats::mean(&hab_errs) * 100.0,
        stats::max(&hab_errs) * 100.0
    );
    Ok(())
}
