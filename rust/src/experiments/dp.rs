//! §6.1.1 — data-parallel scaling predictions.
//!
//! Habitat's single-GPU predictions composed with the ring all-reduce
//! model: predicted scaling curves (1–8 × V100) for a compute-heavy model
//! (ResNet-50) and a communication-heavy model (GNMT, 160M parameters),
//! over NVLink and PCIe 3.0 — the qualitative pattern every data-parallel
//! performance study reports (GNMT over PCIe scales poorly; ResNet over
//! NVLink scales almost linearly).

use crate::device::Device;
use crate::experiments::Ctx;
use crate::predict::distributed::{predict_data_parallel, DataParallelConfig, Interconnect};
use crate::util::csv::CsvWriter;
use crate::{Precision, Result};

pub fn run(ctx: &Ctx) -> Result<()> {
    println!("\n=== §6.1.1: data-parallel scaling (Habitat compute + ring all-reduce) ===");
    let origin = Device::Rtx2070;
    let dest = Device::V100;
    let mut w = CsvWriter::create(
        ctx.csv_path("dp"),
        &["model", "interconnect", "world", "iter_ms", "exposed_comm_ms", "throughput", "efficiency"],
    )?;
    for (model, batch) in [("resnet50", 32usize), ("gnmt", 32)] {
        let analyzed = ctx.engine().analyzed(model, batch, origin)?;
        let trace = &analyzed.trace;
        let pred = ctx.engine().evaluate(&analyzed.plan, dest, Precision::Fp32);
        for (ic_name, ic) in [("nvlink", Interconnect::NvLink), ("pcie3", Interconnect::Pcie3)] {
            println!("\n{model} bs={batch}/gpu on {dest} over {ic_name}:");
            println!(
                "{:>6} {:>10} {:>13} {:>12} {:>11}",
                "GPUs", "iter ms", "exposed comm", "samples/s", "efficiency"
            );
            for world in [1usize, 2, 4, 8] {
                let dp = predict_data_parallel(
                    trace,
                    &pred,
                    &DataParallelConfig {
                        world,
                        interconnect: ic,
                        overlap: 0.7,
                    },
                );
                println!(
                    "{world:>6} {:>10.1} {:>12.1}ms {:>12.0} {:>10.0}%",
                    dp.iter_ms,
                    dp.exposed_ms,
                    dp.throughput,
                    dp.efficiency * 100.0
                );
                w.row(&[
                    model.to_string(),
                    ic_name.to_string(),
                    world.to_string(),
                    format!("{:.4}", dp.iter_ms),
                    format!("{:.4}", dp.exposed_ms),
                    format!("{:.2}", dp.throughput),
                    format!("{:.4}", dp.efficiency),
                ])?;
            }
        }
    }
    w.finish()?;
    println!("\n(expected shape: resnet/nvlink ≈ linear; gnmt/pcie3 scales worst)");
    Ok(())
}
