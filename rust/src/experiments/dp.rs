//! §6.1.1 — data-parallel scaling predictions, cluster-scale.
//!
//! Habitat's single-GPU predictions composed with the topology-aware
//! collective model ([`crate::comm`]): predicted scaling curves
//! (1–256 × V100) for a compute-heavy model (ResNet-50) and a
//! communication-heavy model (GNMT, 160M parameters), over the two seed
//! topologies — `dgx` (NVLink within a node, InfiniBand across) and
//! `cloud` (PCIe 3.0 within, 25 GbE across). The qualitative pattern
//! every data-parallel performance study reports: GNMT on `cloud`
//! scales poorly, ResNet on `dgx` stays near-linear well past a single
//! node.

use crate::comm::{ClusterParams, Topology};
use crate::coordinator::DEFAULT_CLUSTER_WORLDS;
use crate::device::Device;
use crate::experiments::Ctx;
use crate::util::csv::CsvWriter;
use crate::{Precision, Result};

pub fn run(ctx: &Ctx) -> Result<()> {
    println!("\n=== §6.1.1: data-parallel scaling (Habitat compute + topology-aware collectives) ===");
    let origin = Device::Rtx2070;
    let dest = Device::V100;
    let topologies = [Topology::DGX, Topology::CLOUD];
    let worlds = DEFAULT_CLUSTER_WORLDS;
    let params = ClusterParams::default();
    let mut w = CsvWriter::create(
        ctx.csv_path("dp"),
        &["model", "topology", "world", "iter_ms", "exposed_comm_ms", "throughput", "efficiency"],
    )?;
    // Both models' compute predictions come from one multi-trace sweep
    // on the engine's shared pool; each topology × world grid then
    // shares its model's single swept compute time.
    let items = [("resnet50", 32usize), ("gnmt", 32)];
    let reports = ctx.engine().predict_cluster_many(
        &items,
        origin,
        dest,
        Precision::Fp32,
        &topologies,
        &worlds,
        &params,
    )?;
    for ((model, batch), report) in items.iter().zip(&reports) {
        for topology in topologies {
            println!("\n{model} bs={batch}/gpu on {dest} over {}:", topology.name());
            println!(
                "{:>6} {:>10} {:>13} {:>12} {:>11}",
                "GPUs", "iter ms", "exposed comm", "samples/s", "efficiency"
            );
            for cell in report.configs.iter().filter(|c| c.topology == topology) {
                let dp = &cell.pred;
                println!(
                    "{:>6} {:>10.1} {:>12.1}ms {:>12.0} {:>10.0}%",
                    cell.world,
                    dp.iter_ms,
                    dp.exposed_ms,
                    dp.throughput,
                    dp.efficiency * 100.0
                );
                w.row(&[
                    model.to_string(),
                    topology.name().to_string(),
                    cell.world.to_string(),
                    format!("{:.4}", dp.iter_ms),
                    format!("{:.4}", dp.exposed_ms),
                    format!("{:.2}", dp.throughput),
                    format!("{:.4}", dp.efficiency),
                ])?;
            }
        }
    }
    w.finish()?;
    println!("\n(expected shape: resnet/dgx ≈ linear; gnmt/cloud scales worst)");
    Ok(())
}
