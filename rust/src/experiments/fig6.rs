//! Fig. 6 — case study 1: should I rent a cloud GPU? (paper §5.3.1)
//!
//! A user with a P4000 workstation considers renting a P100, T4, or V100
//! to train GNMT. Fig. 6a: predicted training throughput normalized to
//! the P4000. Fig. 6b: predicted cost-normalized throughput. The paper's
//! finding: the V100 is fastest (up to 4.0×), but the **T4** has the best
//! cost-normalized throughput at every batch size — and Habitat predicts
//! the correct *ordering* everywhere (avg error 10.7%).

use crate::device::Device;
use crate::experiments::{ground_truth_ms, Ctx};
use crate::util::csv::CsvWriter;
use crate::util::stats;
use crate::{cost, Precision, Result};

pub fn run(ctx: &Ctx) -> Result<()> {
    println!("\n=== Fig. 6: case study 1 — GNMT from a P4000, rent P100/T4/V100? ===");
    let origin = Device::P4000;
    let clouds = [Device::P100, Device::T4, Device::V100];
    let batches = crate::models::eval_batch_sizes("gnmt");

    let mut w = CsvWriter::create(
        ctx.csv_path("fig6"),
        &[
            "batch", "dest", "pred_ms", "measured_ms", "err_pct",
            "pred_speedup_vs_p4000", "measured_speedup", "pred_cost_norm_tput", "measured_cost_norm_tput",
        ],
    )?;

    let mut errs = Vec::new();
    for &batch in batches {
        let analyzed = ctx.engine().analyzed("gnmt", batch, origin)?;
        // One fan-out pass over the compiled plan for all three clouds.
        let preds = ctx.engine().fan_out(&analyzed.plan, &clouds, Precision::Fp32);
        let base_measured = ground_truth_ms("gnmt", batch, origin);
        println!("\nbatch {batch}:  (P4000 measured {base_measured:.1} ms)");
        println!(
            "{:<8} {:>9} {:>9} {:>6} {:>11} {:>11} {:>14} {:>14}",
            "dest", "pred", "meas", "err%", "pred-spdup", "meas-spdup", "pred-$/tput", "meas-$/tput"
        );

        let mut pred_cost_rank: Vec<(Device, f64)> = Vec::new();
        let mut meas_cost_rank: Vec<(Device, f64)> = Vec::new();
        for (&dest, pred) in clouds.iter().zip(&preds) {
            let measured = ground_truth_ms("gnmt", batch, dest);
            let err = stats::ape(pred.run_time_ms(), measured);
            errs.push(err);
            let pred_speedup = base_measured / pred.run_time_ms();
            let meas_speedup = base_measured / measured;
            let pred_cnt = cost::cost_normalized_throughput(dest, pred.throughput()).unwrap();
            let meas_tput = cost::throughput(batch, measured);
            let meas_cnt = cost::cost_normalized_throughput(dest, meas_tput).unwrap();
            pred_cost_rank.push((dest, pred_cnt));
            meas_cost_rank.push((dest, meas_cnt));
            println!(
                "{:<8} {:>7.1}ms {:>7.1}ms {:>5.1}% {:>10.2}× {:>10.2}× {:>14.1} {:>14.1}",
                dest.id(), pred.run_time_ms(), measured, err * 100.0,
                pred_speedup, meas_speedup, pred_cnt, meas_cnt
            );
            w.row(&[
                batch.to_string(),
                dest.id().to_string(),
                format!("{:.4}", pred.run_time_ms()),
                format!("{measured:.4}"),
                format!("{:.2}", err * 100.0),
                format!("{pred_speedup:.4}"),
                format!("{meas_speedup:.4}"),
                format!("{pred_cnt:.2}"),
                format!("{meas_cnt:.2}"),
            ])?;
        }
        let best = |v: &[(Device, f64)]| {
            v.iter().max_by(|a, b| a.1.partial_cmp(&b.1).unwrap()).unwrap().0
        };
        let (pb, mb) = (best(&pred_cost_rank), best(&meas_cost_rank));
        println!(
            "  best cost-normalized: predicted {} / measured {}  → {}",
            pb.id(),
            mb.id(),
            if pb == mb { "CORRECT decision" } else { "WRONG decision" }
        );
    }
    w.finish()?;
    println!(
        "\navg prediction error {:.1}% (paper: 10.7%); paper's finding: T4 best cost-normalized at all batch sizes",
        stats::mean(&errs) * 100.0
    );
    Ok(())
}
