//! §5.2.3 — prediction contribution breakdown.
//!
//! How much of Habitat's end-to-end prediction flows through each
//! mechanism? Paper: wave scaling covers **95% of unique operations** but
//! only **46% of execution time**; the MLPs cover the remaining 5% of ops
//! and **54% of time**.

use crate::device::ALL_DEVICES;
use crate::experiments::Ctx;
use crate::predict::PredictionMethod;
use crate::util::csv::CsvWriter;
use crate::util::stats;
use crate::{Precision, Result};

pub fn run(ctx: &Ctx) -> Result<()> {
    println!("\n=== §5.2.3: wave scaling vs MLP contribution breakdown ===");
    if !ctx.hybrid {
        println!("(wave-only mode: MLP contribution is 0 by construction — build artifacts first)");
    }
    let mut w = CsvWriter::create(
        ctx.csv_path("contribution"),
        &["model", "wave_op_frac", "mlp_op_frac", "wave_time_frac", "mlp_time_frac"],
    )?;
    let mut op_fracs = Vec::new();
    let mut time_fracs = Vec::new();
    println!(
        "{:<12} {:>10} {:>10} {:>12} {:>12}",
        "model", "wave ops", "mlp ops", "wave time", "mlp time"
    );
    for model in crate::models::MODEL_NAMES {
        let batch = crate::models::eval_batch_sizes(model)[1];
        let mut model_mlp_ops = 0.0;
        let mut model_mlp_time = 0.0;
        let mut n = 0.0;
        for origin in ALL_DEVICES {
            let analyzed = ctx.engine().analyzed(model, batch, origin)?;
            for dest in ALL_DEVICES {
                if dest == origin {
                    continue;
                }
                let pred = ctx.engine().evaluate(&analyzed.plan, dest, Precision::Fp32);
                let mlp_ops = pred
                    .ops
                    .iter()
                    .filter(|o| o.method == PredictionMethod::Mlp)
                    .count() as f64
                    / pred.ops.len() as f64;
                model_mlp_ops += mlp_ops;
                model_mlp_time += pred.mlp_time_fraction();
                n += 1.0;
            }
        }
        let (op_frac, time_frac) = (model_mlp_ops / n, model_mlp_time / n);
        op_fracs.push(op_frac);
        time_fracs.push(time_frac);
        println!(
            "{model:<12} {:>9.1}% {:>9.1}% {:>11.1}% {:>11.1}%",
            (1.0 - op_frac) * 100.0,
            op_frac * 100.0,
            (1.0 - time_frac) * 100.0,
            time_frac * 100.0
        );
        w.row(&[
            model.to_string(),
            format!("{:.4}", 1.0 - op_frac),
            format!("{op_frac:.4}"),
            format!("{:.4}", 1.0 - time_frac),
            format!("{time_frac:.4}"),
        ])?;
    }
    w.finish()?;
    println!(
        "\naverage: wave {:.0}% of ops / {:.0}% of time; MLP {:.0}% of ops / {:.0}% of time  (paper: 95%/46% vs 5%/54%)",
        (1.0 - stats::mean(&op_fracs)) * 100.0,
        (1.0 - stats::mean(&time_fracs)) * 100.0,
        stats::mean(&op_fracs) * 100.0,
        stats::mean(&time_fracs) * 100.0
    );
    Ok(())
}
