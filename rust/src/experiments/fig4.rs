//! Fig. 4 — per-operation prediction-error breakdown with importance
//! (paper §5.2.2).
//!
//! For every op *type*, averaged across all models and all 30 GPU pairs:
//! the prediction error of that op's time, annotated with the op's
//! importance (share of iteration time). Paper: MLP ops average 18.0%
//! error; wave-scaled ops average 29.8%, but high-error wave-scaled ops
//! (`__add__`, `scatter`) have ≤0.3% importance.

use std::collections::BTreeMap;

use crate::device::ALL_DEVICES;
use crate::experiments::Ctx;
use crate::sim::Simulator;
use crate::tracker::OperationTracker;
use crate::util::csv::CsvWriter;
use crate::util::stats;
use crate::{Precision, Result};

#[derive(Default)]
struct OpAgg {
    errs: Vec<f64>,
    time_ms: f64,
    mlp: bool,
}

pub fn run(ctx: &Ctx) -> Result<()> {
    println!("\n=== Fig. 4: per-op error breakdown (importance on top) ===");
    let sim = Simulator::default();
    let mut agg: BTreeMap<String, OpAgg> = Default::default();
    let mut total_time = 0.0;

    for model in crate::models::MODEL_NAMES {
        let batch = crate::models::eval_batch_sizes(model)[1];
        let graph = crate::models::by_name(model, batch).unwrap();
        let mut analyzed = Vec::new();
        for o in ALL_DEVICES {
            analyzed.push((o, ctx.engine().analyzed(model, batch, o)?));
        }
        for dest in ALL_DEVICES {
            // Per-op ground truth on the destination (a custom-simulator
            // tracking pass, so it stays off the engine's cache).
            let dest_trace = OperationTracker::new(dest)
                .with_simulator(sim.clone())
                .track(&graph);
            for (origin, at) in &analyzed {
                if *origin == dest {
                    continue;
                }
                let pred = ctx.engine().evaluate(&at.plan, dest, Precision::Fp32);
                for (p, t) in pred.ops.iter().zip(&dest_trace.ops) {
                    let measured = t.total_ms();
                    if measured <= 0.0 {
                        continue;
                    }
                    let e = agg.entry(p.short_name.clone()).or_default();
                    e.errs.push(stats::ape(p.time_ms, measured));
                    e.time_ms += measured;
                    e.mlp |= p.method == crate::predict::PredictionMethod::Mlp;
                    total_time += measured;
                }
            }
        }
    }

    let mut rows: Vec<(String, f64, f64, bool)> = agg
        .into_iter()
        .map(|(name, a)| (name, stats::mean(&a.errs), a.time_ms / total_time, a.mlp))
        .collect();
    rows.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());

    let mut w = CsvWriter::create(
        ctx.csv_path("fig4"),
        &["op", "method", "avg_err_pct", "importance_pct"],
    )?;
    println!("{:<20} {:>8} {:>10} {:>12}", "op", "method", "err%", "importance%");
    let (mut mlp_errs, mut wave_errs) = (Vec::new(), Vec::new());
    for (name, err, importance, mlp) in &rows {
        if *importance >= 0.001 {
            println!(
                "{name:<20} {:>8} {:>9.1}% {:>11.2}%",
                if *mlp { "mlp" } else { "wave" },
                err * 100.0,
                importance * 100.0
            );
        }
        if *mlp {
            mlp_errs.push(*err);
        } else {
            wave_errs.push(*err);
        }
        w.row(&[
            name.clone(),
            if *mlp { "mlp" } else { "wave" }.into(),
            format!("{:.2}", err * 100.0),
            format!("{:.3}", importance * 100.0),
        ])?;
    }
    w.finish()?;
    println!(
        "MLP-op avg error {:.1}% (paper 18.0%) | wave-scaled avg error {:.1}% (paper 29.8%)",
        stats::mean(&mlp_errs) * 100.0,
        stats::mean(&wave_errs) * 100.0
    );
    Ok(())
}
