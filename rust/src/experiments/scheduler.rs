//! Heterogeneous-cluster scheduling value (intro use-case 3).
//!
//! Quantifies what Habitat's predictions buy a Gavel-style scheduler: a
//! pool of jobs (each profiled only on its owner's workstation GPU) is
//! placed onto a heterogeneous cluster under three policies, and the
//! achieved aggregate (ground-truth) throughput is compared:
//!
//! * `habitat`     — greedy max-normalized-throughput on *predicted* rates,
//! * `round-robin` — device-agnostic placement,
//! * `worst-case`  — adversarial (minimizes the objective), as a bound.
//!
//! The interesting number is how close habitat-informed placement gets to
//! the oracle (same greedy policy on ground-truth rates).
//!
//! A second round repeats the comparison for *gang* placements: each job
//! is a ×2 data-parallel gang on the `dgx` topology, rates come from
//! [`ThroughputMatrix::build_cluster`] (Habitat compute composed with
//! the topology-aware collective model), and the ground truth applies
//! the same collective composition to the measured single-GPU times —
//! so the gap measured is purely Habitat's compute-prediction error.

use crate::cluster::{schedule, Inventory, Job, ThroughputMatrix};
use crate::device::Device;
use crate::engine::PredictionEngine;
use crate::experiments::Ctx;
use crate::tracker::Trace;
use crate::util::csv::CsvWriter;
use crate::Result;

fn job_pool(engine: &PredictionEngine) -> Result<Vec<(Job, Trace)>> {
    let specs = [
        ("a/resnet50", "resnet50", 64, Device::Rtx2070),
        ("b/gnmt", "gnmt", 32, Device::P4000),
        ("c/transformer", "transformer", 64, Device::Rtx2080Ti),
        ("d/dcgan", "dcgan", 128, Device::Rtx2070),
        ("e/inception3", "inception3", 32, Device::P4000),
        ("f/vgg16", "vgg16", 32, Device::Rtx2080Ti),
        ("g/bert_base", "bert_base", 16, Device::Rtx2070),
        ("h/resnet50", "resnet50", 32, Device::P4000),
    ];
    let mut pool = Vec::with_capacity(specs.len());
    for (name, model, batch, origin) in specs {
        let job = Job {
            name: name.into(),
            model: model.into(),
            batch,
            origin,
        };
        // Tracked via the shared engine cache; the matrix builder wants
        // an owned trace, so clone out of the Arc.
        let trace = engine.trace(model, batch, origin)?.as_ref().clone();
        pool.push((job, trace));
    }
    Ok(pool)
}

/// Ground-truth throughput of a job on a device.
fn truth_tput(job: &Job, device: Device) -> f64 {
    let ms = crate::experiments::ground_truth_ms(&job.model, job.batch, device);
    crate::cost::throughput(job.batch, ms)
}

/// Objective: Σ over placed jobs of (ground-truth throughput on the
/// assigned device / job's best ground-truth throughput in the cluster).
fn objective(placements: &[(usize, Device)], jobs: &[Job], devices: &[Device]) -> f64 {
    placements
        .iter()
        .map(|(j, d)| {
            let best = devices
                .iter()
                .map(|dev| truth_tput(&jobs[*j], *dev))
                .fold(f64::MIN, f64::max);
            truth_tput(&jobs[*j], *d) / best
        })
        .sum()
}

pub fn run(ctx: &Ctx) -> Result<()> {
    println!("\n=== Scheduler value: habitat-informed vs baselines (8 jobs, 2×V100 + 2×P100 + 2×T4 + 2×2080Ti) ===");
    let pool = job_pool(ctx.engine())?;
    let jobs: Vec<Job> = pool.iter().map(|(j, _)| j.clone()).collect();
    let devices = [Device::V100, Device::P100, Device::T4, Device::Rtx2080Ti];
    let inventory: Inventory = devices.iter().map(|d| (*d, 2usize)).collect();

    // habitat policy: greedy on *predicted* rates — the whole matrix is
    // one multi-trace sweep on the engine's shared pool.
    let predicted = ThroughputMatrix::build(ctx.engine(), &pool, &devices);
    let habitat_placement: Vec<(usize, Device)> = schedule(&predicted, &inventory)
        .into_iter()
        .map(|p| {
            let j = jobs.iter().position(|job| job.name == p.job).unwrap();
            (j, p.device)
        })
        .collect();

    // oracle policy: same greedy, on ground-truth rates.
    let oracle_matrix = ThroughputMatrix {
        jobs: jobs.clone(),
        devices: devices.to_vec(),
        matrix: jobs
            .iter()
            .map(|j| devices.iter().map(|d| truth_tput(j, *d)).collect())
            .collect(),
    };
    let oracle_placement: Vec<(usize, Device)> = schedule(&oracle_matrix, &inventory)
        .into_iter()
        .map(|p| {
            let j = jobs.iter().position(|job| job.name == p.job).unwrap();
            (j, p.device)
        })
        .collect();

    // round-robin: jobs in order, devices cycled.
    let rr_placement: Vec<(usize, Device)> = (0..jobs.len())
        .map(|j| (j, devices[j % devices.len()]))
        .collect();

    // worst-case: greedy on *negated* truth (adversarial bound).
    let worst_matrix = ThroughputMatrix {
        jobs: jobs.clone(),
        devices: devices.to_vec(),
        matrix: jobs
            .iter()
            .map(|j| devices.iter().map(|d| 1.0 / truth_tput(j, *d)).collect())
            .collect(),
    };
    let worst_placement: Vec<(usize, Device)> = schedule(&worst_matrix, &inventory)
        .into_iter()
        .map(|p| {
            let j = jobs.iter().position(|job| job.name == p.job).unwrap();
            (j, p.device)
        })
        .collect();

    let mut w = CsvWriter::create(ctx.csv_path("scheduler"), &["policy", "objective", "pct_of_oracle"])?;
    let oracle_obj = objective(&oracle_placement, &jobs, &devices);
    println!("{:<24} {:>10} {:>12}", "policy", "objective", "% of oracle");
    for (name, placement) in [
        ("oracle (ground truth)", &oracle_placement),
        ("habitat (predicted)", &habitat_placement),
        ("round-robin", &rr_placement),
        ("worst-case", &worst_placement),
    ] {
        let obj = objective(placement, &jobs, &devices);
        println!("{name:<24} {obj:>10.3} {:>11.1}%", obj / oracle_obj * 100.0);
        w.row(&[
            name.to_string(),
            format!("{obj:.4}"),
            format!("{:.2}", obj / oracle_obj * 100.0),
        ])?;
    }

    // ── Round 2: ×2 gang placement on the dgx topology ──────────────
    // One gang slot per device model (the 2 GPUs of the inventory pair
    // up), so 4 of the 8 jobs place — the policies fight over which.
    println!("\n=== Scheduler value, ×2 gangs on dgx (4 gang slots) ===");
    let topology = crate::comm::Topology::DGX;
    let world = 2usize;
    let params = crate::comm::ClusterParams::default();
    let gang_inventory: Inventory = devices.iter().map(|d| (*d, 1usize)).collect();

    // Ground-truth gang throughput: the measured single-GPU time run
    // through the identical collective composition.
    let truth_gang = |j: usize, d: Device| -> f64 {
        let job = &jobs[j];
        let compute_ms = crate::experiments::ground_truth_ms(&job.model, job.batch, d);
        let comm = crate::comm::trace_comm(&pool[j].1);
        crate::comm::cluster::compose(compute_ms, job.batch, &comm, topology, world, &params)
            .throughput
    };
    let gang_objective = |placements: &[(usize, Device)]| -> f64 {
        placements
            .iter()
            .map(|(j, d)| {
                let best = devices.iter().map(|dev| truth_gang(*j, *dev)).fold(f64::MIN, f64::max);
                truth_gang(*j, *d) / best
            })
            .sum()
    };
    let to_indices = |placements: Vec<crate::cluster::Placement>| -> Vec<(usize, Device)> {
        placements
            .into_iter()
            .map(|p| {
                let j = jobs.iter().position(|job| job.name == p.job).unwrap();
                (j, p.device)
            })
            .collect()
    };

    // habitat policy: greedy on gang rates *predicted* by the cluster
    // composition over the multi-trace single-GPU sweep.
    let predicted_gang =
        ThroughputMatrix::build_cluster(ctx.engine(), &pool, &devices, topology, world, &params);
    let habitat_gang = to_indices(schedule(&predicted_gang, &gang_inventory));

    // oracle: same greedy on ground-truth gang rates.
    let oracle_gang_matrix = ThroughputMatrix {
        jobs: jobs.clone(),
        devices: devices.to_vec(),
        matrix: (0..jobs.len())
            .map(|j| devices.iter().map(|d| truth_gang(j, *d)).collect())
            .collect(),
    };
    let oracle_gang = to_indices(schedule(&oracle_gang_matrix, &gang_inventory));

    // round-robin: first 4 jobs in order, devices cycled.
    let rr_gang: Vec<(usize, Device)> =
        (0..devices.len()).map(|j| (j, devices[j % devices.len()])).collect();

    let oracle_gang_obj = gang_objective(&oracle_gang);
    println!("{:<24} {:>10} {:>12}", "policy", "objective", "% of oracle");
    for (name, placement) in [
        ("oracle ×2 dgx", &oracle_gang),
        ("habitat ×2 dgx", &habitat_gang),
        ("round-robin ×2 dgx", &rr_gang),
    ] {
        let obj = gang_objective(placement);
        println!("{name:<24} {obj:>10.3} {:>11.1}%", obj / oracle_gang_obj * 100.0);
        w.row(&[
            name.to_string(),
            format!("{obj:.4}"),
            format!("{:.2}", obj / oracle_gang_obj * 100.0),
        ])?;
    }
    w.finish()?;
    Ok(())
}
