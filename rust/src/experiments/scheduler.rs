//! Heterogeneous-cluster scheduling value (intro use-case 3).
//!
//! Quantifies what Habitat's predictions buy a Gavel-style scheduler: a
//! pool of jobs (each profiled only on its owner's workstation GPU) is
//! placed onto a heterogeneous cluster under three policies, and the
//! achieved aggregate (ground-truth) throughput is compared:
//!
//! * `habitat`     — greedy max-normalized-throughput on *predicted* rates,
//! * `round-robin` — device-agnostic placement,
//! * `worst-case`  — adversarial (minimizes the objective), as a bound.
//!
//! The interesting number is how close habitat-informed placement gets to
//! the oracle (same greedy policy on ground-truth rates).

use crate::cluster::{schedule, Inventory, Job, ThroughputMatrix};
use crate::device::Device;
use crate::engine::PredictionEngine;
use crate::experiments::Ctx;
use crate::tracker::Trace;
use crate::util::csv::CsvWriter;
use crate::Result;

fn job_pool(engine: &PredictionEngine) -> Result<Vec<(Job, Trace)>> {
    let specs = [
        ("a/resnet50", "resnet50", 64, Device::Rtx2070),
        ("b/gnmt", "gnmt", 32, Device::P4000),
        ("c/transformer", "transformer", 64, Device::Rtx2080Ti),
        ("d/dcgan", "dcgan", 128, Device::Rtx2070),
        ("e/inception3", "inception3", 32, Device::P4000),
        ("f/vgg16", "vgg16", 32, Device::Rtx2080Ti),
        ("g/bert_base", "bert_base", 16, Device::Rtx2070),
        ("h/resnet50", "resnet50", 32, Device::P4000),
    ];
    let mut pool = Vec::with_capacity(specs.len());
    for (name, model, batch, origin) in specs {
        let job = Job {
            name: name.into(),
            model: model.into(),
            batch,
            origin,
        };
        // Tracked via the shared engine cache; the matrix builder wants
        // an owned trace, so clone out of the Arc.
        let trace = engine.trace(model, batch, origin)?.as_ref().clone();
        pool.push((job, trace));
    }
    Ok(pool)
}

/// Ground-truth throughput of a job on a device.
fn truth_tput(job: &Job, device: Device) -> f64 {
    let ms = crate::experiments::ground_truth_ms(&job.model, job.batch, device);
    crate::cost::throughput(job.batch, ms)
}

/// Objective: Σ over placed jobs of (ground-truth throughput on the
/// assigned device / job's best ground-truth throughput in the cluster).
fn objective(placements: &[(usize, Device)], jobs: &[Job], devices: &[Device]) -> f64 {
    placements
        .iter()
        .map(|(j, d)| {
            let best = devices
                .iter()
                .map(|dev| truth_tput(&jobs[*j], *dev))
                .fold(f64::MIN, f64::max);
            truth_tput(&jobs[*j], *d) / best
        })
        .sum()
}

pub fn run(ctx: &Ctx) -> Result<()> {
    println!("\n=== Scheduler value: habitat-informed vs baselines (8 jobs, 2×V100 + 2×P100 + 2×T4 + 2×2080Ti) ===");
    let pool = job_pool(ctx.engine())?;
    let jobs: Vec<Job> = pool.iter().map(|(j, _)| j.clone()).collect();
    let devices = [Device::V100, Device::P100, Device::T4, Device::Rtx2080Ti];
    let inventory: Inventory = devices.iter().map(|d| (*d, 2usize)).collect();

    // habitat policy: greedy on *predicted* rates.
    let predicted = ThroughputMatrix::build(ctx.predictor(), &pool, &devices);
    let habitat_placement: Vec<(usize, Device)> = schedule(&predicted, &inventory)
        .into_iter()
        .map(|p| {
            let j = jobs.iter().position(|job| job.name == p.job).unwrap();
            (j, p.device)
        })
        .collect();

    // oracle policy: same greedy, on ground-truth rates.
    let oracle_matrix = ThroughputMatrix {
        jobs: jobs.clone(),
        devices: devices.to_vec(),
        matrix: jobs
            .iter()
            .map(|j| devices.iter().map(|d| truth_tput(j, *d)).collect())
            .collect(),
    };
    let oracle_placement: Vec<(usize, Device)> = schedule(&oracle_matrix, &inventory)
        .into_iter()
        .map(|p| {
            let j = jobs.iter().position(|job| job.name == p.job).unwrap();
            (j, p.device)
        })
        .collect();

    // round-robin: jobs in order, devices cycled.
    let rr_placement: Vec<(usize, Device)> = (0..jobs.len())
        .map(|j| (j, devices[j % devices.len()]))
        .collect();

    // worst-case: greedy on *negated* truth (adversarial bound).
    let worst_matrix = ThroughputMatrix {
        jobs: jobs.clone(),
        devices: devices.to_vec(),
        matrix: jobs
            .iter()
            .map(|j| devices.iter().map(|d| 1.0 / truth_tput(j, *d)).collect())
            .collect(),
    };
    let worst_placement: Vec<(usize, Device)> = schedule(&worst_matrix, &inventory)
        .into_iter()
        .map(|p| {
            let j = jobs.iter().position(|job| job.name == p.job).unwrap();
            (j, p.device)
        })
        .collect();

    let mut w = CsvWriter::create(ctx.csv_path("scheduler"), &["policy", "objective", "pct_of_oracle"])?;
    let oracle_obj = objective(&oracle_placement, &jobs, &devices);
    println!("{:<24} {:>10} {:>12}", "policy", "objective", "% of oracle");
    for (name, placement) in [
        ("oracle (ground truth)", &oracle_placement),
        ("habitat (predicted)", &habitat_placement),
        ("round-robin", &rr_placement),
        ("worst-case", &worst_placement),
    ] {
        let obj = objective(placement, &jobs, &devices);
        println!("{name:<24} {obj:>10.3} {:>11.1}%", obj / oracle_obj * 100.0);
        w.row(&[
            name.to_string(),
            format!("{obj:.4}"),
            format!("{:.2}", obj / oracle_obj * 100.0),
        ])?;
    }
    w.finish()?;
    Ok(())
}
