//! Ablations the paper discusses but does not plot:
//!
//! * **Eq. 1 vs Eq. 2** — §3.3 argues the ⌈B/W⌉ terms can be dropped
//!   because kernels have many waves; quantify the difference.
//! * **Metrics policy** — §4.2's γ=1 fallback: how much accuracy do we
//!   lose at the paper's 99.5th-percentile profiling threshold vs a warm
//!   cache (all kernels profiled) vs no metrics at all?

use crate::device::ALL_DEVICES;
use crate::engine::PredictionEngine;
use crate::experiments::{ground_truth_ms, Ctx};
use crate::predict::{HybridPredictor, MetricsPolicy};
use crate::util::csv::CsvWriter;
use crate::util::stats;
use crate::Result;

/// Sweep one predictor variant. Traces come from the shared engine cache
/// (tracked once across all variants); each variant compiles its own
/// plan per trace (the γ metrics policy is baked into the plan, which is
/// exactly what the ablation isolates) and evaluates it per destination.
fn sweep(engine: &PredictionEngine, predictor: &HybridPredictor) -> Result<f64> {
    let mut errs = Vec::new();
    for model in crate::models::MODEL_NAMES {
        let batch = crate::models::eval_batch_sizes(model)[1];
        for origin in [crate::Device::Rtx2070, crate::Device::P100] {
            let trace = engine.trace(model, batch, origin)?;
            let plan = crate::plan::AnalyzedPlan::build(&trace, &predictor.metrics_policy);
            for dest in ALL_DEVICES {
                if dest == origin {
                    continue;
                }
                let pred = predictor.evaluate(&plan, dest).run_time_ms();
                errs.push(stats::ape(pred, ground_truth_ms(model, batch, dest)));
            }
        }
    }
    Ok(stats::mean(&errs))
}

pub fn run(ctx: &Ctx) -> Result<()> {
    println!("\n=== Ablations: Eq.1 vs Eq.2; metrics-policy sensitivity ===");
    // Ablate on the wave-only predictor: in the hybrid configuration the
    // MLPs absorb ~90% of predicted time, washing out any difference in
    // the wave-scaling machinery. Wave-only isolates Eq.1-vs-Eq.2 and the
    // γ metrics policy — plus one hybrid row as the reference point.
    let wave = HybridPredictor::wave_only();
    let variants: Vec<(&str, HybridPredictor)> = vec![
        ("hybrid (reference)", ctx.predictor().clone()),
        ("wave eq2 + percentile-99.5 (paper)", wave.clone()),
        ("wave eq1 + percentile-99.5", wave.clone().with_eq1(true)),
        (
            "wave eq2 + warm cache (All)",
            wave.clone().with_metrics_policy(MetricsPolicy::All),
        ),
        (
            "wave eq2 + cold cache (None, γ=1)",
            wave.clone().with_metrics_policy(MetricsPolicy::None),
        ),
        (
            "wave eq2 + percentile-50",
            wave.with_metrics_policy(MetricsPolicy::Percentile(50.0)),
        ),
    ];
    let mut w = CsvWriter::create(ctx.csv_path("ablation"), &["variant", "avg_err_pct"])?;
    println!("{:<38} {:>8}", "variant", "avg err");
    for (name, predictor) in variants {
        let err = sweep(ctx.engine(), &predictor)?;
        println!("{name:<38} {:>7.1}%", err * 100.0);
        w.row(&[name.to_string(), format!("{:.2}", err * 100.0)])?;
    }
    w.finish()?;
    Ok(())
}
