//! Table 1 — MLP dataset summary (paper §4.3.2).
//!
//! Prints the feature counts and the dataset sizes actually generated
//! (from `data/*.csv` if present) next to the paper's numbers. The paper
//! sampled ~100k configurations per op on six physical GPUs; our default
//! is scaled down (see DESIGN.md §1) but the schema is identical.

use crate::experiments::Ctx;
use crate::opgraph::MlpOp;
use crate::util::csv::CsvWriter;
use crate::Result;

/// Paper Table 1 dataset sizes (configurations, ×6 GPUs).
fn paper_size(op: MlpOp) -> usize {
    match op {
        MlpOp::Conv2d => 91_138,
        MlpOp::Lstm => 124_176,
        MlpOp::Bmm => 131_022,
        MlpOp::Linear => 155_596,
    }
}

pub fn run(ctx: &Ctx) -> Result<()> {
    println!("\n=== Table 1: MLP dataset summary ===");
    println!(
        "{:<26} {:>10} {:>16} {:>16}",
        "Operation", "Features", "Paper size", "Ours (rows/6)"
    );
    let mut w = CsvWriter::create(
        ctx.csv_path("table1"),
        &["op", "features", "paper_configs", "our_configs"],
    )?;
    for op in MlpOp::ALL {
        let ours = match crate::util::csv::read_numeric(format!("data/{}.csv", op.id())) {
            Ok((_, rows)) => rows.len() / 6,
            Err(_) => 0,
        };
        println!(
            "{:<26} {:>7} + 4 {:>12} × 6 {:>12} × 6",
            match op {
                MlpOp::Conv2d => "2D Convolution",
                MlpOp::Lstm => "LSTM",
                MlpOp::Bmm => "Batched Matrix Multiply",
                MlpOp::Linear => "Linear Layer",
            },
            op.feature_count(),
            paper_size(op),
            ours
        );
        w.row(&[
            op.id().to_string(),
            op.feature_count().to_string(),
            paper_size(op).to_string(),
            ours.to_string(),
        ])?;
    }
    w.finish()?;
    if !std::path::Path::new("data/conv2d.csv").exists() {
        println!("(run `make dataset` to generate the datasets)");
    }
    Ok(())
}
