//! Additional models beyond the paper's evaluation set.
//!
//! The paper's §2.4 argument for *predictions* over benchmarks is that
//! published numbers only exist for a handful of models — a user with a
//! custom or newer DNN is on their own. These builders demonstrate the
//! claim: neither VGG-16 (older, enormous dense layers) nor BERT-base
//! (newer, encoder-only attention) is in the paper's evaluation, and both
//! work through exactly the same tracker → hybrid-predictor pipeline.

use crate::models::GraphBuilder;
use crate::opgraph::{EwKind, OptimizerKind, PoolKind};
use crate::Graph;

/// VGG-16 [Simonyan & Zisserman '15] — ImageNet 3×224×224, torchvision
/// layout (13 convs + 3 enormous FC layers; 138M parameters).
pub fn vgg16(batch_size: usize) -> Graph {
    let mut b = GraphBuilder::new("vgg16", batch_size);
    let stages: [&[usize]; 5] = [&[64, 64], &[128, 128], &[256, 256, 256], &[512, 512, 512], &[512, 512, 512]];
    let mut x = vec![batch_size, 3, 224, 224];
    for (s, widths) in stages.iter().enumerate() {
        for (i, &w) in widths.iter().enumerate() {
            x = b.conv(&format!("conv{}_{i}", s + 1), x, w, 3, 1, 1, true);
            b.ew(&format!("relu{}_{i}", s + 1), EwKind::Relu, x.clone());
        }
        x = b.pool(&format!("pool{}", s + 1), x, PoolKind::Max, 2, 2, 0);
    }
    debug_assert_eq!(&x[1..], &[512, 7, 7]);
    // Classifier: 25088 → 4096 → 4096 → 1000, with dropout.
    let mut rows = vec![batch_size, 512 * 7 * 7];
    for (i, (d_in, d_out)) in [(25088, 4096), (4096, 4096), (4096, 1000)].into_iter().enumerate() {
        rows = b.linear(&format!("fc{i}"), rows, d_in, d_out, true);
        if i < 2 {
            b.ew(&format!("fc{i}.relu"), EwKind::Relu, rows.clone());
            b.ew(&format!("fc{i}.dropout"), EwKind::Dropout, rows.clone());
        }
    }
    b.cross_entropy("loss", batch_size, 1000);
    b.finish(OptimizerKind::Sgd)
}

/// BERT-base [Devlin et al. '19] — 12 encoder layers, d=768, 12 heads,
/// d_ff=3072, seq len 128, 30522-token vocabulary (masked-LM head).
pub fn bert_base(batch_size: usize) -> Graph {
    const D: usize = 768;
    const FF: usize = 3072;
    const HEADS: usize = 12;
    const LAYERS: usize = 12;
    const SEQ: usize = 128;
    const VOCAB: usize = 30_522;
    let mut b = GraphBuilder::new("bert_base", batch_size);
    let rows = vec![batch_size, SEQ, D];

    b.embedding("embed.tokens", vec![batch_size, SEQ], VOCAB, D);
    b.embedding("embed.positions", vec![batch_size, SEQ], 512, D);
    b.ew("embed.add", EwKind::Add, rows.clone());
    b.layer_norm("embed.ln", rows.clone());
    b.ew("embed.dropout", EwKind::Dropout, rows.clone());

    let d_head = D / HEADS;
    for l in 0..LAYERS {
        let p = format!("enc{l}");
        // Self-attention (fused QKV projection).
        b.linear(&format!("{p}.qkv"), rows.clone(), D, 3 * D, true);
        b.bmm(&format!("{p}.scores"), batch_size * HEADS, SEQ, d_head, SEQ);
        b.ew(&format!("{p}.scale"), EwKind::Scale, vec![batch_size * HEADS, SEQ, SEQ]);
        b.softmax(&format!("{p}.softmax"), vec![batch_size * HEADS, SEQ, SEQ]);
        b.ew(&format!("{p}.attn_dropout"), EwKind::Dropout, vec![batch_size * HEADS, SEQ, SEQ]);
        b.bmm(&format!("{p}.context"), batch_size * HEADS, SEQ, SEQ, d_head);
        b.linear(&format!("{p}.out"), rows.clone(), D, D, true);
        b.ew(&format!("{p}.residual1"), EwKind::Add, rows.clone());
        b.layer_norm(&format!("{p}.ln1"), rows.clone());
        // FFN with GELU.
        b.linear(&format!("{p}.fc1"), rows.clone(), D, FF, true);
        b.ew(&format!("{p}.gelu"), EwKind::Gelu, vec![batch_size, SEQ, FF]);
        b.linear(&format!("{p}.fc2"), vec![batch_size, SEQ, FF], FF, D, true);
        b.ew(&format!("{p}.residual2"), EwKind::Add, rows.clone());
        b.layer_norm(&format!("{p}.ln2"), rows.clone());
    }

    // Masked-LM head.
    b.linear("mlm.transform", rows.clone(), D, D, true);
    b.ew("mlm.gelu", EwKind::Gelu, rows.clone());
    b.layer_norm("mlm.ln", rows);
    b.linear("mlm.decoder", vec![batch_size, SEQ, D], D, VOCAB, true);
    b.cross_entropy("loss", batch_size * SEQ, VOCAB);
    b.finish(OptimizerKind::Adam)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Device;
    use crate::opgraph::OpKind;
    use crate::predict::HybridPredictor;
    use crate::tracker::OperationTracker;

    #[test]
    fn vgg16_parameter_count_matches_reference() {
        // torchvision vgg16: 138.36M parameters.
        let p = vgg16(16).parameter_count() as f64;
        assert!((p / 138.36e6 - 1.0).abs() < 0.01, "{p}");
    }

    #[test]
    fn bert_base_parameter_count_near_reference() {
        // BERT-base: ~110M (plus our untied MLM decoder ≈ 23M more).
        let p = bert_base(16).parameter_count() as f64;
        assert!(p > 100e6 && p < 150e6, "{p}");
    }

    #[test]
    fn vgg16_conv_and_fc_structure() {
        let g = vgg16(8);
        let convs = g.ops.iter().filter(|o| matches!(o.kind, OpKind::Conv2d { .. })).count();
        let fcs = g.ops.iter().filter(|o| matches!(o.kind, OpKind::Linear { .. })).count();
        assert_eq!(convs, 13);
        assert_eq!(fcs, 3);
    }

    #[test]
    fn custom_models_flow_through_the_pipeline() {
        for graph in [vgg16(8), bert_base(8)] {
            let trace = OperationTracker::new(Device::Rtx2070).track(&graph);
            assert!(trace.run_time_ms() > 0.0);
            let pred = HybridPredictor::wave_only().predict(&trace, Device::V100);
            assert!(pred.run_time_ms() > 0.0);
            assert!(pred.run_time_ms() < trace.run_time_ms(), "{}", graph.name);
        }
    }

    #[test]
    fn by_name_includes_extras() {
        assert!(crate::models::by_name("vgg16", 8).is_some());
        assert!(crate::models::by_name("bert_base", 8).is_some());
    }
}
