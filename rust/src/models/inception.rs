//! Inception v3 [Szegedy et al., 2015] — torchvision layout, 3×299×299.
//!
//! Inception exercises the "large fan-out" graph shape the paper calls out
//! (§5.2.1): each inception module runs several parallel convolution
//! branches and concatenates their outputs.
//!
//! Simplification (documented in DESIGN.md): the factorized 1×7/7×1 and
//! 1×3/3×1 convolution pairs of modules B/C are folded into single square
//! 3×3 convolutions with matching channel counts. Habitat's conv2d feature
//! space — like the paper's §4.3.1 sampler — covers square kernels only,
//! and the folded form preserves branch structure and ≈FLOP balance.

use crate::models::GraphBuilder;
use crate::opgraph::{OptimizerKind, PoolKind};
use crate::Graph;

/// Inception-A: 1×1 / 5×5 / double-3×3 / pool-proj branches.
fn inception_a(b: &mut GraphBuilder, name: &str, input: Vec<usize>, pool_ch: usize) -> Vec<usize> {
    let (n, _, h, w) = (input[0], input[1], input[2], input[3]);
    b.conv_bn_relu(&format!("{name}.b1x1"), input.clone(), 64, 1, 1, 0);
    let x = b.conv_bn_relu(&format!("{name}.b5x5_1"), input.clone(), 48, 1, 1, 0);
    b.conv_bn_relu(&format!("{name}.b5x5_2"), x, 64, 5, 1, 2);
    let x = b.conv_bn_relu(&format!("{name}.b3x3dbl_1"), input.clone(), 64, 1, 1, 0);
    let x = b.conv_bn_relu(&format!("{name}.b3x3dbl_2"), x, 96, 3, 1, 1);
    b.conv_bn_relu(&format!("{name}.b3x3dbl_3"), x, 96, 3, 1, 1);
    let p = b.pool(&format!("{name}.pool"), input, PoolKind::Avg, 3, 1, 1);
    b.conv_bn_relu(&format!("{name}.pool_proj"), p, pool_ch, 1, 1, 0);
    let out_ch = 64 + 64 + 96 + pool_ch;
    let out = vec![n, out_ch, h, w];
    b.concat(&format!("{name}.cat"), out.clone(), 4);
    out
}

/// Reduction-A: strided 3×3 + double-3×3 + maxpool.
fn reduction_a(b: &mut GraphBuilder, name: &str, input: Vec<usize>) -> Vec<usize> {
    let n = input[0];
    let x1 = b.conv_bn_relu(&format!("{name}.b3x3"), input.clone(), 384, 3, 2, 0);
    let x = b.conv_bn_relu(&format!("{name}.b3x3dbl_1"), input.clone(), 64, 1, 1, 0);
    let x = b.conv_bn_relu(&format!("{name}.b3x3dbl_2"), x, 96, 3, 1, 1);
    b.conv_bn_relu(&format!("{name}.b3x3dbl_3"), x, 96, 3, 2, 0);
    b.pool(&format!("{name}.pool"), input, PoolKind::Max, 3, 2, 0);
    let out = vec![n, 384 + 96 + 288, x1[2], x1[3]];
    b.concat(&format!("{name}.cat"), out.clone(), 3);
    out
}

/// Inception-B (17×17 modules) with factorized convs folded to 3×3.
fn inception_b(b: &mut GraphBuilder, name: &str, input: Vec<usize>, ch7: usize) -> Vec<usize> {
    let (n, _, h, w) = (input[0], input[1], input[2], input[3]);
    b.conv_bn_relu(&format!("{name}.b1x1"), input.clone(), 192, 1, 1, 0);
    // 1×7+7×1 pair → one 3×3 (square-kernel fold).
    let x = b.conv_bn_relu(&format!("{name}.b7x7_1"), input.clone(), ch7, 1, 1, 0);
    b.conv_bn_relu(&format!("{name}.b7x7_2"), x, 192, 3, 1, 1);
    // Double 7×7 branch → two 3×3.
    let x = b.conv_bn_relu(&format!("{name}.b7x7dbl_1"), input.clone(), ch7, 1, 1, 0);
    let x = b.conv_bn_relu(&format!("{name}.b7x7dbl_2"), x, ch7, 3, 1, 1);
    b.conv_bn_relu(&format!("{name}.b7x7dbl_3"), x, 192, 3, 1, 1);
    let p = b.pool(&format!("{name}.pool"), input, PoolKind::Avg, 3, 1, 1);
    b.conv_bn_relu(&format!("{name}.pool_proj"), p, 192, 1, 1, 0);
    let out = vec![n, 768, h, w];
    b.concat(&format!("{name}.cat"), out.clone(), 4);
    out
}

/// Reduction-B.
fn reduction_b(b: &mut GraphBuilder, name: &str, input: Vec<usize>) -> Vec<usize> {
    let n = input[0];
    let x = b.conv_bn_relu(&format!("{name}.b3x3_1"), input.clone(), 192, 1, 1, 0);
    let x1 = b.conv_bn_relu(&format!("{name}.b3x3_2"), x, 320, 3, 2, 0);
    let x = b.conv_bn_relu(&format!("{name}.b7x7x3_1"), input.clone(), 192, 1, 1, 0);
    let x = b.conv_bn_relu(&format!("{name}.b7x7x3_2"), x, 192, 3, 1, 1);
    let x = b.conv_bn_relu(&format!("{name}.b7x7x3_3"), x, 192, 3, 2, 0);
    b.pool(&format!("{name}.pool"), input, PoolKind::Max, 3, 2, 0);
    let out = vec![n, 320 + 192 + 768, x1[2], x1[3]];
    debug_assert_eq!(x[2], x1[2]);
    b.concat(&format!("{name}.cat"), out.clone(), 3);
    out
}

/// Inception-C (8×8 modules) with 1×3/3×1 splits folded to 3×3.
fn inception_c(b: &mut GraphBuilder, name: &str, input: Vec<usize>) -> Vec<usize> {
    let (n, _, h, w) = (input[0], input[1], input[2], input[3]);
    b.conv_bn_relu(&format!("{name}.b1x1"), input.clone(), 320, 1, 1, 0);
    let x = b.conv_bn_relu(&format!("{name}.b3x3_1"), input.clone(), 384, 1, 1, 0);
    b.conv_bn_relu(&format!("{name}.b3x3_2"), x, 768, 3, 1, 1); // 2×384 split folded
    let x = b.conv_bn_relu(&format!("{name}.b3x3dbl_1"), input.clone(), 448, 1, 1, 0);
    let x = b.conv_bn_relu(&format!("{name}.b3x3dbl_2"), x, 384, 3, 1, 1);
    b.conv_bn_relu(&format!("{name}.b3x3dbl_3"), x, 768, 3, 1, 1); // split folded
    let p = b.pool(&format!("{name}.pool"), input, PoolKind::Avg, 3, 1, 1);
    b.conv_bn_relu(&format!("{name}.pool_proj"), p, 192, 1, 1, 0);
    let out = vec![n, 320 + 768 + 768 + 192, h, w];
    b.concat(&format!("{name}.cat"), out.clone(), 4);
    out
}

/// Build Inception v3 for a batch size (3×299×299 input).
pub fn inception3(batch_size: usize) -> Graph {
    let mut b = GraphBuilder::new("inception3", batch_size);
    // Stem.
    let x = b.conv_bn_relu("stem.1", vec![batch_size, 3, 299, 299], 32, 3, 2, 0);
    let x = b.conv_bn_relu("stem.2", x, 32, 3, 1, 0);
    let x = b.conv_bn_relu("stem.3", x, 64, 3, 1, 1);
    let x = b.pool("stem.pool1", x, PoolKind::Max, 3, 2, 0);
    let x = b.conv_bn_relu("stem.4", x, 80, 1, 1, 0);
    let x = b.conv_bn_relu("stem.5", x, 192, 3, 1, 0);
    let x = b.pool("stem.pool2", x, PoolKind::Max, 3, 2, 0);
    debug_assert_eq!(&x[1..], &[192, 35, 35]);

    // 35×35 modules.
    let x = inception_a(&mut b, "mixed5b", x, 32);
    let x = inception_a(&mut b, "mixed5c", x, 64);
    let x = inception_a(&mut b, "mixed5d", x, 64);
    let x = reduction_a(&mut b, "mixed6a", x);
    debug_assert_eq!(&x[1..], &[768, 17, 17]);

    // 17×17 modules.
    let x = inception_b(&mut b, "mixed6b", x, 128);
    let x = inception_b(&mut b, "mixed6c", x, 160);
    let x = inception_b(&mut b, "mixed6d", x, 160);
    let x = inception_b(&mut b, "mixed6e", x, 192);
    let x = reduction_b(&mut b, "mixed7a", x);
    debug_assert_eq!(&x[1..], &[1280, 8, 8]);

    // 8×8 modules.
    let x = inception_c(&mut b, "mixed7b", x);
    let x = inception_c(&mut b, "mixed7c", x);
    debug_assert_eq!(&x[1..], &[2048, 8, 8]);

    // Head.
    b.pool("avgpool", x, PoolKind::AdaptiveAvg, 1, 1, 0);
    b.linear("fc", vec![batch_size, 2048], 2048, 1000, true);
    b.cross_entropy("loss", batch_size, 1000);
    b.finish(OptimizerKind::Sgd)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opgraph::OpKind;

    #[test]
    fn builds_with_expected_fanout() {
        let g = inception3(16);
        // 11 inception/reduction modules ⇒ many concats.
        let cats = g
            .ops
            .iter()
            .filter(|o| matches!(o.kind, OpKind::Concat { .. }))
            .count();
        assert_eq!(cats, 11);
    }

    #[test]
    fn more_convs_than_resnet() {
        let inc = inception3(16);
        let res = crate::models::resnet50(16);
        let count = |g: &crate::Graph| {
            g.ops
                .iter()
                .filter(|o| matches!(o.kind, OpKind::Conv2d { .. }))
                .count()
        };
        assert!(count(&inc) > count(&res));
    }

    #[test]
    fn parameter_count_in_inceptionish_range() {
        // torchvision inception_v3: 27.2M (with aux head; ours omits the
        // aux classifier but folds factorized convs to square, which adds
        // parameters). Accept a generous band around the reference.
        let g = inception3(16);
        let p = g.parameter_count() as f64;
        assert!(p > 20e6 && p < 45e6, "{p}");
    }

    #[test]
    fn final_feature_map_is_8x8() {
        let g = inception3(4);
        let last_conv = g
            .ops
            .iter()
            .rev()
            .find(|o| matches!(o.kind, OpKind::Conv2d { .. }))
            .unwrap();
        assert_eq!(last_conv.input[2], 8);
    }
}
