//! Model zoo: the paper's five evaluation DNNs (Table 4) as operation
//! graphs, plus a small MLP used by self-tests.
//!
//! | Application      | Model        | Architecture | Input               |
//! |------------------|--------------|--------------|---------------------|
//! | Image classif.   | ResNet-50    | Convolution  | ImageNet 3×224×224  |
//! | Image classif.   | Inception v3 | Convolution  | ImageNet 3×299×299  |
//! | Machine transl.  | Transformer  | Attention    | WMT'16, seq len 50  |
//! | Machine transl.  | GNMT         | Recurrent    | WMT'16, seq len 50  |
//! | Image generation | DCGAN        | Convolution  | LSUN 3×64×64        |
//!
//! Graphs are built layer-by-layer with concrete shapes, mirroring the
//! reference implementations (torchvision ResNet/Inception, the original
//! Transformer-base, MLPerf GNMT, the PyTorch DCGAN example). Known
//! simplifications are documented per model (e.g. Inception's factorized
//! 1×7/7×1 convolutions are folded into square kernels, since Habitat's
//! conv2d feature space — like the paper's — samples square kernels only).

pub mod dcgan;
pub mod extra;
pub mod gnmt;
pub mod inception;
pub mod resnet;
pub mod transformer;

use crate::opgraph::shape::conv_out;
use crate::opgraph::{EwKind, Op, OpKind, OptimizerKind, PoolKind};
use crate::Graph;

pub use dcgan::dcgan;
pub use extra::{bert_base, vgg16};
pub use gnmt::gnmt;
pub use inception::inception3;
pub use resnet::resnet50;
pub use transformer::transformer;

/// All model names, in the paper's order.
pub const MODEL_NAMES: [&str; 5] = ["resnet50", "inception3", "transformer", "gnmt", "dcgan"];

/// Build a model by name.
pub fn by_name(name: &str, batch_size: usize) -> Option<Graph> {
    match name {
        "resnet50" => Some(resnet50(batch_size)),
        "inception3" | "inceptionv3" => Some(inception3(batch_size)),
        "transformer" => Some(transformer(batch_size)),
        "gnmt" => Some(gnmt(batch_size)),
        "dcgan" => Some(dcgan(batch_size)),
        "vgg16" => Some(vgg16(batch_size)),
        "bert_base" | "bert" => Some(bert_base(batch_size)),
        "mlp" => Some(mlp_benchmark_net(batch_size)),
        _ => None,
    }
}

/// The batch sizes evaluated per model (three each, Fig. 3).
pub fn eval_batch_sizes(name: &str) -> &'static [usize] {
    match name {
        "resnet50" | "inception3" | "gnmt" => &[16, 32, 64],
        "transformer" => &[32, 48, 64],
        "dcgan" => &[64, 96, 128],
        _ => &[16, 32, 64],
    }
}

/// Small fully-connected network — a fast workload for tests/benches.
pub fn mlp_benchmark_net(batch_size: usize) -> Graph {
    let mut b = GraphBuilder::new("mlp", batch_size);
    let dims = [1024, 1024, 1024, 256, 10];
    let mut in_dim = 784;
    for (i, out_dim) in dims.into_iter().enumerate() {
        b.linear(&format!("fc{i}"), vec![batch_size, in_dim], in_dim, out_dim, true);
        if i + 1 < dims.len() {
            b.ew(&format!("relu{i}"), EwKind::Relu, vec![batch_size, out_dim]);
        }
        in_dim = out_dim;
    }
    b.cross_entropy("loss", batch_size, 10);
    b.finish(OptimizerKind::Sgd)
}

/// Shared builder: tracks op naming and parameter totals, and appends the
/// optimizer step that closes every training iteration.
pub struct GraphBuilder {
    graph: Graph,
}

impl GraphBuilder {
    pub fn new(name: &str, batch_size: usize) -> Self {
        GraphBuilder {
            graph: Graph::new(name, batch_size),
        }
    }

    pub fn push(&mut self, op: Op) {
        self.graph.push(op);
    }

    /// Conv2d; returns the output shape.
    #[allow(clippy::too_many_arguments)]
    pub fn conv(
        &mut self,
        name: &str,
        input: Vec<usize>,
        out_ch: usize,
        kernel: usize,
        stride: usize,
        padding: usize,
        bias: bool,
    ) -> Vec<usize> {
        let in_ch = input[1];
        let oh = conv_out(input[2], kernel, stride, padding);
        let ow = conv_out(input[3], kernel, stride, padding);
        let out = vec![input[0], out_ch, oh, ow];
        self.push(Op::new(
            name,
            OpKind::Conv2d {
                in_ch,
                out_ch,
                kernel,
                stride,
                padding,
                bias,
            },
            input,
        ));
        out
    }

    /// Conv → BatchNorm → ReLU, the ubiquitous CNN building block.
    #[allow(clippy::too_many_arguments)]
    pub fn conv_bn_relu(
        &mut self,
        name: &str,
        input: Vec<usize>,
        out_ch: usize,
        kernel: usize,
        stride: usize,
        padding: usize,
    ) -> Vec<usize> {
        let out = self.conv(&format!("{name}.conv"), input, out_ch, kernel, stride, padding, false);
        self.batch_norm(&format!("{name}.bn"), out.clone());
        self.ew(&format!("{name}.relu"), EwKind::Relu, out.clone());
        out
    }

    pub fn batch_norm(&mut self, name: &str, input: Vec<usize>) {
        let channels = input[1];
        self.push(Op::new(name, OpKind::BatchNorm2d { channels }, input));
    }

    pub fn layer_norm(&mut self, name: &str, input: Vec<usize>) {
        let dim = *input.last().unwrap();
        self.push(Op::new(name, OpKind::LayerNorm { dim }, input));
    }

    pub fn ew(&mut self, name: &str, kind: EwKind, input: Vec<usize>) {
        self.push(Op::new(name, OpKind::Elementwise { kind }, input));
    }

    pub fn linear(
        &mut self,
        name: &str,
        input: Vec<usize>,
        in_features: usize,
        out_features: usize,
        bias: bool,
    ) -> Vec<usize> {
        debug_assert_eq!(*input.last().unwrap(), in_features);
        let mut out = input.clone();
        *out.last_mut().unwrap() = out_features;
        self.push(Op::new(
            name,
            OpKind::Linear {
                in_features,
                out_features,
                bias,
            },
            input,
        ));
        out
    }

    pub fn bmm(&mut self, name: &str, b: usize, l: usize, m: usize, r: usize) {
        self.push(Op::new(name, OpKind::BatchedMatmul { b, l, m, r }, vec![b, l, m]));
    }

    pub fn softmax(&mut self, name: &str, input: Vec<usize>) {
        let dim = *input.last().unwrap();
        self.push(Op::new(name, OpKind::Softmax { dim }, input));
    }

    pub fn pool(
        &mut self,
        name: &str,
        input: Vec<usize>,
        kind: PoolKind,
        kernel: usize,
        stride: usize,
        padding: usize,
    ) -> Vec<usize> {
        let out = match kind {
            PoolKind::AdaptiveAvg => vec![input[0], input[1], 1, 1],
            _ => vec![
                input[0],
                input[1],
                conv_out(input[2], kernel, stride, padding),
                conv_out(input[3], kernel, stride, padding),
            ],
        };
        self.push(Op::new(
            name,
            OpKind::Pool2d {
                kind,
                kernel,
                stride,
                padding,
            },
            input,
        ));
        out
    }

    pub fn embedding(&mut self, name: &str, indices: Vec<usize>, vocab: usize, dim: usize) {
        self.push(Op::new(name, OpKind::Embedding { vocab, dim }, indices));
    }

    pub fn concat(&mut self, name: &str, total_shape: Vec<usize>, inputs: usize) {
        self.push(Op::new(name, OpKind::Concat { inputs }, total_shape));
    }

    pub fn cross_entropy(&mut self, name: &str, rows: usize, classes: usize) {
        self.push(Op::new(name, OpKind::CrossEntropy { classes }, vec![rows, classes]));
    }

    /// Append the optimizer step over all parameters accumulated so far
    /// and return the finished graph.
    pub fn finish(mut self, kind: OptimizerKind) -> Graph {
        let params = self.graph.parameter_count();
        self.graph.push(Op::new(
            "optimizer",
            OpKind::OptimizerStep { kind, params },
            vec![1],
        ));
        self.graph
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_covers_all_models() {
        for name in MODEL_NAMES {
            let g = by_name(name, 16).unwrap_or_else(|| panic!("{name} missing"));
            assert!(!g.is_empty(), "{name} graph empty");
            assert_eq!(g.batch_size, 16);
        }
        assert!(by_name("vgg", 16).is_none());
    }

    #[test]
    fn every_model_ends_with_optimizer() {
        for name in MODEL_NAMES {
            let g = by_name(name, 16).unwrap();
            assert!(
                matches!(g.ops.last().unwrap().kind, OpKind::OptimizerStep { .. }),
                "{name} must end with the weight update"
            );
        }
    }

    #[test]
    fn every_model_has_kernel_varying_and_alike_ops() {
        for name in MODEL_NAMES {
            let g = by_name(name, 16).unwrap();
            let varying = g.kernel_varying_count();
            assert!(varying > 0, "{name} has no kernel-varying ops");
            assert!(varying < g.len(), "{name} has no kernel-alike ops");
        }
    }

    #[test]
    fn eval_batch_sizes_are_three_each() {
        for name in MODEL_NAMES {
            assert_eq!(eval_batch_sizes(name).len(), 3);
        }
    }
}
