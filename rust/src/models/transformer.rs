//! Transformer-base [Vaswani et al., NeurIPS'17] — WMT'16 EN-DE, the
//! paper's fixed sequence length of 50 (§5.1).
//!
//! d_model = 512, d_ff = 2048, 8 heads, 6 encoder + 6 decoder layers,
//! shared 32k vocabulary, Adam optimizer. Attention decomposes into the
//! kernel-varying ops Habitat models: `linear` projections and `bmm`
//! score/context products, plus kernel-alike softmax/layer-norm/add.

use crate::models::GraphBuilder;
use crate::opgraph::{EwKind, OptimizerKind};
use crate::Graph;

const D_MODEL: usize = 512;
const D_FF: usize = 2048;
const HEADS: usize = 8;
const LAYERS: usize = 6;
const VOCAB: usize = 32_000;
const SEQ: usize = 50;

/// Multi-head attention block: fused QKV projection, per-head score and
/// context bmms, output projection, residual + layer norm.
fn attention(b: &mut GraphBuilder, name: &str, batch: usize, q_len: usize, kv_len: usize) {
    let rows_q = vec![batch, q_len, D_MODEL];
    let d_head = D_MODEL / HEADS;
    // Q projection over the query sequence; K/V over the key sequence.
    b.linear(&format!("{name}.q_proj"), rows_q.clone(), D_MODEL, D_MODEL, true);
    b.linear(
        &format!("{name}.kv_proj"),
        vec![batch, kv_len, D_MODEL],
        D_MODEL,
        2 * D_MODEL,
        true,
    );
    // Scores: [b·h, q, d] × [b·h, d, kv].
    b.bmm(&format!("{name}.scores"), batch * HEADS, q_len, d_head, kv_len);
    b.ew(&format!("{name}.scale"), EwKind::Scale, vec![batch * HEADS, q_len, kv_len]);
    b.softmax(&format!("{name}.softmax"), vec![batch * HEADS, q_len, kv_len]);
    b.ew(&format!("{name}.dropout"), EwKind::Dropout, vec![batch * HEADS, q_len, kv_len]);
    // Context: [b·h, q, kv] × [b·h, kv, d].
    b.bmm(&format!("{name}.context"), batch * HEADS, q_len, kv_len, d_head);
    b.linear(&format!("{name}.out_proj"), rows_q.clone(), D_MODEL, D_MODEL, true);
    b.ew(&format!("{name}.residual"), EwKind::Add, rows_q.clone());
    b.layer_norm(&format!("{name}.ln"), rows_q);
}

/// Position-wise feed-forward block with residual + layer norm.
fn ffn(b: &mut GraphBuilder, name: &str, batch: usize, len: usize) {
    let rows = vec![batch, len, D_MODEL];
    b.linear(&format!("{name}.fc1"), rows.clone(), D_MODEL, D_FF, true);
    b.ew(&format!("{name}.relu"), EwKind::Relu, vec![batch, len, D_FF]);
    b.linear(&format!("{name}.fc2"), vec![batch, len, D_FF], D_FF, D_MODEL, true);
    b.ew(&format!("{name}.residual"), EwKind::Add, rows.clone());
    b.layer_norm(&format!("{name}.ln"), rows);
}

/// Build Transformer-base for a batch size (seq len 50 both sides).
pub fn transformer(batch_size: usize) -> Graph {
    let mut b = GraphBuilder::new("transformer", batch_size);

    // Embeddings (+ positional add, dropout) — encoder and decoder sides.
    for side in ["src", "tgt"] {
        b.embedding(&format!("{side}.embed"), vec![batch_size, SEQ], VOCAB, D_MODEL);
        b.ew(&format!("{side}.pos_add"), EwKind::Add, vec![batch_size, SEQ, D_MODEL]);
        b.ew(&format!("{side}.dropout"), EwKind::Dropout, vec![batch_size, SEQ, D_MODEL]);
    }

    for l in 0..LAYERS {
        attention(&mut b, &format!("enc{l}.self_attn"), batch_size, SEQ, SEQ);
        ffn(&mut b, &format!("enc{l}.ffn"), batch_size, SEQ);
    }
    for l in 0..LAYERS {
        attention(&mut b, &format!("dec{l}.self_attn"), batch_size, SEQ, SEQ);
        attention(&mut b, &format!("dec{l}.cross_attn"), batch_size, SEQ, SEQ);
        ffn(&mut b, &format!("dec{l}.ffn"), batch_size, SEQ);
    }

    // Generator: project to vocabulary and compute the loss.
    b.linear(
        "generator",
        vec![batch_size, SEQ, D_MODEL],
        D_MODEL,
        VOCAB,
        false,
    );
    b.cross_entropy("loss", batch_size * SEQ, VOCAB);
    b.finish(OptimizerKind::Adam)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opgraph::{MlpOp, OpKind};

    #[test]
    fn parameter_count_near_reference() {
        // Transformer-base ≈ 65M with shared embeddings; ours counts the
        // two embedding tables + generator separately (~93M total).
        let g = transformer(32);
        let p = g.parameter_count() as f64;
        assert!(p > 55e6 && p < 110e6, "{p}");
    }

    #[test]
    fn has_bmm_and_linear_kernel_varying_ops() {
        let g = transformer(32);
        let bmm = g
            .ops
            .iter()
            .filter(|o| o.kind.mlp_op() == Some(MlpOp::Bmm))
            .count();
        // 2 bmms per attention × (6 self + 6 self + 6 cross) = 36.
        assert_eq!(bmm, 36);
        let linear = g
            .ops
            .iter()
            .filter(|o| o.kind.mlp_op() == Some(MlpOp::Linear))
            .count();
        // 3 per attention ×18 + 2 per ffn ×12 + generator = 79.
        assert_eq!(linear, 79);
    }

    #[test]
    fn no_convolutions() {
        let g = transformer(32);
        assert!(!g.ops.iter().any(|o| matches!(o.kind, OpKind::Conv2d { .. })));
    }

    #[test]
    fn bmm_batch_includes_heads() {
        let g = transformer(4);
        let scores = g.ops.iter().find(|o| o.name == "enc0.self_attn.scores").unwrap();
        if let OpKind::BatchedMatmul { b, l, m, r } = scores.kind {
            assert_eq!(b, 4 * 8);
            assert_eq!((l, m, r), (50, 64, 50));
        } else {
            panic!("scores op is not a bmm");
        }
    }
}
