//! DCGAN [Radford et al., ICLR'16] — the PyTorch reference example [22],
//! LSUN 3×64×64, nz = 100, ngf = ndf = 64.
//!
//! One GAN training iteration (as the reference implementation executes
//! it) runs the discriminator on a real batch, the generator on a noise
//! batch, the discriminator on the fake batch, and updates both networks.
//! The trace therefore contains the generator ops once and the
//! discriminator ops twice — this is the "computationally lighter" model
//! of the paper's case study 2 (Fig. 7).

use crate::models::GraphBuilder;
use crate::opgraph::shape::conv_transpose_out;
use crate::opgraph::{EwKind, Op, OpKind, OptimizerKind};
use crate::Graph;

const NZ: usize = 100;
const NGF: usize = 64;
const NDF: usize = 64;

/// ConvTranspose2d helper; returns the output shape.
#[allow(clippy::too_many_arguments)]
fn conv_t(
    b: &mut GraphBuilder,
    name: &str,
    input: Vec<usize>,
    out_ch: usize,
    kernel: usize,
    stride: usize,
    padding: usize,
) -> Vec<usize> {
    let in_ch = input[1];
    let oh = conv_transpose_out(input[2], kernel, stride, padding);
    let ow = conv_transpose_out(input[3], kernel, stride, padding);
    let out = vec![input[0], out_ch, oh, ow];
    b.push(Op::new(
        name,
        OpKind::ConvTranspose2d {
            in_ch,
            out_ch,
            kernel,
            stride,
            padding,
            bias: false,
        },
        input,
    ));
    out
}

/// Generator: 100-d noise → 3×64×64 image.
fn generator(b: &mut GraphBuilder, batch: usize) {
    let mut x = vec![batch, NZ, 1, 1];
    let stages = [
        (NGF * 8, 4, 1, 0), // 1 → 4
        (NGF * 4, 4, 2, 1), // 4 → 8
        (NGF * 2, 4, 2, 1), // 8 → 16
        (NGF, 4, 2, 1),     // 16 → 32
    ];
    for (i, (ch, k, s, p)) in stages.into_iter().enumerate() {
        x = conv_t(b, &format!("g.convT{i}"), x, ch, k, s, p);
        b.batch_norm(&format!("g.bn{i}"), x.clone());
        b.ew(&format!("g.relu{i}"), EwKind::Relu, x.clone());
    }
    let x = conv_t(b, "g.convT4", x, 3, 4, 2, 1); // 32 → 64
    b.ew("g.tanh", EwKind::Tanh, x);
}

/// Discriminator: 3×64×64 image → scalar logit.
fn discriminator(b: &mut GraphBuilder, tag: &str, batch: usize) {
    let mut x = vec![batch, 3, 64, 64];
    let stages = [
        (NDF, false),     // 64 → 32
        (NDF * 2, true),  // 32 → 16
        (NDF * 4, true),  // 16 → 8
        (NDF * 8, true),  // 8 → 4
    ];
    for (i, (ch, bn)) in stages.into_iter().enumerate() {
        x = b.conv(&format!("d.{tag}.conv{i}"), x, ch, 4, 2, 1, false);
        if bn {
            b.batch_norm(&format!("d.{tag}.bn{i}"), x.clone());
        }
        b.ew(&format!("d.{tag}.lrelu{i}"), EwKind::LeakyRelu, x.clone());
    }
    let x = b.conv(&format!("d.{tag}.conv4"), x, 1, 4, 1, 0, false);
    b.ew(&format!("d.{tag}.sigmoid"), EwKind::Sigmoid, x);
}

/// Build the DCGAN training iteration for a batch size.
pub fn dcgan(batch_size: usize) -> Graph {
    let mut b = GraphBuilder::new("dcgan", batch_size);
    discriminator(&mut b, "real", batch_size);
    generator(&mut b, batch_size);
    discriminator(&mut b, "fake", batch_size);
    // BCE losses for D(real), D(fake), and the generator objective.
    for loss in ["d_real", "d_fake", "g"] {
        b.cross_entropy(&format!("loss.{loss}"), batch_size, 1);
    }
    b.finish(OptimizerKind::Adam)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opgraph::MlpOp;

    #[test]
    fn discriminator_appears_twice() {
        let g = dcgan(64);
        let convs = g
            .ops
            .iter()
            .filter(|o| matches!(o.kind, OpKind::Conv2d { .. }))
            .count();
        assert_eq!(convs, 10); // 5 conv layers × 2 passes
        let convts = g
            .ops
            .iter()
            .filter(|o| matches!(o.kind, OpKind::ConvTranspose2d { .. }))
            .count();
        assert_eq!(convts, 5);
    }

    #[test]
    fn all_conv_family_maps_to_conv2d_mlp() {
        let g = dcgan(64);
        for op in &g.ops {
            if op.kind.is_kernel_varying() {
                assert_eq!(op.kind.mlp_op(), Some(MlpOp::Conv2d));
            }
        }
    }

    #[test]
    fn generator_output_is_64x64() {
        // Walk the generator shapes: last convT input must be 32×32.
        let g = dcgan(16);
        let last = g.ops.iter().find(|o| o.name == "g.convT4").unwrap();
        assert_eq!(last.input[2], 32);
    }

    #[test]
    fn lighter_than_resnet() {
        // DCGAN at batch 64 is "computationally lighter" than ResNet-50 at
        // batch 64 (paper §5.3.2) — compare simulated V100 times.
        use crate::device::Device;
        let sim = crate::sim::Simulator::noiseless();
        let d = sim.graph_time_ms(Device::V100.spec(), &dcgan(64), crate::Precision::Fp32);
        let r = sim.graph_time_ms(Device::V100.spec(), &crate::models::resnet50(64), crate::Precision::Fp32);
        assert!(d < r, "dcgan {d} ms vs resnet {r} ms");
    }
}
