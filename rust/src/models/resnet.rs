//! ResNet-50 [He et al., CVPR'16] — torchvision layout, ImageNet input.
//!
//! Stem (7×7/2 conv, maxpool) → four stages of bottleneck blocks
//! ([3, 4, 6, 3]) → global average pool → 1000-way classifier.
//! The paper trains it with SGD (§5.1).

use crate::models::GraphBuilder;
use crate::opgraph::{EwKind, OptimizerKind, PoolKind};
use crate::Graph;

/// One bottleneck block: 1×1 reduce → 3×3 → 1×1 expand (+ projection
/// shortcut when shape changes), residual add, ReLU.
fn bottleneck(
    b: &mut GraphBuilder,
    name: &str,
    input: Vec<usize>,
    width: usize,
    stride: usize,
) -> Vec<usize> {
    let in_ch = input[1];
    let out_ch = width * 4;
    let x = b.conv_bn_relu(&format!("{name}.reduce"), input.clone(), width, 1, 1, 0);
    let x = b.conv_bn_relu(&format!("{name}.conv3x3"), x, width, 3, stride, 1);
    let out = b.conv(&format!("{name}.expand.conv"), x, out_ch, 1, 1, 0, false);
    b.batch_norm(&format!("{name}.expand.bn"), out.clone());
    if in_ch != out_ch || stride != 1 {
        let proj = b.conv(&format!("{name}.downsample.conv"), input, out_ch, 1, stride, 0, false);
        b.batch_norm(&format!("{name}.downsample.bn"), proj);
    }
    b.ew(&format!("{name}.add"), EwKind::Add, out.clone());
    b.ew(&format!("{name}.relu"), EwKind::Relu, out.clone());
    out
}

/// Build ResNet-50 for a batch size (ImageNet 3×224×224 input).
pub fn resnet50(batch_size: usize) -> Graph {
    let mut b = GraphBuilder::new("resnet50", batch_size);
    // Stem.
    let x = b.conv_bn_relu("stem", vec![batch_size, 3, 224, 224], 64, 7, 2, 3);
    let mut x = b.pool("stem.maxpool", x, PoolKind::Max, 3, 2, 1);

    // Stages: (width, blocks, first-stride).
    let stages = [(64, 3, 1), (128, 4, 2), (256, 6, 2), (512, 3, 2)];
    for (s, (width, blocks, stride)) in stages.into_iter().enumerate() {
        for block in 0..blocks {
            let st = if block == 0 { stride } else { 1 };
            x = bottleneck(&mut b, &format!("layer{}.{block}", s + 1), x, width, st);
        }
    }

    // Head.
    let x = b.pool("avgpool", x, PoolKind::AdaptiveAvg, 1, 1, 0);
    debug_assert_eq!(x, vec![batch_size, 2048, 1, 1]);
    b.linear("fc", vec![batch_size, 2048], 2048, 1000, true);
    b.cross_entropy("loss", batch_size, 1000);
    b.finish(OptimizerKind::Sgd)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opgraph::OpKind;

    #[test]
    fn parameter_count_close_to_reference() {
        // torchvision resnet50: 25.557M parameters.
        let g = resnet50(32);
        let params = g.parameter_count() as f64;
        assert!(
            (params / 25.557e6 - 1.0).abs() < 0.02,
            "got {params} params"
        );
    }

    #[test]
    fn conv_count_matches_reference() {
        // 53 convolutions in resnet50 (incl. downsample projections).
        let g = resnet50(32);
        let convs = g
            .ops
            .iter()
            .filter(|o| matches!(o.kind, OpKind::Conv2d { .. }))
            .count();
        assert_eq!(convs, 53);
    }

    #[test]
    fn spatial_pipeline_shapes() {
        // Final feature map before pooling must be 2048×7×7.
        let g = resnet50(8);
        let last_conv = g
            .ops
            .iter()
            .rev()
            .find(|o| matches!(o.kind, OpKind::Conv2d { .. }))
            .unwrap();
        assert_eq!(last_conv.input[2], 7);
    }

    #[test]
    fn batch_size_threads_through() {
        for bs in [1, 16, 64] {
            let g = resnet50(bs);
            assert!(g.ops.iter().all(|o| matches!(o.kind, OpKind::OptimizerStep { .. })
                || o.input[0] == bs
                || o.input.len() < 2));
        }
    }
}
