//! GNMT [Wu et al., 2016] — the MLPerf v0.x 4-layer variant used by the
//! paper's evaluation suite, WMT'16 EN-DE, sequence length 50 (§5.1).
//!
//! Encoder: 4 LSTM layers (first bidirectional), hidden 1024, residual
//! connections between upper layers. Decoder: 4 LSTM layers with
//! Bahdanau-style attention over encoder states (linear + bmm + softmax +
//! bmm + concat). 32k vocabulary, Adam optimizer. The LSTM layers are the
//! paper's canonical recurrent kernel-varying ops.

use crate::models::GraphBuilder;
use crate::opgraph::{EwKind, Op, OpKind, OptimizerKind};
use crate::Graph;

const HIDDEN: usize = 1024;
const VOCAB: usize = 32_000;
const SEQ: usize = 50;
const LAYERS: usize = 4;

/// One cuDNN-style LSTM op over the full sequence.
fn lstm(b: &mut GraphBuilder, name: &str, batch: usize, input: usize, bidirectional: bool) {
    b.push(Op::new(
        name,
        OpKind::Lstm {
            input,
            hidden: HIDDEN,
            layers: 1,
            seq: SEQ,
            bidirectional,
            bias: true,
        },
        vec![SEQ, batch, input],
    ));
}

/// Build GNMT for a batch size.
pub fn gnmt(batch_size: usize) -> Graph {
    let mut b = GraphBuilder::new("gnmt", batch_size);
    let seq_rows = vec![SEQ, batch_size, HIDDEN];

    // --- Encoder ---------------------------------------------------------
    b.embedding("enc.embed", vec![batch_size, SEQ], VOCAB, HIDDEN);
    b.ew("enc.dropout", EwKind::Dropout, seq_rows.clone());
    lstm(&mut b, "enc.lstm0", batch_size, HIDDEN, true);
    // Bidirectional output is 2×hidden; layer 1 consumes it.
    lstm(&mut b, "enc.lstm1", batch_size, 2 * HIDDEN, false);
    for l in 2..LAYERS {
        lstm(&mut b, &format!("enc.lstm{l}"), batch_size, HIDDEN, false);
        b.ew(&format!("enc.residual{l}"), EwKind::Add, seq_rows.clone());
    }

    // --- Decoder ---------------------------------------------------------
    b.embedding("dec.embed", vec![batch_size, SEQ], VOCAB, HIDDEN);
    b.ew("dec.dropout", EwKind::Dropout, seq_rows.clone());
    lstm(&mut b, "dec.lstm0", batch_size, HIDDEN, false);

    // Bahdanau attention over encoder states, batched across decoder steps:
    // score = vᵀ·tanh(W_q·q + W_k·k); context = attn·enc_out.
    b.linear("attn.q_proj", vec![batch_size, SEQ, HIDDEN], HIDDEN, HIDDEN, false);
    b.linear("attn.k_proj", vec![batch_size, SEQ, HIDDEN], HIDDEN, HIDDEN, false);
    b.ew("attn.tanh", EwKind::Tanh, vec![batch_size, SEQ, HIDDEN]);
    b.bmm("attn.scores", batch_size, SEQ, HIDDEN, SEQ);
    b.softmax("attn.softmax", vec![batch_size, SEQ, SEQ]);
    b.bmm("attn.context", batch_size, SEQ, SEQ, HIDDEN);
    // Decoder layers 1..4 consume [hidden ; context].
    b.concat("attn.cat", vec![SEQ, batch_size, 2 * HIDDEN], 2);
    for l in 1..LAYERS {
        lstm(&mut b, &format!("dec.lstm{l}"), batch_size, 2 * HIDDEN, false);
        if l >= 2 {
            b.ew(&format!("dec.residual{l}"), EwKind::Add, seq_rows.clone());
        }
    }

    // Classifier + loss.
    b.linear(
        "classifier",
        vec![batch_size, SEQ, HIDDEN],
        HIDDEN,
        VOCAB,
        true,
    );
    b.cross_entropy("loss", batch_size * SEQ, VOCAB);
    b.finish(OptimizerKind::Adam)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opgraph::MlpOp;

    #[test]
    fn has_eight_lstm_layers() {
        let g = gnmt(32);
        let lstms = g
            .ops
            .iter()
            .filter(|o| o.kind.mlp_op() == Some(MlpOp::Lstm))
            .count();
        assert_eq!(lstms, 8); // 4 encoder + 4 decoder
    }

    #[test]
    fn parameter_count_in_gnmt_range() {
        // MLPerf GNMT-4: ~160M parameters (embeddings dominate).
        let g = gnmt(32);
        let p = g.parameter_count() as f64;
        assert!(p > 120e6 && p < 220e6, "{p}");
    }

    #[test]
    fn recurrent_time_dominated_by_lstms() {
        use crate::device::Device;
        let trace = crate::OperationTracker::new(Device::P4000).track(&gnmt(16));
        let lstm_ms: f64 = trace
            .ops
            .iter()
            .filter(|o| o.op.kind.mlp_op() == Some(MlpOp::Lstm))
            .map(|o| o.total_ms())
            .sum();
        assert!(lstm_ms / trace.run_time_ms() > 0.3);
    }

    #[test]
    fn bidirectional_first_encoder_layer() {
        let g = gnmt(8);
        let first = g.ops.iter().find(|o| o.name == "enc.lstm0").unwrap();
        assert!(matches!(
            first.kind,
            OpKind::Lstm {
                bidirectional: true,
                ..
            }
        ));
    }
}
