//! The timing model itself.

use crate::device::GpuSpec;
use crate::engine::memo::WaveTable;
use crate::lowering::{Kernel, Precision};
use crate::util::rng::{hash01, hash_str};

/// Simulator tuning knobs. Defaults are calibrated so absolute iteration
/// times land in the paper's observed ranges (tens to hundreds of ms).
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Kernel launch + driver overhead added to every kernel, ms.
    pub launch_overhead_ms: f64,
    /// Relative amplitude of deterministic measurement jitter (±).
    pub noise: f64,
    /// Seed mixed into the jitter hash, so independent "measurement runs"
    /// can observe different noise.
    pub salt: u64,
    /// Fraction of the non-critical resource's time that cannot be hidden
    /// behind the critical one (imperfect compute/memory overlap).
    pub overlap_penalty: f64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            launch_overhead_ms: 0.0045,
            noise: 0.03,
            salt: 0,
            overlap_penalty: 0.2,
        }
    }
}

/// The ground-truth GPU timing simulator.
#[derive(Debug, Clone, Default)]
pub struct Simulator {
    pub config: SimConfig,
}

impl Simulator {
    pub fn new(config: SimConfig) -> Self {
        Simulator { config }
    }

    /// Simulator with jitter disabled (for calibration and property tests).
    pub fn noiseless() -> Self {
        Simulator::new(SimConfig {
            noise: 0.0,
            ..SimConfig::default()
        })
    }

    /// Sustained compute efficiency (fraction of peak) for a kernel class
    /// on an architecture. GEMM-class kernels come close to peak; the
    /// remainder (pointwise, reductions) never do, but they are memory
    /// bound so the compute leg rarely matters.
    fn compute_efficiency(spec: &GpuSpec, k: &Kernel) -> f64 {
        use crate::device::Arch::*;
        if k.tensor_core_eligible {
            match spec.arch {
                Pascal => 0.62,
                Volta => 0.70,
                Turing => 0.66,
            }
        } else {
            0.35
        }
    }

    /// Peak FLOP/s available to this kernel under the given precision.
    fn peak_flops(spec: &GpuSpec, k: &Kernel, precision: Precision) -> f64 {
        match precision {
            Precision::Fp32 => spec.peak_flops(),
            Precision::Amp => {
                if k.tensor_core_eligible {
                    spec.peak_fp16_tflops * 1e12
                } else {
                    spec.peak_flops()
                }
            }
        }
    }

    /// Execution time of one kernel on one GPU, in milliseconds. Wave
    /// size and occupancy come from the memo table shared with wave
    /// scaling ([`WaveTable`]).
    pub fn kernel_time_ms(&self, spec: &GpuSpec, k: &Kernel, precision: Precision) -> f64 {
        let occ_table = WaveTable::global();
        let wave = occ_table.wave_size(spec, &k.launch).max(1) as f64;
        let blocks = k.launch.grid_blocks.max(1) as f64;

        // Chip fill: a grid smaller than one wave leaves SMs idle.
        let fill = (blocks / wave).min(1.0);

        // Compute leg.
        let eff_c = Self::compute_efficiency(spec, k);
        let peak = Self::peak_flops(spec, k, precision);
        let compute_ms = k.flops / (peak * eff_c * fill) * 1e3;

        // Memory leg: achieved bandwidth derated by occupancy-driven
        // memory-level parallelism, and by chip fill.
        let occ = occ_table.occupancy_fraction(spec, &k.launch);
        let mlp_factor = 0.55 + 0.45 * occ;
        let fill_mem = 0.3 + 0.7 * fill;
        let mem_ms = k.dram_bytes / (spec.achieved_bw_bytes() * mlp_factor * fill_mem) * 1e3;

        // Imperfect overlap of the two legs.
        let (hi, lo) = if compute_ms >= mem_ms {
            (compute_ms, mem_ms)
        } else {
            (mem_ms, compute_ms)
        };
        let mut time = hi + self.config.overlap_penalty * lo;

        // Tail-wave quantization: the last wave runs as long as a full one.
        if blocks > wave {
            let waves = (blocks / wave).ceil();
            time *= waves * wave / blocks;
        }

        time += self.config.launch_overhead_ms;

        // Deterministic measurement jitter.
        if self.config.noise > 0.0 {
            let u = hash01(&[
                hash_str(&k.name),
                hash_str(spec.name),
                k.launch.grid_blocks,
                k.flops.to_bits(),
                self.config.salt,
            ]);
            time *= 1.0 + self.config.noise * (2.0 * u - 1.0);
        }
        time
    }

    /// Total time of a kernel sequence (one CUDA stream: times add).
    pub fn kernels_time_ms(&self, spec: &GpuSpec, kernels: &[Kernel], precision: Precision) -> f64 {
        kernels
            .iter()
            .map(|k| self.kernel_time_ms(spec, k, precision))
            .sum()
    }

    /// Simulate a full training-iteration graph on a device: the ground
    /// truth the paper obtains by running PyTorch on the destination GPU.
    pub fn graph_time_ms(
        &self,
        spec: &GpuSpec,
        graph: &crate::Graph,
        precision: Precision,
    ) -> f64 {
        crate::lowering::lower_graph(graph, spec.arch, precision)
            .iter()
            .map(|(_, _, ks)| self.kernels_time_ms(spec, ks, precision))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{Device, LaunchConfig};
    use crate::lowering::elementwise::ew_kernel;
    use crate::lowering::gemm::gemm_kernel;
    use crate::Arch;

    fn sim() -> Simulator {
        Simulator::noiseless()
    }

    #[test]
    fn bigger_kernel_takes_longer() {
        let s = sim();
        let v100 = Device::V100.spec();
        let small = ew_kernel("relu", 1 << 16, 1.0, 2.0, Precision::Fp32);
        let large = ew_kernel("relu", 1 << 24, 1.0, 2.0, Precision::Fp32);
        assert!(
            s.kernel_time_ms(v100, &large, Precision::Fp32)
                > s.kernel_time_ms(v100, &small, Precision::Fp32)
        );
    }

    #[test]
    fn memory_bound_kernel_tracks_bandwidth_ratio() {
        // A large memory-bound kernel should scale ≈ with achieved BW.
        let s = sim();
        let k = ew_kernel("add", 1 << 26, 2.0, 3.0, Precision::Fp32);
        let t_v100 = s.kernel_time_ms(Device::V100.spec(), &k, Precision::Fp32);
        let t_t4 = s.kernel_time_ms(Device::T4.spec(), &k, Precision::Fp32);
        let ratio = t_t4 / t_v100;
        let bw_ratio = Device::V100.spec().achieved_mem_bw_gbps / Device::T4.spec().achieved_mem_bw_gbps;
        assert!((ratio / bw_ratio - 1.0).abs() < 0.35, "ratio={ratio}, bw={bw_ratio}");
    }

    #[test]
    fn compute_bound_gemm_tracks_flops_ratio_loosely() {
        let s = sim();
        let k = gemm_kernel("big", 1, 4096, 4096, 4096, Arch::Volta, Precision::Fp32, 6144);
        let t_v100 = s.kernel_time_ms(Device::V100.spec(), &k, Precision::Fp32);
        let k_p = gemm_kernel("big", 1, 4096, 4096, 4096, Arch::Pascal, Precision::Fp32, 4096);
        let t_p4000 = s.kernel_time_ms(Device::P4000.spec(), &k_p, Precision::Fp32);
        let ratio = t_p4000 / t_v100;
        let flops_ratio = 15.7 / 5.3;
        assert!(ratio > 0.5 * flops_ratio && ratio < 2.0 * flops_ratio, "ratio={ratio}");
    }

    #[test]
    fn launch_overhead_floors_tiny_kernels() {
        let s = sim();
        let k = ew_kernel("tiny", 32, 1.0, 2.0, Precision::Fp32);
        let t = s.kernel_time_ms(Device::V100.spec(), &k, Precision::Fp32);
        assert!(t >= s.config.launch_overhead_ms);
        assert!(t < 3.0 * s.config.launch_overhead_ms);
    }

    #[test]
    fn tail_wave_quantization_monotone_grid() {
        // Time must be monotonically nondecreasing in grid size for fixed
        // per-block work... (here: fixed total work split over more blocks
        // is not required monotone; instead check tail effect directly).
        let s = sim();
        let v100 = Device::V100.spec();
        let mk = |blocks: u64| Kernel {
            name: "t".into(),
            launch: LaunchConfig::new(blocks, 256, 32, 0),
            flops: 1e9,
            dram_bytes: 1e8,
            tensor_core_eligible: false,
        };
        // 8 blocks/SM × 80 SMs = 640-wide wave: 641 blocks ⇒ 2 waves.
        let exact = s.kernel_time_ms(v100, &mk(640), Precision::Fp32);
        let spill = s.kernel_time_ms(v100, &mk(641), Precision::Fp32);
        assert!(spill > exact * 1.5, "tail wave must hurt: {exact} vs {spill}");
    }

    #[test]
    fn amp_speeds_up_gemm_on_tensor_core_archs_only() {
        let s = sim();
        let k = gemm_kernel("g", 1, 2048, 2048, 2048, Arch::Volta, Precision::Fp32, 6144);
        let v100 = Device::V100.spec();
        let fp32 = s.kernel_time_ms(v100, &k, Precision::Fp32);
        let amp = s.kernel_time_ms(v100, &k, Precision::Amp);
        assert!(amp < fp32 * 0.5, "tensor cores should win big: {fp32} vs {amp}");

        let kp = gemm_kernel("g", 1, 2048, 2048, 2048, Arch::Pascal, Precision::Fp32, 4096);
        let p4000 = Device::P4000.spec();
        let fp32_p = s.kernel_time_ms(p4000, &kp, Precision::Fp32);
        let amp_p = s.kernel_time_ms(p4000, &kp, Precision::Amp);
        // P4000 has no fast FP16 path: only memory traffic shrinks.
        assert!(amp_p > 0.5 * fp32_p);
    }

    #[test]
    fn jitter_is_bounded_and_deterministic() {
        let noisy = Simulator::default();
        let clean = Simulator::noiseless();
        let k = ew_kernel("relu", 1 << 20, 1.0, 2.0, Precision::Fp32);
        let v100 = Device::V100.spec();
        let a = noisy.kernel_time_ms(v100, &k, Precision::Fp32);
        let b = noisy.kernel_time_ms(v100, &k, Precision::Fp32);
        let c = clean.kernel_time_ms(v100, &k, Precision::Fp32);
        assert_eq!(a, b, "same salt ⇒ same measurement");
        assert!((a / c - 1.0).abs() <= noisy.config.noise + 1e-9);
    }

    #[test]
    fn different_salt_changes_measurement() {
        let s1 = Simulator::new(SimConfig { salt: 1, ..SimConfig::default() });
        let s2 = Simulator::new(SimConfig { salt: 2, ..SimConfig::default() });
        let k = ew_kernel("relu", 1 << 20, 1.0, 2.0, Precision::Fp32);
        let v100 = Device::V100.spec();
        assert_ne!(
            s1.kernel_time_ms(v100, &k, Precision::Fp32),
            s2.kernel_time_ms(v100, &k, Precision::Fp32)
        );
    }

    #[test]
    fn underfilled_chip_slower_than_filled_per_unit_work() {
        let s = sim();
        let v100 = Device::V100.spec();
        // Same total FLOPs/bytes, one wave vs a tiny grid.
        let filled = Kernel {
            name: "f".into(),
            launch: LaunchConfig::new(640, 256, 32, 0),
            flops: 1e10,
            dram_bytes: 1e8,
            tensor_core_eligible: true,
        };
        let tiny = Kernel {
            launch: LaunchConfig::new(8, 256, 32, 0),
            ..filled.clone()
        };
        assert!(
            s.kernel_time_ms(v100, &tiny, Precision::Fp32)
                > 2.0 * s.kernel_time_ms(v100, &filled, Precision::Fp32)
        );
    }
}
