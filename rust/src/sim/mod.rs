//! Kernel-granularity GPU timing simulator — the ground-truth substrate.
//!
//! The paper measures kernels on six physical GPUs; this module stands in
//! for those GPUs (DESIGN.md §1). Given a lowered [`Kernel`] and a
//! [`GpuSpec`], it produces an execution time from a calibrated
//! wave/roofline model that is *deliberately richer* than the predictor's
//! own model:
//!
//! * per-architecture compute/memory efficiency curves,
//! * occupancy-dependent memory-level parallelism,
//! * chip under-fill for small grids and tail-wave quantization for
//!   large ones,
//! * fixed kernel launch overhead,
//! * imperfect compute/memory overlap (not a pure roofline `max`),
//! * tensor-core speedups under mixed precision,
//! * deterministic per-kernel measurement jitter.
//!
//! Because the simulator models effects wave scaling cannot see (and the
//! lowering already made kernel-varying ops use different algorithms per
//! architecture), Habitat's predictions against this ground truth carry
//! realistic errors instead of being trivially exact.

pub mod engine;

pub use crate::lowering::Precision;
pub use engine::{SimConfig, Simulator};
