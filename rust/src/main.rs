//! Habitat CLI — the Layer-3 entrypoint.
//!
//! ```text
//! habitat predict   [--model M | --trace FILE] [--batch N] [--origin D]
//!                   [--dest D] [--artifacts DIR] [--wave-only] [--amp]
//! habitat track     [--model M] [--batch N] [--origin D] --out FILE
//! habitat compare   [--model M | --models M,M] [--batch N] [--origin D]
//!                   [--dp WORLD]
//! habitat dataset   [--out DIR] [--configs N] [--seed S]
//! habitat experiment <id|all> [--out DIR] [--artifacts DIR]
//! habitat cluster   [--model M] [--batch N] [--origin D] [--dest D]
//!                   [--topologies T,T] [--worlds N,N] [--rank] [--dests D,D]
//!                   [--overlap F] [--bucket-mib F]
//! habitat workload  [--model M] [--batch N] [--origin D] [--dest D]
//!                   [--topology T] [--world N] [--out FILE]
//! habitat serve     [--addr HOST:PORT] [--artifacts DIR] [--max-conns N]
//!                   [--workers N] [--queue-depth N] [--store DIR]
//!                   [--http-port PORT]
//! habitat devices
//! ```
//!
//! (Flag parsing is hand-rolled: the build environment is offline and has
//! no clap; see Cargo.toml.)

use habitat::device::{registry, Device};
use habitat::engine::PredictionEngine;
use habitat::{models, OperationTracker, Precision};

/// Tiny flag parser: `--key value` pairs plus boolean `--key` switches.
struct Args {
    flags: std::collections::HashMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    fn parse(argv: &[String], switches: &[&str]) -> anyhow::Result<Args> {
        let mut flags = std::collections::HashMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if switches.contains(&key) {
                    flags.insert(key.to_string(), "true".to_string());
                } else {
                    let value = argv
                        .get(i + 1)
                        .ok_or_else(|| anyhow::anyhow!("flag --{key} needs a value"))?;
                    flags.insert(key.to_string(), value.clone());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Args { flags, positional })
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow::anyhow!("--{key}: {e}")),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn parse_device(s: &str) -> anyhow::Result<Device> {
    Device::parse(s).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown device {s:?}; expected one of {}",
            registry::device_names()
                .iter()
                .map(|n| n.to_ascii_lowercase())
                .collect::<Vec<_>>()
                .join(", ")
        )
    })
}

const USAGE: &str = "usage: habitat <predict|track|compare|cluster|workload|dataset|experiment|serve|devices> [flags]
  predict    [--model M | --trace FILE] --batch N --origin DEV --dest DEV
             [--artifacts DIR] [--wave-only] [--amp]
  track      --model M --batch N --origin DEV --out FILE   (save a trace)
  compare    --model M | --models M,M --batch N --origin DEV [--dp WORLD]
             [--wave-only]   (--models ranks all of them in one sweep)
  cluster    --model M --batch N --origin DEV --dest DEV [--topologies T,T]
             [--worlds N,N] [--rank] [--dests D,D] [--overlap F]
             [--bucket-mib F] [--wave-only] [--amp]
  workload   --model M --batch N --origin DEV --dest DEV --topology T
             --world N [--out FILE] [--bucket-mib F] [--wave-only] [--amp]
  dataset    [--out DIR] [--configs N] [--seed S]
  experiment <fig1|fig3|fig4|table1|contribution|fig6|fig7|amp|extrapolate|ablation|dp|scheduler|all>
             [--out DIR] [--artifacts DIR]
  serve      [--addr HOST:PORT] [--artifacts DIR] [--max-conns N]
             [--workers N] [--queue-depth N] [--store DIR]
             [--http-port PORT]   (HTTP front end: POST /v2, /healthz, /metrics)
  devices";

fn parse_topologies(arg: &str) -> anyhow::Result<Vec<habitat::comm::Topology>> {
    arg.split(',')
        .filter(|s| !s.is_empty())
        .map(|name| {
            habitat::comm::topology::find_topology(name).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown topology {name:?}; expected one of {}",
                    habitat::comm::topology::topology_names().join(", ")
                )
            })
        })
        .collect()
}

fn parse_worlds(arg: &str) -> anyhow::Result<Vec<usize>> {
    arg.split(',')
        .filter(|s| !s.is_empty())
        .map(|s| s.parse::<usize>().map_err(|e| anyhow::anyhow!("--worlds: {s:?}: {e}")))
        .collect()
}

fn cluster_params(args: &Args) -> anyhow::Result<habitat::comm::ClusterParams> {
    let mut params = habitat::comm::ClusterParams::default();
    if let Some(v) = args.flags.get("overlap") {
        let o = v.parse::<f64>().map_err(|e| anyhow::anyhow!("--overlap: {e}"))?;
        anyhow::ensure!((0.0..=1.0).contains(&o), "--overlap must be in 0..=1");
        params.overlap = o;
    }
    if let Some(v) = args.flags.get("bucket-mib") {
        let b = v.parse::<f64>().map_err(|e| anyhow::anyhow!("--bucket-mib: {e}"))?;
        anyhow::ensure!(b.is_finite() && b >= 0.0, "--bucket-mib must be non-negative");
        params.bucket_bytes = b * 1024.0 * 1024.0;
    }
    Ok(params)
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(command) = argv.first().cloned() else {
        eprintln!("{USAGE}");
        std::process::exit(2);
    };
    let rest = &argv[1..];

    match command.as_str() {
        "predict" => {
            let args = Args::parse(rest, &["wave-only", "amp"])?;
            let dest = parse_device(&args.get("dest", "v100"))?;
            let engine = if args.has("wave-only") {
                PredictionEngine::wave_only()
            } else {
                PredictionEngine::from_artifacts(&args.get("artifacts", "artifacts"))?
            };
            // Trace source: a saved trace file (compiled into a one-off
            // plan), or a zoo model tracked + analyzed through the
            // engine (memoized for the process lifetime).
            let (trace, plan): (std::sync::Arc<habitat::Trace>, std::sync::Arc<habitat::AnalyzedPlan>) =
                if args.has("trace") {
                    let trace = std::sync::Arc::new(habitat::Trace::load(args.get("trace", ""))?);
                    let plan = engine.analyze(&trace);
                    (trace, plan)
                } else {
                    let model = args.get("model", "resnet50");
                    let batch = args.get_usize("batch", 32)?;
                    let origin = parse_device(&args.get("origin", "rtx2070"))?;
                    let graph = models::by_name(&model, batch)
                        .ok_or_else(|| anyhow::anyhow!("unknown model {model:?}"))?;
                    if !habitat::opgraph::memory::fits(&graph, dest, Precision::Fp32) {
                        eprintln!(
                            "warning: {model} at batch {batch} likely exceeds {dest}'s memory ({:.1} GiB needed)",
                            habitat::opgraph::memory::estimate(&graph, Precision::Fp32).total_gib()
                        );
                    }
                    let analyzed = engine.analyzed(&model, batch, origin)?;
                    (analyzed.trace, analyzed.plan)
                };
            let precision = if args.has("amp") { Precision::Amp } else { Precision::Fp32 };
            let pred = engine.evaluate(&plan, dest, precision);
            println!(
                "{} (batch {}): measured on {} = {:.2} ms",
                trace.model,
                trace.batch_size,
                trace.origin,
                trace.run_time_ms()
            );
            println!(
                "Pred. iter. exec. time on {dest}: {:.2} ms  ({:.1} samples/s){}",
                pred.run_time_ms(),
                pred.throughput(),
                if pred.mlp_fallbacks > 0 {
                    format!("  [{} MLP fallbacks]", pred.mlp_fallbacks)
                } else {
                    String::new()
                }
            );
        }
        "track" => {
            let args = Args::parse(rest, &[])?;
            let model = args.get("model", "resnet50");
            let batch = args.get_usize("batch", 32)?;
            let origin = parse_device(&args.get("origin", "rtx2070"))?;
            let out = args.get("out", "trace.json");
            let graph = models::by_name(&model, batch)
                .ok_or_else(|| anyhow::anyhow!("unknown model {model:?}"))?;
            let trace = OperationTracker::new(origin).track(&graph);
            trace.save(&out)?;
            println!(
                "tracked {model} (batch {batch}) on {origin}: {:.2} ms/iter, {} ops → {out}",
                trace.run_time_ms(),
                trace.ops.len()
            );
        }
        "compare" => {
            let args = Args::parse(rest, &["wave-only"])?;
            let model = args.get("model", "resnet50");
            let batch = args.get_usize("batch", 32)?;
            let origin = parse_device(&args.get("origin", "rtx2070"))?;
            let graph = models::by_name(&model, batch)
                .ok_or_else(|| anyhow::anyhow!("unknown model {model:?}"))?;
            let engine = if args.has("wave-only") {
                PredictionEngine::wave_only()
            } else {
                PredictionEngine::from_artifacts(&args.get("artifacts", "artifacts"))
                    .unwrap_or_else(|e| {
                        eprintln!("(wave scaling only: {e})");
                        PredictionEngine::wave_only()
                    })
            };
            // Multi-model compare: every model is ranked over the whole
            // registry in ONE work-claimed multi-trace sweep
            // (`engine.rank_many`) instead of one fan-out per model.
            if let Some(list) = args.flags.get("models") {
                anyhow::ensure!(!args.has("dp"), "--models and --dp cannot be combined");
                let items: Vec<habitat::engine::RankManyItem> = list
                    .split(',')
                    .filter(|s| !s.is_empty())
                    .map(|m| habitat::engine::RankManyItem {
                        model: m.to_string(),
                        batch,
                        origin,
                    })
                    .collect();
                anyhow::ensure!(!items.is_empty(), "--models must name at least one model");
                let rankings =
                    engine.rank_many(&items, &registry::all_devices(), Precision::Fp32)?;
                for (item, ranking) in items.iter().zip(&rankings) {
                    println!(
                        "{} (batch {batch}) from {origin}, best decision first:",
                        item.model
                    );
                    println!(
                        "{:<10} {:>10} {:>12} {:>14}",
                        "GPU", "pred ms", "samples/s", "samples/s/$"
                    );
                    for entry in &ranking.entries {
                        let tput = entry.pred.throughput();
                        println!(
                            "{:<10} {:>10.2} {:>12.1} {:>14}",
                            entry.dest.id(),
                            entry.pred.run_time_ms(),
                            tput,
                            habitat::cost::cost_normalized_throughput(entry.dest, tput)
                                .map(|v| format!("{v:.1}"))
                                .unwrap_or_else(|| "-".into()),
                        );
                    }
                    println!();
                }
                return Ok(());
            }
            let world = args.get_usize("dp", 1)?;
            // One tracking pass, fanned out to every destination on the
            // engine's worker pool, ranked by cost-normalized throughput.
            // Every device in the registry, runtime registrations included.
            let ranking = engine.rank(&model, batch, origin, &registry::all_devices(), Precision::Fp32)?;
            println!(
                "{model} (batch {batch}) from {origin}{}, best decision first:",
                if world > 1 { format!(", data-parallel ×{world} (pcie3)") } else { String::new() }
            );
            println!(
                "{:<10} {:>10} {:>12} {:>14} {:>6}",
                "GPU", "pred ms", "samples/s", "samples/s/$", "fits"
            );
            // Rows carry the *displayed* metrics (data-parallel when
            // --dp N), so re-rank on those: the DP communication penalty
            // differs per device and can reorder the single-GPU ranking.
            let mut rows: Vec<(Device, f64, f64, Option<f64>)> = ranking
                .entries
                .iter()
                .map(|entry| {
                    let dest = entry.dest;
                    let (ms, tput) = if world > 1 {
                        let dp = habitat::predict::distributed::predict_data_parallel(
                            &ranking.trace,
                            &entry.pred,
                            &habitat::predict::distributed::DataParallelConfig {
                                world,
                                ..Default::default()
                            },
                        );
                        (dp.iter_ms, dp.throughput)
                    } else {
                        (entry.pred.run_time_ms(), entry.pred.throughput())
                    };
                    let cnt = habitat::cost::cost_normalized_throughput(dest, tput);
                    (dest, ms, tput, cnt)
                })
                .collect();
            rows.sort_by(|a, b| habitat::engine::rank_order((a.3, a.2), (b.3, b.2)));
            for (dest, ms, tput, cnt) in rows {
                let fits = habitat::opgraph::memory::fits(&graph, dest, Precision::Fp32);
                println!(
                    "{:<10} {:>10.2} {:>12.1} {:>14} {:>6}",
                    dest.id(),
                    ms,
                    tput,
                    cnt.map(|v| format!("{v:.1}")).unwrap_or_else(|| "-".into()),
                    if fits { "yes" } else { "NO" },
                );
            }
        }
        "cluster" => {
            let args = Args::parse(rest, &["rank", "wave-only", "amp"])?;
            let model = args.get("model", "resnet50");
            let batch = args.get_usize("batch", 32)?;
            let origin = parse_device(&args.get("origin", "rtx2070"))?;
            let precision = if args.has("amp") { Precision::Amp } else { Precision::Fp32 };
            let topologies = parse_topologies(&args.get("topologies", "dgx,cloud"))?;
            let worlds = match args.flags.get("worlds") {
                Some(v) => parse_worlds(v)?,
                None => habitat::coordinator::DEFAULT_CLUSTER_WORLDS.to_vec(),
            };
            anyhow::ensure!(!topologies.is_empty(), "--topologies must name at least one topology");
            anyhow::ensure!(!worlds.is_empty() && worlds.iter().all(|&w| w >= 1), "--worlds must be positive integers");
            let params = cluster_params(&args)?;
            let engine = if args.has("wave-only") {
                PredictionEngine::wave_only()
            } else {
                PredictionEngine::from_artifacts(&args.get("artifacts", "artifacts"))
                    .unwrap_or_else(|e| {
                        eprintln!("(wave scaling only: {e})");
                        PredictionEngine::wave_only()
                    })
            };
            if args.has("rank") {
                // Rank every (destination, topology, world) configuration
                // by cost-normalized global throughput — the cluster
                // procurement question as one kernel-major sweep.
                let dests: Vec<Device> = match args.flags.get("dests") {
                    Some(list) => list
                        .split(',')
                        .filter(|s| !s.is_empty())
                        .map(parse_device)
                        .collect::<anyhow::Result<_>>()?,
                    None => registry::all_devices(),
                };
                let ranking =
                    engine.rank_cluster(&model, batch, origin, &dests, precision, &topologies, &worlds, &params)?;
                println!(
                    "{model} (batch {batch}/replica) from {origin}, best cluster decision first:"
                );
                println!(
                    "{:<10} {:<8} {:>6} {:>10} {:>12} {:>6} {:>14}",
                    "GPU", "topology", "world", "iter ms", "samples/s", "eff", "samples/s/$"
                );
                for e in &ranking.entries {
                    println!(
                        "{:<10} {:<8} {:>6} {:>10.2} {:>12.1} {:>5.0}% {:>14}",
                        e.dest.id(),
                        e.topology.name(),
                        e.world,
                        e.pred.iter_ms,
                        e.pred.throughput,
                        e.pred.efficiency * 100.0,
                        e.cost_normalized_throughput
                            .map(|v| format!("{v:.1}"))
                            .unwrap_or_else(|| "-".into()),
                    );
                }
            } else {
                let dest = parse_device(&args.get("dest", "v100"))?;
                let report =
                    engine.predict_cluster(&model, batch, origin, dest, precision, &topologies, &worlds, &params)?;
                println!(
                    "{model} (batch {batch}/replica) from {origin} on {dest}: {:.2} ms/iter compute",
                    report.compute_ms
                );
                println!(
                    "{:<8} {:>6} {:>10} {:>10} {:>10} {:>12} {:>6}",
                    "topology", "world", "comm ms", "exposed", "iter ms", "samples/s", "eff"
                );
                for c in &report.configs {
                    println!(
                        "{:<8} {:>6} {:>10.2} {:>10.2} {:>10.2} {:>12.1} {:>5.0}%",
                        c.topology.name(),
                        c.world,
                        c.pred.comm_ms,
                        c.pred.exposed_ms,
                        c.pred.iter_ms,
                        c.pred.throughput,
                        c.pred.efficiency * 100.0,
                    );
                }
            }
        }
        "workload" => {
            let args = Args::parse(rest, &["wave-only", "amp"])?;
            let model = args.get("model", "resnet50");
            let batch = args.get_usize("batch", 32)?;
            let origin = parse_device(&args.get("origin", "rtx2070"))?;
            let dest = parse_device(&args.get("dest", "v100"))?;
            let precision = if args.has("amp") { Precision::Amp } else { Precision::Fp32 };
            let topology = parse_topologies(&args.get("topology", "dgx"))?
                .into_iter()
                .next()
                .ok_or_else(|| anyhow::anyhow!("--topology must name a topology"))?;
            let world = args.get_usize("world", 8)?;
            anyhow::ensure!(world >= 1, "--world must be positive");
            let params = cluster_params(&args)?;
            let engine = if args.has("wave-only") {
                PredictionEngine::wave_only()
            } else {
                PredictionEngine::from_artifacts(&args.get("artifacts", "artifacts"))
                    .unwrap_or_else(|e| {
                        eprintln!("(wave scaling only: {e})");
                        PredictionEngine::wave_only()
                    })
            };
            let workload =
                engine.export_workload(&model, batch, origin, dest, precision, topology, world, &params)?;
            let json = workload.to_value().dump();
            match args.flags.get("out") {
                Some(path) => {
                    std::fs::write(path, format!("{json}\n"))?;
                    println!(
                        "wrote {} comm ops ({model} ×{world} on {}) → {path}",
                        workload.comm_ops.len(),
                        topology.name()
                    );
                }
                None => println!("{json}"),
            }
        }
        "dataset" => {
            let args = Args::parse(rest, &[])?;
            habitat::dataset::generate_all(
                &args.get("out", "data"),
                args.get_usize("configs", 6000)?,
                args.get_usize("seed", 42)? as u64,
            )?;
        }
        "experiment" => {
            let args = Args::parse(rest, &[])?;
            let id = args
                .positional
                .first()
                .ok_or_else(|| anyhow::anyhow!("experiment id required\n{USAGE}"))?;
            habitat::experiments::run(id, &args.get("out", "results"), &args.get("artifacts", "artifacts"))?;
        }
        "serve" => {
            let args = Args::parse(rest, &[])?;
            // Worker/queue sizing is read by the engine at construction
            // from the environment; flags simply take precedence over
            // whatever the environment already says.
            if let Some(v) = args.flags.get("workers") {
                let n = v.parse::<usize>().map_err(|e| anyhow::anyhow!("--workers: {e}"))?;
                anyhow::ensure!(n > 0, "--workers must be positive");
                std::env::set_var(habitat::engine::WORKERS_ENV, v);
            }
            if let Some(v) = args.flags.get("queue-depth") {
                let n = v.parse::<usize>().map_err(|e| anyhow::anyhow!("--queue-depth: {e}"))?;
                anyhow::ensure!(n > 0, "--queue-depth must be positive");
                std::env::set_var(habitat::engine::pool::QUEUE_DEPTH_ENV, v);
            }
            if let Some(dir) = args.flags.get("store") {
                anyhow::ensure!(!dir.is_empty(), "--store needs a directory path");
                std::env::set_var(habitat::coordinator::service::STORE_ENV, dir);
            }
            let http_port = match args.flags.get("http-port") {
                None => None,
                Some(v) => {
                    let p = v.parse::<u16>().map_err(|e| anyhow::anyhow!("--http-port: {e}"))?;
                    anyhow::ensure!(p > 0, "--http-port must be positive (the TCP --addr already picks the JSON-lines port)");
                    Some(p)
                }
            };
            let defaults = habitat::coordinator::ServeOptions::default();
            let opts = habitat::coordinator::ServeOptions {
                max_conns: args.get_usize("max-conns", defaults.max_conns)?.max(1),
                http_port,
                ..defaults
            };
            habitat::coordinator::serve_with(
                &args.get("addr", "127.0.0.1:7780"),
                &args.get("artifacts", "artifacts"),
                opts,
            )?;
        }
        "devices" => {
            println!(
                "{:<10} {:<7} {:>4} {:>6} {:>9} {:>9} {:>7} {:>8}",
                "GPU", "Arch", "SMs", "Mem", "BW(GB/s)", "TFLOPS", "Clock", "$/hr"
            );
            for d in registry::all_devices() {
                let s = d.spec();
                println!(
                    "{:<10} {:<7} {:>4} {:>4}GB {:>9.0} {:>9.1} {:>6.0}M {:>8}",
                    s.name,
                    s.arch.to_string(),
                    s.sms,
                    s.mem_gib,
                    s.peak_mem_bw_gbps,
                    s.peak_fp32_tflops,
                    s.boost_clock_mhz,
                    s.rental_usd_per_hr
                        .map(|p| format!("{p:.2}"))
                        .unwrap_or_else(|| "-".into()),
                );
            }
        }
        other => {
            eprintln!("unknown command {other:?}\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}
