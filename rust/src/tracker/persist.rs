//! Trace (de)serialization.
//!
//! The paper's workflow separates *profiling* (done once, on whatever GPU
//! the user has — the shipped artifacts [106] are exactly such recorded
//! kernel metadata) from *prediction* (run anywhere, any number of
//! times). This module makes traces durable as JSON so the CLI can do
//! `habitat track --out trace.json` on one machine and
//! `habitat predict --trace trace.json --dest v100` on another.

use crate::device::{Device, LaunchConfig};
use crate::lowering::{Kernel, Precision};
use crate::opgraph::{Op, OpKind};
use crate::tracker::{KernelMeasurement, Trace, TrackedOp};
use crate::util::binio::{Reader, Writer};
use crate::util::json::{self, Json};
use crate::Result;

fn kernel_to_json(m: &KernelMeasurement) -> Json {
    Json::obj(vec![
        ("name", Json::Str(m.kernel.name.clone())),
        ("grid", Json::Num(m.kernel.launch.grid_blocks as f64)),
        ("threads", Json::Num(m.kernel.launch.threads_per_block as f64)),
        ("regs", Json::Num(m.kernel.launch.regs_per_thread as f64)),
        ("smem", Json::Num(m.kernel.launch.smem_per_block as f64)),
        ("flops", Json::Num(m.kernel.flops)),
        ("dram_bytes", Json::Num(m.kernel.dram_bytes)),
        ("tc", Json::Bool(m.kernel.tensor_core_eligible)),
        ("time_ms", Json::Num(m.time_ms)),
    ])
}

fn kernel_from_json(v: &Json) -> Result<KernelMeasurement> {
    let num = |k: &str| -> Result<f64> {
        v.get(k)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("kernel missing field {k:?}"))
    };
    Ok(KernelMeasurement {
        kernel: Kernel {
            name: v.req_str("name")?.to_string(),
            launch: LaunchConfig::new(
                num("grid")? as u64,
                num("threads")? as u32,
                num("regs")? as u32,
                num("smem")? as u32,
            ),
            flops: num("flops")?,
            dram_bytes: num("dram_bytes")?,
            tensor_core_eligible: matches!(v.get("tc"), Some(Json::Bool(true))),
        },
        time_ms: num("time_ms")?,
    })
}

impl Trace {
    /// Serialize the trace (including all kernel metadata) to JSON.
    pub fn to_json(&self) -> String {
        self.to_value().dump()
    }

    /// The trace as a JSON value — used both for file persistence and
    /// embedded in the wire protocol's `submit_trace` request.
    pub fn to_value(&self) -> Json {
        let ops: Vec<Json> = self
            .ops
            .iter()
            .map(|op| {
                Json::obj(vec![
                    ("index", Json::Num(op.index as f64)),
                    ("name", Json::Str(op.op.name.clone())),
                    // The op kind round-trips through its debug form plus
                    // the feature-relevant fields; prediction only needs
                    // kind-classification + features + input shape.
                    ("kind", Json::Str(serialize_kind(&op.op.kind))),
                    (
                        "input",
                        Json::Arr(op.op.input.iter().map(|d| Json::Num(*d as f64)).collect()),
                    ),
                    ("fwd", Json::Arr(op.fwd.iter().map(kernel_to_json).collect())),
                    ("bwd", Json::Arr(op.bwd.iter().map(kernel_to_json).collect())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("format", Json::Str("habitat-trace-v1".into())),
            ("model", Json::Str(self.model.clone())),
            ("batch_size", Json::Num(self.batch_size as f64)),
            ("origin", Json::Str(self.origin.id().to_string())),
            (
                "precision",
                Json::Str(match self.precision {
                    Precision::Fp32 => "fp32".into(),
                    Precision::Amp => "amp".into(),
                }),
            ),
            ("ops", Json::Arr(ops)),
        ])
    }

    /// Parse a trace serialized by [`Trace::to_json`].
    pub fn from_json(text: &str) -> Result<Trace> {
        Self::from_value(&json::parse(text)?)
    }

    /// Parse a trace from an already-parsed JSON value (e.g. embedded
    /// in a `submit_trace` request).
    pub fn from_value(v: &Json) -> Result<Trace> {
        anyhow::ensure!(
            v.req_str("format")? == "habitat-trace-v1",
            "unknown trace format"
        );
        let origin = Device::parse(v.req_str("origin")?)
            .ok_or_else(|| anyhow::anyhow!("unknown origin device in trace"))?;
        let precision = match v.req_str("precision")? {
            "fp32" => Precision::Fp32,
            "amp" => Precision::Amp,
            other => anyhow::bail!("unknown precision {other:?}"),
        };
        let mut ops = Vec::new();
        for op_v in v
            .get("ops")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing ops array"))?
        {
            let input: Vec<usize> = op_v
                .req_f64_array("input")?
                .into_iter()
                .map(|d| d as usize)
                .collect();
            let kind = parse_kind(op_v.req_str("kind")?)?;
            let parse_kernels = |key: &str| -> Result<Vec<KernelMeasurement>> {
                op_v.get(key)
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .map(kernel_from_json)
                    .collect()
            };
            ops.push(TrackedOp {
                index: op_v.req_usize("index")?,
                op: Op::new(op_v.req_str("name")?, kind, input),
                fwd: parse_kernels("fwd")?,
                bwd: parse_kernels("bwd")?,
            });
        }
        Ok(Trace {
            model: v.req_str("model")?.to_string(),
            batch_size: v.req_usize("batch_size")?,
            origin,
            precision,
            ops,
        })
    }

    /// Encode the trace into the compact binary layout used by the
    /// persistent plan store. Field-for-field equivalent to the JSON
    /// form, but `f64`s are stored as raw bit patterns so kernel
    /// timings round-trip exactly.
    pub(crate) fn encode_binary(&self, w: &mut Writer) {
        let kernel = |w: &mut Writer, m: &KernelMeasurement| {
            w.str(&m.kernel.name);
            w.u64(m.kernel.launch.grid_blocks);
            w.u32(m.kernel.launch.threads_per_block);
            w.u32(m.kernel.launch.regs_per_thread);
            w.u32(m.kernel.launch.smem_per_block);
            w.f64(m.kernel.flops);
            w.f64(m.kernel.dram_bytes);
            w.bool(m.kernel.tensor_core_eligible);
            w.f64(m.time_ms);
        };
        w.str(&self.model);
        w.u64(self.batch_size as u64);
        w.str(self.origin.id());
        w.u8(match self.precision {
            Precision::Fp32 => 0,
            Precision::Amp => 1,
        });
        w.u32(self.ops.len() as u32);
        for op in &self.ops {
            w.u64(op.index as u64);
            w.str(&op.op.name);
            w.str(&serialize_kind(&op.op.kind));
            w.u64_slice(&op.op.input.iter().map(|&d| d as u64).collect::<Vec<_>>());
            for kernels in [&op.fwd, &op.bwd] {
                w.u32(kernels.len() as u32);
                for m in kernels {
                    kernel(w, m);
                }
            }
        }
    }

    /// Decode a trace written by [`Trace::encode_binary`]. Any
    /// truncation or field corruption is an `Err`, never a panic.
    pub(crate) fn decode_binary(r: &mut Reader<'_>) -> Result<Trace> {
        let kernel = |r: &mut Reader<'_>| -> Result<KernelMeasurement> {
            Ok(KernelMeasurement {
                kernel: Kernel {
                    name: r.str()?,
                    launch: LaunchConfig::new(r.u64()?, r.u32()?, r.u32()?, r.u32()?),
                    flops: r.f64()?,
                    dram_bytes: r.f64()?,
                    tensor_core_eligible: r.bool()?,
                },
                time_ms: r.f64()?,
            })
        };
        let model = r.str()?;
        let batch_size = r.u64()? as usize;
        let origin = r.str()?;
        let origin = Device::parse(&origin)
            .ok_or_else(|| anyhow::anyhow!("unknown origin device {origin:?} in stored trace"))?;
        let precision = match r.u8()? {
            0 => Precision::Fp32,
            1 => Precision::Amp,
            b => anyhow::bail!("unknown precision byte {b}"),
        };
        let n_ops = r.u32()? as usize;
        let mut ops = Vec::with_capacity(n_ops);
        for _ in 0..n_ops {
            let index = r.u64()? as usize;
            let name = r.str()?;
            let kind = parse_kind(&r.str()?)?;
            let input: Vec<usize> = r.u64_vec()?.into_iter().map(|d| d as usize).collect();
            let mut fwd_bwd = [Vec::new(), Vec::new()];
            for kernels in &mut fwd_bwd {
                let n = r.u32()? as usize;
                for _ in 0..n {
                    kernels.push(kernel(r)?);
                }
            }
            let [fwd, bwd] = fwd_bwd;
            ops.push(TrackedOp { index, op: Op::new(&name, kind, input), fwd, bwd });
        }
        Ok(Trace { model, batch_size, origin, precision, ops })
    }

    /// Write the trace to a file.
    pub fn save<P: AsRef<std::path::Path>>(&self, path: P) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir).ok();
        }
        std::fs::write(path, self.to_json())?;
        Ok(())
    }

    /// Load a trace from a file.
    pub fn load<P: AsRef<std::path::Path>>(path: P) -> Result<Trace> {
        Trace::from_json(&std::fs::read_to_string(path)?)
    }
}

/// Compact kind encoding: `name(arg,arg,...)`.
fn serialize_kind(kind: &OpKind) -> String {
    use OpKind::*;
    match kind {
        Conv2d { in_ch, out_ch, kernel, stride, padding, bias } => {
            format!("conv2d({in_ch},{out_ch},{kernel},{stride},{padding},{})", *bias as u8)
        }
        ConvTranspose2d { in_ch, out_ch, kernel, stride, padding, bias } => {
            format!("conv_t2d({in_ch},{out_ch},{kernel},{stride},{padding},{})", *bias as u8)
        }
        Linear { in_features, out_features, bias } => {
            format!("linear({in_features},{out_features},{})", *bias as u8)
        }
        BatchedMatmul { b, l, m, r } => format!("bmm({b},{l},{m},{r})"),
        Lstm { input, hidden, layers, seq, bidirectional, bias } => format!(
            "lstm({input},{hidden},{layers},{seq},{},{})",
            *bidirectional as u8, *bias as u8
        ),
        BatchNorm2d { channels } => format!("bn2d({channels})"),
        LayerNorm { dim } => format!("ln({dim})"),
        Elementwise { kind } => format!("ew({kind:?})"),
        Pool2d { kind, kernel, stride, padding } => {
            format!("pool({kind:?},{kernel},{stride},{padding})")
        }
        Softmax { dim } => format!("softmax({dim})"),
        Embedding { vocab, dim } => format!("embedding({vocab},{dim})"),
        CrossEntropy { classes } => format!("ce({classes})"),
        Concat { inputs } => format!("cat({inputs})"),
        OptimizerStep { kind, params } => format!("opt({kind:?},{params})"),
    }
}

fn parse_kind(s: &str) -> Result<OpKind> {
    let (name, args) = s
        .split_once('(')
        .ok_or_else(|| anyhow::anyhow!("bad kind {s:?}"))?;
    let args = args.trim_end_matches(')');
    let parts: Vec<&str> = if args.is_empty() { vec![] } else { args.split(',').collect() };
    let n = |i: usize| -> Result<usize> {
        parts
            .get(i)
            .ok_or_else(|| anyhow::anyhow!("kind {s:?}: missing arg {i}"))?
            .parse::<usize>()
            .map_err(|e| anyhow::anyhow!("kind {s:?}: {e}"))
    };
    let b = |i: usize| -> Result<bool> { Ok(n(i)? != 0) };
    use crate::opgraph::{EwKind, OptimizerKind, PoolKind};
    use OpKind::*;
    Ok(match name {
        "conv2d" => Conv2d {
            in_ch: n(0)?, out_ch: n(1)?, kernel: n(2)?, stride: n(3)?, padding: n(4)?, bias: b(5)?,
        },
        "conv_t2d" => ConvTranspose2d {
            in_ch: n(0)?, out_ch: n(1)?, kernel: n(2)?, stride: n(3)?, padding: n(4)?, bias: b(5)?,
        },
        "linear" => Linear { in_features: n(0)?, out_features: n(1)?, bias: b(2)? },
        "bmm" => BatchedMatmul { b: n(0)?, l: n(1)?, m: n(2)?, r: n(3)? },
        "lstm" => Lstm {
            input: n(0)?, hidden: n(1)?, layers: n(2)?, seq: n(3)?,
            bidirectional: b(4)?, bias: b(5)?,
        },
        "bn2d" => BatchNorm2d { channels: n(0)? },
        "ln" => LayerNorm { dim: n(0)? },
        "softmax" => Softmax { dim: n(0)? },
        "embedding" => Embedding { vocab: n(0)?, dim: n(1)? },
        "ce" => CrossEntropy { classes: n(0)? },
        "cat" => Concat { inputs: n(0)? },
        "ew" => {
            let kind = match parts[0] {
                "Relu" => EwKind::Relu,
                "LeakyRelu" => EwKind::LeakyRelu,
                "Tanh" => EwKind::Tanh,
                "Sigmoid" => EwKind::Sigmoid,
                "Gelu" => EwKind::Gelu,
                "Add" => EwKind::Add,
                "Mul" => EwKind::Mul,
                "Scale" => EwKind::Scale,
                "Dropout" => EwKind::Dropout,
                "Copy" => EwKind::Copy,
                other => anyhow::bail!("unknown elementwise kind {other:?}"),
            };
            Elementwise { kind }
        }
        "pool" => {
            let kind = match parts[0] {
                "Max" => PoolKind::Max,
                "Avg" => PoolKind::Avg,
                "AdaptiveAvg" => PoolKind::AdaptiveAvg,
                other => anyhow::bail!("unknown pool kind {other:?}"),
            };
            Pool2d { kind, kernel: n(1)?, stride: n(2)?, padding: n(3)? }
        }
        "opt" => {
            let kind = match parts[0] {
                "Sgd" => OptimizerKind::Sgd,
                "Adam" => OptimizerKind::Adam,
                other => anyhow::bail!("unknown optimizer kind {other:?}"),
            };
            OptimizerStep {
                kind,
                params: parts[1]
                    .parse::<u64>()
                    .map_err(|e| anyhow::anyhow!("opt params: {e}"))?,
            }
        }
        other => anyhow::bail!("unknown op kind {other:?}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tracker::OperationTracker;

    #[test]
    fn roundtrip_preserves_everything_that_matters() {
        for model in ["resnet50", "gnmt", "transformer", "dcgan"] {
            let graph = crate::models::by_name(model, 16).unwrap();
            let trace = OperationTracker::new(Device::T4).track(&graph);
            let back = Trace::from_json(&trace.to_json()).unwrap();
            assert_eq!(back.model, trace.model);
            assert_eq!(back.batch_size, trace.batch_size);
            assert_eq!(back.origin, trace.origin);
            assert_eq!(back.ops.len(), trace.ops.len());
            assert!((back.run_time_ms() - trace.run_time_ms()).abs() < 1e-9);
            // Predictions from the deserialized trace must be identical.
            let p1 = crate::predict::HybridPredictor::wave_only().predict(&trace, Device::V100);
            let p2 = crate::predict::HybridPredictor::wave_only().predict(&back, Device::V100);
            assert!(
                (p1.run_time_ms() - p2.run_time_ms()).abs() < 1e-9,
                "{model}: {} vs {}",
                p1.run_time_ms(),
                p2.run_time_ms()
            );
            // Kind classification survives (MLP features identical).
            for (a, b) in trace.ops.iter().zip(&back.ops) {
                assert_eq!(a.op.mlp_features(), b.op.mlp_features(), "{model}/{}", a.op.name);
            }
        }
    }

    #[test]
    fn file_roundtrip() {
        let graph = crate::models::mlp_benchmark_net(8);
        let trace = OperationTracker::new(Device::P100).track(&graph);
        let path = std::env::temp_dir().join("habitat_trace_test.json");
        trace.save(&path).unwrap();
        let back = Trace::load(&path).unwrap();
        assert_eq!(back.ops.len(), trace.ops.len());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Trace::from_json("{}").is_err());
        assert!(Trace::from_json("{\"format\":\"habitat-trace-v1\"}").is_err());
        assert!(Trace::from_json("not json").is_err());
        assert!(parse_kind("frobnicate(1,2)").is_err());
        assert!(parse_kind("conv2d(1)").is_err());
    }

    #[test]
    fn binary_roundtrip_is_bit_exact() {
        for model in ["resnet50", "gnmt"] {
            let graph = crate::models::by_name(model, 16).unwrap();
            let trace = OperationTracker::new(Device::T4)
                .with_precision(Precision::Amp)
                .track(&graph);
            let mut w = Writer::new();
            trace.encode_binary(&mut w);
            let bytes = w.into_bytes();
            let back = Trace::decode_binary(&mut Reader::new(&bytes)).unwrap();
            assert_eq!(back.model, trace.model);
            assert_eq!(back.batch_size, trace.batch_size);
            assert_eq!(back.origin, trace.origin);
            assert_eq!(back.precision, trace.precision);
            assert_eq!(back.ops.len(), trace.ops.len());
            for (a, b) in trace.ops.iter().zip(&back.ops) {
                assert_eq!(a.index, b.index);
                assert_eq!(a.op.mlp_features(), b.op.mlp_features());
                for (ka, kb) in a.fwd.iter().chain(&a.bwd).zip(b.fwd.iter().chain(&b.bwd)) {
                    assert_eq!(ka.kernel.name, kb.kernel.name);
                    assert_eq!(ka.time_ms.to_bits(), kb.time_ms.to_bits());
                    assert_eq!(ka.kernel.flops.to_bits(), kb.kernel.flops.to_bits());
                }
            }
            // Truncated buffers must fail cleanly at every length.
            for cut in [0, 1, bytes.len() / 2, bytes.len() - 1] {
                assert!(Trace::decode_binary(&mut Reader::new(&bytes[..cut])).is_err());
            }
        }
    }

    #[test]
    fn amp_precision_roundtrips() {
        let graph = crate::models::mlp_benchmark_net(8);
        let trace = OperationTracker::new(Device::V100)
            .with_precision(Precision::Amp)
            .track(&graph);
        let back = Trace::from_json(&trace.to_json()).unwrap();
        assert_eq!(back.precision, Precision::Amp);
    }
}
