//! Operation tracking — the paper's Listing 1 / §4.1.
//!
//! In the paper, `habitat.OperationTracker` monkey-patches PyTorch, runs a
//! training iteration on the origin GPU, re-runs each operation in
//! isolation to time it with CUDA events, and records kernel metadata via
//! CUPTI. Here, the origin GPU is the [`crate::sim::Simulator`]: tracking
//! a [`crate::Graph`] lowers every op for the origin architecture and
//! "measures" each kernel on the simulator, producing the same trace
//! content — per-op forward/backward kernel timings plus launch configs
//! and arithmetic-intensity metrics.


pub mod persist;

use crate::device::Device;
use crate::lowering::{self, Kernel, Pass, Precision};
use crate::sim::Simulator;
use crate::Graph;

/// One timed kernel within an operation, as CUPTI would report it.
#[derive(Debug, Clone)]
pub struct KernelMeasurement {
    pub kernel: Kernel,
    /// Measured execution time on the origin GPU, ms.
    pub time_ms: f64,
}

/// One tracked operation: the op itself plus its measured kernels.
#[derive(Debug, Clone)]
pub struct TrackedOp {
    /// Index in the graph's execution order.
    pub index: usize,
    pub op: crate::Op,
    pub fwd: Vec<KernelMeasurement>,
    pub bwd: Vec<KernelMeasurement>,
}

impl TrackedOp {
    pub fn fwd_ms(&self) -> f64 {
        self.fwd.iter().map(|k| k.time_ms).sum()
    }

    pub fn bwd_ms(&self) -> f64 {
        self.bwd.iter().map(|k| k.time_ms).sum()
    }

    /// Forward + backward time (the quantity Habitat predicts per op).
    pub fn total_ms(&self) -> f64 {
        self.fwd_ms() + self.bwd_ms()
    }
}

/// The tracked trace of one training iteration on the origin GPU.
#[derive(Debug, Clone)]
pub struct Trace {
    pub model: String,
    pub batch_size: usize,
    pub origin: Device,
    pub precision: Precision,
    pub ops: Vec<TrackedOp>,
}

impl Trace {
    /// Measured iteration execution time on the origin GPU, ms.
    pub fn run_time_ms(&self) -> f64 {
        self.ops.iter().map(|o| o.total_ms()).sum()
    }

    /// Predict this iteration's execution time on a different GPU using
    /// wave scaling only (no MLP artifacts needed). For the paper's full
    /// hybrid scheme use [`crate::predict::HybridPredictor`].
    pub fn to_device(&self, dest: Device) -> crate::predict::PredictedTrace {
        crate::predict::HybridPredictor::wave_only().predict(self, dest)
    }

    /// Per-op share of iteration time — the "importance" annotation of the
    /// paper's Fig. 4, keyed by the op's short name. A zero-time trace
    /// (no ops, or all-zero measurements) reports every share as 0 rather
    /// than dividing by zero.
    pub fn op_importance(&self) -> Vec<(String, f64)> {
        let total = self.run_time_ms();
        let mut by_name: std::collections::BTreeMap<String, f64> = Default::default();
        for op in &self.ops {
            *by_name.entry(op.op.kind.short_name().to_string()).or_default() += op.total_ms();
        }
        let mut v: Vec<(String, f64)> = by_name
            .into_iter()
            .map(|(k, ms)| (k, if total > 0.0 { ms / total } else { 0.0 }))
            .collect();
        v.sort_by(|a, b| b.1.total_cmp(&a.1));
        v
    }
}

/// Records the operations of a training iteration on an origin device.
#[derive(Debug, Clone)]
pub struct OperationTracker {
    origin: Device,
    precision: Precision,
    sim: Simulator,
}

impl OperationTracker {
    /// Track on `origin` in FP32 with the default simulator.
    pub fn new(origin: Device) -> Self {
        OperationTracker {
            origin,
            precision: Precision::Fp32,
            sim: Simulator::default(),
        }
    }

    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }

    /// Replace the measurement substrate (e.g. a noiseless simulator, or a
    /// different measurement-noise salt).
    pub fn with_simulator(mut self, sim: Simulator) -> Self {
        self.sim = sim;
        self
    }

    pub fn origin(&self) -> Device {
        self.origin
    }

    /// Track **and compile**: one pass produces both the measured
    /// [`Trace`] and its destination-independent
    /// [`crate::plan::AnalyzedPlan`], sharing the
    /// [`lowering::lower_graph`] output (the kernels measured here are
    /// exactly what the plan flattens — the predictors never re-derive
    /// the lowering). `policy` is the metrics-availability policy of the
    /// predictor that will evaluate the plan (baked into the plan's γ
    /// tables).
    pub fn track_analyzed(
        &self,
        graph: &Graph,
        policy: &crate::predict::MetricsPolicy,
    ) -> crate::plan::AnalyzedTrace {
        let trace = std::sync::Arc::new(self.track(graph));
        let plan = std::sync::Arc::new(crate::plan::AnalyzedPlan::build(&trace, policy));
        crate::plan::AnalyzedTrace { trace, plan }
    }

    /// "Run" one training iteration of `graph` and record every operation.
    pub fn track(&self, graph: &Graph) -> Trace {
        let spec = self.origin.spec();
        let mut ops: Vec<TrackedOp> = graph
            .ops
            .iter()
            .enumerate()
            .map(|(index, op)| TrackedOp {
                index,
                op: op.clone(),
                fwd: Vec::new(),
                bwd: Vec::new(),
            })
            .collect();

        for (index, pass, kernels) in lowering::lower_graph(graph, spec.arch, self.precision) {
            let measured: Vec<KernelMeasurement> = kernels
                .into_iter()
                .map(|kernel| {
                    let time_ms = self.sim.kernel_time_ms(spec, &kernel, self.precision);
                    KernelMeasurement { kernel, time_ms }
                })
                .collect();
            match pass {
                Pass::Forward => ops[index].fwd = measured,
                Pass::Backward => ops[index].bwd = measured,
            }
        }

        Trace {
            model: graph.name.clone(),
            batch_size: graph.batch_size,
            origin: self.origin,
            precision: self.precision,
            ops,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opgraph::{EwKind, Op, OpKind};

    fn toy_graph() -> Graph {
        let mut g = Graph::new("toy", 8);
        g.push(Op::new(
            "fc1",
            OpKind::Linear {
                in_features: 64,
                out_features: 64,
                bias: true,
            },
            vec![8, 64],
        ));
        g.push(Op::new("act", OpKind::Elementwise { kind: EwKind::Relu }, vec![8, 64]));
        g
    }

    #[test]
    fn trace_covers_all_ops_with_both_passes() {
        let trace = OperationTracker::new(Device::V100).track(&toy_graph());
        assert_eq!(trace.ops.len(), 2);
        for op in &trace.ops {
            assert!(!op.fwd.is_empty(), "{} missing fwd", op.op.name);
            assert!(!op.bwd.is_empty(), "{} missing bwd", op.op.name);
            assert!(op.total_ms() > 0.0);
        }
    }

    #[test]
    fn run_time_is_sum_of_ops() {
        let trace = OperationTracker::new(Device::T4).track(&toy_graph());
        let sum: f64 = trace.ops.iter().map(|o| o.total_ms()).sum();
        assert!((trace.run_time_ms() - sum).abs() < 1e-12);
    }

    #[test]
    fn tracking_is_deterministic() {
        let g = toy_graph();
        let a = OperationTracker::new(Device::P100).track(&g);
        let b = OperationTracker::new(Device::P100).track(&g);
        assert_eq!(a.run_time_ms(), b.run_time_ms());
    }

    #[test]
    fn importance_sums_to_one() {
        let trace = OperationTracker::new(Device::Rtx2080Ti).track(&toy_graph());
        let total: f64 = trace.op_importance().iter().map(|(_, f)| f).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn importance_of_zero_time_trace_does_not_panic() {
        // An op with no measured kernels has zero time; a trace of such
        // ops used to produce NaN shares and a panicking sort.
        let trace = Trace {
            model: "empty".into(),
            batch_size: 1,
            origin: Device::T4,
            precision: Precision::Fp32,
            ops: vec![TrackedOp {
                index: 0,
                op: Op::new("noop", OpKind::Elementwise { kind: EwKind::Relu }, vec![1]),
                fwd: Vec::new(),
                bwd: Vec::new(),
            }],
        };
        let shares = trace.op_importance();
        assert_eq!(shares.len(), 1);
        assert_eq!(shares[0].1, 0.0);
    }

    #[test]
    fn amp_tracking_differs_from_fp32() {
        let g = toy_graph();
        let fp32 = OperationTracker::new(Device::V100).track(&g);
        let amp = OperationTracker::new(Device::V100)
            .with_precision(Precision::Amp)
            .track(&g);
        assert_ne!(fp32.run_time_ms(), amp.run_time_ms());
    }
}
