//! PJRT runtime: executes the AOT-compiled JAX MLP predictors from Rust.
//!
//! Layer-2 (`python/compile/model.py` + the Pallas kernel) is lowered once
//! at build time to HLO *text* (`make artifacts`); this module loads those
//! artifacts with the `xla` crate (`HloModuleProto::from_text_file` →
//! `XlaComputation` → `PjRtClient::compile`) and runs them on the PJRT CPU
//! client. Python is never on this path.
//!
//! PJRT executables have **static shapes**, so each op's MLP is exported
//! at several batch *buckets* (1, 8, 32, 128, 512); inference pads a
//! request to the smallest bucket that fits. The PJRT objects wrap
//! non-`Send` `Rc` handles, so [`service::MlpService`] owns them on a
//! dedicated thread and hands out a `Send + Sync` handle that implements
//! [`crate::predict::MlpBackend`] — this thread is also where cross-request
//! dynamic batching happens (see [`crate::coordinator`]).

pub mod mlp;
pub mod service;
pub(crate) mod xla_compat;

pub use mlp::{MlpModel, MlpRuntime, RuntimeMeta};
pub use service::{MlpService, MlpServiceHandle};

use std::sync::Arc;

use crate::predict::HybridPredictor;
use crate::Result;

/// Build the paper's full hybrid predictor from an artifacts directory.
/// Spawns the PJRT service thread on first use.
pub fn predictor_from_artifacts(dir: &str) -> Result<HybridPredictor> {
    let handle = MlpService::spawn(dir.to_string())?;
    Ok(HybridPredictor::with_mlp(Arc::new(handle)))
}
