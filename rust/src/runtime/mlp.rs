//! Loading and executing one MLP predictor artifact set.
//!
//! Artifact layout (produced by `python/compile/aot.py`):
//! ```text
//! artifacts/
//!   conv2d.meta.json       # buckets, feature stats, output transform
//!   conv2d_b1.hlo.txt      # HLO text, input f32[1, F] → (f32[1, 1],)
//!   conv2d_b8.hlo.txt      # ...
//!   ...
//! ```
//!
//! Inputs are transformed exactly as in training: `log1p`, then
//! standardized with the training-set mean/std from the sidecar. The MLP
//! predicts `ln(time_ms)`; [`MlpModel::predict`] exponentiates.

use std::collections::HashMap;
use std::path::Path;

// PJRT bindings: the offline build links the in-tree shim; swap in the
// real `xla` crate here to execute actual HLO artifacts.
use crate::runtime::xla_compat as xla;

use crate::dataset::gpu_features;
use crate::device::Device;
use crate::opgraph::MlpOp;
use crate::Result;

/// Sidecar metadata written next to each op's HLO artifacts.
#[derive(Debug, Clone)]
pub struct RuntimeMeta {
    pub op: String,
    /// Total input features (op features + 4 GPU features).
    pub features: usize,
    /// Exported batch buckets, ascending.
    pub buckets: Vec<usize>,
    /// Standardization statistics over log1p-transformed features.
    pub mean: Vec<f64>,
    pub std: Vec<f64>,
    /// Output transform; currently always `"log_ms"`.
    pub output: String,
}

impl RuntimeMeta {
    /// Parse the sidecar JSON.
    pub fn parse(text: &str) -> Result<Self> {
        let v = crate::util::json::parse(text)?;
        let buckets = v
            .req_f64_array("buckets")?
            .into_iter()
            .map(|b| b as usize)
            .collect();
        Ok(RuntimeMeta {
            op: v.req_str("op")?.to_string(),
            features: v.req_usize("features")?,
            buckets,
            mean: v.req_f64_array("mean")?,
            std: v.req_f64_array("std")?,
            output: v.req_str("output")?.to_string(),
        })
    }
}

/// One op family's compiled MLP: a bucket ladder of PJRT executables.
pub struct MlpModel {
    pub meta: RuntimeMeta,
    /// (bucket_size, compiled executable), ascending by bucket.
    executables: Vec<(usize, xla::PjRtLoadedExecutable)>,
}

impl MlpModel {
    /// Load and compile all buckets for `op` from `dir`.
    pub fn load(client: &xla::PjRtClient, dir: &Path, op: MlpOp) -> Result<Self> {
        let meta_path = dir.join(format!("{}.meta.json", op.id()));
        let meta = RuntimeMeta::parse(
            &std::fs::read_to_string(&meta_path)
                .map_err(|e| anyhow::anyhow!("reading {}: {e}", meta_path.display()))?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e}", meta_path.display()))?;
        anyhow::ensure!(meta.op == op.id(), "meta/op mismatch in {}", meta_path.display());
        anyhow::ensure!(meta.output == "log_ms", "unsupported output transform {}", meta.output);
        anyhow::ensure!(
            meta.mean.len() == meta.features && meta.std.len() == meta.features,
            "stats length mismatch"
        );
        let mut executables = Vec::new();
        for &bucket in &meta.buckets {
            let hlo = dir.join(format!("{}_b{bucket}.hlo.txt", op.id()));
            let proto = xla::HloModuleProto::from_text_file(&hlo)
                .map_err(|e| anyhow::anyhow!("loading {}: {e}", hlo.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            executables.push((bucket, exe));
        }
        executables.sort_by_key(|(b, _)| *b);
        anyhow::ensure!(!executables.is_empty(), "no buckets for {}", op.id());
        Ok(MlpModel { meta, executables })
    }

    /// Smallest bucket ≥ n (or the largest bucket, with chunking upstream).
    fn bucket_for(&self, n: usize) -> usize {
        self.executables
            .iter()
            .map(|(b, _)| *b)
            .find(|b| *b >= n)
            .unwrap_or_else(|| self.executables.last().unwrap().0)
    }

    fn executable(&self, bucket: usize) -> &xla::PjRtLoadedExecutable {
        &self
            .executables
            .iter()
            .find(|(b, _)| *b == bucket)
            .expect("bucket_for returned a known bucket")
            .1
    }

    /// Apply the training-time feature transform to one row.
    fn normalize(&self, row: &[f64]) -> Vec<f32> {
        row.iter()
            .enumerate()
            .map(|(i, &v)| {
                let z = (v.max(0.0).ln_1p() - self.meta.mean[i]) / self.meta.std[i].max(1e-12);
                z as f32
            })
            .collect()
    }

    /// Predict fwd+bwd times (ms) for feature rows. Rows longer than the
    /// largest bucket are processed in chunks.
    pub fn predict(&self, rows: &[Vec<f64>]) -> Result<Vec<f64>> {
        let nfeat = self.meta.features;
        let max_bucket = self.executables.last().unwrap().0;
        let mut out = Vec::with_capacity(rows.len());
        let mut start = 0;
        while start < rows.len() {
            let n = (rows.len() - start).min(max_bucket);
            let chunk = &rows[start..start + n];
            let bucket = self.bucket_for(n);
            // Flatten + pad (repeat the first row: harmless, ignored).
            let mut flat: Vec<f32> = Vec::with_capacity(bucket * nfeat);
            for row in chunk {
                anyhow::ensure!(row.len() == nfeat, "feature row has {} values, want {nfeat}", row.len());
                flat.extend(self.normalize(row));
            }
            for _ in n..bucket {
                let first = flat[..nfeat].to_vec();
                flat.extend(first);
            }
            // Single-copy literal construction (vec1+reshape would copy
            // twice; see EXPERIMENTS.md §Perf).
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(flat.as_ptr() as *const u8, flat.len() * 4)
            };
            let literal = xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                &[bucket, nfeat],
                bytes,
            )?;
            let result = self.executable(bucket).execute::<xla::Literal>(&[literal])?[0][0]
                .to_literal_sync()?;
            let values = result.to_tuple1()?.to_vec::<f32>()?;
            anyhow::ensure!(values.len() == bucket, "unexpected output length");
            out.extend(values[..n].iter().map(|v| (*v as f64).exp()));
            start += n;
        }
        Ok(out)
    }
}

/// All four op families' MLPs on one PJRT client. **Not `Send`** (PJRT
/// handles are `Rc`-based) — wrap in [`super::MlpService`] to share.
pub struct MlpRuntime {
    models: HashMap<MlpOp, MlpModel>,
}

impl MlpRuntime {
    /// Load every op family that has artifacts in `dir`. Errors if none do.
    pub fn load(dir: &str) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        let dir = Path::new(dir);
        let mut models = HashMap::new();
        let mut errors = Vec::new();
        for op in MlpOp::ALL {
            if dir.join(format!("{}.meta.json", op.id())).exists() {
                match MlpModel::load(&client, dir, op) {
                    Ok(m) => {
                        models.insert(op, m);
                    }
                    Err(e) => errors.push(format!("{op}: {e}")),
                }
            }
        }
        anyhow::ensure!(
            !models.is_empty(),
            "no MLP artifacts found in {} (run `make artifacts`){}",
            dir.display(),
            if errors.is_empty() {
                String::new()
            } else {
                format!("; load errors: {}", errors.join("; "))
            }
        );
        if !errors.is_empty() {
            eprintln!("warning: some MLP artifacts failed to load: {}", errors.join("; "));
        }
        Ok(MlpRuntime { models })
    }

    pub fn loaded_ops(&self) -> Vec<MlpOp> {
        let mut v: Vec<MlpOp> = self.models.keys().copied().collect();
        v.sort();
        v
    }

    /// Predict from full feature rows (op features + GPU features already
    /// appended). Used by the batching service, which mixes destinations.
    pub fn predict_rows(&self, op: MlpOp, rows: &[Vec<f64>]) -> Result<Vec<f64>> {
        let model = self
            .models
            .get(&op)
            .ok_or_else(|| anyhow::anyhow!("no MLP artifact loaded for {op}"))?;
        model.predict(rows)
    }

    /// Predict fwd+bwd times for op-feature rows on a destination GPU:
    /// appends the four GPU features to each row and runs the op's MLP.
    pub fn predict(&self, op: MlpOp, features: &[Vec<f64>], dest: Device) -> Result<Vec<f64>> {
        let model = self
            .models
            .get(&op)
            .ok_or_else(|| anyhow::anyhow!("no MLP artifact loaded for {op}"))?;
        let gpu = gpu_features(dest);
        let rows: Vec<Vec<f64>> = features
            .iter()
            .map(|f| {
                let mut row = f.clone();
                row.extend(gpu);
                row
            })
            .collect();
        model.predict(&rows)
    }
}
