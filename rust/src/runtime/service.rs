//! The MLP service thread: owns the (non-`Send`) PJRT runtime and batches
//! prediction requests from any number of threads/tasks.
//!
//! This is the bottom half of the coordinator's dynamic batcher: callers
//! enqueue `(op, feature rows, dest)` work items; the service thread
//! drains everything queued, groups items by op family, executes one
//! padded PJRT call per group, and scatters results back. Under
//! concurrency this coalesces many small MLP calls into few large ones —
//! the same reason serving systems batch (the MLP accounts for ~54% of
//! predicted time in the paper's §5.2.3, so it is the hot path here).

use std::sync::mpsc;
use std::sync::Arc;

use crate::device::Device;
use crate::opgraph::MlpOp;
use crate::predict::MlpBackend;
use crate::runtime::MlpRuntime;
use crate::Result;

/// One queued inference request.
struct Request {
    op: MlpOp,
    features: Vec<Vec<f64>>,
    dest: Device,
    reply: mpsc::Sender<Result<Vec<f64>>>,
}

/// Counters exported by the service thread (for benches and tests).
#[derive(Debug, Default)]
pub struct ServiceStats {
    pub requests: std::sync::atomic::AtomicU64,
    pub rows: std::sync::atomic::AtomicU64,
    pub executions: std::sync::atomic::AtomicU64,
}

/// Handle to the service thread. Cheap to clone; `Send + Sync`.
#[derive(Clone)]
pub struct MlpServiceHandle {
    tx: mpsc::Sender<Request>,
    stats: Arc<ServiceStats>,
}

/// The service itself (namespace for [`MlpService::spawn`]).
pub struct MlpService;

impl MlpService {
    /// Spawn the service thread, loading artifacts from `dir`. Returns an
    /// error if the artifacts fail to load (reported synchronously).
    pub fn spawn(dir: String) -> Result<MlpServiceHandle> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let stats = Arc::new(ServiceStats::default());
        let thread_stats = stats.clone();
        std::thread::Builder::new()
            .name("habitat-mlp".into())
            .spawn(move || {
                let runtime = match MlpRuntime::load(&dir) {
                    Ok(r) => {
                        let _ = ready_tx.send(Ok(()));
                        r
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                Self::run(runtime, rx, thread_stats);
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("MLP service thread died during startup"))??;
        Ok(MlpServiceHandle { tx, stats })
    }

    /// Service loop: block for one request, then drain the queue and batch.
    fn run(runtime: MlpRuntime, rx: mpsc::Receiver<Request>, stats: Arc<ServiceStats>) {
        use std::sync::atomic::Ordering::Relaxed;
        while let Ok(first) = rx.recv() {
            // Dynamic batching: opportunistically take everything queued.
            let mut batch = vec![first];
            while let Ok(req) = rx.try_recv() {
                batch.push(req);
            }
            stats.requests.fetch_add(batch.len() as u64, Relaxed);

            // Group by op family. Rows already carry per-dest GPU features
            // (appended below per request), so dests can share a batch.
            let mut by_op: std::collections::BTreeMap<MlpOp, Vec<usize>> = Default::default();
            for (i, req) in batch.iter().enumerate() {
                by_op.entry(req.op).or_default().push(i);
            }

            for (op, indices) in by_op {
                // Build the combined row matrix for this op family.
                let mut rows: Vec<Vec<f64>> = Vec::new();
                let mut spans: Vec<(usize, usize)> = Vec::with_capacity(indices.len());
                for &i in &indices {
                    let req = &batch[i];
                    let gpu = crate::dataset::gpu_features(req.dest);
                    let start = rows.len();
                    for f in &req.features {
                        let mut row = f.clone();
                        row.extend(gpu);
                        rows.push(row);
                    }
                    spans.push((start, rows.len()));
                }
                stats.rows.fetch_add(rows.len() as u64, Relaxed);
                stats.executions.fetch_add(1, Relaxed);

                // One batched execution; scatter the results.
                let result = runtime
                    .predict_rows(op, &rows)
                    .map_err(|e| e.to_string());
                for (&i, (start, end)) in indices.iter().zip(spans) {
                    let reply = match &result {
                        Ok(all) => Ok(all[start..end].to_vec()),
                        Err(e) => Err(anyhow::anyhow!("{e}")),
                    };
                    let _ = batch[i].reply.send(reply);
                }
            }
        }
    }

    // (No Drop needed: the thread exits when the last handle is dropped
    // and the channel disconnects.)
}

impl MlpServiceHandle {
    pub fn stats(&self) -> &ServiceStats {
        &self.stats
    }
}

impl MlpBackend for MlpServiceHandle {
    fn predict_batch(&self, op: MlpOp, features: &[Vec<f64>], dest: Device) -> Result<Vec<f64>> {
        if features.is_empty() {
            return Ok(Vec::new());
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Request {
                op,
                features: features.to_vec(),
                dest,
                reply: reply_tx,
            })
            .map_err(|_| anyhow::anyhow!("MLP service thread is gone"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("MLP service dropped the request"))?
    }

    fn predict_batch_multi(
        &self,
        op: MlpOp,
        features: &[Vec<f64>],
        dests: &[Device],
    ) -> Vec<Result<Vec<f64>>> {
        if features.is_empty() {
            return dests.iter().map(|_| Ok(Vec::new())).collect();
        }
        // Pipeline: enqueue every destination *before* collecting any
        // reply, so the service thread's drain pass sees the whole
        // multi-destination sweep at once and coalesces it into one
        // padded execution per op family (rows already carry per-dest
        // GPU features, so destinations share a batch). The default
        // trait impl would serialize N send→recv round-trips instead.
        let pending: Vec<_> = dests
            .iter()
            .map(|&dest| {
                let (reply_tx, reply_rx) = mpsc::channel();
                let sent = self.tx.send(Request {
                    op,
                    features: features.to_vec(),
                    dest,
                    reply: reply_tx,
                });
                (sent, reply_rx)
            })
            .collect();
        pending
            .into_iter()
            .map(|(sent, reply_rx)| -> Result<Vec<f64>> {
                sent.map_err(|_| anyhow::anyhow!("MLP service thread is gone"))?;
                reply_rx
                    .recv()
                    .map_err(|_| anyhow::anyhow!("MLP service dropped the request"))?
            })
            .collect()
    }
}
