//! Build-time shim for the `xla` crate (PJRT bindings).
//!
//! The build image is offline and carries no prebuilt XLA/PJRT shared
//! libraries, so the real `xla` crate cannot be resolved or linked here.
//! This module exposes the minimal API surface [`super::mlp`] consumes;
//! every entry point reports PJRT as unavailable, which makes artifact
//! loading fail cleanly and every caller degrade to wave scaling (the
//! paper's documented no-artifacts path — see
//! [`crate::predict::HybridPredictor`]). Swapping the real crate back in
//! is a one-line change: replace the `use crate::runtime::xla_compat as
//! xla;` import in `runtime/mlp.rs` with the external crate.

use std::path::Path;

use crate::Result;

fn unavailable<T>(what: &str) -> Result<T> {
    anyhow::bail!(
        "PJRT runtime unavailable in this build ({what}); \
         link the real `xla` crate to enable MLP artifacts"
    )
}

/// Stub for `xla::PjRtClient`.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Stub for `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Stub for `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Stub for `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Stub for `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Stub for `xla::ElementType`.
pub enum ElementType {
    F32,
}

/// Stub for `xla::Literal`.
pub struct Literal;

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Self> {
        unavailable("Literal::create_from_shape_and_untyped_data")
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        unavailable("Literal::to_tuple1")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}
