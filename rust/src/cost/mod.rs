//! Cost-efficiency analysis (paper §5.1 Metrics, §5.3 case studies).
//!
//! Habitat's end product is not a time in ms but an *informed decision*:
//! which GPU maximizes throughput, and which maximizes throughput per
//! dollar. This module turns predicted iteration times into those
//! decision metrics using the rental prices of Table 2.

use crate::device::Device;

/// Training throughput: samples per second for a batch size and iteration
/// time.
pub fn throughput(batch_size: usize, iter_ms: f64) -> f64 {
    debug_assert!(iter_ms > 0.0);
    batch_size as f64 / (iter_ms / 1e3)
}

/// Cost-normalized throughput: samples per second per $/hr. `None` when
/// the device is not offered for rent (paper Table 2 leaves these blank).
pub fn cost_normalized_throughput(device: Device, tput: f64) -> Option<f64> {
    device.spec().rental_usd_per_hr.map(|price| tput / price)
}

/// Cost-normalized throughput for a `world`-GPU cluster: global
/// samples/s per total rental $/hr (`world ×` the per-device price).
/// `None` when the device is not offered for rent.
pub fn cluster_cost_normalized_throughput(
    device: Device,
    world: usize,
    global_tput: f64,
) -> Option<f64> {
    device
        .spec()
        .rental_usd_per_hr
        .map(|price| global_tput / (world as f64 * price))
}

/// Dollars to process `samples` at a given throughput on a rented device.
pub fn cost_to_train(device: Device, tput: f64, samples: u64) -> Option<f64> {
    device
        .spec()
        .rental_usd_per_hr
        .map(|price| samples as f64 / tput / 3600.0 * price)
}

/// Rank devices by a metric, descending; ties broken by device order.
pub fn rank_devices<F: Fn(Device) -> f64>(devices: &[Device], metric: F) -> Vec<Device> {
    let mut v: Vec<(Device, f64)> = devices.iter().map(|d| (*d, metric(*d))).collect();
    v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    v.into_iter().map(|(d, _)| d).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_formula() {
        // batch 64 at 100 ms ⇒ 640 samples/s.
        assert!((throughput(64, 100.0) - 640.0).abs() < 1e-9);
    }

    #[test]
    fn cost_normalized_only_for_rentable() {
        assert!(cost_normalized_throughput(Device::V100, 640.0).is_some());
        assert!(cost_normalized_throughput(Device::Rtx2080Ti, 640.0).is_none());
        let t4 = cost_normalized_throughput(Device::T4, 320.0).unwrap();
        assert!((t4 - 320.0 / 0.35).abs() < 1e-9);
    }

    #[test]
    fn t4_cost_efficiency_beats_v100_at_same_throughput() {
        let t4 = cost_normalized_throughput(Device::T4, 100.0).unwrap();
        let v100 = cost_normalized_throughput(Device::V100, 100.0).unwrap();
        assert!(t4 > v100);
    }

    #[test]
    fn cluster_cost_normalization_divides_by_fleet_price() {
        // Perfect linear scaling keeps samples/s/$ flat as world grows.
        let single = cost_normalized_throughput(Device::T4, 100.0).unwrap();
        let four = cluster_cost_normalized_throughput(Device::T4, 4, 400.0).unwrap();
        assert!((four - single).abs() < 1e-9);
        // Sublinear scaling makes the cluster strictly less cost-efficient.
        let lossy = cluster_cost_normalized_throughput(Device::T4, 4, 300.0).unwrap();
        assert!(lossy < single);
        assert!(cluster_cost_normalized_throughput(Device::Rtx2080Ti, 4, 300.0).is_none());
    }

    #[test]
    fn cost_to_train_scales_with_samples() {
        let one = cost_to_train(Device::P100, 1000.0, 1_000_000).unwrap();
        let two = cost_to_train(Device::P100, 1000.0, 2_000_000).unwrap();
        assert!((two / one - 2.0).abs() < 1e-9);
    }

    #[test]
    fn rank_devices_descending() {
        let ranked = rank_devices(&[Device::T4, Device::V100, Device::P100], |d| {
            d.spec().peak_fp32_tflops
        });
        assert_eq!(ranked[0], Device::V100);
        assert_eq!(ranked[2], Device::T4);
    }
}
