//! # Habitat — a runtime-based computational performance predictor for DNN training
//!
//! Reproduction of *"Habitat: A Runtime-Based Computational Performance
//! Predictor for Deep Neural Network Training"* (Yu, Gao, Golikov,
//! Pekhimenko; USENIX ATC '21) as a three-layer Rust + JAX + Pallas stack.
//!
//! Habitat answers the question *"how fast would my training job run on a
//! GPU I don't have?"*. It records the execution time of every operation in
//! one training iteration on an **origin** GPU and scales each operation's
//! time onto a **destination** GPU using either:
//!
//! * **wave scaling** ([`predict::wave`]) — an analytical model based on the
//!   GPU execution model (thread-block *waves*), for *kernel-alike*
//!   operations that use the same kernels on every GPU, or
//! * **pre-trained MLPs** ([`runtime`]) — learned predictors for
//!   *kernel-varying* operations (`conv2d`, `lstm`, `bmm`, `linear`) whose
//!   kernel selection differs across GPU architectures. The MLPs are
//!   authored in JAX, AOT-lowered to HLO text at build time, and executed
//!   from Rust through the PJRT C API — Python is never on the request path.
//!
//! Because this environment has no physical GPUs, the repo also contains the
//! full substrate the paper's evaluation needs: a datasheet-accurate
//! [`device`] database, a CUDA [`device::occupancy`] calculator, a DNN
//! [`opgraph`] with a five-model [`models`] zoo, an architecture-aware
//! op→kernel [`lowering`], and a kernel-granularity GPU timing [`sim`]ulator
//! that stands in for real hardware as ground truth (see `DESIGN.md` §1).
//!
//! ## Quickstart (Listing 1 of the paper, in Rust)
//!
//! ```no_run
//! use habitat::{Device, OperationTracker, models};
//!
//! let graph = models::resnet50(64);                  // batch size 64
//! let tracker = OperationTracker::new(Device::Rtx2070);
//! let trace = tracker.track(&graph);                 // "run" one iteration
//! let pred = trace.to_device(Device::V100);          // wave scaling only
//! println!("Pred. iter. exec. time: {:.2} ms", pred.run_time_ms());
//! ```
//!
//! With the MLP artifacts built (`make artifacts`), use
//! [`predict::HybridPredictor`] for the paper's full hybrid scheme, or the
//! async [`coordinator::PredictionService`] to serve batched prediction
//! requests.

pub mod cluster;
pub mod coordinator;
pub mod cost;
pub mod dataset;
pub mod device;
pub mod experiments;
pub mod lowering;
pub mod models;
pub mod opgraph;
pub mod predict;
pub mod runtime;
pub mod sim;
pub mod tracker;
pub mod util;

pub use device::{Arch, Device, GpuSpec};
pub use opgraph::{Graph, Op, OpKind};
pub use predict::{HybridPredictor, PredictedTrace};
pub use sim::Precision;
pub use tracker::{OperationTracker, Trace};

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
