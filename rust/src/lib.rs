//! # Habitat — a runtime-based computational performance predictor for DNN training
//!
//! Reproduction of *"Habitat: A Runtime-Based Computational Performance
//! Predictor for Deep Neural Network Training"* (Yu, Gao, Golikov,
//! Pekhimenko; USENIX ATC '21) as a three-layer Rust + JAX + Pallas stack.
//!
//! Habitat answers the question *"how fast would my training job run on a
//! GPU I don't have?"*. It records the execution time of every operation in
//! one training iteration on an **origin** GPU and scales each operation's
//! time onto a **destination** GPU using either:
//!
//! * **wave scaling** ([`predict::wave`]) — an analytical model based on the
//!   GPU execution model (thread-block *waves*), for *kernel-alike*
//!   operations that use the same kernels on every GPU, or
//! * **pre-trained MLPs** ([`runtime`]) — learned predictors for
//!   *kernel-varying* operations (`conv2d`, `lstm`, `bmm`, `linear`) whose
//!   kernel selection differs across GPU architectures. The MLPs are
//!   authored in JAX, AOT-lowered to HLO text at build time, and executed
//!   from Rust through the PJRT C API — Python is never on the request path.
//!
//! Because this environment has no physical GPUs, the repo also contains the
//! full substrate the paper's evaluation needs: a datasheet-accurate
//! [`device`] database, a CUDA [`device::occupancy`] calculator, a DNN
//! [`opgraph`] with a five-model [`models`] zoo, an architecture-aware
//! op→kernel [`lowering`], and a kernel-granularity GPU timing [`sim`]ulator
//! that stands in for real hardware as ground truth (see `DESIGN.md` §1).
//!
//! ## Quickstart (Listing 1 of the paper, in Rust)
//!
//! ```no_run
//! use habitat::{Device, OperationTracker, models};
//!
//! let graph = models::resnet50(64);                  // batch size 64
//! let tracker = OperationTracker::new(Device::Rtx2070);
//! let trace = tracker.track(&graph);                 // "run" one iteration
//! let pred = trace.to_device(Device::V100);          // wave scaling only
//! println!("Pred. iter. exec. time: {:.2} ms", pred.run_time_ms());
//! ```
//!
//! ## The prediction engine: track → analyze → evaluate
//!
//! Production callers go through the unified [`engine::PredictionEngine`]
//! rather than composing tracker + predictor by hand. The engine runs a
//! three-stage pipeline (see `docs/ARCHITECTURE.md`):
//!
//! 1. **track** — one simulated training iteration produces the origin
//!    [`Trace`] (the expensive, reusable step);
//! 2. **analyze** — the trace is compiled once into a flat
//!    [`plan::AnalyzedPlan`] that hoists every destination-independent
//!    quantity: kernel launch metadata, wave sizes batched for all
//!    `(launch shape, device)` pairs, policy-resolved roofline γ, AMP
//!    factors, and MLP feature rows;
//! 3. **evaluate** — each destination is a thin pass of scaling
//!    arithmetic over the plan's arrays (no locking, hashing, or feature
//!    recomputation in the fan-out loop).
//!
//! Trace and plan are memoized together in a content-keyed LRU cache
//! (repeated requests skip tracking *and* analysis), and one cached plan
//! fans out to *all* destination GPUs on a persistent worker pool:
//!
//! ```no_run
//! use habitat::{engine::PredictionEngine, device::ALL_DEVICES, Device, Precision};
//!
//! let engine = PredictionEngine::wave_only();        // or from_artifacts(..)
//! let ranking = engine
//!     .rank("resnet50", 64, Device::Rtx2070, &ALL_DEVICES, Precision::Fp32)
//!     .unwrap();
//! for e in &ranking.entries {
//!     println!(
//!         "{:<10} {:>8.2} ms  {:?} samples/s/$",
//!         e.dest,
//!         e.pred.run_time_ms(),
//!         e.cost_normalized_throughput,
//!     );
//! }
//! ```
//!
//! With the MLP artifacts built (`make artifacts`), build the engine with
//! [`engine::PredictionEngine::from_artifacts`] for the paper's full
//! hybrid scheme. The TCP front end ([`coordinator::PredictionService`])
//! serves the same engine over newline-delimited JSON, including a `rank`
//! request that returns every destination GPU ordered by cost-normalized
//! throughput in a single RPC and a `stats` request exposing the
//! trace/plan cache counters and pool size (see `docs/SERVICE.md`).
//!
//! ## The open world: device registry and trace upload
//!
//! The device set is not a closed enum. The six paper GPUs are seed
//! entries of the process-wide [`device::registry`]; new accelerators
//! register at runtime — in-process via [`device::registry::register`],
//! or over the wire via the v2 envelope's `register_device` op — and are
//! immediately valid as origins, destinations, `rank` candidates,
//! scheduler inventory, and dataset rows. Likewise, workloads are not
//! limited to the model zoo: a [`Trace`] profiled anywhere can be
//! uploaded with `submit_trace` and predicted by its content-hashed
//! `trace_id` through the same cached-plan machinery. All v2 requests
//! ride a versioned envelope (`{"v":2,"op":...}`) with structured
//! errors, while v1 request lines keep working bit-identically.

pub mod cluster;
pub mod comm;
pub mod coordinator;
pub mod cost;
pub mod dataset;
pub mod device;
pub mod engine;
pub mod experiments;
pub mod lowering;
pub mod models;
pub mod opgraph;
pub mod plan;
pub mod predict;
pub mod runtime;
pub mod sim;
pub mod tracker;
pub mod util;

pub use device::{Arch, Device, DeviceId, GpuSpec, NewDevice};
pub use engine::PredictionEngine;
pub use opgraph::{Graph, Op, OpKind};
pub use plan::{AnalyzedPlan, AnalyzedTrace};
pub use predict::{HybridPredictor, PredictedTrace};
pub use sim::Precision;
pub use tracker::{OperationTracker, Trace};

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
