//! Heterogeneous-cluster scheduling on top of Habitat predictions.
//!
//! The paper's introduction motivates Habitat with cluster scheduling:
//! *"Determining how to schedule a job in a heterogeneous GPU cluster …
//! will typically depend on the job's … performance on the GPU being
//! considered [18, 61]"*. This module is that consumer: a Gavel-style
//! [61] throughput-aware scheduler whose throughput matrix comes from
//! Habitat predictions instead of exhaustive on-hardware profiling —
//! each job only needs to have been profiled once, on whatever GPU the
//! owner had.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::device::Device;
use crate::engine::{PredictionEngine, SweepJob, SweepTimes};
use crate::lowering::Precision;
use crate::plan::AnalyzedPlan;
use crate::tracker::Trace;

/// One training job waiting for placement.
#[derive(Debug, Clone)]
pub struct Job {
    pub name: String,
    pub model: String,
    pub batch: usize,
    /// The GPU the job was profiled on (its owner's workstation).
    pub origin: Device,
}

/// Cluster inventory: how many of each GPU are free.
pub type Inventory = BTreeMap<Device, usize>;

/// A placement decision.
#[derive(Debug, Clone)]
pub struct Placement {
    pub job: String,
    pub device: Device,
    /// Predicted throughput of the job on that device, samples/s.
    pub throughput: f64,
    /// Throughput normalized to the job's best-device throughput ∈ (0, 1].
    pub normalized: f64,
}

/// Habitat-predicted throughput matrix: jobs × devices.
pub struct ThroughputMatrix {
    pub jobs: Vec<Job>,
    pub devices: Vec<Device>,
    /// `matrix[j][d]` = predicted samples/s for job `j` on device `d`.
    pub matrix: Vec<Vec<f64>>,
}

impl ThroughputMatrix {
    /// Compile every job's trace into a plan and run the whole matrix as
    /// **one** multi-trace sweep on the engine's shared worker pool
    /// ([`PredictionEngine::evaluate_many_times`]): one work-claimed job
    /// set, one scratch arena per worker, no per-job pool round-trips.
    /// Each row stays bit-identical to a per-job kernel-major sweep —
    /// and therefore to per-cell scalar evaluates.
    fn sweep(
        engine: &PredictionEngine,
        traces: &[(Job, Trace)],
        devices: &[Device],
    ) -> (Vec<Arc<AnalyzedPlan>>, SweepTimes) {
        let plans: Vec<Arc<AnalyzedPlan>> =
            traces.iter().map(|(_, t)| engine.analyze(t)).collect();
        let jobs: Vec<SweepJob<'_>> = plans
            .iter()
            .map(|plan| SweepJob {
                plan: Arc::clone(plan),
                dests: devices,
                precision: Precision::Fp32,
            })
            .collect();
        let mut times = SweepTimes::new();
        engine.evaluate_many_times(&jobs, &mut times);
        (plans, times)
    }

    /// Build the matrix by tracking each job once on its origin and
    /// predicting every candidate device.
    pub fn build(engine: &PredictionEngine, traces: &[(Job, Trace)], devices: &[Device]) -> Self {
        let (plans, times) = Self::sweep(engine, traces, devices);
        let matrix: Vec<Vec<f64>> = plans
            .iter()
            .enumerate()
            .map(|(j, plan)| {
                // Same expression as `EvalScratch::throughput`, applied
                // to the swept per-destination times.
                let batch = plan.batch_size as f64;
                times.job(j).iter().map(|ms| batch / (ms / 1e3)).collect()
            })
            .collect();
        ThroughputMatrix {
            jobs: traces.iter().map(|(j, _)| j.clone()).collect(),
            devices: devices.to_vec(),
            matrix,
        }
    }

    /// [`ThroughputMatrix::build`] for *gang* placements: every cell is
    /// the **global** samples/s of a `world`-replica data-parallel gang
    /// of that device on `topology`, composed with the topology-aware
    /// collective model ([`crate::comm::cluster::compose`]). `world = 1`
    /// degenerates to `build` exactly. All jobs still run as one
    /// multi-trace sweep; the collective composition is a per-cell
    /// epilogue on the swept compute times.
    pub fn build_cluster(
        engine: &PredictionEngine,
        traces: &[(Job, Trace)],
        devices: &[Device],
        topology: crate::comm::Topology,
        world: usize,
        params: &crate::comm::ClusterParams,
    ) -> Self {
        let (plans, times) = Self::sweep(engine, traces, devices);
        let matrix: Vec<Vec<f64>> = plans
            .iter()
            .enumerate()
            .map(|(j, plan)| {
                let comm = crate::comm::trace_comm(&traces[j].1);
                times
                    .job(j)
                    .iter()
                    .map(|compute_ms| {
                        crate::comm::cluster::compose(
                            *compute_ms,
                            plan.batch_size,
                            &comm,
                            topology,
                            world,
                            params,
                        )
                        .throughput
                    })
                    .collect()
            })
            .collect();
        ThroughputMatrix {
            jobs: traces.iter().map(|(j, _)| j.clone()).collect(),
            devices: devices.to_vec(),
            matrix,
        }
    }
}

/// Greedy max-normalized-throughput scheduler (the Gavel "max sum of
/// normalized throughputs" objective, solved greedily): repeatedly place
/// the (job, device) pair with the highest normalized throughput among
/// unplaced jobs and free devices.
pub fn schedule(matrix: &ThroughputMatrix, inventory: &Inventory) -> Vec<Placement> {
    let mut free = inventory.clone();
    let mut placed = vec![false; matrix.jobs.len()];
    let mut placements = Vec::new();

    // Per-job best throughput for normalization.
    let best: Vec<f64> = matrix
        .matrix
        .iter()
        .map(|row| row.iter().cloned().fold(f64::MIN, f64::max))
        .collect();

    loop {
        let mut candidate: Option<(usize, usize, f64)> = None;
        for (j, row) in matrix.matrix.iter().enumerate() {
            if placed[j] {
                continue;
            }
            for (d, tput) in row.iter().enumerate() {
                let device = matrix.devices[d];
                if free.get(&device).copied().unwrap_or(0) == 0 {
                    continue;
                }
                let norm = tput / best[j];
                if candidate.map_or(true, |(_, _, n)| norm > n) {
                    candidate = Some((j, d, norm));
                }
            }
        }
        let Some((j, d, norm)) = candidate else { break };
        let device = matrix.devices[d];
        *free.get_mut(&device).unwrap() -= 1;
        placed[j] = true;
        placements.push(Placement {
            job: matrix.jobs[j].name.clone(),
            device,
            throughput: matrix.matrix[j][d],
            normalized: norm,
        });
    }
    placements
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tracker::OperationTracker;

    fn job(name: &str, model: &str, batch: usize) -> (Job, Trace) {
        let j = Job {
            name: name.into(),
            model: model.into(),
            batch,
            origin: Device::Rtx2070,
        };
        let g = crate::models::by_name(model, batch).unwrap();
        let t = OperationTracker::new(j.origin).track(&g);
        (j, t)
    }

    fn toy_matrix() -> ThroughputMatrix {
        let engine = PredictionEngine::wave_only();
        let traces = vec![job("a", "mlp", 64), job("b", "dcgan", 64)];
        ThroughputMatrix::build(&engine, &traces, &[Device::V100, Device::T4])
    }

    #[test]
    fn matrix_is_bit_identical_to_per_cell_scalar_evaluation() {
        // The multi-trace-sweep rewrite of `build` must not move a single
        // bit: every cell is pinned against an independent scalar evaluate.
        let engine = PredictionEngine::wave_only();
        let traces = vec![job("a", "mlp", 64), job("b", "dcgan", 64)];
        let devices = [Device::V100, Device::T4, Device::P4000];
        let m = ThroughputMatrix::build(&engine, &traces, &devices);
        assert_eq!(m.matrix.len(), traces.len());
        for (j, (_, trace)) in traces.iter().enumerate() {
            let plan = engine.analyze(trace);
            assert_eq!(m.matrix[j].len(), devices.len());
            for (d, dev) in devices.iter().enumerate() {
                let scalar = engine.predictor().evaluate(&plan, *dev).throughput();
                assert_eq!(
                    m.matrix[j][d].to_bits(),
                    scalar.to_bits(),
                    "job {j} on {dev}: batched {} vs scalar {scalar}",
                    m.matrix[j][d]
                );
            }
        }
    }

    #[test]
    fn cluster_matrix_world_one_is_bit_identical_to_single_gpu_build() {
        let engine = PredictionEngine::wave_only();
        let traces = vec![job("a", "mlp", 64), job("b", "dcgan", 64)];
        let devices = [Device::V100, Device::T4];
        let single = ThroughputMatrix::build(&engine, &traces, &devices);
        let gang = ThroughputMatrix::build_cluster(
            &engine,
            &traces,
            &devices,
            crate::comm::Topology::DGX,
            1,
            &crate::comm::ClusterParams::default(),
        );
        for (srow, grow) in single.matrix.iter().zip(&gang.matrix) {
            for (s, g) in srow.iter().zip(grow) {
                assert_eq!(s.to_bits(), g.to_bits());
            }
        }
    }

    #[test]
    fn cluster_matrix_gangs_scale_sublinearly_but_upward() {
        let engine = PredictionEngine::wave_only();
        let traces = vec![job("a", "resnet50", 32)];
        let devices = [Device::V100];
        let params = crate::comm::ClusterParams::default();
        let t1 = ThroughputMatrix::build_cluster(
            &engine, &traces, &devices, crate::comm::Topology::DGX, 1, &params,
        )
        .matrix[0][0];
        let t8 = ThroughputMatrix::build_cluster(
            &engine, &traces, &devices, crate::comm::Topology::DGX, 8, &params,
        )
        .matrix[0][0];
        assert!(t8 > t1, "an 8-gang should beat one GPU: {t8} vs {t1}");
        assert!(t8 <= 8.0 * t1 + 1e-9, "no superlinear scaling: {t8} vs 8×{t1}");
        // A slower interconnect can only hurt.
        let t8_cloud = ThroughputMatrix::build_cluster(
            &engine, &traces, &devices, crate::comm::Topology::CLOUD, 8, &params,
        )
        .matrix[0][0];
        assert!(t8_cloud <= t8 + 1e-9, "cloud gang beat NVLink gang: {t8_cloud} vs {t8}");
    }

    #[test]
    fn schedule_accepts_a_cluster_matrix() {
        // Gang-level placement: cells are global gang throughputs, the
        // greedy objective is unchanged.
        let engine = PredictionEngine::wave_only();
        let traces = vec![job("a", "mlp", 64), job("b", "dcgan", 64)];
        let m = ThroughputMatrix::build_cluster(
            &engine,
            &traces,
            &[Device::V100, Device::T4],
            crate::comm::Topology::DGX,
            2,
            &crate::comm::ClusterParams::default(),
        );
        let inv: Inventory = [(Device::V100, 1), (Device::T4, 1)].into();
        let placements = schedule(&m, &inv);
        assert_eq!(placements.len(), 2);
        for p in &placements {
            assert!(p.throughput > 0.0 && p.normalized > 0.0 && p.normalized <= 1.0 + 1e-12);
        }
    }

    #[test]
    fn schedules_all_jobs_when_capacity_allows() {
        let m = toy_matrix();
        let inv: Inventory = [(Device::V100, 1), (Device::T4, 1)].into();
        let placements = schedule(&m, &inv);
        assert_eq!(placements.len(), 2);
        // Each device used once.
        let mut devs: Vec<Device> = placements.iter().map(|p| p.device).collect();
        devs.sort();
        devs.dedup();
        assert_eq!(devs.len(), 2);
    }

    #[test]
    fn respects_inventory_limits() {
        let m = toy_matrix();
        let inv: Inventory = [(Device::T4, 1)].into();
        let placements = schedule(&m, &inv);
        assert_eq!(placements.len(), 1, "only one slot available");
        assert_eq!(placements[0].device, Device::T4);
    }

    #[test]
    fn normalized_throughput_in_unit_interval() {
        let m = toy_matrix();
        let inv: Inventory = [(Device::V100, 2), (Device::T4, 2)].into();
        for p in schedule(&m, &inv) {
            assert!(p.normalized > 0.0 && p.normalized <= 1.0 + 1e-12);
        }
    }

    #[test]
    fn empty_inventory_places_nothing() {
        let m = toy_matrix();
        assert!(schedule(&m, &Inventory::new()).is_empty());
    }

    #[test]
    fn runtime_registered_device_is_schedulable() {
        // Open-world scheduling: a GPU registered at runtime joins the
        // throughput matrix and can win placements like any built-in.
        let d = crate::device::registry::register(&crate::device::NewDevice {
            usd_per_hr: Some(3.5),
            ..crate::device::NewDevice::new("sim-sched-xl", 128, 1700.0, 1600.0, 48.0, true)
        })
        .unwrap();
        let engine = PredictionEngine::wave_only();
        let traces = vec![job("a", "mlp", 64)];
        let m = ThroughputMatrix::build(&engine, &traces, &[Device::T4, d]);
        assert!(m.matrix[0].iter().all(|t| *t > 0.0));
        // The big registered GPU out-throughputs a T4; with only it free,
        // the job lands there.
        let inv: Inventory = [(d, 1)].into();
        let placements = schedule(&m, &inv);
        assert_eq!(placements.len(), 1);
        assert_eq!(placements[0].device, d);
    }
}
