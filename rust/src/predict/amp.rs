//! Mixed-precision prediction à la Daydream (paper §6.1.2).
//!
//! Daydream [110] predicts the benefit of switching FP32 → AMP on a
//! *fixed* GPU by transforming a measured kernel timeline: matmul-class
//! kernels speed up by the tensor-core ratio, memory-bound kernels by the
//! halved traffic. Composed with Habitat: first predict the FP32 iteration
//! on the destination GPU, then apply the Daydream transformation with the
//! destination's hardware parameters.

use crate::device::{Device, GpuSpec};
use crate::predict::roofline;
use crate::predict::{HybridPredictor, PredictedTrace};
use crate::tracker::Trace;

/// Effective AMP speedup factor (multiplier on time, < 1 is faster) for
/// one kernel with memory-boundedness γ on a destination GPU.
///
/// * memory leg: traffic halves ⇒ ×0.5
/// * compute leg: tensor-core-eligible kernels run at the FP16 peak,
///   derated by a 0.6 achieved-efficiency factor vs the FP32 baseline;
///   non-eligible kernels keep their FP32 compute time.
pub fn amp_factor(gamma: f64, tensor_core_eligible: bool, dest: &GpuSpec) -> f64 {
    let mem_factor = 0.5;
    let compute_factor = if tensor_core_eligible && dest.arch.has_tensor_cores() {
        (dest.peak_fp32_tflops / (dest.peak_fp16_tflops * 0.6)).min(1.0)
    } else if tensor_core_eligible && dest.peak_fp16_tflops > dest.peak_fp32_tflops {
        // P100: fast FP16 path without tensor cores.
        dest.peak_fp32_tflops / dest.peak_fp16_tflops
    } else {
        1.0
    };
    gamma * mem_factor + (1.0 - gamma) * compute_factor
}

/// Transform an FP32 trace *measured on its own device* into a predicted
/// AMP iteration time on the same device (pure Daydream).
pub fn amp_time_same_device(trace: &Trace) -> f64 {
    let spec = trace.origin.spec();
    trace
        .ops
        .iter()
        .flat_map(|o| o.fwd.iter().chain(&o.bwd))
        .map(|m| {
            let g = roofline::gamma(m.kernel.arith_intensity(), spec);
            m.time_ms * amp_factor(g, m.kernel.tensor_core_eligible, spec)
        })
        .sum()
}

/// Habitat + Daydream: predict the **AMP** iteration time on a
/// **different** GPU from an FP32 trace on the origin (§6.1.2).
///
/// Step 1 — Habitat predicts the FP32 time per op on `dest`.
/// Step 2 — Daydream's transformation scales each op by its AMP factor,
/// with γ taken from the op's measured kernels.
pub fn predict_amp(predictor: &HybridPredictor, trace: &Trace, dest: Device) -> PredictedTrace {
    amp_transform(&predictor.predict(trace, dest), trace)
}

/// Step 2 alone: apply the Daydream AMP transformation to an
/// already-predicted FP32 destination iteration. Split out so the
/// engine's fan-out can reuse one FP32 prediction pass per destination.
pub fn amp_transform(fp32: &PredictedTrace, trace: &Trace) -> PredictedTrace {
    let dest_spec = fp32.dest.spec();
    let mut amped = fp32.clone();
    for (pred_op, tracked) in amped.ops.iter_mut().zip(&trace.ops) {
        // Time-weighted AMP factor over the op's kernels.
        let total: f64 = tracked.total_ms();
        if total <= 0.0 {
            continue;
        }
        let factor: f64 = tracked
            .fwd
            .iter()
            .chain(&tracked.bwd)
            .map(|m| {
                let g = roofline::gamma(m.kernel.arith_intensity(), dest_spec);
                amp_factor(g, m.kernel.tensor_core_eligible, dest_spec) * m.time_ms
            })
            .sum::<f64>()
            / total;
        pred_op.time_ms *= factor;
    }
    amped
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opgraph::{Op, OpKind};
    use crate::tracker::OperationTracker;

    fn conv_trace(origin: Device) -> Trace {
        let mut g = crate::Graph::new("toy", 32);
        g.push(Op::new(
            "conv",
            OpKind::Conv2d {
                in_ch: 256,
                out_ch: 256,
                kernel: 3,
                stride: 1,
                padding: 1,
                bias: false,
            },
            vec![32, 256, 28, 28],
        ));
        OperationTracker::new(origin).track(&g)
    }

    #[test]
    fn amp_factor_bounds() {
        let v100 = Device::V100.spec();
        for g in [0.0, 0.25, 0.5, 0.75, 1.0] {
            for tc in [true, false] {
                let f = amp_factor(g, tc, v100);
                assert!(f > 0.0 && f <= 1.0, "γ={g} tc={tc}: {f}");
            }
        }
    }

    #[test]
    fn tensor_cores_beat_no_tensor_cores() {
        let v100 = Device::V100.spec();
        let p4000 = Device::P4000.spec();
        // Compute-bound kernel: tensor cores help on V100, not on P4000.
        assert!(amp_factor(0.0, true, v100) < 0.5);
        assert_eq!(amp_factor(0.0, true, p4000), 1.0);
    }

    #[test]
    fn memory_bound_amp_halves_time() {
        let t4 = Device::T4.spec();
        assert!((amp_factor(1.0, false, t4) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn amp_faster_than_fp32_on_tensor_core_gpu() {
        let trace = conv_trace(Device::Rtx2080Ti);
        let amp = amp_time_same_device(&trace);
        assert!(amp < trace.run_time_ms());
    }

    #[test]
    fn cross_gpu_amp_prediction_faster_than_fp32_prediction() {
        let trace = conv_trace(Device::P4000);
        let predictor = HybridPredictor::wave_only();
        let fp32 = predictor.predict(&trace, Device::Rtx2070);
        let amp = predict_amp(&predictor, &trace, Device::Rtx2070);
        assert!(amp.run_time_ms() < fp32.run_time_ms());
    }
}
