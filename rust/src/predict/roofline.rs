//! γ selection from the roofline model (paper §4.2, Eq. 3).
//!
//! γ represents a kernel's *memory-bandwidth boundedness*. Habitat
//! computes the kernel's arithmetic intensity `x` (FLOPs per DRAM byte —
//! a property of the kernel's code, fixed across GPUs) and compares it to
//! the destination GPU's ridge point `R = P/D`:
//!
//! ```text
//! γ = (−0.5/R)·x + 1   if x < R      (1 → 0.5 linearly)
//!   = 0.5·R/x          otherwise     (0.5 → 0 hyperbolically)
//! ```
//!
//! Collecting the metrics needed for `x` is expensive on real hardware
//! (kernel replay), so the paper only profiles kernels from operations at
//! or above a percentile of per-op execution time, caches results keyed by
//! kernel name + launch configuration, and falls back to γ = 1 (fully
//! memory bound) when metrics are unavailable — a good default because
//! unprofiled kernel-alike ops are almost always simple, memory-bound
//! kernels. [`MetricsPolicy`] reproduces that machinery.

use std::collections::HashSet;

use crate::device::GpuSpec;
use crate::tracker::Trace;
use crate::util::rng::hash_str;
use crate::util::stats::percentile;

/// Eq. 3: γ from arithmetic intensity `x` and destination ridge point `R`.
pub fn gamma(x: f64, dest: &GpuSpec) -> f64 {
    let r = dest.ridge_point();
    debug_assert!(r > 0.0);
    if !x.is_finite() {
        return 0.0; // no memory traffic at all ⇒ purely compute bound
    }
    let g = if x < r { (-0.5 / r) * x + 1.0 } else { 0.5 * r / x };
    g.clamp(0.0, 1.0)
}

/// Which kernels have profiled metrics available (§4.2 "practical
/// optimizations").
#[derive(Debug, Clone)]
pub enum MetricsPolicy {
    /// Warm metrics cache: every kernel has metrics (the steady state
    /// after Habitat has profiled a model a few times).
    All,
    /// Cold cache: no metrics; every kernel takes the γ = 1 fallback.
    None,
    /// The paper's default: profile kernels belonging to operations whose
    /// execution time is at or above this percentile (e.g. 99.5), then
    /// share results across kernels with the same name + launch via the
    /// metrics cache.
    Percentile(f64),
}

impl Default for MetricsPolicy {
    fn default() -> Self {
        // The paper's stated threshold.
        MetricsPolicy::Percentile(99.5)
    }
}

impl MetricsPolicy {
    /// Resolve the policy against a trace: the set of kernel cache keys
    /// (name + launch signature) that have metrics available.
    /// Keys are 64-bit hashes — the predict hot path builds this set per
    /// call, so it must not allocate per kernel (see EXPERIMENTS.md §Perf).
    pub fn profiled_kernels(&self, trace: &Trace) -> Option<HashSet<u64>> {
        match self {
            MetricsPolicy::All => None, // `None` = everything profiled
            MetricsPolicy::None => Some(HashSet::new()),
            MetricsPolicy::Percentile(p) => {
                let times: Vec<f64> = trace.ops.iter().map(|o| o.total_ms()).collect();
                if times.is_empty() {
                    return Some(HashSet::new());
                }
                let threshold = percentile(&times, *p);
                let mut keys = HashSet::new();
                for op in &trace.ops {
                    if op.total_ms() >= threshold {
                        for m in op.fwd.iter().chain(&op.bwd) {
                            keys.insert(cache_key(&m.kernel));
                        }
                    }
                }
                Some(keys)
            }
        }
    }
}

/// Metrics-cache key: kernel name + launch configuration (§4.2: "keyed by
/// the kernel's name and its launch configuration"), as an allocation-free
/// 64-bit hash.
pub fn cache_key(kernel: &crate::lowering::Kernel) -> u64 {
    hash_str(&kernel.name)
        ^ kernel.launch.grid_blocks.rotate_left(17)
        ^ (kernel.launch.threads_per_block as u64).rotate_left(41)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Device;
    use crate::tracker::OperationTracker;
    use crate::opgraph::{EwKind, Op, OpKind};

    #[test]
    fn gamma_is_one_at_zero_intensity() {
        let v100 = Device::V100.spec();
        assert!((gamma(0.0, v100) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn gamma_is_half_at_ridge_point() {
        let v100 = Device::V100.spec();
        let r = v100.ridge_point();
        assert!((gamma(r, v100) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn gamma_decays_beyond_ridge() {
        let v100 = Device::V100.spec();
        let r = v100.ridge_point();
        assert!((gamma(2.0 * r, v100) - 0.25).abs() < 1e-9);
        assert!(gamma(100.0 * r, v100) < 0.01);
        assert_eq!(gamma(f64::INFINITY, v100), 0.0);
    }

    #[test]
    fn gamma_monotone_decreasing_and_bounded() {
        let t4 = Device::T4.spec();
        let mut prev = 1.0 + 1e-12;
        for i in 0..1000 {
            let x = i as f64 * 0.5;
            let g = gamma(x, t4);
            assert!((0.0..=1.0).contains(&g));
            assert!(g <= prev + 1e-12, "γ must be non-increasing in x");
            prev = g;
        }
    }

    fn toy_trace() -> Trace {
        let mut g = crate::Graph::new("toy", 8);
        // One heavy op and many light ops.
        g.push(Op::new(
            "fc",
            OpKind::Linear {
                in_features: 4096,
                out_features: 4096,
                bias: false,
            },
            vec![512, 4096],
        ));
        for i in 0..20 {
            g.push(Op::new(
                format!("relu{i}"),
                OpKind::Elementwise { kind: EwKind::Relu },
                vec![128],
            ));
        }
        OperationTracker::new(Device::V100).track(&g)
    }

    #[test]
    fn percentile_policy_profiles_only_heavy_ops() {
        let trace = toy_trace();
        let keys = MetricsPolicy::Percentile(99.0)
            .profiled_kernels(&trace)
            .unwrap();
        assert!(!keys.is_empty());
        // The heavy GEMM's kernels must be profiled; the tiny relus not.
        let gemm_op = trace.ops.iter().find(|o| o.op.name == "fc").unwrap();
        for m in gemm_op.fwd.iter().chain(&gemm_op.bwd) {
            assert!(keys.contains(&cache_key(&m.kernel)));
        }
        let relu_op = trace.ops.iter().find(|o| o.op.name == "relu0").unwrap();
        for m in relu_op.fwd.iter().chain(&relu_op.bwd) {
            assert!(!keys.contains(&cache_key(&m.kernel)));
        }
    }

    #[test]
    fn all_and_none_policies() {
        let trace = toy_trace();
        assert!(MetricsPolicy::All.profiled_kernels(&trace).is_none());
        assert!(MetricsPolicy::None
            .profiled_kernels(&trace)
            .unwrap()
            .is_empty());
    }
}
