//! Batch-size extrapolation (paper §6.1.3).
//!
//! When the desired batch size does not fit on the origin GPU, Habitat
//! predicts the iteration time for several batch sizes that *do* fit,
//! fits a linear model `time = a + b·batch` over the predictions (the
//! paper observed an approximately linear relationship in Skyline [107]),
//! and extrapolates.

use crate::util::stats::linear_fit;

/// A fitted iteration-time ∼ batch-size model.
#[derive(Debug, Clone, Copy)]
pub struct BatchExtrapolator {
    /// Intercept, ms.
    pub a: f64,
    /// Slope, ms per sample.
    pub b: f64,
}

impl BatchExtrapolator {
    /// Fit from `(batch_size, iteration_ms)` points (≥ 2; the paper
    /// suggests three).
    pub fn fit(points: &[(usize, f64)]) -> Self {
        assert!(points.len() >= 2, "need at least two batch sizes");
        let xs: Vec<f64> = points.iter().map(|(b, _)| *b as f64).collect();
        let ys: Vec<f64> = points.iter().map(|(_, t)| *t).collect();
        let (a, b) = linear_fit(&xs, &ys);
        BatchExtrapolator { a, b }
    }

    /// Predicted iteration time at a batch size, ms.
    pub fn predict(&self, batch_size: usize) -> f64 {
        self.a + self.b * batch_size as f64
    }

    /// Predicted throughput at a batch size, samples/s.
    pub fn throughput(&self, batch_size: usize) -> f64 {
        batch_size as f64 / (self.predict(batch_size) / 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_on_linear_data() {
        let m = BatchExtrapolator::fit(&[(16, 26.0), (32, 42.0), (64, 74.0)]);
        // time = 10 + 1·batch
        assert!((m.predict(128) - 138.0).abs() < 1e-9);
    }

    #[test]
    fn throughput_saturates_with_batch() {
        let m = BatchExtrapolator::fit(&[(16, 26.0), (32, 42.0)]);
        // With a fixed intercept, throughput grows toward 1000/b
        assert!(m.throughput(64) > m.throughput(16));
    }

    #[test]
    #[should_panic]
    fn refuses_single_point() {
        BatchExtrapolator::fit(&[(16, 26.0)]);
    }
}
