//! Data-parallel training prediction (paper §6.1.1).
//!
//! The paper positions Habitat's computation predictions as the input to
//! existing data-parallel performance models [87, 88, 110]: predicting a
//! distributed iteration reduces to (i) per-GPU compute time — Habitat's
//! job — plus (ii) gradient-synchronization communication and (iii) its
//! overlap with the backward pass. This module supplies (ii) and (iii)
//! with the standard ring all-reduce cost model those papers use, so a
//! single-GPU trace profiled on a workstation yields multi-GPU scaling
//! estimates for a cluster the user does not have.

use crate::comm;
use crate::comm::cluster::{trace_comm, TraceComm};
use crate::device::Device;
use crate::plan::{AnalyzedPlan, EvalScratch};
use crate::predict::{HybridPredictor, PredictedTrace};
use crate::tracker::Trace;

/// Interconnect between the replicas.
///
/// **Deprecated in favor of [`comm::Link`]**: the bandwidth/latency
/// constants this enum used to hard-code now live as seed entries of
/// the process-wide link registry (same pattern as the device
/// registry), where new links can also be registered at runtime. The
/// enum is kept so existing constructors compile; every variant except
/// `Custom` is a thin name for a registry link (see
/// [`Interconnect::link`]), and the cost arithmetic delegates to
/// [`comm::collective`] — bit-identical for the seed links, pinned by
/// `seed_links_are_bit_identical_to_the_legacy_constants` below.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Interconnect {
    /// PCIe 3.0 x16 (~12 GB/s effective).
    Pcie3,
    /// PCIe 4.0 x16 (~24 GB/s effective).
    Pcie4,
    /// NVLink 2.0 (V100-class, ~130 GB/s effective per GPU).
    NvLink,
    /// 25 Gb/s Ethernet between nodes (~2.9 GB/s effective).
    Ethernet25G,
    /// Custom effective bus bandwidth, GB/s (not registry-backed; use
    /// [`comm::register_link`] + `Interconnect::from` for a named,
    /// wire-addressable link instead).
    Custom(f64),
    /// A registry link — the forward-looking variant the legacy names
    /// above are aliases of.
    Link(comm::Link),
}

impl From<comm::Link> for Interconnect {
    fn from(l: comm::Link) -> Interconnect {
        Interconnect::Link(l)
    }
}

impl Interconnect {
    /// The registry link backing this interconnect (`None` only for
    /// `Custom`, which never entered the registry).
    pub fn link(self) -> Option<comm::Link> {
        match self {
            Interconnect::Pcie3 => Some(comm::Link::PCIE3),
            Interconnect::Pcie4 => Some(comm::Link::PCIE4),
            Interconnect::NvLink => Some(comm::Link::NVLINK),
            Interconnect::Ethernet25G => Some(comm::Link::ETHERNET_25G),
            Interconnect::Custom(_) => None,
            Interconnect::Link(l) => Some(l),
        }
    }

    /// Effective all-reduce bus bandwidth, bytes/s.
    pub fn bandwidth_bytes(self) -> f64 {
        if let Interconnect::Custom(v) = self {
            return v * 1e9;
        }
        self.link().expect("non-custom interconnects are registry links").spec().bandwidth_bytes()
    }

    /// Per-message launch latency (ring step), ms.
    pub fn step_latency_ms(self) -> f64 {
        match self.link() {
            Some(l) => l.spec().step_latency_ms,
            None => 0.01, // legacy Custom default
        }
    }
}

/// Configuration of the data-parallel job.
#[derive(Debug, Clone, Copy)]
pub struct DataParallelConfig {
    /// Number of replicas (GPUs).
    pub world: usize,
    pub interconnect: Interconnect,
    /// Fraction of the backward pass that gradient communication can
    /// overlap with (bucketed all-reduce à la PyTorch DDP). 0 = fully
    /// exposed, 1 = fully overlappable.
    pub overlap: f64,
}

impl Default for DataParallelConfig {
    fn default() -> Self {
        DataParallelConfig {
            world: 2,
            interconnect: Interconnect::Pcie3,
            overlap: 0.7,
        }
    }
}

/// A data-parallel iteration prediction.
#[derive(Debug, Clone)]
pub struct DpPrediction {
    /// Per-replica compute time (Habitat's single-GPU prediction), ms.
    pub compute_ms: f64,
    /// Total all-reduce time, ms.
    pub allreduce_ms: f64,
    /// All-reduce time not hidden behind the backward pass, ms.
    pub exposed_ms: f64,
    /// Predicted distributed iteration time, ms.
    pub iter_ms: f64,
    /// Global throughput, samples/s (world × per-replica batch).
    pub throughput: f64,
    /// Scaling efficiency vs `world ×` the single-GPU throughput.
    pub efficiency: f64,
}

/// Ring all-reduce time for `bytes` over `world` replicas:
/// `2·(n−1)/n · bytes / BW + 2·(n−1) · latency`. Delegates to
/// [`comm::collective::ring_allreduce_ms_raw`] (same float-op order as
/// the historical inline formula).
pub fn ring_allreduce_ms(bytes: f64, world: usize, interconnect: Interconnect) -> f64 {
    comm::collective::ring_allreduce_ms_raw(
        bytes,
        world,
        interconnect.bandwidth_bytes(),
        interconnect.step_latency_ms(),
    )
}

/// Compose a Habitat cross-GPU prediction with the all-reduce model.
///
/// `pred` is the (destination-GPU) single-replica prediction for the
/// per-replica batch; `trace` supplies the backward-time share and the
/// gradient volume (= parameter count × 4 bytes, FP32 gradients).
pub fn predict_data_parallel(
    trace: &Trace,
    pred: &PredictedTrace,
    config: &DataParallelConfig,
) -> DpPrediction {
    compose(pred.run_time_ms(), pred.batch_size, &trace_comm(trace), config)
}

/// Compose one destination's compute time with the all-reduce model —
/// the shared arithmetic of [`predict_data_parallel`] (scalar) and
/// [`data_parallel_sweep`] (batched), so the two cannot drift.
fn compose(
    compute_ms: f64,
    batch_size: usize,
    comm: &TraceComm,
    config: &DataParallelConfig,
) -> DpPrediction {
    let allreduce_ms = ring_allreduce_ms(comm.grad_bytes, config.world, config.interconnect);
    let overlappable = config.overlap.clamp(0.0, 1.0) * comm.bwd_fraction * compute_ms;
    let exposed_ms = (allreduce_ms - overlappable).max(0.0);

    let iter_ms = compute_ms + exposed_ms;
    let single_throughput = batch_size as f64 / (compute_ms / 1e3);
    let throughput = config.world as f64 * batch_size as f64 / (iter_ms / 1e3);
    DpPrediction {
        compute_ms,
        allreduce_ms,
        exposed_ms,
        iter_ms,
        throughput,
        efficiency: throughput / (config.world as f64 * single_throughput),
    }
}

/// Sweep one compiled plan across many candidate destination GPUs: a
/// single kernel-major batched evaluation
/// ([`HybridPredictor::evaluate_batch_times`]) produces every
/// destination's compute time, and each is composed with the all-reduce
/// model. Returns one [`DpPrediction`] per destination, in caller
/// order (duplicates evaluated once), bit-identical to evaluating each
/// destination scalar-ly and calling [`predict_data_parallel`].
pub fn data_parallel_sweep(
    predictor: &HybridPredictor,
    plan: &AnalyzedPlan,
    trace: &Trace,
    dests: &[Device],
    precision: crate::lowering::Precision,
    config: &DataParallelConfig,
) -> Vec<DpPrediction> {
    let comm = trace_comm(trace);
    let mut scratch = EvalScratch::new();
    predictor.evaluate_batch_times(plan, dests, precision, &mut scratch);
    (0..dests.len())
        .map(|i| compose(scratch.run_time_ms(i), plan.batch_size, &comm, config))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Device;
    use crate::predict::HybridPredictor;
    use crate::tracker::OperationTracker;

    fn setup(model: &str, batch: usize) -> (Trace, PredictedTrace) {
        let graph = crate::models::by_name(model, batch).unwrap();
        let trace = OperationTracker::new(Device::Rtx2070).track(&graph);
        let pred = HybridPredictor::wave_only().predict(&trace, Device::V100);
        (trace, pred)
    }

    #[test]
    fn single_gpu_has_no_communication() {
        let (trace, pred) = setup("resnet50", 32);
        let dp = predict_data_parallel(
            &trace,
            &pred,
            &DataParallelConfig {
                world: 1,
                ..Default::default()
            },
        );
        assert_eq!(dp.allreduce_ms, 0.0);
        assert!((dp.iter_ms - dp.compute_ms).abs() < 1e-12);
        assert!((dp.efficiency - 1.0).abs() < 1e-9);
    }

    #[test]
    fn efficiency_decreases_with_world_size() {
        let (trace, pred) = setup("resnet50", 32);
        let mut prev = 1.01;
        for world in [1, 2, 4, 8] {
            let dp = predict_data_parallel(
                &trace,
                &pred,
                &DataParallelConfig {
                    world,
                    interconnect: Interconnect::Pcie3,
                    overlap: 0.7,
                },
            );
            assert!(dp.efficiency <= prev + 1e-9, "world {world}: {}", dp.efficiency);
            assert!(dp.efficiency > 0.2);
            prev = dp.efficiency;
        }
    }

    #[test]
    fn nvlink_beats_pcie() {
        let (trace, pred) = setup("gnmt", 32); // 160M params: comm heavy
        let mk = |ic| {
            predict_data_parallel(
                &trace,
                &pred,
                &DataParallelConfig {
                    world: 4,
                    interconnect: ic,
                    overlap: 0.7,
                },
            )
        };
        let nvlink = mk(Interconnect::NvLink);
        let pcie = mk(Interconnect::Pcie3);
        let eth = mk(Interconnect::Ethernet25G);
        assert!(nvlink.iter_ms < pcie.iter_ms);
        assert!(pcie.iter_ms < eth.iter_ms);
    }

    #[test]
    fn overlap_hides_communication() {
        let (trace, pred) = setup("gnmt", 32);
        let mk = |overlap| {
            predict_data_parallel(
                &trace,
                &pred,
                &DataParallelConfig {
                    world: 4,
                    interconnect: Interconnect::Pcie3,
                    overlap,
                },
            )
        };
        assert!(mk(1.0).iter_ms <= mk(0.0).iter_ms);
        assert!(mk(0.0).exposed_ms >= mk(0.5).exposed_ms);
    }

    #[test]
    fn sweep_matches_per_destination_composition() {
        let graph = crate::models::by_name("resnet50", 32).unwrap();
        let trace = OperationTracker::new(Device::Rtx2070).track(&graph);
        let p = HybridPredictor::wave_only();
        let plan = AnalyzedPlan::build(&trace, &p.metrics_policy);
        // Duplicated destination exercises the dedup/re-expand path.
        let dests = [Device::V100, Device::T4, Device::V100];
        let config = DataParallelConfig {
            world: 4,
            ..Default::default()
        };
        let sweep = data_parallel_sweep(
            &p,
            &plan,
            &trace,
            &dests,
            crate::lowering::Precision::Fp32,
            &config,
        );
        assert_eq!(sweep.len(), dests.len());
        for (dp, &dest) in sweep.iter().zip(&dests) {
            let pred = p.evaluate(&plan, dest);
            let scalar = predict_data_parallel(&trace, &pred, &config);
            assert_eq!(dp.compute_ms.to_bits(), scalar.compute_ms.to_bits(), "{dest}");
            assert_eq!(dp.iter_ms.to_bits(), scalar.iter_ms.to_bits(), "{dest}");
            assert_eq!(dp.throughput.to_bits(), scalar.throughput.to_bits(), "{dest}");
            assert_eq!(dp.efficiency.to_bits(), scalar.efficiency.to_bits(), "{dest}");
        }
    }

    #[test]
    fn ring_formula_matches_hand_computation() {
        // 4 GPUs, 1 GB, 12 GB/s: 2·3/4·(1/12) s = 125 ms + 6·0.01 latency.
        let ms = ring_allreduce_ms(1e9, 4, Interconnect::Pcie3);
        assert!((ms - (125.0 + 0.06)).abs() < 0.5, "{ms}");
    }

    #[test]
    fn seed_links_are_bit_identical_to_the_legacy_constants() {
        // The exact constants the enum hard-coded before the comm link
        // registry existed; this pins the delegation bit-for-bit.
        let seeds = [
            (Interconnect::Pcie3, 12.0, 0.01),
            (Interconnect::Pcie4, 24.0, 0.01),
            (Interconnect::NvLink, 130.0, 0.01),
            (Interconnect::Ethernet25G, 2.9, 0.03),
        ];
        for (ic, gbps, lat) in seeds {
            assert_eq!(ic.bandwidth_bytes().to_bits(), (gbps * 1e9).to_bits(), "{ic:?}");
            assert_eq!(ic.step_latency_ms().to_bits(), lat.to_bits(), "{ic:?}");
            assert_eq!(ring_allreduce_ms(1e9, 1, ic), 0.0);
            for world in [2usize, 4, 8, 64] {
                for bytes in [1e6, 1e8, 4.08e9] {
                    let n = world as f64;
                    let legacy = 2.0 * (n - 1.0) / n * bytes / (gbps * 1e9) * 1e3
                        + 2.0 * (n - 1.0) * lat;
                    assert_eq!(
                        ring_allreduce_ms(bytes, world, ic).to_bits(),
                        legacy.to_bits(),
                        "{ic:?} world {world} bytes {bytes}"
                    );
                    // The registry-link route computes the same number.
                    let link = ic.link().unwrap();
                    assert_eq!(
                        crate::comm::ring_allreduce_ms(bytes, world, link).to_bits(),
                        legacy.to_bits()
                    );
                    assert_eq!(
                        ring_allreduce_ms(bytes, world, Interconnect::from(link)).to_bits(),
                        legacy.to_bits()
                    );
                }
            }
        }
        // Custom bandwidths keep the old arithmetic and default latency.
        let c = Interconnect::Custom(42.0);
        assert_eq!(c.bandwidth_bytes().to_bits(), (42.0f64 * 1e9).to_bits());
        assert_eq!(c.step_latency_ms(), 0.01);
        assert_eq!(c.link(), None);
    }

    #[test]
    fn throughput_scales_sublinearly_but_positively() {
        let (trace, pred) = setup("resnet50", 32);
        let one = predict_data_parallel(&trace, &pred, &DataParallelConfig { world: 1, ..Default::default() });
        let four = predict_data_parallel(&trace, &pred, &DataParallelConfig { world: 4, ..Default::default() });
        assert!(four.throughput > one.throughput, "more GPUs must help");
        assert!(four.throughput <= 4.0 * one.throughput * (1.0 + 1e-9), "but not superlinearly");
    }
}
