//! Wave scaling (paper §3.3).
//!
//! A kernel's computation executes in *waves* of `W_i` thread blocks
//! (`W_i` = resident blocks across the chip, from the occupancy
//! calculator). Wave scaling transfers a kernel's measured time from the
//! origin GPU `o` to the destination GPU `d` by scaling with ratios of
//! memory bandwidth `D`, wave size `W`, and clock `C`, blended by the
//! kernel's memory-bandwidth-boundedness γ ∈ [0, 1]:
//!
//! Eq. 1:  T_d = ⌈B/W_d⌉ · (D_o/D_d · W_d/W_o)^γ · (C_o/C_d)^(1−γ) · ⌈B/W_o⌉⁻¹ · T_o
//! Eq. 2:  T_d = (D_o/D_d)^γ · (W_o/W_d)^(1−γ) · (C_o/C_d)^(1−γ) · T_o
//!
//! Habitat uses Eq. 2 (the large-wave-count limit of Eq. 1) by default,
//! because real kernels almost always have many waves.

use crate::device::{GpuSpec, LaunchConfig};
use crate::engine::memo::WaveTable;

/// The hardware ratios wave scaling consumes, for one kernel.
#[derive(Debug, Clone, Copy)]
pub struct WaveRatios {
    /// Achieved memory bandwidth ratio `D_o / D_d`.
    pub bw: f64,
    /// Wave-size ratio `W_o / W_d`.
    pub wave: f64,
    /// Clock ratio `C_o / C_d`.
    pub clock: f64,
    /// Thread blocks in the kernel (`B`).
    pub blocks: u64,
    /// Wave sizes on each device.
    pub w_origin: u64,
    pub w_dest: u64,
}

/// Compute the ratios for one kernel launch between two GPUs. Wave sizes
/// come from the process-wide memo table shared with the simulator
/// ([`WaveTable`]), so repeated launches — and multi-destination fan-out
/// over the same trace — never recompute the occupancy calculation.
pub fn ratios(launch: &LaunchConfig, origin: &GpuSpec, dest: &GpuSpec) -> WaveRatios {
    let table = WaveTable::global();
    let w_origin = table.wave_size(origin, launch).max(1);
    let w_dest = table.wave_size(dest, launch).max(1);
    WaveRatios {
        bw: origin.achieved_bw_bytes() / dest.achieved_bw_bytes(),
        wave: w_origin as f64 / w_dest as f64,
        clock: origin.boost_clock_mhz / dest.boost_clock_mhz,
        blocks: launch.grid_blocks.max(1),
        w_origin,
        w_dest,
    }
}

/// Assemble the ratios from already-resolved wave sizes — the lock-free
/// path used by the plan evaluator ([`crate::plan::AnalyzedPlan`] batches
/// every wave-size lookup at build time). `bw` and `clock` are the
/// origin/destination ratios `D_o/D_d` and `C_o/C_d`; the caller is
/// responsible for having clamped `w_origin`/`w_dest`/`blocks` to ≥ 1,
/// exactly as [`ratios`] does.
pub fn ratios_from_parts(bw: f64, clock: f64, blocks: u64, w_origin: u64, w_dest: u64) -> WaveRatios {
    WaveRatios {
        bw,
        wave: w_origin as f64 / w_dest as f64,
        clock,
        blocks,
        w_origin,
        w_dest,
    }
}

/// Eq. 2 — the production path.
pub fn scale_eq2(time_origin_ms: f64, r: &WaveRatios, gamma: f64) -> f64 {
    debug_assert!((0.0..=1.0).contains(&gamma));
    scale_eq2_parts(time_origin_ms, r.bw, r.wave, r.clock, gamma)
}

/// Eq. 2 from already-unpacked ratio parts — the branch-free form the
/// kernel-major batched evaluator inlines in its `dests × kernels`
/// inner loop. [`scale_eq2`] delegates here, so the scalar and batched
/// paths share one expression and cannot drift bit-wise.
#[inline(always)]
pub fn scale_eq2_parts(time_origin_ms: f64, bw: f64, wave: f64, clock: f64, gamma: f64) -> f64 {
    time_origin_ms * bw.powf(gamma) * (wave * clock).powf(1.0 - gamma)
}

/// Eq. 1 — exact wave counts, for kernels with few waves.
pub fn scale_eq1(time_origin_ms: f64, r: &WaveRatios, gamma: f64) -> f64 {
    debug_assert!((0.0..=1.0).contains(&gamma));
    let waves_o = r.blocks.div_ceil(r.w_origin) as f64;
    let waves_d = r.blocks.div_ceil(r.w_dest) as f64;
    scale_eq1_parts(time_origin_ms, waves_o, waves_d, r.bw, r.wave, r.clock, gamma)
}

/// Eq. 1 from already-unpacked parts (`waves_o`/`waves_d` are the
/// origin/destination wave *counts* `⌈B/W⌉`, precomputed per kernel and
/// per `(kernel, dest)` by the batched evaluator). Shared with
/// [`scale_eq1`] so both paths stay bit-identical.
#[inline(always)]
pub fn scale_eq1_parts(
    time_origin_ms: f64,
    waves_o: f64,
    waves_d: f64,
    bw: f64,
    wave: f64,
    clock: f64,
    gamma: f64,
) -> f64 {
    time_origin_ms * waves_d * (bw / wave).powf(gamma) * clock.powf(1.0 - gamma) / waves_o
}

/// Fill the two `powf` factor lanes of Eq. 2 for one kernel row of the
/// batched sweep: `p1[i] = bw[i]^γᵢ`, `p2[i] = wc[i]^(1−γᵢ)` where
/// `wc[i]` is the precomputed exact product `wave[i] · clock[i]`.
/// These are the *same* two `powf` calls [`scale_eq2_parts`] makes, so
/// `(t · p1[i]) · p2[i]` (the [`crate::util::simdf64::eq2_add`] lane
/// step) reproduces the scalar expression bit-for-bit. `powf` stays a
/// scalar per-lane libm call on every backend — only the exact IEEE
/// multiplies and adds around it are vectorized.
#[inline]
pub fn eq2_factor_lanes(p1: &mut [f64], p2: &mut [f64], bw: &[f64], wc: &[f64], gamma: &[f64]) {
    for i in 0..p1.len() {
        let g = gamma[i];
        p1[i] = bw[i].powf(g);
        p2[i] = wc[i].powf(1.0 - g);
    }
}

/// Fill the two `powf` factor lanes of Eq. 1 for one kernel row:
/// `p1[i] = ratio[i]^γᵢ` where `ratio[i]` is the precomputed exact
/// quotient `bw[i] / wave[i]`, and `p2[i] = clock[i]^(1−γᵢ)`. The same
/// two `powf` calls as [`scale_eq1_parts`], so the
/// [`crate::util::simdf64::eq1_add`] lane step
/// `(((t · wd[i]) · p1[i]) · p2[i]) / wo` matches the scalar expression
/// bit-for-bit.
#[inline]
pub fn eq1_factor_lanes(
    p1: &mut [f64],
    p2: &mut [f64],
    ratio: &[f64],
    clock: &[f64],
    gamma: &[f64],
) {
    for i in 0..p1.len() {
        let g = gamma[i];
        p1[i] = ratio[i].powf(g);
        p2[i] = clock[i].powf(1.0 - g);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Device;

    fn launch(blocks: u64) -> LaunchConfig {
        LaunchConfig::new(blocks, 256, 32, 0)
    }

    #[test]
    fn identity_when_origin_is_dest() {
        let v100 = Device::V100.spec();
        let r = ratios(&launch(10_000), v100, v100);
        for gamma in [0.0, 0.3, 1.0] {
            assert!((scale_eq2(5.0, &r, gamma) - 5.0).abs() < 1e-12);
            assert!((scale_eq1(5.0, &r, gamma) - 5.0).abs() < 1e-12);
        }
    }

    #[test]
    fn memory_bound_scales_by_bandwidth() {
        // γ=1: pure bandwidth ratio.
        let t4 = Device::T4.spec();
        let v100 = Device::V100.spec();
        let r = ratios(&launch(100_000), t4, v100);
        let scaled = scale_eq2(10.0, &r, 1.0);
        let expected = 10.0 * t4.achieved_bw_bytes() / v100.achieved_bw_bytes();
        assert!((scaled - expected).abs() < 1e-9);
        assert!(scaled < 10.0, "V100 has more bandwidth than T4");
    }

    #[test]
    fn compute_bound_scales_by_wave_and_clock() {
        // γ=0: (W_o/W_d)·(C_o/C_d).
        let p4000 = Device::P4000.spec();
        let v100 = Device::V100.spec();
        let l = launch(100_000);
        let r = ratios(&l, p4000, v100);
        let scaled = scale_eq2(10.0, &r, 0.0);
        assert!(scaled < 10.0, "V100 is a much bigger chip: {scaled}");
        assert!((scale_eq1(10.0, &r, 0.0) / scaled - 1.0).abs() < 0.05);
    }

    #[test]
    fn eq1_approaches_eq2_for_many_waves() {
        let o = Device::Rtx2070.spec();
        let d = Device::P100.spec();
        let l = launch(1_000_000);
        let r = ratios(&l, o, d);
        let a = scale_eq1(3.0, &r, 0.6);
        let b = scale_eq2(3.0, &r, 0.6);
        assert!((a / b - 1.0).abs() < 0.02, "eq1={a} eq2={b}");
    }

    #[test]
    fn eq1_captures_tail_effects_for_few_waves() {
        // One wave on the origin, forced two on a smaller destination.
        let o = Device::V100.spec();
        let d = Device::P4000.spec();
        let l = launch(600); // < one V100 wave (640), > one P4000 wave (112)
        let r = ratios(&l, o, d);
        let eq1 = scale_eq1(1.0, &r, 0.0);
        let eq2 = scale_eq2(1.0, &r, 0.0);
        // Eq1 quantizes to whole waves; must differ from the smooth Eq2.
        assert!((eq1 / eq2 - 1.0).abs() > 0.01);
    }

    #[test]
    fn factor_lanes_reassemble_the_scalar_expressions_bitwise() {
        // The batched sweep's factorized form — powf lanes + exact
        // mul/add — must reproduce scale_eq{1,2}_parts bit-for-bit.
        let bw = [0.8, 1.6, 0.5];
        let wave = [1.3, 0.7, 2.5];
        let clock = [0.95, 1.2, 0.85];
        let gamma = [0.0, 0.4, 1.0];
        let (t, wo) = (1.75, 3.0);
        let wd = [5.0, 2.0, 9.0];
        let n = bw.len();

        let wc: Vec<f64> = (0..n).map(|i| wave[i] * clock[i]).collect();
        let (mut p1, mut p2) = (vec![0.0; n], vec![0.0; n]);
        eq2_factor_lanes(&mut p1, &mut p2, &bw, &wc, &gamma);
        for i in 0..n {
            let lane = (t * p1[i]) * p2[i];
            let scalar = scale_eq2_parts(t, bw[i], wave[i], clock[i], gamma[i]);
            assert_eq!(lane.to_bits(), scalar.to_bits(), "eq2 lane {i}");
        }

        let ratio: Vec<f64> = (0..n).map(|i| bw[i] / wave[i]).collect();
        eq1_factor_lanes(&mut p1, &mut p2, &ratio, &clock, &gamma);
        for i in 0..n {
            let lane = (((t * wd[i]) * p1[i]) * p2[i]) / wo;
            let scalar = scale_eq1_parts(t, wo, wd[i], bw[i], wave[i], clock[i], gamma[i]);
            assert_eq!(lane.to_bits(), scalar.to_bits(), "eq1 lane {i}");
        }
    }

    #[test]
    fn gamma_interpolates_monotonically() {
        let o = Device::P4000.spec();
        let d = Device::V100.spec();
        let r = ratios(&launch(50_000), o, d);
        let lo = scale_eq2(10.0, &r, 0.0);
        let mid = scale_eq2(10.0, &r, 0.5);
        let hi = scale_eq2(10.0, &r, 1.0);
        let (min, max) = (lo.min(hi), lo.max(hi));
        assert!(mid >= min && mid <= max);
    }
}
