//! The full Habitat predictor: wave scaling + MLPs (paper §3.2).
//!
//! For every operation in the origin trace:
//! * kernel-varying ops (conv2d, conv_transpose2d, lstm, bmm, linear) are
//!   predicted by the pre-trained MLP for their op family, queried through
//!   the pluggable [`MlpBackend`] (the production backend executes
//!   AOT-compiled JAX MLPs via PJRT — see [`crate::runtime`]);
//! * every other op is predicted by wave scaling each of its measured
//!   kernels with a roofline-selected γ.
//!
//! If no MLP backend is configured (or an artifact is missing) the
//! predictor degrades gracefully to wave scaling for the affected ops and
//! counts the fallbacks.

use std::sync::Arc;


use crate::device::Device;
use crate::opgraph::MlpOp;
use crate::predict::roofline::{self, MetricsPolicy};
use crate::predict::wave;
use crate::tracker::Trace;
use crate::util::simdf64;
use crate::Result;

/// How one op's destination time was obtained.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PredictionMethod {
    WaveScaling,
    Mlp,
}

/// A batched MLP inference backend. `features` rows are the op-specific
/// feature vectors (see [`crate::opgraph::Op::mlp_features`]); the backend
/// appends the destination GPU's hardware features and returns the
/// predicted forward+backward time in ms for each row.
pub trait MlpBackend: Send + Sync {
    fn predict_batch(&self, op: MlpOp, features: &[Vec<f64>], dest: Device) -> Result<Vec<f64>>;

    /// Predict the same feature rows against several destinations at
    /// once, returning one [`MlpBackend::predict_batch`]-shaped result
    /// per destination, in order. The default loops per destination —
    /// bit-identical to N scalar calls, so existing backends need no
    /// changes. Backends that coalesce across requests (the MLP service
    /// thread) override this to pipeline every destination into one
    /// batched execution instead of N round-trips.
    fn predict_batch_multi(
        &self,
        op: MlpOp,
        features: &[Vec<f64>],
        dests: &[Device],
    ) -> Vec<Result<Vec<f64>>> {
        dests
            .iter()
            .map(|&d| self.predict_batch(op, features, d))
            .collect()
    }
}

/// One predicted operation on the destination GPU.
#[derive(Debug, Clone)]
pub struct PredictedOp {
    pub index: usize,
    pub name: String,
    pub short_name: String,
    pub time_ms: f64,
    pub method: PredictionMethod,
}

/// A full predicted training iteration on the destination GPU.
#[derive(Debug, Clone)]
pub struct PredictedTrace {
    pub model: String,
    pub batch_size: usize,
    pub origin: Device,
    pub dest: Device,
    pub ops: Vec<PredictedOp>,
    /// Kernel-varying ops that wanted an MLP but fell back to wave scaling.
    pub mlp_fallbacks: usize,
}

impl PredictedTrace {
    /// Predicted iteration execution time, ms (the paper's headline
    /// quantity; Listing 1's `run_time_ms`).
    pub fn run_time_ms(&self) -> f64 {
        self.ops.iter().map(|o| o.time_ms).sum()
    }

    /// Predicted training throughput, samples/s (§5.1 Metrics).
    pub fn throughput(&self) -> f64 {
        self.batch_size as f64 / (self.run_time_ms() / 1e3)
    }

    /// Share of predicted time attributed to MLP predictions (§5.2.3).
    pub fn mlp_time_fraction(&self) -> f64 {
        let total = self.run_time_ms();
        if total == 0.0 {
            return 0.0;
        }
        self.ops
            .iter()
            .filter(|o| o.method == PredictionMethod::Mlp)
            .map(|o| o.time_ms)
            .sum::<f64>()
            / total
    }
}

/// The hybrid predictor.
#[derive(Clone)]
pub struct HybridPredictor {
    mlp: Option<Arc<dyn MlpBackend>>,
    /// Metrics availability policy for γ selection.
    pub metrics_policy: MetricsPolicy,
    /// Use Eq. 1 (exact wave counts) instead of Eq. 2. The paper ships
    /// Eq. 2; Eq. 1 is kept for the ablation bench.
    pub use_eq1: bool,
}

impl HybridPredictor {
    /// Wave scaling for *all* ops (no MLP artifacts required).
    pub fn wave_only() -> Self {
        HybridPredictor {
            mlp: None,
            metrics_policy: MetricsPolicy::default(),
            use_eq1: false,
        }
    }

    /// The paper's full configuration: MLPs for kernel-varying ops.
    pub fn with_mlp(backend: Arc<dyn MlpBackend>) -> Self {
        HybridPredictor {
            mlp: Some(backend),
            metrics_policy: MetricsPolicy::default(),
            use_eq1: false,
        }
    }

    pub fn with_metrics_policy(mut self, policy: MetricsPolicy) -> Self {
        self.metrics_policy = policy;
        self
    }

    pub fn with_eq1(mut self, use_eq1: bool) -> Self {
        self.use_eq1 = use_eq1;
        self
    }

    pub fn has_mlp(&self) -> bool {
        self.mlp.is_some()
    }

    /// Wave-scale every kernel of one tracked op.
    fn wave_scale_op(
        &self,
        op: &crate::tracker::TrackedOp,
        origin: &crate::device::GpuSpec,
        dest: &crate::device::GpuSpec,
        profiled: Option<&std::collections::HashSet<u64>>,
    ) -> f64 {
        op.fwd
            .iter()
            .chain(&op.bwd)
            .map(|m| {
                let has_metrics =
                    profiled.map_or(true, |set| set.contains(&roofline::cache_key(&m.kernel)));
                // γ = 1 fallback when the kernel was never profiled (§4.2).
                let g = if has_metrics {
                    roofline::gamma(m.kernel.arith_intensity(), dest)
                } else {
                    1.0
                };
                let r = wave::ratios(&m.kernel.launch, origin, dest);
                if self.use_eq1 {
                    wave::scale_eq1(m.time_ms, &r, g)
                } else {
                    wave::scale_eq2(m.time_ms, &r, g)
                }
            })
            .sum()
    }

    /// Predict the trace's iteration time on `dest`.
    pub fn predict(&self, trace: &Trace, dest: Device) -> PredictedTrace {
        let profiled = self.metrics_policy.profiled_kernels(trace);
        self.predict_with_profiled(trace, dest, profiled.as_ref())
    }

    /// [`HybridPredictor::predict`] with the metrics-availability set
    /// resolved by the caller. The engine's multi-destination fan-out
    /// resolves the set once per trace and shares it across every
    /// destination (`None` means every kernel has metrics, matching
    /// [`MetricsPolicy::profiled_kernels`]).
    pub fn predict_with_profiled(
        &self,
        trace: &Trace,
        dest: Device,
        profiled: Option<&std::collections::HashSet<u64>>,
    ) -> PredictedTrace {
        let origin_spec = trace.origin.spec();
        let dest_spec = dest.spec();

        // Pass 1: wave-scale everything; collect MLP work items.
        let mut ops: Vec<PredictedOp> = Vec::with_capacity(trace.ops.len());
        let mut mlp_items: std::collections::BTreeMap<MlpOp, (Vec<usize>, Vec<Vec<f64>>)> =
            Default::default();
        for (i, t) in trace.ops.iter().enumerate() {
            let wave_ms = self.wave_scale_op(t, origin_spec, dest_spec, profiled);
            ops.push(PredictedOp {
                index: t.index,
                name: t.op.name.clone(),
                short_name: t.op.kind.short_name().to_string(),
                time_ms: wave_ms,
                method: PredictionMethod::WaveScaling,
            });
            if self.mlp.is_some() {
                if let Some((mlp_op, features)) = t.op.mlp_features() {
                    let entry = mlp_items.entry(mlp_op).or_default();
                    entry.0.push(i);
                    entry.1.push(features);
                }
            }
        }

        // Pass 2: batched MLP predictions overwrite kernel-varying ops.
        let mut fallbacks = 0;
        if let Some(backend) = &self.mlp {
            for (mlp_op, (indices, features)) in mlp_items {
                match backend.predict_batch(mlp_op, &features, dest) {
                    Ok(times) if times.len() == indices.len() => {
                        for (slot, ms) in indices.into_iter().zip(times) {
                            // Defensive: an MLP can extrapolate badly on
                            // out-of-range configs; never accept a
                            // non-positive time.
                            if ms.is_finite() && ms > 0.0 {
                                ops[slot].time_ms = ms;
                                ops[slot].method = PredictionMethod::Mlp;
                            } else {
                                fallbacks += 1;
                            }
                        }
                    }
                    _ => fallbacks += indices.len(),
                }
            }
        }

        PredictedTrace {
            model: trace.model.clone(),
            batch_size: trace.batch_size,
            origin: trace.origin,
            dest,
            ops,
            mlp_fallbacks: fallbacks,
        }
    }

    /// Thin per-destination evaluator over a compiled
    /// [`crate::plan::AnalyzedPlan`]: pure scaling arithmetic over the
    /// plan's flat arrays — no wave-table lock, no hashing, no feature
    /// recomputation. Bit-identical to [`HybridPredictor::predict`] on
    /// the trace the plan was built from (provided the plan was built
    /// with this predictor's metrics policy; γ selection is baked into
    /// the plan at build time).
    pub fn evaluate(&self, plan: &crate::plan::AnalyzedPlan, dest: Device) -> PredictedTrace {
        let origin_spec = plan.origin.spec();
        let dest_spec = dest.spec();
        let bw = origin_spec.achieved_bw_bytes() / dest_spec.achieved_bw_bytes();
        let clock = origin_spec.boost_clock_mhz / dest_spec.boost_clock_mhz;
        // Dense borrows for devices in the plan's registry snapshot;
        // computed once here for devices registered after it.
        let lanes = plan.device_lanes(dest);

        // Pass 1: wave-scale every op from the precomputed arrays.
        let mut ops = plan.blank_ops();
        for (slot, op) in ops.iter_mut().enumerate() {
            let mut wave_ms = 0.0;
            for k in plan.kernel_range(slot) {
                let g = lanes.gamma(k);
                let r = wave::ratios_from_parts(
                    bw,
                    clock,
                    plan.kernel_blocks(k),
                    plan.wave_origin(k),
                    lanes.wave_dest(k),
                );
                wave_ms += if self.use_eq1 {
                    wave::scale_eq1(plan.kernel_time_ms(k), &r, g)
                } else {
                    wave::scale_eq2(plan.kernel_time_ms(k), &r, g)
                };
            }
            op.time_ms = wave_ms;
        }

        // Pass 2: batched MLP predictions overwrite kernel-varying ops,
        // from the plan's prebuilt feature rows.
        let mut fallbacks = 0;
        if let Some(backend) = &self.mlp {
            for group in plan.mlp_groups() {
                match backend.predict_batch(group.op, &group.features, dest) {
                    Ok(times) if times.len() == group.slots.len() => {
                        for (&slot, ms) in group.slots.iter().zip(times) {
                            if ms.is_finite() && ms > 0.0 {
                                ops[slot].time_ms = ms;
                                ops[slot].method = PredictionMethod::Mlp;
                            } else {
                                fallbacks += 1;
                            }
                        }
                    }
                    _ => fallbacks += group.slots.len(),
                }
            }
        }

        PredictedTrace {
            model: plan.model.clone(),
            batch_size: plan.batch_size,
            origin: plan.origin,
            dest,
            ops,
            mlp_fallbacks: fallbacks,
        }
    }

    /// [`HybridPredictor::evaluate`] with the requested prediction
    /// precision: FP32 directly, or the precomputed Daydream AMP
    /// transformation composed on top (§6.1.2).
    pub fn evaluate_with_precision(
        &self,
        plan: &crate::plan::AnalyzedPlan,
        dest: Device,
        precision: crate::lowering::Precision,
    ) -> PredictedTrace {
        let mut pred = self.evaluate(plan, dest);
        if precision == crate::lowering::Precision::Amp {
            plan.apply_amp(&mut pred);
        }
        pred
    }

    /// Kernel-major batched evaluation: **one** pass over the plan's
    /// flat kernel arrays accumulates per-op times for every
    /// destination simultaneously, instead of re-walking the arrays
    /// once per destination. Duplicate destinations are deduped before
    /// the sweep and re-expanded to the caller's order in the result.
    /// Bit-identical to N [`HybridPredictor::evaluate_with_precision`]
    /// calls (pinned by the golden suite): the sweep accumulates in the
    /// same kernel order through the factorized form of the same
    /// [`wave::scale_eq2_parts`] / [`wave::scale_eq1_parts`] expressions
    /// the scalar path uses — its exact IEEE pieces run on the
    /// [`crate::util::simdf64`] lanes (AVX2 when available, scalar
    /// chunks otherwise, `HABITAT_SIMD=off` to force the latter), and
    /// both backends produce the same bits.
    pub fn evaluate_batch(
        &self,
        plan: &crate::plan::AnalyzedPlan,
        dests: &[Device],
        precision: crate::lowering::Precision,
    ) -> Vec<PredictedTrace> {
        let mut scratch = crate::plan::EvalScratch::new();
        self.evaluate_batch_with(plan, dests, precision, &mut scratch)
    }

    /// [`HybridPredictor::evaluate_batch`] with a caller-provided
    /// scratch arena (the engine pools one per worker thread, so
    /// steady-state sweeps reuse capacity instead of reallocating).
    pub fn evaluate_batch_with(
        &self,
        plan: &crate::plan::AnalyzedPlan,
        dests: &[Device],
        precision: crate::lowering::Precision,
        scratch: &mut crate::plan::EvalScratch,
    ) -> Vec<PredictedTrace> {
        self.evaluate_batch_times(plan, dests, precision, scratch);
        (0..dests.len()).map(|i| scratch.materialize(plan, i)).collect()
    }

    /// The allocation-free core of the batched path: run the sweep and
    /// leave the per-op times in `scratch`, without materializing
    /// [`PredictedTrace`]s. Consumers that only need aggregates — the
    /// cluster throughput matrix, distributed sweeps — query
    /// [`crate::plan::EvalScratch::run_time_ms`] /
    /// [`crate::plan::EvalScratch::throughput`] directly and skip the
    /// per-op `String` clones entirely. With a warm scratch and
    /// snapshot destinations, this performs **zero heap allocation**
    /// (pinned by `rust/tests/batched_alloc.rs`; MLP dispatch and
    /// post-snapshot computed lanes are the documented exceptions).
    pub fn evaluate_batch_times(
        &self,
        plan: &crate::plan::AnalyzedPlan,
        dests: &[Device],
        precision: crate::lowering::Precision,
        scratch: &mut crate::plan::EvalScratch,
    ) {
        scratch.begin(dests);
        plan.gather_lanes(self.use_eq1, scratch);
        let time = plan.kernel_times();

        // Phase 1: the wave-scaling sweep. Kernel-major: for each
        // kernel of each op, the innermost loop runs over the
        // lane-padded destination rows of the transposed matrices in
        // whole SIMD chunks. Per kernel row, the exact IEEE pieces of
        // the wave expression (`wave · clock` or `bw / wave`, the
        // multiply-accumulate around the factors) go through
        // `util::simdf64`; the two `powf` factors stay scalar per-lane
        // libm calls (`wave::eq{1,2}_factor_lanes`) on every backend, so
        // each lane computes exactly the `scale_eq{1,2}_parts`
        // expression in the same association order — bit-identical to
        // the scalar path with either backend selected.
        {
            let s = &mut *scratch;
            let sd = s.stride;
            let acc = &mut s.acc[..];
            let (gamma_t, wave_t) = (&s.gamma_t[..], &s.wave_t[..]);
            let (bw, clock) = (&s.bw[..], &s.clock[..]);
            let (wc, p1, p2) = (&mut s.wc[..], &mut s.p1[..], &mut s.p2[..]);
            if self.use_eq1 {
                let (waves_d_t, waves_o) = (&s.waves_d_t[..], &s.waves_o[..]);
                for o in 0..plan.n_ops() {
                    let row = &mut acc[o * sd..(o + 1) * sd];
                    for k in plan.kernel_range(o) {
                        let (t, wo) = (time[k], waves_o[k]);
                        let g_row = &gamma_t[k * sd..(k + 1) * sd];
                        let w_row = &wave_t[k * sd..(k + 1) * sd];
                        let wd_row = &waves_d_t[k * sd..(k + 1) * sd];
                        simdf64::div_into(wc, bw, w_row);
                        wave::eq1_factor_lanes(p1, p2, wc, clock, g_row);
                        simdf64::eq1_add(row, t, wd_row, p1, p2, wo);
                    }
                }
            } else {
                for o in 0..plan.n_ops() {
                    let row = &mut acc[o * sd..(o + 1) * sd];
                    for k in plan.kernel_range(o) {
                        let t = time[k];
                        let g_row = &gamma_t[k * sd..(k + 1) * sd];
                        let w_row = &wave_t[k * sd..(k + 1) * sd];
                        simdf64::mul_into(wc, w_row, clock);
                        wave::eq2_factor_lanes(p1, p2, bw, wc, g_row);
                        simdf64::eq2_add(row, t, p1, p2);
                    }
                }
            }
        }

        // Phase 2: MLP overrides — one multi-destination call per MLP
        // group (instead of one per group per destination), so a
        // coalescing backend turns the whole sweep into one padded
        // execution per op family.
        if let Some(backend) = &self.mlp {
            let s = &mut *scratch;
            let sd = s.stride;
            for group in plan.mlp_groups() {
                let results = backend.predict_batch_multi(group.op, &group.features, &s.dests);
                for (di, res) in results.into_iter().enumerate() {
                    match res {
                        Ok(times) if times.len() == group.slots.len() => {
                            for (&slot, ms) in group.slots.iter().zip(times) {
                                if ms.is_finite() && ms > 0.0 {
                                    s.acc[slot * sd + di] = ms;
                                    s.mlp_hit[slot * sd + di] = true;
                                } else {
                                    s.fallbacks[di] += 1;
                                }
                            }
                        }
                        _ => s.fallbacks[di] += group.slots.len(),
                    }
                }
            }
        }

        // Phase 3: AMP — multiply the precomputed Daydream factor rows
        // in, after MLP overrides, exactly as the scalar path composes
        // `evaluate` + `apply_amp`. The rows are staged into the
        // accumulator's transposed `[op * stride + dest]` layout (pad
        // columns keep their identity-1 fill), then each op row is one
        // exact vector multiply — the same per-element `acc *= factor`
        // the scalar path performs, so bits cannot change.
        if precision == crate::lowering::Precision::Amp {
            let s = &mut *scratch;
            let sd = s.stride;
            let (dests, lane_amp, amp_t, acc) =
                (&s.dests, &mut s.lane_amp, &mut s.amp_t, &mut s.acc);
            for (di, &dest) in dests.iter().enumerate() {
                let factors = plan.amp_row(dest, lane_amp);
                for o in 0..plan.n_ops() {
                    amp_t[o * sd + di] = factors[o];
                }
            }
            for o in 0..plan.n_ops() {
                simdf64::mul_assign(&mut acc[o * sd..(o + 1) * sd], &amp_t[o * sd..(o + 1) * sd]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Device;
    use crate::opgraph::{EwKind, Op, OpKind};
    use crate::tracker::OperationTracker;

    fn toy_trace(origin: Device) -> Trace {
        let mut g = crate::Graph::new("toy", 16);
        g.push(Op::new(
            "conv",
            OpKind::Conv2d {
                in_ch: 64,
                out_ch: 64,
                kernel: 3,
                stride: 1,
                padding: 1,
                bias: false,
            },
            vec![16, 64, 32, 32],
        ));
        g.push(Op::new("act", OpKind::Elementwise { kind: EwKind::Relu }, vec![16, 64, 32, 32]));
        OperationTracker::new(origin).track(&g)
    }

    #[test]
    fn wave_only_identity_on_same_device() {
        let trace = toy_trace(Device::V100);
        let pred = HybridPredictor::wave_only()
            .with_metrics_policy(MetricsPolicy::All)
            .predict(&trace, Device::V100);
        assert!(
            (pred.run_time_ms() / trace.run_time_ms() - 1.0).abs() < 1e-9,
            "same-device wave scaling must be the identity"
        );
    }

    #[test]
    fn all_methods_wave_without_backend() {
        let trace = toy_trace(Device::T4);
        let pred = HybridPredictor::wave_only().predict(&trace, Device::V100);
        assert!(pred.ops.iter().all(|o| o.method == PredictionMethod::WaveScaling));
        assert_eq!(pred.mlp_fallbacks, 0);
    }

    struct FixedBackend(f64);
    impl MlpBackend for FixedBackend {
        fn predict_batch(&self, _op: MlpOp, features: &[Vec<f64>], _dest: Device) -> Result<Vec<f64>> {
            Ok(vec![self.0; features.len()])
        }
    }

    #[test]
    fn mlp_overrides_kernel_varying_ops() {
        let trace = toy_trace(Device::T4);
        let backend = Arc::new(FixedBackend(42.0));
        let pred = HybridPredictor::with_mlp(backend).predict(&trace, Device::V100);
        let conv = pred.ops.iter().find(|o| o.short_name == "conv2d").unwrap();
        let relu = pred.ops.iter().find(|o| o.short_name == "relu").unwrap();
        assert_eq!(conv.method, PredictionMethod::Mlp);
        assert_eq!(conv.time_ms, 42.0);
        assert_eq!(relu.method, PredictionMethod::WaveScaling);
    }

    struct FailingBackend;
    impl MlpBackend for FailingBackend {
        fn predict_batch(&self, _op: MlpOp, _f: &[Vec<f64>], _d: Device) -> Result<Vec<f64>> {
            anyhow::bail!("artifact missing")
        }
    }

    #[test]
    fn backend_failure_falls_back_to_wave() {
        let trace = toy_trace(Device::T4);
        let pred = HybridPredictor::with_mlp(Arc::new(FailingBackend)).predict(&trace, Device::V100);
        assert_eq!(pred.mlp_fallbacks, 1);
        assert!(pred.ops.iter().all(|o| o.method == PredictionMethod::WaveScaling));
        assert!(pred.run_time_ms() > 0.0);
    }

    struct NegativeBackend;
    impl MlpBackend for NegativeBackend {
        fn predict_batch(&self, _op: MlpOp, f: &[Vec<f64>], _d: Device) -> Result<Vec<f64>> {
            Ok(vec![-1.0; f.len()])
        }
    }

    #[test]
    fn non_positive_mlp_output_rejected() {
        let trace = toy_trace(Device::T4);
        let pred = HybridPredictor::with_mlp(Arc::new(NegativeBackend)).predict(&trace, Device::V100);
        assert_eq!(pred.mlp_fallbacks, 1);
        assert!(pred.run_time_ms() > 0.0);
    }

    #[test]
    fn evaluate_matches_predict_bit_for_bit() {
        let trace = toy_trace(Device::T4);
        for policy in [
            MetricsPolicy::All,
            MetricsPolicy::None,
            MetricsPolicy::Percentile(99.5),
        ] {
            for use_eq1 in [false, true] {
                let p = HybridPredictor::wave_only()
                    .with_metrics_policy(policy.clone())
                    .with_eq1(use_eq1);
                let plan = crate::plan::AnalyzedPlan::build(&trace, &p.metrics_policy);
                for dest in crate::device::ALL_DEVICES {
                    let legacy = p.predict(&trace, dest);
                    let fast = p.evaluate(&plan, dest);
                    assert_eq!(legacy.ops.len(), fast.ops.len());
                    for (a, b) in legacy.ops.iter().zip(&fast.ops) {
                        assert_eq!(
                            a.time_ms.to_bits(),
                            b.time_ms.to_bits(),
                            "{dest} eq1={use_eq1} {policy:?} op {}: {} vs {}",
                            a.name,
                            a.time_ms,
                            b.time_ms
                        );
                        assert_eq!(a.method, b.method);
                        assert_eq!(a.name, b.name);
                        assert_eq!(a.index, b.index);
                    }
                }
            }
        }
    }

    #[test]
    fn evaluate_dispatches_mlp_from_prebuilt_features() {
        let trace = toy_trace(Device::T4);
        let p = HybridPredictor::with_mlp(Arc::new(FixedBackend(42.0)));
        let plan = crate::plan::AnalyzedPlan::build(&trace, &p.metrics_policy);
        let legacy = p.predict(&trace, Device::V100);
        let fast = p.evaluate(&plan, Device::V100);
        assert_eq!(fast.mlp_fallbacks, legacy.mlp_fallbacks);
        for (a, b) in legacy.ops.iter().zip(&fast.ops) {
            assert_eq!(a.time_ms.to_bits(), b.time_ms.to_bits());
            assert_eq!(a.method, b.method);
        }
        let conv = fast.ops.iter().find(|o| o.short_name == "conv2d").unwrap();
        assert_eq!(conv.method, PredictionMethod::Mlp);
        assert_eq!(conv.time_ms, 42.0);
    }

    #[test]
    fn evaluate_amp_matches_amp_transform_bit_for_bit() {
        let trace = toy_trace(Device::P4000);
        let p = HybridPredictor::wave_only();
        let plan = crate::plan::AnalyzedPlan::build(&trace, &p.metrics_policy);
        for dest in crate::device::ALL_DEVICES {
            let legacy =
                crate::predict::amp::amp_transform(&p.predict(&trace, dest), &trace);
            let fast = p.evaluate_with_precision(&plan, dest, crate::lowering::Precision::Amp);
            for (a, b) in legacy.ops.iter().zip(&fast.ops) {
                assert_eq!(
                    a.time_ms.to_bits(),
                    b.time_ms.to_bits(),
                    "{dest} AMP op {}",
                    a.name
                );
            }
        }
    }

    #[test]
    fn evaluate_batch_matches_scalar_bit_for_bit() {
        use crate::lowering::Precision;
        let trace = toy_trace(Device::T4);
        for policy in [MetricsPolicy::All, MetricsPolicy::None] {
            for use_eq1 in [false, true] {
                let p = HybridPredictor::wave_only()
                    .with_metrics_policy(policy.clone())
                    .with_eq1(use_eq1);
                let plan = crate::plan::AnalyzedPlan::build(&trace, &p.metrics_policy);
                let dests: Vec<Device> = crate::device::ALL_DEVICES.to_vec();
                for precision in [Precision::Fp32, Precision::Amp] {
                    let batch = p.evaluate_batch(&plan, &dests, precision);
                    assert_eq!(batch.len(), dests.len());
                    for (pred, &dest) in batch.iter().zip(&dests) {
                        let scalar = p.evaluate_with_precision(&plan, dest, precision);
                        assert_eq!(pred.dest, dest);
                        assert_eq!(pred.ops.len(), scalar.ops.len());
                        assert_eq!(pred.mlp_fallbacks, scalar.mlp_fallbacks);
                        for (a, b) in scalar.ops.iter().zip(&pred.ops) {
                            assert_eq!(
                                a.time_ms.to_bits(),
                                b.time_ms.to_bits(),
                                "{dest} eq1={use_eq1} {policy:?} {precision:?} op {}",
                                a.name
                            );
                            assert_eq!(a.method, b.method);
                            assert_eq!(a.name, b.name);
                            assert_eq!(a.index, b.index);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn evaluate_batch_dedups_duplicate_destinations() {
        use crate::lowering::Precision;
        let trace = toy_trace(Device::P4000);
        let p = HybridPredictor::wave_only();
        let plan = crate::plan::AnalyzedPlan::build(&trace, &p.metrics_policy);
        let dests = [
            Device::V100,
            Device::T4,
            Device::V100,
            Device::V100,
            Device::T4,
        ];
        let mut scratch = crate::plan::EvalScratch::new();
        let batch = p.evaluate_batch_with(&plan, &dests, Precision::Fp32, &mut scratch);
        assert_eq!(scratch.n_unique(), 2, "duplicates must be evaluated once");
        assert_eq!(batch.len(), dests.len(), "…but re-expanded to caller order");
        for (pred, &dest) in batch.iter().zip(&dests) {
            assert_eq!(pred.dest, dest);
            let scalar = p.evaluate(&plan, dest);
            assert_eq!(
                pred.run_time_ms().to_bits(),
                scalar.run_time_ms().to_bits(),
                "{dest}"
            );
        }
        // The scratch aggregates answer per *caller* index.
        for (i, pred) in batch.iter().enumerate() {
            assert_eq!(
                scratch.run_time_ms(i).to_bits(),
                pred.run_time_ms().to_bits()
            );
            assert_eq!(
                scratch.throughput(i, plan.batch_size).to_bits(),
                pred.throughput().to_bits()
            );
        }
    }

    #[test]
    fn evaluate_batch_dispatches_mlp_once_per_group() {
        use crate::lowering::Precision;
        use std::sync::atomic::{AtomicUsize, Ordering};

        struct CountingBackend(AtomicUsize);
        impl MlpBackend for CountingBackend {
            fn predict_batch(&self, _op: MlpOp, f: &[Vec<f64>], _d: Device) -> Result<Vec<f64>> {
                self.0.fetch_add(1, Ordering::Relaxed);
                Ok(vec![7.5; f.len()])
            }
        }

        let trace = toy_trace(Device::T4);
        let backend = Arc::new(CountingBackend(AtomicUsize::new(0)));
        let p = HybridPredictor::with_mlp(backend.clone());
        let plan = crate::plan::AnalyzedPlan::build(&trace, &p.metrics_policy);
        let dests = [Device::V100, Device::P4000, Device::V100];
        let batch = p.evaluate_batch(&plan, &dests, Precision::Fp32);
        // One group (conv2d) × two *unique* destinations through the
        // default predict_batch_multi loop.
        assert_eq!(backend.0.load(Ordering::Relaxed), 2);
        for (pred, &dest) in batch.iter().zip(&dests) {
            let scalar = p.evaluate(&plan, dest);
            for (a, b) in scalar.ops.iter().zip(&pred.ops) {
                assert_eq!(a.time_ms.to_bits(), b.time_ms.to_bits());
                assert_eq!(a.method, b.method);
            }
        }
    }

    #[test]
    fn evaluate_batch_counts_fallbacks_like_scalar() {
        use crate::lowering::Precision;
        let trace = toy_trace(Device::T4);
        for backend in [
            Arc::new(FailingBackend) as Arc<dyn MlpBackend>,
            Arc::new(NegativeBackend) as Arc<dyn MlpBackend>,
        ] {
            let p = HybridPredictor::with_mlp(backend);
            let plan = crate::plan::AnalyzedPlan::build(&trace, &p.metrics_policy);
            let batch = p.evaluate_batch(&plan, &crate::device::ALL_DEVICES, Precision::Fp32);
            for (pred, &dest) in batch.iter().zip(&crate::device::ALL_DEVICES) {
                let scalar = p.evaluate(&plan, dest);
                assert_eq!(pred.mlp_fallbacks, scalar.mlp_fallbacks);
                assert_eq!(
                    pred.run_time_ms().to_bits(),
                    scalar.run_time_ms().to_bits()
                );
            }
        }
    }

    #[test]
    fn throughput_definition() {
        let trace = toy_trace(Device::T4);
        let pred = HybridPredictor::wave_only().predict(&trace, Device::V100);
        let tp = pred.throughput();
        assert!((tp - 16.0 / (pred.run_time_ms() / 1e3)).abs() < 1e-9);
    }
}
