//! Execution-time prediction — the paper's core contribution.
//!
//! * [`wave`] — wave scaling (Eq. 1 / Eq. 2), for kernel-alike operations.
//! * [`roofline`] — γ selection from arithmetic intensity (Eq. 3, §4.2).
//! * [`hybrid`] — the full Habitat scheme: wave scaling for kernel-alike
//!   ops, pre-trained MLPs (through a pluggable [`MlpBackend`]) for
//!   kernel-varying ops.
//! * [`heuristic`] — the peak-FLOPS-ratio baseline the paper argues
//!   against (§2.3, Fig. 1).
//!
//! The hybrid predictor has three interchangeable paths: the legacy
//! trace-walking [`HybridPredictor::predict`] (kept as the reference
//! implementation), the plan-based [`HybridPredictor::evaluate`] (a
//! thin per-destination loop over a compiled
//! [`crate::plan::AnalyzedPlan`]), and the kernel-major
//! [`HybridPredictor::evaluate_batch`], which produces *every*
//! destination of a fan-out from one pass over the plan's flat kernel
//! arrays. All three are bit-identical; the engine's fan-out and the
//! cluster/distributed sweeps use the batched route.
//! * [`amp`] — mixed-precision prediction à la Daydream (§6.1.2).
//! * [`extrapolate`] — batch-size extrapolation (§6.1.3).

pub mod amp;
pub mod distributed;
pub mod extrapolate;
pub mod heuristic;
pub mod hybrid;
pub mod roofline;
pub mod wave;

pub use hybrid::{HybridPredictor, MlpBackend, PredictedOp, PredictedTrace, PredictionMethod};
pub use roofline::MetricsPolicy;
