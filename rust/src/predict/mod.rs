//! Execution-time prediction — the paper's core contribution.
//!
//! * [`wave`] — wave scaling (Eq. 1 / Eq. 2), for kernel-alike operations.
//! * [`roofline`] — γ selection from arithmetic intensity (Eq. 3, §4.2).
//! * [`hybrid`] — the full Habitat scheme: wave scaling for kernel-alike
//!   ops, pre-trained MLPs (through a pluggable [`MlpBackend`]) for
//!   kernel-varying ops.
//! * [`heuristic`] — the peak-FLOPS-ratio baseline the paper argues
//!   against (§2.3, Fig. 1).
//!
//! The hybrid predictor has two interchangeable paths: the legacy
//! trace-walking [`HybridPredictor::predict`] (kept as the reference
//! implementation) and the plan-based [`HybridPredictor::evaluate`],
//! a thin per-destination loop over a compiled
//! [`crate::plan::AnalyzedPlan`]. The two are bit-identical; the engine
//! and every fan-out path use the plan route.
//! * [`amp`] — mixed-precision prediction à la Daydream (§6.1.2).
//! * [`extrapolate`] — batch-size extrapolation (§6.1.3).

pub mod amp;
pub mod distributed;
pub mod extrapolate;
pub mod heuristic;
pub mod hybrid;
pub mod roofline;
pub mod wave;

pub use hybrid::{HybridPredictor, MlpBackend, PredictedOp, PredictedTrace, PredictionMethod};
pub use roofline::MetricsPolicy;
