//! The peak-FLOPS-ratio heuristic baseline (paper §2.3, Fig. 1).
//!
//! "Common wisdom" scaling: multiply the measured iteration time by the
//! ratio of the two GPUs' peak FLOP/s. The paper shows this heuristic is
//! off by 42.5–64.9% on DCGAN; the Fig. 1 experiment regenerates that
//! comparison against Habitat.

use crate::device::Device;
use crate::tracker::Trace;

/// Predict the destination iteration time as
/// `T_o × (peak_o / peak_d)`.
pub fn flops_ratio_prediction(trace: &Trace, dest: Device) -> f64 {
    let origin = trace.origin.spec();
    let d = dest.spec();
    trace.run_time_ms() * origin.peak_fp32_tflops / d.peak_fp32_tflops
}

/// Variant using the CUDA-core-count ratio (another folk heuristic).
pub fn core_ratio_prediction(trace: &Trace, dest: Device) -> f64 {
    let origin = trace.origin.spec();
    let d = dest.spec();
    trace.run_time_ms() * origin.cuda_cores as f64 / d.cuda_cores as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opgraph::{EwKind, Op, OpKind};
    use crate::tracker::OperationTracker;

    fn trace() -> Trace {
        let mut g = crate::Graph::new("toy", 8);
        g.push(Op::new("a", OpKind::Elementwise { kind: EwKind::Relu }, vec![1 << 20]));
        OperationTracker::new(Device::T4).track(&g)
    }

    #[test]
    fn identity_on_same_device() {
        let t = trace();
        assert!((flops_ratio_prediction(&t, Device::T4) - t.run_time_ms()).abs() < 1e-12);
        assert!((core_ratio_prediction(&t, Device::T4) - t.run_time_ms()).abs() < 1e-12);
    }

    #[test]
    fn faster_peak_means_smaller_prediction() {
        let t = trace();
        assert!(flops_ratio_prediction(&t, Device::V100) < t.run_time_ms());
        assert!(flops_ratio_prediction(&t, Device::P4000) > t.run_time_ms());
    }

    #[test]
    fn heuristic_mispredicts_memory_bound_workloads() {
        // The toy trace is one big memory-bound op; T4→V100 truth scales by
        // bandwidth (~3.05×), but the heuristic scales by FLOPS (~1.94×).
        let t = trace();
        let heuristic = flops_ratio_prediction(&t, Device::V100);
        let truth = crate::sim::Simulator::default().graph_time_ms(
            Device::V100.spec(),
            &{
                let mut g = crate::Graph::new("toy", 8);
                g.push(Op::new("a", OpKind::Elementwise { kind: EwKind::Relu }, vec![1 << 24]));
                g
            },
            crate::sim::Precision::Fp32,
        );
        let err = (heuristic - truth).abs() / truth;
        assert!(err > 0.2, "heuristic should be badly wrong here: {err}");
    }
}
