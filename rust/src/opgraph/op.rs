//! Operation kinds and per-operation parameters.
//!
//! The set mirrors what the paper's five evaluation models exercise
//! (§5.1): convolution families, recurrent layers, attention building
//! blocks, normalization, activations, pooling, losses, and the optimizer
//! step. Each kind is classified as *kernel-varying* (implemented with
//! architecture-specific kernels by cuDNN/cuBLAS ⇒ predicted with MLPs) or
//! *kernel-alike* (same kernels everywhere ⇒ predicted with wave scaling),
//! following §3.2.


use crate::opgraph::shape::{numel, Shape};

/// Simple elementwise operator flavors (all kernel-alike).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EwKind {
    Relu,
    LeakyRelu,
    Tanh,
    Sigmoid,
    Gelu,
    Add,
    Mul,
    Scale,
    Dropout,
    Copy,
}

impl EwKind {
    /// FLOPs per element (rough; transcendentals cost more).
    pub fn flops_per_elem(self) -> f64 {
        match self {
            EwKind::Relu | EwKind::Copy => 1.0,
            EwKind::Add | EwKind::Mul | EwKind::Scale | EwKind::LeakyRelu | EwKind::Dropout => 2.0,
            EwKind::Tanh | EwKind::Sigmoid => 10.0,
            EwKind::Gelu => 14.0,
        }
    }

    /// Input + output tensor streams touched per element.
    pub fn mem_streams(self) -> f64 {
        match self {
            EwKind::Add | EwKind::Mul => 3.0, // two reads + one write
            _ => 2.0,                         // one read + one write
        }
    }
}

/// Pooling flavors.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PoolKind {
    Max,
    Avg,
    AdaptiveAvg,
}

/// Optimizer flavors for the weight-update op.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OptimizerKind {
    /// SGD with momentum: ~4 FLOPs and 4 memory streams per parameter.
    Sgd,
    /// Adam: ~12 FLOPs and 6 memory streams per parameter.
    Adam,
}

/// Which pre-trained MLP predicts a kernel-varying operation (§3.4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum MlpOp {
    Conv2d,
    Lstm,
    Bmm,
    Linear,
}

impl MlpOp {
    pub const ALL: [MlpOp; 4] = [MlpOp::Conv2d, MlpOp::Lstm, MlpOp::Bmm, MlpOp::Linear];

    /// Stable identifier used for dataset files and artifact names.
    pub fn id(self) -> &'static str {
        match self {
            MlpOp::Conv2d => "conv2d",
            MlpOp::Lstm => "lstm",
            MlpOp::Bmm => "bmm",
            MlpOp::Linear => "linear",
        }
    }

    /// Number of operation-specific input features (paper Table 1).
    pub fn feature_count(self) -> usize {
        match self {
            MlpOp::Conv2d | MlpOp::Lstm => 7,
            MlpOp::Bmm | MlpOp::Linear => 4,
        }
    }

    pub fn parse(s: &str) -> Option<MlpOp> {
        MlpOp::ALL.into_iter().find(|o| o.id() == s)
    }
}

impl std::fmt::Display for MlpOp {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.id())
    }
}

/// An operation's kind and parameters.
#[derive(Debug, Clone, PartialEq)]
pub enum OpKind {
    /// 2-D convolution over NCHW input.
    Conv2d {
        in_ch: usize,
        out_ch: usize,
        kernel: usize,
        stride: usize,
        padding: usize,
        bias: bool,
    },
    /// 2-D transposed convolution (DCGAN generator).
    ConvTranspose2d {
        in_ch: usize,
        out_ch: usize,
        kernel: usize,
        stride: usize,
        padding: usize,
        bias: bool,
    },
    /// Fully connected layer over `[rows, in_features]`.
    Linear {
        in_features: usize,
        out_features: usize,
        bias: bool,
    },
    /// Batched matrix multiply `[b,l,m] × [b,m,r]` (attention).
    BatchedMatmul { b: usize, l: usize, m: usize, r: usize },
    /// (Multi-layer, optionally bidirectional) LSTM over a full sequence.
    Lstm {
        input: usize,
        hidden: usize,
        layers: usize,
        seq: usize,
        bidirectional: bool,
        bias: bool,
    },
    /// Batch normalization over NCHW input.
    BatchNorm2d { channels: usize },
    /// Layer normalization over the trailing dimension.
    LayerNorm { dim: usize },
    /// Elementwise op over the input tensor.
    Elementwise { kind: EwKind },
    /// Spatial pooling.
    Pool2d {
        kind: PoolKind,
        kernel: usize,
        stride: usize,
        padding: usize,
    },
    /// Softmax over the trailing dimension.
    Softmax { dim: usize },
    /// Embedding lookup: `[rows]` indices → `[rows, dim]`.
    Embedding { vocab: usize, dim: usize },
    /// Cross-entropy loss over `[rows, classes]` logits.
    CrossEntropy { classes: usize },
    /// Concatenation along the channel axis (Inception, GNMT attention).
    Concat { inputs: usize },
    /// Optimizer weight update over all model parameters.
    OptimizerStep { kind: OptimizerKind, params: u64 },
}

impl OpKind {
    /// Kernel-varying operations are implemented with GPU-architecture-
    /// specific kernels (cuDNN algorithm selection, cuBLAS arch dispatch)
    /// and are predicted with MLPs; everything else is kernel-alike.
    pub fn is_kernel_varying(&self) -> bool {
        matches!(
            self,
            OpKind::Conv2d { .. }
                | OpKind::ConvTranspose2d { .. }
                | OpKind::Linear { .. }
                | OpKind::BatchedMatmul { .. }
                | OpKind::Lstm { .. }
        )
    }

    /// Which MLP predicts this op, if it is kernel-varying.
    /// Transposed convolution is the gradient of a convolution with the
    /// channel roles swapped, so it maps onto the conv2d MLP.
    pub fn mlp_op(&self) -> Option<MlpOp> {
        match self {
            OpKind::Conv2d { .. } | OpKind::ConvTranspose2d { .. } => Some(MlpOp::Conv2d),
            OpKind::Lstm { .. } => Some(MlpOp::Lstm),
            OpKind::BatchedMatmul { .. } => Some(MlpOp::Bmm),
            OpKind::Linear { .. } => Some(MlpOp::Linear),
            _ => None,
        }
    }

    /// Trainable parameters contributed by this op.
    pub fn parameter_count(&self) -> u64 {
        match *self {
            OpKind::Conv2d {
                in_ch,
                out_ch,
                kernel,
                bias,
                ..
            }
            | OpKind::ConvTranspose2d {
                in_ch,
                out_ch,
                kernel,
                bias,
                ..
            } => (in_ch * out_ch * kernel * kernel + if bias { out_ch } else { 0 }) as u64,
            OpKind::Linear {
                in_features,
                out_features,
                bias,
            } => (in_features * out_features + if bias { out_features } else { 0 }) as u64,
            OpKind::Lstm {
                input,
                hidden,
                layers,
                bidirectional,
                bias,
                ..
            } => {
                let dirs = if bidirectional { 2 } else { 1 };
                let mut total = 0u64;
                for layer in 0..layers {
                    let in_dim = if layer == 0 { input } else { hidden * dirs };
                    // 4 gates: W_ih [4h×in], W_hh [4h×h], plus two bias vecs.
                    let per_dir =
                        4 * hidden * in_dim + 4 * hidden * hidden + if bias { 8 * hidden } else { 0 };
                    total += (per_dir * dirs) as u64;
                }
                total
            }
            OpKind::BatchNorm2d { channels } => 2 * channels as u64,
            OpKind::LayerNorm { dim } => 2 * dim as u64,
            OpKind::Embedding { vocab, dim } => (vocab * dim) as u64,
            _ => 0,
        }
    }

    /// Short name used in traces and the per-op error breakdown (Fig. 4).
    pub fn short_name(&self) -> &'static str {
        match self {
            OpKind::Conv2d { .. } => "conv2d",
            OpKind::ConvTranspose2d { .. } => "conv_transpose2d",
            OpKind::Linear { .. } => "linear",
            OpKind::BatchedMatmul { .. } => "bmm",
            OpKind::Lstm { .. } => "lstm",
            OpKind::BatchNorm2d { .. } => "batch_norm",
            OpKind::LayerNorm { .. } => "layer_norm",
            OpKind::Elementwise { kind } => match kind {
                EwKind::Relu => "relu",
                EwKind::LeakyRelu => "leaky_relu",
                EwKind::Tanh => "tanh",
                EwKind::Sigmoid => "sigmoid",
                EwKind::Gelu => "gelu",
                EwKind::Add => "__add__",
                EwKind::Mul => "__mul__",
                EwKind::Scale => "scale",
                EwKind::Dropout => "dropout",
                EwKind::Copy => "copy",
            },
            OpKind::Pool2d { kind, .. } => match kind {
                PoolKind::Max => "max_pool2d",
                PoolKind::Avg => "avg_pool2d",
                PoolKind::AdaptiveAvg => "adaptive_avg_pool2d",
            },
            OpKind::Softmax { .. } => "softmax",
            OpKind::Embedding { .. } => "embedding",
            OpKind::CrossEntropy { .. } => "cross_entropy",
            OpKind::Concat { .. } => "cat",
            OpKind::OptimizerStep { .. } => "optimizer_step",
        }
    }
}

/// One node of a [`crate::Graph`]: kind + concrete input shape.
#[derive(Debug, Clone)]
pub struct Op {
    /// Qualified layer name, e.g. `"layer3.4.conv2"`.
    pub name: String,
    pub kind: OpKind,
    /// Concrete shape of the primary input tensor.
    pub input: Shape,
}

impl Op {
    pub fn new(name: impl Into<String>, kind: OpKind, input: Shape) -> Self {
        Op {
            name: name.into(),
            kind,
            input,
        }
    }

    /// Elements in the primary input.
    pub fn input_numel(&self) -> usize {
        numel(&self.input)
    }

    /// MLP feature vector for kernel-varying ops (§3.4 "input features").
    ///
    /// Layouts (must match `python/compile/model.py`):
    /// * conv2d: `[batch, in_ch, out_ch, kernel, stride, padding, image]`
    /// * lstm:   `[batch, input, hidden, seq, layers, bidir, bias]`
    /// * bmm:    `[b, l, m, r]`
    /// * linear: `[rows, in_features, out_features, bias]`
    pub fn mlp_features(&self) -> Option<(MlpOp, Vec<f64>)> {
        match self.kind {
            OpKind::Conv2d {
                in_ch,
                out_ch,
                kernel,
                stride,
                padding,
                ..
            } => {
                let batch = self.input[0] as f64;
                let image = self.input[3] as f64;
                Some((
                    MlpOp::Conv2d,
                    vec![
                        batch,
                        in_ch as f64,
                        out_ch as f64,
                        kernel as f64,
                        stride as f64,
                        padding as f64,
                        image,
                    ],
                ))
            }
            // A transposed conv computes over the *output* (upsampled)
            // spatial extent: its FLOPs equal those of a stride-1 dense
            // convolution at the output resolution with the same channel
            // roles — so that is the point in conv2d feature space that
            // represents it best.
            OpKind::ConvTranspose2d {
                in_ch,
                out_ch,
                kernel,
                stride,
                padding,
                ..
            } => {
                let batch = self.input[0] as f64;
                let out_img =
                    crate::opgraph::shape::conv_transpose_out(self.input[3], kernel, stride, padding)
                        as f64;
                Some((
                    MlpOp::Conv2d,
                    vec![
                        batch,
                        in_ch as f64,
                        out_ch as f64,
                        kernel as f64,
                        1.0, // stride-1 equivalent at output resolution
                        padding as f64,
                        out_img,
                    ],
                ))
            }
            OpKind::Lstm {
                input,
                hidden,
                layers,
                seq,
                bidirectional,
                bias,
            } => {
                let batch = self.input[1] as f64; // input shape [seq, batch, feat]
                Some((
                    MlpOp::Lstm,
                    vec![
                        batch,
                        input as f64,
                        hidden as f64,
                        seq as f64,
                        layers as f64,
                        bidirectional as u8 as f64,
                        bias as u8 as f64,
                    ],
                ))
            }
            OpKind::BatchedMatmul { b, l, m, r } => {
                Some((MlpOp::Bmm, vec![b as f64, l as f64, m as f64, r as f64]))
            }
            OpKind::Linear {
                in_features,
                out_features,
                bias,
            } => {
                // Rows = product of all leading dims (e.g. batch × seq).
                let rows: usize = self.input[..self.input.len() - 1].iter().product();
                Some((
                    MlpOp::Linear,
                    vec![
                        rows as f64,
                        in_features as f64,
                        out_features as f64,
                        bias as u8 as f64,
                    ],
                ))
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_varying_classification() {
        assert!(OpKind::Conv2d {
            in_ch: 3,
            out_ch: 64,
            kernel: 7,
            stride: 2,
            padding: 3,
            bias: false
        }
        .is_kernel_varying());
        assert!(OpKind::Lstm {
            input: 10,
            hidden: 20,
            layers: 1,
            seq: 5,
            bidirectional: false,
            bias: true
        }
        .is_kernel_varying());
        assert!(!OpKind::Elementwise { kind: EwKind::Relu }.is_kernel_varying());
        assert!(!OpKind::BatchNorm2d { channels: 64 }.is_kernel_varying());
    }

    #[test]
    fn conv_features_layout() {
        let op = Op::new(
            "conv1",
            OpKind::Conv2d {
                in_ch: 3,
                out_ch: 64,
                kernel: 7,
                stride: 2,
                padding: 3,
                bias: false,
            },
            vec![32, 3, 224, 224],
        );
        let (mlp, f) = op.mlp_features().unwrap();
        assert_eq!(mlp, MlpOp::Conv2d);
        assert_eq!(f, vec![32.0, 3.0, 64.0, 7.0, 2.0, 3.0, 224.0]);
        assert_eq!(f.len(), MlpOp::Conv2d.feature_count());
    }

    #[test]
    fn linear_features_flatten_leading_dims() {
        let op = Op::new(
            "proj",
            OpKind::Linear {
                in_features: 512,
                out_features: 512,
                bias: true,
            },
            vec![64, 50, 512], // batch 64 × seq 50
        );
        let (mlp, f) = op.mlp_features().unwrap();
        assert_eq!(mlp, MlpOp::Linear);
        assert_eq!(f, vec![3200.0, 512.0, 512.0, 1.0]);
    }

    #[test]
    fn lstm_parameter_count_matches_pytorch_formula() {
        // PyTorch LSTM(10, 20, num_layers=2, bias=True):
        // layer0: 4*20*10 + 4*20*20 + 2*4*20 = 800+1600+160 = 2560
        // layer1: 4*20*20 + 4*20*20 + 160 = 3360
        let k = OpKind::Lstm {
            input: 10,
            hidden: 20,
            layers: 2,
            seq: 5,
            bidirectional: false,
            bias: true,
        };
        assert_eq!(k.parameter_count(), 2560 + 3360);
    }

    #[test]
    fn feature_counts_match_table1() {
        assert_eq!(MlpOp::Conv2d.feature_count(), 7);
        assert_eq!(MlpOp::Lstm.feature_count(), 7);
        assert_eq!(MlpOp::Bmm.feature_count(), 4);
        assert_eq!(MlpOp::Linear.feature_count(), 4);
    }

    #[test]
    fn mlp_op_parse_roundtrip() {
        for op in MlpOp::ALL {
            assert_eq!(MlpOp::parse(op.id()), Some(op));
        }
        assert_eq!(MlpOp::parse("gemm"), None);
    }
}
