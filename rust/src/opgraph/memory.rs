//! Training-memory footprint estimation.
//!
//! Habitat's predictions are for a (model, batch size) pair — but a
//! destination GPU can only run that pair if it *fits* (§6.1.3 exists
//! precisely because the *origin* sometimes cannot fit the batch). This
//! estimator answers "will it fit?" for any device with the standard
//! training-memory accounting:
//!
//!   weights + gradients + optimizer state + saved activations + workspace
//!
//! Activations use the autograd rule: every op that needs its input for
//! backward keeps it alive until the backward pass.

use crate::device::Device;
use crate::opgraph::{Op, OpKind, OptimizerKind};
use crate::Graph;

/// Estimated training-memory footprint, bytes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemoryEstimate {
    pub weights: f64,
    pub gradients: f64,
    pub optimizer_state: f64,
    pub activations: f64,
    /// cuDNN-style workspace + allocator slack (fraction of activations).
    pub workspace: f64,
}

impl MemoryEstimate {
    pub fn total(&self) -> f64 {
        self.weights + self.gradients + self.optimizer_state + self.activations + self.workspace
    }

    /// Total in GiB.
    pub fn total_gib(&self) -> f64 {
        self.total() / (1u64 << 30) as f64
    }
}

/// Saved-activation bytes for one op (input kept for backward).
fn saved_activation_bytes(op: &Op, elem_bytes: f64) -> f64 {
    match op.kind {
        // Elementwise ops with trivial backward recompute from the output
        // (ReLU keeps a bitmask at most); dropout keeps its mask.
        OpKind::Elementwise { .. } => op.input_numel() as f64 * elem_bytes * 0.25,
        // Optimizer runs after backward: saves nothing.
        OpKind::OptimizerStep { .. } => 0.0,
        // Everything else keeps its input tensor.
        _ => op.input_numel() as f64 * elem_bytes,
    }
}

/// Per-parameter optimizer-state floats (FP32 regardless of precision).
fn optimizer_state_floats(graph: &Graph) -> f64 {
    graph
        .ops
        .iter()
        .filter_map(|o| match o.kind {
            OpKind::OptimizerStep { kind, .. } => Some(match kind {
                OptimizerKind::Sgd => 1.0,  // momentum buffer
                OptimizerKind::Adam => 2.0, // m + v
            }),
            _ => None,
        })
        .next()
        .unwrap_or(1.0)
}

/// Estimate the training footprint of one iteration of `graph`.
pub fn estimate(graph: &Graph, precision: crate::lowering::Precision) -> MemoryEstimate {
    let elem = precision.elem_bytes();
    let params = graph.parameter_count() as f64;
    let weights = params * 4.0; // master weights stay FP32 under AMP too
    let gradients = params * elem;
    let optimizer_state = params * 4.0 * optimizer_state_floats(graph);
    let activations: f64 = graph
        .ops
        .iter()
        .map(|o| saved_activation_bytes(o, elem))
        .sum();
    MemoryEstimate {
        weights,
        gradients,
        optimizer_state,
        activations,
        workspace: 0.15 * activations,
    }
}

/// Does one training iteration of `graph` fit on `device`? Uses a 6%
/// reserve for the CUDA context + framework overhead.
pub fn fits(graph: &Graph, device: Device, precision: crate::lowering::Precision) -> bool {
    let budget = device.spec().mem_gib * 0.94 * (1u64 << 30) as f64;
    estimate(graph, precision).total() <= budget
}

/// Largest evaluated batch size that fits (doubling + binary search).
pub fn max_batch<F: Fn(usize) -> Graph>(
    build: F,
    device: Device,
    precision: crate::lowering::Precision,
) -> usize {
    if !fits(&build(1), device, precision) {
        return 0;
    }
    let mut lo = 1usize;
    let mut hi = 2usize;
    while hi <= 65_536 && fits(&build(hi), device, precision) {
        lo = hi;
        hi *= 2;
    }
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        if fits(&build(mid), device, precision) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lowering::Precision;
    use crate::models;

    #[test]
    fn resnet50_footprint_in_plausible_range() {
        // ResNet-50 at batch 32 trains comfortably in ~6–14 GiB in practice.
        let est = estimate(&models::resnet50(32), Precision::Fp32);
        let gib = est.total_gib();
        assert!(gib > 2.0 && gib < 16.0, "{gib} GiB");
        // Weights ≈ 25.5M × 4B ≈ 102 MB.
        assert!((est.weights / 102e6 - 1.0).abs() < 0.05);
    }

    #[test]
    fn activations_scale_with_batch() {
        let a = estimate(&models::resnet50(16), Precision::Fp32).activations;
        let b = estimate(&models::resnet50(64), Precision::Fp32).activations;
        assert!((b / a - 4.0).abs() < 0.1, "{}", b / a);
    }

    #[test]
    fn amp_reduces_activation_memory() {
        let fp32 = estimate(&models::resnet50(32), Precision::Fp32);
        let amp = estimate(&models::resnet50(32), Precision::Amp);
        assert!(amp.activations < fp32.activations);
        // Master weights + optimizer state unchanged.
        assert_eq!(amp.weights, fp32.weights);
        assert_eq!(amp.optimizer_state, fp32.optimizer_state);
    }

    #[test]
    fn adam_state_twice_sgd() {
        let resnet = estimate(&models::resnet50(16), Precision::Fp32); // SGD
        let ratio = resnet.optimizer_state / resnet.weights;
        assert!((ratio - 1.0).abs() < 1e-9, "SGD momentum = 1× weights");
        let gnmt = estimate(&models::gnmt(16), Precision::Fp32); // Adam
        assert!((gnmt.optimizer_state / gnmt.weights - 2.0).abs() < 1e-9);
    }

    #[test]
    fn bigger_gpus_fit_bigger_batches() {
        let p4000 = max_batch(models::resnet50, Device::P4000, Precision::Fp32);
        let v100 = max_batch(models::resnet50, Device::V100, Precision::Fp32);
        assert!(p4000 >= 16, "{p4000}");
        assert!(v100 > p4000, "{v100} !> {p4000}");
    }

    #[test]
    fn amp_fits_bigger_batches() {
        let fp32 = max_batch(models::resnet50, Device::Rtx2070, Precision::Fp32);
        let amp = max_batch(models::resnet50, Device::Rtx2070, Precision::Amp);
        assert!(amp > fp32);
    }

    #[test]
    fn max_batch_is_consistent_with_fits() {
        let b = max_batch(models::gnmt, Device::T4, Precision::Fp32);
        assert!(fits(&models::gnmt(b), Device::T4, Precision::Fp32));
        assert!(!fits(&models::gnmt(b + 1), Device::T4, Precision::Fp32));
    }
}
