//! Tensor shapes and the shape arithmetic the model builders use.

/// A tensor shape: dimension sizes, NCHW for images.
pub type Shape = Vec<usize>;

/// Number of elements in a shape.
pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

/// Output spatial size of a convolution / pooling window:
/// `floor((in + 2·pad − kernel) / stride) + 1`.
pub fn conv_out(input: usize, kernel: usize, stride: usize, padding: usize) -> usize {
    debug_assert!(stride >= 1);
    debug_assert!(input + 2 * padding >= kernel, "window larger than padded input");
    (input + 2 * padding - kernel) / stride + 1
}

/// Output spatial size of a transposed convolution:
/// `(in − 1)·stride − 2·pad + kernel`.
pub fn conv_transpose_out(input: usize, kernel: usize, stride: usize, padding: usize) -> usize {
    (input - 1) * stride + kernel - 2 * padding
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_out_formula() {
        // ResNet stem: 224, k=7, s=2, p=3 → 112.
        assert_eq!(conv_out(224, 7, 2, 3), 112);
        // 3×3 same-pad: 56, k=3, s=1, p=1 → 56.
        assert_eq!(conv_out(56, 3, 1, 1), 56);
        // 1×1 stride 2: 56 → 28.
        assert_eq!(conv_out(56, 1, 2, 0), 28);
    }

    #[test]
    fn conv_transpose_out_formula() {
        // DCGAN generator: 1, k=4, s=1, p=0 → 4; then 4, k=4, s=2, p=1 → 8.
        assert_eq!(conv_transpose_out(1, 4, 1, 0), 4);
        assert_eq!(conv_transpose_out(4, 4, 2, 1), 8);
        assert_eq!(conv_transpose_out(32, 4, 2, 1), 64);
    }

    #[test]
    fn numel_product() {
        assert_eq!(numel(&[64, 3, 224, 224]), 64 * 3 * 224 * 224);
        assert_eq!(numel(&[]), 1);
    }
}
