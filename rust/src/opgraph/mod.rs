//! DNN computation graphs.
//!
//! Habitat operates on the *trace of operations* executed by one training
//! iteration (the paper extracts it by monkey-patching PyTorch, §4.1). Here
//! the equivalent substrate is an explicit operation graph: each node
//! carries its operator kind, concrete parameters, and concrete input
//! shape, exactly the information Habitat's wrappers record at runtime.
//!
//! A [`Graph`] is stored in execution order. Iteration time is additive
//! over operations (GPU kernels within one stream serialize), so execution
//! order is all the predictor needs — graph fan-out (Inception) or
//! dual-network structure (DCGAN) shows up only in *which* ops appear.

pub mod memory;
pub mod op;
pub mod shape;

pub use op::{EwKind, MlpOp, Op, OpKind, OptimizerKind, PoolKind};
pub use shape::{conv_out, Shape};


/// A DNN training-iteration computation graph, in execution order.
#[derive(Debug, Clone)]
pub struct Graph {
    /// Human-readable model name (e.g. `"resnet50"`).
    pub name: String,
    /// Training batch size the graph was instantiated for.
    pub batch_size: usize,
    /// Operations in forward-pass execution order.
    pub ops: Vec<Op>,
}

impl Graph {
    pub fn new(name: impl Into<String>, batch_size: usize) -> Self {
        Graph {
            name: name.into(),
            batch_size,
            ops: Vec::new(),
        }
    }

    /// Append an operation.
    pub fn push(&mut self, op: Op) {
        self.ops.push(op);
    }

    /// Total number of operations.
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Count of operations that are *kernel-varying* (predicted by MLPs).
    pub fn kernel_varying_count(&self) -> usize {
        self.ops.iter().filter(|o| o.kind.is_kernel_varying()).count()
    }

    /// Total trainable-parameter count implied by the graph's layer ops.
    pub fn parameter_count(&self) -> u64 {
        self.ops.iter().map(|o| o.kind.parameter_count()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_len() {
        let mut g = Graph::new("toy", 8);
        assert!(g.is_empty());
        g.push(Op::new(
            "fc",
            OpKind::Linear {
                in_features: 16,
                out_features: 4,
                bias: true,
            },
            vec![8, 16],
        ));
        assert_eq!(g.len(), 1);
        assert_eq!(g.kernel_varying_count(), 1);
        assert_eq!(g.parameter_count(), 16 * 4 + 4);
    }
}
