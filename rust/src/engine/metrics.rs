//! Per-op service metrics: lock-free request/error counters and
//! fixed-bucket latency histograms.
//!
//! One [`ServiceMetrics`] lives on each [`PredictionEngine`] and is
//! fed by the transport-agnostic dispatcher
//! ([`crate::coordinator::Dispatcher`]): every wire request — over TCP
//! or HTTP — is classified into an [`OpKind`], timed, and recorded
//! here. Everything is a relaxed atomic, so recording never contends
//! with the prediction hot path, and a `/metrics` scrape or a v2
//! `stats` op reads a consistent-enough snapshot without stopping the
//! world.
//!
//! The histogram uses fixed bucket bounds (milliseconds, chosen to
//! straddle the cache-hit path at tens of µs and the tracking pipeline
//! at tens of ms) so scrapes from different processes are directly
//! comparable and the Prometheus exposition needs no float formatting
//! gymnastics: every `le` label is a pre-rendered string constant.
//!
//! [`PredictionEngine`]: super::PredictionEngine

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::time::Duration;

use super::EngineStats;

/// The wire operations the dispatcher distinguishes. `Other` absorbs
/// unparseable lines, unsupported versions, and unknown ops — traffic
/// that never resolved to a real operation but still cost a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    Predict,
    Rank,
    RankMany,
    Stats,
    SubmitTrace,
    RegisterDevice,
    PredictCluster,
    RankCluster,
    ExportWorkload,
    Other,
}

impl OpKind {
    /// Every kind, in the order they are emitted on `/metrics`.
    pub const ALL: [OpKind; 10] = [
        OpKind::Predict,
        OpKind::Rank,
        OpKind::RankMany,
        OpKind::Stats,
        OpKind::SubmitTrace,
        OpKind::RegisterDevice,
        OpKind::PredictCluster,
        OpKind::RankCluster,
        OpKind::ExportWorkload,
        OpKind::Other,
    ];

    /// The wire name of the op (matches the v2 `"op"` field; `Other`
    /// has no wire name and labels as `"other"`).
    pub fn label(self) -> &'static str {
        match self {
            OpKind::Predict => "predict",
            OpKind::Rank => "rank",
            OpKind::RankMany => "rank_many",
            OpKind::Stats => "stats",
            OpKind::SubmitTrace => "submit_trace",
            OpKind::RegisterDevice => "register_device",
            OpKind::PredictCluster => "predict_cluster",
            OpKind::RankCluster => "rank_cluster",
            OpKind::ExportWorkload => "export_workload",
            OpKind::Other => "other",
        }
    }

    fn index(self) -> usize {
        match self {
            OpKind::Predict => 0,
            OpKind::Rank => 1,
            OpKind::RankMany => 2,
            OpKind::Stats => 3,
            OpKind::SubmitTrace => 4,
            OpKind::RegisterDevice => 5,
            OpKind::PredictCluster => 6,
            OpKind::RankCluster => 7,
            OpKind::ExportWorkload => 8,
            OpKind::Other => 9,
        }
    }
}

/// Histogram bucket upper bounds in milliseconds, paired with the
/// exact `le` label each renders as. The final `+Inf` bucket is
/// implicit (it is the observation count).
pub const BUCKET_BOUNDS_MS: [(f64, &str); 11] = [
    (0.05, "0.05"),
    (0.1, "0.1"),
    (0.25, "0.25"),
    (0.5, "0.5"),
    (1.0, "1"),
    (2.5, "2.5"),
    (5.0, "5"),
    (10.0, "10"),
    (25.0, "25"),
    (100.0, "100"),
    (1000.0, "1000"),
];

/// Finite buckets plus the `+Inf` overflow slot.
const SLOTS: usize = BUCKET_BOUNDS_MS.len() + 1;

/// Counters for one [`OpKind`]. Bucket slots are *disjoint* (slot `i`
/// counts observations in `(bound[i-1], bound[i]]`); the cumulative
/// sums Prometheus wants are computed at render time.
#[derive(Default)]
struct OpCell {
    requests: AtomicU64,
    errors: AtomicU64,
    slots: [AtomicU64; SLOTS],
    latency_ns: AtomicU64,
}

/// A point-in-time copy of one op's counters, for tests and the v2
/// `stats` payload.
#[derive(Debug, Clone)]
pub struct OpSnapshot {
    pub op: OpKind,
    pub requests: u64,
    pub errors: u64,
    /// Disjoint per-slot counts; `buckets[SLOTS - 1]` is the `+Inf`
    /// overflow slot.
    pub buckets: Vec<u64>,
    pub latency_ms_sum: f64,
}

/// Lock-free per-op request metrics for one engine/service instance.
#[derive(Default)]
pub struct ServiceMetrics {
    cells: [OpCell; OpKind::ALL.len()],
}

impl ServiceMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one completed request: which op it resolved to, whether
    /// the reply was an error, and how long routing + handling took.
    pub fn record(&self, op: OpKind, ok: bool, elapsed: Duration) {
        let cell = &self.cells[op.index()];
        cell.requests.fetch_add(1, Relaxed);
        if !ok {
            cell.errors.fetch_add(1, Relaxed);
        }
        let ms = elapsed.as_secs_f64() * 1e3;
        let slot = BUCKET_BOUNDS_MS
            .iter()
            .position(|&(bound, _)| ms <= bound)
            .unwrap_or(SLOTS - 1);
        cell.slots[slot].fetch_add(1, Relaxed);
        cell.latency_ns
            .fetch_add(elapsed.as_nanos().min(u64::MAX as u128) as u64, Relaxed);
    }

    /// Total requests recorded across every op.
    pub fn requests_total(&self) -> u64 {
        self.cells.iter().map(|c| c.requests.load(Relaxed)).sum()
    }

    /// Total error replies recorded across every op.
    pub fn errors_total(&self) -> u64 {
        self.cells.iter().map(|c| c.errors.load(Relaxed)).sum()
    }

    /// Snapshot one op's counters.
    pub fn snapshot(&self, op: OpKind) -> OpSnapshot {
        let cell = &self.cells[op.index()];
        OpSnapshot {
            op,
            requests: cell.requests.load(Relaxed),
            errors: cell.errors.load(Relaxed),
            buckets: cell.slots.iter().map(|s| s.load(Relaxed)).collect(),
            latency_ms_sum: cell.latency_ns.load(Relaxed) as f64 / 1e6,
        }
    }

    /// Render the Prometheus text exposition for `GET /metrics`: the
    /// per-op request/error counters, the per-op latency histograms,
    /// and the engine counter gauges from `stats`. Every op is emitted
    /// even at zero so scrape series are stable from the first scrape.
    pub fn render_prometheus(&self, engine: &EngineStats) -> String {
        let mut out = String::with_capacity(8 * 1024);

        out.push_str("# HELP habitat_requests_total Wire requests handled, by op.\n");
        out.push_str("# TYPE habitat_requests_total counter\n");
        for op in OpKind::ALL {
            let snap = self.snapshot(op);
            out.push_str(&format!(
                "habitat_requests_total{{op=\"{}\"}} {}\n",
                op.label(),
                snap.requests
            ));
        }

        out.push_str("# HELP habitat_request_errors_total Error replies, by op.\n");
        out.push_str("# TYPE habitat_request_errors_total counter\n");
        for op in OpKind::ALL {
            let snap = self.snapshot(op);
            out.push_str(&format!(
                "habitat_request_errors_total{{op=\"{}\"}} {}\n",
                op.label(),
                snap.errors
            ));
        }

        out.push_str(
            "# HELP habitat_request_latency_ms Request routing+handling latency, by op.\n",
        );
        out.push_str("# TYPE habitat_request_latency_ms histogram\n");
        for op in OpKind::ALL {
            let snap = self.snapshot(op);
            let mut cumulative = 0u64;
            for (slot, &(_, le)) in BUCKET_BOUNDS_MS.iter().enumerate() {
                cumulative += snap.buckets[slot];
                out.push_str(&format!(
                    "habitat_request_latency_ms_bucket{{op=\"{}\",le=\"{}\"}} {}\n",
                    op.label(),
                    le,
                    cumulative
                ));
            }
            cumulative += snap.buckets[SLOTS - 1];
            out.push_str(&format!(
                "habitat_request_latency_ms_bucket{{op=\"{}\",le=\"+Inf\"}} {}\n",
                op.label(),
                cumulative
            ));
            out.push_str(&format!(
                "habitat_request_latency_ms_sum{{op=\"{}\"}} {}\n",
                op.label(),
                snap.latency_ms_sum
            ));
            out.push_str(&format!(
                "habitat_request_latency_ms_count{{op=\"{}\"}} {}\n",
                op.label(),
                cumulative
            ));
        }

        let gauges: [(&str, &str, u64); 15] = [
            ("habitat_engine_trace_hits", "Trace-cache hits.", engine.trace_hits),
            ("habitat_engine_trace_misses", "Trace-cache misses.", engine.trace_misses),
            (
                "habitat_engine_trace_entries",
                "Resident trace+plan entries.",
                engine.trace_entries as u64,
            ),
            (
                "habitat_engine_trace_uploads",
                "Distinct uploaded traces accepted.",
                engine.trace_uploads,
            ),
            (
                "habitat_engine_uploaded_entries",
                "Resident uploaded trace+plan entries.",
                engine.uploaded_entries as u64,
            ),
            ("habitat_engine_devices", "Devices in the registry.", engine.devices as u64),
            ("habitat_engine_plan_builds", "Plan compilations.", engine.plan_builds),
            ("habitat_engine_wave_hits", "Wave-table hits (process-wide).", engine.wave_hits),
            (
                "habitat_engine_wave_misses",
                "Wave-table misses (process-wide).",
                engine.wave_misses,
            ),
            ("habitat_engine_workers", "Fan-out worker-pool width.", engine.workers as u64),
            ("habitat_engine_store_hits", "Plan-store hits.", engine.store_hits),
            ("habitat_engine_store_misses", "Plan-store misses.", engine.store_misses),
            (
                "habitat_engine_warm_restores",
                "Records warm-restored from the plan store.",
                engine.warm_restores,
            ),
            (
                "habitat_engine_parallel_build_chunks",
                "Lane rows filled by the parallel plan builder.",
                engine.parallel_build_chunks,
            ),
            (
                "habitat_engine_simd_active",
                "1 when the evaluation sweeps run on the vector backend, \
                 0 on the scalar fallback (bit-identical either way).",
                u64::from(engine.simd == "avx2"),
            ),
        ];
        for (name, help, value) in gauges {
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} gauge\n{name} {value}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_into_the_right_bucket_and_op() {
        let m = ServiceMetrics::new();
        m.record(OpKind::Predict, true, Duration::from_micros(80)); // ≤ 0.1 ms
        m.record(OpKind::Predict, false, Duration::from_millis(3)); // ≤ 5 ms
        m.record(OpKind::Rank, true, Duration::from_secs(2)); // +Inf slot

        let p = m.snapshot(OpKind::Predict);
        assert_eq!(p.requests, 2);
        assert_eq!(p.errors, 1);
        assert_eq!(p.buckets[1], 1, "80 µs lands in the (0.05, 0.1] slot");
        assert_eq!(p.buckets[6], 1, "3 ms lands in the (2.5, 5] slot");

        let r = m.snapshot(OpKind::Rank);
        assert_eq!(r.buckets[SLOTS - 1], 1, "2 s overflows to +Inf");
        assert_eq!(m.requests_total(), 3);
        assert_eq!(m.errors_total(), 1);
    }

    #[test]
    fn prometheus_exposition_is_cumulative_and_complete() {
        let m = ServiceMetrics::new();
        m.record(OpKind::Stats, true, Duration::from_micros(10));
        m.record(OpKind::Stats, true, Duration::from_millis(50));
        let engine = crate::engine::PredictionEngine::wave_only();
        let text = m.render_prometheus(&engine.stats());

        // Every op appears even at zero.
        for op in OpKind::ALL {
            assert!(
                text.contains(&format!("habitat_requests_total{{op=\"{}\"}}", op.label())),
                "missing series for {}",
                op.label()
            );
        }
        // Cumulative: the 100 ms bucket and +Inf both see the 50 ms hit
        // plus the 10 µs one.
        assert!(text.contains("habitat_request_latency_ms_bucket{op=\"stats\",le=\"0.05\"} 1"));
        assert!(text.contains("habitat_request_latency_ms_bucket{op=\"stats\",le=\"100\"} 2"));
        assert!(text.contains("habitat_request_latency_ms_bucket{op=\"stats\",le=\"+Inf\"} 2"));
        assert!(text.contains("habitat_request_latency_ms_count{op=\"stats\"} 2"));
        assert!(text.contains("habitat_engine_workers "));
        // The SIMD gauge mirrors the engine's selected backend.
        let expect = u64::from(crate::util::simdf64::backend() == "avx2");
        assert!(text.contains(&format!("habitat_engine_simd_active {expect}")));
    }

    #[test]
    fn labels_match_wire_op_names() {
        assert_eq!(OpKind::SubmitTrace.label(), "submit_trace");
        assert_eq!(OpKind::RankMany.label(), "rank_many");
        assert_eq!(OpKind::ExportWorkload.label(), "export_workload");
        assert_eq!(OpKind::ALL.len(), 10);
    }
}
