//! Persistent worker pool for multi-destination fan-out.
//!
//! The engine used to spawn-and-join a fresh set of `std::thread`s
//! inside every `fan_out` call; under service load (every `rank` RPC
//! fans out) that is thousands of thread spawns per second for work
//! items that take microseconds each. This pool spawns its threads once
//! at engine construction and feeds them closures over a channel.
//!
//! Sizing: [`crate::engine::PredictionEngine::with_workers`] (builder)
//! or the `HABITAT_WORKERS` environment variable, defaulting to the
//! machine's available parallelism capped at 8.

use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size pool of persistent worker threads executing boxed jobs
/// in submission order (work-stealing is overkill: jobs are uniform
/// per-destination evaluations).
pub struct WorkerPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `size` (≥ 1) worker threads.
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("habitat-predict-{i}"))
                    .spawn(move || loop {
                        // Hold the receiver lock only while dequeuing,
                        // never while running the job.
                        let job = match rx.lock() {
                            Ok(guard) => guard.recv(),
                            Err(_) => break, // a job panicked mid-recv
                        };
                        match job {
                            // Contain a panicking job (e.g. a
                            // misbehaving external MlpBackend) to that
                            // one request: the submitter sees its result
                            // channel close, but the worker survives to
                            // serve other requests — matching the old
                            // per-call scoped threads, which never
                            // outlived one request.
                            Ok(job) => {
                                let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                            }
                            Err(_) => break, // pool dropped
                        }
                    })
                    .expect("spawn fan-out worker")
            })
            .collect();
        WorkerPool { tx: Some(tx), workers }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit one job. Job panics are contained to the job (the worker
    /// survives); the send itself cannot fail while the pool is alive.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool is alive until drop")
            .send(Box::new(job))
            .expect("fan-out workers alive");
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing the channel lets each worker drain its queue and exit.
        drop(self.tx.take());
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_job_across_workers() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.size(), 4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel::<usize>();
        for i in 0..64 {
            let counter = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                counter.fetch_add(1, Ordering::Relaxed);
                tx.send(i).unwrap();
            });
        }
        drop(tx);
        let mut got: Vec<usize> = rx.iter().collect();
        got.sort_unstable();
        assert_eq!(got, (0..64).collect::<Vec<_>>());
        assert_eq!(counter.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn zero_size_is_clamped_to_one() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.size(), 1);
        let (tx, rx) = channel::<u32>();
        pool.execute(move || tx.send(7).unwrap());
        assert_eq!(rx.recv().unwrap(), 7);
    }

    #[test]
    fn panicking_job_does_not_kill_the_worker() {
        let pool = WorkerPool::new(1);
        pool.execute(|| panic!("one bad request"));
        // The single worker must survive to run the next job.
        let (tx, rx) = channel::<u32>();
        pool.execute(move || tx.send(11).unwrap());
        assert_eq!(rx.recv().unwrap(), 11);
    }

    #[test]
    fn drop_drains_outstanding_jobs() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = WorkerPool::new(2);
            for _ in 0..32 {
                let counter = Arc::clone(&counter);
                pool.execute(move || {
                    counter.fetch_add(1, Ordering::Relaxed);
                });
            }
        } // Drop joins the workers after the queue drains.
        assert_eq!(counter.load(Ordering::Relaxed), 32);
    }
}
