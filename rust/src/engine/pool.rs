//! The shared compute pool: a fixed set of worker threads fed from one
//! **bounded** submission queue.
//!
//! This is the single execution budget of the process. Both kinds of
//! concurrent work draw from it:
//!
//! * **fan-out helpers** — `PredictionEngine::fan_out` submits
//!   per-destination evaluation helpers with [`WorkerPool::try_execute`]
//!   (never blocking: the calling thread always evaluates too, so a
//!   fan-out makes progress even when every worker is busy — which is
//!   exactly what happens when the fan-out itself runs *on* a pool
//!   worker serving a `rank` request);
//! * **service requests** — the TCP runtime
//!   (`coordinator::service::start`) submits one job per request line
//!   with `try_execute`; a full queue is answered with a typed
//!   `overloaded` error instead of letting work pile up unboundedly.
//!
//! The queue is a `sync_channel` of [`DEFAULT_QUEUE_DEPTH`] slots
//! (override with `HABITAT_QUEUE_DEPTH`): [`WorkerPool::execute`]
//! blocks for a slot (used by tests and one-off background work),
//! [`WorkerPool::try_execute`] returns [`Busy`] immediately — the
//! backpressure primitive.
//!
//! Sizing: [`crate::engine::PredictionEngine::with_workers`] (builder)
//! or the `HABITAT_WORKERS` environment variable, defaulting to the
//! machine's available parallelism capped at 8.

use std::cell::RefCell;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::plan::EvalScratch;

type Job = Box<dyn FnOnce() + Send + 'static>;

thread_local! {
    /// One batched-evaluation scratch arena per thread (pool workers and
    /// callers alike). Thread-local rather than per-pool so a fan-out
    /// chunk keeps its warm buffers across jobs with no locking and no
    /// cross-thread handoff.
    static SCRATCH: RefCell<EvalScratch> = RefCell::new(EvalScratch::new());
}

/// Run `f` with this thread's pooled [`EvalScratch`]. Steady-state
/// batched evaluations on a warm thread reuse the arena's capacity and
/// perform no heap allocation. Re-entrant calls (an evaluation that
/// somehow triggers another on the same thread) get a fresh arena
/// instead of panicking on the `RefCell`.
pub fn with_scratch<R>(f: impl FnOnce(&mut EvalScratch) -> R) -> R {
    SCRATCH.with(|cell| match cell.try_borrow_mut() {
        Ok(mut scratch) => f(&mut scratch),
        Err(_) => f(&mut EvalScratch::new()),
    })
}

/// Environment variable overriding the submission-queue depth.
pub const QUEUE_DEPTH_ENV: &str = "HABITAT_QUEUE_DEPTH";

/// Default bounded submission-queue depth.
pub const DEFAULT_QUEUE_DEPTH: usize = 1024;

/// Read the queue depth from `HABITAT_QUEUE_DEPTH`, defaulting to
/// [`DEFAULT_QUEUE_DEPTH`].
pub fn queue_depth_from_env() -> usize {
    std::env::var(QUEUE_DEPTH_ENV)
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(DEFAULT_QUEUE_DEPTH)
}

/// The submission queue was full (or the pool is shutting down); the
/// job was **not** run and has been dropped. Callers that must answer
/// regardless (the service) keep their own reply channel and send a
/// typed `overloaded` error instead.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Busy;

impl std::fmt::Display for Busy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("compute queue full")
    }
}

impl std::error::Error for Busy {}

/// A fixed-size pool of persistent worker threads executing boxed jobs
/// from a bounded MPMC queue in submission order (work-stealing is
/// overkill: jobs are uniform per-destination evaluations or request
/// handlers).
pub struct WorkerPool {
    tx: Option<SyncSender<Job>>,
    workers: Vec<JoinHandle<()>>,
    queue_depth: usize,
}

impl WorkerPool {
    /// Spawn `size` (≥ 1) worker threads with the environment-derived
    /// queue depth.
    pub fn new(size: usize) -> Self {
        Self::with_queue_depth(size, queue_depth_from_env())
    }

    /// Spawn `size` (≥ 1) worker threads over a queue of `queue_depth`
    /// (≥ 1) slots.
    pub fn with_queue_depth(size: usize, queue_depth: usize) -> Self {
        let size = size.max(1);
        let queue_depth = queue_depth.max(1);
        let (tx, rx) = sync_channel::<Job>(queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("habitat-worker-{i}"))
                    .spawn(move || Self::worker_loop(&rx))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool {
            tx: Some(tx),
            workers,
            queue_depth,
        }
    }

    fn worker_loop(rx: &Mutex<Receiver<Job>>) {
        loop {
            // Hold the receiver lock only while dequeuing, never while
            // running the job.
            let job = match rx.lock() {
                Ok(guard) => guard.recv(),
                Err(_) => break, // a job panicked mid-recv
            };
            match job {
                // Contain a panicking job (e.g. a misbehaving external
                // MlpBackend, or a request handler hitting a bug) to
                // that one job: the submitter sees its result channel
                // close, but the worker survives to serve other work.
                Ok(job) => {
                    let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                }
                Err(_) => break, // pool dropped
            }
        }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Bounded submission-queue depth.
    pub fn queue_depth(&self) -> usize {
        self.queue_depth
    }

    /// Submit one job, **blocking** until a queue slot frees up. Job
    /// panics are contained to the job (the worker survives). Never
    /// call this from *inside* a pool job — a full queue would deadlock
    /// the worker; in-pool submitters use [`WorkerPool::try_execute`].
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool is alive until drop")
            .send(Box::new(job))
            .expect("pool workers alive");
    }

    /// Submit one job without blocking: `Err(Busy)` if every queue slot
    /// is taken (the job is dropped, not run). This is the only
    /// submission path safe from inside a pool job, and the hook the
    /// service's `overloaded` backpressure hangs off.
    pub fn try_execute(&self, job: impl FnOnce() + Send + 'static) -> Result<(), Busy> {
        match self
            .tx
            .as_ref()
            .expect("pool is alive until drop")
            .try_send(Box::new(job))
        {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => Err(Busy),
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing the channel lets each worker drain its queue and exit.
        drop(self.tx.take());
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::mpsc::channel;

    #[test]
    fn with_scratch_reuses_the_thread_arena() {
        let cap = with_scratch(|s| {
            s.dests.reserve(64);
            s.dests.capacity()
        });
        assert!(cap >= 64);
        let again = with_scratch(|s| s.dests.capacity());
        assert!(again >= cap, "the arena must persist across calls");
        // Re-entrancy degrades to a fresh arena instead of panicking.
        with_scratch(|_outer| {
            with_scratch(|inner| assert_eq!(inner.dests.capacity(), 0));
        });
    }

    #[test]
    fn runs_every_job_across_workers() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.size(), 4);
        assert!(pool.queue_depth() >= 1);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel::<usize>();
        for i in 0..64 {
            let counter = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                counter.fetch_add(1, Ordering::Relaxed);
                tx.send(i).unwrap();
            });
        }
        drop(tx);
        let mut got: Vec<usize> = rx.iter().collect();
        got.sort_unstable();
        assert_eq!(got, (0..64).collect::<Vec<_>>());
        assert_eq!(counter.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn zero_size_is_clamped_to_one() {
        let pool = WorkerPool::with_queue_depth(0, 0);
        assert_eq!(pool.size(), 1);
        assert_eq!(pool.queue_depth(), 1);
        let (tx, rx) = channel::<u32>();
        pool.execute(move || tx.send(7).unwrap());
        assert_eq!(rx.recv().unwrap(), 7);
    }

    #[test]
    fn panicking_job_does_not_kill_the_worker() {
        let pool = WorkerPool::new(1);
        pool.execute(|| panic!("one bad request"));
        // The single worker must survive to run the next job.
        let (tx, rx) = channel::<u32>();
        pool.execute(move || tx.send(11).unwrap());
        assert_eq!(rx.recv().unwrap(), 11);
    }

    #[test]
    fn drop_drains_outstanding_jobs() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = WorkerPool::new(2);
            for _ in 0..32 {
                let counter = Arc::clone(&counter);
                pool.execute(move || {
                    counter.fetch_add(1, Ordering::Relaxed);
                });
            }
        } // Drop joins the workers after the queue drains.
        assert_eq!(counter.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn try_execute_reports_busy_when_the_queue_is_full() {
        // One worker, one queue slot. Wedge the worker on a gate, fill
        // the single slot, and the third submission must bounce.
        let pool = WorkerPool::with_queue_depth(1, 1);
        let (gate_tx, gate_rx) = channel::<()>();
        pool.execute(move || {
            gate_rx.recv().unwrap();
        });
        // The worker may or may not have dequeued the gate job yet;
        // keep try-filling until the queue slot is occupied.
        while pool.try_execute(|| {}).is_ok() {}
        assert_eq!(pool.try_execute(|| {}), Err(Busy));
        gate_tx.send(()).unwrap();
        // After the gate opens, the queue drains and submissions flow.
        let (tx, rx) = channel::<u32>();
        loop {
            let tx = tx.clone();
            if pool.try_execute(move || tx.send(5).unwrap()).is_ok() {
                break;
            }
            std::thread::yield_now();
        }
        assert_eq!(rx.recv().unwrap(), 5);
    }
}
