//! A sharded, concurrently readable LRU cache with per-key
//! singleflight build gates.
//!
//! Backs the engine's trace/plan cache and its uploaded-trace store.
//! The previous design put one `Mutex<LruCache>` in front of every
//! lookup, so under concurrent service load the *hit* path — a hash
//! probe and an `Arc` clone — serialized across all connections. This
//! version stripes the key space over N independent shards:
//!
//! * **reads scale**: each shard's map sits behind an `RwLock`; a hit
//!   takes a read guard, bumps an atomic recency stamp, and clones the
//!   value — any number of threads hit concurrently, across shards
//!   *and* within one;
//! * **writers only block their shard**: an insert (or an LRU eviction)
//!   write-locks one shard; hits on the other shards proceed;
//! * **singleflight is per key, waiting is per shard**: `claim` hands
//!   exactly one caller a [`BuildGuard`] for a cold key; everyone else
//!   parks on that shard's `Condvar` and wakes into a cache hit when
//!   the builder [`BuildGuard::complete`]s (or retries the claim if
//!   the builder failed). A build in one shard never blocks a hit —
//!   or another build — anywhere else, and even two builds of distinct
//!   keys in the *same* shard run in parallel (they only share the
//!   wake-up signal);
//! * **`len` is lock-free**: entry counts are maintained in an atomic
//!   so stats snapshots never touch a shard lock.
//!
//! Capacity is split evenly across shards, so bounds are enforced
//! per shard (a pathological key distribution can evict slightly
//! early — acceptable for a cache whose values are recomputable).
//! Small capacities collapse to a single shard, which preserves exact
//! global LRU order; the shard count only grows once there is enough
//! capacity for striping to matter.

use std::collections::{HashMap, HashSet};
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering::Relaxed};
use std::sync::{Condvar, Mutex, RwLock};

/// Upper bound on the shard count (capacity permitting).
pub const MAX_SHARDS: usize = 16;

/// Minimum per-shard capacity before another shard is worth adding.
const TARGET_PER_SHARD: usize = 8;

struct Entry<V> {
    value: V,
    /// Recency stamp, updated through a shared reference on the read
    /// path (so hits never need the write lock).
    last_used: AtomicU64,
}

struct Shard<K, V> {
    map: RwLock<HashMap<K, Entry<V>>>,
    tick: AtomicU64,
    /// Keys currently being built by some thread (singleflight gates).
    building: Mutex<HashSet<K>>,
    /// Signaled whenever a build completes or aborts; waiters re-check
    /// the map and either hit or take over the build.
    built: Condvar,
}

impl<K: Eq + Hash + Clone, V: Clone> Shard<K, V> {
    fn new() -> Self {
        Shard {
            map: RwLock::new(HashMap::new()),
            tick: AtomicU64::new(0),
            building: Mutex::new(HashSet::new()),
            built: Condvar::new(),
        }
    }

    fn next_tick(&self) -> u64 {
        self.tick.fetch_add(1, Relaxed) + 1
    }

    fn get(&self, key: &K) -> Option<V> {
        let map = self.map.read().unwrap();
        map.get(key).map(|e| {
            e.last_used.store(self.next_tick(), Relaxed);
            e.value.clone()
        })
    }
}

/// The result of [`ShardedLru::claim`]: either the cached value, or an
/// exclusive license to build it.
pub enum Claim<'a, K: Eq + Hash + Clone, V: Clone> {
    /// The key was resident (possibly because another thread finished
    /// building it while this one waited).
    Hit(V),
    /// This caller is the designated builder for the key. Build the
    /// value outside any lock, then [`BuildGuard::complete`]. Dropping
    /// the guard without completing (error or panic paths) releases the
    /// gate so waiters can retry — a failed build never wedges a key.
    Build(BuildGuard<'a, K, V>),
}

/// Exclusive build license for one key (see [`Claim`]).
pub struct BuildGuard<'a, K: Eq + Hash + Clone, V: Clone> {
    cache: &'a ShardedLru<K, V>,
    key: K,
    done: bool,
}

impl<K: Eq + Hash + Clone, V: Clone> BuildGuard<'_, K, V> {
    /// The key this guard licenses.
    pub fn key(&self) -> &K {
        &self.key
    }

    /// Publish the built value and wake every waiter into a cache hit.
    pub fn complete(mut self, value: V) {
        self.done = true;
        // Insert *before* releasing the gate: a waiter that wakes (or
        // re-checks under the `building` lock) must observe the value.
        self.cache.insert(self.key.clone(), value);
        self.release();
    }

    fn release(&self) {
        let shard = self.cache.shard(&self.key);
        shard.building.lock().unwrap().remove(&self.key);
        shard.built.notify_all();
    }
}

impl<K: Eq + Hash + Clone, V: Clone> Drop for BuildGuard<'_, K, V> {
    fn drop(&mut self) {
        if !self.done {
            self.release();
        }
    }
}

/// Least-recently-used cache striped over lock-independent shards.
pub struct ShardedLru<K: Eq + Hash + Clone, V: Clone> {
    shards: Vec<Shard<K, V>>,
    /// Power of two, so shard selection is a mask.
    shard_mask: usize,
    shard_capacity: usize,
    capacity: usize,
    len: AtomicUsize,
}

impl<K: Eq + Hash + Clone, V: Clone> ShardedLru<K, V> {
    /// Create a cache holding at most (approximately) `capacity`
    /// entries, sharded as widely as the capacity justifies.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "cache capacity must be positive");
        let shards = (capacity / TARGET_PER_SHARD)
            .max(1)
            .next_power_of_two()
            .min(MAX_SHARDS);
        Self::with_shards(capacity, shards)
    }

    /// Explicit shard count (rounded up to a power of two). Exposed so
    /// tests can pin deterministic single-shard LRU semantics.
    pub fn with_shards(capacity: usize, shards: usize) -> Self {
        assert!(capacity > 0, "cache capacity must be positive");
        let n = shards.max(1).next_power_of_two();
        ShardedLru {
            shards: (0..n).map(|_| Shard::new()).collect(),
            shard_mask: n - 1,
            shard_capacity: capacity.div_ceil(n),
            capacity,
            len: AtomicUsize::new(0),
        }
    }

    fn shard(&self, key: &K) -> &Shard<K, V> {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) & self.shard_mask]
    }

    /// Look up a key, refreshing its recency on a hit. Takes only a
    /// shard read lock — hits never serialize against each other.
    pub fn get(&self, key: &K) -> Option<V> {
        self.shard(key).get(key)
    }

    /// Insert (or replace) a key, evicting that shard's LRU entry if
    /// the shard is over capacity.
    pub fn insert(&self, key: K, value: V) {
        let shard = self.shard(&key);
        let tick = shard.next_tick();
        let mut map = shard.map.write().unwrap();
        let prev = map.insert(
            key,
            Entry {
                value,
                last_used: AtomicU64::new(tick),
            },
        );
        if prev.is_none() {
            self.len.fetch_add(1, Relaxed);
            if map.len() > self.shard_capacity && Self::evict_lru(&mut map) {
                self.len.fetch_sub(1, Relaxed);
            }
        }
    }

    /// Insert unless the key is already resident; returns the resident
    /// value and whether this call inserted it. The check and the
    /// insert happen under one shard write lock, so two racing callers
    /// agree on a single winner.
    pub fn get_or_insert(&self, key: K, value: V) -> (V, bool) {
        let shard = self.shard(&key);
        let tick = shard.next_tick();
        let mut map = shard.map.write().unwrap();
        if let Some(e) = map.get(&key) {
            e.last_used.store(tick, Relaxed);
            return (e.value.clone(), false);
        }
        let out = value.clone();
        map.insert(
            key,
            Entry {
                value,
                last_used: AtomicU64::new(tick),
            },
        );
        self.len.fetch_add(1, Relaxed);
        if map.len() > self.shard_capacity && Self::evict_lru(&mut map) {
            self.len.fetch_sub(1, Relaxed);
        }
        (out, true)
    }

    fn evict_lru(map: &mut HashMap<K, Entry<V>>) -> bool {
        let oldest = map
            .iter()
            .min_by_key(|(_, e)| e.last_used.load(Relaxed))
            .map(|(k, _)| k.clone());
        match oldest {
            Some(k) => map.remove(&k).is_some(),
            None => false,
        }
    }

    /// Hit the cache or become the key's designated builder.
    ///
    /// At most one [`BuildGuard`] exists per key at a time; concurrent
    /// claimers of the same cold key block on this shard's condvar and
    /// return `Hit` once the builder completes. Claims of *different*
    /// keys never wait on each other, whichever shard they land in.
    pub fn claim(&self, key: &K) -> Claim<'_, K, V> {
        let shard = self.shard(key);
        if let Some(v) = shard.get(key) {
            return Claim::Hit(v);
        }
        let mut building = shard.building.lock().unwrap();
        loop {
            // Re-check under the gate lock: a builder publishes to the
            // map before releasing its gate, so a miss here plus an
            // absent gate really means "nobody is building".
            if let Some(v) = shard.get(key) {
                return Claim::Hit(v);
            }
            if !building.contains(key) {
                building.insert(key.clone());
                return Claim::Build(BuildGuard {
                    cache: self,
                    key: key.clone(),
                    done: false,
                });
            }
            building = shard.built.wait(building).unwrap();
        }
    }

    /// Resident entries, maintained atomically — reading it never takes
    /// a shard lock (used by lock-free stats snapshots).
    pub fn len(&self) -> usize {
        self.len.load(Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total configured capacity (split across shards).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Number of lock-independent shards.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Visit every resident entry under its shard's read lock, without
    /// refreshing recency. Used by the engine's incremental
    /// plan-extension step (`register_device` appends a new device's
    /// lanes to each cached plan exactly once). `f` must not call back
    /// into this cache — a same-shard write would deadlock.
    pub fn for_each(&self, mut f: impl FnMut(&K, &V)) {
        for shard in &self.shards {
            let map = shard.map.read().unwrap();
            for (k, e) in map.iter() {
                f(k, &e.value);
            }
        }
    }

    /// Drop every entry (build gates are untouched: in-flight builders
    /// simply publish into an emptier cache).
    pub fn clear(&self) {
        for shard in &self.shards {
            let mut map = shard.map.write().unwrap();
            self.len.fetch_sub(map.len(), Relaxed);
            map.clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::{mpsc, Arc};

    #[test]
    fn hit_and_miss() {
        let c: ShardedLru<u32, String> = ShardedLru::new(4);
        assert!(c.get(&1).is_none());
        c.insert(1, "one".into());
        assert_eq!(c.get(&1).as_deref(), Some("one"));
        assert!(!c.is_empty());
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn single_shard_evicts_least_recently_used() {
        // Capacity 2 collapses to one shard → exact global LRU order.
        let c: ShardedLru<u32, u32> = ShardedLru::new(2);
        assert_eq!(c.shards(), 1);
        c.insert(1, 10);
        c.insert(2, 20);
        // Touch 1 so 2 becomes the LRU entry.
        assert_eq!(c.get(&1), Some(10));
        c.insert(3, 30);
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(&2), None, "2 was least recently used");
        assert_eq!(c.get(&1), Some(10));
        assert_eq!(c.get(&3), Some(30));
    }

    #[test]
    fn replacing_does_not_evict() {
        let c: ShardedLru<u32, u32> = ShardedLru::new(2);
        c.insert(1, 10);
        c.insert(2, 20);
        c.insert(1, 11);
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(&1), Some(11));
        assert_eq!(c.get(&2), Some(20));
    }

    #[test]
    fn clear_empties() {
        let c: ShardedLru<u32, u32> = ShardedLru::new(2);
        c.insert(1, 10);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.capacity(), 2);
    }

    #[test]
    fn default_capacity_shards_out() {
        let c: ShardedLru<u32, u32> = ShardedLru::new(128);
        assert_eq!(c.shards(), MAX_SHARDS);
        for i in 0..100u32 {
            c.insert(i, i);
        }
        assert!(c.len() <= 128);
        assert!(c.len() >= 90, "per-shard bounds must not evict aggressively");
    }

    #[test]
    fn get_or_insert_keeps_the_first_value() {
        let c: ShardedLru<u32, u32> = ShardedLru::new(8);
        let (v, inserted) = c.get_or_insert(1, 10);
        assert_eq!((v, inserted), (10, true));
        let (v, inserted) = c.get_or_insert(1, 99);
        assert_eq!((v, inserted), (10, false));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn for_each_visits_every_resident_entry() {
        let c: ShardedLru<u32, u32> = ShardedLru::new(64);
        for i in 0..20u32 {
            c.insert(i, i * 10);
        }
        let mut seen: Vec<(u32, u32)> = Vec::new();
        c.for_each(|k, v| seen.push((*k, *v)));
        seen.sort_unstable();
        assert_eq!(seen, (0..20u32).map(|i| (i, i * 10)).collect::<Vec<_>>());
        // Visiting must not perturb LRU recency enough to break reads.
        assert_eq!(c.get(&0), Some(0));
    }

    #[test]
    fn claim_builds_once_and_waiters_hit() {
        let c: Arc<ShardedLru<String, u32>> = Arc::new(ShardedLru::new(16));
        let builds = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..8 {
                let c = Arc::clone(&c);
                let builds = Arc::clone(&builds);
                s.spawn(move || match c.claim(&"k".to_string()) {
                    Claim::Hit(v) => assert_eq!(v, 7),
                    Claim::Build(guard) => {
                        builds.fetch_add(1, Relaxed);
                        // Make the build slow enough that the herd piles
                        // onto the condvar.
                        std::thread::sleep(std::time::Duration::from_millis(20));
                        guard.complete(7);
                    }
                });
            }
        });
        assert_eq!(builds.load(Relaxed), 1, "exactly one thread builds");
        assert_eq!(c.get(&"k".to_string()), Some(7));
    }

    #[test]
    fn abandoned_build_releases_the_gate() {
        let c: ShardedLru<String, u32> = ShardedLru::new(16);
        match c.claim(&"k".to_string()) {
            Claim::Build(guard) => drop(guard), // builder failed
            Claim::Hit(_) => panic!("cold key cannot hit"),
        }
        // The key is claimable again (a wedged gate would make this
        // claim wait forever).
        match c.claim(&"k".to_string()) {
            Claim::Build(guard) => guard.complete(1),
            Claim::Hit(_) => panic!("nothing was published"),
        }
        assert_eq!(c.get(&"k".to_string()), Some(1));
    }

    #[test]
    fn building_one_key_does_not_block_other_keys() {
        // Deterministic cross-key independence: hold a build gate open
        // on one key and prove that claims and completions of *other*
        // keys run to completion meanwhile (if they blocked, this test
        // would hang, not fail an assert).
        let c: Arc<ShardedLru<String, u32>> = Arc::new(ShardedLru::new(64));
        let (release_tx, release_rx) = mpsc::channel::<()>();
        let (claimed_tx, claimed_rx) = mpsc::channel::<()>();
        let slow = {
            let c = Arc::clone(&c);
            std::thread::spawn(move || match c.claim(&"slow".to_string()) {
                Claim::Build(guard) => {
                    claimed_tx.send(()).unwrap();
                    release_rx.recv().unwrap(); // gate stays held
                    guard.complete(1);
                }
                Claim::Hit(_) => panic!("cold key cannot hit"),
            })
        };
        claimed_rx.recv().unwrap();
        // With "slow" mid-build, every other key remains fully usable.
        for i in 0..32u32 {
            let key = format!("fast-{i}");
            match c.claim(&key) {
                Claim::Build(guard) => guard.complete(i),
                Claim::Hit(_) => panic!("cold key cannot hit"),
            }
            assert_eq!(c.get(&key), Some(i));
        }
        release_tx.send(()).unwrap();
        slow.join().unwrap();
        assert_eq!(c.get(&"slow".to_string()), Some(1));
    }

    #[test]
    fn concurrent_reads_and_writes_keep_len_consistent() {
        let c: Arc<ShardedLru<u32, u32>> = Arc::new(ShardedLru::new(256));
        std::thread::scope(|s| {
            for t in 0..8u32 {
                let c = Arc::clone(&c);
                s.spawn(move || {
                    for i in 0..64 {
                        let k = t * 64 + i;
                        c.insert(k, k);
                        assert_eq!(c.get(&k), Some(k));
                    }
                });
            }
        });
        // 512 inserts into capacity 256: bounded, and len agrees with a
        // full recount.
        let n = c.len();
        assert!(n <= 256, "len {n} exceeds capacity");
        let recount: usize = (0..512u32).filter(|k| c.get(k).is_some()).count();
        assert_eq!(n, recount, "atomic len must match resident entries");
    }
}
