//! A small content-keyed LRU cache.
//!
//! Backs the engine's trace cache. Capacity is expected to be modest
//! (hundreds of entries, each an `Arc` to a shared trace), so eviction
//! does an O(n) scan for the least-recently-used entry instead of
//! maintaining an intrusive list — simpler, and invisible next to the
//! cost of producing one cache value (tracking a model is milliseconds;
//! the scan is nanoseconds).

use std::collections::HashMap;
use std::hash::Hash;

struct Entry<V> {
    value: V,
    last_used: u64,
}

/// Least-recently-used cache over hashable keys.
pub struct LruCache<K: Eq + Hash + Clone, V: Clone> {
    capacity: usize,
    tick: u64,
    map: HashMap<K, Entry<V>>,
}

impl<K: Eq + Hash + Clone, V: Clone> LruCache<K, V> {
    /// Create a cache holding at most `capacity` entries.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "LRU capacity must be positive");
        LruCache {
            capacity,
            tick: 0,
            map: HashMap::new(),
        }
    }

    /// Look up a key, refreshing its recency on a hit.
    pub fn get(&mut self, key: &K) -> Option<V> {
        self.tick += 1;
        let tick = self.tick;
        self.map.get_mut(key).map(|e| {
            e.last_used = tick;
            e.value.clone()
        })
    }

    /// Insert (or replace) a key, evicting the least-recently-used entry
    /// if the cache is over capacity.
    pub fn insert(&mut self, key: K, value: V) {
        self.tick += 1;
        let tick = self.tick;
        self.map.insert(key, Entry { value, last_used: tick });
        if self.map.len() > self.capacity {
            if let Some(oldest) = self
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
            {
                self.map.remove(&oldest);
            }
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn clear(&mut self) {
        self.map.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_and_miss() {
        let mut c: LruCache<u32, String> = LruCache::new(4);
        assert!(c.get(&1).is_none());
        c.insert(1, "one".into());
        assert_eq!(c.get(&1).as_deref(), Some("one"));
        assert!(!c.is_empty());
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c: LruCache<u32, u32> = LruCache::new(2);
        c.insert(1, 10);
        c.insert(2, 20);
        // Touch 1 so 2 becomes the LRU entry.
        assert_eq!(c.get(&1), Some(10));
        c.insert(3, 30);
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(&2), None, "2 was least recently used");
        assert_eq!(c.get(&1), Some(10));
        assert_eq!(c.get(&3), Some(30));
    }

    #[test]
    fn replacing_does_not_evict() {
        let mut c: LruCache<u32, u32> = LruCache::new(2);
        c.insert(1, 10);
        c.insert(2, 20);
        c.insert(1, 11);
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(&1), Some(11));
        assert_eq!(c.get(&2), Some(20));
    }

    #[test]
    fn clear_empties() {
        let mut c: LruCache<u32, u32> = LruCache::new(2);
        c.insert(1, 10);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.capacity(), 2);
    }
}
