//! Memoized occupancy / wave-size table, shared by the simulator and
//! wave scaling.
//!
//! Both the ground-truth [`crate::sim::Simulator`] and the predictor's
//! [`crate::predict::wave`] need `W_i`, the wave size of a kernel launch
//! on a device. The underlying calculation
//! ([`crate::device::occupancy::blocks_per_sm`]) is pure and depends only
//! on `(device, threads_per_block, regs_per_thread, smem_per_block)` —
//! notably *not* on the grid size — so the result space is tiny (a few
//! hundred distinct launch shapes per device across the whole model zoo)
//! while the call count is enormous (every kernel of every trace of every
//! prediction). This table memoizes it process-wide behind an `RwLock`:
//! the steady state is read-only and uncontended.
//!
//! Hit/miss counters are exported through
//! [`crate::engine::PredictionEngine::stats`] so benches and tests can
//! observe the sharing.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{OnceLock, RwLock};

use crate::device::{occupancy, Device, GpuSpec, LaunchConfig};

/// The occupancy-relevant projection of `(device, LaunchConfig)`:
/// `grid_blocks` is dropped because resident blocks per SM do not depend
/// on how many blocks the grid has in total.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct OccKey {
    device: Device,
    threads_per_block: u32,
    regs_per_thread: u32,
    smem_per_block: u32,
}

impl OccKey {
    fn new(spec: &GpuSpec, cfg: &LaunchConfig) -> Self {
        OccKey {
            device: spec.device,
            threads_per_block: cfg.threads_per_block,
            regs_per_thread: cfg.regs_per_thread,
            smem_per_block: cfg.smem_per_block,
        }
    }
}

/// Process-wide memo table for blocks-per-SM (and everything derived
/// from it: wave size, occupancy fraction).
#[derive(Default)]
pub struct WaveTable {
    table: RwLock<HashMap<OccKey, u32>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl WaveTable {
    /// The shared table used by [`crate::sim`] and [`crate::predict::wave`].
    pub fn global() -> &'static WaveTable {
        static GLOBAL: OnceLock<WaveTable> = OnceLock::new();
        GLOBAL.get_or_init(WaveTable::default)
    }

    /// Memoized [`occupancy::blocks_per_sm`].
    pub fn blocks_per_sm(&self, spec: &GpuSpec, cfg: &LaunchConfig) -> u32 {
        let key = OccKey::new(spec, cfg);
        if let Some(v) = self.table.read().unwrap().get(&key) {
            self.hits.fetch_add(1, Relaxed);
            return *v;
        }
        self.misses.fetch_add(1, Relaxed);
        let v = occupancy::blocks_per_sm(spec, cfg);
        self.table.write().unwrap().insert(key, v);
        v
    }

    /// Memoized [`occupancy::wave_size`]: resident blocks across the chip.
    pub fn wave_size(&self, spec: &GpuSpec, cfg: &LaunchConfig) -> u64 {
        self.blocks_per_sm(spec, cfg) as u64 * spec.sms as u64
    }

    /// Memoized [`occupancy::occupancy_fraction`].
    pub fn occupancy_fraction(&self, spec: &GpuSpec, cfg: &LaunchConfig) -> f64 {
        let resident = self.blocks_per_sm(spec, cfg) as f64 * cfg.threads_per_block as f64;
        (resident / spec.max_threads_per_sm as f64).min(1.0)
    }

    /// (hits, misses) since process start.
    pub fn counters(&self) -> (u64, u64) {
        (self.hits.load(Relaxed), self.misses.load(Relaxed))
    }

    /// Distinct launch shapes memoized so far.
    pub fn len(&self) -> usize {
        self.table.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::ALL_DEVICES;

    fn launch(blocks: u64) -> LaunchConfig {
        LaunchConfig::new(blocks, 256, 32, 0)
    }

    #[test]
    fn matches_direct_calculation() {
        let t = WaveTable::default();
        for d in ALL_DEVICES {
            let spec = d.spec();
            for cfg in [
                LaunchConfig::new(1024, 256, 32, 0),
                LaunchConfig::new(64, 1024, 128, 48 * 1024),
                LaunchConfig::new(1, 32, 16, 0),
            ] {
                assert_eq!(t.blocks_per_sm(spec, &cfg), occupancy::blocks_per_sm(spec, &cfg));
                assert_eq!(t.wave_size(spec, &cfg), occupancy::wave_size(spec, &cfg));
                assert!(
                    (t.occupancy_fraction(spec, &cfg) - occupancy::occupancy_fraction(spec, &cfg))
                        .abs()
                        < 1e-12
                );
            }
        }
    }

    #[test]
    fn grid_size_does_not_fragment_the_table() {
        let t = WaveTable::default();
        let spec = Device::V100.spec();
        t.wave_size(spec, &launch(1));
        t.wave_size(spec, &launch(1_000_000));
        assert_eq!(t.len(), 1, "grid_blocks must not be part of the key");
        let (hits, misses) = t.counters();
        assert_eq!((hits, misses), (1, 1));
    }

    #[test]
    fn second_lookup_hits() {
        let t = WaveTable::default();
        let spec = Device::T4.spec();
        let cfg = LaunchConfig::new(77, 128, 64, 1024);
        let a = t.wave_size(spec, &cfg);
        let b = t.wave_size(spec, &cfg);
        assert_eq!(a, b);
        let (hits, misses) = t.counters();
        assert_eq!(misses, 1);
        assert!(hits >= 1);
    }
}
