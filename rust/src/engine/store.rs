//! Persistent, content-addressed plan store — durable warm starts for
//! the prediction engine.
//!
//! The paper's workflow profiles once and predicts many times, so a
//! restarted predictor that recompiles its whole zoo from scratch is
//! pure waste: nothing about a compiled [`AnalyzedPlan`] depends on the
//! process that built it. This store persists each analyzed trace as
//! one record file under the trace's existing content id (`tr-<hash>`
//! of its canonical JSON), containing the compact binary trace plus the
//! plan's dense per-device lane tables as raw bit patterns. On the next
//! boot the engine replays the device-registry log, loads every record,
//! and reruns only the cheap destination-independent prefix walk —
//! `AnalyzedPlan::from_parts` installs the stored lanes verbatim, so
//! a restored plan is **bit-identical** to a freshly compiled one by
//! construction (the golden suite referees this).
//!
//! Robustness over trust: every record carries a magic, a format
//! version, a payload length, and an FNV-1a checksum, plus the metrics
//! policy fingerprint and the device-name snapshot it was compiled
//! against. Any mismatch — truncation, bit flip, version bump, policy
//! change, foreign registry — makes [`PlanStore::load`] return `None`
//! and the engine transparently rebuilds from source; a corrupt file is
//! never an error the caller sees. Writes go to a unique temp file and
//! `rename` into place, so a crash mid-write leaves either the old
//! record or a stray `*.tmp-*` that the next [`PlanStore::open`]
//! sweeps away — never a half-written record under a live name.

use std::collections::HashMap;
use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex, RwLock};

use crate::device::registry::{self, NewDevice};
use crate::device::Arch;
use crate::plan::{AnalyzedPlan, AnalyzedTrace, DenseLanes};
use crate::predict::MetricsPolicy;
use crate::tracker::Trace;
use crate::util::binio::{Reader, Writer};
use crate::util::json::{self, Json};
use crate::util::rng::hash_str;
use crate::Result;

use super::TraceKey;

/// Record-file magic: identifies a habitat plan record.
const MAGIC: &[u8; 8] = b"HABPLAN\0";

/// Bump on any change to the record payload layout. A version mismatch
/// is a silent miss (rebuild), never a parse attempt.
pub const STORE_FORMAT_VERSION: u32 = 1;

/// Plan-record filename extension.
const RECORD_EXT: &str = "plan";

/// Append-only device-registration log (JSON lines, one [`NewDevice`]
/// per line), replayed through the idempotent registry at open so
/// stored lane tables for runtime-registered devices stay meaningful.
const DEVICES_LOG: &str = "devices.log";

/// What a record holds: a zoo-model compilation (restored into the
/// engine's keyed trace cache) or a client-uploaded trace (restored
/// into the upload cache under its content id).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoredKind {
    Zoo,
    Upload,
}

/// FNV-1a over raw bytes (the byte-slice sibling of
/// [`crate::util::rng::hash_str`]): cheap, dependency-free corruption
/// detection — this is an integrity check, not an authenticity one.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The on-disk plan store. `Send + Sync`; the engine wraps it in an
/// `Arc` and saves from pool workers (write-behind).
pub struct PlanStore {
    dir: PathBuf,
    /// Fingerprint of the metrics policy the owning engine compiles
    /// with (`format!("{policy:?}")` — the variants derive a stable
    /// `Debug`). A record built under a different policy has different
    /// γ lanes, so it must miss rather than load.
    policy_fp: String,
    /// Zoo-key → record id, populated by every successful zoo
    /// [`PlanStore::load`]/[`PlanStore::save`]: lets the engine find a
    /// record again after its cache entry ages out of the LRU.
    index: RwLock<HashMap<TraceKey, String>>,
    /// Serializes appends to `devices.log` (registrations are rare).
    log: Mutex<()>,
    tmp_seq: AtomicU64,
}

impl PlanStore {
    /// Open (or create) a store directory: sweep temp-file debris from
    /// a previous crash, then replay the device log so every device a
    /// stored record references exists again. Corrupt log lines (e.g.
    /// a torn trailing write) are skipped, not fatal.
    pub fn open<P: AsRef<Path>>(dir: P, policy: &MetricsPolicy) -> Result<PlanStore> {
        let dir = dir.as_ref().to_path_buf();
        fs::create_dir_all(&dir)?;
        for entry in fs::read_dir(&dir)?.flatten() {
            let name = entry.file_name();
            if name.to_string_lossy().contains(".tmp-") {
                fs::remove_file(entry.path()).ok();
            }
        }
        let store = PlanStore {
            dir,
            policy_fp: format!("{policy:?}"),
            index: RwLock::new(HashMap::new()),
            log: Mutex::new(()),
            tmp_seq: AtomicU64::new(0),
        };
        store.replay_device_log();
        Ok(store)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Every record id currently on disk, sorted (deterministic restore
    /// order).
    pub fn ids(&self) -> Vec<String> {
        let mut ids = Vec::new();
        if let Ok(entries) = fs::read_dir(&self.dir) {
            for entry in entries.flatten() {
                let path = entry.path();
                if path.extension().is_some_and(|e| e == RECORD_EXT) {
                    if let Some(stem) = path.file_stem().and_then(|s| s.to_str()) {
                        ids.push(stem.to_string());
                    }
                }
            }
        }
        ids.sort();
        ids
    }

    /// The record id a zoo key was last stored or loaded under, if any.
    pub fn lookup(&self, key: &TraceKey) -> Option<String> {
        self.index.read().unwrap().get(key).cloned()
    }

    fn record_path(&self, id: &str) -> PathBuf {
        self.dir.join(format!("{id}.{RECORD_EXT}"))
    }

    /// Persist one analyzed trace under its content id. Idempotent and
    /// last-writer-wins: the record is fully rewritten via temp file +
    /// `rename`, so readers only ever see a complete record.
    pub fn save(&self, kind: StoredKind, entry: &AnalyzedTrace) -> Result<String> {
        let canonical = entry.trace.to_json();
        let id = format!("tr-{:016x}", hash_str(&canonical));

        let mut payload = Writer::new();
        payload.u8(match kind {
            StoredKind::Zoo => 0,
            StoredKind::Upload => 1,
        });
        payload.str(&self.policy_fp);
        // The device-name snapshot the dense lanes are indexed by:
        // validated prefix-wise at load (the registry is append-only,
        // so a valid snapshot stays a prefix of the live registry).
        let names = registry::device_names();
        let n_devices = entry.plan.n_devices();
        payload.u32(n_devices as u32);
        for name in names.iter().take(n_devices) {
            payload.str(name);
        }
        entry.trace.encode_binary(&mut payload);
        let (wave_origin, wave_dest, gamma, amp) = entry.plan.lane_tables();
        payload.u64_slice(wave_origin);
        payload.u64_slice(wave_dest);
        payload.f64_slice(gamma);
        payload.f64_slice(amp);
        let payload = payload.into_bytes();

        let mut file = Writer::new();
        file.raw(MAGIC);
        file.u32(STORE_FORMAT_VERSION);
        file.u64(payload.len() as u64);
        file.u64(fnv1a(&payload));
        file.raw(&payload);

        let tmp = self.dir.join(format!(
            "{id}.{RECORD_EXT}.tmp-{}-{}",
            std::process::id(),
            self.tmp_seq.fetch_add(1, Relaxed)
        ));
        fs::write(&tmp, file.into_bytes())?;
        fs::rename(&tmp, self.record_path(&id))?;

        if kind == StoredKind::Zoo {
            let key: TraceKey = (
                entry.trace.model.clone(),
                entry.trace.batch_size,
                entry.trace.origin,
                entry.trace.precision,
            );
            self.index.write().unwrap().insert(key, id.clone());
        }
        Ok(id)
    }

    /// Load and validate one record; `None` on *any* defect (missing,
    /// truncated, corrupt, wrong version, different policy, foreign
    /// device snapshot) — the caller recompiles from source. The plan
    /// is reassembled through `AnalyzedPlan::from_parts`, which
    /// reruns the prefix walk and installs the stored lanes
    /// bit-for-bit.
    pub fn load(&self, id: &str) -> Option<(StoredKind, AnalyzedTrace)> {
        let bytes = fs::read(self.record_path(id)).ok()?;
        let mut r = Reader::new(&bytes);

        if r.u64().ok()? != u64::from_le_bytes(*MAGIC) {
            return None;
        }
        if r.u32().ok()? != STORE_FORMAT_VERSION {
            return None;
        }
        let payload_len = r.u64().ok()? as usize;
        let checksum = r.u64().ok()?;
        if r.remaining() != payload_len {
            return None;
        }
        let payload = &bytes[bytes.len() - payload_len..];
        if fnv1a(payload) != checksum {
            return None;
        }

        let mut r = Reader::new(payload);
        let kind = match r.u8().ok()? {
            0 => StoredKind::Zoo,
            1 => StoredKind::Upload,
            _ => return None,
        };
        if r.str().ok()? != self.policy_fp {
            return None;
        }
        // The stored snapshot must be a prefix of the live registry —
        // same names, same order — or the dense lane indices would
        // point at different hardware.
        let n_devices = r.u32().ok()? as usize;
        let live = registry::device_names();
        if n_devices > live.len() {
            return None;
        }
        for live_name in live.iter().take(n_devices) {
            if r.str().ok()? != *live_name {
                return None;
            }
        }
        let trace = Trace::decode_binary(&mut r).ok()?;
        let lanes = DenseLanes {
            n_devices,
            wave_origin: r.u64_vec().ok()?,
            wave_dest: r.u64_vec().ok()?,
            gamma: r.f64_vec().ok()?,
            amp_op_factor: r.f64_vec().ok()?,
        };
        if !r.is_empty() {
            return None; // trailing garbage: treat as corrupt
        }
        // Paranoia belt-and-braces: the filename must match the
        // content it claims to address.
        if id != format!("tr-{:016x}", hash_str(&trace.to_json())) {
            return None;
        }
        let plan = AnalyzedPlan::from_parts(&trace, &self.reparse_policy()?, lanes).ok()?;
        let entry = AnalyzedTrace {
            trace: Arc::new(trace),
            plan: Arc::new(plan),
        };
        if kind == StoredKind::Zoo {
            let key: TraceKey = (
                entry.trace.model.clone(),
                entry.trace.batch_size,
                entry.trace.origin,
                entry.trace.precision,
            );
            self.index.write().unwrap().insert(key, id.to_string());
        }
        Some((kind, entry))
    }

    /// Append one device registration to the durable log.
    pub fn record_device(&self, d: &NewDevice) -> Result<()> {
        let _guard = self.log.lock().unwrap();
        let mut f = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(self.dir.join(DEVICES_LOG))?;
        writeln!(f, "{}", device_to_json(d).dump())?;
        Ok(())
    }

    fn replay_device_log(&self) {
        let Ok(text) = fs::read_to_string(self.dir.join(DEVICES_LOG)) else {
            return;
        };
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            // A torn trailing line or a conflicting registration is
            // skipped: the log is best-effort durability, and any
            // record whose snapshot needs the missing device simply
            // misses and recompiles.
            let Ok(v) = json::parse(line) else { continue };
            let Ok(d) = device_from_json(&v) else { continue };
            let _ = registry::register(&d);
        }
    }

    /// Reconstruct the policy this store fingerprints. The engine only
    /// ever opens a store with its own policy, so this just re-parses
    /// the fingerprint it wrote; an unrecognized fingerprint (future
    /// variant) fails the load.
    fn reparse_policy(&self) -> Option<MetricsPolicy> {
        match self.policy_fp.as_str() {
            "All" => Some(MetricsPolicy::All),
            "None" => Some(MetricsPolicy::None),
            s => {
                let p = s.strip_prefix("Percentile(")?.strip_suffix(')')?;
                Some(MetricsPolicy::Percentile(p.parse().ok()?))
            }
        }
    }
}

fn device_to_json(d: &NewDevice) -> Json {
    let mut pairs = vec![
        ("name", Json::Str(d.name.clone())),
        ("sms", Json::Num(d.sms as f64)),
        ("clock_mhz", Json::Num(d.clock_mhz)),
        ("mem_bw_gbps", Json::Num(d.mem_bw_gbps)),
        ("fp32_tflops", Json::Num(d.fp32_tflops)),
        ("tensor_cores", Json::Bool(d.tensor_cores)),
    ];
    if let Some(v) = d.usd_per_hr {
        pairs.push(("usd_per_hr", Json::Num(v)));
    }
    if let Some(a) = d.arch {
        pairs.push(("arch", Json::Str(a.to_string())));
    }
    if let Some(v) = d.achieved_bw_gbps {
        pairs.push(("achieved_bw_gbps", Json::Num(v)));
    }
    if let Some(v) = d.mem_gib {
        pairs.push(("mem_gib", Json::Num(v)));
    }
    if let Some(v) = d.fp16_tflops {
        pairs.push(("fp16_tflops", Json::Num(v)));
    }
    if let Some(v) = d.cuda_cores {
        pairs.push(("cuda_cores", Json::Num(v as f64)));
    }
    if let Some(v) = d.l2_kib {
        pairs.push(("l2_kib", Json::Num(v as f64)));
    }
    Json::obj(pairs)
}

fn device_from_json(v: &Json) -> Result<NewDevice> {
    let num = |k: &str| -> Result<f64> {
        v.get(k)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("device log entry missing field {k:?}"))
    };
    let opt = |k: &str| v.get(k).and_then(Json::as_f64);
    let arch = match v.get("arch").and_then(Json::as_str) {
        Some(s) => Some(
            Arch::parse(s).ok_or_else(|| anyhow::anyhow!("unknown arch {s:?} in device log"))?,
        ),
        None => None,
    };
    Ok(NewDevice {
        name: v.req_str("name")?.to_string(),
        sms: num("sms")? as u32,
        clock_mhz: num("clock_mhz")?,
        mem_bw_gbps: num("mem_bw_gbps")?,
        fp32_tflops: num("fp32_tflops")?,
        tensor_cores: matches!(v.get("tensor_cores"), Some(Json::Bool(true))),
        usd_per_hr: opt("usd_per_hr"),
        arch,
        achieved_bw_gbps: opt("achieved_bw_gbps"),
        mem_gib: opt("mem_gib"),
        fp16_tflops: opt("fp16_tflops"),
        cuda_cores: opt("cuda_cores").map(|c| c as u32),
        l2_kib: opt("l2_kib").map(|c| c as u32),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Device;
    use crate::tracker::OperationTracker;

    fn unique_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "habitat_store_unit_{tag}_{}",
            std::process::id()
        ));
        fs::remove_dir_all(&dir).ok();
        dir
    }

    fn analyzed(model: &str, batch: usize) -> AnalyzedTrace {
        let graph = crate::models::by_name(model, batch).unwrap();
        let policy = MetricsPolicy::default();
        OperationTracker::new(Device::T4).track_analyzed(&graph, &policy)
    }

    #[test]
    fn save_load_roundtrip_is_bit_identical() {
        let dir = unique_dir("roundtrip");
        let policy = MetricsPolicy::default();
        let store = PlanStore::open(&dir, &policy).unwrap();
        let entry = analyzed("mlp", 16);
        let id = store.save(StoredKind::Zoo, &entry).unwrap();
        assert!(id.starts_with("tr-"));
        assert_eq!(store.ids(), vec![id.clone()]);

        let (kind, back) = store.load(&id).unwrap();
        assert_eq!(kind, StoredKind::Zoo);
        let (wo_a, wd_a, g_a, a_a) = entry.plan.lane_tables();
        let (wo_b, wd_b, g_b, a_b) = back.plan.lane_tables();
        assert_eq!(wo_a, wo_b);
        assert_eq!(wd_a, wd_b);
        assert_eq!(
            g_a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            g_b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(
            a_a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            a_b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        let key: TraceKey = ("mlp".into(), 16, Device::T4, crate::Precision::Fp32);
        assert_eq!(store.lookup(&key), Some(id));
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn policy_mismatch_misses() {
        let dir = unique_dir("policy");
        let store = PlanStore::open(&dir, &MetricsPolicy::default()).unwrap();
        let id = store.save(StoredKind::Zoo, &analyzed("mlp", 8)).unwrap();
        assert!(store.load(&id).is_some());
        let other = PlanStore::open(&dir, &MetricsPolicy::All).unwrap();
        assert!(other.load(&id).is_none(), "different policy must not load");
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn open_sweeps_tmp_debris_and_tolerates_garbage_log() {
        let dir = unique_dir("sweep");
        fs::create_dir_all(&dir).unwrap();
        fs::write(dir.join("tr-0000.plan.tmp-99-0"), b"half a record").unwrap();
        fs::write(dir.join(DEVICES_LOG), "not json at all\n{\"also\": \"junk\"}\n").unwrap();
        let store = PlanStore::open(&dir, &MetricsPolicy::default()).unwrap();
        assert!(store.ids().is_empty());
        assert!(!dir.join("tr-0000.plan.tmp-99-0").exists(), "debris swept");
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn device_log_roundtrips_and_replays() {
        let dir = unique_dir("devlog");
        let policy = MetricsPolicy::default();
        let store = PlanStore::open(&dir, &policy).unwrap();
        let desc = NewDevice {
            usd_per_hr: Some(0.75),
            arch: Some(Arch::Turing),
            mem_gib: Some(24.0),
            ..NewDevice::new("sim-store-devlog", 46, 1710.0, 448.0, 14.2, true)
        };
        let d = registry::register(&desc).unwrap();
        store.record_device(&desc).unwrap();
        drop(store);
        // Re-open replays the log; registration is idempotent, so the
        // device resolves to the same interned handle.
        let store = PlanStore::open(&dir, &policy).unwrap();
        drop(store);
        assert_eq!(Device::parse("sim-store-devlog"), Some(d));
        fs::remove_dir_all(&dir).ok();
    }
}
